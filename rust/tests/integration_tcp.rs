//! TCP multi-process runtime integration: a real leader + 3 worker
//! processes must reproduce the in-process trainer's numbers (the leader
//! mirrors worker state and runs the identical distributed W reduction).
//!
//! Runs on the native backend — no artifacts required.

use cgcn::util::cli::ArgSpec;

fn train_args(extra: &[&str]) -> cgcn::util::cli::Args {
    let base = [
        "train",
        "--dataset",
        "fig1",
        "--communities",
        "3",
        "--epochs",
        "3",
        "--hidden",
        "8",
    ];
    // Mirror main.rs's declared options (subset used by setup).
    let spec = ArgSpec::new("t", "test")
        .opt("dataset", Some("fig1"), "")
        .opt("scale", Some("0.25"), "")
        .opt("hidden", Some("8"), "")
        .opt("layers", Some("2"), "")
        .opt("epochs", Some("3"), "")
        .opt("communities", Some("3"), "")
        .opt("method", Some("admm"), "")
        .opt("partition", Some("metis"), "")
        .opt("rho", Some("auto"), "")
        .opt("nu", Some("auto"), "")
        .opt("lr", Some("auto"), "")
        .opt("seed", Some("17"), "")
        .opt("out", Some(""), "")
        .opt("transport", Some("local"), "")
        .opt("exec", Some("serial"), "")
        .opt("threads", Some("0"), "")
        .opt("backend", Some("auto"), "")
        .opt("link-mbps", Some("10000"), "")
        .opt("link-lat-us", Some("100"), "")
        .opt("listen", Some(""), "")
        .opt("worker-idx", Some("0"), "")
        .opt("save", Some(""), "")
        .opt("checkpoint-every", Some("0"), "")
        .opt("checkpoint-dir", Some("checkpoints"), "")
        .opt("resume", Some(""), "")
        .opt("hb-timeout-ms", Some("5000"), "")
        .opt("hb-interval-ms", Some("1000"), "")
        .flag("parallel-layers", "")
        .flag("csv", "");
    let toks: Vec<String> = base
        .iter()
        .chain(extra.iter())
        .map(|s| s.to_string())
        .collect();
    spec.parse(toks).unwrap()
}

#[test]
fn tcp_training_matches_local_training() {
    // Workers are spawned from the real cgcn binary.
    std::env::set_var("CGCN_WORKER_EXE", env!("CARGO_BIN_EXE_cgcn"));
    let dir = std::env::temp_dir().join(format!("cgcn_tcp_test_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let local_model = dir.join("local.cgnm");
    let tcp_model = dir.join("tcp.cgnm");

    let local_args = train_args(&["--save", local_model.to_str().unwrap()]);
    let local_setup = cgcn::coordinator::setup_from_args(&local_args).unwrap();
    let local = cgcn::coordinator::run_training(&local_setup, &local_args).unwrap();

    let tcp_args = train_args(&["--transport", "tcp", "--save", tcp_model.to_str().unwrap()]);
    let tcp_setup = cgcn::coordinator::setup_from_args(&tcp_args).unwrap();
    let tcp = cgcn::coordinator::run_training(&tcp_setup, &tcp_args).unwrap();

    assert_eq!(local.epochs.len(), tcp.epochs.len());
    for (a, b) in local.epochs.iter().zip(&tcp.epochs) {
        assert!(
            (a.loss - b.loss).abs() < 1e-4 * a.loss.abs().max(1.0),
            "epoch {}: local loss {} vs tcp {}",
            a.epoch,
            a.loss,
            b.loss
        );
        assert_eq!(a.train_acc, b.train_acc, "epoch {} train acc", a.epoch);
        assert_eq!(a.test_acc, b.test_acc, "epoch {} test acc", a.epoch);
    }
    // Bitwise: the snapshots only differ in the run label, so compare the
    // decoded weights.
    let lw = cgcn::serve::load_model(&local_model).unwrap();
    let tw = cgcn::serve::load_model(&tcp_model).unwrap();
    assert_eq!(lw.w.len(), tw.w.len());
    for (a, b) in lw.w.iter().zip(&tw.w) {
        assert_eq!(a.data(), b.data(), "tcp weights differ bitwise from local");
    }
    std::fs::remove_dir_all(&dir).ok();
    // Real bytes actually moved through the sockets.
    assert!(tcp.total_bytes() > 10_000, "tcp bytes {}", tcp.total_bytes());
}
