//! End-to-end ADMM / baseline integration tests on the fixture datasets.
//!
//! These run on the always-available native backend (and automatically
//! pick up the XLA artifact backend instead when the crate is built with
//! `--features xla` and `make artifacts` has been run).

use cgcn::baselines::{BaselineTrainer, Optimizer};
use cgcn::config::HyperParams;
use cgcn::coordinator::{AdmmOptions, AdmmTrainer, Workspace};
use cgcn::data::fixtures;
use cgcn::partition::Method;
use cgcn::runtime::{default_backend, ComputeBackend};
use std::sync::Arc;

fn backend() -> Arc<dyn ComputeBackend> {
    default_backend()
}

fn fig1_hp(m: usize) -> HyperParams {
    let mut hp = HyperParams::for_dataset("fig1");
    hp.hidden = 8;
    hp.communities = m;
    hp
}

#[test]
fn serial_admm_learns_fig1() {
    let backend = backend();
    let ds = fixtures::fig1();
    let ws = Arc::new(Workspace::build(&ds, &fig1_hp(1), Method::Metis).unwrap());
    let mut t = AdmmTrainer::new(ws, backend, AdmmOptions::for_mode(1)).unwrap();
    let rep = t.train(40, "serial").unwrap();
    assert!(
        rep.final_train_acc() >= 0.6 && rep.best_test_acc() >= 0.75,
        "serial ADMM failed to learn fig1: train {} best-test {}",
        rep.final_train_acc(),
        rep.best_test_acc()
    );
    // Loss decreased substantially.
    let first = rep.epochs.first().unwrap().loss;
    let last = rep.epochs.last().unwrap().loss;
    assert!(last < 0.6 * first, "loss {first} -> {last} did not drop");
}

#[test]
fn parallel_admm_learns_fig1_and_communicates() {
    let backend = backend();
    let ds = fixtures::fig1();
    let ws = Arc::new(Workspace::build(&ds, &fig1_hp(3), Method::Metis).unwrap());
    let mut t = AdmmTrainer::new(ws, backend, AdmmOptions::for_mode(3)).unwrap();
    let rep = t.train(40, "parallel").unwrap();
    assert!(rep.best_test_acc() >= 0.7, "best test {}", rep.best_test_acc());
    assert!(rep.total_bytes() > 0, "parallel mode shipped no bytes");
    assert!(rep.total_comm() > 0.0);
}

#[test]
fn serial_and_parallel_start_from_identical_state() {
    // Same seed => same init => identical epoch-0 loss (the init forward
    // pass is global in both modes).
    let backend = backend();
    let ds = fixtures::fig1();
    let ws1 = Arc::new(Workspace::build(&ds, &fig1_hp(1), Method::Metis).unwrap());
    let ws3 = Arc::new(Workspace::build(&ds, &fig1_hp(3), Method::Metis).unwrap());
    let t1 = AdmmTrainer::new(ws1, backend.clone(), AdmmOptions::for_mode(1)).unwrap();
    let t3 = AdmmTrainer::new(ws3, backend, AdmmOptions::for_mode(3)).unwrap();
    let (tr1, te1, l1) = t1.evaluate().unwrap();
    let (tr3, te3, l3) = t3.evaluate().unwrap();
    assert_eq!(tr1, tr3);
    assert_eq!(te1, te3);
    assert!((l1 - l3).abs() < 1e-5, "init loss differs: {l1} vs {l3}");
}

#[test]
fn three_layer_admm_runs_and_learns() {
    let backend = backend();
    let ds = fixtures::caveman(24, 17);
    let mut hp = HyperParams::for_dataset("caveman-l3");
    hp.hidden = 8;
    hp.layers = 3;
    hp.communities = 3;
    let ws = Arc::new(Workspace::build(&ds, &hp, Method::Metis).unwrap());
    let mut t = AdmmTrainer::new(ws, backend, AdmmOptions::for_mode(3)).unwrap();
    let rep = t.train(25, "l3").unwrap();
    assert!(rep.best_test_acc() >= 0.7, "best test {}", rep.best_test_acc());
}

#[test]
fn all_baselines_run_and_gd_decreases_loss() {
    let backend = backend();
    let ds = fixtures::caveman(24, 3);
    let mut hp = HyperParams::for_dataset("caveman");
    hp.hidden = 8;
    hp.communities = 1;
    let ws = Arc::new(Workspace::build(&ds, &hp, Method::Metis).unwrap());
    for name in ["gd", "adam", "adagrad", "adadelta"] {
        let opt = Optimizer::parse(name, Some("0.05")).unwrap();
        let mut t = BaselineTrainer::new(ws.clone(), backend.clone(), opt).unwrap();
        let rep = t.train(25).unwrap();
        let first = rep.epochs.first().unwrap().loss;
        let last = rep.epochs.last().unwrap().loss;
        assert!(
            last < first,
            "{name}: loss did not decrease ({first} -> {last})"
        );
    }
}

#[test]
fn partition_method_does_not_break_training() {
    let backend = backend();
    let ds = fixtures::caveman(24, 5);
    for method in [Method::Metis, Method::Random, Method::Bfs] {
        let mut hp = HyperParams::for_dataset("caveman");
        hp.hidden = 8;
        hp.communities = 3;
        let ws = Arc::new(Workspace::build(&ds, &hp, method).unwrap());
        let mut t = AdmmTrainer::new(ws, backend.clone(), AdmmOptions::for_mode(3)).unwrap();
        let rep = t.train(15, method.name()).unwrap();
        assert!(rep.epochs.iter().all(|e| e.loss.is_finite()));
    }
}

#[test]
fn admm_epoch_timings_are_sane() {
    let backend = backend();
    let ds = fixtures::fig1();
    let ws = Arc::new(Workspace::build(&ds, &fig1_hp(3), Method::Metis).unwrap());
    let mut t = AdmmTrainer::new(ws, backend, AdmmOptions::for_mode(3)).unwrap();
    let rep = t.train(5, "timing").unwrap();
    for e in &rep.epochs {
        assert!(e.t_train > 0.0 && e.t_train.is_finite());
        assert!(e.t_comm >= 0.0 && e.t_comm.is_finite());
        // Virtual parallel time can't exceed the 1-core wall time by more
        // than measurement noise (it's a max over sequentially-measured
        // parts) — and must not be absurdly small either.
        assert!(e.t_train <= e.t_wall * 1.5 + 0.01);
    }
}

#[test]
fn central_w_ablation_matches_distributed_w_math() {
    // Both W-update schedules optimise the same subproblem; from the same
    // init, one epoch should land at nearly the same training loss.
    let backend = backend();
    let ds = fixtures::caveman(24, 3);
    let mut hp = HyperParams::for_dataset("caveman");
    hp.hidden = 8;
    hp.communities = 3;
    let ws = Arc::new(Workspace::build(&ds, &hp, Method::Metis).unwrap());
    let mut dist =
        AdmmTrainer::new(ws.clone(), backend.clone(), AdmmOptions::for_mode(3)).unwrap();
    let mut central = {
        let mut o = AdmmOptions::for_mode(3);
        o.central_w = true;
        AdmmTrainer::new(ws, backend, o).unwrap()
    };
    let rd = dist.train(3, "dist").unwrap();
    let rc = central.train(3, "central").unwrap();
    let ld = rd.epochs.last().unwrap().loss;
    let lc = rc.epochs.last().unwrap().loss;
    assert!(
        (ld - lc).abs() < 0.05 * ld.abs().max(0.1),
        "distributed {ld} vs central {lc} diverged"
    );
}
