//! Telemetry subsystem integration tests (DESIGN.md §10).
//!
//! Covers the cross-thread registry contract (N threads × M increments
//! sum exactly), histogram bucket-boundary semantics, the Chrome
//! trace-event export round-tripping through `cgcn::util::json` with
//! non-decreasing `ts` per thread lane, and the load-bearing invariant
//! that flipping the `CGCN_OBS` gate never perturbs training results
//! bitwise.
//!
//! Tests in this binary share one process-global registry and gate, so
//! every test that flips `obs::force` serialises on [`gate_lock`].

use cgcn::config::HyperParams;
use cgcn::coordinator::{AdmmOptions, AdmmTrainer, Workspace};
use cgcn::data::fixtures;
use cgcn::obs;
use cgcn::partition::Method;
use cgcn::runtime::default_backend;
use cgcn::util::json::Json;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, MutexGuard};

fn gate_lock() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[test]
fn concurrent_counter_increments_sum_exactly() {
    let _g = gate_lock();
    obs::force(true);
    const N: usize = 8;
    const M: u64 = 10_000;
    let c = obs::registry().counter("test.obs.concurrency");
    let threads: Vec<_> = (0..N)
        .map(|_| {
            std::thread::spawn(move || {
                for _ in 0..M {
                    c.inc();
                }
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }
    // Writers have quiesced (joined), so the sharded sum is exact.
    let total = obs::registry().snapshot().counter("test.obs.concurrency");
    assert_eq!(total, N as u64 * M, "lost counter increments");
}

#[test]
fn histogram_bucket_boundaries_are_inclusive_upper() {
    let _g = gate_lock();
    obs::force(true);
    let h = obs::registry().histogram("test.obs.bounds", obs::SIZE_BUCKETS);
    h.record(1.0); // exactly on the first bound → bucket 0 (le="1")
    h.record(1.5); // bucket 1 (le="2")
    h.record(2.0); // exactly on a bound → same bucket 1
    h.record(4096.0); // last finite bucket
    h.record(5000.0); // past every bound → +Inf overflow
    let snap = obs::registry().snapshot();
    let hs = snap.hist("test.obs.bounds").expect("histogram registered");
    let n_bounds = hs.bounds.len();
    assert_eq!(hs.count, 5);
    assert_eq!(hs.buckets.len(), n_bounds + 1, "one extra +Inf slot");
    assert_eq!(hs.buckets[0], 1, "v == bound lands in that bucket");
    assert_eq!(hs.buckets[1], 2, "(1,2] bucket holds 1.5 and 2.0");
    assert_eq!(hs.buckets[n_bounds - 1], 1, "last finite bucket");
    assert_eq!(hs.buckets[n_bounds], 1, "+Inf overflow bucket");
    assert!((hs.sum - (1.0 + 1.5 + 2.0 + 4096.0 + 5000.0)).abs() < 1e-9);
}

#[test]
fn chrome_trace_roundtrips_with_nondecreasing_ts_per_thread() {
    let _g = gate_lock();
    obs::force(true);
    // A few spans on this thread plus one on a named helper thread, so
    // the export carries at least two tid lanes.
    for i in 0..4 {
        let _s = cgcn::span!("test.obs.trace", idx = i);
        std::thread::sleep(std::time::Duration::from_micros(50));
    }
    std::thread::Builder::new()
        .name("obs-test-helper".into())
        .spawn(|| {
            let _s = cgcn::span!("test.obs.trace.helper");
        })
        .unwrap()
        .join()
        .unwrap();

    // Round-trip the document through the in-house JSON codec.
    let text = obs::chrome_trace_json().to_string();
    let back = Json::parse(&text).expect("trace JSON re-parses");
    assert_eq!(back.get("displayTimeUnit").as_str(), Some("ms"));
    let evs = back.get("traceEvents").as_arr().expect("traceEvents array");

    let mut last_ts: BTreeMap<i64, f64> = BTreeMap::new();
    let mut n_complete = 0usize;
    for e in evs {
        match e.get("ph").as_str() {
            Some("X") => {}
            Some("M") => continue, // metadata (process/thread names)
            other => panic!("unexpected event phase {other:?}"),
        }
        n_complete += 1;
        assert_eq!(e.get("cat").as_str(), Some("cgcn"));
        assert!(e.get("dur").as_f64().unwrap() >= 0.0);
        let tid = e.get("tid").as_f64().expect("tid") as i64;
        let ts = e.get("ts").as_f64().expect("ts");
        if let Some(prev) = last_ts.get(&tid) {
            assert!(*prev <= ts, "ts decreased within tid {tid}: {prev} > {ts}");
        }
        last_ts.insert(tid, ts);
    }
    assert!(n_complete >= 5, "only {n_complete} complete events exported");
    let named = |name: &str| evs.iter().any(|e| e.get("name").as_str() == Some(name));
    assert!(named("test.obs.trace"));
    assert!(named("test.obs.trace.helper"), "helper thread lane missing");
    // The span argument survives export.
    let has_arg = evs.iter().any(|e| {
        e.get("name").as_str() == Some("test.obs.trace")
            && e.get("args").get("idx").as_f64() == Some(3.0)
    });
    assert!(has_arg, "span arg idx=3 missing from export");
}

/// Train a few parallel-ADMM epochs and return every weight bit.
fn train_weight_bits(label: &str) -> Vec<Vec<u32>> {
    let ds = fixtures::fig1();
    let mut hp = HyperParams::for_dataset("fig1");
    hp.hidden = 8;
    hp.communities = 3;
    let ws = Arc::new(Workspace::build(&ds, &hp, Method::Metis).unwrap());
    let mut t = AdmmTrainer::new(ws, default_backend(), AdmmOptions::for_mode(3)).unwrap();
    t.train(5, label).unwrap();
    t.state
        .w
        .iter()
        .map(|w| w.data().iter().map(|v| v.to_bits()).collect())
        .collect()
}

#[test]
fn obs_gate_does_not_perturb_training_bitwise() {
    let _g = gate_lock();
    obs::force(true);
    let with_obs = train_weight_bits("obs-on");
    obs::force(false);
    let without_obs = train_weight_bits("obs-off");
    obs::force(true);
    assert_eq!(
        with_obs, without_obs,
        "CGCN_OBS gate changed training results"
    );
}
