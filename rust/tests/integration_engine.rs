//! Backend integration tests: every [`ComputeBackend`] implementation vs
//! the host-side reference math in `cgcn::tensor`.
//!
//! The native backend (serial and pool-parallel) always runs; the XLA
//! artifact backend joins in when the crate is built with `--features
//! xla` and `make artifacts` has produced the fig1 shapes.

use cgcn::runtime::{ComputeBackend, NativeBackend};
use cgcn::tensor::{self, Matrix};
use cgcn::util::rng::Rng;
use std::sync::Arc;

/// fig1 artifact shapes: n=128, dims 4 -> 8 -> 3.
const N: usize = 128;
const A: usize = 4;
const B: usize = 8;
const C: usize = 3;

fn backends() -> Vec<(String, Arc<dyn ComputeBackend>)> {
    let mut v: Vec<(String, Arc<dyn ComputeBackend>)> = vec![
        ("native-1".into(), Arc::new(NativeBackend::new())),
        // Grain 0 forces the row-parallel path even on these small shapes.
        ("native-4".into(), Arc::new(NativeBackend::with_grain(4, 0))),
    ];
    #[cfg(feature = "xla")]
    {
        if cgcn::runtime::Engine::available() {
            let dir = cgcn::runtime::Engine::default_dir();
            v.push((
                "xla".into(),
                Arc::new(cgcn::runtime::XlaBackend::load(&dir).unwrap()),
            ));
        } else {
            eprintln!("note: artifacts not built — xla backend not exercised");
        }
    }
    v
}

fn mats(rng: &mut Rng) -> (Matrix, Matrix) {
    (Matrix::glorot(N, A, rng), Matrix::glorot(A, B, rng))
}

#[test]
fn mm_primitives_match_host_matmul() {
    for (name, be) in backends() {
        let mut rng = Rng::new(1);
        let (x, w) = mats(&mut rng);
        let y = Matrix::glorot(N, B, &mut rng);

        let got = be.mm_nn(&x, &w).unwrap();
        assert!(got.max_abs_diff(&x.matmul(&w)) < 1e-4, "{name} mm_nn");

        let got = be.mm_tn(&x, &y).unwrap();
        assert!(
            got.max_abs_diff(&x.transpose().matmul(&y)) < 1e-4,
            "{name} mm_tn"
        );

        let got = be.mm_bt(&y, &w).unwrap();
        assert!(
            got.max_abs_diff(&y.matmul(&w.transpose())) < 1e-4,
            "{name} mm_bt"
        );
    }
}

#[test]
fn fwd_relu_matches_and_keeps_padding_inert() {
    for (name, be) in backends() {
        let mut rng = Rng::new(3);
        let (mut x, w) = mats(&mut rng);
        // Zero the tail rows — padded communities look exactly like this.
        for r in 100..N {
            x.row_mut(r).fill(0.0);
        }
        let got = be.fwd_relu(&x, &w).unwrap();
        let want = tensor::relu(&x.matmul(&w));
        assert!(got.max_abs_diff(&want) < 1e-4, "{name} fwd_relu");
        for r in 100..N {
            assert!(
                got.row(r).iter().all(|&v| v == 0.0),
                "{name}: padding row {r} leaked"
            );
        }
    }
}

#[test]
fn residual_entries_match_host_formulas() {
    for (name, be) in backends() {
        let mut rng = Rng::new(4);
        let pre = Matrix::glorot(N, B, &mut rng);
        let zt = Matrix::glorot(N, B, &mut rng);
        let nu = 0.37f32;

        let (val, r) = be.hidden_residual(&pre, &zt, nu).unwrap();
        let act = tensor::relu(&pre);
        let d = act.sub(&zt);
        assert!(
            (val - 0.5 * nu * d.frob_norm_sq() as f32).abs() < 1e-3 * val.abs().max(1.0),
            "{name} hidden_residual value"
        );
        let want_r = d.hadamard(&tensor::relu_mask(&pre)).scale(nu);
        assert!(r.max_abs_diff(&want_r) < 1e-5, "{name} hidden_residual R");

        // out_residual: val = <U, Zt-pre> + rho/2 ||Zt-pre||²; R = -(U + rho d).
        let u = Matrix::glorot(N, C, &mut rng);
        let pre_c = Matrix::glorot(N, C, &mut rng);
        let zt_c = Matrix::glorot(N, C, &mut rng);
        let rho = 0.05f32;
        let (val, r) = be.out_residual(&pre_c, &zt_c, &u, rho).unwrap();
        let d = zt_c.sub(&pre_c);
        let want_val = u.dot(&d) as f32 + 0.5 * rho * d.frob_norm_sq() as f32;
        assert!(
            (val - want_val).abs() < 1e-3 * want_val.abs().max(1.0),
            "{name} out_residual value"
        );
        let mut want_r = u.clone();
        want_r.axpy(rho, &d);
        assert!(
            r.max_abs_diff(&want_r.scale(-1.0)) < 1e-5,
            "{name} out_residual R"
        );

        // Value-only entries agree with their residual twins.
        let phi = be.hidden_phi(&pre, &zt, nu).unwrap();
        let (v2, _) = be.hidden_residual(&pre, &zt, nu).unwrap();
        assert!((phi - v2).abs() < 1e-4 * v2.abs().max(1.0), "{name} hidden_phi");
        let ophi = be.out_phi(&pre_c, &zt_c, &u, rho).unwrap();
        assert!(
            (ophi - val).abs() < 1e-4 * val.abs().max(1.0),
            "{name} out_phi"
        );
    }
}

#[test]
fn z_combine_and_prox_val_are_consistent() {
    for (name, be) in backends() {
        let mut rng = Rng::new(7);
        let z = Matrix::glorot(N, B, &mut rng);
        let pin = Matrix::glorot(N, B, &mut rng);
        let gsum = Matrix::glorot(N, B, &mut rng);
        let (nu, theta) = (0.21f32, 2.0f32);
        let (znew, prox, gsq) = be.z_combine(&z, &pin, &gsum, nu, theta).unwrap();
        let fpin = tensor::relu(&pin);
        let d = z.sub(&fpin);
        let g = d.scale(nu).add(&gsum);
        let want_z = z.sub(&g.scale(1.0 / theta));
        assert!(znew.max_abs_diff(&want_z) < 1e-5, "{name} z_combine step");
        assert!(
            (prox - 0.5 * nu * d.frob_norm_sq() as f32).abs() < 1e-3 * prox.abs().max(1.0),
            "{name} z_combine prox"
        );
        assert!(
            (gsq - g.frob_norm_sq() as f32).abs() < 1e-3 * gsq.abs().max(1.0),
            "{name} z_combine gsq"
        );
        let pv = be.z_prox_val(&z, &pin, nu).unwrap();
        assert!((pv - prox).abs() < 1e-4 * prox.abs().max(1.0), "{name} z_prox_val");
    }
}

#[test]
fn xent_loss_matches_host_cross_entropy() {
    for (name, be) in backends() {
        let mut rng = Rng::new(5);
        let logits = Matrix::glorot(N, C, &mut rng).scale(3.0);
        let labels: Vec<usize> = (0..N).map(|_| rng.gen_range(C)).collect();
        let mut y = Matrix::zeros(N, C);
        let mut mask = vec![0.0f32; N];
        for i in 0..N {
            y.set(i, labels[i], 1.0);
            if rng.gen_bool(0.5) {
                mask[i] = 1.0;
            }
        }
        let denom: f32 = mask.iter().sum::<f32>().max(1.0);
        let got = be.xent_loss(&logits, &y, &mask, denom).unwrap();
        let (want, _) = tensor::masked_cross_entropy(&logits, &labels, &mask);
        assert!(
            (got as f64 - want).abs() < 1e-4 * want.abs().max(1.0),
            "{name}: backend {got} vs host {want}"
        );
    }
}

#[test]
fn bp_grads_match_finite_reference() {
    for (name, be) in backends() {
        let mut rng = Rng::new(6);
        let h1 = Matrix::glorot(N, B, &mut rng);
        let w2 = Matrix::glorot(B, C, &mut rng);
        let labels: Vec<usize> = (0..N).map(|_| rng.gen_range(C)).collect();
        let mut y = Matrix::zeros(N, C);
        let mask = vec![1.0f32; N];
        for i in 0..N {
            y.set(i, labels[i], 1.0);
        }
        let denom = N as f32;
        let (loss, dw2, dh1) = be.bp_out_grads(&h1, &w2, &y, &mask, denom).unwrap();
        // Host reference: logits, CE grad, chain rule.
        let logits = h1.matmul(&w2);
        let (want_loss, dl) = tensor::masked_cross_entropy(&logits, &labels, &mask);
        assert!(
            (loss as f64 - want_loss).abs() < 1e-4 * want_loss.abs().max(1.0),
            "{name} bp loss"
        );
        let want_dw2 = h1.transpose().matmul(&dl);
        let want_dh1 = dl.matmul(&w2.transpose());
        assert!(dw2.max_abs_diff(&want_dw2) < 1e-5, "{name} dW2");
        assert!(dh1.max_abs_diff(&want_dh1) < 1e-5, "{name} dH1");

        // Hidden tail.
        let h0 = Matrix::glorot(N, A, &mut rng);
        let w1 = Matrix::glorot(A, B, &mut rng);
        let dz1 = Matrix::glorot(N, B, &mut rng);
        let dw1 = be.bp_hidden_grads(&h0, &w1, &dz1).unwrap();
        let pre = h0.matmul(&w1);
        let r = dz1.hadamard(&tensor::relu_mask(&pre));
        let want_dw1 = h0.transpose().matmul(&r);
        assert!(dw1.max_abs_diff(&want_dw1) < 1e-5, "{name} dW1");
    }
}

#[test]
fn zl_fista_decreases_its_objective() {
    for (name, be) in backends() {
        let mut rng = Rng::new(6);
        let q = Matrix::glorot(N, C, &mut rng);
        let u = Matrix::glorot(N, C, &mut rng).scale(0.05);
        let labels: Vec<usize> = (0..N).map(|_| rng.gen_range(C)).collect();
        let mut y = Matrix::zeros(N, C);
        let mask = vec![1.0f32; N];
        for i in 0..N {
            y.set(i, labels[i], 1.0);
        }
        let denom = N as f32;
        let rho = 0.1f32;
        let objective = |z: &Matrix| -> f64 {
            let (ce, _) = tensor::masked_cross_entropy(z, &labels, &mask);
            let d = z.sub(&q);
            ce + u.dot(&d) + 0.5 * rho as f64 * d.frob_norm_sq()
        };
        let (z_new, _risk) = be
            .zl_fista(&q, &u, &y, &mask, &q, rho, denom, 10)
            .unwrap();
        assert!(
            objective(&z_new) < objective(&q) - 1e-6,
            "{name}: FISTA failed to decrease the eq.-7 objective"
        );
    }
}

#[cfg(feature = "xla")]
mod xla_only {
    use cgcn::runtime::{Engine, In};
    use cgcn::tensor::Matrix;
    use cgcn::util::rng::Rng;

    #[test]
    fn prepared_literals_give_identical_results() {
        if !Engine::available() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        }
        let engine = Engine::load(&Engine::default_dir()).unwrap();
        let mut rng = Rng::new(2);
        let x = Matrix::glorot(super::N, super::A, &mut rng);
        let w = Matrix::glorot(super::A, super::B, &mut rng);
        let sig = format!("mm_nn__n{}_a{}_b{}", super::N, super::A, super::B);
        let plain = engine
            .exec(&sig, &[In::Mat(&x), In::Mat(&w)])
            .unwrap()
            .remove(0)
            .into_mat();
        let prep = engine.prepare(&x).unwrap();
        let prepped = engine
            .exec(&sig, &[In::Prep(&prep), In::Mat(&w)])
            .unwrap()
            .remove(0)
            .into_mat();
        assert_eq!(plain.data(), prepped.data());
    }
}
