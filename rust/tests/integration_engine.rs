//! Engine integration tests: AOT artifacts vs host-side reference math.
//!
//! These need `make artifacts`; they skip (with a notice) when the
//! artifacts directory is absent so a bare `cargo test` still passes.

use cgcn::runtime::{Engine, In};
use cgcn::tensor::{self, Matrix};
use cgcn::util::rng::Rng;
use std::sync::Arc;

fn engine() -> Option<Arc<Engine>> {
    if !Engine::available() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Arc::new(Engine::load(&Engine::default_dir()).unwrap()))
}

/// fig1 artifact shapes: n=128, dims 4 -> 8 -> 3.
const N: usize = 128;
const A: usize = 4;
const B: usize = 8;
const C: usize = 3;

fn mats(rng: &mut Rng) -> (Matrix, Matrix) {
    (Matrix::glorot(N, A, rng), Matrix::glorot(A, B, rng))
}

#[test]
fn mm_primitives_match_host_matmul() {
    let Some(engine) = engine() else { return };
    let mut rng = Rng::new(1);
    let (x, w) = mats(&mut rng);
    let y = Matrix::glorot(N, B, &mut rng);

    let got = engine
        .exec(&format!("mm_nn__n{N}_a{A}_b{B}"), &[In::Mat(&x), In::Mat(&w)])
        .unwrap()
        .remove(0)
        .into_mat();
    assert!(got.max_abs_diff(&x.matmul(&w)) < 1e-4);

    let got = engine
        .exec(&format!("mm_tn__n{N}_a{A}_b{B}"), &[In::Mat(&x), In::Mat(&y)])
        .unwrap()
        .remove(0)
        .into_mat();
    assert!(got.max_abs_diff(&x.transpose().matmul(&y)) < 1e-4);

    let got = engine
        .exec(&format!("mm_bt__n{N}_a{A}_b{B}"), &[In::Mat(&y), In::Mat(&w)])
        .unwrap()
        .remove(0)
        .into_mat();
    assert!(got.max_abs_diff(&y.matmul(&w.transpose())) < 1e-4);
}

#[test]
fn prepared_literals_give_identical_results() {
    let Some(engine) = engine() else { return };
    let mut rng = Rng::new(2);
    let (x, w) = mats(&mut rng);
    let sig = format!("mm_nn__n{N}_a{A}_b{B}");
    let plain = engine
        .exec(&sig, &[In::Mat(&x), In::Mat(&w)])
        .unwrap()
        .remove(0)
        .into_mat();
    let prep = engine.prepare(&x).unwrap();
    let prepped = engine
        .exec(&sig, &[In::Prep(&prep), In::Mat(&w)])
        .unwrap()
        .remove(0)
        .into_mat();
    assert_eq!(plain.data(), prepped.data());
}

#[test]
fn fwd_relu_matches_and_keeps_padding_inert() {
    let Some(engine) = engine() else { return };
    let mut rng = Rng::new(3);
    let (mut x, w) = mats(&mut rng);
    // Zero the tail rows — padded communities look exactly like this.
    for r in 100..N {
        x.row_mut(r).fill(0.0);
    }
    let got = engine
        .exec(&format!("fwd_relu__n{N}_a{A}_b{B}"), &[In::Mat(&x), In::Mat(&w)])
        .unwrap()
        .remove(0)
        .into_mat();
    let want = tensor::relu(&x.matmul(&w));
    assert!(got.max_abs_diff(&want) < 1e-4);
    for r in 100..N {
        assert!(got.row(r).iter().all(|&v| v == 0.0), "padding row {r} leaked");
    }
}

#[test]
fn residual_entries_match_host_formulas() {
    let Some(engine) = engine() else { return };
    let mut rng = Rng::new(4);
    let pre = Matrix::glorot(N, B, &mut rng);
    let zt = Matrix::glorot(N, B, &mut rng);
    let nu = 0.37f32;

    let outs = engine
        .exec(
            &format!("hidden_residual__n{N}_c{B}"),
            &[In::Mat(&pre), In::Mat(&zt), In::Scalar(nu)],
        )
        .unwrap();
    let val = outs[0].scalar();
    let r = match &outs[1] {
        cgcn::runtime::Out::Mat(m) => m.clone(),
        _ => panic!(),
    };
    let act = tensor::relu(&pre);
    let d = act.sub(&zt);
    assert!((val - 0.5 * nu * d.frob_norm_sq() as f32).abs() < 1e-3 * val.abs().max(1.0));
    let want_r = d.hadamard(&tensor::relu_mask(&pre)).scale(nu);
    assert!(r.max_abs_diff(&want_r) < 1e-5);

    // out_residual: val = <U, Zt-pre> + rho/2 ||Zt-pre||²; R = -(U + rho d).
    let u = Matrix::glorot(N, C, &mut rng);
    let pre_c = Matrix::glorot(N, C, &mut rng);
    let zt_c = Matrix::glorot(N, C, &mut rng);
    let rho = 0.05f32;
    let outs = engine
        .exec(
            &format!("out_residual__n{N}_c{C}"),
            &[In::Mat(&pre_c), In::Mat(&zt_c), In::Mat(&u), In::Scalar(rho)],
        )
        .unwrap();
    let val = outs[0].scalar();
    let d = zt_c.sub(&pre_c);
    let want_val = u.dot(&d) as f32 + 0.5 * rho * d.frob_norm_sq() as f32;
    assert!((val - want_val).abs() < 1e-3 * want_val.abs().max(1.0));
}

#[test]
fn xent_loss_matches_host_cross_entropy() {
    let Some(engine) = engine() else { return };
    let mut rng = Rng::new(5);
    let logits = Matrix::glorot(N, C, &mut rng).scale(3.0);
    let labels: Vec<usize> = (0..N).map(|_| rng.gen_range(C)).collect();
    let mut y = Matrix::zeros(N, C);
    let mut mask = vec![0.0f32; N];
    for i in 0..N {
        y.set(i, labels[i], 1.0);
        if rng.gen_bool(0.5) {
            mask[i] = 1.0;
        }
    }
    let denom: f32 = mask.iter().sum::<f32>().max(1.0);
    let got = engine
        .exec(
            &format!("xent_loss__n{N}_c{C}"),
            &[In::Mat(&logits), In::Mat(&y), In::Vec(&mask), In::Scalar(denom)],
        )
        .unwrap()
        .remove(0)
        .scalar();
    let (want, _) = tensor::masked_cross_entropy(&logits, &labels, &mask);
    assert!(
        (got as f64 - want).abs() < 1e-4 * want.abs().max(1.0),
        "artifact {got} vs host {want}"
    );
}

#[test]
fn zl_fista_decreases_its_objective() {
    let Some(engine) = engine() else { return };
    let mut rng = Rng::new(6);
    let q = Matrix::glorot(N, C, &mut rng);
    let u = Matrix::glorot(N, C, &mut rng).scale(0.05);
    let labels: Vec<usize> = (0..N).map(|_| rng.gen_range(C)).collect();
    let mut y = Matrix::zeros(N, C);
    let mask = vec![1.0f32; N];
    for i in 0..N {
        y.set(i, labels[i], 1.0);
    }
    let denom = N as f32;
    let rho = 0.1f32;
    let objective = |z: &Matrix| -> f64 {
        let (ce, _) = tensor::masked_cross_entropy(z, &labels, &mask);
        let d = z.sub(&q);
        ce + u.dot(&d) + 0.5 * rho as f64 * d.frob_norm_sq()
    };
    let outs = engine
        .exec(
            &format!("zl_fista__n{N}_c{C}_steps10"),
            &[
                In::Mat(&q),
                In::Mat(&u),
                In::Mat(&y),
                In::Vec(&mask),
                In::Mat(&q), // warm start at Q
                In::Scalar(rho),
                In::Scalar(denom),
            ],
        )
        .unwrap();
    let z_new = match &outs[0] {
        cgcn::runtime::Out::Mat(m) => m.clone(),
        _ => panic!(),
    };
    assert!(
        objective(&z_new) < objective(&q) - 1e-6,
        "FISTA failed to decrease the eq.-7 objective"
    );
}
