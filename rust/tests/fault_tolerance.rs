//! Chaos suite for the elastic distributed runtime.
//!
//! Everything here runs on [`SimTransport`] (single-threaded,
//! deterministic, seeded fault injection) plus [`ChannelTransport`] for
//! a real-concurrency cross-check — no processes, no timers, no flaky
//! `kill -9` races. The contract under test:
//!
//! 1. No-fault elastic runs are **bitwise identical** to the local serial
//!    executor (and the channel transport to the sim).
//! 2. Crashing any host at any seeded epoch still completes, and the
//!    recovered run's final weights/accuracy are bitwise identical to the
//!    no-fault run (recovery restarts the epoch from its barrier, and an
//!    epoch is a pure function of barrier state).
//! 3. Recovery is deterministic per fault seed — same plan, same bytes.
//! 4. Checkpoint + resume reproduces the uninterrupted run bitwise for
//!    the serial executor, the threaded executor, and the sim transport.

use cgcn::config::HyperParams;
use cgcn::coordinator::checkpoint::{self, CheckpointSink, CkptMeta, TrainCheckpoint};
use cgcn::coordinator::sim::{run_sim_training, FaultPlan};
use cgcn::coordinator::{
    run_elastic_training, AdmmOptions, AdmmTrainer, ChannelTransport, ElasticCfg, ExecMode,
    LinkModel, Workspace,
};
use cgcn::partition::Method;
use cgcn::runtime::NativeBackend;
use cgcn::serve::SnapshotMeta;
use cgcn::tensor::Matrix;
use std::path::PathBuf;
use std::sync::Arc;

const EPOCHS: usize = 6;
const SEED: u64 = 7;

fn workspace() -> Arc<Workspace> {
    let ds = cgcn::data::fixtures::caveman(24, 3);
    let mut hp = HyperParams::for_dataset("caveman");
    hp.communities = 3;
    hp.hidden = 8;
    hp.seed = SEED;
    Arc::new(Workspace::build(&ds, &hp, Method::Metis).unwrap())
}

fn trainer(ws: &Arc<Workspace>) -> AdmmTrainer {
    let backend = Arc::new(NativeBackend::new());
    AdmmTrainer::new(ws.clone(), backend, AdmmOptions::for_mode(ws.m)).unwrap()
}

fn cfg(start: usize, epochs: usize) -> ElasticCfg<'static> {
    ElasticCfg {
        label: "fault-test".into(),
        dataset: "caveman".into(),
        start_epoch: start,
        epochs,
        link: LinkModel::new(10_000.0, 100.0),
        sink: None,
    }
}

fn assert_weights_eq(a: &[Matrix], b: &[Matrix], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: layer count");
    for (li, (wa, wb)) in a.iter().zip(b).enumerate() {
        assert_eq!(wa.data(), wb.data(), "{what}: W_{} differs bitwise", li + 1);
    }
}

/// The no-fault reference: local serial executor.
fn serial_reference(ws: &Arc<Workspace>, epochs: usize) -> AdmmTrainer {
    let mut t = trainer(ws);
    t.train(epochs, "serial-ref").unwrap();
    t
}

#[test]
fn no_fault_sim_and_channel_match_local_serial_bitwise() {
    let ws = workspace();
    let reference = serial_reference(&ws, EPOCHS);
    let ref_eval = reference.evaluate().unwrap();

    // Sim transport, no faults.
    let mut sim = trainer(&ws);
    let (report, stats) = run_sim_training(&mut sim, FaultPlan::none(), &cfg(0, EPOCHS)).unwrap();
    assert_eq!(report.epochs.len(), EPOCHS);
    assert_eq!(stats.crashes, 0);
    assert_eq!(stats.links_lost, 0);
    assert_weights_eq(&reference.state.w, &sim.state.w, "sim vs serial");
    assert_eq!(sim.evaluate().unwrap(), ref_eval);
    assert!(report.total_bytes() > 0, "sim shipped no bytes");

    // Channel transport (real threads + mpsc), no faults.
    let mut chan = trainer(&ws);
    let backend = chan.backend.clone();
    let mut t = ChannelTransport::spawn(&ws, &backend, AdmmOptions::for_mode(ws.m).gauss_seidel);
    let report = run_elastic_training(&mut chan, &mut t, &cfg(0, EPOCHS)).unwrap();
    drop(t);
    assert_eq!(report.epochs.len(), EPOCHS);
    assert_weights_eq(&reference.state.w, &chan.state.w, "channel vs serial");
    assert_eq!(chan.evaluate().unwrap(), ref_eval);
}

#[test]
fn crashing_each_host_at_seeded_epochs_recovers_bitwise() {
    let ws = workspace();
    let reference = serial_reference(&ws, EPOCHS);
    let ref_eval = reference.evaluate().unwrap();

    for host in 0..ws.m {
        // ≥ 3 distinct fault seeds per scenario, each picking a different
        // crash epoch for this host.
        for fault_seed in [1u64, 2, 3] {
            let epoch = 1 + (fault_seed + host as u64) % (EPOCHS as u64 - 1);
            let plan = FaultPlan::crash(host, epoch);

            let mut a = trainer(&ws);
            let (report, stats) = run_sim_training(&mut a, plan.clone(), &cfg(0, EPOCHS))
                .unwrap_or_else(|e| panic!("host {host} crash at {epoch}: {e:#}"));
            assert_eq!(report.epochs.len(), EPOCHS, "host {host} epoch {epoch}");
            assert_eq!(stats.crashes, 1, "host {host} epoch {epoch}");
            assert_weights_eq(
                &reference.state.w,
                &a.state.w,
                &format!("crash host {host} at epoch {epoch}"),
            );
            assert_eq!(a.evaluate().unwrap(), ref_eval);

            // Determinism per seed: the identical plan replays the
            // identical run (weights AND fault counters).
            let mut b = trainer(&ws);
            let (_, stats_b) = run_sim_training(&mut b, plan, &cfg(0, EPOCHS)).unwrap();
            assert_weights_eq(&a.state.w, &b.state.w, "replay determinism");
            assert_eq!(stats.frames, stats_b.frames, "replay frame count");
        }
    }
}

#[test]
fn two_hosts_lost_still_recovers_on_the_survivor() {
    let ws = workspace();
    let reference = serial_reference(&ws, EPOCHS);
    let plan = FaultPlan {
        crash_at: vec![(0, 1), (2, 3)],
        ..FaultPlan::default()
    };
    let mut t = trainer(&ws);
    let (report, stats) = run_sim_training(&mut t, plan, &cfg(0, EPOCHS)).unwrap();
    assert_eq!(report.epochs.len(), EPOCHS);
    assert_eq!(stats.crashes, 2);
    assert_weights_eq(&reference.state.w, &t.state.w, "two crashes");
}

#[test]
fn all_hosts_lost_is_a_clean_error() {
    let ws = workspace();
    let plan = FaultPlan {
        crash_at: vec![(0, 1), (1, 1), (2, 1)],
        ..FaultPlan::default()
    };
    let mut t = trainer(&ws);
    let err = run_sim_training(&mut t, plan, &cfg(0, EPOCHS)).unwrap_err();
    assert!(
        err.to_string().contains("cannot recover"),
        "unexpected error: {err:#}"
    );
}

#[test]
fn dropped_frame_triggers_recovery_with_identical_results() {
    let ws = workspace();
    let reference = serial_reference(&ws, EPOCHS);
    // ≥ 3 seeds per scenario: each seed drops a different early frame
    // (during initial adoption / the first epochs), losing that host's
    // link mid-protocol.
    for fault_seed in [11u64, 12, 13] {
        let plan = FaultPlan {
            drop_frames: vec![3 + fault_seed % 17],
            ..FaultPlan::default()
        };
        let mut t = trainer(&ws);
        let (report, stats) = run_sim_training(&mut t, plan.clone(), &cfg(0, EPOCHS))
            .unwrap_or_else(|e| panic!("seed {fault_seed}: {e:#}"));
        assert_eq!(report.epochs.len(), EPOCHS);
        assert_eq!(stats.dropped, 1, "seed {fault_seed}");
        assert_eq!(stats.links_lost, 1, "seed {fault_seed}");
        assert_weights_eq(
            &reference.state.w,
            &t.state.w,
            &format!("drop seed {fault_seed}"),
        );

        let mut b = trainer(&ws);
        let (_, stats_b) = run_sim_training(&mut b, plan, &cfg(0, EPOCHS)).unwrap();
        assert_weights_eq(&t.state.w, &b.state.w, "drop replay determinism");
        assert_eq!(stats.frames, stats_b.frames);
    }
}

#[test]
fn duplicated_frames_are_absorbed_without_changing_results() {
    let ws = workspace();
    let reference = serial_reference(&ws, EPOCHS);
    for fault_seed in [21u64, 22, 23] {
        let plan = FaultPlan {
            dup_frames: vec![4 + fault_seed % 13, 20 + fault_seed % 7],
            ..FaultPlan::default()
        };
        let mut t = trainer(&ws);
        let (report, stats) = run_sim_training(&mut t, plan, &cfg(0, EPOCHS))
            .unwrap_or_else(|e| panic!("seed {fault_seed}: {e:#}"));
        assert_eq!(report.epochs.len(), EPOCHS);
        assert!(stats.duplicated >= 1, "seed {fault_seed}");
        // Duplicates alone must not cost a host or change a single bit.
        assert_eq!(stats.links_lost, 0, "seed {fault_seed}");
        assert_weights_eq(
            &reference.state.w,
            &t.state.w,
            &format!("dup seed {fault_seed}"),
        );
    }
}

#[test]
fn delayed_frames_either_pass_or_fail_over_deterministically() {
    let ws = workspace();
    let reference = serial_reference(&ws, EPOCHS);
    for fault_seed in [31u64, 32, 33] {
        let plan = FaultPlan {
            seed: fault_seed,
            delay_frames: vec![5 + fault_seed % 11],
            ..FaultPlan::default()
        };
        let mut t = trainer(&ws);
        let (report, stats) = run_sim_training(&mut t, plan.clone(), &cfg(0, EPOCHS))
            .unwrap_or_else(|e| panic!("seed {fault_seed}: {e:#}"));
        assert_eq!(report.epochs.len(), EPOCHS);
        assert_eq!(stats.delayed, 1, "seed {fault_seed}");
        assert_weights_eq(
            &reference.state.w,
            &t.state.w,
            &format!("delay seed {fault_seed}"),
        );
        let mut b = trainer(&ws);
        let (_, stats_b) = run_sim_training(&mut b, plan, &cfg(0, EPOCHS)).unwrap();
        assert_weights_eq(&t.state.w, &b.state.w, "delay replay determinism");
        assert_eq!(stats.links_lost, stats_b.links_lost);
    }
}

#[test]
fn probabilistic_chaos_soak_never_panics_and_is_seed_deterministic() {
    let ws = workspace();
    let reference = serial_reference(&ws, EPOCHS);
    for fault_seed in [41u64, 42, 43] {
        let plan = FaultPlan {
            seed: fault_seed,
            p_drop: 0.005,
            p_dup: 0.05,
            p_delay: 0.03,
            ..FaultPlan::default()
        };
        let mut a = trainer(&ws);
        let ra = run_sim_training(&mut a, plan.clone(), &cfg(0, EPOCHS));
        let mut b = trainer(&ws);
        let rb = run_sim_training(&mut b, plan, &cfg(0, EPOCHS));
        match (&ra, &rb) {
            (Ok((_, sa)), Ok((_, sb))) => {
                // Completed: identical to the no-fault run, bit for bit.
                assert_weights_eq(&reference.state.w, &a.state.w, "soak");
                assert_weights_eq(&a.state.w, &b.state.w, "soak determinism");
                assert_eq!(sa.frames, sb.frames, "soak frame determinism");
            }
            (Err(ea), Err(eb)) => {
                // Every host can be lost under heavy faults — that must
                // be the documented clean error, deterministically.
                assert!(ea.to_string().contains("cannot recover"), "{ea:#}");
                assert_eq!(ea.to_string(), eb.to_string(), "error determinism");
            }
            _ => panic!("seed {fault_seed}: outcomes diverged between identical runs"),
        }
    }
}

// ---------------------------------------------------------------------------
// Checkpoint + resume determinism across executors and transports
// ---------------------------------------------------------------------------

fn ckpt_meta(ws: &Workspace) -> CkptMeta {
    CkptMeta {
        snap: SnapshotMeta {
            label: "fault-test".into(),
            dataset: "caveman".into(),
            scale: 1.0,
            seed: SEED,
            partition: "metis".into(),
            communities: ws.m,
            hidden: ws.hp.hidden,
            layers: ws.layers,
        },
        method: "admm".into(),
        rho: ws.hp.rho,
        nu: ws.hp.nu,
    }
}

fn temp_ckpt_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("cgcn_ft_{}_{}", name, std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

#[test]
fn resume_is_bitwise_identical_for_serial_and_threads_executors() {
    let ws = workspace();
    for exec in [ExecMode::Serial, ExecMode::Threads] {
        let mk = |ws: &Arc<Workspace>| {
            let mut opts = AdmmOptions::for_mode(ws.m);
            opts.exec = exec;
            opts.threads = 2;
            AdmmTrainer::new(ws.clone(), Arc::new(NativeBackend::new()), opts).unwrap()
        };
        let dir = temp_ckpt_dir(exec.name());
        let sink = CheckpointSink::new(2, dir.clone(), ckpt_meta(&ws)).unwrap();

        // Uninterrupted run (checkpointing along the way).
        let mut full = mk(&ws);
        full.train_range(0, EPOCHS, "full", Some(&sink)).unwrap();

        // Resume from every checkpoint epoch; the tail must land on the
        // same bits.
        for k in [2usize, 4] {
            let path = checkpoint::checkpoint_path(&dir, k as u64);
            let ck = TrainCheckpoint::load(&path).unwrap();
            assert_eq!(ck.epoch, k as u64);
            let mut resumed = mk(&ws);
            checkpoint::restore_admm(&mut resumed, &ck).unwrap();
            resumed.train_range(k, EPOCHS, "resumed", None).unwrap();
            assert_weights_eq(
                &full.state.w,
                &resumed.state.w,
                &format!("{} resume from {k}", exec.name()),
            );
            assert_eq!(resumed.evaluate().unwrap(), full.evaluate().unwrap());
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn resume_is_bitwise_identical_for_sim_transport() {
    let ws = workspace();
    let dir = temp_ckpt_dir("sim");
    let sink = CheckpointSink::new(2, dir.clone(), ckpt_meta(&ws)).unwrap();

    let mut full = trainer(&ws);
    let full_cfg = ElasticCfg {
        label: "fault-test".into(),
        dataset: "caveman".into(),
        start_epoch: 0,
        epochs: EPOCHS,
        link: LinkModel::new(10_000.0, 100.0),
        sink: Some(&sink),
    };
    run_sim_training(&mut full, FaultPlan::none(), &full_cfg).unwrap();

    for k in [2usize, 4] {
        let ck = TrainCheckpoint::load(&checkpoint::checkpoint_path(&dir, k as u64)).unwrap();
        let mut resumed = trainer(&ws);
        checkpoint::restore_admm(&mut resumed, &ck).unwrap();
        let (report, _) = run_sim_training(&mut resumed, FaultPlan::none(), &cfg(k, EPOCHS)).unwrap();
        assert_eq!(report.epochs.len(), EPOCHS - k);
        assert_weights_eq(
            &full.state.w,
            &resumed.state.w,
            &format!("sim resume from {k}"),
        );
    }

    // A crash *after* the checkpoint epoch on the resumed run still lands
    // on the same bits (recovery + resume compose).
    let ck = TrainCheckpoint::load(&checkpoint::checkpoint_path(&dir, 2)).unwrap();
    let mut resumed = trainer(&ws);
    checkpoint::restore_admm(&mut resumed, &ck).unwrap();
    let (_, stats) =
        run_sim_training(&mut resumed, FaultPlan::crash(1, 4), &cfg(2, EPOCHS)).unwrap();
    assert_eq!(stats.crashes, 1);
    assert_weights_eq(&full.state.w, &resumed.state.w, "resume + crash");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn stale_checkpoint_for_wrong_shape_refuses_cleanly() {
    // A checkpoint from a different configuration must be rejected by the
    // shape checks, not silently corrupt training.
    let ws = workspace();
    let mut t = trainer(&ws);
    let mut ck = TrainCheckpoint {
        meta: ckpt_meta(&ws),
        epoch: 2,
        state: cgcn::coordinator::CkptState::from_admm(&t.state),
    };
    // Corrupt one Z block's shape.
    if let cgcn::coordinator::CkptState::Admm { z, .. } = &mut ck.state {
        z[0][1] = Matrix::zeros(1, 1);
    }
    let err = checkpoint::restore_admm(&mut t, &ck).unwrap_err();
    assert!(err.to_string().contains("shape"), "{err}");
}
