//! End-to-end serving: train → `save_model` → `load_model` → an
//! [`InferenceSession`] behind the batched multi-threaded TCP server,
//! with concurrent clients asserting that served logits are bitwise
//! identical to the in-process forward pass (the acceptance bar for the
//! serving subsystem — batching and threading must be pure scheduling,
//! never numerics).

use cgcn::baselines::{BaselineTrainer, ClusterGcnOptions, ClusterGcnTrainer, Optimizer};
use cgcn::config::HyperParams;
use cgcn::coordinator::{AdmmOptions, AdmmTrainer, Workspace};
use cgcn::partition::Method;
use cgcn::runtime::NativeBackend;
use cgcn::serve::{load_model, serve, InferenceSession, ServeClient, ServeOptions, SnapshotMeta};
use cgcn::tensor::Matrix;
use cgcn::util::rng::Rng;
use std::path::PathBuf;
use std::sync::Arc;

const SEED: u64 = 5;

fn caveman_workspace(m: usize) -> Arc<Workspace> {
    // Through the same loader the snapshot rebuild uses, so the
    // roundtrip replays an identical workspace.
    let ds = cgcn::cmd::load_dataset("caveman", 1.0, SEED).unwrap();
    let mut hp = HyperParams::for_dataset("caveman");
    hp.communities = m;
    hp.hidden = 8;
    hp.seed = SEED;
    Arc::new(Workspace::build(&ds, &hp, Method::Metis).unwrap())
}

fn meta(label: &str, ws: &Workspace) -> SnapshotMeta {
    SnapshotMeta {
        label: label.to_string(),
        dataset: "caveman".to_string(),
        scale: 1.0,
        seed: SEED,
        partition: "metis".to_string(),
        communities: ws.m,
        hidden: ws.hp.hidden,
        layers: ws.layers,
    }
}

fn temp_path(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("cgcn_serve_e2e");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

#[test]
fn admm_snapshot_roundtrips_and_serves_bitwise_identical() {
    let ws = caveman_workspace(3);
    let backend: Arc<NativeBackend> = Arc::new(NativeBackend::new());
    let mut trainer =
        AdmmTrainer::new(ws.clone(), backend.clone(), AdmmOptions::for_mode(ws.m)).unwrap();
    trainer.train(5, "e2e").unwrap();
    let trained_eval = trainer.evaluate().unwrap();

    // Save → load → rebuild: same weights, same evaluation.
    let path = temp_path("admm.cgnm");
    trainer.save_model(&path, meta("e2e", &ws)).unwrap();
    let snap = load_model(&path).unwrap();
    std::fs::remove_file(&path).ok();
    for (a, b) in snap.w.iter().zip(&trainer.state.w) {
        assert_eq!(a.data(), b.data(), "weights drifted through the codec");
    }
    let mut session = InferenceSession::from_snapshot(&snap, backend.clone()).unwrap();
    assert_eq!(session.evaluate().unwrap(), trained_eval);

    // Reference logits from the exact evaluate_forward kernel sequence.
    let full = session.full_logits().unwrap();
    let n = session.n();

    // Serve it: 4 handler threads, a wide batch window so concurrent
    // queries coalesce.
    let handle = serve(
        session,
        &ServeOptions {
            addr: "127.0.0.1:0".to_string(),
            threads: 4,
            batch_window_us: 2_000,
            max_batch: 64,
        },
    )
    .unwrap();
    let addr = handle.addr().to_string();

    // Concurrent clients with overlapping random node subsets; every
    // response row must equal the reference bitwise.
    let full_ref = &full;
    let addr_ref = &addr;
    let per_client = 12usize;
    std::thread::scope(|s| {
        for ci in 0..4u64 {
            s.spawn(move || {
                let mut rng = Rng::new(100 + ci);
                let mut client = ServeClient::connect(addr_ref).unwrap();
                let info = client.info().unwrap();
                assert_eq!(info.n, n);
                for _ in 0..per_client {
                    let k = 1 + rng.gen_range(6);
                    let nodes: Vec<usize> = (0..k).map(|_| rng.gen_range(n)).collect();
                    let rows = client.query(&nodes).unwrap();
                    assert_eq!(rows.len(), nodes.len());
                    for (row, &id) in rows.iter().zip(&nodes) {
                        assert_eq!(
                            row.as_slice(),
                            full_ref.row(id),
                            "served logits differ from evaluate_forward at node {id}"
                        );
                    }
                }
            });
        }
    });

    // Counters: every request answered; batching means batches ≤ requests.
    let (requests, nodes, batches) = handle.counters();
    assert_eq!(requests, 4 * per_client as u64);
    assert!(nodes >= requests, "every query carries ≥ 1 node");
    assert!(batches >= 1 && batches <= requests);

    // Remote shutdown: the ack arrives before the server exits, and
    // wait() returns even though an idle client is still connected
    // (shutdown force-closes registered sockets so no handler can pin
    // its pool worker).
    let idle = ServeClient::connect(&addr).unwrap();
    let mut closer = ServeClient::connect(&addr).unwrap();
    closer.shutdown().unwrap();
    drop(closer);
    handle.wait();
    drop(idle);
}

#[test]
fn baseline_snapshot_serves_too() {
    let ws = caveman_workspace(2);
    let backend: Arc<NativeBackend> = Arc::new(NativeBackend::new());
    let opt = Optimizer::parse("adam", None).unwrap();
    let mut trainer = BaselineTrainer::new(ws.clone(), backend.clone(), opt).unwrap();
    trainer.train(3).unwrap();
    let path = temp_path("adam.cgnm");
    trainer.save_model(&path, meta("adam", &ws)).unwrap();
    let snap = load_model(&path).unwrap();
    std::fs::remove_file(&path).ok();

    let mut session = InferenceSession::from_snapshot(&snap, backend).unwrap();
    assert_eq!(session.evaluate().unwrap(), trainer.evaluate().unwrap());

    // Subset queries (cold cache) match the full pass bitwise.
    let full = session.full_logits().unwrap();
    let mut cold = InferenceSession::from_snapshot(&snap, Arc::new(NativeBackend::new())).unwrap();
    let ids: Vec<usize> = (0..cold.n()).step_by(3).collect();
    let got = cold.logits_for(&ids).unwrap();
    for (qi, &id) in ids.iter().enumerate() {
        assert_eq!(got.row(qi), full.row(id));
    }
}

#[test]
fn cluster_gcn_snapshot_serves_too() {
    // A mini-batch-trained model must produce a snapshot the serving
    // stack accepts exactly like a full-batch one: same codec, same
    // workspace rebuild, identical evaluation through the session.
    let ds = Arc::new(cgcn::cmd::load_dataset("caveman", 1.0, SEED).unwrap());
    let ws = caveman_workspace(3);
    let backend: Arc<NativeBackend> = Arc::new(NativeBackend::new());
    let opt = Optimizer::parse("adam", None).unwrap();
    let mut trainer = ClusterGcnTrainer::new(
        ds,
        ws.clone(),
        backend.clone(),
        opt,
        ClusterGcnOptions {
            clusters: 8,
            batch_clusters: 2,
            method: Method::Metis,
        },
    )
    .unwrap();
    trainer.train(3).unwrap();
    assert!(trainer.peak_batch_nodes() > 0);
    assert!(
        trainer.peak_batch_nodes() < ws.n,
        "mini-batch peak {} should be below the full graph {}",
        trainer.peak_batch_nodes(),
        ws.n
    );

    let path = temp_path("cluster_gcn.cgnm");
    trainer.save_model(&path, meta("cluster-gcn", &ws)).unwrap();
    let snap = load_model(&path).unwrap();
    std::fs::remove_file(&path).ok();
    for (a, b) in snap.w.iter().zip(trainer.weights()) {
        assert_eq!(a.data(), b.data(), "weights drifted through the codec");
    }

    let mut session = InferenceSession::from_snapshot(&snap, backend).unwrap();
    assert_eq!(session.evaluate().unwrap(), trainer.evaluate().unwrap());

    // Subset queries (cold cache) match the full pass bitwise.
    let full = session.full_logits().unwrap();
    let mut cold = InferenceSession::from_snapshot(&snap, Arc::new(NativeBackend::new())).unwrap();
    let ids: Vec<usize> = (0..cold.n()).step_by(5).collect();
    let got = cold.logits_for(&ids).unwrap();
    for (qi, &id) in ids.iter().enumerate() {
        assert_eq!(got.row(qi), full.row(id));
    }
}

#[test]
fn multithreaded_op_backend_serves_identically() {
    // The batcher may run a pooled backend; results must not change.
    let ws = caveman_workspace(3);
    let mut rng = Rng::new(77);
    let w: Vec<Matrix> = (1..=ws.layers)
        .map(|l| Matrix::glorot(ws.dims[l - 1], ws.dims[l], &mut rng))
        .collect();
    let mut serial =
        InferenceSession::new(ws.clone(), Arc::new(NativeBackend::new()), w.clone()).unwrap();
    let mut pooled =
        InferenceSession::new(ws.clone(), Arc::new(NativeBackend::with_grain(4, 0)), w).unwrap();
    let full = serial.full_logits().unwrap();
    let ids: Vec<usize> = (0..ws.n).collect();
    let got = pooled.logits_for(&ids).unwrap();
    assert_eq!(got.data(), full.data());
}
