//! End-to-end serving: train → `save_model` → `load_model` → an
//! [`InferenceSession`] behind the batched multi-threaded TCP server,
//! with concurrent clients asserting that served logits are bitwise
//! identical to the in-process forward pass (the acceptance bar for the
//! serving subsystem — batching and threading must be pure scheduling,
//! never numerics).

use cgcn::baselines::{BaselineTrainer, ClusterGcnOptions, ClusterGcnTrainer, Optimizer};
use cgcn::config::HyperParams;
use cgcn::coordinator::{AdmmOptions, AdmmTrainer, Workspace};
use cgcn::partition::Method;
use cgcn::runtime::NativeBackend;
use cgcn::serve::{load_model, serve, InferenceSession, ServeClient, ServeOptions, SnapshotMeta};
use cgcn::tensor::Matrix;
use cgcn::util::rng::Rng;
use std::path::PathBuf;
use std::sync::Arc;

const SEED: u64 = 5;

fn caveman_workspace(m: usize) -> Arc<Workspace> {
    // Through the same loader the snapshot rebuild uses, so the
    // roundtrip replays an identical workspace.
    let ds = cgcn::cmd::load_dataset("caveman", 1.0, SEED).unwrap();
    let mut hp = HyperParams::for_dataset("caveman");
    hp.communities = m;
    hp.hidden = 8;
    hp.seed = SEED;
    Arc::new(Workspace::build(&ds, &hp, Method::Metis).unwrap())
}

fn meta(label: &str, ws: &Workspace) -> SnapshotMeta {
    SnapshotMeta {
        label: label.to_string(),
        dataset: "caveman".to_string(),
        scale: 1.0,
        seed: SEED,
        partition: "metis".to_string(),
        communities: ws.m,
        hidden: ws.hp.hidden,
        layers: ws.layers,
    }
}

fn temp_path(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("cgcn_serve_e2e");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

#[test]
fn admm_snapshot_roundtrips_and_serves_bitwise_identical() {
    let ws = caveman_workspace(3);
    let backend: Arc<NativeBackend> = Arc::new(NativeBackend::new());
    let mut trainer =
        AdmmTrainer::new(ws.clone(), backend.clone(), AdmmOptions::for_mode(ws.m)).unwrap();
    trainer.train(5, "e2e").unwrap();
    let trained_eval = trainer.evaluate().unwrap();

    // Save → load → rebuild: same weights, same evaluation.
    let path = temp_path("admm.cgnm");
    trainer.save_model(&path, meta("e2e", &ws)).unwrap();
    let snap = load_model(&path).unwrap();
    std::fs::remove_file(&path).ok();
    for (a, b) in snap.w.iter().zip(&trainer.state.w) {
        assert_eq!(a.data(), b.data(), "weights drifted through the codec");
    }
    let mut session = InferenceSession::from_snapshot(&snap, backend.clone()).unwrap();
    assert_eq!(session.evaluate().unwrap(), trained_eval);

    // Reference logits from the exact evaluate_forward kernel sequence.
    let full = session.full_logits().unwrap();
    let n = session.n();

    // Serve it: 4 handler threads, a wide batch window so concurrent
    // queries coalesce.
    let handle = serve(
        session,
        &ServeOptions {
            addr: "127.0.0.1:0".to_string(),
            threads: 4,
            batch_window_us: 2_000,
            max_batch: 64,
        },
    )
    .unwrap();
    let addr = handle.addr().to_string();

    // Concurrent clients with overlapping random node subsets; every
    // response row must equal the reference bitwise.
    let full_ref = &full;
    let addr_ref = &addr;
    let per_client = 12usize;
    std::thread::scope(|s| {
        for ci in 0..4u64 {
            s.spawn(move || {
                let mut rng = Rng::new(100 + ci);
                let mut client = ServeClient::connect(addr_ref).unwrap();
                let info = client.info().unwrap();
                assert_eq!(info.n, n);
                for _ in 0..per_client {
                    let k = 1 + rng.gen_range(6);
                    let nodes: Vec<usize> = (0..k).map(|_| rng.gen_range(n)).collect();
                    let rows = client.query(&nodes).unwrap();
                    assert_eq!(rows.len(), nodes.len());
                    for (row, &id) in rows.iter().zip(&nodes) {
                        assert_eq!(
                            row.as_slice(),
                            full_ref.row(id),
                            "served logits differ from evaluate_forward at node {id}"
                        );
                    }
                }
            });
        }
    });

    // Counters: every request answered; batching means batches ≤ requests.
    let (requests, nodes, batches) = handle.counters();
    assert_eq!(requests, 4 * per_client as u64);
    assert!(nodes >= requests, "every query carries ≥ 1 node");
    assert!(batches >= 1 && batches <= requests);

    // Remote shutdown: the ack arrives before the server exits, and
    // wait() returns even though an idle client is still connected
    // (shutdown force-closes registered sockets so no handler can pin
    // its pool worker).
    let idle = ServeClient::connect(&addr).unwrap();
    let mut closer = ServeClient::connect(&addr).unwrap();
    closer.shutdown().unwrap();
    drop(closer);
    handle.wait();
    drop(idle);
}

#[test]
fn crash_resume_save_serve_is_bitwise_identical_to_uninterrupted() {
    use cgcn::coordinator::checkpoint::{self, CheckpointSink, CkptMeta, TrainCheckpoint};

    let ws = caveman_workspace(3);
    let backend: Arc<NativeBackend> = Arc::new(NativeBackend::new());

    // Uninterrupted reference pipeline: train 6 epochs → snapshot.
    let mut full =
        AdmmTrainer::new(ws.clone(), backend.clone(), AdmmOptions::for_mode(ws.m)).unwrap();
    full.train(6, "full").unwrap();
    let full_path = temp_path("full.cgnm");
    full.save_model(&full_path, meta("e2e-ckpt", &ws)).unwrap();

    // Interrupted pipeline: checkpoint every 3 epochs, train 3, then the
    // process "dies" (trainer dropped, nothing persisted but the .cgck).
    let ckpt_dir = std::env::temp_dir().join(format!("cgcn_e2e_ckpt_{}", std::process::id()));
    std::fs::remove_dir_all(&ckpt_dir).ok();
    let cmeta = CkptMeta {
        snap: meta("e2e-ckpt", &ws),
        method: "admm".into(),
        rho: ws.hp.rho,
        nu: ws.hp.nu,
    };
    let sink = CheckpointSink::new(3, ckpt_dir.clone(), cmeta).unwrap();
    {
        let mut pre = AdmmTrainer::new(
            ws.clone(),
            backend.clone(),
            AdmmOptions::for_mode(ws.m),
        )
        .unwrap();
        pre.train_range(0, 3, "pre-crash", Some(&sink)).unwrap();
    } // crash

    // Resume in a "fresh process": rebuild the workspace from checkpoint
    // metadata alone, restore, finish training, snapshot.
    let ck_path = checkpoint::latest_in_dir(&ckpt_dir)
        .unwrap()
        .expect("checkpoint written before crash");
    let ck = TrainCheckpoint::load(&ck_path).unwrap();
    assert_eq!(ck.epoch, 3);
    let mut hp = ck.meta.snap.base_hyperparams();
    hp.rho = ck.meta.rho;
    hp.nu = ck.meta.nu;
    let ds = cgcn::cmd::load_dataset(&ck.meta.snap.dataset, ck.meta.snap.scale, ck.meta.snap.seed)
        .unwrap();
    let rws = Arc::new(Workspace::build(&ds, &hp, Method::Metis).unwrap());
    let mut resumed =
        AdmmTrainer::new(rws.clone(), backend.clone(), AdmmOptions::for_mode(rws.m)).unwrap();
    checkpoint::restore_admm(&mut resumed, &ck).unwrap();
    resumed.train_range(3, 6, "resumed", None).unwrap();
    let resumed_path = temp_path("resumed.cgnm");
    resumed
        .save_model(&resumed_path, meta("e2e-ckpt", &rws))
        .unwrap();

    // The two snapshots are byte-identical (weights AND metadata).
    let full_bytes = std::fs::read(&full_path).unwrap();
    let resumed_bytes = std::fs::read(&resumed_path).unwrap();
    assert_eq!(
        full_bytes, resumed_bytes,
        "resumed .cgnm differs from the uninterrupted pipeline's"
    );

    // Serve the resumed model; served logits must equal the uninterrupted
    // pipeline's in-process forward pass bitwise.
    let snap = load_model(&resumed_path).unwrap();
    std::fs::remove_file(&full_path).ok();
    std::fs::remove_file(&resumed_path).ok();
    std::fs::remove_dir_all(&ckpt_dir).ok();
    let mut reference = InferenceSession::new(ws.clone(), backend.clone(), full.state.w.clone())
        .unwrap();
    let full_logits = reference.full_logits().unwrap();
    let session = InferenceSession::from_snapshot(&snap, backend).unwrap();
    let n = session.n();
    let handle = serve(
        session,
        &ServeOptions {
            addr: "127.0.0.1:0".to_string(),
            threads: 2,
            batch_window_us: 200,
            max_batch: 64,
        },
    )
    .unwrap();
    let addr = handle.addr().to_string();
    let mut client = ServeClient::connect(&addr).unwrap();
    let ids: Vec<usize> = (0..n).collect();
    for chunk in ids.chunks(64) {
        let rows = client.query(chunk).unwrap();
        assert_eq!(rows.len(), chunk.len());
        for (row, &id) in rows.iter().zip(chunk) {
            assert_eq!(
                row.as_slice(),
                full_logits.row(id),
                "served logits after crash+resume differ at node {id}"
            );
        }
    }
    let mut closer = ServeClient::connect(&addr).unwrap();
    closer.shutdown().unwrap();
    drop(closer);
    drop(client);
    handle.wait();
}

#[test]
fn baseline_snapshot_serves_too() {
    let ws = caveman_workspace(2);
    let backend: Arc<NativeBackend> = Arc::new(NativeBackend::new());
    let opt = Optimizer::parse("adam", None).unwrap();
    let mut trainer = BaselineTrainer::new(ws.clone(), backend.clone(), opt).unwrap();
    trainer.train(3).unwrap();
    let path = temp_path("adam.cgnm");
    trainer.save_model(&path, meta("adam", &ws)).unwrap();
    let snap = load_model(&path).unwrap();
    std::fs::remove_file(&path).ok();

    let mut session = InferenceSession::from_snapshot(&snap, backend).unwrap();
    assert_eq!(session.evaluate().unwrap(), trainer.evaluate().unwrap());

    // Subset queries (cold cache) match the full pass bitwise.
    let full = session.full_logits().unwrap();
    let mut cold = InferenceSession::from_snapshot(&snap, Arc::new(NativeBackend::new())).unwrap();
    let ids: Vec<usize> = (0..cold.n()).step_by(3).collect();
    let got = cold.logits_for(&ids).unwrap();
    for (qi, &id) in ids.iter().enumerate() {
        assert_eq!(got.row(qi), full.row(id));
    }
}

#[test]
fn cluster_gcn_snapshot_serves_too() {
    // A mini-batch-trained model must produce a snapshot the serving
    // stack accepts exactly like a full-batch one: same codec, same
    // workspace rebuild, identical evaluation through the session.
    let ds = Arc::new(cgcn::cmd::load_dataset("caveman", 1.0, SEED).unwrap());
    let ws = caveman_workspace(3);
    let backend: Arc<NativeBackend> = Arc::new(NativeBackend::new());
    let opt = Optimizer::parse("adam", None).unwrap();
    let mut trainer = ClusterGcnTrainer::new(
        ds,
        ws.clone(),
        backend.clone(),
        opt,
        ClusterGcnOptions {
            clusters: 8,
            batch_clusters: 2,
            method: Method::Metis,
        },
    )
    .unwrap();
    trainer.train(3).unwrap();
    assert!(trainer.peak_batch_nodes() > 0);
    assert!(
        trainer.peak_batch_nodes() < ws.n,
        "mini-batch peak {} should be below the full graph {}",
        trainer.peak_batch_nodes(),
        ws.n
    );

    let path = temp_path("cluster_gcn.cgnm");
    trainer.save_model(&path, meta("cluster-gcn", &ws)).unwrap();
    let snap = load_model(&path).unwrap();
    std::fs::remove_file(&path).ok();
    for (a, b) in snap.w.iter().zip(trainer.weights()) {
        assert_eq!(a.data(), b.data(), "weights drifted through the codec");
    }

    let mut session = InferenceSession::from_snapshot(&snap, backend).unwrap();
    assert_eq!(session.evaluate().unwrap(), trainer.evaluate().unwrap());

    // Subset queries (cold cache) match the full pass bitwise.
    let full = session.full_logits().unwrap();
    let mut cold = InferenceSession::from_snapshot(&snap, Arc::new(NativeBackend::new())).unwrap();
    let ids: Vec<usize> = (0..cold.n()).step_by(5).collect();
    let got = cold.logits_for(&ids).unwrap();
    for (qi, &id) in ids.iter().enumerate() {
        assert_eq!(got.row(qi), full.row(id));
    }
}

#[test]
fn multithreaded_op_backend_serves_identically() {
    // The batcher may run a pooled backend; results must not change.
    let ws = caveman_workspace(3);
    let mut rng = Rng::new(77);
    let w: Vec<Matrix> = (1..=ws.layers)
        .map(|l| Matrix::glorot(ws.dims[l - 1], ws.dims[l], &mut rng))
        .collect();
    let mut serial =
        InferenceSession::new(ws.clone(), Arc::new(NativeBackend::new()), w.clone()).unwrap();
    let mut pooled =
        InferenceSession::new(ws.clone(), Arc::new(NativeBackend::with_grain(4, 0)), w).unwrap();
    let full = serial.full_logits().unwrap();
    let ids: Vec<usize> = (0..ws.n).collect();
    let got = pooled.logits_for(&ids).unwrap();
    assert_eq!(got.data(), full.data());
}
