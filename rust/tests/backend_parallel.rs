//! Backend-equivalence property tests and the serial-vs-threads executor
//! determinism guarantee.
//!
//! Two claims under test (DESIGN.md):
//! 1. `NativeBackend` ops at any thread count are *bitwise* identical to
//!    the serial host reference ops in `cgcn::tensor` — row-block
//!    parallelism never reorders a single accumulation.
//! 2. `--exec threads` produces bitwise-identical epoch metrics and final
//!    state to `--exec serial` for a fixed seed: the channel-based message
//!    exchange canonicalises fold order.

use cgcn::config::HyperParams;
use cgcn::coordinator::{
    run_elastic_training, AdmmOptions, AdmmTrainer, ChannelTransport, ElasticCfg, ExecMode,
    LinkModel, Workspace,
};
use cgcn::data::fixtures;
use cgcn::graph::Csr;
use cgcn::partition::Method;
use cgcn::runtime::{ComputeBackend, NativeBackend};
use cgcn::tensor::{masked_cross_entropy, Matrix};
use cgcn::prop_assert;
use cgcn::util::pool::Runtime;
use cgcn::util::proplite;
use std::sync::Arc;

fn gen_matrix(g: &mut proplite::Gen, rows: usize, cols: usize) -> Matrix {
    let data = g.vec_f32(rows * cols, 2.0);
    Matrix::from_vec(rows, cols, data)
}

#[test]
fn prop_matmul_variants_match_reference_at_all_thread_counts() {
    proplite::check("matmul-thread-equiv", 40, 0xBEEF, |g| {
        let n = g.usize_in(1, 24);
        let a = g.usize_in(1, 16);
        let b = g.usize_in(1, 12);
        let x = gen_matrix(g, n, a);
        let w = gen_matrix(g, a, b);
        let y = gen_matrix(g, n, b);
        let want_nn = x.matmul(&w);
        let want_tn = x.transpose().matmul(&y);
        for threads in [1usize, 2, 4, 8] {
            // Grain 0 forces the parallel path even on tiny shapes.
            let be = NativeBackend::with_grain(threads, 0);
            let got = be.mm_nn(&x, &w).map_err(|e| e.to_string())?;
            prop_assert!(
                got.data() == want_nn.data(),
                "mm_nn differs at {threads} threads ({n}x{a}x{b})"
            );
            let got = be.mm_tn(&x, &y).map_err(|e| e.to_string())?;
            prop_assert!(
                got.data() == want_tn.data(),
                "mm_tn differs at {threads} threads ({n}x{a}x{b})"
            );
            let got = be.mm_bt(&y, &w).map_err(|e| e.to_string())?;
            let serial = NativeBackend::new().mm_bt(&y, &w).map_err(|e| e.to_string())?;
            prop_assert!(
                got.data() == serial.data(),
                "mm_bt differs at {threads} threads ({n}x{a}x{b})"
            );
            let got = be.fwd_relu(&x, &w).map_err(|e| e.to_string())?;
            let want_relu = cgcn::tensor::relu(&want_nn);
            prop_assert!(
                got.data() == want_relu.data(),
                "fwd_relu differs at {threads} threads"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_spmm_matches_reference_at_all_thread_counts() {
    proplite::check("spmm-thread-equiv", 40, 0xF00D, |g| {
        let n = g.usize_in(1, 24);
        let m = g.usize_in(1, 24);
        let k = g.usize_in(1, 8);
        let mut trips = Vec::new();
        for r in 0..n {
            for c in 0..m {
                if g.rng.gen_bool(0.25) {
                    trips.push((r, c, g.f32_in(1.5)));
                }
            }
        }
        let a = Csr::from_triplets(n, m, &trips);
        let x = gen_matrix(g, m, k);
        let want = a.spmm(&x);
        for threads in [1usize, 2, 4, 8] {
            let be = NativeBackend::with_grain(threads, 0);
            let got = be.spmm(&a, &x);
            prop_assert!(
                got.data() == want.data(),
                "spmm differs at {threads} threads (nnz={})",
                a.nnz()
            );
        }
        Ok(())
    });
}

#[test]
fn prop_softmax_xent_matches_host_reference() {
    proplite::check("xent-host-equiv", 40, 0xCAFE, |g| {
        let n = g.usize_in(1, 24);
        let c = g.usize_in(2, 8);
        let logits = gen_matrix(g, n, c).scale(3.0);
        let labels: Vec<usize> = (0..n).map(|_| g.rng.gen_range(c)).collect();
        let mut y = Matrix::zeros(n, c);
        let mut mask = vec![0.0f32; n];
        let mut any = false;
        for i in 0..n {
            y.set(i, labels[i], 1.0);
            if g.rng.gen_bool(0.7) {
                mask[i] = 1.0;
                any = true;
            }
        }
        if !any {
            mask[0] = 1.0;
        }
        let denom: f32 = mask.iter().sum();
        let be = NativeBackend::new();
        let got = be
            .xent_loss(&logits, &y, &mask, denom)
            .map_err(|e| e.to_string())? as f64;
        let (want, _) = masked_cross_entropy(&logits, &labels, &mask);
        prop_assert!(
            (got - want).abs() < 1e-4 * want.abs().max(1.0),
            "xent mismatch: backend {got} vs host {want} (n={n} c={c})"
        );
        Ok(())
    });
}

#[test]
fn prop_every_backend_op_is_bitwise_identical_to_serial() {
    // The full-trait sweep: every ComputeBackend method, odd shapes, grain
    // forced to 0 so even 1-row matrices take the parallel path, and both
    // executors (persistent pool and legacy spawn-per-op). "Bitwise" means
    // `assert_eq!` on raw data and on exact f32 scalar returns — the
    // one-writer-per-element / caller-ordered-fold scheme admits no
    // tolerance.
    proplite::check("all-ops-thread-equiv", 25, 0x5EED, |g| {
        let n = g.usize_in(1, 37);
        let c = g.usize_in(2, 11);
        let k = g.usize_in(1, 9);
        let pre = gen_matrix(g, n, c);
        let zt = gen_matrix(g, n, c);
        let u = gen_matrix(g, n, c);
        let gsum = gen_matrix(g, n, c);
        let x = gen_matrix(g, n, k);
        let w = gen_matrix(g, k, c);
        let mut y = Matrix::zeros(n, c);
        let mut mask = vec![0.0f32; n];
        for i in 0..n {
            y.set(i, g.rng.gen_range(c), 1.0);
            if g.rng.gen_bool(0.6) {
                mask[i] = 1.0;
            }
        }
        mask[0] = 1.0;
        let denom: f32 = mask.iter().sum();
        let (nu, rho, theta) = (0.7f32, 1.3f32, 2.0f32);

        let s = NativeBackend::new();
        let e = |e: anyhow::Error| e.to_string();
        let s_mm_nn = s.mm_nn(&x, &w).map_err(e)?;
        let s_mm_tn = s.mm_tn(&pre, &zt).map_err(e)?;
        let s_mm_bt = s.mm_bt(&pre, &zt).map_err(e)?;
        let s_relu = s.fwd_relu(&x, &w).map_err(e)?;
        let s_hres = s.hidden_residual(&pre, &zt, nu).map_err(e)?;
        let s_hphi = s.hidden_phi(&pre, &zt, nu).map_err(e)?;
        let s_ores = s.out_residual(&pre, &zt, &u, rho).map_err(e)?;
        let s_ophi = s.out_phi(&pre, &zt, &u, rho).map_err(e)?;
        let s_prox = s.z_prox_val(&zt, &pre, nu).map_err(e)?;
        let s_comb = s.z_combine(&zt, &pre, &gsum, nu, theta).map_err(e)?;
        let s_fista = s
            .zl_fista(&pre, &u, &y, &mask, &zt, rho, denom, 5)
            .map_err(e)?;
        let s_xent = s.xent_loss(&pre, &y, &mask, denom).map_err(e)?;
        let s_bpo = s.bp_out_grads(&x, &w, &y, &mask, denom).map_err(e)?;
        let s_bph = s.bp_hidden_grads(&x, &w, &gsum).map_err(e)?;

        for threads in [2usize, 3, 8] {
            for spawn in [false, true] {
                let be = if spawn {
                    NativeBackend::with_spawn_grain(threads, 0)
                } else {
                    NativeBackend::with_grain(threads, 0)
                };
                let tag = if spawn { "spawn" } else { "pool" };
                let ctx = format!("{tag} t={threads} n={n} c={c} k={k}");
                // Two passes: the second reuses arena buffers recycled
                // after the first, proving stale scratch never leaks into
                // results (recycle is part of the trait surface too).
                for pass in 0..2 {
                    let p = be.mm_nn(&x, &w).map_err(e)?;
                    prop_assert!(p.data() == s_mm_nn.data(), "mm_nn {ctx} pass {pass}");
                    be.recycle(p);
                    let p = be.mm_tn(&pre, &zt).map_err(e)?;
                    prop_assert!(p.data() == s_mm_tn.data(), "mm_tn {ctx} pass {pass}");
                    be.recycle(p);
                    let p = be.mm_bt(&pre, &zt).map_err(e)?;
                    prop_assert!(p.data() == s_mm_bt.data(), "mm_bt {ctx} pass {pass}");
                    be.recycle(p);
                    let p = be.fwd_relu(&x, &w).map_err(e)?;
                    prop_assert!(p.data() == s_relu.data(), "fwd_relu {ctx} pass {pass}");
                    be.recycle(p);
                    let (v, r) = be.hidden_residual(&pre, &zt, nu).map_err(e)?;
                    prop_assert!(
                        v == s_hres.0 && r.data() == s_hres.1.data(),
                        "hidden_residual {ctx} pass {pass}"
                    );
                    be.recycle(r);
                    let v = be.hidden_phi(&pre, &zt, nu).map_err(e)?;
                    prop_assert!(v == s_hphi, "hidden_phi {ctx} pass {pass}");
                    let (v, r) = be.out_residual(&pre, &zt, &u, rho).map_err(e)?;
                    prop_assert!(
                        v == s_ores.0 && r.data() == s_ores.1.data(),
                        "out_residual {ctx} pass {pass}"
                    );
                    be.recycle(r);
                    let v = be.out_phi(&pre, &zt, &u, rho).map_err(e)?;
                    prop_assert!(v == s_ophi, "out_phi {ctx} pass {pass}");
                    let v = be.z_prox_val(&zt, &pre, nu).map_err(e)?;
                    prop_assert!(v == s_prox, "z_prox_val {ctx} pass {pass}");
                    let (zn, prox0, gsq) =
                        be.z_combine(&zt, &pre, &gsum, nu, theta).map_err(e)?;
                    prop_assert!(
                        zn.data() == s_comb.0.data() && prox0 == s_comb.1 && gsq == s_comb.2,
                        "z_combine {ctx} pass {pass}"
                    );
                    be.recycle(zn);
                    let (zl, risk) = be
                        .zl_fista(&pre, &u, &y, &mask, &zt, rho, denom, 5)
                        .map_err(e)?;
                    prop_assert!(
                        zl.data() == s_fista.0.data() && risk == s_fista.1,
                        "zl_fista {ctx} pass {pass}"
                    );
                    be.recycle(zl);
                    let v = be.xent_loss(&pre, &y, &mask, denom).map_err(e)?;
                    prop_assert!(v == s_xent, "xent_loss {ctx} pass {pass}");
                    let (loss, dw2, dh1) =
                        be.bp_out_grads(&x, &w, &y, &mask, denom).map_err(e)?;
                    prop_assert!(
                        loss == s_bpo.0
                            && dw2.data() == s_bpo.1.data()
                            && dh1.data() == s_bpo.2.data(),
                        "bp_out_grads {ctx} pass {pass}"
                    );
                    be.recycle(dw2);
                    be.recycle(dh1);
                    let dw1 = be.bp_hidden_grads(&x, &w, &gsum).map_err(e)?;
                    prop_assert!(
                        dw1.data() == s_bph.data(),
                        "bp_hidden_grads {ctx} pass {pass}"
                    );
                    be.recycle(dw1);
                }
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Executor determinism
// ---------------------------------------------------------------------------

fn caveman_ws(m: usize) -> Arc<Workspace> {
    let ds = fixtures::caveman(24, 3);
    let mut hp = HyperParams::for_dataset("caveman");
    hp.hidden = 8;
    hp.communities = m;
    Arc::new(Workspace::build(&ds, &hp, Method::Metis).unwrap())
}

#[test]
fn threads_exec_is_bitwise_identical_to_serial() {
    let ws = caveman_ws(3);
    let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::new());

    let mut serial =
        AdmmTrainer::new(ws.clone(), backend.clone(), AdmmOptions::for_mode(3)).unwrap();
    let mut threaded = {
        let mut o = AdmmOptions::for_mode(3);
        o.exec = ExecMode::Threads;
        o.threads = 4;
        AdmmTrainer::new(ws, backend, o).unwrap()
    };

    let rs = serial.train(3, "serial-exec").unwrap();
    let rt = threaded.train(3, "threads-exec").unwrap();

    assert_eq!(rs.epochs.len(), rt.epochs.len());
    for (a, b) in rs.epochs.iter().zip(&rt.epochs) {
        assert_eq!(a.loss, b.loss, "epoch {} loss differs", a.epoch);
        assert_eq!(a.train_acc, b.train_acc, "epoch {} train acc", a.epoch);
        assert_eq!(a.test_acc, b.test_acc, "epoch {} test acc", a.epoch);
        assert_eq!(a.bytes, b.bytes, "epoch {} bytes", a.epoch);
    }

    // Full final state, bit for bit.
    for (ws_, wt) in serial.state.w.iter().zip(&threaded.state.w) {
        assert_eq!(ws_.data(), wt.data(), "weights diverged");
    }
    for (zl_s, zl_t) in serial.state.z.iter().zip(&threaded.state.z) {
        for (zs, zt) in zl_s.iter().zip(zl_t) {
            assert_eq!(zs.data(), zt.data(), "Z diverged");
        }
    }
    for (us, ut) in serial.state.u.iter().zip(&threaded.state.u) {
        assert_eq!(us.data(), ut.data(), "U diverged");
    }
}

#[test]
fn threads_exec_learns_fig1_like_serial() {
    let ds = fixtures::fig1();
    let mut hp = HyperParams::for_dataset("fig1");
    hp.hidden = 8;
    hp.communities = 3;
    let ws = Arc::new(Workspace::build(&ds, &hp, Method::Metis).unwrap());
    let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::new());
    let mut o = AdmmOptions::for_mode(3);
    o.exec = ExecMode::Threads;
    o.threads = 3;
    let mut t = AdmmTrainer::new(ws, backend, o).unwrap();
    let rep = t.train(40, "threads").unwrap();
    assert!(rep.best_test_acc() >= 0.7, "best test {}", rep.best_test_acc());
    assert!(rep.total_bytes() > 0);
}

#[test]
fn exec_mode_parses() {
    assert_eq!(ExecMode::parse("serial"), Some(ExecMode::Serial));
    assert_eq!(ExecMode::parse("threads"), Some(ExecMode::Threads));
    assert_eq!(ExecMode::parse("gpu"), None);
    assert_eq!(ExecMode::Threads.name(), "threads");
}

// ---------------------------------------------------------------------------
// Shared work-stealing runtime (`--runtime shared`)
// ---------------------------------------------------------------------------

/// Agents (communities) × runtime budgets, all nested on one shared
/// work-stealing runtime with grain 0 (every kernel forks, so agent
/// tasks and kernel chunks genuinely interleave on the same workers).
/// Stealing may move chunks between workers, but must never change a
/// single bit of the training output.
#[test]
fn shared_runtime_nested_parallelism_is_bitwise_identical_to_serial() {
    for m in [1usize, 2, 4] {
        let ws = caveman_ws(m);
        let serial_be: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::new());
        let mut serial =
            AdmmTrainer::new(ws.clone(), serial_be, AdmmOptions::for_mode(m)).unwrap();
        let rs = serial.train(3, "serial-ref").unwrap();

        for budget in [1usize, 2, 8] {
            let rt = Arc::new(Runtime::new(budget));
            let be: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::with_runtime_grain(rt, 0));
            assert!(be.runtime().is_some(), "backend must expose the runtime");
            let mut o = AdmmOptions::for_mode(m);
            o.exec = ExecMode::Threads;
            let mut t = AdmmTrainer::new(ws.clone(), be, o).unwrap();
            let r = t.train(3, "shared-rt").unwrap();

            assert_eq!(rs.epochs.len(), r.epochs.len());
            for (a, b) in rs.epochs.iter().zip(&r.epochs) {
                assert_eq!(a.loss, b.loss, "m={m} budget={budget} epoch {} loss", a.epoch);
                assert_eq!(a.train_acc, b.train_acc, "m={m} budget={budget} train acc");
                assert_eq!(a.test_acc, b.test_acc, "m={m} budget={budget} test acc");
            }
            for (a, b) in serial.state.w.iter().zip(&t.state.w) {
                assert_eq!(a.data(), b.data(), "m={m} budget={budget}: W diverged");
            }
            for (zl_s, zl_t) in serial.state.z.iter().zip(&t.state.z) {
                for (zs, zt) in zl_s.iter().zip(zl_t) {
                    assert_eq!(zs.data(), zt.data(), "m={m} budget={budget}: Z diverged");
                }
            }
            for (us, ut) in serial.state.u.iter().zip(&t.state.u) {
                assert_eq!(us.data(), ut.data(), "m={m} budget={budget}: U diverged");
            }
        }
    }
}

/// A hub-and-spokes (power-law-ish) graph gives `balanced_row_chunks`
/// a heavily skewed nnz distribution; repeated SpMM forks from the main
/// thread must both (a) be stolen by runtime workers at least once and
/// (b) stay bitwise identical to the serial kernel.
#[test]
fn runtime_steals_skewed_spmm_chunks_without_changing_bits() {
    cgcn::obs::force(true);
    let n = 2048;
    let mut trips: Vec<(usize, usize, f32)> = Vec::new();
    for v in 1..n {
        trips.push((0, v, 1.0));
        trips.push((v, 0, 1.0));
    }
    for v in 1..n - 1 {
        trips.push((v, v + 1, 0.5));
    }
    let a = Csr::from_triplets(n, n, &trips);
    // The hub row dominates: nnz-balanced chunking yields uneven row
    // spans (first chunk ~1 row, later chunks thousands).
    let chunks = a.balanced_row_chunks(8);
    assert!(chunks.len() > 1, "skewed graph should split into chunks");
    let spans: Vec<usize> = chunks.iter().map(|&(lo, hi)| hi - lo).collect();
    assert!(
        spans.iter().max() > spans.iter().min(),
        "expected uneven row spans from the hub row, got {spans:?}"
    );

    let x = {
        let mut g = proplite::Gen::new(0xD00F, 64);
        gen_matrix(&mut g, n, 16)
    };
    let want = NativeBackend::new().spmm(&a, &x);

    let before = cgcn::obs::registry().snapshot().counter("pool.steal");
    let rt = Arc::new(Runtime::new(4));
    let be = NativeBackend::with_runtime_grain(rt, 0);
    for round in 0..50 {
        let got = be.spmm(&a, &x);
        assert_eq!(got.data(), want.data(), "spmm diverged on round {round}");
        be.recycle(got);
    }
    let after = cgcn::obs::registry().snapshot().counter("pool.steal");
    assert!(
        after > before,
        "no chunk was stolen across 50 skewed spmm forks (before={before} after={after})"
    );
}

/// A panic inside a (possibly stolen) chunk must land on the fork
/// caller — not on whichever worker ran the chunk — and the runtime
/// must stay fully usable afterwards.
#[test]
fn runtime_panic_under_stealing_propagates_to_fork_caller() {
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::sync::atomic::{AtomicUsize, Ordering};

    let rt = Arc::new(Runtime::new(4));
    let caught = catch_unwind(AssertUnwindSafe(|| {
        rt.run(16, &|i| {
            if i == 11 {
                panic!("chunk 11 exploded");
            }
        });
    }));
    let payload = caught.expect_err("panic must propagate to the fork caller");
    let msg = payload
        .downcast_ref::<&str>()
        .copied()
        .unwrap_or_default();
    assert!(msg.contains("chunk 11"), "unexpected panic payload {msg:?}");

    // The runtime survives the poisoned fork.
    let total = AtomicUsize::new(0);
    rt.run(16, &|i| {
        total.fetch_add(i + 1, Ordering::Relaxed);
    });
    assert_eq!(total.load(Ordering::Relaxed), 136);
}

// ---------------------------------------------------------------------------
// SIMD microkernel (DESIGN.md §12)
// ---------------------------------------------------------------------------

/// Serialises the tests that flip the global `tensor::simd` gate. Results
/// are bitwise invariant under the gate, so concurrent flips can't corrupt
/// any *data* assertion — but the fallback test asserts the gate *value*,
/// which must not race another test's `force` calls.
static SIMD_GATE_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// The tentpole claim for the 8-wide AVX matmul microkernel: SIMD-on,
/// SIMD-off and serial are bitwise identical for every dense matmul-family
/// op, at shapes hitting every remainder lane (`cols % 8 ∈ {0..7}`,
/// including `cols < 8` where only the scalar tail runs), at threads
/// {1, 2, 8}, on both kernel engines (owned `FjPool` and the shared
/// work-stealing `Runtime`). On hosts without AVX `with_simd(true)`
/// clamps to scalar and the sweep still holds. The host-side
/// `Matrix::matmul` runs the same microkernel behind the global
/// `tensor::simd` gate — flipping the gate mid-process is safe precisely
/// because results never depend on it.
#[test]
fn simd_sweep_every_lane_thread_count_and_engine_is_bitwise_identical() {
    use cgcn::tensor::simd;
    let _gate = SIMD_GATE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let mut g = proplite::Gen::new(0x51BD, 64);
    // One shared runtime per budget, reused across the whole sweep.
    let rts: Vec<Arc<Runtime>> = [1usize, 2, 8]
        .iter()
        .map(|&t| Arc::new(Runtime::new(t)))
        .collect();
    for cols in [1usize, 2, 3, 4, 5, 6, 7, 8, 9, 12, 15, 16, 24] {
        let n = g.usize_in(3, 17);
        let inner = g.usize_in(1, 11);
        let x = gen_matrix(&mut g, n, inner); // mm_nn/fwd_relu lhs
        let w = gen_matrix(&mut g, inner, cols); // lanes = cols
        let y = gen_matrix(&mut g, n, cols); // mm_tn rhs, lanes = cols
        let wb = gen_matrix(&mut g, cols, inner); // mm_bt rhs, lanes = cols

        // Host reference with the global gate forced off, then on: the
        // serial `Matrix::matmul` must not move a bit either.
        simd::force(false);
        let want_nn = x.matmul(&w);
        let want_tn = x.transpose().matmul(&y);
        simd::force(true);
        assert_eq!(x.matmul(&w).data(), want_nn.data(), "host matmul cols={cols}");
        assert_eq!(
            x.transpose().matmul(&y).data(),
            want_tn.data(),
            "host matmul (tn) cols={cols}"
        );

        let serial = NativeBackend::new().with_simd(false);
        let want_bt = serial.mm_bt(&x, &wb).unwrap();
        let want_relu = serial.fwd_relu(&x, &w).unwrap();
        for (ti, &threads) in [1usize, 2, 8].iter().enumerate() {
            for shared in [false, true] {
                for on in [false, true] {
                    let be = if shared {
                        NativeBackend::with_runtime_grain(rts[ti].clone(), 0).with_simd(on)
                    } else {
                        NativeBackend::with_grain(threads, 0).with_simd(on)
                    };
                    let ctx = format!(
                        "cols={cols} t={threads} {} simd={on}",
                        if shared { "shared-rt" } else { "pool" }
                    );
                    assert_eq!(be.mm_nn(&x, &w).unwrap().data(), want_nn.data(), "mm_nn {ctx}");
                    assert_eq!(be.mm_tn(&x, &y).unwrap().data(), want_tn.data(), "mm_tn {ctx}");
                    assert_eq!(be.mm_bt(&x, &wb).unwrap().data(), want_bt.data(), "mm_bt {ctx}");
                    assert_eq!(
                        be.fwd_relu(&x, &w).unwrap().data(),
                        want_relu.data(),
                        "fwd_relu {ctx}"
                    );
                }
            }
        }
    }
}

/// Detection/fallback unit contract: forcing the gate on clamps to what
/// `is_x86_feature_detected!` reported, `CGCN_SIMD=off`-style forcing off
/// always sticks, and a backend built with either override trains the
/// same bits (the end-to-end identity every other test leans on).
#[test]
fn simd_detection_fallback_clamps_and_preserves_bits() {
    use cgcn::tensor::simd;
    let _gate = SIMD_GATE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    simd::force(true);
    assert_eq!(
        simd::enabled(),
        simd::detected(),
        "forcing the gate on must clamp to hardware detection"
    );
    simd::force(false);
    assert!(!simd::enabled(), "forcing the gate off must stick");
    simd::force(true);

    let ws = caveman_ws(2);
    let scalar_be: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::new().with_simd(false));
    let simd_be: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::new().with_simd(true));
    let mut a = AdmmTrainer::new(ws.clone(), scalar_be, AdmmOptions::for_mode(2)).unwrap();
    let mut b = AdmmTrainer::new(ws, simd_be, AdmmOptions::for_mode(2)).unwrap();
    let ra = a.train(2, "scalar").unwrap();
    let rb = b.train(2, "simd").unwrap();
    for (ea, eb) in ra.epochs.iter().zip(&rb.epochs) {
        assert_eq!(ea.loss, eb.loss, "epoch {} loss", ea.epoch);
        assert_eq!(ea.test_acc, eb.test_acc, "epoch {} acc", ea.epoch);
    }
    for (wa, wb) in a.state.w.iter().zip(&b.state.w) {
        assert_eq!(wa.data(), wb.data(), "weights diverged across SIMD on/off");
    }
}

/// `--transport channel` workers share the leader's backend, so on a
/// shared runtime their per-community kernels all fork onto the same
/// worker set — and the run must still match local serial bitwise.
#[test]
fn channel_transport_on_shared_runtime_matches_serial_bitwise() {
    let ws = caveman_ws(3);
    let serial_be: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::new());
    let mut reference =
        AdmmTrainer::new(ws.clone(), serial_be, AdmmOptions::for_mode(3)).unwrap();
    reference.train(4, "serial-ref").unwrap();

    let rt = Arc::new(Runtime::new(4));
    let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::with_runtime_grain(rt, 0));
    let mut chan = AdmmTrainer::new(ws.clone(), backend.clone(), AdmmOptions::for_mode(3)).unwrap();
    let mut t = ChannelTransport::spawn(&ws, &backend, AdmmOptions::for_mode(3).gauss_seidel);
    let cfg = ElasticCfg {
        label: "shared-rt-channel".into(),
        dataset: "caveman".into(),
        start_epoch: 0,
        epochs: 4,
        link: LinkModel::new(10_000.0, 100.0),
        sink: None,
    };
    let report = run_elastic_training(&mut chan, &mut t, &cfg).unwrap();
    drop(t);
    assert_eq!(report.epochs.len(), 4);
    for (a, b) in reference.state.w.iter().zip(&chan.state.w) {
        assert_eq!(a.data(), b.data(), "channel-on-shared-runtime weights diverged");
    }
    assert_eq!(reference.evaluate().unwrap(), chan.evaluate().unwrap());
}
