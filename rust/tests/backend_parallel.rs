//! Backend-equivalence property tests and the serial-vs-threads executor
//! determinism guarantee.
//!
//! Two claims under test (DESIGN.md):
//! 1. `NativeBackend` ops at any thread count are *bitwise* identical to
//!    the serial host reference ops in `cgcn::tensor` — row-block
//!    parallelism never reorders a single accumulation.
//! 2. `--exec threads` produces bitwise-identical epoch metrics and final
//!    state to `--exec serial` for a fixed seed: the channel-based message
//!    exchange canonicalises fold order.

use cgcn::config::HyperParams;
use cgcn::coordinator::{AdmmOptions, AdmmTrainer, ExecMode, Workspace};
use cgcn::data::fixtures;
use cgcn::graph::Csr;
use cgcn::partition::Method;
use cgcn::runtime::{ComputeBackend, NativeBackend};
use cgcn::tensor::{masked_cross_entropy, Matrix};
use cgcn::prop_assert;
use cgcn::util::proplite;
use std::sync::Arc;

fn gen_matrix(g: &mut proplite::Gen, rows: usize, cols: usize) -> Matrix {
    let data = g.vec_f32(rows * cols, 2.0);
    Matrix::from_vec(rows, cols, data)
}

#[test]
fn prop_matmul_variants_match_reference_at_all_thread_counts() {
    proplite::check("matmul-thread-equiv", 40, 0xBEEF, |g| {
        let n = g.usize_in(1, 24);
        let a = g.usize_in(1, 16);
        let b = g.usize_in(1, 12);
        let x = gen_matrix(g, n, a);
        let w = gen_matrix(g, a, b);
        let y = gen_matrix(g, n, b);
        let want_nn = x.matmul(&w);
        let want_tn = x.transpose().matmul(&y);
        for threads in [1usize, 2, 4, 8] {
            // Grain 0 forces the parallel path even on tiny shapes.
            let be = NativeBackend::with_grain(threads, 0);
            let got = be.mm_nn(&x, &w).map_err(|e| e.to_string())?;
            prop_assert!(
                got.data() == want_nn.data(),
                "mm_nn differs at {threads} threads ({n}x{a}x{b})"
            );
            let got = be.mm_tn(&x, &y).map_err(|e| e.to_string())?;
            prop_assert!(
                got.data() == want_tn.data(),
                "mm_tn differs at {threads} threads ({n}x{a}x{b})"
            );
            let got = be.mm_bt(&y, &w).map_err(|e| e.to_string())?;
            let serial = NativeBackend::new().mm_bt(&y, &w).map_err(|e| e.to_string())?;
            prop_assert!(
                got.data() == serial.data(),
                "mm_bt differs at {threads} threads ({n}x{a}x{b})"
            );
            let got = be.fwd_relu(&x, &w).map_err(|e| e.to_string())?;
            let want_relu = cgcn::tensor::relu(&want_nn);
            prop_assert!(
                got.data() == want_relu.data(),
                "fwd_relu differs at {threads} threads"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_spmm_matches_reference_at_all_thread_counts() {
    proplite::check("spmm-thread-equiv", 40, 0xF00D, |g| {
        let n = g.usize_in(1, 24);
        let m = g.usize_in(1, 24);
        let k = g.usize_in(1, 8);
        let mut trips = Vec::new();
        for r in 0..n {
            for c in 0..m {
                if g.rng.gen_bool(0.25) {
                    trips.push((r, c, g.f32_in(1.5)));
                }
            }
        }
        let a = Csr::from_triplets(n, m, &trips);
        let x = gen_matrix(g, m, k);
        let want = a.spmm(&x);
        for threads in [1usize, 2, 4, 8] {
            let be = NativeBackend::with_grain(threads, 0);
            let got = be.spmm(&a, &x);
            prop_assert!(
                got.data() == want.data(),
                "spmm differs at {threads} threads (nnz={})",
                a.nnz()
            );
        }
        Ok(())
    });
}

#[test]
fn prop_softmax_xent_matches_host_reference() {
    proplite::check("xent-host-equiv", 40, 0xCAFE, |g| {
        let n = g.usize_in(1, 24);
        let c = g.usize_in(2, 8);
        let logits = gen_matrix(g, n, c).scale(3.0);
        let labels: Vec<usize> = (0..n).map(|_| g.rng.gen_range(c)).collect();
        let mut y = Matrix::zeros(n, c);
        let mut mask = vec![0.0f32; n];
        let mut any = false;
        for i in 0..n {
            y.set(i, labels[i], 1.0);
            if g.rng.gen_bool(0.7) {
                mask[i] = 1.0;
                any = true;
            }
        }
        if !any {
            mask[0] = 1.0;
        }
        let denom: f32 = mask.iter().sum();
        let be = NativeBackend::new();
        let got = be
            .xent_loss(&logits, &y, &mask, denom)
            .map_err(|e| e.to_string())? as f64;
        let (want, _) = masked_cross_entropy(&logits, &labels, &mask);
        prop_assert!(
            (got - want).abs() < 1e-4 * want.abs().max(1.0),
            "xent mismatch: backend {got} vs host {want} (n={n} c={c})"
        );
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Executor determinism
// ---------------------------------------------------------------------------

fn caveman_ws(m: usize) -> Arc<Workspace> {
    let ds = fixtures::caveman(24, 3);
    let mut hp = HyperParams::for_dataset("caveman");
    hp.hidden = 8;
    hp.communities = m;
    Arc::new(Workspace::build(&ds, &hp, Method::Metis).unwrap())
}

#[test]
fn threads_exec_is_bitwise_identical_to_serial() {
    let ws = caveman_ws(3);
    let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::new());

    let mut serial =
        AdmmTrainer::new(ws.clone(), backend.clone(), AdmmOptions::for_mode(3)).unwrap();
    let mut threaded = {
        let mut o = AdmmOptions::for_mode(3);
        o.exec = ExecMode::Threads;
        o.threads = 4;
        AdmmTrainer::new(ws, backend, o).unwrap()
    };

    let rs = serial.train(3, "serial-exec").unwrap();
    let rt = threaded.train(3, "threads-exec").unwrap();

    assert_eq!(rs.epochs.len(), rt.epochs.len());
    for (a, b) in rs.epochs.iter().zip(&rt.epochs) {
        assert_eq!(a.loss, b.loss, "epoch {} loss differs", a.epoch);
        assert_eq!(a.train_acc, b.train_acc, "epoch {} train acc", a.epoch);
        assert_eq!(a.test_acc, b.test_acc, "epoch {} test acc", a.epoch);
        assert_eq!(a.bytes, b.bytes, "epoch {} bytes", a.epoch);
    }

    // Full final state, bit for bit.
    for (ws_, wt) in serial.state.w.iter().zip(&threaded.state.w) {
        assert_eq!(ws_.data(), wt.data(), "weights diverged");
    }
    for (zl_s, zl_t) in serial.state.z.iter().zip(&threaded.state.z) {
        for (zs, zt) in zl_s.iter().zip(zl_t) {
            assert_eq!(zs.data(), zt.data(), "Z diverged");
        }
    }
    for (us, ut) in serial.state.u.iter().zip(&threaded.state.u) {
        assert_eq!(us.data(), ut.data(), "U diverged");
    }
}

#[test]
fn threads_exec_learns_fig1_like_serial() {
    let ds = fixtures::fig1();
    let mut hp = HyperParams::for_dataset("fig1");
    hp.hidden = 8;
    hp.communities = 3;
    let ws = Arc::new(Workspace::build(&ds, &hp, Method::Metis).unwrap());
    let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::new());
    let mut o = AdmmOptions::for_mode(3);
    o.exec = ExecMode::Threads;
    o.threads = 3;
    let mut t = AdmmTrainer::new(ws, backend, o).unwrap();
    let rep = t.train(40, "threads").unwrap();
    assert!(rep.best_test_acc() >= 0.7, "best test {}", rep.best_test_acc());
    assert!(rep.total_bytes() > 0);
}

#[test]
fn exec_mode_parses() {
    assert_eq!(ExecMode::parse("serial"), Some(ExecMode::Serial));
    assert_eq!(ExecMode::parse("threads"), Some(ExecMode::Threads));
    assert_eq!(ExecMode::parse("gpu"), None);
    assert_eq!(ExecMode::Threads.name(), "threads");
}
