//! Synthetic Amazon-like datasets (substitute for the paper's downloads).
//!
//! A degree-corrected stochastic block model with `num_classes` planted
//! blocks: intra-block edges are much more likely than inter-block ones
//! (matching the strong community structure of co-purchase graphs, which is
//! what makes METIS partitions effective in the paper), and features are a
//! noisy class centroid (so a 2-layer GCN can actually learn — the paper's
//! Figure-2 accuracy dynamics need learnable signal).
//!
//! `scale` shrinks node counts proportionally (features/classes/degree are
//! preserved) for fast CI runs; `scale = 1.0` reproduces Table-2 statistics
//! exactly.

use super::Dataset;
use crate::graph::Graph;
use crate::tensor::Matrix;
use crate::util::rng::Rng;

/// Generation parameters.
#[derive(Clone, Debug)]
pub struct SynthSpec {
    pub name: &'static str,
    pub nodes: usize,
    pub features: usize,
    pub classes: usize,
    pub train: usize,
    pub test: usize,
    /// Target average degree (2|E|/N).
    pub avg_degree: f64,
    /// Fraction of edge endpoints that stay within the node's block.
    pub intra_frac: f64,
    /// Feature signal-to-noise: centroid magnitude vs unit noise.
    pub signal: f32,
}

/// Amazon Computers statistics (paper Table 2; |E| from the published
/// dataset: 245,861 undirected edges → avg degree ≈ 35.76).
pub const AMAZON_COMPUTERS: SynthSpec = SynthSpec {
    name: "synth-computers",
    nodes: 13752,
    features: 767,
    classes: 10,
    train: 1000,
    test: 1000,
    avg_degree: 35.76,
    intra_frac: 0.85,
    signal: 0.3,
};

/// Amazon Photo statistics (Table 2; |E| = 119,081 → avg degree ≈ 31.13).
pub const AMAZON_PHOTO: SynthSpec = SynthSpec {
    name: "synth-photo",
    nodes: 7650,
    features: 745,
    classes: 8,
    train: 800,
    test: 1000,
    avg_degree: 31.13,
    intra_frac: 0.85,
    signal: 0.3,
};

/// Look up a spec by dataset name (`synth-computers`, `synth-photo`).
pub fn spec_by_name(name: &str) -> Option<SynthSpec> {
    match name {
        "synth-computers" => Some(AMAZON_COMPUTERS),
        "synth-photo" => Some(AMAZON_PHOTO),
        _ => None,
    }
}

/// Generate a dataset from a spec at the given node-count scale.
pub fn generate(spec: &SynthSpec, scale: f64, seed: u64) -> Dataset {
    assert!(scale > 0.0 && scale <= 1.0, "scale must be in (0,1]");
    let n = ((spec.nodes as f64 * scale).round() as usize).max(spec.classes * 8);
    let train = ((spec.train as f64 * scale).round() as usize).max(spec.classes);
    let test = ((spec.test as f64 * scale).round() as usize).max(spec.classes);
    assert!(train + test <= n, "train+test exceed node count at this scale");

    let mut rng = Rng::new(seed);

    // ---- planted blocks ----------------------------------------------------
    // Block sizes: uneven (Zipf-ish) like real co-purchase categories.
    let labels = assign_blocks(n, spec.classes, &mut rng);
    let mut blocks: Vec<Vec<usize>> = vec![Vec::new(); spec.classes];
    for (i, &c) in labels.iter().enumerate() {
        blocks[c].push(i);
    }

    // ---- edges -------------------------------------------------------------
    // Draw E = n * avg_degree / 2 edges: with prob intra_frac both endpoints
    // from one block (degree-corrected preferential pick), else across two
    // blocks. Duplicates / self-loops are dropped afterwards, so oversample
    // slightly to hit the target count.
    let target_edges = (n as f64 * spec.avg_degree / 2.0).round() as usize;
    let mut edges: Vec<(usize, usize)> = Vec::with_capacity(target_edges * 11 / 10);
    let mut seen = std::collections::HashSet::with_capacity(target_edges * 2);
    let mut attempts = 0usize;
    let max_attempts = target_edges * 20;
    while edges.len() < target_edges && attempts < max_attempts {
        attempts += 1;
        let (u, v) = if rng.gen_bool(spec.intra_frac) {
            let b = &blocks[rng.gen_range(spec.classes)];
            if b.len() < 2 {
                continue;
            }
            (b[rng.gen_range(b.len())], b[rng.gen_range(b.len())])
        } else {
            (rng.gen_range(n), rng.gen_range(n))
        };
        if u == v {
            continue;
        }
        let key = if u < v { (u, v) } else { (v, u) };
        if seen.insert(key) {
            edges.push(key);
        }
    }
    let graph = Graph::from_edges(n, &edges);

    // ---- features ----------------------------------------------------------
    // Class centroids: sparse random ±signal patterns (co-purchase features
    // are bag-of-words-like: sparse, non-negative-ish). Node feature =
    // centroid + N(0,1) noise, then ReLU to keep the bag-of-words flavour.
    let f = spec.features;
    let mut centroids = Matrix::zeros(spec.classes, f);
    for c in 0..spec.classes {
        let active = f / 8;
        for &j in rng.sample_indices(f, active).iter() {
            centroids.set(c, j, spec.signal * (1.0 + rng.gen_f32()));
        }
    }
    let mut features = Matrix::zeros(n, f);
    for i in 0..n {
        let c = labels[i];
        let row = features.row_mut(i);
        for j in 0..f {
            let v = centroids.at(c, j) + rng.gen_normal() as f32;
            row[j] = v.max(0.0);
        }
    }
    // Row-normalise (standard for these benchmarks).
    for i in 0..n {
        let row = features.row_mut(i);
        let norm: f32 = row.iter().map(|x| x * x).sum::<f32>().sqrt();
        if norm > 0.0 {
            for x in row.iter_mut() {
                *x /= norm;
            }
        }
    }

    // ---- masks (class-balanced train selection, like the benchmark) --------
    let mut train_mask = vec![0.0f32; n];
    let mut test_mask = vec![0.0f32; n];
    let per_class = train / spec.classes;
    let mut used: Vec<usize> = Vec::new();
    for b in &blocks {
        let k = per_class.min(b.len());
        for &i in rng.sample_indices(b.len(), k).iter().map(|j| &b[*j]) {
            train_mask[i] = 1.0;
            used.push(i);
        }
    }
    // Top-up to exactly `train` from any unlabeled node.
    let mut remaining: Vec<usize> = (0..n).filter(|&i| train_mask[i] == 0.0).collect();
    rng.shuffle(&mut remaining);
    let mut ri = 0;
    while train_mask.iter().filter(|&&m| m > 0.0).count() < train && ri < remaining.len() {
        train_mask[remaining[ri]] = 1.0;
        ri += 1;
    }
    // Test nodes from the rest.
    let rest: Vec<usize> = remaining[ri..].to_vec();
    for &i in rest.iter().take(test) {
        test_mask[i] = 1.0;
    }

    let ds = Dataset {
        name: format!("{}{}", spec.name, if scale < 1.0 { format!("@{scale}") } else { String::new() }),
        graph,
        features,
        labels,
        num_classes: spec.classes,
        train_mask,
        test_mask,
    };
    ds.validate();
    ds
}

/// Uneven block assignment: block sizes ∝ 1/(1+k/2), shuffled node order.
fn assign_blocks(n: usize, classes: usize, rng: &mut Rng) -> Vec<usize> {
    let weights: Vec<f64> = (0..classes).map(|k| 1.0 / (1.0 + k as f64 / 2.0)).collect();
    let total: f64 = weights.iter().sum();
    let mut sizes: Vec<usize> = weights
        .iter()
        .map(|w| ((w / total) * n as f64).floor() as usize)
        .collect();
    // Distribute the rounding remainder.
    let mut assigned: usize = sizes.iter().sum();
    let mut k = 0;
    while assigned < n {
        sizes[k % classes] += 1;
        assigned += 1;
        k += 1;
    }
    let mut labels = Vec::with_capacity(n);
    for (c, &s) in sizes.iter().enumerate() {
        labels.extend(std::iter::repeat(c).take(s));
    }
    rng.shuffle(&mut labels);
    labels
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_statistics_match_at_full_scale() {
        // Full-scale generation is a few seconds; use photo (smaller).
        let ds = generate(&AMAZON_PHOTO, 1.0, 7);
        assert_eq!(ds.n(), 7650);
        assert_eq!(ds.num_features(), 745);
        assert_eq!(ds.num_classes, 8);
        assert_eq!(ds.train_count(), 800);
        assert_eq!(ds.test_count(), 1000);
        let deg = ds.graph.avg_degree();
        assert!(
            (deg - 31.13).abs() < 2.0,
            "avg degree {deg} too far from Table-2 target"
        );
    }

    #[test]
    fn scaled_generation_shrinks_proportionally() {
        let ds = generate(&AMAZON_COMPUTERS, 0.1, 3);
        assert!((ds.n() as f64 - 1375.0).abs() < 2.0);
        assert_eq!(ds.num_features(), 767);
        assert_eq!(ds.num_classes, 10);
        assert!((ds.graph.avg_degree() - 35.76).abs() < 4.0);
        ds.validate();
    }

    #[test]
    fn deterministic_given_seed() {
        let a = generate(&AMAZON_PHOTO, 0.05, 42);
        let b = generate(&AMAZON_PHOTO, 0.05, 42);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.graph.num_edges(), b.graph.num_edges());
        assert_eq!(a.features.data(), b.features.data());
    }

    #[test]
    fn community_structure_is_planted() {
        // Intra-class edges should dominate (this is what METIS exploits).
        let ds = generate(&AMAZON_PHOTO, 0.1, 9);
        let mut intra = 0usize;
        for &(u, v) in ds.graph.edges() {
            if ds.labels[u as usize] == ds.labels[v as usize] {
                intra += 1;
            }
        }
        let frac = intra as f64 / ds.graph.num_edges() as f64;
        assert!(frac > 0.6, "intra-class edge fraction {frac} too low");
    }

    #[test]
    fn features_carry_class_signal() {
        // Mean feature distance within class < across classes.
        let ds = generate(&AMAZON_PHOTO, 0.05, 11);
        let n = ds.n();
        let mut within = (0.0f64, 0usize);
        let mut across = (0.0f64, 0usize);
        for i in (0..n).step_by(7) {
            for j in (1..n).step_by(11) {
                if i == j {
                    continue;
                }
                let d: f32 = ds
                    .features
                    .row(i)
                    .iter()
                    .zip(ds.features.row(j))
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                if ds.labels[i] == ds.labels[j] {
                    within.0 += d as f64;
                    within.1 += 1;
                } else {
                    across.0 += d as f64;
                    across.1 += 1;
                }
            }
        }
        let w = within.0 / within.1 as f64;
        let a = across.0 / across.1 as f64;
        assert!(w < a, "within-class distance {w} !< across-class {a}");
    }

    #[test]
    fn masks_disjoint_and_sized() {
        let ds = generate(&AMAZON_COMPUTERS, 0.05, 13);
        for i in 0..ds.n() {
            assert!(!(ds.train_mask[i] > 0.0 && ds.test_mask[i] > 0.0));
        }
        assert!(ds.train_count() > 0);
        assert!(ds.test_count() > 0);
    }
}
