//! `.cgnp` — the on-disk binary dataset format.
//!
//! Layout (all little-endian, via [`crate::util::wire`]):
//!
//! ```text
//! magic "CGNP" | version u32 | name str
//! n u64 | num_classes u32 | features: u64 rows, u64 cols, f32 data
//! labels u32s | train_mask f32s | test_mask f32s
//! edges: u64 count, (u32, u32) pairs
//! ```
//!
//! Real Amazon datasets exported from torch-geometric can be converted to
//! this format (see README §Datasets) and dropped in — the loaders don't
//! care whether a graph is synthetic.

use super::Dataset;
use crate::graph::Graph;
use crate::tensor::Matrix;
use crate::util::wire::{Dec, Enc};
use anyhow::{bail, Context, Result};
use std::path::Path;

const MAGIC: &[u8; 4] = b"CGNP";
const VERSION: u32 = 1;

/// Serialise a dataset to bytes.
pub fn to_bytes(ds: &Dataset) -> Vec<u8> {
    let mut e = Enc::with_capacity(ds.n() * (ds.num_features() + 4) * 4);
    e.u8(MAGIC[0]).u8(MAGIC[1]).u8(MAGIC[2]).u8(MAGIC[3]);
    e.u32(VERSION);
    e.str(&ds.name);
    e.u64(ds.n() as u64);
    e.u32(ds.num_classes as u32);
    e.u64(ds.features.rows() as u64);
    e.u64(ds.features.cols() as u64);
    e.f32s(ds.features.data());
    e.u32s(&ds.labels.iter().map(|&l| l as u32).collect::<Vec<_>>());
    e.f32s(&ds.train_mask);
    e.f32s(&ds.test_mask);
    e.u64(ds.graph.num_edges() as u64);
    for &(u, v) in ds.graph.edges() {
        e.u32(u).u32(v);
    }
    e.into_bytes()
}

/// Parse a dataset from bytes.
pub fn from_bytes(bytes: &[u8]) -> Result<Dataset> {
    let mut d = Dec::new(bytes);
    let magic = [d.u8()?, d.u8()?, d.u8()?, d.u8()?];
    if &magic != MAGIC {
        bail!("not a .cgnp file (bad magic)");
    }
    let version = d.u32()?;
    if version != VERSION {
        bail!("unsupported .cgnp version {version}");
    }
    let name = d.str()?;
    let n = d.u64()? as usize;
    let num_classes = d.u32()? as usize;
    let rows = d.u64()? as usize;
    let cols = d.u64()? as usize;
    let fdata = d.f32s()?;
    if fdata.len() != rows * cols || rows != n {
        bail!("feature shape mismatch");
    }
    let features = Matrix::from_vec(rows, cols, fdata);
    let labels: Vec<usize> = d.u32s()?.into_iter().map(|l| l as usize).collect();
    let train_mask = d.f32s()?;
    let test_mask = d.f32s()?;
    let num_edges = d.u64()? as usize;
    let mut edges = Vec::with_capacity(num_edges);
    for _ in 0..num_edges {
        edges.push((d.u32()? as usize, d.u32()? as usize));
    }
    if !d.done() {
        bail!("trailing bytes in .cgnp file");
    }
    let ds = Dataset {
        name,
        graph: Graph::from_edges(n, &edges),
        features,
        labels,
        num_classes,
        train_mask,
        test_mask,
    };
    ds.validate();
    Ok(ds)
}

/// Save to a file.
pub fn save(ds: &Dataset, path: &Path) -> Result<()> {
    std::fs::write(path, to_bytes(ds)).with_context(|| format!("writing {}", path.display()))
}

/// Load from a file.
pub fn load(path: &Path) -> Result<Dataset> {
    let bytes =
        std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    from_bytes(&bytes).with_context(|| format!("parsing {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::fixtures;

    #[test]
    fn roundtrip_preserves_everything() {
        let ds = fixtures::caveman(10, 5);
        let bytes = to_bytes(&ds);
        let back = from_bytes(&bytes).unwrap();
        assert_eq!(back.name, ds.name);
        assert_eq!(back.n(), ds.n());
        assert_eq!(back.labels, ds.labels);
        assert_eq!(back.train_mask, ds.train_mask);
        assert_eq!(back.test_mask, ds.test_mask);
        assert_eq!(back.features.data(), ds.features.data());
        assert_eq!(back.graph.edges(), ds.graph.edges());
    }

    #[test]
    fn rejects_corruption() {
        let ds = fixtures::fig1();
        let mut bytes = to_bytes(&ds);
        bytes[0] = b'X';
        assert!(from_bytes(&bytes).is_err());
        let bytes = to_bytes(&ds);
        assert!(from_bytes(&bytes[..bytes.len() - 3]).is_err());
    }

    #[test]
    fn rejects_version_mismatch() {
        let ds = fixtures::fig1();
        let mut bytes = to_bytes(&ds);
        bytes[4..8].copy_from_slice(&999u32.to_le_bytes());
        let err = from_bytes(&bytes).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");
    }

    #[test]
    fn truncation_anywhere_errors_not_panics() {
        let bytes = to_bytes(&fixtures::fig1());
        for cut in [0, 3, 4, 8, 12, bytes.len() / 3, bytes.len() - 1] {
            assert!(
                from_bytes(&bytes[..cut]).is_err(),
                "truncation at {cut} did not error"
            );
        }
        let mut trailing = bytes.clone();
        trailing.push(7);
        assert!(from_bytes(&trailing).is_err(), "trailing bytes accepted");
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("cgcn_test_format");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("fig1.cgnp");
        let ds = fixtures::fig1();
        save(&ds, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.n(), 9);
        std::fs::remove_file(path).ok();
    }
}
