//! Datasets: synthetic Amazon-like benchmarks, tiny fixtures, and a binary
//! on-disk format.
//!
//! The paper evaluates on Amazon Computers / Amazon Photo (Table 2). Those
//! are torch-geometric downloads, unavailable offline, so [`synth`]
//! generates stochastic-block-model graphs matching the paper's exact
//! statistics (node/feature/class/train/test counts, real-co-purchase-graph
//! average degrees) with class-correlated features — see DESIGN.md §2 for
//! why this preserves the behaviours the algorithm depends on.

pub mod fixtures;
pub mod format;
pub mod synth;

use crate::graph::Graph;
use crate::tensor::Matrix;

/// Resolve a dataset by name: synthetic spec, fixture, or `.cgnp` path.
/// The single entry point shared by the CLI, the trainers' setup and
/// model-snapshot workspace rebuilds.
pub fn load_by_name(name: &str, scale: f64, seed: u64) -> anyhow::Result<Dataset> {
    if let Some(spec) = synth::spec_by_name(name) {
        return Ok(synth::generate(&spec, scale, seed));
    }
    match name {
        "fig1" => Ok(fixtures::fig1()),
        "caveman" | "caveman-l3" => Ok(fixtures::caveman(24, seed)),
        path if path.ends_with(".cgnp") => format::load(std::path::Path::new(path)),
        other => anyhow::bail!(
            "unknown dataset '{other}' (try synth-computers, synth-photo, fig1, caveman, or a .cgnp path)"
        ),
    }
}

/// A node-classification dataset (full-batch, transductive — the paper's
/// setting).
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub graph: Graph,
    /// N × F node features.
    pub features: Matrix,
    /// Class index per node.
    pub labels: Vec<usize>,
    pub num_classes: usize,
    /// 1.0 for training nodes, else 0.0 (length N).
    pub train_mask: Vec<f32>,
    /// 1.0 for test nodes, else 0.0 (length N).
    pub test_mask: Vec<f32>,
}

impl Dataset {
    pub fn n(&self) -> usize {
        self.graph.n()
    }
    pub fn num_features(&self) -> usize {
        self.features.cols()
    }
    pub fn train_count(&self) -> usize {
        self.train_mask.iter().filter(|&&m| m > 0.0).count()
    }
    pub fn test_count(&self) -> usize {
        self.test_mask.iter().filter(|&&m| m > 0.0).count()
    }

    /// Table-2 style one-line summary.
    pub fn stats_row(&self) -> String {
        format!(
            "{:<18} {:>7} {:>8} {:>7} {:>7} {:>9} {:>9} {:>8.2}",
            self.name,
            self.n(),
            self.train_count(),
            self.test_count(),
            self.num_classes,
            self.num_features(),
            self.graph.num_edges(),
            self.graph.avg_degree(),
        )
    }

    /// Accuracy of predictions over a mask.
    pub fn accuracy(&self, preds: &[usize], mask: &[f32]) -> f64 {
        assert_eq!(preds.len(), self.n());
        let mut correct = 0usize;
        let mut total = 0usize;
        for i in 0..self.n() {
            if mask[i] > 0.0 {
                total += 1;
                if preds[i] == self.labels[i] {
                    correct += 1;
                }
            }
        }
        if total == 0 {
            0.0
        } else {
            correct as f64 / total as f64
        }
    }

    /// Validate internal consistency (masks disjoint, labels in range).
    pub fn validate(&self) {
        assert_eq!(self.features.rows(), self.n());
        assert_eq!(self.labels.len(), self.n());
        assert_eq!(self.train_mask.len(), self.n());
        assert_eq!(self.test_mask.len(), self.n());
        for i in 0..self.n() {
            assert!(self.labels[i] < self.num_classes, "label out of range");
            assert!(
                !(self.train_mask[i] > 0.0 && self.test_mask[i] > 0.0),
                "node {i} in both train and test masks"
            );
        }
    }
}
