//! Tiny deterministic datasets for unit / integration tests.

use super::Dataset;
use crate::graph::Graph;
use crate::tensor::Matrix;
use crate::util::rng::Rng;

/// The paper's Figure-1 graph: 9 nodes, 3 communities, bridges 0↔2.
/// Labels = community ids, features = noisy one-hot of label.
pub fn fig1() -> Dataset {
    let edges = [
        (0, 1),
        (0, 2),
        (1, 3),
        (2, 3),
        (4, 5),
        (6, 7),
        (7, 8),
        (6, 8),
        (2, 6),
        (3, 6),
    ];
    let graph = Graph::from_edges(9, &edges);
    let labels = vec![0, 0, 0, 0, 1, 1, 2, 2, 2];
    let mut rng = Rng::new(0xF161);
    let features = Matrix::from_fn(9, 4, |r, c| {
        let base = if labels[r] == c { 1.0 } else { 0.0 };
        base + (rng.gen_f32() - 0.5) * 0.1
    });
    // Train on 5 nodes, test on the rest.
    let train_mask = vec![1.0, 0.0, 1.0, 0.0, 1.0, 0.0, 1.0, 1.0, 0.0];
    let test_mask = vec![0.0, 1.0, 0.0, 1.0, 0.0, 1.0, 0.0, 0.0, 1.0];
    let ds = Dataset {
        name: "fig1".into(),
        graph,
        features,
        labels,
        num_classes: 3,
        train_mask,
        test_mask,
    };
    ds.validate();
    ds
}

/// A two-community "caveman" graph with `per` nodes per cave and a couple
/// of bridges: bigger than fig1 but still fast, good for convergence tests.
pub fn caveman(per: usize, seed: u64) -> Dataset {
    let n = per * 2;
    let mut rng = Rng::new(seed);
    let mut edges = Vec::new();
    for half in 0..2 {
        let off = half * per;
        for i in 0..per {
            for j in (i + 1)..per {
                if rng.gen_bool(0.5) {
                    edges.push((off + i, off + j));
                }
            }
        }
    }
    // Bridges.
    edges.push((0, per));
    edges.push((per / 2, per + per / 2));
    let graph = Graph::from_edges(n, &edges);
    let labels: Vec<usize> = (0..n).map(|i| i / per).collect();
    let features = Matrix::from_fn(n, 6, |r, c| {
        let base = if labels[r] == c % 2 { 1.0 } else { 0.0 };
        base + (rng.gen_f32() - 0.5) * 0.2
    });
    let train_mask: Vec<f32> = (0..n).map(|i| if i % 3 == 0 { 1.0 } else { 0.0 }).collect();
    let test_mask: Vec<f32> = (0..n).map(|i| if i % 3 == 1 { 1.0 } else { 0.0 }).collect();
    let ds = Dataset {
        name: format!("caveman-{per}"),
        graph,
        features,
        labels,
        num_classes: 2,
        train_mask,
        test_mask,
    };
    ds.validate();
    ds
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_valid() {
        let ds = fig1();
        assert_eq!(ds.n(), 9);
        assert_eq!(ds.num_classes, 3);
        assert_eq!(ds.graph.num_edges(), 10);
    }

    #[test]
    fn caveman_valid_and_bridged() {
        let ds = caveman(8, 1);
        assert_eq!(ds.n(), 16);
        assert!(ds.graph.has_edge(0, 8));
        ds.validate();
    }
}
