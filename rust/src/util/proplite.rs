//! Property-based testing micro-framework (proptest is unavailable offline).
//!
//! A property is a closure over a [`Gen`] (a seeded RNG wrapper with sized
//! generators). `check` runs it for N seeds and, on failure, retries the
//! failing seed with progressively smaller size budgets — a coarse
//! equivalent of shrinking that in practice yields near-minimal graphs /
//! matrices for debugging. Failures print the seed so a case can be
//! replayed exactly with [`check_seed`].

use crate::util::rng::Rng;

/// Generation context handed to properties: a deterministic RNG plus a
/// size budget that generators should respect.
pub struct Gen {
    pub rng: Rng,
    pub size: usize,
}

impl Gen {
    pub fn new(seed: u64, size: usize) -> Self {
        Gen {
            rng: Rng::new(seed),
            size,
        }
    }

    /// A usize in `[lo, hi]`, biased to respect the size budget.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        let span = (hi - lo).min(self.size.max(1));
        lo + self.rng.gen_range(span + 1)
    }

    /// f32 in [-scale, scale].
    pub fn f32_in(&mut self, scale: f32) -> f32 {
        (self.rng.gen_f32() * 2.0 - 1.0) * scale
    }

    /// Vector of f32s in [-scale, scale].
    pub fn vec_f32(&mut self, len: usize, scale: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_in(scale)).collect()
    }

    /// Random undirected edge list over n nodes with expected density p
    /// (no self loops, no duplicates).
    pub fn edges(&mut self, n: usize, p: f64) -> Vec<(usize, usize)> {
        let mut es = Vec::new();
        for u in 0..n {
            for v in (u + 1)..n {
                if self.rng.gen_bool(p) {
                    es.push((u, v));
                }
            }
        }
        es
    }
}

/// Outcome of a property check.
#[derive(Debug)]
pub struct Failure {
    pub seed: u64,
    pub size: usize,
    pub message: String,
}

/// Run `prop` across `cases` seeds (derived from `base_seed`). On failure,
/// attempts smaller sizes for the failing seed and panics with the smallest
/// reproduction found.
pub fn check<F>(name: &str, cases: usize, base_seed: u64, prop: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    let max_size = 24;
    for i in 0..cases {
        let seed = base_seed ^ (i as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let size = 2 + (i * max_size) / cases.max(1);
        if let Err(msg) = run_one(&prop, seed, size) {
            // "Shrink": same seed, smaller size budgets.
            let mut best = Failure {
                seed,
                size,
                message: msg,
            };
            for s in (1..size).rev() {
                if let Err(msg) = run_one(&prop, seed, s) {
                    best = Failure {
                        seed,
                        size: s,
                        message: msg,
                    };
                }
            }
            panic!(
                "property '{name}' failed (seed={}, size={}): {}\n  replay: proplite::check_seed(\"{name}\", {}, {}, prop)",
                best.seed, best.size, best.message, best.seed, best.size
            );
        }
    }
}

/// Replay a single (seed, size) case — used to debug failures.
pub fn check_seed<F>(name: &str, seed: u64, size: usize, prop: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    if let Err(msg) = run_one(&prop, seed, size) {
        panic!("property '{name}' failed on replay (seed={seed}, size={size}): {msg}");
    }
}

fn run_one<F>(prop: &F, seed: u64, size: usize) -> Result<(), String>
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    let mut g = Gen::new(seed, size);
    prop(&mut g)
}

/// Assert helper producing `Result<(), String>` for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("reverse-twice", 50, 42, |g| {
            let len = g.usize_in(0, 30);
            let v = g.vec_f32(len, 10.0);
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            prop_assert!(v == w, "reverse twice changed the vector");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_seed() {
        check("always-fails", 5, 1, |_g| Err("nope".to_string()));
    }

    #[test]
    fn edges_are_simple() {
        check("gen-edges-simple", 30, 7, |g| {
            let n = g.usize_in(2, 20);
            let es = g.edges(n, 0.3);
            let mut seen = std::collections::HashSet::new();
            for &(u, v) in &es {
                prop_assert!(u < v, "edge not canonical: ({u},{v})");
                prop_assert!(v < n, "edge endpoint out of range");
                prop_assert!(seen.insert((u, v)), "duplicate edge ({u},{v})");
            }
            Ok(())
        });
    }
}
