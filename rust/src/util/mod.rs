//! In-house utility substrates.
//!
//! The offline crate registry only contains the `xla` dependency closure,
//! so the usual ecosystem crates (serde, clap, rand, proptest, criterion)
//! are unavailable; each submodule here is a small, tested replacement for
//! the slice of functionality this project needs.

pub mod cli;
pub mod json;
pub mod logger;
pub mod pool;
pub mod proplite;
pub mod rng;
pub mod stats;
pub mod wire;
