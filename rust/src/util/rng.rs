//! Deterministic pseudo-random number generation.
//!
//! Implements xoshiro256** (Blackman & Vigna) seeded via SplitMix64 — the
//! same construction the `rand` ecosystem uses for reproducible simulation
//! seeds. All stochastic behaviour in the crate (dataset synthesis,
//! partitioner tie-breaking, property-test generation, weight init) flows
//! through this type so experiments are bit-reproducible given a seed.

/// xoshiro256** PRNG. Deterministic, fast, 2^256-1 period.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

/// SplitMix64 step used for seeding (and usable standalone for hashing).
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for per-agent / per-worker RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        let base = self.next_u64();
        Rng::new(base ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Snapshot the generator state (training checkpoints). Restoring via
    /// [`Rng::from_state`] continues the exact same output stream, which
    /// is what makes interrupted-then-resumed stochastic training bitwise
    /// identical to an uninterrupted run.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a [`Rng::state`] snapshot.
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift rejection method.
    #[inline]
    pub fn gen_range(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= lo.wrapping_neg() % n {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn gen_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Standard normal via Box–Muller (cached second value is not kept —
    /// simplicity over speed; this is not on the training hot path).
    pub fn gen_normal(&mut self) -> f64 {
        loop {
            let u1 = self.gen_f64();
            if u1 > 1e-300 {
                let u2 = self.gen_f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (floyd's algorithm for
    /// small k, shuffle for large k).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        if k * 4 >= n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            all.sort_unstable();
            all
        } else {
            let mut chosen = std::collections::HashSet::with_capacity(k);
            // Floyd's: for j in n-k..n, pick t in [0, j]; insert t or j.
            for j in (n - k)..n {
                let t = self.gen_range(j + 1);
                if !chosen.insert(t) {
                    chosen.insert(j);
                }
            }
            let mut v: Vec<usize> = chosen.into_iter().collect();
            v.sort_unstable();
            v
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn gen_range_in_bounds_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.gen_range(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gen_f64_unit_interval() {
        let mut r = Rng::new(9);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.gen_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gen_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.08, "var={var}");
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut r = Rng::new(3);
        for &(n, k) in &[(10usize, 3usize), (100, 90), (1000, 10), (5, 5)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            for w in s.windows(2) {
                assert!(w[0] < w[1]);
            }
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn state_roundtrip_continues_stream() {
        let mut a = Rng::new(99);
        for _ in 0..10 {
            a.next_u64();
        }
        let snap = a.state();
        let expect: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let mut b = Rng::from_state(snap);
        let got: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        assert_eq!(expect, got);
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(123);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
