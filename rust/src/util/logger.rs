//! Minimal `log` facade backend (env_logger is unavailable offline).
//!
//! Level is controlled by `CGCN_LOG` (error|warn|info|debug|trace|off,
//! default info; `0`/`false`/`none` also disable). Output goes to stderr
//! with elapsed-time + thread-name prefixes so training logs double as
//! coarse timing traces and pool-worker / transport lines are
//! attributable to the thread that emitted them.

use std::io::Write;
use std::sync::OnceLock;
use std::time::Instant;

struct Logger {
    start: Instant,
    level: log::LevelFilter,
}

impl log::Log for Logger {
    fn enabled(&self, metadata: &log::Metadata) -> bool {
        metadata.level() <= self.level
    }

    fn log(&self, record: &log::Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed().as_secs_f64();
        let cur = std::thread::current();
        let thread = cur.name().unwrap_or("?");
        let mut err = std::io::stderr().lock();
        let _ = writeln!(
            err,
            "[{t:9.3}s {:5} {thread} {}] {}",
            record.level(),
            record.target().split("::").last().unwrap_or(""),
            record.args()
        );
    }

    fn flush(&self) {}
}

static LOGGER: OnceLock<Logger> = OnceLock::new();

/// Parse a `CGCN_LOG` value into a level filter. Unknown values (and an
/// unset variable, passed as `None`) fall back to `Info`.
pub fn parse_level(v: Option<&str>) -> log::LevelFilter {
    match v {
        Some("error") => log::LevelFilter::Error,
        Some("warn") => log::LevelFilter::Warn,
        Some("debug") => log::LevelFilter::Debug,
        Some("trace") => log::LevelFilter::Trace,
        Some("off") | Some("0") | Some("false") | Some("none") => log::LevelFilter::Off,
        _ => log::LevelFilter::Info,
    }
}

/// Install the logger (idempotent). Call early in main / test setup.
pub fn init() {
    let level = parse_level(std::env::var("CGCN_LOG").ok().as_deref());
    let logger = LOGGER.get_or_init(|| Logger {
        start: Instant::now(),
        level,
    });
    // set_logger fails if already set (e.g. repeated test init) — fine.
    let _ = log::set_logger(logger);
    log::set_max_level(logger.level);
}

#[cfg(test)]
mod tests {
    use super::parse_level;
    use log::LevelFilter;

    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logger smoke test");
    }

    #[test]
    fn level_parsing() {
        assert_eq!(parse_level(Some("error")), LevelFilter::Error);
        assert_eq!(parse_level(Some("warn")), LevelFilter::Warn);
        assert_eq!(parse_level(Some("debug")), LevelFilter::Debug);
        assert_eq!(parse_level(Some("trace")), LevelFilter::Trace);
        for off in ["off", "0", "false", "none"] {
            assert_eq!(parse_level(Some(off)), LevelFilter::Off, "{off}");
        }
        // Default and unknown values → info.
        assert_eq!(parse_level(None), LevelFilter::Info);
        assert_eq!(parse_level(Some("info")), LevelFilter::Info);
        assert_eq!(parse_level(Some("verbose")), LevelFilter::Info);
    }
}
