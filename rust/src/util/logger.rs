//! Minimal `log` facade backend (env_logger is unavailable offline).
//!
//! Level is controlled by `CGCN_LOG` (error|warn|info|debug|trace, default
//! info). Output goes to stderr with elapsed-time prefixes so training logs
//! double as coarse timing traces.

use std::io::Write;
use std::sync::OnceLock;
use std::time::Instant;

struct Logger {
    start: Instant,
    level: log::LevelFilter,
}

impl log::Log for Logger {
    fn enabled(&self, metadata: &log::Metadata) -> bool {
        metadata.level() <= self.level
    }

    fn log(&self, record: &log::Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed().as_secs_f64();
        let mut err = std::io::stderr().lock();
        let _ = writeln!(
            err,
            "[{t:9.3}s {:5} {}] {}",
            record.level(),
            record.target().split("::").last().unwrap_or(""),
            record.args()
        );
    }

    fn flush(&self) {}
}

static LOGGER: OnceLock<Logger> = OnceLock::new();

/// Install the logger (idempotent). Call early in main / test setup.
pub fn init() {
    let level = match std::env::var("CGCN_LOG").as_deref() {
        Ok("error") => log::LevelFilter::Error,
        Ok("warn") => log::LevelFilter::Warn,
        Ok("debug") => log::LevelFilter::Debug,
        Ok("trace") => log::LevelFilter::Trace,
        Ok("off") => log::LevelFilter::Off,
        _ => log::LevelFilter::Info,
    };
    let logger = LOGGER.get_or_init(|| Logger {
        start: Instant::now(),
        level,
    });
    // set_logger fails if already set (e.g. repeated test init) — fine.
    let _ = log::set_logger(logger);
    log::set_max_level(logger.level);
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logger smoke test");
    }
}
