//! Minimal JSON parser / serializer.
//!
//! serde is not resolvable offline, so configs, the artifact manifest and
//! metric dumps use this small self-contained implementation. It supports
//! the full JSON grammar (objects, arrays, strings with escapes, numbers,
//! bools, null) and pretty / compact serialisation.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept sorted (BTreeMap) so serialisation is
/// deterministic — required for artifact manifests that are diffed in tests.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset for debuggability.
#[derive(Debug)]
pub struct ParseError {
    pub offset: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    // ---- constructors ----------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }
    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    // ---- accessors --------------------------------------------------------
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    /// Object field lookup; returns `Json::Null` for missing keys so lookup
    /// chains (`j.get("a").get("b")`) don't need Option plumbing.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(m) => m.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    // ---- parsing ----------------------------------------------------------
    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- serialisation ----------------------------------------------------
    /// Compact single-line serialisation.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialisation with 2-space indent.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(map) => {
                if map.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            offset: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pairs.
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("expected low surrogate"));
                            }
                            let lo = self.hex4()?;
                            let combined =
                                0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(combined)
                        } else {
                            char::from_u32(cp)
                        };
                        s.push(c.ok_or_else(|| self.err("invalid codepoint"))?);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(b) if b < 0x20 => return Err(self.err("control char in string")),
                Some(b) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if b < 0x80 {
                        s.push(b as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(b);
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (b as char)
                .to_digit(16)
                .ok_or_else(|| self.err("invalid hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    if first >= 0xF0 {
        4
    } else if first >= 0xE0 {
        3
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, -2.5e3], "c": {"nested": "x\ny"}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").as_usize(), Some(1));
        assert_eq!(v.get("b").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("b").as_arr().unwrap()[2].as_f64(), Some(-2500.0));
        assert_eq!(v.get("c").get("nested").as_str(), Some("x\ny"));
        // Round-trip through compact serialisation.
        let again = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn roundtrip_pretty() {
        let v = Json::obj(vec![
            ("name", Json::str("cgcn")),
            ("dims", Json::arr(vec![Json::num(128.0), Json::num(767.0)])),
            ("flag", Json::Bool(false)),
        ]);
        let again = Json::parse(&v.to_pretty()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""éA δοκιμή 😀""#).unwrap();
        assert_eq!(v.as_str(), Some("éA δοκιμή 😀"));
        let again = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn missing_key_is_null() {
        let v = Json::parse("{}").unwrap();
        assert_eq!(*v.get("nope").get("deeper"), Json::Null);
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::num(3.0).to_string(), "3");
        assert_eq!(Json::num(3.5).to_string(), "3.5");
    }
}
