//! Tiny declarative CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments,
//! subcommands (first positional), typed getters with defaults, and an
//! auto-generated `--help`.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// One declared option.
#[derive(Clone, Debug)]
struct OptSpec {
    name: String,
    help: String,
    default: Option<String>,
    is_flag: bool,
}

/// A declarative argument parser.
pub struct ArgSpec {
    program: String,
    about: String,
    opts: Vec<OptSpec>,
}

/// Parsed arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    positional: Vec<String>,
}

#[derive(Debug)]
pub enum CliError {
    Unknown(String, String),
    MissingValue(String),
    Invalid {
        key: String,
        value: String,
        why: String,
    },
    Help(String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::Unknown(key, usage) => write!(f, "unknown option --{key}\n\n{usage}"),
            CliError::MissingValue(key) => write!(f, "option --{key} expects a value"),
            CliError::Invalid { key, value, why } => {
                write!(f, "invalid value for --{key}: {value:?} ({why})")
            }
            CliError::Help(h) => write!(f, "{h}"),
        }
    }
}

impl std::error::Error for CliError {}

impl ArgSpec {
    pub fn new(program: &str, about: &str) -> Self {
        ArgSpec {
            program: program.to_string(),
            about: about.to_string(),
            opts: Vec::new(),
        }
    }

    /// Declare `--name <value>` with an optional default.
    pub fn opt(mut self, name: &str, default: Option<&str>, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: default.map(|s| s.to_string()),
            is_flag: false,
        });
        self
    }

    /// Declare a boolean `--name` flag.
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_flag: true,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}", self.program, self.about);
        let _ = writeln!(s, "\nOptions:");
        for o in &self.opts {
            let head = if o.is_flag {
                format!("  --{}", o.name)
            } else {
                format!("  --{} <v>", o.name)
            };
            let default = o
                .default
                .as_ref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            let _ = writeln!(s, "{head:<28} {}{default}", o.help);
        }
        s
    }

    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(&self, argv: I) -> Result<Args, CliError> {
        let mut args = Args::default();
        for o in &self.opts {
            if let Some(d) = &o.default {
                args.values.insert(o.name.clone(), d.clone());
            }
            if o.is_flag {
                args.flags.insert(o.name.clone(), false);
            }
        }
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if tok == "--help" || tok == "-h" {
                return Err(CliError::Help(self.usage()));
            }
            if let Some(stripped) = tok.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| CliError::Unknown(key.clone(), self.usage()))?;
                if spec.is_flag {
                    let v = match inline_val.as_deref() {
                        None => true,
                        Some("true") => true,
                        Some("false") => false,
                        Some(other) => {
                            return Err(CliError::Invalid {
                                key,
                                value: other.to_string(),
                                why: "flags take true/false".into(),
                            })
                        }
                    };
                    args.flags.insert(key, v);
                } else {
                    let v = match inline_val {
                        Some(v) => v,
                        None => it.next().ok_or(CliError::MissingValue(key.clone()))?,
                    };
                    args.values.insert(key, v);
                }
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    /// Parse `std::env::args()` (skipping argv[0]); print help & exit on -h.
    pub fn parse_env(&self) -> Args {
        match self.parse(std::env::args().skip(1)) {
            Ok(a) => a,
            Err(CliError::Help(h)) => {
                println!("{h}");
                std::process::exit(0);
            }
            Err(e) => {
                eprintln!("error: {e}");
                std::process::exit(2);
            }
        }
    }
}

impl Args {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }
    pub fn get_str(&self, key: &str) -> String {
        self.values
            .get(key)
            .cloned()
            .unwrap_or_else(|| panic!("option --{key} not declared / has no default"))
    }
    pub fn get_usize(&self, key: &str) -> usize {
        self.typed(key)
    }
    pub fn get_u64(&self, key: &str) -> u64 {
        self.typed(key)
    }
    pub fn get_f32(&self, key: &str) -> f32 {
        self.typed(key)
    }
    pub fn get_f64(&self, key: &str) -> f64 {
        self.typed(key)
    }
    pub fn get_flag(&self, key: &str) -> bool {
        *self
            .flags
            .get(key)
            .unwrap_or_else(|| panic!("flag --{key} not declared"))
    }
    /// Parse a comma-separated `usize` list (`--nodes 0,5,17`); empty
    /// value → empty list. Exits with a CLI error on a malformed entry,
    /// like the other typed getters.
    pub fn get_list_usize(&self, key: &str) -> Vec<usize> {
        let raw = self.get_str(key);
        raw.split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(|s| {
                s.parse::<usize>().unwrap_or_else(|e| {
                    eprintln!("error: invalid value for --{key}: {raw:?} ({e})");
                    std::process::exit(2);
                })
            })
            .collect()
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
    /// First positional argument — conventionally the subcommand.
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    fn typed<T: std::str::FromStr>(&self, key: &str) -> T
    where
        T::Err: std::fmt::Display,
    {
        let raw = self.get_str(key);
        raw.parse::<T>().unwrap_or_else(|e| {
            eprintln!("error: invalid value for --{key}: {raw:?} ({e})");
            std::process::exit(2);
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ArgSpec {
        ArgSpec::new("t", "test")
            .opt("epochs", Some("50"), "number of epochs")
            .opt("dataset", Some("synth-computers"), "dataset name")
            .opt("rho", Some("0.001"), "ADMM rho")
            .flag("verbose", "chatty output")
    }

    fn parse(toks: &[&str]) -> Args {
        spec()
            .parse(toks.iter().map(|s| s.to_string()))
            .expect("parse")
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]);
        assert_eq!(a.get_usize("epochs"), 50);
        assert_eq!(a.get_str("dataset"), "synth-computers");
        assert!(!a.get_flag("verbose"));
    }

    #[test]
    fn values_and_flags() {
        let a = parse(&["train", "--epochs", "10", "--rho=0.1", "--verbose"]);
        assert_eq!(a.subcommand(), Some("train"));
        assert_eq!(a.get_usize("epochs"), 10);
        assert!((a.get_f32("rho") - 0.1).abs() < 1e-6);
        assert!(a.get_flag("verbose"));
    }

    #[test]
    fn usize_lists_parse() {
        let spec = ArgSpec::new("t", "test").opt("nodes", Some(""), "node list");
        let a = spec
            .parse(vec!["--nodes".to_string(), "0, 5,17".to_string()])
            .unwrap();
        assert_eq!(a.get_list_usize("nodes"), vec![0, 5, 17]);
        let empty = spec.parse(Vec::new()).unwrap();
        assert!(empty.get_list_usize("nodes").is_empty());
    }

    #[test]
    fn unknown_option_errors() {
        let r = spec().parse(vec!["--nope".to_string()]);
        assert!(matches!(r, Err(CliError::Unknown(..))));
    }

    #[test]
    fn missing_value_errors() {
        let r = spec().parse(vec!["--epochs".to_string()]);
        assert!(matches!(r, Err(CliError::MissingValue(..))));
    }

    #[test]
    fn help_is_generated() {
        let r = spec().parse(vec!["--help".to_string()]);
        match r {
            Err(CliError::Help(h)) => {
                assert!(h.contains("--epochs"));
                assert!(h.contains("default: 50"));
            }
            other => panic!("expected help, got {other:?}"),
        }
    }
}
