//! In-house worker pools + data-parallel dispatch (rayon is not available
//! offline).
//!
//! The primary engine is the shared work-stealing [`Runtime`]
//! (`--runtime shared`, DESIGN.md §11): one pool of workers with
//! per-worker job deques plus a global injector, executing *both* coarse
//! `'static` tasks (community-agent phases, serve connection handlers) and
//! fork-join kernel chunks, so agent-level and kernel-level parallelism
//! trade threads dynamically instead of owning separate pools. Blocked
//! fork-join callers steal other jobs' chunks instead of parking.
//!
//! The legacy primitives survive as the `--runtime dual` escape hatch and
//! A/B references:
//!
//! - [`Pool`] — a persistent thread pool for `'static` jobs (the dual-mode
//!   agent executor). Jobs are panic-isolated at the job boundary.
//! - [`FjPool`] — a persistent single-job fork-join pool (the dual-mode
//!   kernel executor): workers park on a condvar between ops, a
//!   `fork_lock` serialises concurrent callers, and nested forks run
//!   inline.
//! - [`scoped_map`] / [`parallel_row_chunks`] — spawn-per-op fork-join on
//!   `std::thread::scope` (`--op-spawn`, `NativeBackend::with_spawn_threads`)
//!   and the fallback when no pool is available.
//!
//! Determinism: every helper partitions work by index and every output
//! element is written by exactly one thread running the same scalar loop
//! the serial path runs, so parallel results are bitwise identical to
//! serial ones at any thread count — stealing only moves *which worker*
//! runs a chunk, never what the chunk computes. Reductions are always
//! folded on the caller's thread in index order.

use std::cell::Cell;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Resolve a user-facing thread count: 0 means "all available cores",
/// with a floor of 1.
pub fn resolve_threads(threads: usize) -> usize {
    if threads == 0 {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    } else {
        threads
    }
}

/// The single thread budget of the shared [`Runtime`]
/// (`--runtime shared`): the max over the *nonzero* `--threads` /
/// `--op-threads` knobs, or all cores when both are 0. A `0` defers to
/// the other knob rather than meaning "all cores", so an explicit cap on
/// either level caps the whole process — unlike dual mode, where the two
/// pools multiply (agents × op-threads) and can oversubscribe.
pub fn shared_thread_budget(threads: usize, op_threads: usize) -> usize {
    match (threads, op_threads) {
        (0, 0) => resolve_threads(0),
        (t, 0) => t,
        (0, k) => k,
        (t, k) => t.max(k),
    }
}

/// A small persistent worker pool for `'static` jobs.
pub struct Pool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl Pool {
    /// Spawn a pool with `threads` workers (at least 1).
    pub fn new(threads: usize) -> Pool {
        let threads = threads.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("cgcn-pool-{i}"))
                    .spawn(move || loop {
                        // Take the lock only to dequeue; run unlocked.
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            // Catch panics at the job boundary so a bad job
                            // cannot silently shrink the pool: the worker
                            // survives and keeps serving the queue. The
                            // submitter observes the failure through its
                            // own result channel going dead (the agent
                            // executor already handles that case).
                            Ok(job) => {
                                if catch_unwind(AssertUnwindSafe(job)).is_err() {
                                    log::warn!("pool job panicked; worker continues");
                                }
                            }
                            Err(_) => break, // all senders dropped
                        }
                    })
                    .expect("spawning pool worker")
            })
            .collect();
        Pool {
            tx: Some(tx),
            workers,
        }
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Enqueue a job. Panicking jobs are caught at the job boundary; the
    /// worker is reused for subsequent jobs.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        crate::obs_counter!("pool.jobs").inc();
        self.tx
            .as_ref()
            .expect("pool already shut down")
            .send(Box::new(job))
            .expect("pool worker channel closed");
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

// ---------------------------------------------------------------------------
// FjPool — persistent fork-join pool for borrowed-data kernels
// ---------------------------------------------------------------------------

thread_local! {
    /// True while this thread is executing a fork-join chunk (worker or
    /// participating caller). A nested [`FjPool::run`] from inside a chunk
    /// runs its chunks inline instead of re-forking — this makes nesting
    /// (e.g. a pooled `fj_map` item calling pooled backend kernels)
    /// deadlock-free by construction.
    static IN_FJ_CHUNK: Cell<bool> = const { Cell::new(false) };
}

/// Type-erased pointer to the current job closure. The pointee lives on
/// the stack of the thread blocked in [`FjPool::run`]; see the safety
/// argument there.
struct JobPtr(*const (dyn Fn(usize) + Sync));
// SAFETY: the pointer is only dereferenced by workers between job
// publication and the last `done` increment, a window during which the
// caller of `run` is pinned (participating or waiting on `done_cv`), so
// the pointee outlives every dereference. Sync: the pointee type is
// `dyn Fn(usize) + Sync`, so concurrent calls from several threads are
// part of its contract.
unsafe impl Send for JobPtr {}
unsafe impl Sync for JobPtr {}

#[derive(Default)]
struct FjState {
    job: Option<JobPtr>,
    n_chunks: usize,
    next_chunk: usize,
    done: usize,
    panic_payload: Option<Box<dyn std::any::Any + Send>>,
    shutdown: bool,
}

struct FjShared {
    state: Mutex<FjState>,
    /// Workers park here between jobs.
    work_cv: Condvar,
    /// The caller parks here until `done == n_chunks`.
    done_cv: Condvar,
}

/// A persistent fork-join pool: `threads − 1` parked workers plus the
/// calling thread, woken per [`FjPool::run`] call through a condvar.
///
/// Compared to `thread::scope` (spawn + join per op) the steady-state
/// dispatch cost is one mutex round-trip and a wakeup, which is what makes
/// op-level parallelism profitable at the small grains the ADMM inner
/// loops actually run at (see `benches/kernel_bench.rs`).
///
/// Panic isolation: each chunk runs under `catch_unwind` on both workers
/// and the caller; the first payload is re-raised on the caller *after*
/// every chunk has finished, so workers never dangle into a dead caller
/// frame and the pool stays usable after a panicking job.
pub struct FjPool {
    shared: Arc<FjShared>,
    /// Serialises concurrent `run` callers (one fork-join job at a time).
    fork_lock: Mutex<()>,
    threads: usize,
    workers: Vec<thread::JoinHandle<()>>,
}

impl FjPool {
    /// Pool sized for `threads` total participants: the caller plus
    /// `threads − 1` spawned workers (so `FjPool::new(1)` spawns nothing
    /// and every `run` is a plain serial loop).
    pub fn new(threads: usize) -> FjPool {
        let threads = threads.max(1);
        let shared = Arc::new(FjShared {
            state: Mutex::new(FjState::default()),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let workers = (1..threads)
            .map(|i| {
                let shared = Arc::clone(&shared);
                thread::Builder::new()
                    .name(format!("cgcn-fj-{i}"))
                    .spawn(move || {
                        IN_FJ_CHUNK.with(|f| f.set(true));
                        worker_loop(&shared);
                    })
                    .expect("spawning fj worker")
            })
            .collect();
        FjPool {
            shared,
            fork_lock: Mutex::new(()),
            threads,
            workers,
        }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(chunk)` for `chunk in 0..n_chunks`, distributing chunks over
    /// the pool (the caller participates). Blocks until every chunk has
    /// finished; re-raises the first chunk panic afterwards. Calls nested
    /// inside a running chunk execute inline (serially) instead of
    /// deadlocking on the pool.
    pub fn run(&self, n_chunks: usize, f: &(dyn Fn(usize) + Sync)) {
        if n_chunks == 0 {
            return;
        }
        let nested = IN_FJ_CHUNK.with(|c| c.get());
        if nested || n_chunks == 1 || self.threads <= 1 {
            for c in 0..n_chunks {
                f(c);
            }
            return;
        }
        crate::obs_counter!("pool.fj.runs").inc();
        let _forking = self.fork_lock.lock().unwrap();
        // SAFETY: `f` outlives this call; the raw pointer is only
        // dereferenced while some chunk index is still unclaimed or
        // running, and this frame does not return (or unwind — the
        // caller's own chunks run under catch_unwind) until
        // `done == n_chunks`.
        let job = JobPtr(f as *const (dyn Fn(usize) + Sync));
        {
            let mut st = self.shared.state.lock().unwrap();
            st.job = Some(job);
            st.n_chunks = n_chunks;
            st.next_chunk = 0;
            st.done = 0;
            st.panic_payload = None;
        }
        self.shared.work_cv.notify_all();

        // Participate: claim chunks like any worker.
        IN_FJ_CHUNK.with(|c| c.set(true));
        loop {
            let chunk = {
                let mut st = self.shared.state.lock().unwrap();
                if st.next_chunk >= st.n_chunks {
                    break;
                }
                let c = st.next_chunk;
                st.next_chunk += 1;
                c
            };
            let busy0 = obs_now();
            let result = catch_unwind(AssertUnwindSafe(|| f(chunk)));
            record_chunk(busy0);
            finish_chunk(&self.shared, result);
        }
        IN_FJ_CHUNK.with(|c| c.set(false));

        // Join: wait for workers to drain the remaining chunks.
        let payload = {
            let mut st = self.shared.state.lock().unwrap();
            while st.done < st.n_chunks {
                st = self.shared.done_cv.wait(st).unwrap();
            }
            st.job = None;
            st.panic_payload.take()
        };
        drop(_forking);
        if let Some(p) = payload {
            std::panic::resume_unwind(p);
        }
    }
}

fn worker_loop(shared: &FjShared) {
    loop {
        let idle0 = obs_now();
        let (fptr, chunk) = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if let Some(job) = &st.job {
                    if st.next_chunk < st.n_chunks {
                        let c = st.next_chunk;
                        st.next_chunk += 1;
                        break (job.0, c);
                    }
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        if let Some(t) = idle0 {
            crate::obs_hist!("pool.worker.idle.secs", crate::obs::TIME_BUCKETS)
                .record(t.elapsed().as_secs_f64());
        }
        let busy0 = obs_now();
        // SAFETY: see JobPtr — the caller is pinned until `done` reaches
        // `n_chunks`, which only happens after this dereference completes.
        let result = catch_unwind(AssertUnwindSafe(|| unsafe { (*fptr)(chunk) }));
        record_chunk(busy0);
        finish_chunk(shared, result);
    }
}

/// `Instant::now()` only when telemetry is on — the fork-join loops run at
/// microsecond chunk grains, so even a clock read must be behind the gate.
#[inline]
fn obs_now() -> Option<std::time::Instant> {
    crate::obs::enabled().then(std::time::Instant::now)
}

/// Per-chunk telemetry: chunk count + busy-time histogram (counts and
/// seconds per worker shard; the scrape sums them).
#[inline]
fn record_chunk(busy0: Option<std::time::Instant>) {
    if let Some(t) = busy0 {
        crate::obs_hist!("pool.worker.busy.secs", crate::obs::TIME_BUCKETS)
            .record(t.elapsed().as_secs_f64());
        crate::obs_counter!("pool.chunks").inc();
    }
}

/// Record a finished chunk (and its panic payload, if any); wake the
/// caller when it was the last one.
fn finish_chunk(shared: &FjShared, result: Result<(), Box<dyn std::any::Any + Send>>) {
    let mut st = shared.state.lock().unwrap();
    if let Err(p) = result {
        if st.panic_payload.is_none() {
            st.panic_payload = Some(p);
        }
    }
    st.done += 1;
    if st.done == st.n_chunks {
        shared.done_cv.notify_all();
    }
}

impl Drop for FjPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Runtime — shared work-stealing runtime (agents + kernels + serving)
// ---------------------------------------------------------------------------

/// Distinguishes runtime instances so a worker publishing a nested
/// fork-join job can tell "my runtime's deque" from "some other runtime"
/// (tests routinely build several runtimes in one process).
static RUNTIME_IDS: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// `(runtime id, worker index)` on [`Runtime`] worker threads; `None`
    /// on external threads (trainer main thread, transport threads, …).
    static RT_WORKER: Cell<Option<(usize, usize)>> = const { Cell::new(None) };
}

/// One in-flight fork-join job on the [`Runtime`].
///
/// Chunk *claims* happen under the scheduler lock (same cost profile as
/// [`FjPool`], which also takes a mutex per claim); chunk *completion*
/// lands under the job's own `fin` lock so finishing work never contends
/// with scheduling. Lock order is always sched → fin, never the reverse.
struct RtJob {
    /// Borrowed chunk closure — valid until the publishing [`Runtime::run`]
    /// frame observes `done == n_chunks` (see the [`JobPtr`] safety note).
    job: JobPtr,
    n_chunks: usize,
    /// Where the job was published: `Some(worker)` = that worker's deque,
    /// `None` = the external-jobs queue. Immutable after publication; used
    /// to eagerly remove the job from its deque at the exhausting claim.
    home: Option<usize>,
    /// Next unclaimed chunk. Mutated only under the scheduler lock — the
    /// atomic is for interior mutability through the `Arc`, not for
    /// lock-free claiming.
    next: AtomicUsize,
    fin: Mutex<RtJobFin>,
    /// The publisher parks here until `done == n_chunks` (only after it
    /// has run out of work to steal).
    done_cv: Condvar,
}

#[derive(Default)]
struct RtJobFin {
    done: usize,
    panic: Option<Box<dyn std::any::Any + Send>>,
}

/// Scheduler state: all queues live behind one mutex. Critical sections
/// are O(live jobs) pointer shuffles — queue residency is tiny (nesting
/// depth per worker, plus one entry per concurrent external caller) and
/// chunk granularity is already bounded below by the backend's flop
/// grains, so a single lock is not the bottleneck and buys airtight
/// condvar wakeups (work is published under the same lock the sleep
/// predicate is evaluated under).
struct Sched {
    /// Global FIFO of coarse `'static` tasks (agent phases, serve
    /// connection handlers). Only idle workers take from here — a thread
    /// blocked inside [`Runtime::run`] never steals an injector task,
    /// because a coarse task may block arbitrarily long (e.g. a connection
    /// handler waiting on a socket) and would wedge the fork it owes.
    injector: VecDeque<Job>,
    /// Fork-join jobs published by worker `i`. Chase-lev discipline under
    /// the lock: the owner works the back (newest job — the fork it is
    /// currently blocked in), thieves take the front (oldest job).
    worker_jobs: Vec<VecDeque<Arc<RtJob>>>,
    /// Fork-join jobs published by non-worker threads, oldest first.
    external_jobs: VecDeque<Arc<RtJob>>,
    shutdown: bool,
}

struct RtShared {
    id: usize,
    threads: usize,
    sched: Mutex<Sched>,
    /// Idle workers park here; notified on every publication.
    work_cv: Condvar,
}

/// A work unit a worker picked up: a fork-join chunk or a coarse task.
enum Unit {
    Chunk {
        job: Arc<RtJob>,
        chunk: usize,
        stolen: bool,
    },
    Task(Job),
}

/// The shared work-stealing runtime (`--runtime shared`, DESIGN.md §11):
/// one thread budget serving community-agent phase tasks, fork-join
/// kernel chunks, and serve connection handlers.
///
/// Differences from the [`Pool`]+[`FjPool`] dual setup it replaces:
///
/// - **One budget.** `Runtime::new(b)` spawns `b − 1` workers; fork-join
///   callers participate, so `b` threads compute during any fork. Agent
///   tasks and kernel chunks draw from the same workers instead of two
///   pools that blindly oversubscribe (or strand) cores.
/// - **Concurrent + nested forks.** There is no `fork_lock` and no nested
///   inline guard: every fork publishes a job deque entry and any worker
///   may claim its chunks. C agents forking kernels concurrently all make
///   progress on whatever threads are free.
/// - **Blocked forks steal.** A caller whose chunks are all claimed steals
///   *other jobs' chunks* (never injector tasks) until its own job
///   finishes — lending its thread instead of parking.
///
/// Deadlock freedom: a thread parks only when every chunk of its awaited
/// job is claimed and nothing is stealable; each claimed chunk is being
/// executed by exactly one thread. Take the deepest-nested job awaited by
/// any parked thread — the thread executing its unfinished chunk would
/// have to be parked on a strictly deeper job, contradiction; so some
/// thread always runs, and the done-counts strictly increase.
///
/// Determinism: identical to the [`FjPool`] argument — stealing moves
/// *which thread* runs a chunk, never what the chunk computes or the
/// order any output element is accumulated in, so results stay bitwise
/// equal to serial at any budget.
///
/// Panic semantics match [`FjPool`] ([`Runtime::run`] re-raises the first
/// chunk panic after all chunks finish) and [`Pool`] ([`Runtime::execute`]
/// tasks are caught at the task boundary; the worker survives).
pub struct Runtime {
    shared: Arc<RtShared>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl Runtime {
    /// Runtime with a total thread budget of `threads` (at least 1):
    /// `threads − 1` spawned workers plus participating fork-join callers.
    /// A budget of 1 still spawns one worker so [`Runtime::execute`] tasks
    /// have somewhere to run (forks run inline on the caller).
    pub fn new(threads: usize) -> Runtime {
        let threads = threads.max(1);
        let n_workers = (threads - 1).max(1);
        let shared = Arc::new(RtShared {
            id: RUNTIME_IDS.fetch_add(1, Ordering::Relaxed),
            threads,
            sched: Mutex::new(Sched {
                injector: VecDeque::new(),
                worker_jobs: (0..n_workers).map(|_| VecDeque::new()).collect(),
                external_jobs: VecDeque::new(),
                shutdown: false,
            }),
            work_cv: Condvar::new(),
        });
        let workers = (0..n_workers)
            .map(|i| {
                let shared = Arc::clone(&shared);
                thread::Builder::new()
                    .name(format!("cgcn-rt-{i}"))
                    .spawn(move || {
                        RT_WORKER.with(|w| w.set(Some((shared.id, i))));
                        rt_worker_loop(&shared, i);
                    })
                    .expect("spawning runtime worker")
            })
            .collect();
        Runtime { shared, workers }
    }

    /// The total thread budget (spawned workers + the participating
    /// caller), i.e. how many threads compute during a fork-join.
    pub fn threads(&self) -> usize {
        self.shared.threads
    }

    /// Enqueue a coarse `'static` task on the global injector. Panicking
    /// tasks are caught at the task boundary; the worker survives (the
    /// submitter observes failure through its own result channel dying,
    /// exactly as with [`Pool::execute`]).
    pub fn execute(&self, task: impl FnOnce() + Send + 'static) {
        crate::obs_counter!("runtime.tasks").inc();
        let depth = {
            let mut s = self.shared.sched.lock().unwrap();
            assert!(!s.shutdown, "runtime already shut down");
            s.injector.push_back(Box::new(task));
            s.injector.len()
        };
        crate::obs_gauge!("runtime.injector.depth").set(depth as i64);
        self.shared.work_cv.notify_all();
    }

    /// Run `f(chunk)` for `chunk in 0..n_chunks`, distributing chunks over
    /// the runtime (the caller participates, then steals while blocked).
    /// Blocks until every chunk has finished; re-raises the first chunk
    /// panic afterwards. Drop-in compatible with [`FjPool::run`], but
    /// concurrent callers proceed in parallel and nested calls fork for
    /// real instead of inlining.
    pub fn run(&self, n_chunks: usize, f: &(dyn Fn(usize) + Sync)) {
        if n_chunks == 0 {
            return;
        }
        if n_chunks == 1 || self.shared.threads <= 1 {
            for c in 0..n_chunks {
                f(c);
            }
            return;
        }
        crate::obs_counter!("runtime.runs").inc();
        let me = RT_WORKER
            .with(|w| w.get())
            .filter(|(id, _)| *id == self.shared.id)
            .map(|(_, i)| i);
        // SAFETY (JobPtr): `f` outlives this call — this frame does not
        // return until `done == n_chunks`, and every dereference happens
        // before the final `done` increment.
        let job = Arc::new(RtJob {
            job: JobPtr(f as *const (dyn Fn(usize) + Sync)),
            n_chunks,
            home: me,
            next: AtomicUsize::new(0),
            fin: Mutex::new(RtJobFin::default()),
            done_cv: Condvar::new(),
        });
        {
            let mut s = self.shared.sched.lock().unwrap();
            match me {
                Some(i) => s.worker_jobs[i].push_back(Arc::clone(&job)),
                None => s.external_jobs.push_back(Arc::clone(&job)),
            }
        }
        self.shared.work_cv.notify_all();

        // Participate: claim our own job's chunks first.
        loop {
            let c = {
                let mut s = self.shared.sched.lock().unwrap();
                claim(&mut s, &job)
            };
            match c {
                Some(c) => run_rt_chunk(&job, c, false),
                None => break,
            }
        }

        // Every chunk is claimed. Steal other jobs' chunks while ours
        // drain; park on the job's condvar only when nothing is stealable.
        loop {
            if job.fin.lock().unwrap().done == job.n_chunks {
                break;
            }
            let other = {
                let mut s = self.shared.sched.lock().unwrap();
                next_chunk_unit(&mut s, me)
            };
            match other {
                Some((j, c, stolen)) => run_rt_chunk(&j, c, stolen),
                None => {
                    let fin = job.fin.lock().unwrap();
                    let _fin = job
                        .done_cv
                        .wait_while(fin, |f| f.done < job.n_chunks)
                        .unwrap();
                    break;
                }
            }
        }

        let payload = job.fin.lock().unwrap().panic.take();
        if let Some(p) = payload {
            std::panic::resume_unwind(p);
        }
    }
}

impl Drop for Runtime {
    fn drop(&mut self) {
        {
            let mut s = self.shared.sched.lock().unwrap();
            s.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Claim the next chunk of `job` (scheduler lock held). The claim that
/// exhausts the job also removes it from its home queue, so queues never
/// hold exhausted jobs.
fn claim(s: &mut Sched, job: &Arc<RtJob>) -> Option<usize> {
    let c = job.next.load(Ordering::Relaxed);
    if c >= job.n_chunks {
        return None;
    }
    job.next.store(c + 1, Ordering::Relaxed);
    if c + 1 == job.n_chunks {
        remove_job(s, job);
    }
    Some(c)
}

fn remove_job(s: &mut Sched, job: &Arc<RtJob>) {
    let dq = match job.home {
        Some(i) => &mut s.worker_jobs[i],
        None => &mut s.external_jobs,
    };
    if let Some(pos) = dq.iter().position(|j| Arc::ptr_eq(j, job)) {
        dq.remove(pos);
    }
}

/// Find the next fork-join chunk for thread `me` (scheduler lock held):
/// own deque newest-first (the fork we are inside), then external jobs
/// oldest-first, then other workers' deques from the cold end — the
/// chase-lev scan order. Returns `(job, chunk, stolen)`; a claim is a
/// *steal* when the claimer did not publish the job. Never touches the
/// injector — coarse tasks are for idle workers only.
fn next_chunk_unit(s: &mut Sched, me: Option<usize>) -> Option<(Arc<RtJob>, usize, bool)> {
    if let Some(i) = me {
        while let Some(j) = s.worker_jobs[i].back().cloned() {
            match claim(s, &j) {
                Some(c) => return Some((j, c, false)),
                None => remove_job(s, &j), // stale entry; drop and rescan
            }
        }
    }
    while let Some(j) = s.external_jobs.front().cloned() {
        match claim(s, &j) {
            Some(c) => return Some((j, c, true)),
            None => remove_job(s, &j),
        }
    }
    let n = s.worker_jobs.len();
    let start = me.map(|i| i + 1).unwrap_or(0);
    for d in 0..n {
        let v = (start + d) % n;
        if Some(v) == me {
            continue;
        }
        while let Some(j) = s.worker_jobs[v].front().cloned() {
            match claim(s, &j) {
                Some(c) => return Some((j, c, true)),
                None => remove_job(s, &j),
            }
        }
    }
    None
}

/// Execute one claimed chunk and record its completion. Steals bump
/// `pool.steal` (scraped as `cgcn_pool_steal_total`) and land in the
/// steal-duration histogram alongside the shared busy histogram.
fn run_rt_chunk(job: &RtJob, chunk: usize, stolen: bool) {
    if stolen {
        crate::obs_counter!("pool.steal").inc();
    }
    let busy0 = obs_now();
    let fptr = job.job.0;
    // SAFETY: see JobPtr — the publishing `run` frame is pinned until
    // `done == n_chunks`, which happens only after this call returns.
    let result = catch_unwind(AssertUnwindSafe(|| unsafe { (*fptr)(chunk) }));
    if let Some(t) = busy0 {
        let secs = t.elapsed().as_secs_f64();
        crate::obs_hist!("pool.worker.busy.secs", crate::obs::TIME_BUCKETS).record(secs);
        if stolen {
            crate::obs_hist!("pool.worker.steal.secs", crate::obs::TIME_BUCKETS).record(secs);
        }
        crate::obs_counter!("pool.chunks").inc();
    }
    let mut fin = job.fin.lock().unwrap();
    if let Err(p) = result {
        if fin.panic.is_none() {
            fin.panic = Some(p);
        }
    }
    fin.done += 1;
    if fin.done == job.n_chunks {
        job.done_cv.notify_all();
    }
}

fn rt_worker_loop(shared: &RtShared, me: usize) {
    loop {
        let idle0 = obs_now();
        let unit = {
            let mut s = shared.sched.lock().unwrap();
            loop {
                if let Some((job, chunk, stolen)) = next_chunk_unit(&mut s, Some(me)) {
                    break Unit::Chunk { job, chunk, stolen };
                }
                if let Some(t) = s.injector.pop_front() {
                    crate::obs_gauge!("runtime.injector.depth").set(s.injector.len() as i64);
                    break Unit::Task(t);
                }
                // Shutdown only once all queues are drained, so tasks
                // submitted before Drop still run (Pool drains likewise).
                if s.shutdown {
                    return;
                }
                s = shared.work_cv.wait(s).unwrap();
            }
        };
        if let Some(t) = idle0 {
            crate::obs_hist!("pool.worker.idle.secs", crate::obs::TIME_BUCKETS)
                .record(t.elapsed().as_secs_f64());
        }
        match unit {
            Unit::Chunk { job, chunk, stolen } => run_rt_chunk(&job, chunk, stolen),
            Unit::Task(task) => {
                if catch_unwind(AssertUnwindSafe(task)).is_err() {
                    log::warn!("runtime task panicked; worker continues");
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Dispatch helpers
// ---------------------------------------------------------------------------

/// A raw pointer wrapper that asserts cross-thread shareability.
///
/// Used to hand disjoint row ranges of one output buffer to fork-join
/// chunks without the borrow checker seeing an aliased `&mut`. SAFETY
/// contract for all users: chunks may only touch the index range they were
/// dispatched, ranges never overlap, and the buffer outlives the dispatch
/// call (which blocks until every chunk is done).
pub struct SendPtr<T>(*mut T);

unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    pub fn new(p: *mut T) -> SendPtr<T> {
        SendPtr(p)
    }
    pub fn get(&self) -> *mut T {
        self.0
    }
}

/// How a single data-parallel op executes.
pub enum OpExec<'a> {
    /// On the caller, one chunk after another.
    Serial,
    /// Legacy spawn-per-op path: one scoped thread per chunk.
    Spawn,
    /// Dual-mode persistent pool: chunks claimed by parked workers + the
    /// caller (`--runtime dual`).
    Pool(&'a FjPool),
    /// Shared work-stealing runtime (`--runtime shared`): chunks claimed
    /// by whichever runtime workers are free, stolen by blocked forks.
    Rt(&'a Runtime),
}

/// Run `f(lo, hi)` once per `(lo, hi)` range in `bounds` on the chosen
/// executor. Blocks until all ranges are done. `f` must only write state
/// belonging to its own range — under that contract results are bitwise
/// identical across executors and thread counts, because each range runs
/// the identical scalar loop exactly once.
pub fn dispatch_ranges(exec: &OpExec, bounds: &[(usize, usize)], f: &(dyn Fn(usize, usize) + Sync)) {
    match exec {
        OpExec::Serial => {
            for &(lo, hi) in bounds {
                f(lo, hi);
            }
        }
        OpExec::Spawn => thread::scope(|s| {
            for &(lo, hi) in bounds {
                s.spawn(move || f(lo, hi));
            }
        }),
        OpExec::Pool(p) => p.run(bounds.len(), &|ci| {
            let (lo, hi) = bounds[ci];
            f(lo, hi)
        }),
        OpExec::Rt(rt) => rt.run(bounds.len(), &|ci| {
            let (lo, hi) = bounds[ci];
            f(lo, hi)
        }),
    }
}

/// Split `0..rows` into up to `chunks` contiguous ranges of (near-)equal
/// row count — the partition rule the legacy `parallel_row_chunks` used,
/// kept so pooled and spawn dispatch chunk identically.
pub fn uniform_chunks(chunks: usize, rows: usize) -> Vec<(usize, usize)> {
    if rows == 0 {
        return Vec::new();
    }
    let t = chunks.max(1).min(rows);
    // Spread the remainder one row per leading chunk so sizes differ by at
    // most one and exactly `t` chunks come back. (The old `div_ceil`
    // sizing left stragglers — 65 rows × 8 chunks gave seven 9-row chunks
    // plus one of 2 — and could return fewer chunks than workers: 17 rows
    // × 8 chunks rounded up to 3-row chunks, i.e. only 6.)
    let base = rows / t;
    let rem = rows % t;
    let mut out = Vec::with_capacity(t);
    let mut lo = 0usize;
    for i in 0..t {
        let hi = lo + base + usize::from(i < rem);
        out.push((lo, hi));
        lo = hi;
    }
    out
}

/// Run `f(i)` for `i in 0..n` on up to `threads` scoped worker threads and
/// return the results in index order. `threads <= 1` or `n <= 1` degrades
/// to a plain serial map (no threads spawned). Work is distributed by an
/// atomic counter so uneven item costs balance out.
pub fn scoped_map<T, F>(threads: usize, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let t = threads.min(n);
    let counter = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, T)>();
    let fr = &f;
    let cr = &counter;
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    thread::scope(|s| {
        for _ in 0..t {
            let tx = tx.clone();
            s.spawn(move || loop {
                let i = cr.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                if tx.send((i, fr(i))).is_err() {
                    break;
                }
            });
        }
        drop(tx);
        for (i, v) in rx {
            out[i] = Some(v);
        }
    });
    out.into_iter()
        .map(|o| o.expect("scoped_map worker panicked"))
        .collect()
}

/// Which fork-join engine a [`fork_map`] should fan out on.
#[derive(Clone, Copy)]
pub enum ForkExec<'a> {
    /// No persistent engine: fall back to [`scoped_map`].
    None,
    /// Dual-mode [`FjPool`].
    Fj(&'a FjPool),
    /// Shared work-stealing [`Runtime`].
    Rt(&'a Runtime),
}

/// [`scoped_map`] semantics on a persistent fork-join engine: run `f(i)`
/// for `i in 0..n` and return results in index order, claiming items from
/// the engine instead of spawning scoped threads. Falls back to
/// [`scoped_map`] when no engine is supplied (or parallelism is off).
pub fn fork_map<T, F>(exec: ForkExec, threads: usize, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if threads <= 1 || n <= 1 || matches!(exec, ForkExec::None) {
        return scoped_map(threads, n, f);
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let slots = SendPtr::new(out.as_mut_ptr());
    // SAFETY: item i writes only slot i; `run` blocks until every item
    // finished and `out` outlives the call.
    let item = |i: usize| unsafe { *slots.get().add(i) = Some(f(i)) };
    match exec {
        ForkExec::None => unreachable!(),
        ForkExec::Fj(p) => p.run(n, &item),
        ForkExec::Rt(rt) => rt.run(n, &item),
    }
    out.into_iter()
        .map(|o| o.expect("fork_map item panicked"))
        .collect()
}

/// [`fork_map`] on an optional [`FjPool`] — the original dual-mode entry
/// point, kept for the legacy call sites and tests.
pub fn fj_map<T, F>(pool: Option<&FjPool>, threads: usize, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    fork_map(pool.map_or(ForkExec::None, ForkExec::Fj), threads, n, f)
}

/// Split a row-major `rows × cols` output buffer into contiguous row
/// chunks, one per thread, and run `f(row_lo, row_hi, chunk)` on scoped
/// threads. With `threads <= 1` the single chunk runs on the caller's
/// thread. Each output row is written by exactly one invocation, so the
/// result is bitwise identical to the serial run of the same `f`.
///
/// This is the legacy spawn-per-op path; the backend now routes through
/// [`dispatch_ranges`] + [`FjPool`] by default and keeps this helper as
/// the `--op-spawn` A/B reference.
pub fn parallel_row_chunks<F>(threads: usize, rows: usize, cols: usize, out: &mut [f32], f: F)
where
    F: Fn(usize, usize, &mut [f32]) + Sync,
{
    assert_eq!(out.len(), rows * cols, "output buffer shape mismatch");
    if threads <= 1 || rows <= 1 {
        f(0, rows, out);
        return;
    }
    let t = threads.min(rows);
    let chunk_rows = rows.div_ceil(t);
    let fr = &f;
    thread::scope(|s| {
        let mut rest = out;
        let mut lo = 0usize;
        while lo < rows {
            let hi = (lo + chunk_rows).min(rows);
            let (head, tail) = rest.split_at_mut((hi - lo) * cols);
            rest = tail;
            s.spawn(move || fr(lo, hi, head));
            lo = hi;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_jobs_and_shuts_down() {
        let pool = Pool::new(4);
        assert_eq!(pool.threads(), 4);
        let hits = Arc::new(AtomicU64::new(0));
        let (tx, rx) = mpsc::channel();
        for i in 0..32u64 {
            let hits = hits.clone();
            let tx = tx.clone();
            pool.execute(move || {
                hits.fetch_add(i, Ordering::Relaxed);
                tx.send(()).unwrap();
            });
        }
        drop(tx);
        for _ in 0..32 {
            rx.recv().unwrap();
        }
        assert_eq!(hits.load(Ordering::Relaxed), (0..32).sum::<u64>());
        drop(pool); // joins workers
    }

    #[test]
    fn pool_survives_panicking_job() {
        // A single-worker pool: if the panicking job killed its worker,
        // none of the follow-up jobs could ever run.
        let pool = Pool::new(1);
        pool.execute(|| panic!("job goes boom"));
        let (tx, rx) = mpsc::channel();
        for i in 0..8u64 {
            let tx = tx.clone();
            pool.execute(move || tx.send(i).unwrap());
        }
        drop(tx);
        let mut got: Vec<u64> = rx.iter().collect();
        got.sort_unstable();
        assert_eq!(got, (0..8).collect::<Vec<u64>>());
    }

    #[test]
    fn scoped_map_is_ordered_and_complete() {
        for threads in [1usize, 2, 4, 8] {
            let got = scoped_map(threads, 37, |i| i * i);
            let want: Vec<usize> = (0..37).map(|i| i * i).collect();
            assert_eq!(got, want, "threads={threads}");
        }
        assert!(scoped_map(4, 0, |i| i).is_empty());
    }

    #[test]
    fn parallel_row_chunks_matches_serial() {
        let rows = 57;
        let cols = 13;
        let fill = |lo: usize, hi: usize, chunk: &mut [f32]| {
            for (ri, r) in (lo..hi).enumerate() {
                for c in 0..cols {
                    chunk[ri * cols + c] = (r * cols + c) as f32 * 0.5;
                }
            }
        };
        let mut serial = vec![0.0f32; rows * cols];
        fill(0, rows, &mut serial);
        for threads in [1usize, 2, 3, 8, 64] {
            let mut par = vec![0.0f32; rows * cols];
            parallel_row_chunks(threads, rows, cols, &mut par, fill);
            assert_eq!(par, serial, "threads={threads}");
        }
    }

    #[test]
    fn fj_pool_runs_and_is_reusable() {
        let pool = FjPool::new(4);
        for round in 0..50usize {
            let n = 1 + (round % 7);
            let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            pool.run(n, &|c| {
                hits[c].fetch_add((c + round) as u64, Ordering::Relaxed);
            });
            for (c, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), (c + round) as u64, "round {round}");
            }
        }
    }

    #[test]
    fn fj_pool_survives_panicking_chunk() {
        let pool = FjPool::new(4);
        let caught = catch_unwind(AssertUnwindSafe(|| {
            pool.run(8, &|c| {
                if c == 3 {
                    panic!("chunk goes boom");
                }
            });
        }));
        assert!(caught.is_err(), "chunk panic must propagate to the caller");
        // The pool must still be fully usable afterwards.
        let hits = AtomicU64::new(0);
        pool.run(16, &|_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn fj_pool_nested_run_executes_inline() {
        let pool = FjPool::new(4);
        let outer = AtomicU64::new(0);
        let inner = AtomicU64::new(0);
        pool.run(4, &|_| {
            outer.fetch_add(1, Ordering::Relaxed);
            // Nested fork from inside a chunk: must not deadlock.
            pool.run(4, &|_| {
                inner.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(outer.load(Ordering::Relaxed), 4);
        assert_eq!(inner.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn fj_pool_serialises_concurrent_callers() {
        let pool = Arc::new(FjPool::new(3));
        let total = Arc::new(AtomicU64::new(0));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let pool = pool.clone();
            let total = total.clone();
            handles.push(thread::spawn(move || {
                for _ in 0..25 {
                    pool.run(6, &|_| {
                        total.fetch_add(1, Ordering::Relaxed);
                    });
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(total.load(Ordering::Relaxed), 4 * 25 * 6);
    }

    #[test]
    fn fj_map_is_ordered_and_complete() {
        let pool = FjPool::new(4);
        for threads in [1usize, 2, 4, 8] {
            let got = fj_map(Some(&pool), threads, 37, |i| i * i);
            let want: Vec<usize> = (0..37).map(|i| i * i).collect();
            assert_eq!(got, want, "threads={threads}");
        }
        assert!(fj_map(Some(&pool), 4, 0, |i| i).is_empty());
        // No pool → scoped_map fallback.
        let got = fj_map(None, 4, 9, |i| i + 1);
        assert_eq!(got, (1..10).collect::<Vec<usize>>());
    }

    #[test]
    fn dispatch_ranges_all_executors_match() {
        let rows = 41usize;
        let run = |exec: OpExec| -> Vec<u32> {
            let mut out = vec![0u32; rows];
            let bounds = uniform_chunks(4, rows);
            let p = SendPtr::new(out.as_mut_ptr());
            dispatch_ranges(&exec, &bounds, &|lo, hi| {
                for r in lo..hi {
                    // SAFETY: ranges are disjoint.
                    unsafe { *p.get().add(r) = (r * r) as u32 };
                }
            });
            out
        };
        let want = run(OpExec::Serial);
        assert_eq!(run(OpExec::Spawn), want);
        let pool = FjPool::new(4);
        assert_eq!(run(OpExec::Pool(&pool)), want);
        let rt = Runtime::new(4);
        assert_eq!(run(OpExec::Rt(&rt)), want);
    }

    #[test]
    fn runtime_runs_and_is_reusable() {
        for budget in [1usize, 2, 4] {
            let rt = Runtime::new(budget);
            assert_eq!(rt.threads(), budget);
            for round in 0..50usize {
                let n = 1 + (round % 7);
                let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
                rt.run(n, &|c| {
                    hits[c].fetch_add((c + round) as u64, Ordering::Relaxed);
                });
                for (c, h) in hits.iter().enumerate() {
                    assert_eq!(
                        h.load(Ordering::Relaxed),
                        (c + round) as u64,
                        "budget {budget} round {round}"
                    );
                }
            }
        }
    }

    #[test]
    fn runtime_executes_tasks_and_survives_task_panic() {
        let rt = Runtime::new(2); // 1 worker: a dead worker would hang this
        rt.execute(|| panic!("task goes boom"));
        let (tx, rx) = mpsc::channel();
        for i in 0..8u64 {
            let tx = tx.clone();
            rt.execute(move || tx.send(i).unwrap());
        }
        drop(tx);
        let mut got: Vec<u64> = rx.iter().collect();
        got.sort_unstable();
        assert_eq!(got, (0..8).collect::<Vec<u64>>());
    }

    #[test]
    fn runtime_concurrent_forks_make_progress() {
        // Unlike FjPool (fork_lock), concurrent callers fork in parallel:
        // more callers than workers, each forking repeatedly, must all
        // complete with exact totals.
        let rt = Arc::new(Runtime::new(3));
        let total = Arc::new(AtomicU64::new(0));
        let mut handles = Vec::new();
        for _ in 0..6 {
            let rt = Arc::clone(&rt);
            let total = Arc::clone(&total);
            handles.push(thread::spawn(move || {
                for _ in 0..25 {
                    rt.run(6, &|_| {
                        total.fetch_add(1, Ordering::Relaxed);
                    });
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(total.load(Ordering::Relaxed), 6 * 25 * 6);
    }

    #[test]
    fn runtime_nested_fork_from_task_completes() {
        // An injector task (agent-phase shape) forking kernels on the same
        // runtime: the worker running the task participates in its own
        // fork and steals, so this must complete even on a 2-thread budget
        // where the only other thread is the blocked test caller.
        let rt = Arc::new(Runtime::new(2));
        let inner = Arc::new(AtomicU64::new(0));
        let (tx, rx) = mpsc::channel();
        for t in 0..4u64 {
            let rt2 = Arc::clone(&rt);
            let inner = Arc::clone(&inner);
            let tx = tx.clone();
            rt.execute(move || {
                rt2.run(8, &|_| {
                    inner.fetch_add(1, Ordering::Relaxed);
                });
                tx.send(t).unwrap();
            });
        }
        drop(tx);
        assert_eq!(rx.iter().count(), 4);
        assert_eq!(inner.load(Ordering::Relaxed), 32);
    }

    #[test]
    fn runtime_nested_run_inside_chunk_completes() {
        // A fork inside a fork chunk (trainer fork_map item calling pooled
        // backend kernels). FjPool inlines this; the runtime forks for
        // real — both must give exact counts.
        let rt = Runtime::new(4);
        let outer = AtomicU64::new(0);
        let inner = AtomicU64::new(0);
        rt.run(4, &|_| {
            outer.fetch_add(1, Ordering::Relaxed);
            rt.run(4, &|_| {
                inner.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(outer.load(Ordering::Relaxed), 4);
        assert_eq!(inner.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn runtime_survives_panicking_chunk() {
        let rt = Runtime::new(4);
        let caught = catch_unwind(AssertUnwindSafe(|| {
            rt.run(8, &|c| {
                if c == 3 {
                    panic!("chunk goes boom");
                }
            });
        }));
        assert!(caught.is_err(), "chunk panic must propagate to the caller");
        let hits = AtomicU64::new(0);
        rt.run(16, &|_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn runtime_blocked_fork_steals_under_skew() {
        // One giant chunk pins a thread; the publisher must not park but
        // steal the other job's chunks so both forks finish. Budget 2 =
        // 1 worker + caller: if the blocked caller refused to steal, the
        // second fork could only finish after the slow chunk (~forever
        // relative to the barrier below).
        let rt = Arc::new(Runtime::new(2));
        let (slow_tx, slow_rx) = mpsc::channel::<()>();
        let slow_rx = Mutex::new(slow_rx);
        let rt2 = Arc::clone(&rt);
        let done = Arc::new(AtomicU64::new(0));
        let done2 = Arc::clone(&done);
        let h = thread::spawn(move || {
            rt2.run(2, &|c| {
                if c == 0 {
                    // Block until the main thread's fork finished.
                    slow_rx.lock().unwrap().recv().unwrap();
                }
            });
            done2.fetch_add(1, Ordering::Relaxed);
        });
        // Give the spawned fork time to get its slow chunk claimed.
        thread::sleep(std::time::Duration::from_millis(50));
        // This fork's chunks can only run via stealing: the sole worker
        // (or the spawned caller) is busy/blocked in the slow job.
        let hits = AtomicU64::new(0);
        rt.run(8, &|_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 8);
        slow_tx.send(()).unwrap();
        h.join().unwrap();
        assert_eq!(done.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn fork_map_matches_scoped_map_on_all_engines() {
        let want: Vec<usize> = (0..37).map(|i| i * i).collect();
        let pool = FjPool::new(4);
        let rt = Runtime::new(4);
        for threads in [1usize, 2, 4, 8] {
            for exec in [ForkExec::None, ForkExec::Fj(&pool), ForkExec::Rt(&rt)] {
                let got = fork_map(exec, threads, 37, |i| i * i);
                assert_eq!(got, want, "threads={threads}");
            }
        }
        assert!(fork_map(ForkExec::Rt(&rt), 4, 0, |i| i).is_empty());
    }

    #[test]
    fn uniform_chunks_cover_exactly() {
        for rows in [0usize, 1, 7, 17, 57, 64, 65] {
            for chunks in [1usize, 2, 3, 8, 100] {
                let b = uniform_chunks(chunks, rows);
                let mut next = 0usize;
                for &(lo, hi) in &b {
                    assert_eq!(lo, next);
                    assert!(hi > lo);
                    next = hi;
                }
                assert_eq!(next, rows);
                assert!(b.len() <= chunks.max(1));
                if rows > 0 {
                    // Every requested worker gets a chunk (capped by rows),
                    // and the split is balanced: max − min ≤ 1 row.
                    assert_eq!(b.len(), chunks.max(1).min(rows), "rows={rows} chunks={chunks}");
                    let min = b.iter().map(|&(lo, hi)| hi - lo).min().unwrap();
                    let max = b.iter().map(|&(lo, hi)| hi - lo).max().unwrap();
                    assert!(
                        max - min <= 1,
                        "unbalanced split rows={rows} chunks={chunks}: {b:?}"
                    );
                }
            }
        }
    }
}
