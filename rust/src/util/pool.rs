//! In-house worker pools + data-parallel dispatch (rayon is not available
//! offline).
//!
//! Three execution primitives, matching the three shapes of parallelism in
//! the trainer:
//!
//! - [`Pool`] — a persistent thread pool for `'static` jobs. The parallel
//!   agent runtime ([`crate::coordinator`]) moves each community agent's
//!   state into a job and exchanges p/s messages over `mpsc` channels, so
//!   jobs own everything they touch and no scoped lifetimes are needed.
//!   Jobs are panic-isolated: a panicking job is caught at the job
//!   boundary and its worker keeps serving the queue.
//! - [`FjPool`] — a persistent *fork-join* pool for borrowed-data jobs:
//!   workers park on a condvar between ops, so dispatching a parallel
//!   kernel costs a mutex round-trip + wakeup (~1–2 µs) instead of a fresh
//!   `thread::scope` spawn per op (~tens of µs). This is what
//!   [`crate::runtime::NativeBackend`] drives every parallel kernel
//!   through, and what [`fj_map`] uses for the per-community W partials.
//! - [`scoped_map`] / [`parallel_row_chunks`] — the legacy spawn-per-op
//!   fork-join helpers built on `std::thread::scope`. Kept as the A/B
//!   reference path (`--op-spawn`, `NativeBackend::with_spawn_threads`)
//!   and as the fallback when no pool is available.
//!
//! Determinism: every helper partitions work by index and every output
//! element is written by exactly one thread running the same scalar loop
//! the serial path runs, so parallel results are bitwise identical to
//! serial ones at any thread count. Reductions are always folded on the
//! caller's thread in index order.

use std::cell::Cell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Resolve a user-facing thread count: 0 means "all available cores",
/// with a floor of 1.
pub fn resolve_threads(threads: usize) -> usize {
    if threads == 0 {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    } else {
        threads
    }
}

/// A small persistent worker pool for `'static` jobs.
pub struct Pool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl Pool {
    /// Spawn a pool with `threads` workers (at least 1).
    pub fn new(threads: usize) -> Pool {
        let threads = threads.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("cgcn-pool-{i}"))
                    .spawn(move || loop {
                        // Take the lock only to dequeue; run unlocked.
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            // Catch panics at the job boundary so a bad job
                            // cannot silently shrink the pool: the worker
                            // survives and keeps serving the queue. The
                            // submitter observes the failure through its
                            // own result channel going dead (the agent
                            // executor already handles that case).
                            Ok(job) => {
                                if catch_unwind(AssertUnwindSafe(job)).is_err() {
                                    log::warn!("pool job panicked; worker continues");
                                }
                            }
                            Err(_) => break, // all senders dropped
                        }
                    })
                    .expect("spawning pool worker")
            })
            .collect();
        Pool {
            tx: Some(tx),
            workers,
        }
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Enqueue a job. Panicking jobs are caught at the job boundary; the
    /// worker is reused for subsequent jobs.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        crate::obs_counter!("pool.jobs").inc();
        self.tx
            .as_ref()
            .expect("pool already shut down")
            .send(Box::new(job))
            .expect("pool worker channel closed");
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

// ---------------------------------------------------------------------------
// FjPool — persistent fork-join pool for borrowed-data kernels
// ---------------------------------------------------------------------------

thread_local! {
    /// True while this thread is executing a fork-join chunk (worker or
    /// participating caller). A nested [`FjPool::run`] from inside a chunk
    /// runs its chunks inline instead of re-forking — this makes nesting
    /// (e.g. a pooled `fj_map` item calling pooled backend kernels)
    /// deadlock-free by construction.
    static IN_FJ_CHUNK: Cell<bool> = const { Cell::new(false) };
}

/// Type-erased pointer to the current job closure. The pointee lives on
/// the stack of the thread blocked in [`FjPool::run`]; see the safety
/// argument there.
struct JobPtr(*const (dyn Fn(usize) + Sync));
// SAFETY: the pointer is only dereferenced by workers between job
// publication and the last `done` increment, a window during which the
// caller of `run` is pinned (participating or waiting on `done_cv`), so
// the pointee outlives every dereference.
unsafe impl Send for JobPtr {}

#[derive(Default)]
struct FjState {
    job: Option<JobPtr>,
    n_chunks: usize,
    next_chunk: usize,
    done: usize,
    panic_payload: Option<Box<dyn std::any::Any + Send>>,
    shutdown: bool,
}

struct FjShared {
    state: Mutex<FjState>,
    /// Workers park here between jobs.
    work_cv: Condvar,
    /// The caller parks here until `done == n_chunks`.
    done_cv: Condvar,
}

/// A persistent fork-join pool: `threads − 1` parked workers plus the
/// calling thread, woken per [`FjPool::run`] call through a condvar.
///
/// Compared to `thread::scope` (spawn + join per op) the steady-state
/// dispatch cost is one mutex round-trip and a wakeup, which is what makes
/// op-level parallelism profitable at the small grains the ADMM inner
/// loops actually run at (see `benches/kernel_bench.rs`).
///
/// Panic isolation: each chunk runs under `catch_unwind` on both workers
/// and the caller; the first payload is re-raised on the caller *after*
/// every chunk has finished, so workers never dangle into a dead caller
/// frame and the pool stays usable after a panicking job.
pub struct FjPool {
    shared: Arc<FjShared>,
    /// Serialises concurrent `run` callers (one fork-join job at a time).
    fork_lock: Mutex<()>,
    threads: usize,
    workers: Vec<thread::JoinHandle<()>>,
}

impl FjPool {
    /// Pool sized for `threads` total participants: the caller plus
    /// `threads − 1` spawned workers (so `FjPool::new(1)` spawns nothing
    /// and every `run` is a plain serial loop).
    pub fn new(threads: usize) -> FjPool {
        let threads = threads.max(1);
        let shared = Arc::new(FjShared {
            state: Mutex::new(FjState::default()),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let workers = (1..threads)
            .map(|i| {
                let shared = Arc::clone(&shared);
                thread::Builder::new()
                    .name(format!("cgcn-fj-{i}"))
                    .spawn(move || {
                        IN_FJ_CHUNK.with(|f| f.set(true));
                        worker_loop(&shared);
                    })
                    .expect("spawning fj worker")
            })
            .collect();
        FjPool {
            shared,
            fork_lock: Mutex::new(()),
            threads,
            workers,
        }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(chunk)` for `chunk in 0..n_chunks`, distributing chunks over
    /// the pool (the caller participates). Blocks until every chunk has
    /// finished; re-raises the first chunk panic afterwards. Calls nested
    /// inside a running chunk execute inline (serially) instead of
    /// deadlocking on the pool.
    pub fn run(&self, n_chunks: usize, f: &(dyn Fn(usize) + Sync)) {
        if n_chunks == 0 {
            return;
        }
        let nested = IN_FJ_CHUNK.with(|c| c.get());
        if nested || n_chunks == 1 || self.threads <= 1 {
            for c in 0..n_chunks {
                f(c);
            }
            return;
        }
        crate::obs_counter!("pool.fj.runs").inc();
        let _forking = self.fork_lock.lock().unwrap();
        // SAFETY: `f` outlives this call; the raw pointer is only
        // dereferenced while some chunk index is still unclaimed or
        // running, and this frame does not return (or unwind — the
        // caller's own chunks run under catch_unwind) until
        // `done == n_chunks`.
        let job = JobPtr(f as *const (dyn Fn(usize) + Sync));
        {
            let mut st = self.shared.state.lock().unwrap();
            st.job = Some(job);
            st.n_chunks = n_chunks;
            st.next_chunk = 0;
            st.done = 0;
            st.panic_payload = None;
        }
        self.shared.work_cv.notify_all();

        // Participate: claim chunks like any worker.
        IN_FJ_CHUNK.with(|c| c.set(true));
        loop {
            let chunk = {
                let mut st = self.shared.state.lock().unwrap();
                if st.next_chunk >= st.n_chunks {
                    break;
                }
                let c = st.next_chunk;
                st.next_chunk += 1;
                c
            };
            let busy0 = obs_now();
            let result = catch_unwind(AssertUnwindSafe(|| f(chunk)));
            record_chunk(busy0);
            finish_chunk(&self.shared, result);
        }
        IN_FJ_CHUNK.with(|c| c.set(false));

        // Join: wait for workers to drain the remaining chunks.
        let payload = {
            let mut st = self.shared.state.lock().unwrap();
            while st.done < st.n_chunks {
                st = self.shared.done_cv.wait(st).unwrap();
            }
            st.job = None;
            st.panic_payload.take()
        };
        drop(_forking);
        if let Some(p) = payload {
            std::panic::resume_unwind(p);
        }
    }
}

fn worker_loop(shared: &FjShared) {
    loop {
        let idle0 = obs_now();
        let (fptr, chunk) = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if let Some(job) = &st.job {
                    if st.next_chunk < st.n_chunks {
                        let c = st.next_chunk;
                        st.next_chunk += 1;
                        break (job.0, c);
                    }
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        if let Some(t) = idle0 {
            crate::obs_hist!("pool.worker.idle.secs", crate::obs::TIME_BUCKETS)
                .record(t.elapsed().as_secs_f64());
        }
        let busy0 = obs_now();
        // SAFETY: see JobPtr — the caller is pinned until `done` reaches
        // `n_chunks`, which only happens after this dereference completes.
        let result = catch_unwind(AssertUnwindSafe(|| unsafe { (*fptr)(chunk) }));
        record_chunk(busy0);
        finish_chunk(shared, result);
    }
}

/// `Instant::now()` only when telemetry is on — the fork-join loops run at
/// microsecond chunk grains, so even a clock read must be behind the gate.
#[inline]
fn obs_now() -> Option<std::time::Instant> {
    crate::obs::enabled().then(std::time::Instant::now)
}

/// Per-chunk telemetry: chunk count + busy-time histogram (counts and
/// seconds per worker shard; the scrape sums them).
#[inline]
fn record_chunk(busy0: Option<std::time::Instant>) {
    if let Some(t) = busy0 {
        crate::obs_hist!("pool.worker.busy.secs", crate::obs::TIME_BUCKETS)
            .record(t.elapsed().as_secs_f64());
        crate::obs_counter!("pool.chunks").inc();
    }
}

/// Record a finished chunk (and its panic payload, if any); wake the
/// caller when it was the last one.
fn finish_chunk(shared: &FjShared, result: Result<(), Box<dyn std::any::Any + Send>>) {
    let mut st = shared.state.lock().unwrap();
    if let Err(p) = result {
        if st.panic_payload.is_none() {
            st.panic_payload = Some(p);
        }
    }
    st.done += 1;
    if st.done == st.n_chunks {
        shared.done_cv.notify_all();
    }
}

impl Drop for FjPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Dispatch helpers
// ---------------------------------------------------------------------------

/// A raw pointer wrapper that asserts cross-thread shareability.
///
/// Used to hand disjoint row ranges of one output buffer to fork-join
/// chunks without the borrow checker seeing an aliased `&mut`. SAFETY
/// contract for all users: chunks may only touch the index range they were
/// dispatched, ranges never overlap, and the buffer outlives the dispatch
/// call (which blocks until every chunk is done).
pub struct SendPtr<T>(*mut T);

unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    pub fn new(p: *mut T) -> SendPtr<T> {
        SendPtr(p)
    }
    pub fn get(&self) -> *mut T {
        self.0
    }
}

/// How a single data-parallel op executes.
pub enum OpExec<'a> {
    /// On the caller, one chunk after another.
    Serial,
    /// Legacy spawn-per-op path: one scoped thread per chunk.
    Spawn,
    /// Persistent pool: chunks claimed by parked workers + the caller.
    Pool(&'a FjPool),
}

/// Run `f(lo, hi)` once per `(lo, hi)` range in `bounds` on the chosen
/// executor. Blocks until all ranges are done. `f` must only write state
/// belonging to its own range — under that contract results are bitwise
/// identical across executors and thread counts, because each range runs
/// the identical scalar loop exactly once.
pub fn dispatch_ranges(exec: &OpExec, bounds: &[(usize, usize)], f: &(dyn Fn(usize, usize) + Sync)) {
    match exec {
        OpExec::Serial => {
            for &(lo, hi) in bounds {
                f(lo, hi);
            }
        }
        OpExec::Spawn => thread::scope(|s| {
            for &(lo, hi) in bounds {
                s.spawn(move || f(lo, hi));
            }
        }),
        OpExec::Pool(p) => p.run(bounds.len(), &|ci| {
            let (lo, hi) = bounds[ci];
            f(lo, hi)
        }),
    }
}

/// Split `0..rows` into up to `chunks` contiguous ranges of (near-)equal
/// row count — the partition rule the legacy `parallel_row_chunks` used,
/// kept so pooled and spawn dispatch chunk identically.
pub fn uniform_chunks(chunks: usize, rows: usize) -> Vec<(usize, usize)> {
    if rows == 0 {
        return Vec::new();
    }
    let t = chunks.max(1).min(rows);
    let chunk_rows = rows.div_ceil(t);
    let mut out = Vec::with_capacity(t);
    let mut lo = 0usize;
    while lo < rows {
        let hi = (lo + chunk_rows).min(rows);
        out.push((lo, hi));
        lo = hi;
    }
    out
}

/// Run `f(i)` for `i in 0..n` on up to `threads` scoped worker threads and
/// return the results in index order. `threads <= 1` or `n <= 1` degrades
/// to a plain serial map (no threads spawned). Work is distributed by an
/// atomic counter so uneven item costs balance out.
pub fn scoped_map<T, F>(threads: usize, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let t = threads.min(n);
    let counter = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, T)>();
    let fr = &f;
    let cr = &counter;
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    thread::scope(|s| {
        for _ in 0..t {
            let tx = tx.clone();
            s.spawn(move || loop {
                let i = cr.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                if tx.send((i, fr(i))).is_err() {
                    break;
                }
            });
        }
        drop(tx);
        for (i, v) in rx {
            out[i] = Some(v);
        }
    });
    out.into_iter()
        .map(|o| o.expect("scoped_map worker panicked"))
        .collect()
}

/// [`scoped_map`] semantics on a persistent [`FjPool`]: run `f(i)` for
/// `i in 0..n` and return results in index order, claiming items from the
/// pool instead of spawning scoped threads. Falls back to [`scoped_map`]
/// when no pool is supplied (or parallelism is off).
pub fn fj_map<T, F>(pool: Option<&FjPool>, threads: usize, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    match pool {
        Some(p) if threads > 1 && n > 1 => {
            let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
            let slots = SendPtr::new(out.as_mut_ptr());
            // SAFETY: item i writes only slot i; `run` blocks until every
            // item finished and `out` outlives the call.
            p.run(n, &|i| unsafe { *slots.get().add(i) = Some(f(i)) });
            out.into_iter()
                .map(|o| o.expect("fj_map item panicked"))
                .collect()
        }
        _ => scoped_map(threads, n, f),
    }
}

/// Split a row-major `rows × cols` output buffer into contiguous row
/// chunks, one per thread, and run `f(row_lo, row_hi, chunk)` on scoped
/// threads. With `threads <= 1` the single chunk runs on the caller's
/// thread. Each output row is written by exactly one invocation, so the
/// result is bitwise identical to the serial run of the same `f`.
///
/// This is the legacy spawn-per-op path; the backend now routes through
/// [`dispatch_ranges`] + [`FjPool`] by default and keeps this helper as
/// the `--op-spawn` A/B reference.
pub fn parallel_row_chunks<F>(threads: usize, rows: usize, cols: usize, out: &mut [f32], f: F)
where
    F: Fn(usize, usize, &mut [f32]) + Sync,
{
    assert_eq!(out.len(), rows * cols, "output buffer shape mismatch");
    if threads <= 1 || rows <= 1 {
        f(0, rows, out);
        return;
    }
    let t = threads.min(rows);
    let chunk_rows = rows.div_ceil(t);
    let fr = &f;
    thread::scope(|s| {
        let mut rest = out;
        let mut lo = 0usize;
        while lo < rows {
            let hi = (lo + chunk_rows).min(rows);
            let (head, tail) = rest.split_at_mut((hi - lo) * cols);
            rest = tail;
            s.spawn(move || fr(lo, hi, head));
            lo = hi;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_jobs_and_shuts_down() {
        let pool = Pool::new(4);
        assert_eq!(pool.threads(), 4);
        let hits = Arc::new(AtomicU64::new(0));
        let (tx, rx) = mpsc::channel();
        for i in 0..32u64 {
            let hits = hits.clone();
            let tx = tx.clone();
            pool.execute(move || {
                hits.fetch_add(i, Ordering::Relaxed);
                tx.send(()).unwrap();
            });
        }
        drop(tx);
        for _ in 0..32 {
            rx.recv().unwrap();
        }
        assert_eq!(hits.load(Ordering::Relaxed), (0..32).sum::<u64>());
        drop(pool); // joins workers
    }

    #[test]
    fn pool_survives_panicking_job() {
        // A single-worker pool: if the panicking job killed its worker,
        // none of the follow-up jobs could ever run.
        let pool = Pool::new(1);
        pool.execute(|| panic!("job goes boom"));
        let (tx, rx) = mpsc::channel();
        for i in 0..8u64 {
            let tx = tx.clone();
            pool.execute(move || tx.send(i).unwrap());
        }
        drop(tx);
        let mut got: Vec<u64> = rx.iter().collect();
        got.sort_unstable();
        assert_eq!(got, (0..8).collect::<Vec<u64>>());
    }

    #[test]
    fn scoped_map_is_ordered_and_complete() {
        for threads in [1usize, 2, 4, 8] {
            let got = scoped_map(threads, 37, |i| i * i);
            let want: Vec<usize> = (0..37).map(|i| i * i).collect();
            assert_eq!(got, want, "threads={threads}");
        }
        assert!(scoped_map(4, 0, |i| i).is_empty());
    }

    #[test]
    fn parallel_row_chunks_matches_serial() {
        let rows = 57;
        let cols = 13;
        let fill = |lo: usize, hi: usize, chunk: &mut [f32]| {
            for (ri, r) in (lo..hi).enumerate() {
                for c in 0..cols {
                    chunk[ri * cols + c] = (r * cols + c) as f32 * 0.5;
                }
            }
        };
        let mut serial = vec![0.0f32; rows * cols];
        fill(0, rows, &mut serial);
        for threads in [1usize, 2, 3, 8, 64] {
            let mut par = vec![0.0f32; rows * cols];
            parallel_row_chunks(threads, rows, cols, &mut par, fill);
            assert_eq!(par, serial, "threads={threads}");
        }
    }

    #[test]
    fn fj_pool_runs_and_is_reusable() {
        let pool = FjPool::new(4);
        for round in 0..50usize {
            let n = 1 + (round % 7);
            let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            pool.run(n, &|c| {
                hits[c].fetch_add((c + round) as u64, Ordering::Relaxed);
            });
            for (c, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), (c + round) as u64, "round {round}");
            }
        }
    }

    #[test]
    fn fj_pool_survives_panicking_chunk() {
        let pool = FjPool::new(4);
        let caught = catch_unwind(AssertUnwindSafe(|| {
            pool.run(8, &|c| {
                if c == 3 {
                    panic!("chunk goes boom");
                }
            });
        }));
        assert!(caught.is_err(), "chunk panic must propagate to the caller");
        // The pool must still be fully usable afterwards.
        let hits = AtomicU64::new(0);
        pool.run(16, &|_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn fj_pool_nested_run_executes_inline() {
        let pool = FjPool::new(4);
        let outer = AtomicU64::new(0);
        let inner = AtomicU64::new(0);
        pool.run(4, &|_| {
            outer.fetch_add(1, Ordering::Relaxed);
            // Nested fork from inside a chunk: must not deadlock.
            pool.run(4, &|_| {
                inner.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(outer.load(Ordering::Relaxed), 4);
        assert_eq!(inner.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn fj_pool_serialises_concurrent_callers() {
        let pool = Arc::new(FjPool::new(3));
        let total = Arc::new(AtomicU64::new(0));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let pool = pool.clone();
            let total = total.clone();
            handles.push(thread::spawn(move || {
                for _ in 0..25 {
                    pool.run(6, &|_| {
                        total.fetch_add(1, Ordering::Relaxed);
                    });
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(total.load(Ordering::Relaxed), 4 * 25 * 6);
    }

    #[test]
    fn fj_map_is_ordered_and_complete() {
        let pool = FjPool::new(4);
        for threads in [1usize, 2, 4, 8] {
            let got = fj_map(Some(&pool), threads, 37, |i| i * i);
            let want: Vec<usize> = (0..37).map(|i| i * i).collect();
            assert_eq!(got, want, "threads={threads}");
        }
        assert!(fj_map(Some(&pool), 4, 0, |i| i).is_empty());
        // No pool → scoped_map fallback.
        let got = fj_map(None, 4, 9, |i| i + 1);
        assert_eq!(got, (1..10).collect::<Vec<usize>>());
    }

    #[test]
    fn dispatch_ranges_all_executors_match() {
        let rows = 41usize;
        let run = |exec: OpExec| -> Vec<u32> {
            let mut out = vec![0u32; rows];
            let bounds = uniform_chunks(4, rows);
            let p = SendPtr::new(out.as_mut_ptr());
            dispatch_ranges(&exec, &bounds, &|lo, hi| {
                for r in lo..hi {
                    // SAFETY: ranges are disjoint.
                    unsafe { *p.get().add(r) = (r * r) as u32 };
                }
            });
            out
        };
        let want = run(OpExec::Serial);
        assert_eq!(run(OpExec::Spawn), want);
        let pool = FjPool::new(4);
        assert_eq!(run(OpExec::Pool(&pool)), want);
    }

    #[test]
    fn uniform_chunks_cover_exactly() {
        for rows in [0usize, 1, 7, 57, 64] {
            for chunks in [1usize, 2, 3, 8, 100] {
                let b = uniform_chunks(chunks, rows);
                let mut next = 0usize;
                for &(lo, hi) in &b {
                    assert_eq!(lo, next);
                    assert!(hi > lo);
                    next = hi;
                }
                assert_eq!(next, rows);
                assert!(b.len() <= chunks.max(1));
            }
        }
    }
}
