//! In-house worker pool + scoped data-parallel helpers (rayon is not
//! available offline).
//!
//! Two execution primitives, matching the two shapes of parallelism in the
//! trainer:
//!
//! - [`Pool`] — a persistent thread pool for `'static` jobs. The parallel
//!   agent runtime ([`crate::coordinator`]) moves each community agent's
//!   state into a job and exchanges p/s messages over `mpsc` channels, so
//!   jobs own everything they touch and no scoped lifetimes are needed.
//! - [`scoped_map`] / [`parallel_row_chunks`] — fork-join helpers built on
//!   `std::thread::scope` for data-parallel loops over *borrowed* data
//!   (dense matmul / SpMM row blocks, per-community W partials). Scoped
//!   threads let the closures borrow matrices without `Arc`-ing the world;
//!   the spawn cost (~tens of µs) only matters below the grain sizes the
//!   callers already guard against.
//!
//! Determinism: both helpers partition work by index and every output
//! element is written by exactly one thread with the same scalar math the
//! serial path uses, so parallel results are bitwise identical to serial
//! ones. Reductions are always folded on the caller's thread in index
//! order.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Resolve a user-facing thread count: 0 means "all available cores",
/// with a floor of 1.
pub fn resolve_threads(threads: usize) -> usize {
    if threads == 0 {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    } else {
        threads
    }
}

/// A small persistent worker pool for `'static` jobs.
pub struct Pool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl Pool {
    /// Spawn a pool with `threads` workers (at least 1).
    pub fn new(threads: usize) -> Pool {
        let threads = threads.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("cgcn-pool-{i}"))
                    .spawn(move || loop {
                        // Take the lock only to dequeue; run unlocked.
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // all senders dropped
                        }
                    })
                    .expect("spawning pool worker")
            })
            .collect();
        Pool {
            tx: Some(tx),
            workers,
        }
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Enqueue a job. Jobs must not panic the pool away: a panicking job
    /// kills its worker thread but the queue and remaining workers live on.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.tx
            .as_ref()
            .expect("pool already shut down")
            .send(Box::new(job))
            .expect("pool worker channel closed");
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Run `f(i)` for `i in 0..n` on up to `threads` scoped worker threads and
/// return the results in index order. `threads <= 1` or `n <= 1` degrades
/// to a plain serial map (no threads spawned). Work is distributed by an
/// atomic counter so uneven item costs balance out.
pub fn scoped_map<T, F>(threads: usize, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let t = threads.min(n);
    let counter = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, T)>();
    let fr = &f;
    let cr = &counter;
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    thread::scope(|s| {
        for _ in 0..t {
            let tx = tx.clone();
            s.spawn(move || loop {
                let i = cr.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                if tx.send((i, fr(i))).is_err() {
                    break;
                }
            });
        }
        drop(tx);
        for (i, v) in rx {
            out[i] = Some(v);
        }
    });
    out.into_iter()
        .map(|o| o.expect("scoped_map worker panicked"))
        .collect()
}

/// Split a row-major `rows × cols` output buffer into contiguous row
/// chunks, one per thread, and run `f(row_lo, row_hi, chunk)` on scoped
/// threads. With `threads <= 1` the single chunk runs on the caller's
/// thread. Each output row is written by exactly one invocation, so the
/// result is bitwise identical to the serial run of the same `f`.
pub fn parallel_row_chunks<F>(threads: usize, rows: usize, cols: usize, out: &mut [f32], f: F)
where
    F: Fn(usize, usize, &mut [f32]) + Sync,
{
    assert_eq!(out.len(), rows * cols, "output buffer shape mismatch");
    if threads <= 1 || rows <= 1 {
        f(0, rows, out);
        return;
    }
    let t = threads.min(rows);
    let chunk_rows = rows.div_ceil(t);
    let fr = &f;
    thread::scope(|s| {
        let mut rest = out;
        let mut lo = 0usize;
        while lo < rows {
            let hi = (lo + chunk_rows).min(rows);
            let (head, tail) = rest.split_at_mut((hi - lo) * cols);
            rest = tail;
            s.spawn(move || fr(lo, hi, head));
            lo = hi;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_jobs_and_shuts_down() {
        let pool = Pool::new(4);
        assert_eq!(pool.threads(), 4);
        let hits = Arc::new(AtomicU64::new(0));
        let (tx, rx) = mpsc::channel();
        for i in 0..32u64 {
            let hits = hits.clone();
            let tx = tx.clone();
            pool.execute(move || {
                hits.fetch_add(i, Ordering::Relaxed);
                tx.send(()).unwrap();
            });
        }
        drop(tx);
        for _ in 0..32 {
            rx.recv().unwrap();
        }
        assert_eq!(hits.load(Ordering::Relaxed), (0..32).sum::<u64>());
        drop(pool); // joins workers
    }

    #[test]
    fn scoped_map_is_ordered_and_complete() {
        for threads in [1usize, 2, 4, 8] {
            let got = scoped_map(threads, 37, |i| i * i);
            let want: Vec<usize> = (0..37).map(|i| i * i).collect();
            assert_eq!(got, want, "threads={threads}");
        }
        assert!(scoped_map(4, 0, |i| i).is_empty());
    }

    #[test]
    fn parallel_row_chunks_matches_serial() {
        let rows = 57;
        let cols = 13;
        let fill = |lo: usize, hi: usize, chunk: &mut [f32]| {
            for (ri, r) in (lo..hi).enumerate() {
                for c in 0..cols {
                    chunk[ri * cols + c] = (r * cols + c) as f32 * 0.5;
                }
            }
        };
        let mut serial = vec![0.0f32; rows * cols];
        fill(0, rows, &mut serial);
        for threads in [1usize, 2, 3, 8, 64] {
            let mut par = vec![0.0f32; rows * cols];
            parallel_row_chunks(threads, rows, cols, &mut par, fill);
            assert_eq!(par, serial, "threads={threads}");
        }
    }
}
