//! Binary wire format: length-prefixed little-endian encoding used by the
//! multi-process transport, the `.cgnp` dataset format and metric dumps.
//!
//! The encoding is deliberately boring: fixed-width LE integers, f32 slices
//! as raw bytes, strings as u32-length + UTF-8. Every message that crosses
//! an agent boundary goes through this module, which is also where
//! communication-volume accounting happens (the byte counts reported in the
//! Table-3 reproduction are measured here, not estimated).

use std::io::{self, Read, Write};

/// Append-only encoder.
#[derive(Default)]
pub struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    pub fn new() -> Self {
        Enc { buf: Vec::new() }
    }

    pub fn with_capacity(n: usize) -> Self {
        Enc {
            buf: Vec::with_capacity(n),
        }
    }

    pub fn u8(&mut self, v: u8) -> &mut Self {
        self.buf.push(v);
        self
    }
    pub fn u32(&mut self, v: u32) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }
    pub fn u64(&mut self, v: u64) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }
    pub fn f32(&mut self, v: f32) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }
    pub fn f64(&mut self, v: f64) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }
    pub fn str(&mut self, s: &str) -> &mut Self {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
        self
    }
    /// f32 slice: u64 length then raw LE bytes (bulk-copied).
    pub fn f32s(&mut self, xs: &[f32]) -> &mut Self {
        self.u64(xs.len() as u64);
        // Safe bulk copy: f32 -> 4 LE bytes each. On little-endian targets
        // this is a straight memcpy.
        self.buf.reserve(xs.len() * 4);
        for chunk in xs.chunks(4096) {
            for &x in chunk {
                self.buf.extend_from_slice(&x.to_le_bytes());
            }
        }
        self
    }
    pub fn u32s(&mut self, xs: &[u32]) -> &mut Self {
        self.u64(xs.len() as u64);
        for &x in xs {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
        self
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }
    pub fn bytes(&self) -> &[u8] {
        &self.buf
    }
}

/// Cursor-based decoder over a byte slice.
pub struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

#[derive(Debug)]
pub struct DecodeError {
    pub at: usize,
    pub what: &'static str,
}

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "wire decode error at byte {}: {}", self.at, self.what)
    }
}

impl std::error::Error for DecodeError {}

impl<'a> Dec<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Dec { buf, pos: 0 }
    }

    fn take(&mut self, n: usize, what: &'static str) -> Result<&'a [u8], DecodeError> {
        if self.pos + n > self.buf.len() {
            return Err(DecodeError { at: self.pos, what });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, DecodeError> {
        Ok(self.take(1, "u8")?[0])
    }
    pub fn u32(&mut self) -> Result<u32, DecodeError> {
        Ok(u32::from_le_bytes(self.take(4, "u32")?.try_into().unwrap()))
    }
    pub fn u64(&mut self) -> Result<u64, DecodeError> {
        Ok(u64::from_le_bytes(self.take(8, "u64")?.try_into().unwrap()))
    }
    pub fn f32(&mut self) -> Result<f32, DecodeError> {
        Ok(f32::from_le_bytes(self.take(4, "f32")?.try_into().unwrap()))
    }
    pub fn f64(&mut self) -> Result<f64, DecodeError> {
        Ok(f64::from_le_bytes(self.take(8, "f64")?.try_into().unwrap()))
    }
    pub fn str(&mut self) -> Result<String, DecodeError> {
        let n = self.u32()? as usize;
        let bytes = self.take(n, "str body")?;
        String::from_utf8(bytes.to_vec()).map_err(|_| DecodeError {
            at: self.pos,
            what: "invalid utf-8",
        })
    }
    /// Validate an untrusted element count against the bytes actually
    /// remaining, so a corrupted length field is a decode error — never
    /// a multiply overflow or a huge `Vec::with_capacity` panic.
    fn slice_len(&self, n: usize, what: &'static str) -> Result<usize, DecodeError> {
        n.checked_mul(4)
            .filter(|&bytes| bytes <= self.remaining())
            .ok_or(DecodeError { at: self.pos, what })
    }

    pub fn f32s(&mut self) -> Result<Vec<f32>, DecodeError> {
        let n = self.u64()? as usize;
        let nbytes = self.slice_len(n, "f32s length")?;
        let bytes = self.take(nbytes, "f32s body")?;
        let mut out = Vec::with_capacity(n);
        for c in bytes.chunks_exact(4) {
            out.push(f32::from_le_bytes(c.try_into().unwrap()));
        }
        Ok(out)
    }
    pub fn u32s(&mut self) -> Result<Vec<u32>, DecodeError> {
        let n = self.u64()? as usize;
        let nbytes = self.slice_len(n, "u32s length")?;
        let bytes = self.take(nbytes, "u32s body")?;
        let mut out = Vec::with_capacity(n);
        for c in bytes.chunks_exact(4) {
            out.push(u32::from_le_bytes(c.try_into().unwrap()));
        }
        Ok(out)
    }
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
    pub fn done(&self) -> bool {
        self.remaining() == 0
    }
}

/// Write a `[u32 length][payload]` frame to a stream (TCP transport).
pub fn write_frame<W: Write>(w: &mut W, payload: &[u8]) -> io::Result<()> {
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()
}

/// Read one `[u32 length][payload]` frame. Returns `None` on clean EOF.
pub fn read_frame<R: Read>(r: &mut R) -> io::Result<Option<Vec<u8>>> {
    read_frame_capped(r, u32::MAX as usize)
}

/// [`read_frame`] with a payload-size cap: a length prefix above
/// `max_len` is an `InvalidData` error *before* any allocation. Servers
/// reading from untrusted sockets must use this — a bare 4-byte
/// `0xFFFFFFFF` would otherwise make every handler allocate 4 GiB.
pub fn read_frame_capped<R: Read>(r: &mut R, max_len: usize) -> io::Result<Option<Vec<u8>>> {
    let mut len_buf = [0u8; 4];
    match r.read_exact(&mut len_buf) {
        Ok(()) => {}
        Err(e) if e.kind() == io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e),
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > max_len {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame length {len} exceeds cap {max_len}"),
        ));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    Ok(Some(payload))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_roundtrip() {
        let mut e = Enc::new();
        e.u8(7).u32(0xDEADBEEF).u64(1 << 40).f32(3.5).f64(-2.25).str("héllo");
        let bytes = e.into_bytes();
        let mut d = Dec::new(&bytes);
        assert_eq!(d.u8().unwrap(), 7);
        assert_eq!(d.u32().unwrap(), 0xDEADBEEF);
        assert_eq!(d.u64().unwrap(), 1 << 40);
        assert_eq!(d.f32().unwrap(), 3.5);
        assert_eq!(d.f64().unwrap(), -2.25);
        assert_eq!(d.str().unwrap(), "héllo");
        assert!(d.done());
    }

    #[test]
    fn slice_roundtrip() {
        let xs: Vec<f32> = (0..1000).map(|i| i as f32 * 0.5 - 3.0).collect();
        let idx: Vec<u32> = (0..64).map(|i| i * 3).collect();
        let mut e = Enc::new();
        e.f32s(&xs).u32s(&idx);
        let bytes = e.into_bytes();
        let mut d = Dec::new(&bytes);
        assert_eq!(d.f32s().unwrap(), xs);
        assert_eq!(d.u32s().unwrap(), idx);
        assert!(d.done());
    }

    #[test]
    fn truncation_is_an_error_not_a_panic() {
        let mut e = Enc::new();
        e.f32s(&[1.0, 2.0, 3.0]);
        let bytes = e.into_bytes();
        let mut d = Dec::new(&bytes[..bytes.len() - 2]);
        assert!(d.f32s().is_err());
    }

    #[test]
    fn corrupted_length_fields_error_not_panic() {
        // A length prefix far beyond the buffer (or overflowing n*4) must
        // be a decode error before any allocation happens.
        for n in [u64::MAX, u64::MAX / 4 + 1, 1 << 40] {
            let mut e = Enc::new();
            e.u64(n);
            let bytes = e.into_bytes();
            assert!(Dec::new(&bytes).f32s().is_err(), "f32s len {n}");
            assert!(Dec::new(&bytes).u32s().is_err(), "u32s len {n}");
        }
    }

    #[test]
    fn capped_frame_read_rejects_oversized_lengths() {
        let mut pipe: Vec<u8> = Vec::new();
        write_frame(&mut pipe, b"ok").unwrap();
        pipe.extend_from_slice(&u32::MAX.to_le_bytes()); // huge frame, no body
        let mut cur = std::io::Cursor::new(pipe);
        assert_eq!(read_frame_capped(&mut cur, 1024).unwrap().unwrap(), b"ok");
        let err = read_frame_capped(&mut cur, 1024).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
    }

    #[test]
    fn prop_random_streams_never_panic_and_respect_cap() {
        // Seeded random byte soup through the frame reader and every
        // slice decoder: errors are fine, panics and over-cap payloads
        // are not (a corrupted length prefix must be rejected *before*
        // any allocation larger than the cap).
        crate::util::proplite::check("wire_random_stream", 48, 0xF00D_CAFE, |g| {
            // usize_in respects the (small) size budget; scale it up so
            // streams span multiple frames.
            let n = g.usize_in(0, 64) * 37;
            let bytes: Vec<u8> = (0..n).map(|_| (g.rng.next_u64() & 0xFF) as u8).collect();
            let cap = 256usize;
            let mut cur = std::io::Cursor::new(&bytes);
            loop {
                match read_frame_capped(&mut cur, cap) {
                    Ok(Some(p)) if p.len() > cap => {
                        return Err(format!("payload {} exceeds cap {cap}", p.len()))
                    }
                    Ok(Some(_)) => {}
                    Ok(None) | Err(_) => break,
                }
            }
            let _ = Dec::new(&bytes).f32s();
            let _ = Dec::new(&bytes).u32s();
            let _ = Dec::new(&bytes).str();
            let mut d = Dec::new(&bytes);
            while d.u8().is_ok() {} // drain — must terminate without panic
            Ok(())
        });
    }

    #[test]
    fn prop_mutated_valid_frames_never_panic() {
        // Encode valid frames (scalars + slices), flip seeded bits across
        // the pipe, and re-read: the reader and decoders must never panic
        // and capped reads must never hand back an over-cap payload.
        crate::util::proplite::check("wire_mutated_frames", 48, 0xBEEF_5EED, |g| {
            let mut pipe: Vec<u8> = Vec::new();
            for fi in 0..3 {
                let mut e = Enc::new();
                e.u8(fi as u8).u32(fi as u32 * 7);
                let xs = g.vec_f32(g.usize_in(0, 40), 10.0);
                e.f32s(&xs);
                e.str("frame");
                write_frame(&mut pipe, e.bytes()).unwrap();
            }
            let flips = 1 + g.usize_in(0, 8);
            for _ in 0..flips {
                let i = g.rng.gen_range(pipe.len());
                pipe[i] ^= 1 << g.rng.gen_range(8);
            }
            let cap = 1 << 16;
            let mut cur = std::io::Cursor::new(&pipe);
            loop {
                match read_frame_capped(&mut cur, cap) {
                    Ok(Some(p)) => {
                        if p.len() > cap {
                            return Err(format!("payload {} exceeds cap {cap}", p.len()));
                        }
                        // Decode the mutated payload the way a worker
                        // would — errors allowed, panics not.
                        let mut d = Dec::new(&p);
                        let _ = d.u8();
                        let _ = d.u32();
                        let _ = d.f32s();
                        let _ = d.str();
                    }
                    Ok(None) | Err(_) => break,
                }
            }
            Ok(())
        });
    }

    #[test]
    fn frame_roundtrip_over_buffer() {
        let mut pipe: Vec<u8> = Vec::new();
        write_frame(&mut pipe, b"first").unwrap();
        write_frame(&mut pipe, b"").unwrap();
        write_frame(&mut pipe, &[9u8; 300]).unwrap();
        let mut cur = std::io::Cursor::new(pipe);
        assert_eq!(read_frame(&mut cur).unwrap().unwrap(), b"first");
        assert_eq!(read_frame(&mut cur).unwrap().unwrap(), b"");
        assert_eq!(read_frame(&mut cur).unwrap().unwrap(), vec![9u8; 300]);
        assert!(read_frame(&mut cur).unwrap().is_none());
    }
}
