//! Summary statistics used by the benchmark harness and metric reports.

/// Summary of a sample of measurements (e.g. per-epoch times in seconds).
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute a summary; panics on an empty sample.
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of on empty sample");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile(&sorted, 0.50),
            p95: percentile(&sorted, 0.95),
            p99: percentile(&sorted, 0.99),
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted sample, q in [0,1].
/// An empty sample yields 0.0 (telemetry scrapes may race an idle
/// recorder; a percentile query must never abort the process).
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    match sorted {
        [] => return 0.0,
        [only] => return *only,
        _ => {}
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Online mean/variance accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Online {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Online {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }
    pub fn n(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn var(&self) -> f64 {
        if self.n > 1 {
            self.m2 / (self.n - 1) as f64
        } else {
            0.0
        }
    }
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_known_values() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert!((percentile(&sorted, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile(&sorted, 0.0), 0.0);
        assert_eq!(percentile(&sorted, 1.0), 10.0);
    }

    #[test]
    fn percentile_edge_lengths() {
        // q ∈ {0, 0.5, 0.99, 1} on lengths 0, 1, 2: no panics, no
        // out-of-bounds, correct interpolation.
        let qs = [0.0, 0.5, 0.99, 1.0];
        for &q in &qs {
            assert_eq!(percentile(&[], q), 0.0);
            assert_eq!(percentile(&[7.5], q), 7.5);
        }
        let two = [2.0, 4.0];
        assert_eq!(percentile(&two, 0.0), 2.0);
        assert!((percentile(&two, 0.5) - 3.0).abs() < 1e-12);
        assert!((percentile(&two, 0.99) - 3.98).abs() < 1e-12);
        assert_eq!(percentile(&two, 1.0), 4.0);
        // Out-of-range q clamps rather than indexing out of bounds.
        assert_eq!(percentile(&two, -1.0), 2.0);
        assert_eq!(percentile(&two, 2.0), 4.0);
    }

    #[test]
    fn online_matches_batch() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut o = Online::default();
        for &x in &xs {
            o.push(x);
        }
        let s = Summary::of(&xs);
        assert!((o.mean() - s.mean).abs() < 1e-12);
        assert!((o.std() - s.std).abs() < 1e-12);
    }
}
