//! First-order optimizers (the paper's four comparison methods).
//!
//! Formulas follow the standard references: GD, Adagrad [Duchi'11],
//! Adadelta [Zeiler'12], Adam [Kingma & Ba'15]. Each is unit-tested against
//! hand-computed updates and on a quadratic convergence check.

use crate::tensor::Matrix;
use anyhow::{bail, Result};

/// Optimizer kind + hyper-parameters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Optimizer {
    /// Vanilla gradient descent (paper lr: 1e-1).
    Gd { lr: f32 },
    /// Adam (paper lr: 1e-3).
    Adam {
        lr: f32,
        beta1: f32,
        beta2: f32,
        eps: f32,
    },
    /// Adagrad (paper lr: 1e-3).
    Adagrad { lr: f32, eps: f32 },
    /// Adadelta (paper "lr" 1e-3 scales the update).
    Adadelta { lr: f32, rho: f32, eps: f32 },
}

/// Per-parameter optimizer state (first/second moment accumulators).
#[derive(Clone, Debug)]
pub struct OptState {
    pub m: Matrix,
    pub v: Matrix,
    pub t: u64,
}

impl OptState {
    pub fn new((rows, cols): (usize, usize)) -> OptState {
        OptState {
            m: Matrix::zeros(rows, cols),
            v: Matrix::zeros(rows, cols),
            t: 0,
        }
    }
}

impl Optimizer {
    /// Parse a CLI method name with the paper's default learning rate when
    /// `lr` is None/"auto".
    pub fn parse(name: &str, lr: Option<&str>) -> Result<Optimizer> {
        let lr_val = |default: f32| -> Result<f32> {
            match lr {
                None | Some("auto") | Some("") => Ok(default),
                Some(s) => Ok(s.parse::<f32>()?),
            }
        };
        Ok(match name {
            "gd" => Optimizer::Gd { lr: lr_val(1e-1)? },
            "adam" => Optimizer::Adam {
                lr: lr_val(1e-3)?,
                beta1: 0.9,
                beta2: 0.999,
                eps: 1e-8,
            },
            "adagrad" => Optimizer::Adagrad {
                lr: lr_val(1e-3)?,
                eps: 1e-10,
            },
            "adadelta" => Optimizer::Adadelta {
                lr: lr_val(1e-3)?,
                rho: 0.95,
                eps: 1e-6,
            },
            other => bail!("unknown optimizer '{other}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Optimizer::Gd { .. } => "gd",
            Optimizer::Adam { .. } => "adam",
            Optimizer::Adagrad { .. } => "adagrad",
            Optimizer::Adadelta { .. } => "adadelta",
        }
    }

    /// The configured learning rate (persisted in training checkpoints so
    /// a resumed run reconstructs the exact optimizer).
    pub fn lr(&self) -> f32 {
        match *self {
            Optimizer::Gd { lr }
            | Optimizer::Adam { lr, .. }
            | Optimizer::Adagrad { lr, .. }
            | Optimizer::Adadelta { lr, .. } => lr,
        }
    }

    /// Override the learning rate (checkpoint restore).
    pub fn set_lr(&mut self, new_lr: f32) {
        match self {
            Optimizer::Gd { lr }
            | Optimizer::Adam { lr, .. }
            | Optimizer::Adagrad { lr, .. }
            | Optimizer::Adadelta { lr, .. } => *lr = new_lr,
        }
    }

    /// In-place parameter update.
    pub fn apply(&self, w: &mut Matrix, grad: &Matrix, st: &mut OptState) {
        assert_eq!(w.shape(), grad.shape());
        st.t += 1;
        match *self {
            Optimizer::Gd { lr } => {
                w.axpy(-lr, grad);
            }
            Optimizer::Adam {
                lr,
                beta1,
                beta2,
                eps,
            } => {
                let bc1 = 1.0 - beta1.powi(st.t as i32);
                let bc2 = 1.0 - beta2.powi(st.t as i32);
                let wd = w.data_mut();
                let md = st.m.data_mut();
                let vd = st.v.data_mut();
                for i in 0..wd.len() {
                    let g = grad.data()[i];
                    md[i] = beta1 * md[i] + (1.0 - beta1) * g;
                    vd[i] = beta2 * vd[i] + (1.0 - beta2) * g * g;
                    let mhat = md[i] / bc1;
                    let vhat = vd[i] / bc2;
                    wd[i] -= lr * mhat / (vhat.sqrt() + eps);
                }
            }
            Optimizer::Adagrad { lr, eps } => {
                let wd = w.data_mut();
                let vd = st.v.data_mut();
                for i in 0..wd.len() {
                    let g = grad.data()[i];
                    vd[i] += g * g;
                    wd[i] -= lr * g / (vd[i].sqrt() + eps);
                }
            }
            Optimizer::Adadelta { lr, rho, eps } => {
                // m = E[g²], v = E[Δ²].
                let wd = w.data_mut();
                let md = st.m.data_mut();
                let vd = st.v.data_mut();
                for i in 0..wd.len() {
                    let g = grad.data()[i];
                    md[i] = rho * md[i] + (1.0 - rho) * g * g;
                    let dx = -((vd[i] + eps).sqrt() / (md[i] + eps).sqrt()) * g;
                    vd[i] = rho * vd[i] + (1.0 - rho) * dx * dx;
                    wd[i] += lr * dx;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quad_converges(opt: Optimizer, iters: usize, lr_scale_tol: f32) {
        // Minimise f(w) = ||w - 3||²/2 elementwise; grad = w - 3.
        let mut w = Matrix::from_vec(2, 2, vec![0.0, 10.0, -5.0, 3.0]);
        let mut st = OptState::new((2, 2));
        for _ in 0..iters {
            let grad = w.map(|x| x - 3.0);
            opt.apply(&mut w, &grad, &mut st);
        }
        for &x in w.data() {
            assert!(
                (x - 3.0).abs() < lr_scale_tol,
                "{opt:?} did not converge: {x}"
            );
        }
    }

    #[test]
    fn gd_known_step() {
        let opt = Optimizer::Gd { lr: 0.5 };
        let mut w = Matrix::from_vec(1, 2, vec![1.0, 2.0]);
        let g = Matrix::from_vec(1, 2, vec![0.2, -0.4]);
        let mut st = OptState::new((1, 2));
        opt.apply(&mut w, &g, &mut st);
        assert_eq!(w.data(), &[0.9, 2.2]);
    }

    #[test]
    fn adam_first_step_is_lr_sized() {
        // After one step, |Δw| ≈ lr regardless of gradient scale.
        let opt = Optimizer::parse("adam", None).unwrap();
        for scale in [1e-3f32, 1.0, 1e3] {
            let mut w = Matrix::zeros(1, 1);
            let g = Matrix::from_vec(1, 1, vec![scale]);
            let mut st = OptState::new((1, 1));
            opt.apply(&mut w, &g, &mut st);
            assert!(
                (w.data()[0].abs() - 1e-3).abs() < 1e-5,
                "scale {scale}: step {}",
                w.data()[0]
            );
        }
    }

    #[test]
    fn adagrad_accumulates_and_decays_step() {
        let opt = Optimizer::Adagrad { lr: 1.0, eps: 0.0 };
        let mut w = Matrix::zeros(1, 1);
        let g = Matrix::from_vec(1, 1, vec![2.0]);
        let mut st = OptState::new((1, 1));
        opt.apply(&mut w, &g, &mut st);
        // v = 4, step = 1 * 2/2 = 1.
        assert!((w.data()[0] + 1.0).abs() < 1e-6);
        opt.apply(&mut w, &g, &mut st);
        // v = 8, step = 2/sqrt(8).
        assert!((w.data()[0] + 1.0 + 2.0 / 8f32.sqrt()).abs() < 1e-6);
    }

    #[test]
    fn all_optimizers_converge_on_quadratic() {
        quad_converges(Optimizer::Gd { lr: 0.1 }, 200, 1e-3);
        quad_converges(Optimizer::parse("adam", Some("0.1")).unwrap(), 800, 2e-2);
        quad_converges(Optimizer::Adagrad { lr: 2.0, eps: 1e-10 }, 2000, 5e-2);
        quad_converges(
            Optimizer::Adadelta {
                lr: 1.0,
                rho: 0.95,
                eps: 1e-6,
            },
            3000,
            5e-2,
        );
    }

    #[test]
    fn lr_roundtrips_through_accessors() {
        for name in ["gd", "adam", "adagrad", "adadelta"] {
            let mut opt = Optimizer::parse(name, None).unwrap();
            opt.set_lr(0.0625);
            assert_eq!(opt.lr(), 0.0625, "{name}");
            // Reconstructing from (name, lr) — the checkpoint restore
            // path — yields the identical optimizer.
            let mut back = Optimizer::parse(name, None).unwrap();
            back.set_lr(opt.lr());
            assert_eq!(back, opt, "{name}");
        }
    }

    #[test]
    fn parse_defaults_match_paper() {
        assert_eq!(
            Optimizer::parse("gd", None).unwrap(),
            Optimizer::Gd { lr: 0.1 }
        );
        match Optimizer::parse("adam", None).unwrap() {
            Optimizer::Adam { lr, .. } => assert_eq!(lr, 1e-3),
            _ => unreachable!(),
        }
        assert!(Optimizer::parse("sgd-nope", None).is_err());
    }
}
