//! Backprop GCN training: the paper's four full-batch comparison methods
//! (GD, Adam, Adagrad, Adadelta — Figure 2) plus the stochastic community
//! mini-batch engine ([`ClusterGcnTrainer`], Cluster-GCN path).
//!
//! Gradients flow through the same [`ComputeBackend`] kernels + SpMM
//! pipeline as the ADMM trainer (see python/compile/model.py `bp_*`
//! entries for the kernel spec); the optimizers themselves run host-side
//! (they're O(params), off the roofline). Paper learning rates: 1e-3 for
//! Adam/Adagrad/Adadelta, 1e-1 for GD.

mod cluster_gcn;
mod optim;

pub use cluster_gcn::{ClusterGcnOptions, ClusterGcnTrainer};
pub use optim::{OptState, Optimizer};

use crate::coordinator::checkpoint::{CheckpointSink, CkptState};
use crate::coordinator::clock::timed;
use crate::coordinator::{evaluate_forward, Workspace};
use crate::metrics::{EpochRecord, RunReport};
use crate::runtime::ComputeBackend;
use crate::serve::{ModelSnapshot, SnapshotMeta};
use crate::tensor::Matrix;
use crate::util::rng::Rng;
use anyhow::{ensure, Result};
use std::sync::Arc;
use std::time::Instant;

/// Full-batch backprop trainer for the 2-layer GCN (paper's baseline
/// architecture; deeper nets are supported by the ADMM path only, matching
/// the paper's experiments).
pub struct BaselineTrainer {
    ws: Arc<Workspace>,
    backend: Arc<dyn ComputeBackend>,
    opt: Optimizer,
    w: Vec<Matrix>,
    opt_state: Vec<OptState>,
}

impl BaselineTrainer {
    pub fn new(
        ws: Arc<Workspace>,
        backend: Arc<dyn ComputeBackend>,
        opt: Optimizer,
    ) -> Result<BaselineTrainer> {
        ensure!(
            ws.layers == 2,
            "baseline trainer supports the paper's 2-layer GCN (got L={})",
            ws.layers
        );
        let mut rng = Rng::new(ws.hp.seed);
        let dims = ws.dims.clone();
        let w: Vec<Matrix> = (1..=ws.layers)
            .map(|l| Matrix::glorot(dims[l - 1], dims[l], &mut rng))
            .collect();
        let opt_state = w.iter().map(|wl| OptState::new(wl.shape())).collect();
        Ok(BaselineTrainer {
            ws,
            backend,
            opt,
            w,
            opt_state,
        })
    }

    /// One full-batch training step; returns the loss.
    pub fn step(&mut self) -> Result<f64> {
        let ws = &self.ws;
        let backend = &*self.backend;

        // Forward: Z1 = f(H0 W1); H1 = Ã Z1.
        let z1 = backend.fwd_relu(&ws.h0_glob, &self.w[0])?;
        let h1 = backend.spmm(&ws.a_glob, &z1);

        // Head: loss + dW2 + dH1.
        let (loss, dw2, dh1) =
            backend.bp_out_grads(&h1, &self.w[1], &ws.y_glob, &ws.train_mask_glob, ws.denom)?;

        // dZ1 = Ãᵀ dH1 = Ã dH1 (symmetric), then the hidden tail.
        let dz1 = backend.spmm(&ws.a_glob, &dh1);
        let dw1 = backend.bp_hidden_grads(&ws.h0_glob, &self.w[0], &dz1)?;

        self.opt.apply(&mut self.w[0], &dw1, &mut self.opt_state[0]);
        self.opt.apply(&mut self.w[1], &dw2, &mut self.opt_state[1]);
        Ok(loss as f64)
    }

    pub fn evaluate(&self) -> Result<(f64, f64, f64)> {
        evaluate_forward(&self.ws, &*self.backend, &self.w)
    }

    pub fn train(&mut self, epochs: usize) -> Result<RunReport> {
        self.train_range(0, epochs, None)
    }

    /// Run epochs `start..epochs` (resume support), optionally writing a
    /// `.cgck` checkpoint at the sink interval. The optimizer slots and
    /// step counters persist with the weights, so a resumed run repeats
    /// the uninterrupted float sequence exactly.
    pub fn train_range(
        &mut self,
        start: usize,
        epochs: usize,
        sink: Option<&CheckpointSink>,
    ) -> Result<RunReport> {
        let label = self.opt.name();
        let mut report = RunReport::new(label, &format!("n{}", self.ws.n), 1);
        for e in start..epochs {
            let wall0 = Instant::now();
            let (loss, secs) = timed(|| self.step());
            let loss = loss?;
            let wall = wall0.elapsed().as_secs_f64();
            let (train_acc, test_acc, _) = self.evaluate()?;
            log::debug!(
                "[{label}] epoch {e}: loss={loss:.4} train={train_acc:.3} test={test_acc:.3}"
            );
            report.push(EpochRecord {
                epoch: e,
                train_acc,
                test_acc,
                loss,
                t_train: secs,
                t_comm: 0.0,
                t_wall: wall,
                bytes: 0,
            });
            if let Some(sink) = sink {
                sink.maybe_write(e + 1, || self.checkpoint_state())?;
            }
        }
        Ok(report)
    }

    pub fn weights(&self) -> &[Matrix] {
        &self.w
    }

    /// Capture the resumable state (weights + optimizer slots).
    fn checkpoint_state(&self) -> CkptState {
        CkptState::Baseline {
            opt: self.opt.name().to_string(),
            lr: self.opt.lr(),
            w: self.w.clone(),
            m: self.opt_state.iter().map(|s| s.m.clone()).collect(),
            v: self.opt_state.iter().map(|s| s.v.clone()).collect(),
            t: self.opt_state.iter().map(|s| s.t).collect(),
        }
    }

    /// Restore weights + optimizer slots from a checkpoint; shape-checked
    /// so a stale checkpoint errs instead of corrupting training.
    pub fn restore_state(&mut self, w: Vec<Matrix>, st: Vec<OptState>) -> Result<()> {
        ensure!(
            w.len() == self.w.len() && st.len() == self.w.len(),
            "checkpoint has {} weight layers, trainer expects {}",
            w.len(),
            self.w.len()
        );
        for (li, (wl, cur)) in w.iter().zip(&self.w).enumerate() {
            ensure!(
                wl.shape() == cur.shape(),
                "checkpoint W_{} shape {:?} != {:?}",
                li + 1,
                wl.shape(),
                cur.shape()
            );
            ensure!(
                st[li].m.shape() == cur.shape() && st[li].v.shape() == cur.shape(),
                "checkpoint optimizer slots for W_{} have wrong shape",
                li + 1
            );
        }
        self.w = w;
        self.opt_state = st;
        Ok(())
    }

    /// Snapshot the current weights to a `.cgnm` file (`train --save`);
    /// reload with [`crate::serve::load_model`] and serve with
    /// [`crate::serve::InferenceSession`].
    pub fn save_model(&self, path: &std::path::Path, meta: SnapshotMeta) -> Result<()> {
        ModelSnapshot::capture(meta, &self.ws, &self.w)?.save(path)
    }
}
