//! Stochastic community mini-batch GCN training (Cluster-GCN path).
//!
//! Cluster-GCN [Chiang et al. '19, 1905.07953] observes that the same
//! community structure the paper exploits for distributed ADMM also
//! supports *memory-bounded stochastic training*: partition `G` into many
//! small clusters, and each step trains full GCN propagation on the
//! subgraph induced by a random group of `q` clusters. Multi-cluster
//! batching keeps between-cluster edges *within the batch*, which repairs
//! most of the edges a single-cluster batch would drop, while every dense
//! *training activation* (forward and gradient) is bounded by the batch's
//! node count — the full-batch baselines can never bound those below the
//! global row count. (The trainer still holds the full-graph [`Workspace`]
//! for per-epoch evaluation and snapshotting, so resident memory remains
//! O(n); it is the per-step activation working set that stops scaling
//! with the graph.)
//!
//! Concretely, per step over batch `B` (the union of `q` clusters):
//!
//! ```text
//! Ã_B  = (D_B + I)^{-1/2} (A_B + I) (D_B + I)^{-1/2}   (induced, renormalised)
//! H0_B = Ã_B X_B;   Z1 = f(H0_B W1);   H1 = Ã_B Z1;   logits = H1 W2
//! loss = masked-mean CE over B's labeled nodes (denom = |B ∩ train|)
//! ```
//!
//! Forward/backward runs through the exact [`ComputeBackend`] kernels the
//! full-batch baselines use (`spmm`, `fwd_relu`, `bp_out_grads`,
//! `bp_hidden_grads`), with Adam (or any [`Optimizer`]) applying the
//! updates; evaluation is the standard full-graph forward pass, so
//! accuracies are directly comparable to the GCN baseline and ADMM.
//!
//! Determinism: the fine partition, the weight init and the per-epoch
//! cluster shuffle are all driven by `hp.seed`, so the same seed yields
//! identical cluster groupings and bitwise-identical training.
//!
//! Under `--runtime shared` the trainer pipelines batch *preparation*
//! (induced-subgraph extraction, feature/label row gathers) onto the
//! shared [`Runtime`]: while step `i`'s kernels run on the caller, a
//! runtime task materialises batch `i+1`. [`prepare_batch`] is a pure
//! function of the node set and prepared batches are consumed strictly
//! in schedule order, so the weight stream stays bitwise-identical to
//! the serial loop — the pipeline changes *when* a batch is built,
//! never what it contains or the order steps apply.

use super::{OptState, Optimizer};
use crate::coordinator::checkpoint::{CheckpointSink, CkptState};
use crate::coordinator::clock::timed;
use crate::coordinator::{evaluate_forward, Workspace};
use crate::data::Dataset;
use crate::graph::{induced_subgraph_with, InducedSubgraph};
use crate::metrics::{EpochRecord, RunReport};
use crate::partition::{self, Method, Partition};
use crate::runtime::ComputeBackend;
use crate::serve::{ModelSnapshot, SnapshotMeta};
use crate::tensor::Matrix;
use crate::util::pool::Runtime;
use crate::util::rng::Rng;
use anyhow::{anyhow, ensure, Result};
use std::collections::BTreeMap;
use std::sync::{mpsc, Arc};
use std::time::Instant;

/// Mini-batch engine configuration.
#[derive(Clone, Copy, Debug)]
pub struct ClusterGcnOptions {
    /// Fine partition count `c` (clamped to the node count). Many small
    /// clusters → small batches → low peak memory; the METIS objective
    /// keeps each cluster dense so few edges are lost per batch.
    pub clusters: usize,
    /// Clusters grouped per step `q` (Cluster-GCN's stochastic multiple
    /// partitions). Batch size ≈ `q/c · n`.
    pub batch_clusters: usize,
    /// Partitioner for the fine clusters.
    pub method: Method,
}

impl Default for ClusterGcnOptions {
    fn default() -> Self {
        // c=32, q=8 (quarter-graph batches from fine clusters): the sweet
        // spot in BENCH_minibatch.json — matches the full-batch accuracy
        // trajectory while bounding activations to ~n/4 rows. Coarser
        // clusterings at the same q/c ratio (e.g. 8/2) lose accuracy:
        // finer clusters re-mix more cross-cluster edges per epoch,
        // which is Cluster-GCN's stochastic-multiple-partitions argument.
        ClusterGcnOptions {
            clusters: 32,
            batch_clusters: 8,
            method: Method::Metis,
        }
    }
}

impl ClusterGcnOptions {
    /// Read `--clusters`, `--batch-clusters` and `--partition` from CLI
    /// args. Undeclared keys fall back to the defaults (so library
    /// callers with partial arg specs keep working); declared-but-invalid
    /// values exit with a CLI error like the other typed getters.
    pub fn from_args(args: &crate::util::cli::Args) -> ClusterGcnOptions {
        let d = ClusterGcnOptions::default();
        let get = |key: &str, dflt: usize| -> usize {
            match args.get(key) {
                None => dflt,
                Some(raw) => match raw.parse::<usize>() {
                    Ok(v) if v > 0 => v,
                    _ => {
                        eprintln!(
                            "error: invalid value for --{key}: {raw:?} (want a positive integer)"
                        );
                        std::process::exit(2);
                    }
                },
            }
        };
        ClusterGcnOptions {
            clusters: get("clusters", d.clusters),
            batch_clusters: get("batch-clusters", d.batch_clusters),
            method: args
                .get("partition")
                .and_then(Method::parse)
                .unwrap_or(d.method),
        }
    }
}

/// Stochastic community mini-batch trainer for the 2-layer GCN.
///
/// Holds the *original-order* dataset for batch extraction (batches are
/// induced subgraphs of the raw graph) alongside the community-major
/// [`Workspace`] used for full-graph evaluation and `.cgnm` snapshots —
/// the snapshot is identical in kind to the full-batch trainers', so
/// `serve`/`query --verify` accept it unchanged.
pub struct ClusterGcnTrainer {
    ws: Arc<Workspace>,
    ds: Arc<Dataset>,
    backend: Arc<dyn ComputeBackend>,
    opt: Optimizer,
    /// Fine cluster partition (original node ids; members sorted).
    fine: Partition,
    batch_clusters: usize,
    w: Vec<Matrix>,
    opt_state: Vec<OptState>,
    /// Per-epoch cluster-shuffle stream (forked off the init stream so
    /// weight init stays identical to the full-batch baselines).
    rng: Rng,
    /// Reusable global→local map for induced-subgraph extraction (all
    /// `u32::MAX` between batches), keeping per-step map work O(|B|).
    scratch: Vec<u32>,
    /// Largest batch node count seen — the per-step dense-activation row
    /// bound reported by the mini-batch bench.
    peak_batch_nodes: usize,
}

impl ClusterGcnTrainer {
    pub fn new(
        ds: Arc<Dataset>,
        ws: Arc<Workspace>,
        backend: Arc<dyn ComputeBackend>,
        opt: Optimizer,
        opts: ClusterGcnOptions,
    ) -> Result<ClusterGcnTrainer> {
        ensure!(
            ws.layers == 2,
            "cluster-gcn trainer supports the paper's 2-layer GCN (got L={})",
            ws.layers
        );
        ensure!(ds.n() == ws.n, "dataset/workspace node count mismatch");
        let clusters = opts.clusters.clamp(1, ds.n());
        let batch_clusters = opts.batch_clusters.clamp(1, clusters);
        let fine = partition::partition(&ds.graph, clusters, opts.method, ws.hp.seed);

        // Same init stream as BaselineTrainer: identical starting weights
        // make the accuracy-trajectory comparison apples-to-apples.
        let mut rng = Rng::new(ws.hp.seed);
        let dims = ws.dims.clone();
        let w: Vec<Matrix> = (1..=ws.layers)
            .map(|l| Matrix::glorot(dims[l - 1], dims[l], &mut rng))
            .collect();
        let opt_state = w.iter().map(|wl| OptState::new(wl.shape())).collect();
        let batch_rng = rng.fork(0xC1B5);
        let scratch = vec![u32::MAX; ds.n()];
        Ok(ClusterGcnTrainer {
            ws,
            ds,
            backend,
            opt,
            fine,
            batch_clusters,
            w,
            opt_state,
            rng: batch_rng,
            scratch,
            peak_batch_nodes: 0,
        })
    }

    /// Number of fine clusters `c`.
    pub fn num_clusters(&self) -> usize {
        self.fine.m()
    }

    /// Largest batch (node count) processed so far — every dense
    /// activation in a step has exactly this many rows at peak.
    pub fn peak_batch_nodes(&self) -> usize {
        self.peak_batch_nodes
    }

    /// Draw one epoch's batch schedule: shuffle the cluster ids and chunk
    /// them into groups of `q`. Every cluster is visited exactly once per
    /// epoch (sampling without replacement, as in Cluster-GCN).
    pub fn epoch_groups(&mut self) -> Vec<Vec<usize>> {
        let mut order: Vec<usize> = (0..self.fine.m()).collect();
        self.rng.shuffle(&mut order);
        order
            .chunks(self.batch_clusters)
            .map(|c| c.to_vec())
            .collect()
    }

    /// The sorted node union of a cluster group — one batch.
    pub fn batch_nodes(&self, group: &[usize]) -> Vec<usize> {
        let mut nodes: Vec<usize> = group
            .iter()
            .flat_map(|&c| self.fine.members[c].iter().copied())
            .collect();
        // Cluster member lists are sorted and disjoint, so a sort is
        // enough to produce the sorted unique batch order.
        nodes.sort_unstable();
        nodes
    }

    /// One mini-batch training step over a prepared batch. Returns
    /// `Some((loss, labeled))` or `None` when the batch holds no labeled
    /// node (no gradient — skipped, as in the reference implementations).
    fn step_prepared(&mut self, prep: PreparedBatch) -> Result<Option<(f32, f32)>> {
        let _span = crate::span!("cluster_gcn.step", batch_nodes = prep.nb);
        let Some((sub, x_b, y_b)) = prep.data else {
            return Ok(None);
        };
        // Recorded only for batches that allocate activations — skipped
        // label-free batches never build them, so they don't set the
        // measured peak.
        self.peak_batch_nodes = self.peak_batch_nodes.max(prep.nb);

        let backend = &*self.backend;
        // Forward: H0 = Ã_B X_B; Z1 = f(H0 W1); H1 = Ã_B Z1.
        let h0 = backend.spmm(&sub.a_norm, &x_b);
        let z1 = backend.fwd_relu(&h0, &self.w[0])?;
        let h1 = backend.spmm(&sub.a_norm, &z1);

        // Head: loss + dW2 + dH1 with the batch-local denominator.
        let (loss, dw2, dh1) =
            backend.bp_out_grads(&h1, &self.w[1], &y_b, &prep.mask_b, prep.denom_b)?;

        // dZ1 = Ã_Bᵀ dH1 = Ã_B dH1 (symmetric), then the hidden tail.
        let dz1 = backend.spmm(&sub.a_norm, &dh1);
        let dw1 = backend.bp_hidden_grads(&h0, &self.w[0], &dz1)?;

        self.opt.apply(&mut self.w[0], &dw1, &mut self.opt_state[0]);
        self.opt.apply(&mut self.w[1], &dw2, &mut self.opt_state[1]);
        Ok(Some((loss, prep.denom_b)))
    }

    /// One epoch: every cluster visited once in random `q`-groups.
    /// Returns the label-count-weighted mean loss (comparable to the
    /// full-batch per-epoch loss: each labeled node contributes once).
    ///
    /// When the backend exposes a shared [`Runtime`], batch preparation
    /// is pipelined one step ahead on it; either path yields bitwise-
    /// identical weights (see the module docs).
    pub fn train_epoch(&mut self) -> Result<f64> {
        let _span = crate::span!("cluster_gcn.epoch");
        crate::obs_counter!("cluster_gcn.epochs").inc();
        let groups = self.epoch_groups();
        let (loss_sum, denom_sum) = match self.backend.runtime().cloned() {
            Some(rt) if groups.len() > 1 => self.epoch_pipelined(&rt, &groups)?,
            _ => self.epoch_serial(&groups)?,
        };
        Ok(loss_sum / denom_sum.max(1.0))
    }

    /// In-order epoch loop: prepare and train each batch on the caller.
    fn epoch_serial(&mut self, groups: &[Vec<usize>]) -> Result<(f64, f64)> {
        let mut loss_sum = 0.0f64;
        let mut denom_sum = 0.0f64;
        for group in groups {
            let nodes = self.batch_nodes(group);
            let ds = Arc::clone(&self.ds);
            let prep = prepare_batch(&ds, &nodes, &mut self.scratch);
            if let Some((loss, denom)) = self.step_prepared(prep)? {
                loss_sum += loss as f64 * denom as f64;
                denom_sum += denom as f64;
            }
        }
        Ok((loss_sum, denom_sum))
    }

    /// Pipelined epoch on the shared runtime: batch `i+1`'s subgraph
    /// extraction and row gathers run as a runtime task while the
    /// caller executes step `i`'s kernels on the same worker set.
    /// Prepared batches are consumed strictly in schedule order, so the
    /// weight stream is bitwise-identical to [`Self::epoch_serial`].
    fn epoch_pipelined(
        &mut self,
        rt: &Arc<Runtime>,
        groups: &[Vec<usize>],
    ) -> Result<(f64, f64)> {
        // Two recycled scratch maps bound the prep window to depth 2
        // (one batch in flight while one is consumed): enough to hide
        // prep latency behind the kernels, while pipeline memory stays
        // at two materialised batches regardless of the schedule.
        let n = self.ds.n();
        let mut free: Vec<Vec<u32>> =
            vec![std::mem::take(&mut self.scratch), vec![u32::MAX; n]];
        let (tx, rx) = mpsc::channel::<(usize, PreparedBatch, Vec<u32>)>();
        let mut ready: BTreeMap<usize, PreparedBatch> = BTreeMap::new();
        let mut next_submit = 0usize;
        let mut loss_sum = 0.0f64;
        let mut denom_sum = 0.0f64;
        for next_consume in 0..groups.len() {
            while next_submit < groups.len() {
                let Some(mut scratch) = free.pop() else { break };
                if scratch.len() != n {
                    scratch = vec![u32::MAX; n];
                }
                let nodes = self.batch_nodes(&groups[next_submit]);
                let ds = Arc::clone(&self.ds);
                let tx = tx.clone();
                let idx = next_submit;
                rt.execute(move || {
                    let prep = prepare_batch(&ds, &nodes, &mut scratch);
                    // The receiver is gone when the epoch aborted early;
                    // dropping the result is fine then.
                    let _ = tx.send((idx, prep, scratch));
                });
                next_submit += 1;
            }
            let prep = loop {
                if let Some(p) = ready.remove(&next_consume) {
                    break p;
                }
                // A closed channel means a prep task died without
                // sending — the runtime logs the panic; surface it here
                // instead of deadlocking on a batch that never arrives.
                let (idx, prep, scratch) = rx
                    .recv()
                    .map_err(|_| anyhow!("mini-batch prep task panicked"))?;
                free.push(scratch);
                ready.insert(idx, prep);
            };
            if let Some((loss, denom)) = self.step_prepared(prep)? {
                loss_sum += loss as f64 * denom as f64;
                denom_sum += denom as f64;
            }
        }
        // Hand one map back for the next epoch / serial fallback. On an
        // error path `self.scratch` stays empty and `prepare_batch`'s
        // size guard re-materialises it on next use.
        self.scratch = free.pop().unwrap_or_default();
        Ok((loss_sum, denom_sum))
    }

    /// Full-graph evaluation (train acc, test acc, loss) — identical to
    /// the full-batch baselines' evaluation path.
    pub fn evaluate(&self) -> Result<(f64, f64, f64)> {
        evaluate_forward(&self.ws, &*self.backend, &self.w)
    }

    pub fn train(&mut self, epochs: usize) -> Result<RunReport> {
        self.train_range(0, epochs, None)
    }

    /// Run epochs `start..epochs` (resume support), optionally writing a
    /// `.cgck` checkpoint at the sink interval. Checkpoints capture the
    /// batch-shuffle RNG *after* each epoch's draws, so a resumed run
    /// continues the exact shuffle stream — same groupings, bitwise-same
    /// weights as an uninterrupted run.
    pub fn train_range(
        &mut self,
        start: usize,
        epochs: usize,
        sink: Option<&CheckpointSink>,
    ) -> Result<RunReport> {
        let mut report = RunReport::new(
            "cluster-gcn",
            &format!("n{}", self.ws.n),
            self.num_clusters(),
        );
        for e in start..epochs {
            let wall0 = Instant::now();
            let (loss, secs) = timed(|| self.train_epoch());
            let loss = loss?;
            let wall = wall0.elapsed().as_secs_f64();
            let (train_acc, test_acc, _) = self.evaluate()?;
            log::debug!(
                "[cluster-gcn c={} q={}] epoch {e}: loss={loss:.4} train={train_acc:.3} test={test_acc:.3} peak_batch={}",
                self.num_clusters(),
                self.batch_clusters,
                self.peak_batch_nodes
            );
            report.push(EpochRecord {
                epoch: e,
                train_acc,
                test_acc,
                loss,
                t_train: secs,
                t_comm: 0.0,
                t_wall: wall,
                bytes: 0,
            });
            if let Some(sink) = sink {
                sink.maybe_write(e + 1, || self.checkpoint_state())?;
            }
        }
        Ok(report)
    }

    pub fn weights(&self) -> &[Matrix] {
        &self.w
    }

    /// Capture the resumable state.
    fn checkpoint_state(&self) -> CkptState {
        CkptState::ClusterGcn {
            opt: self.opt.name().to_string(),
            lr: self.opt.lr(),
            clusters: self.num_clusters() as u32,
            batch_clusters: self.batch_clusters as u32,
            rng: self.rng.state(),
            peak: self.peak_batch_nodes as u64,
            w: self.w.clone(),
            m: self.opt_state.iter().map(|s| s.m.clone()).collect(),
            v: self.opt_state.iter().map(|s| s.v.clone()).collect(),
            t: self.opt_state.iter().map(|s| s.t).collect(),
        }
    }

    /// Restore weights, optimizer slots, shuffle RNG and the measured
    /// batch peak from a checkpoint (shape-checked).
    pub fn restore_state(
        &mut self,
        w: Vec<Matrix>,
        st: Vec<OptState>,
        rng: [u64; 4],
        peak: usize,
    ) -> Result<()> {
        ensure!(
            w.len() == self.w.len() && st.len() == self.w.len(),
            "checkpoint has {} weight layers, trainer expects {}",
            w.len(),
            self.w.len()
        );
        for (li, (wl, cur)) in w.iter().zip(&self.w).enumerate() {
            ensure!(
                wl.shape() == cur.shape()
                    && st[li].m.shape() == cur.shape()
                    && st[li].v.shape() == cur.shape(),
                "checkpoint state for W_{} has wrong shape",
                li + 1
            );
        }
        self.w = w;
        self.opt_state = st;
        self.rng = Rng::from_state(rng);
        self.peak_batch_nodes = peak;
        Ok(())
    }

    /// Snapshot the current weights to a `.cgnm` file (`train --save`);
    /// the snapshot is served exactly like a full-batch one.
    pub fn save_model(&self, path: &std::path::Path, meta: SnapshotMeta) -> Result<()> {
        ModelSnapshot::capture(meta, &self.ws, &self.w)?.save(path)
    }
}

/// A fully materialised mini-batch: everything [`ClusterGcnTrainer::step_prepared`]
/// needs, built by [`prepare_batch`] as a pure function of the node set
/// so it can run ahead on the shared runtime while the previous step
/// trains.
struct PreparedBatch {
    /// Batch node count (rows of every dense activation in the step).
    nb: usize,
    /// Per-node train-mask slice (the loss mask in batch-local order).
    mask_b: Vec<f32>,
    /// Labeled-node count — the batch-local loss denominator.
    denom_b: f32,
    /// Induced subgraph, gathered feature rows and one-hot labels.
    /// `None` when the batch holds no labeled node: the step is skipped
    /// and no activations are built, matching the serial fast path.
    data: Option<(InducedSubgraph, Matrix, Matrix)>,
}

/// Materialise one mini-batch: mask/denominator, renormalised induced
/// subgraph, feature row gather and one-hot labels. Deterministic in
/// `nodes` alone — no RNG, no shared mutable state — which is what lets
/// the pipelined epoch run it ahead of schedule without perturbing the
/// weight stream. A wrong-sized (or stolen) scratch map is
/// re-materialised in place, so callers may hand over an empty vector.
fn prepare_batch(ds: &Dataset, nodes: &[usize], scratch: &mut Vec<u32>) -> PreparedBatch {
    let _span = crate::span!("cluster_gcn.prep", batch_nodes = nodes.len());
    if scratch.len() != ds.n() {
        *scratch = vec![u32::MAX; ds.n()];
    }
    let nb = nodes.len();
    let mask_b: Vec<f32> = nodes.iter().map(|&v| ds.train_mask[v]).collect();
    let denom_b: f32 = mask_b.iter().sum();
    if denom_b <= 0.0 {
        return PreparedBatch { nb, mask_b, denom_b, data: None };
    }
    let sub = induced_subgraph_with(&ds.graph, nodes, scratch);
    let x_b = ds.features.gather_rows(nodes);
    let mut y_b = Matrix::zeros(nb, ds.num_classes);
    for (i, &v) in nodes.iter().enumerate() {
        y_b.set(i, ds.labels[v], 1.0);
    }
    PreparedBatch {
        nb,
        mask_b,
        denom_b,
        data: Some((sub, x_b, y_b)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HyperParams;
    use crate::runtime::NativeBackend;

    fn mk(seed: u64, clusters: usize, q: usize) -> ClusterGcnTrainer {
        let ds = Arc::new(crate::data::fixtures::caveman(24, 3));
        let mut hp = HyperParams::for_dataset("caveman");
        hp.communities = 3;
        hp.hidden = 8;
        hp.seed = seed;
        let ws = Arc::new(Workspace::build(&ds, &hp, Method::Metis).unwrap());
        let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::new());
        let opt = Optimizer::parse("adam", None).unwrap();
        ClusterGcnTrainer::new(
            ds,
            ws,
            backend,
            opt,
            ClusterGcnOptions {
                clusters,
                batch_clusters: q,
                method: Method::Metis,
            },
        )
        .unwrap()
    }

    #[test]
    fn same_seed_same_groupings_and_accuracy() {
        // The mini-batch determinism contract: identical seeds give
        // identical cluster schedules, bitwise-identical weights and the
        // same final accuracy.
        let mut a = mk(11, 8, 2);
        let mut b = mk(11, 8, 2);
        assert_eq!(a.epoch_groups(), b.epoch_groups());
        assert_eq!(a.epoch_groups(), b.epoch_groups());
        // Fresh trainers (the groups above consumed the shuffle stream).
        let mut a = mk(11, 8, 2);
        let mut b = mk(11, 8, 2);
        let ra = a.train(4).unwrap();
        let rb = b.train(4).unwrap();
        for (wa, wb) in a.weights().iter().zip(b.weights()) {
            assert_eq!(wa.data(), wb.data(), "weights diverged under one seed");
        }
        assert_eq!(ra.final_test_acc(), rb.final_test_acc());
        assert_eq!(ra.final_train_acc(), rb.final_train_acc());
        // And a different seed actually changes the schedule.
        let mut c = mk(12, 8, 2);
        assert_ne!(mk(11, 8, 2).epoch_groups(), c.epoch_groups());
    }

    #[test]
    fn pipelined_epochs_match_serial_bitwise() {
        // The shared-runtime pipelined prep path must reproduce the
        // serial loop exactly: same losses, bitwise-same weights.
        let ds = Arc::new(crate::data::fixtures::caveman(24, 3));
        let mut hp = HyperParams::for_dataset("caveman");
        hp.communities = 3;
        hp.hidden = 8;
        hp.seed = 11;
        let ws = Arc::new(Workspace::build(&ds, &hp, Method::Metis).unwrap());
        let opts = ClusterGcnOptions {
            clusters: 8,
            batch_clusters: 2,
            method: Method::Metis,
        };
        let opt = || Optimizer::parse("adam", None).unwrap();

        let serial_backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::new());
        let mut serial =
            ClusterGcnTrainer::new(ds.clone(), ws.clone(), serial_backend, opt(), opts).unwrap();
        let rs = serial.train(3).unwrap();

        let rt = Arc::new(Runtime::new(4));
        let shared: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::with_runtime_grain(rt, 0));
        assert!(shared.runtime().is_some(), "shared backend must expose the runtime");
        let mut piped = ClusterGcnTrainer::new(ds, ws, shared, opt(), opts).unwrap();
        let rp = piped.train(3).unwrap();

        for (a, b) in serial.weights().iter().zip(piped.weights()) {
            assert_eq!(a.data(), b.data(), "pipelined weights diverged from serial");
        }
        for (ea, eb) in rs.epochs.iter().zip(&rp.epochs) {
            assert_eq!(ea.loss, eb.loss, "epoch {} loss diverged", ea.epoch);
        }
        assert_eq!(serial.peak_batch_nodes(), piped.peak_batch_nodes());
    }

    #[test]
    fn peak_batch_is_bounded_by_cluster_group_size() {
        let mut t = mk(7, 8, 2);
        t.train(2).unwrap();
        // Peak dense-activation rows are bounded by the q largest
        // clusters, and strictly below the full graph.
        let mut sizes = t.fine.sizes();
        sizes.sort_unstable_by(|x, y| y.cmp(x));
        let bound: usize = sizes.iter().take(2).sum();
        assert!(t.peak_batch_nodes() > 0);
        assert!(
            t.peak_batch_nodes() <= bound,
            "peak {} > q-largest-clusters bound {bound}",
            t.peak_batch_nodes()
        );
        assert!(t.peak_batch_nodes() < t.ds.n());
    }

    #[test]
    fn every_cluster_visited_once_per_epoch() {
        let mut t = mk(5, 8, 3);
        let groups = t.epoch_groups();
        let mut seen: Vec<usize> = groups.into_iter().flatten().collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..t.num_clusters()).collect::<Vec<_>>());
        // Batches cover each node exactly once per epoch.
        let groups = t.epoch_groups();
        let mut nodes: Vec<usize> = groups
            .iter()
            .flat_map(|g| t.batch_nodes(g))
            .collect();
        nodes.sort_unstable();
        assert_eq!(nodes, (0..t.ds.n()).collect::<Vec<_>>());
    }

    #[test]
    fn learns_the_caveman_fixture() {
        // Mini-batch Adam must decrease the loss and beat random guessing
        // on the clean two-class fixture (sanity, not a tuning target) —
        // same lr/epoch budget the full-batch baseline tests use.
        let ds = Arc::new(crate::data::fixtures::caveman(24, 3));
        let mut hp = HyperParams::for_dataset("caveman");
        hp.communities = 3;
        hp.hidden = 8;
        hp.seed = 17;
        let ws = Arc::new(Workspace::build(&ds, &hp, Method::Metis).unwrap());
        let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::new());
        let opt = Optimizer::parse("adam", Some("0.05")).unwrap();
        let mut t = ClusterGcnTrainer::new(
            ds,
            ws,
            backend,
            opt,
            ClusterGcnOptions {
                clusters: 6,
                batch_clusters: 2,
                method: Method::Metis,
            },
        )
        .unwrap();
        let report = t.train(25).unwrap();
        let first = report.epochs.first().unwrap().loss;
        let last = report.epochs.last().unwrap().loss;
        assert!(last < first, "loss did not decrease ({first} -> {last})");
        assert!(
            report.final_train_acc() > 0.6,
            "train acc {}",
            report.final_train_acc()
        );
    }

    #[test]
    fn snapshot_from_minibatch_weights_is_servable() {
        let mut t = mk(9, 8, 2);
        t.train(2).unwrap();
        let meta = SnapshotMeta {
            label: "cluster-gcn".into(),
            dataset: "caveman".into(),
            scale: 1.0,
            seed: 3,
            partition: "metis".into(),
            communities: 3,
            hidden: 8,
            layers: 2,
        };
        let snap = ModelSnapshot::capture(meta, &t.ws, t.weights()).unwrap();
        let back = ModelSnapshot::from_bytes(&snap.to_bytes()).unwrap();
        // The snapshot round-trips and serves through the standard
        // inference session, agreeing with the trainer's own evaluation.
        let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::new());
        let mut session =
            crate::serve::InferenceSession::new(t.ws.clone(), backend, back.w.clone()).unwrap();
        let served = session.full_logits().unwrap();
        assert_eq!(served.rows(), t.ws.n);
        let (train_acc, _, _) = t.evaluate().unwrap();
        let (s_train, _, _) = session.evaluate().unwrap();
        assert_eq!(train_acc, s_train);
    }
}
