//! Run metrics: per-epoch records, Table-3-style timing summaries, CSV
//! emission for the Figure-2 accuracy curves.

use crate::util::json::Json;

/// One epoch of a training run.
#[derive(Clone, Debug)]
pub struct EpochRecord {
    pub epoch: usize,
    pub train_acc: f64,
    pub test_acc: f64,
    pub loss: f64,
    /// Virtual training (compute) seconds this epoch.
    pub t_train: f64,
    /// Virtual communication seconds this epoch.
    pub t_comm: f64,
    /// Real wall-clock seconds this epoch (all agents share one core).
    pub t_wall: f64,
    pub bytes: u64,
}

/// A full training run.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub method: String,
    pub dataset: String,
    pub communities: usize,
    pub epochs: Vec<EpochRecord>,
}

impl RunReport {
    pub fn new(method: &str, dataset: &str, communities: usize) -> RunReport {
        RunReport {
            method: method.to_string(),
            dataset: dataset.to_string(),
            communities,
            epochs: Vec::new(),
        }
    }

    pub fn push(&mut self, rec: EpochRecord) {
        self.epochs.push(rec);
    }

    pub fn total_train(&self) -> f64 {
        self.epochs.iter().map(|e| e.t_train).sum()
    }
    pub fn total_comm(&self) -> f64 {
        self.epochs.iter().map(|e| e.t_comm).sum()
    }
    pub fn total_virtual(&self) -> f64 {
        self.total_train() + self.total_comm()
    }
    pub fn total_wall(&self) -> f64 {
        self.epochs.iter().map(|e| e.t_wall).sum()
    }
    pub fn total_bytes(&self) -> u64 {
        self.epochs.iter().map(|e| e.bytes).sum()
    }
    pub fn final_train_acc(&self) -> f64 {
        self.epochs.last().map(|e| e.train_acc).unwrap_or(0.0)
    }
    pub fn final_test_acc(&self) -> f64 {
        self.epochs.last().map(|e| e.test_acc).unwrap_or(0.0)
    }
    /// Best test accuracy across epochs.
    pub fn best_test_acc(&self) -> f64 {
        self.epochs.iter().map(|e| e.test_acc).fold(0.0, f64::max)
    }

    /// CSV with header — the Figure-2 series format.
    pub fn to_csv(&self) -> String {
        let mut s = String::from(
            "method,dataset,communities,epoch,train_acc,test_acc,loss,t_train,t_comm,t_wall,bytes\n",
        );
        for e in &self.epochs {
            s.push_str(&format!(
                "{},{},{},{},{:.4},{:.4},{:.6},{:.6},{:.6},{:.6},{}\n",
                self.method,
                self.dataset,
                self.communities,
                e.epoch,
                e.train_acc,
                e.test_acc,
                e.loss,
                e.t_train,
                e.t_comm,
                e.t_wall,
                e.bytes
            ));
        }
        s
    }

    /// JSON summary (machine-readable experiment record).
    pub fn summary_json(&self) -> Json {
        Json::obj(vec![
            ("method", Json::str(&self.method)),
            ("dataset", Json::str(&self.dataset)),
            ("communities", Json::num(self.communities as f64)),
            ("epochs", Json::num(self.epochs.len() as f64)),
            ("total_train_s", Json::num(self.total_train())),
            ("total_comm_s", Json::num(self.total_comm())),
            ("total_virtual_s", Json::num(self.total_virtual())),
            ("total_wall_s", Json::num(self.total_wall())),
            ("total_bytes", Json::num(self.total_bytes() as f64)),
            ("final_train_acc", Json::num(self.final_train_acc())),
            ("final_test_acc", Json::num(self.final_test_acc())),
            ("best_test_acc", Json::num(self.best_test_acc())),
        ])
    }

    /// One Table-3 style row: total / training / communication / speedup
    /// (speedup is filled by the caller who knows the serial total).
    pub fn table3_row(&self, label: &str, speedup: Option<f64>) -> String {
        format!(
            "{:<22} {:>9.2} {:>10.2} {:>14.2} {:>9}",
            label,
            self.total_virtual(),
            self.total_train(),
            self.total_comm(),
            speedup
                .map(|s| format!("{s:.2}x"))
                .unwrap_or_else(|| "-".into()),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(epoch: usize, t: f64, c: f64) -> EpochRecord {
        EpochRecord {
            epoch,
            train_acc: 0.5 + epoch as f64 * 0.01,
            test_acc: 0.4 + epoch as f64 * 0.01,
            loss: 1.0 / (epoch + 1) as f64,
            t_train: t,
            t_comm: c,
            t_wall: t + c,
            bytes: 1000,
        }
    }

    #[test]
    fn totals_and_csv() {
        let mut r = RunReport::new("admm-parallel", "synth-photo", 3);
        r.push(rec(0, 1.0, 0.5));
        r.push(rec(1, 2.0, 0.25));
        assert!((r.total_train() - 3.0).abs() < 1e-12);
        assert!((r.total_comm() - 0.75).abs() < 1e-12);
        assert!((r.total_virtual() - 3.75).abs() < 1e-12);
        assert_eq!(r.total_bytes(), 2000);
        let csv = r.to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.starts_with("method,dataset"));
        assert!(csv.contains("admm-parallel,synth-photo,3,1,"));
    }

    #[test]
    fn summary_json_roundtrips() {
        let mut r = RunReport::new("adam", "fig1", 1);
        r.push(rec(0, 0.1, 0.0));
        let j = Json::parse(&r.summary_json().to_string()).unwrap();
        assert_eq!(j.get("method").as_str(), Some("adam"));
        assert_eq!(j.get("epochs").as_usize(), Some(1));
    }
}
