//! Tracing spans: per-thread ring buffers + Chrome trace-event export.
//!
//! A span is opened with [`crate::span!`] and recorded when its guard
//! drops — one fixed-size event (name, start, duration, one optional
//! integer argument) appended to the *calling thread's* ring buffer. The
//! ring is guarded by a per-thread mutex that only the owner ever takes on
//! the record path (export is the sole other reader, at end of run /
//! scrape), so recording is an uncontended lock + a vector write: cheap at
//! phase/chunk granularity, and kept strictly off kernel inner loops.
//!
//! Rings are bounded (`CGCN_OBS_RING` events per thread, default 65536);
//! on overflow the oldest events are overwritten and a drop count is kept,
//! so a long run can never exhaust memory through telemetry.
//!
//! Export renders the Chrome trace-event format — a JSON object with a
//! `traceEvents` array of `ph:"X"` (complete) events carrying `ts`/`dur`
//! in microseconds plus `ph:"M"` thread-name metadata, one `tid` lane per
//! thread — which `chrome://tracing` and Perfetto open directly. Events
//! are sorted by `ts` within each thread (guards record at *close* time,
//! so a parent span lands after its children despite starting earlier).

use super::{enabled, now_us, thread_id, thread_label};
use crate::util::json::Json;
use crate::util::stats::Summary;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, OnceLock};

/// One closed span.
#[derive(Clone, Copy, Debug)]
struct SpanEvent {
    name: &'static str,
    /// Start, microseconds since the trace epoch.
    ts_us: f64,
    dur_us: f64,
    arg: Option<(&'static str, i64)>,
}

/// Fixed-capacity overwrite-oldest ring.
struct Ring {
    buf: Vec<SpanEvent>,
    /// Next write slot once `buf.len() == cap`.
    next: usize,
    dropped: u64,
}

struct TraceBuf {
    tid: u64,
    label: String,
    ring: Mutex<Ring>,
}

struct Trace {
    bufs: Mutex<Vec<Arc<TraceBuf>>>,
    cap: usize,
}

static TRACE: OnceLock<Trace> = OnceLock::new();

fn trace() -> &'static Trace {
    TRACE.get_or_init(|| {
        let cap = std::env::var("CGCN_OBS_RING")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&c| c > 0)
            .unwrap_or(65536);
        Trace {
            bufs: Mutex::new(Vec::new()),
            cap,
        }
    })
}

thread_local! {
    static TBUF: Arc<TraceBuf> = {
        let t = trace();
        let buf = Arc::new(TraceBuf {
            tid: thread_id(),
            label: thread_label(),
            ring: Mutex::new(Ring {
                buf: Vec::new(),
                next: 0,
                dropped: 0,
            }),
        });
        t.bufs.lock().unwrap().push(buf.clone());
        buf
    };
}

fn record(ev: SpanEvent) {
    let cap = trace().cap;
    // No-op during TLS teardown: dropping the event beats panicking in a
    // thread destructor.
    let _ = TBUF.try_with(|b| {
        let mut ring = b.ring.lock().unwrap();
        if ring.buf.len() < cap {
            ring.buf.push(ev);
        } else {
            let slot = ring.next;
            ring.buf[slot] = ev;
            ring.next = (slot + 1) % cap;
            ring.dropped += 1;
        }
    });
}

// ---------------------------------------------------------------------------
// Span guard
// ---------------------------------------------------------------------------

/// RAII span: opened by [`crate::span!`], recorded on drop. Unarmed (a
/// pure no-op) when the `CGCN_OBS` gate is off at entry.
pub struct SpanGuard {
    name: &'static str,
    arg: Option<(&'static str, i64)>,
    t0_us: f64,
    armed: bool,
}

impl SpanGuard {
    #[inline]
    pub fn enter(name: &'static str) -> SpanGuard {
        let armed = enabled();
        SpanGuard {
            name,
            arg: None,
            t0_us: if armed { now_us() } else { 0.0 },
            armed,
        }
    }

    #[inline]
    pub fn enter_arg(name: &'static str, key: &'static str, val: i64) -> SpanGuard {
        let mut g = SpanGuard::enter(name);
        g.arg = Some((key, val));
        g
    }
}

impl Drop for SpanGuard {
    #[inline]
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        let dur = now_us() - self.t0_us;
        record(SpanEvent {
            name: self.name,
            ts_us: self.t0_us,
            dur_us: dur,
            arg: self.arg,
        });
    }
}

// ---------------------------------------------------------------------------
// Export
// ---------------------------------------------------------------------------

/// Snapshot one thread's events in `ts` order.
fn sorted_events(buf: &TraceBuf) -> (Vec<SpanEvent>, u64) {
    let ring = buf.ring.lock().unwrap();
    let mut evs = ring.buf.clone();
    let dropped = ring.dropped;
    drop(ring);
    evs.sort_by(|a, b| a.ts_us.total_cmp(&b.ts_us));
    (evs, dropped)
}

/// The full Chrome trace-event document (round-trips through
/// [`crate::util::json`]; `ts` is non-decreasing within each `tid`).
pub fn chrome_trace_json() -> Json {
    let bufs: Vec<Arc<TraceBuf>> = trace().bufs.lock().unwrap().clone();
    let mut events: Vec<Json> = Vec::new();
    events.push(Json::obj(vec![
        ("name", Json::str("process_name")),
        ("ph", Json::str("M")),
        ("pid", Json::num(1.0)),
        ("args", Json::obj(vec![("name", Json::str("cgcn"))])),
    ]));
    let mut total_dropped = 0u64;
    for buf in &bufs {
        let (evs, dropped) = sorted_events(buf);
        total_dropped += dropped;
        if evs.is_empty() {
            continue;
        }
        events.push(Json::obj(vec![
            ("name", Json::str("thread_name")),
            ("ph", Json::str("M")),
            ("pid", Json::num(1.0)),
            ("tid", Json::num(buf.tid as f64)),
            (
                "args",
                Json::obj(vec![("name", Json::str(&buf.label))]),
            ),
        ]));
        for e in evs {
            let mut fields = vec![
                ("name", Json::str(e.name)),
                ("cat", Json::str("cgcn")),
                ("ph", Json::str("X")),
                ("ts", Json::num(e.ts_us)),
                ("dur", Json::num(e.dur_us)),
                ("pid", Json::num(1.0)),
                ("tid", Json::num(buf.tid as f64)),
            ];
            if let Some((k, v)) = e.arg {
                fields.push(("args", Json::obj(vec![(k, Json::num(v as f64))])));
            }
            events.push(Json::obj(fields));
        }
    }
    if total_dropped > 0 {
        log::warn!("obs: trace rings overflowed; {total_dropped} oldest events dropped");
    }
    Json::obj(vec![
        ("traceEvents", Json::arr(events)),
        ("displayTimeUnit", Json::str("ms")),
        ("droppedEvents", Json::num(total_dropped as f64)),
    ])
}

/// Per-span-name duration summaries in microseconds, across all threads —
/// computed with the shared [`crate::util::stats`] percentile math.
pub fn span_summaries() -> Vec<(String, Summary)> {
    let bufs: Vec<Arc<TraceBuf>> = trace().bufs.lock().unwrap().clone();
    let mut by_name: BTreeMap<&'static str, Vec<f64>> = BTreeMap::new();
    for buf in &bufs {
        let (evs, _) = sorted_events(buf);
        for e in evs {
            by_name.entry(e.name).or_default().push(e.dur_us);
        }
    }
    by_name
        .into_iter()
        .map(|(name, durs)| (name.to_string(), Summary::of(&durs)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_records_and_exports() {
        let _guard = super::super::test_lock();
        super::super::force(true);
        {
            let _s = crate::span!("test.trace.outer", community = 3);
            let _inner = crate::span!("test.trace.inner");
        }
        let doc = chrome_trace_json();
        let evs = doc.get("traceEvents").as_arr().expect("traceEvents");
        let outer = evs
            .iter()
            .find(|e| e.get("name").as_str() == Some("test.trace.outer"))
            .expect("outer span exported");
        assert_eq!(outer.get("ph").as_str(), Some("X"));
        assert_eq!(
            outer.get("args").get("community").as_f64(),
            Some(3.0)
        );
        assert!(evs
            .iter()
            .any(|e| e.get("name").as_str() == Some("test.trace.inner")));
        // Summaries cover the recorded names.
        let sums = span_summaries();
        assert!(sums.iter().any(|(n, s)| n == "test.trace.outer" && s.n >= 1));
    }

    #[test]
    fn disabled_gate_records_nothing() {
        let _guard = super::super::test_lock();
        super::super::force(false);
        {
            let _s = crate::span!("test.trace.gated");
        }
        super::super::force(true);
        let doc = chrome_trace_json();
        let evs = doc.get("traceEvents").as_arr().expect("traceEvents");
        assert!(!evs
            .iter()
            .any(|e| e.get("name").as_str() == Some("test.trace.gated")));
    }
}
