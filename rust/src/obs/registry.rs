//! The process-global metrics registry: counters, gauges and fixed-bucket
//! histograms with per-thread lock-free recorders.
//!
//! Recording model: every thread owns one [`Shard`] (created lazily on its
//! first record and registered with the global [`Registry`]). Counters and
//! histograms write only to the owning thread's shard with relaxed atomic
//! *load + store* — the owner is the sole writer, so no `fetch_add`, no
//! CAS loop and no mutex exist on any record path. Scrapes (`snapshot()`)
//! read every shard's atomics and sum; a scrape racing a record may miss
//! the in-flight sample, which is the standard sharded-counter contract
//! (eventually exact once the writers quiesce — the concurrency test in
//! `rust/tests/obs.rs` joins its writers before scraping).
//!
//! Gauges are point-in-time values set from anywhere (queue depth, live
//! connections), so they live in one global atomic slot per gauge rather
//! than per-thread shards.
//!
//! Registration is bounded: at most [`MAX_COUNTERS`]/[`MAX_GAUGES`]/
//! [`MAX_HISTS`] distinct metrics. Shards pre-allocate dense fixed-size
//! slots so a metric registered *after* a shard exists still has its slot.
//! Overflowing the bound yields a dead handle (records become no-ops) and
//! a one-line warning — telemetry must never abort training.

use super::enabled;
use crate::util::stats;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering::Relaxed};
use std::sync::{Arc, Mutex, OnceLock};

/// Distinct counter metrics supported per process.
pub const MAX_COUNTERS: usize = 192;
/// Distinct gauge metrics supported per process.
pub const MAX_GAUGES: usize = 64;
/// Distinct histogram metrics supported per process.
pub const MAX_HISTS: usize = 64;

/// Dead-handle sentinel (registration overflow / unknown metric).
const DEAD: u32 = u32::MAX;

/// Exponential latency bounds in seconds (1 µs … 10 s, 1-2-5 decades).
/// The final `+Inf` overflow bucket is implicit.
pub const TIME_BUCKETS: &[f64] = &[
    1e-6, 2e-6, 5e-6, 1e-5, 2e-5, 5e-5, 1e-4, 2e-4, 5e-4, 1e-3, 2e-3, 5e-3, 1e-2, 2e-2, 5e-2,
    1e-1, 2e-1, 5e-1, 1.0, 2.0, 5.0, 10.0,
];

/// Power-of-two size bounds (batch sizes, queue depths, chunk counts).
pub const SIZE_BUCKETS: &[f64] = &[
    1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0, 512.0, 1024.0, 2048.0, 4096.0,
];

/// Handle to a monotonically increasing counter.
#[derive(Clone, Copy, Debug)]
pub struct Counter(u32);

/// Handle to a set/add point-in-time gauge.
#[derive(Clone, Copy, Debug)]
pub struct Gauge(u32);

/// Handle to a fixed-bucket histogram.
#[derive(Clone, Copy, Debug)]
pub struct Histogram(u32);

/// One thread's private recording slots. Only the owning thread writes;
/// scrapes read concurrently (hence atomics, but never RMW contention).
pub(crate) struct Shard {
    counters: Box<[AtomicU64]>,
    /// Lazily sized per-histogram bucket stores (bounds differ per metric).
    hists: Box<[OnceLock<HistStore>]>,
}

struct HistStore {
    /// Bucket upper bounds, cached here at first record so the hot path
    /// never touches the registry lock.
    bounds: &'static [f64],
    /// `bounds.len() + 1` slots; the last is the +Inf overflow bucket.
    buckets: Box<[AtomicU64]>,
    count: AtomicU64,
    /// Sum of recorded values as f64 bits (owner-only load/modify/store).
    sum_bits: AtomicU64,
}

impl Shard {
    fn new() -> Shard {
        Shard {
            counters: (0..MAX_COUNTERS).map(|_| AtomicU64::new(0)).collect(),
            hists: (0..MAX_HISTS).map(|_| OnceLock::new()).collect(),
        }
    }
}

struct HistDef {
    name: &'static str,
    bounds: &'static [f64],
}

/// The process-global registry. Obtain it with [`registry()`].
pub struct Registry {
    counter_names: Mutex<Vec<&'static str>>,
    gauge_names: Mutex<Vec<&'static str>>,
    gauge_vals: Box<[AtomicI64]>,
    hist_defs: Mutex<Vec<HistDef>>,
    shards: Mutex<Vec<(u64, String, Arc<Shard>)>>,
}

static REGISTRY: OnceLock<Registry> = OnceLock::new();

/// The process-global [`Registry`].
pub fn registry() -> &'static Registry {
    REGISTRY.get_or_init(|| Registry {
        counter_names: Mutex::new(Vec::new()),
        gauge_names: Mutex::new(Vec::new()),
        gauge_vals: (0..MAX_GAUGES).map(|_| AtomicI64::new(0)).collect(),
        hist_defs: Mutex::new(Vec::new()),
        shards: Mutex::new(Vec::new()),
    })
}

thread_local! {
    static SHARD: Arc<Shard> = {
        let shard = Arc::new(Shard::new());
        let mut shards = registry().shards.lock().unwrap();
        shards.push((super::thread_id(), super::thread_label(), shard.clone()));
        shard
    };
}

/// Run `f` against the calling thread's shard; a no-op during TLS
/// teardown (a dropped sample beats a panic in a thread destructor).
#[inline]
fn with_shard(f: impl FnOnce(&Shard)) {
    let _ = SHARD.try_with(|s| f(s));
}

fn intern(names: &Mutex<Vec<&'static str>>, name: &'static str, cap: usize, kind: &str) -> u32 {
    let mut names = names.lock().unwrap();
    if let Some(i) = names.iter().position(|n| *n == name) {
        return i as u32;
    }
    if names.len() >= cap {
        log::warn!("obs: {kind} registry full ({cap}); '{name}' will not be recorded");
        return DEAD;
    }
    names.push(name);
    (names.len() - 1) as u32
}

impl Registry {
    /// Register (or look up) a counter by name.
    pub fn counter(&self, name: &'static str) -> Counter {
        Counter(intern(&self.counter_names, name, MAX_COUNTERS, "counter"))
    }

    /// Register (or look up) a gauge by name.
    pub fn gauge(&self, name: &'static str) -> Gauge {
        Gauge(intern(&self.gauge_names, name, MAX_GAUGES, "gauge"))
    }

    /// Register (or look up) a histogram with the given bucket upper
    /// bounds (ascending; a +Inf overflow bucket is implicit). Re-registering
    /// an existing name keeps the original bounds.
    pub fn histogram(&self, name: &'static str, bounds: &'static [f64]) -> Histogram {
        debug_assert!(bounds.windows(2).all(|w| w[0] < w[1]), "bounds must ascend");
        let mut defs = self.hist_defs.lock().unwrap();
        if let Some(i) = defs.iter().position(|d| d.name == name) {
            return Histogram(i as u32);
        }
        if defs.len() >= MAX_HISTS {
            log::warn!("obs: histogram registry full ({MAX_HISTS}); '{name}' will not be recorded");
            return Histogram(DEAD);
        }
        defs.push(HistDef { name, bounds });
        Histogram((defs.len() - 1) as u32)
    }

    /// Aggregate every shard into one consistent-enough snapshot.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let counter_names: Vec<&'static str> = self.counter_names.lock().unwrap().clone();
        let gauge_names: Vec<&'static str> = self.gauge_names.lock().unwrap().clone();
        let hist_meta: Vec<(&'static str, &'static [f64])> = {
            let defs = self.hist_defs.lock().unwrap();
            defs.iter().map(|d| (d.name, d.bounds)).collect()
        };
        let shards: Vec<Arc<Shard>> = {
            let s = self.shards.lock().unwrap();
            s.iter().map(|(_, _, sh)| sh.clone()).collect()
        };

        let mut counters: Vec<(String, u64)> = counter_names
            .iter()
            .enumerate()
            .map(|(i, n)| {
                let total = shards.iter().map(|s| s.counters[i].load(Relaxed)).sum();
                (n.to_string(), total)
            })
            .collect();
        counters.sort_by(|a, b| a.0.cmp(&b.0));

        let mut gauges: Vec<(String, i64)> = gauge_names
            .iter()
            .enumerate()
            .map(|(i, n)| (n.to_string(), self.gauge_vals[i].load(Relaxed)))
            .collect();
        gauges.sort_by(|a, b| a.0.cmp(&b.0));

        let mut hists: Vec<HistSnapshot> = hist_meta
            .iter()
            .enumerate()
            .map(|(i, (name, bounds))| {
                let mut buckets = vec![0u64; bounds.len() + 1];
                let mut count = 0u64;
                let mut sum = 0.0f64;
                for shard in &shards {
                    if let Some(store) = shard.hists[i].get() {
                        for (acc, b) in buckets.iter_mut().zip(store.buckets.iter()) {
                            *acc += b.load(Relaxed);
                        }
                        count += store.count.load(Relaxed);
                        sum += f64::from_bits(store.sum_bits.load(Relaxed));
                    }
                }
                HistSnapshot {
                    name: name.to_string(),
                    bounds: bounds.to_vec(),
                    buckets,
                    count,
                    sum,
                }
            })
            .collect();
        hists.sort_by(|a, b| a.name.cmp(&b.name));

        MetricsSnapshot {
            counters,
            gauges,
            hists,
        }
    }
}

impl Counter {
    #[inline]
    pub fn inc(self) {
        self.add(1);
    }

    #[inline]
    pub fn add(self, n: u64) {
        if !enabled() || self.0 == DEAD {
            return;
        }
        with_shard(|s| {
            let c = &s.counters[self.0 as usize];
            // Owner-only writer: plain load+store beats fetch_add (no
            // lock prefix) and loses nothing.
            c.store(c.load(Relaxed).wrapping_add(n), Relaxed);
        });
    }
}

impl Gauge {
    #[inline]
    pub fn set(self, v: i64) {
        if !enabled() || self.0 == DEAD {
            return;
        }
        registry().gauge_vals[self.0 as usize].store(v, Relaxed);
    }

    /// Add a (possibly negative) delta — gauges are written from many
    /// threads, so unlike counters this must be a real RMW.
    #[inline]
    pub fn add(self, d: i64) {
        if !enabled() || self.0 == DEAD {
            return;
        }
        registry().gauge_vals[self.0 as usize].fetch_add(d, Relaxed);
    }
}

impl Histogram {
    /// Record one observation.
    #[inline]
    pub fn record(self, v: f64) {
        if !enabled() || self.0 == DEAD {
            return;
        }
        with_shard(|s| {
            // One registry-lock round-trip per (thread, histogram) to cache
            // the bounds; every later record is pure atomics.
            let store = s.hists[self.0 as usize].get_or_init(|| {
                let bounds = {
                    let defs = registry().hist_defs.lock().unwrap();
                    defs[self.0 as usize].bounds
                };
                HistStore {
                    bounds,
                    buckets: (0..bounds.len() + 1).map(|_| AtomicU64::new(0)).collect(),
                    count: AtomicU64::new(0),
                    sum_bits: AtomicU64::new(0.0f64.to_bits()),
                }
            });
            let bounds = store.bounds;
            let idx = bounds.iter().position(|&b| v <= b).unwrap_or(bounds.len());
            let b = &store.buckets[idx];
            b.store(b.load(Relaxed) + 1, Relaxed);
            let c = &store.count;
            c.store(c.load(Relaxed) + 1, Relaxed);
            let s_ = f64::from_bits(store.sum_bits.load(Relaxed)) + v;
            store.sum_bits.store(s_.to_bits(), Relaxed);
        });
    }

    /// Record a duration in seconds.
    #[inline]
    pub fn record_secs(self, t0: std::time::Instant) {
        if !enabled() || self.0 == DEAD {
            return;
        }
        self.record(t0.elapsed().as_secs_f64());
    }
}

// ---------------------------------------------------------------------------
// Snapshots
// ---------------------------------------------------------------------------

/// One scrape of the whole registry (sorted by metric name).
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub counters: Vec<(String, u64)>,
    pub gauges: Vec<(String, i64)>,
    pub hists: Vec<HistSnapshot>,
}

impl MetricsSnapshot {
    /// Value of a counter by name (0 if never registered).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| *v)
            .unwrap_or(0)
    }

    /// Histogram scrape by name.
    pub fn hist(&self, name: &str) -> Option<&HistSnapshot> {
        self.hists.iter().find(|h| h.name == name)
    }
}

/// Aggregated histogram state at scrape time.
#[derive(Clone, Debug)]
pub struct HistSnapshot {
    pub name: String,
    /// Bucket upper bounds (ascending); `buckets` has one extra +Inf slot.
    pub bounds: Vec<f64>,
    pub buckets: Vec<u64>,
    pub count: u64,
    pub sum: f64,
}

impl HistSnapshot {
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Estimated quantile, `q ∈ [0,1]` — the same clamp + linear
    /// interpolation as [`stats::percentile`], applied to the bucket CDF
    /// (interpolating within the bucket that holds the target rank).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = q.clamp(0.0, 1.0) * (self.count as f64 - 1.0);
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if (seen + c) as f64 > target {
                let lo = if i == 0 { 0.0 } else { self.bounds[i - 1] };
                let hi = self.bounds.get(i).copied().unwrap_or_else(|| {
                    // +Inf overflow bucket: fall back to the largest bound
                    // (or the mean when there are no finite bounds).
                    self.bounds.last().copied().unwrap_or_else(|| self.mean())
                });
                let frac = (target - seen as f64) / c as f64;
                return lo + (hi - lo) * frac.clamp(0.0, 1.0);
            }
            seen += c;
        }
        self.bounds.last().copied().unwrap_or_else(|| self.mean())
    }

    /// `(p50, p95, p99)` — the percentile triple [`stats::Summary`] reports.
    pub fn percentiles(&self) -> (f64, f64, f64) {
        (self.quantile(0.50), self.quantile(0.95), self.quantile(0.99))
    }
}

/// Summarise raw samples with the shared percentile math (used by the
/// exporters for span durations, where exact samples exist).
pub fn summarize(samples: &[f64]) -> Option<stats::Summary> {
    if samples.is_empty() {
        None
    } else {
        Some(stats::Summary::of(samples))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_roundtrip_and_gate() {
        let _guard = super::super::test_lock();
        super::super::force(true);
        let c = registry().counter("test.registry.roundtrip");
        c.inc();
        c.add(4);
        assert_eq!(registry().snapshot().counter("test.registry.roundtrip"), 5);
        // Flipping the gate off drops samples entirely.
        super::super::force(false);
        let g = registry().counter("test.registry.gated");
        g.add(100);
        super::super::force(true);
        assert_eq!(registry().snapshot().counter("test.registry.gated"), 0);
    }

    #[test]
    fn gauge_set_and_add() {
        let _guard = super::super::test_lock();
        super::super::force(true);
        let g = registry().gauge("test.registry.gauge");
        g.set(7);
        g.add(-2);
        let snap = registry().snapshot();
        let v = snap.gauges.iter().find(|(n, _)| n == "test.registry.gauge");
        assert_eq!(v.map(|(_, v)| *v), Some(5));
    }

    #[test]
    fn histogram_quantiles_interpolate() {
        let _guard = super::super::test_lock();
        super::super::force(true);
        let h = registry().histogram("test.registry.hist", SIZE_BUCKETS);
        for v in [1.0, 2.0, 3.0, 100.0] {
            h.record(v);
        }
        let snap = registry().snapshot();
        let hs = snap.hist("test.registry.hist").unwrap();
        assert_eq!(hs.count, 4);
        assert!((hs.sum - 106.0).abs() < 1e-9);
        let p50 = hs.quantile(0.5);
        assert!((1.0..=4.0).contains(&p50), "p50 {p50}");
        assert!(hs.quantile(1.0) >= hs.quantile(0.0));
    }

    #[test]
    fn duplicate_registration_reuses_id() {
        let a = registry().counter("test.registry.dup");
        let b = registry().counter("test.registry.dup");
        assert_eq!(a.0, b.0);
        let ha = registry().histogram("test.registry.dup.h", TIME_BUCKETS);
        let hb = registry().histogram("test.registry.dup.h", SIZE_BUCKETS);
        assert_eq!(ha.0, hb.0);
    }
}
