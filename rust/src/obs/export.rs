//! Exporters: Prometheus-style text exposition and `metrics.json`.
//!
//! Both render one [`MetricsSnapshot`] scrape. The text form is what the
//! serve wire's `Metrics` frame and `cgcn stats` print (counter/gauge
//! sample lines, cumulative `_bucket{le="…"}` histogram series with
//! `_sum`/`_count`, plus `{quantile="…"}` summary lines interpolated from
//! the buckets). The JSON form (`--metrics-out`) adds per-span duration
//! summaries computed from the trace rings through
//! [`crate::util::stats::Summary`], and round-trips through
//! [`crate::util::json`].

use super::registry::{registry, HistSnapshot, MetricsSnapshot};
use super::trace;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::fmt::Write as _;
use std::path::Path;

/// Mangle a dotted metric name into a Prometheus-legal one
/// (`serve.request.latency` → `cgcn_serve_request_latency`).
fn prom_name(name: &str) -> String {
    let mut out = String::with_capacity(name.len() + 5);
    out.push_str("cgcn_");
    for c in name.chars() {
        out.push(if c.is_ascii_alphanumeric() { c } else { '_' });
    }
    out
}

/// Format a bucket bound the way Prometheus expects (`0.005`, `256`).
fn fmt_bound(b: f64) -> String {
    if b.fract() == 0.0 && b.abs() < 1e15 {
        format!("{}", b as i64)
    } else {
        format!("{b}")
    }
}

fn render_hist(out: &mut String, h: &HistSnapshot) {
    let base = prom_name(&h.name);
    let _ = writeln!(out, "# TYPE {base} histogram");
    let mut cum = 0u64;
    for (i, &c) in h.buckets.iter().enumerate() {
        cum += c;
        let le = match h.bounds.get(i) {
            Some(&b) => fmt_bound(b),
            None => "+Inf".to_string(),
        };
        let _ = writeln!(out, "{base}_bucket{{le=\"{le}\"}} {cum}");
    }
    let _ = writeln!(out, "{base}_sum {}", h.sum);
    let _ = writeln!(out, "{base}_count {}", h.count);
    // Summary-style quantile lines so a human (or the ci smoke) can read
    // percentiles straight off the exposition.
    for q in [0.5, 0.95, 0.99] {
        let _ = writeln!(out, "{base}{{quantile=\"{q}\"}} {}", h.quantile(q));
    }
}

/// Render a scrape as Prometheus text exposition.
pub fn render_prometheus(snap: &MetricsSnapshot) -> String {
    let mut out = String::new();
    for (name, v) in &snap.counters {
        let base = prom_name(name);
        let _ = writeln!(out, "# TYPE {base}_total counter");
        let _ = writeln!(out, "{base}_total {v}");
    }
    for (name, v) in &snap.gauges {
        let base = prom_name(name);
        let _ = writeln!(out, "# TYPE {base} gauge");
        let _ = writeln!(out, "{base} {v}");
    }
    for h in &snap.hists {
        render_hist(&mut out, h);
    }
    out
}

/// Scrape the global registry and render Prometheus text.
pub fn prometheus_text() -> String {
    render_prometheus(&registry().snapshot())
}

/// Scrape the registry + trace rings into one `metrics.json` document.
pub fn metrics_json() -> Json {
    let snap = registry().snapshot();
    let counters = Json::Obj(
        snap.counters
            .iter()
            .map(|(n, v)| (n.clone(), Json::num(*v as f64)))
            .collect(),
    );
    let gauges = Json::Obj(
        snap.gauges
            .iter()
            .map(|(n, v)| (n.clone(), Json::num(*v as f64)))
            .collect(),
    );
    let hists = Json::Obj(
        snap.hists
            .iter()
            .map(|h| {
                let buckets: Vec<Json> = h
                    .buckets
                    .iter()
                    .enumerate()
                    .map(|(i, &c)| {
                        Json::obj(vec![
                            (
                                "le",
                                match h.bounds.get(i) {
                                    Some(&b) => Json::num(b),
                                    None => Json::str("+Inf"),
                                },
                            ),
                            ("count", Json::num(c as f64)),
                        ])
                    })
                    .collect();
                let (p50, p95, p99) = h.percentiles();
                let body = Json::obj(vec![
                    ("count", Json::num(h.count as f64)),
                    ("sum", Json::num(h.sum)),
                    ("mean", Json::num(h.mean())),
                    ("p50", Json::num(p50)),
                    ("p95", Json::num(p95)),
                    ("p99", Json::num(p99)),
                    ("buckets", Json::arr(buckets)),
                ]);
                (h.name.clone(), body)
            })
            .collect(),
    );
    let spans = Json::Obj(
        trace::span_summaries()
            .into_iter()
            .map(|(name, s)| {
                let body = Json::obj(vec![
                    ("count", Json::num(s.n as f64)),
                    ("mean_us", Json::num(s.mean)),
                    ("p50_us", Json::num(s.p50)),
                    ("p95_us", Json::num(s.p95)),
                    ("p99_us", Json::num(s.p99)),
                    ("max_us", Json::num(s.max)),
                    ("total_us", Json::num(s.mean * s.n as f64)),
                ]);
                (name, body)
            })
            .collect(),
    );
    Json::obj(vec![
        ("counters", counters),
        ("gauges", gauges),
        ("histograms", hists),
        ("spans", spans),
    ])
}

/// Write the Chrome trace-event JSON for this process's spans.
pub fn write_chrome_trace(path: &Path) -> Result<()> {
    let doc = trace::chrome_trace_json();
    std::fs::write(path, doc.to_string() + "\n")
        .with_context(|| format!("writing trace to {}", path.display()))?;
    log::info!("wrote Chrome trace to {} (chrome://tracing)", path.display());
    Ok(())
}

/// Write the end-of-run `metrics.json`.
pub fn write_metrics_json(path: &Path) -> Result<()> {
    std::fs::write(path, metrics_json().to_pretty() + "\n")
        .with_context(|| format!("writing metrics to {}", path.display()))?;
    log::info!("wrote metrics to {}", path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::TIME_BUCKETS;

    #[test]
    fn prometheus_text_renders_registered_metrics() {
        let _guard = crate::obs::test_lock();
        crate::obs::force(true);
        registry().counter("test.export.counter").add(3);
        registry()
            .histogram("test.export.lat", TIME_BUCKETS)
            .record(0.0015);
        let text = prometheus_text();
        assert!(text.contains("cgcn_test_export_counter_total 3"));
        assert!(text.contains("# TYPE cgcn_test_export_lat histogram"));
        assert!(text.contains("cgcn_test_export_lat_count 1"));
        assert!(text.contains("quantile=\"0.99\""));
        // Cumulative buckets end at the total count.
        assert!(text.contains("cgcn_test_export_lat_bucket{le=\"+Inf\"} 1"));
    }

    #[test]
    fn metrics_json_roundtrips() {
        let _guard = crate::obs::test_lock();
        crate::obs::force(true);
        registry().counter("test.export.json").inc();
        let doc = metrics_json();
        let back = Json::parse(&doc.to_pretty()).unwrap();
        assert!(back.get("counters").get("test.export.json").as_f64() >= Some(1.0));
        assert!(back.get("histograms").as_obj().is_some());
    }
}
