//! Zero-dependency telemetry: a metrics registry, tracing spans, Chrome
//! trace export and Prometheus-style exposition.
//!
//! Three pieces (DESIGN.md §10):
//!
//! - [`registry`] — a process-global [`Registry`] of counters, gauges and
//!   fixed-bucket histograms. Counters and histograms record into
//!   *per-thread shards* (plain relaxed atomic load+store — the owning
//!   thread is the only writer, so there is no RMW contention and no lock
//!   anywhere near a kernel loop); a scrape sums the shards.
//! - [`trace`] — lightweight spans (`span!("admm.w_update", community = k)`)
//!   recorded into bounded per-thread ring buffers and exported as Chrome
//!   trace-event JSON (`--trace-out trace.json` opens directly in
//!   `chrome://tracing` / Perfetto, with one lane per thread).
//! - [`export`] — renders a scrape as Prometheus text exposition (the
//!   serve `Metrics` frame / `cgcn stats`) or as `metrics.json`
//!   (`--metrics-out`), with span-duration summaries computed through
//!   [`crate::util::stats`].
//!
//! Everything is gated on `CGCN_OBS` (`off`/`0` disables; default on).
//! Disabled, every record path is one relaxed atomic load and a branch.
//! Telemetry only *observes* — it never reorders or synchronises work —
//! so training results are bitwise identical with the gate on or off
//! (asserted by `rust/tests/obs.rs`).
//!
//! Span guards must be *bound* to live for the measured region:
//! `let _span = span!("phase");` — a bare `let _ =` drops immediately.

pub mod export;
pub mod registry;
pub mod trace;

pub use export::{metrics_json, prometheus_text, write_chrome_trace, write_metrics_json};
pub use registry::{
    registry, Counter, Gauge, Histogram, HistSnapshot, MetricsSnapshot, Registry, SIZE_BUCKETS,
    TIME_BUCKETS,
};
pub use trace::{chrome_trace_json, span_summaries, SpanGuard};

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

// ---------------------------------------------------------------------------
// Runtime gate
// ---------------------------------------------------------------------------

/// 0 = uninitialised, 1 = on, 2 = off.
static GATE: AtomicU8 = AtomicU8::new(0);

#[cold]
fn init_gate() -> bool {
    let on = match std::env::var("CGCN_OBS").as_deref() {
        Ok("off") | Ok("0") | Ok("false") => false,
        _ => true,
    };
    GATE.store(if on { 1 } else { 2 }, Ordering::Relaxed);
    on
}

/// Is telemetry recording enabled? One relaxed load on the fast path.
#[inline]
pub fn enabled() -> bool {
    match GATE.load(Ordering::Relaxed) {
        1 => true,
        2 => false,
        _ => init_gate(),
    }
}

/// Override the `CGCN_OBS` gate at runtime (tests and the bench overhead
/// gate flip this within one process).
pub fn force(on: bool) {
    GATE.store(if on { 1 } else { 2 }, Ordering::Relaxed);
}

// ---------------------------------------------------------------------------
// Time base + thread identity
// ---------------------------------------------------------------------------

static EPOCH: OnceLock<Instant> = OnceLock::new();

/// The process-wide trace time origin (first telemetry touch).
pub(crate) fn epoch() -> Instant {
    *EPOCH.get_or_init(Instant::now)
}

/// Microseconds since the trace epoch.
#[inline]
pub(crate) fn now_us() -> f64 {
    epoch().elapsed().as_secs_f64() * 1e6
}

static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static TID: u64 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
}

/// Small dense per-thread id, shared by metric shards and trace lanes so a
/// worker occupies the same `tid` lane everywhere.
pub(crate) fn thread_id() -> u64 {
    TID.try_with(|t| *t).unwrap_or(0)
}

/// The current thread's name (trace-lane label), or `thread-<tid>`.
pub(crate) fn thread_label() -> String {
    std::thread::current()
        .name()
        .map(str::to_string)
        .unwrap_or_else(|| format!("thread-{}", thread_id()))
}

// ---------------------------------------------------------------------------
// Call-site handle caches
// ---------------------------------------------------------------------------

/// A cached [`Counter`] handle for a literal metric name — registration
/// runs once per call site, recording is lock-free after that.
#[macro_export]
macro_rules! obs_counter {
    ($name:expr) => {{
        static __OBS_C: std::sync::OnceLock<$crate::obs::Counter> = std::sync::OnceLock::new();
        *__OBS_C.get_or_init(|| $crate::obs::registry().counter($name))
    }};
}

/// A cached [`Gauge`] handle for a literal metric name.
#[macro_export]
macro_rules! obs_gauge {
    ($name:expr) => {{
        static __OBS_G: std::sync::OnceLock<$crate::obs::Gauge> = std::sync::OnceLock::new();
        *__OBS_G.get_or_init(|| $crate::obs::registry().gauge($name))
    }};
}

/// A cached [`Histogram`] handle: `obs_hist!("name", TIME_BUCKETS)`.
#[macro_export]
macro_rules! obs_hist {
    ($name:expr, $bounds:expr) => {{
        static __OBS_H: std::sync::OnceLock<$crate::obs::Histogram> = std::sync::OnceLock::new();
        *__OBS_H.get_or_init(|| $crate::obs::registry().histogram($name, $bounds))
    }};
}

/// Serialises unit tests that flip the global gate (tests share one
/// process; an unsynchronised `force(false)` would drop another test's
/// samples).
#[cfg(test)]
pub(crate) fn test_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Open a tracing span; close (and record) on drop. Bind it:
/// `let _span = span!("admm.w_update", community = k);`
#[macro_export]
macro_rules! span {
    ($name:expr) => {
        $crate::obs::SpanGuard::enter($name)
    };
    ($name:expr, $key:ident = $val:expr) => {
        $crate::obs::SpanGuard::enter_arg($name, stringify!($key), ($val) as i64)
    };
}
