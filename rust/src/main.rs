//! `cgcn` — the CLI entry point / launcher.
//!
//! Subcommands are declared once in [`SUBCOMMANDS`]; both the dispatch
//! and the error/help text are driven from that table, so they cannot
//! drift apart.

use cgcn::util::cli::{ArgSpec, Args};

struct Subcommand {
    name: &'static str,
    help: &'static str,
    run: fn(&Args) -> i32,
}

/// The single source of truth for subcommand dispatch *and* help text.
const SUBCOMMANDS: &[Subcommand] = &[
    Subcommand {
        name: "plan",
        help: "write configs/artifacts.json (shape source of truth)",
        run: cgcn::cmd::cmd_plan,
    },
    Subcommand {
        name: "data",
        help: "dataset utilities (stats / generate / export)",
        run: cgcn::cmd::cmd_data,
    },
    Subcommand {
        name: "train",
        help: "train with ADMM, a full-batch baseline, or cluster-gcn mini-batches; --save snapshots the model, --checkpoint-every/--resume give crash recovery",
        run: cgcn::cmd::cmd_train,
    },
    Subcommand {
        name: "partition",
        help: "partition a dataset (louvain|lpa|metis|random|bfs), print a quality report (modularity/edge-cut/conductance/balance), optionally export the assignment (--partition-file) for train to reuse",
        run: cgcn::cmd::cmd_partition,
    },
    Subcommand {
        name: "serve",
        help: "run the batched multi-threaded inference server on a saved model",
        run: cgcn::cmd::cmd_serve,
    },
    Subcommand {
        name: "query",
        help: "query a running inference server (--nodes / --verify / --shutdown-server)",
        run: cgcn::cmd::cmd_query,
    },
    Subcommand {
        name: "loadgen",
        help: "generate closed-loop query load against a running server",
        run: cgcn::cmd::cmd_loadgen,
    },
    Subcommand {
        name: "stats",
        help: "scrape a running inference server: serve counters + the full metrics registry (Prometheus text)",
        run: cgcn::cmd::cmd_stats,
    },
    Subcommand {
        name: "artifacts",
        help: "list indexed artifacts and compile-check them",
        run: cgcn::cmd::cmd_artifacts,
    },
    Subcommand {
        name: "worker",
        help: "internal: community worker process (TCP transport)",
        run: cgcn::cmd::cmd_worker,
    },
];

fn main() {
    cgcn::util::logger::init();
    let spec = ArgSpec::new(
        "cgcn",
        "community-based layerwise distributed GCN training (ADMM) + inference serving",
    )
    .opt("dataset", Some("synth-computers"), "dataset name or .cgnp path")
    .opt("scale", Some("0.25"), "synthetic dataset node-count scale (0,1]")
    .opt("hidden", Some("256"), "hidden units per GCN layer")
    .opt("layers", Some("2"), "GCN layers L")
    .opt("epochs", Some("50"), "training epochs")
    .opt("communities", Some("3"), "number of communities M (1 = serial)")
    .opt("method", Some("admm"), "train method: admm|gd|adam|adagrad|adadelta|cluster-gcn")
    .opt("partition", Some("metis"), "partitioner: metis|random|bfs|louvain|lpa")
    .opt("partition-file", Some(""), "partition: export the assignment to this path; train: import a precomputed assignment (cgcn-partition-v1 JSON) instead of partitioning")
    .opt("clusters", Some("32"), "cluster-gcn: fine partition count c (clamped to n)")
    .opt("batch-clusters", Some("8"), "cluster-gcn: clusters grouped per mini-batch step q")
    .opt("rho", Some("auto"), "ADMM rho (auto = paper default per dataset)")
    .opt("nu", Some("auto"), "ADMM nu (auto = paper default per dataset)")
    .opt("lr", Some("auto"), "baseline learning rate (auto = paper default)")
    .opt("seed", Some("17"), "random seed")
    .opt("out", Some(""), "output path (plan json / csv / cgnp / loadgen json)")
    .opt("transport", Some("local"), "agent transport: local|channel|tcp (channel = in-process worker threads over mpsc, tcp = one worker process per community)")
    .opt("exec", Some("serial"), "agent execution: serial|threads (threads = real shared-memory parallelism)")
    .opt("threads", Some("0"), "worker threads: train --exec threads agent pool, serve connection pool (0 = all cores)")
    .opt("backend", Some("auto"), "compute backend: auto|native|xla")
    .opt("link-mbps", Some("10000"), "simulated link bandwidth (Mbit/s; default models the paper's same-machine agents)")
    .opt("link-lat-us", Some("100"), "simulated link latency (microseconds)")
    .opt("checkpoint-every", Some("0"), "train: write a .cgck training checkpoint every N epochs (0 = off)")
    .opt("checkpoint-dir", Some("checkpoints"), "train: directory for .cgck training checkpoints")
    .opt("resume", Some(""), "train: resume from a .cgck checkpoint (run config comes from the checkpoint; --epochs sets the new target)")
    .opt("hb-timeout-ms", Some("5000"), "tcp leader: declare a worker dead after this much heartbeat silence")
    .opt("hb-interval-ms", Some("1000"), "worker: transport heartbeat interval")
    .opt("listen", Some(""), "worker: leader address to connect to")
    .opt("worker-idx", Some("0"), "worker: community index owned by this process")
    .opt("save", Some(""), "train: save the trained weights to a .cgnm model snapshot")
    .opt("model", Some(""), "serve/query --verify: model snapshot (.cgnm) path")
    .opt("addr", Some("127.0.0.1:0"), "serve: bind address (port 0 = ephemeral); query/loadgen: server address")
    .opt("addr-file", Some(""), "serve: write the bound address to this file once ready")
    .opt("batch-window-us", Some("200"), "serve: micro-batch collection window in microseconds")
    .opt("max-batch", Some("256"), "serve: max queries coalesced into one backend batch")
    .opt("runtime", Some("shared"), "thread runtime: shared (one work-stealing worker set for agents, kernels and serving, sized by the max of --threads/--op-threads) | dual (legacy separate pools)")
    .opt("op-threads", Some("0"), "native backend kernel threads (results are bitwise identical at any count). 0 = auto. Shared runtime: folded into the one budget (max with --threads). Dual: all cores, or 1 under --exec threads to avoid oversubscribing the agent pool")
    .opt("trace-out", Some(""), "train: write a Chrome trace-event JSON of the run's spans (load in chrome://tracing or Perfetto)")
    .opt("metrics-out", Some(""), "train: write the end-of-run metrics registry as JSON")
    .opt("nodes", Some(""), "query: comma-separated node ids")
    .opt("clients", Some("4"), "loadgen: concurrent client connections")
    .opt("requests", Some("200"), "loadgen: queries per client")
    .opt("nodes-per-query", Some("4"), "loadgen: node ids per query")
    .flag("parallel-layers", "ADMM: update W layers in parallel (paper Alg. 1)")
    .flag("op-spawn", "use the legacy spawn-per-op kernel executor instead of the persistent pool (A/B benchmarking)")
    .flag("csv", "emit per-epoch CSV to stdout")
    .flag("verify", "query: check served logits bitwise against an in-process forward pass of --model")
    .flag("shutdown-server", "query: ask the server to stop");
    let args = spec.parse_env();

    let code = match args.subcommand() {
        Some(name) => match SUBCOMMANDS.iter().find(|s| s.name == name) {
            Some(sub) => (sub.run)(&args),
            None => usage_error(Some(name), &spec),
        },
        None => usage_error(None, &spec),
    };
    std::process::exit(code);
}

fn usage_error(got: Option<&str>, spec: &ArgSpec) -> i32 {
    eprintln!("unknown or missing subcommand {got:?}\n\nsubcommands:");
    for sub in SUBCOMMANDS {
        eprintln!("  {:<10} {}", sub.name, sub.help);
    }
    eprintln!("\n{}", spec.usage());
    2
}
