//! `cgcn` — the CLI entry point / launcher.
//!
//! Subcommands:
//!   plan        write configs/artifacts.json (shape source of truth)
//!   data        dataset utilities (stats / generate / export)
//!   train       train with ADMM (serial or parallel) or a baseline
//!   eval        evaluate saved predictions / quick forward pass
//!   worker      internal: community worker process (TCP transport)
//!   artifacts   list indexed artifacts and compile-check them

use cgcn::util::cli::ArgSpec;

fn main() {
    cgcn::util::logger::init();
    let spec = ArgSpec::new(
        "cgcn",
        "community-based layerwise distributed GCN training (ADMM)",
    )
    .opt("dataset", Some("synth-computers"), "dataset name or .cgnp path")
    .opt("scale", Some("0.25"), "synthetic dataset node-count scale (0,1]")
    .opt("hidden", Some("256"), "hidden units per GCN layer")
    .opt("layers", Some("2"), "GCN layers L")
    .opt("epochs", Some("50"), "training epochs")
    .opt("communities", Some("3"), "number of communities M (1 = serial)")
    .opt("method", Some("admm"), "train method: admm|gd|adam|adagrad|adadelta")
    .opt("partition", Some("metis"), "partitioner: metis|random|bfs")
    .opt("rho", Some("auto"), "ADMM rho (auto = paper default per dataset)")
    .opt("nu", Some("auto"), "ADMM nu (auto = paper default per dataset)")
    .opt("lr", Some("auto"), "baseline learning rate (auto = paper default)")
    .opt("seed", Some("17"), "random seed")
    .opt("out", Some(""), "output path (plan json / csv / cgnp)")
    .opt("transport", Some("local"), "agent transport: local|tcp")
    .opt("exec", Some("serial"), "agent execution: serial|threads (threads = real shared-memory parallelism)")
    .opt("threads", Some("0"), "worker threads for --exec threads (0 = all cores); with --exec serial, sets native backend op threads (0 = 1, the deterministic single-thread baseline)")
    .opt("backend", Some("auto"), "compute backend: auto|native|xla")
    .opt("link-mbps", Some("10000"), "simulated link bandwidth (Mbit/s; default models the paper's same-machine agents)")
    .opt("link-lat-us", Some("100"), "simulated link latency (microseconds)")
    .opt("listen", Some(""), "worker: leader address to connect to")
    .opt("worker-idx", Some("0"), "worker: community index owned by this process")
    .flag("parallel-layers", "ADMM: update W layers in parallel (paper Alg. 1)")
    .flag("csv", "emit per-epoch CSV to stdout");
    let args = spec.parse_env();

    let code = match args.subcommand() {
        Some("plan") => cgcn::cmd::cmd_plan(&args),
        Some("data") => cgcn::cmd::cmd_data(&args),
        Some("train") => cgcn::cmd::cmd_train(&args),
        Some("artifacts") => cgcn::cmd::cmd_artifacts(&args),
        Some("worker") => cgcn::cmd::cmd_worker(&args),
        other => {
            eprintln!(
                "unknown or missing subcommand {:?}\n\n{}",
                other,
                spec.usage()
            );
            eprintln!("subcommands: plan | data | train | artifacts | worker");
            2
        }
    };
    std::process::exit(code);
}
