//! Experiment configuration and artifact shape planning.
//!
//! This module is the single source of truth for every shape that crosses
//! the Python/Rust boundary: `cgcn plan` serialises the artifact shape list
//! to `configs/artifacts.json`, `python -m compile.aot` lowers exactly
//! those shapes, and the [`crate::runtime`] looks artifacts up by the same
//! signatures. The padding rules here and the partitioner's balance cap
//! use the same constant, so a valid partition always fits its padded
//! artifact.

use crate::util::json::Json;

/// Row-tile multiple: community/global row counts are padded to this, so
/// Pallas BlockSpecs never see ragged edges (128 = TPU lane count).
pub const ROW_TILE: usize = 128;

/// Allowed partition imbalance — must match `partition::metis`'s EPS.
pub const BALANCE_EPS: f64 = 0.10;

/// Round up to the row tile.
pub fn pad_to_tile(n: usize) -> usize {
    n.div_ceil(ROW_TILE) * ROW_TILE
}

/// Hard cap on community size for an (n, m) partition.
pub fn community_cap(n: usize, m: usize) -> usize {
    if m == 1 {
        n
    } else {
        ((1.0 + BALANCE_EPS) * n as f64 / m as f64).ceil() as usize
    }
}

/// Padded per-community row count for an (n, m) partition.
pub fn padded_community(n: usize, m: usize) -> usize {
    pad_to_tile(community_cap(n, m))
}

/// Padded global row count.
pub fn padded_global(n: usize) -> usize {
    pad_to_tile(n)
}

/// Hyper-parameters of one training run (paper §4 settings by default).
#[derive(Clone, Debug)]
pub struct HyperParams {
    /// Hidden units per GCN layer (paper: 1000; fast profile: 256).
    pub hidden: usize,
    /// Number of GCN layers L (paper: 2). L > 2 exercises the eq.-5 path.
    pub layers: usize,
    /// ADMM penalty ρ (paper: 1e-3 computers / 1e-4 photo).
    pub rho: f32,
    /// Relaxation weight ν (paper: same values as ρ).
    pub nu: f32,
    /// Communities M (paper: 3).
    pub communities: usize,
    /// Training epochs (paper: 50).
    pub epochs: usize,
    /// FISTA iterations inside the Z_L artifact.
    pub fista_steps: usize,
    /// RNG seed for init / partitioning.
    pub seed: u64,
}

impl HyperParams {
    /// Paper defaults for a named dataset (ρ=ν=1e-3 for computers,
    /// 1e-4 for photo; 1e-3 otherwise).
    pub fn for_dataset(name: &str) -> HyperParams {
        let rho = if name.contains("photo") { 1e-4 } else { 1e-3 };
        HyperParams {
            hidden: 256,
            layers: 2,
            rho,
            nu: rho,
            communities: 3,
            epochs: 50,
            fista_steps: 10,
            seed: 17,
        }
    }

    /// Layer dimension chain C_0..C_L for a dataset with the given
    /// feature/class counts.
    pub fn dims(&self, features: usize, classes: usize) -> Vec<usize> {
        let mut d = vec![features];
        for _ in 1..self.layers {
            d.push(self.hidden);
        }
        d.push(classes);
        d
    }
}

/// One dataset's shape requirements for planning.
#[derive(Clone, Debug)]
pub struct PlanDataset {
    pub name: String,
    pub nodes: usize,
    pub features: usize,
    pub classes: usize,
    pub hidden: usize,
    pub layers: usize,
    pub fista_steps: usize,
    /// Community counts to support (1 = serial).
    pub ms: Vec<usize>,
}

impl PlanDataset {
    fn dims(&self) -> Vec<usize> {
        let mut d = vec![self.features];
        for _ in 1..self.layers {
            d.push(self.hidden);
        }
        d.push(self.classes);
        d
    }

    /// All padded row counts this dataset needs artifacts for.
    pub fn row_counts(&self) -> Vec<usize> {
        let mut ns = vec![padded_global(self.nodes)];
        for &m in &self.ms {
            if m > 1 {
                ns.push(padded_community(self.nodes, m));
            }
        }
        ns.sort_unstable();
        ns.dedup();
        ns
    }
}

/// Artifact spec mirrored by `aot.artifact_sig` on the Python side.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct ArtifactSpec {
    pub entry: &'static str,
    pub n: usize,
    /// (a, b) for matmul-shaped entries; 0 when unused.
    pub a: usize,
    pub b: usize,
    /// c for single-dim entries; 0 when unused.
    pub c: usize,
    /// FISTA steps for zl_fista; 0 when unused.
    pub steps: usize,
    pub pallas: bool,
}

impl ArtifactSpec {
    /// The artifact signature — must match `aot.artifact_sig`.
    pub fn sig(&self) -> String {
        let mut parts = Vec::new();
        parts.push(format!("n{}", self.n));
        if self.a > 0 {
            parts.push(format!("a{}", self.a));
        }
        if self.b > 0 {
            parts.push(format!("b{}", self.b));
        }
        if self.c > 0 {
            parts.push(format!("c{}", self.c));
        }
        if self.steps > 0 {
            parts.push(format!("steps{}", self.steps));
        }
        format!("{}__{}", self.entry, parts.join("_"))
    }

    fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("entry", Json::str(self.entry)),
            ("n", Json::num(self.n as f64)),
            ("pallas", Json::Bool(self.pallas)),
        ];
        if self.a > 0 {
            pairs.push(("a", Json::num(self.a as f64)));
        }
        if self.b > 0 {
            pairs.push(("b", Json::num(self.b as f64)));
        }
        if self.c > 0 {
            pairs.push(("c", Json::num(self.c as f64)));
        }
        if self.steps > 0 {
            pairs.push(("steps", Json::num(self.steps as f64)));
        }
        Json::obj(pairs)
    }
}

fn nab(entry: &'static str, n: usize, a: usize, b: usize, pallas: bool) -> ArtifactSpec {
    ArtifactSpec {
        entry,
        n,
        a,
        b,
        c: 0,
        steps: 0,
        pallas,
    }
}

fn nc(entry: &'static str, n: usize, c: usize, pallas: bool) -> ArtifactSpec {
    ArtifactSpec {
        entry,
        n,
        a: 0,
        b: 0,
        c,
        steps: 0,
        pallas,
    }
}

/// Pallas-interpret grids get expensive on CPU above this row count; the
/// kernel story is identical either way (same math, same artifact
/// interface), so larger shapes default to the plain-XLA lowering. See
/// EXPERIMENTS.md §Perf for the measured crossover.
pub const PALLAS_MAX_ROWS: usize = 512;

/// Enumerate every artifact a dataset needs (one call covers serial,
/// parallel and baseline training plus eval).
pub fn dataset_artifacts(ds: &PlanDataset) -> Vec<ArtifactSpec> {
    let dims = ds.dims();
    let l = dims.len() - 1; // number of layers
    let mut out = Vec::new();
    for &n in &ds.row_counts() {
        let pallas = n <= PALLAS_MAX_ROWS;
        for layer in 1..=l {
            let (a, b) = (dims[layer - 1], dims[layer]);
            // Matmul primitives used by both ADMM phases and baselines:
            // V = Z W (mm_nn), gW = Zᵀ(ÃR) (mm_tn), Gz = (ÃR)Wᵀ (mm_bt).
            out.push(nab("mm_nn", n, a, b, pallas));
            out.push(nab("mm_tn", n, a, b, pallas));
            out.push(nab("mm_bt", n, a, b, pallas));
            if layer < l {
                out.push(nab("fwd_relu", n, a, b, pallas));
                out.push(nab("bp_hidden_grads", n, a, b, pallas));
            } else {
                out.push(nab("bp_out_grads", n, a, b, pallas));
            }
        }
        // Elementwise residual/value entries per distinct layer width.
        for layer in 1..l {
            let c = dims[layer];
            out.push(nc("hidden_residual", n, c, pallas));
            out.push(nc("hidden_phi", n, c, pallas));
            out.push(nc("z_combine", n, c, pallas));
            out.push(nc("z_prox_val", n, c, pallas));
        }
        let classes = dims[l];
        out.push(nc("out_residual", n, classes, pallas));
        out.push(nc("out_phi", n, classes, pallas));
        out.push(ArtifactSpec {
            entry: "zl_fista",
            n,
            a: 0,
            b: 0,
            c: classes,
            steps: ds.fista_steps,
            pallas,
        });
        out.push(nc("xent_loss", n, classes, pallas));
    }
    out
}

/// The default plan: test fixtures + fast-profile synthetic datasets.
pub fn default_plan_datasets(hidden: usize, scale: f64, ms: Vec<usize>) -> Vec<PlanDataset> {
    use crate::data::synth;
    let scaled = |spec: &synth::SynthSpec| -> usize {
        // Must mirror data::synth::generate's node-count rule.
        ((spec.nodes as f64 * scale).round() as usize).max(spec.classes * 8)
    };
    vec![
        // Tiny fixtures for rust integration tests.
        PlanDataset {
            name: "fig1".into(),
            nodes: 9,
            features: 4,
            classes: 3,
            hidden: 8,
            layers: 2,
            fista_steps: 10,
            ms: ms.clone(),
        },
        PlanDataset {
            name: "caveman".into(),
            nodes: 48,
            features: 6,
            classes: 2,
            hidden: 8,
            layers: 2,
            fista_steps: 10,
            ms: ms.clone(),
        },
        // Three-layer fixture exercising the eq.-5 (hidden Z) path.
        PlanDataset {
            name: "caveman-l3".into(),
            nodes: 48,
            features: 6,
            classes: 2,
            hidden: 8,
            layers: 3,
            fista_steps: 10,
            ms: ms.clone(),
        },
        PlanDataset {
            name: "synth-computers".into(),
            nodes: scaled(&synth::AMAZON_COMPUTERS),
            features: synth::AMAZON_COMPUTERS.features,
            classes: synth::AMAZON_COMPUTERS.classes,
            hidden,
            layers: 2,
            fista_steps: 10,
            ms: ms.clone(),
        },
        PlanDataset {
            name: "synth-photo".into(),
            nodes: scaled(&synth::AMAZON_PHOTO),
            features: synth::AMAZON_PHOTO.features,
            classes: synth::AMAZON_PHOTO.classes,
            hidden,
            layers: 2,
            fista_steps: 10,
            ms,
        },
    ]
}

/// Serialise a plan to the configs/artifacts.json format aot.py consumes.
pub fn plan_to_json(datasets: &[PlanDataset]) -> Json {
    let mut specs = Vec::new();
    let mut seen = std::collections::HashSet::new();
    for ds in datasets {
        for spec in dataset_artifacts(ds) {
            if seen.insert(spec.sig()) {
                specs.push(spec);
            }
        }
    }
    specs.sort_by_key(|s| s.sig());
    Json::obj(vec![
        ("use_pallas", Json::Bool(true)),
        ("fista_steps", Json::num(10.0)),
        (
            "artifacts",
            Json::arr(specs.iter().map(|s| s.to_json()).collect()),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn padding_rules() {
        assert_eq!(pad_to_tile(1), 128);
        assert_eq!(pad_to_tile(128), 128);
        assert_eq!(pad_to_tile(129), 256);
        assert_eq!(community_cap(100, 1), 100);
        assert_eq!(community_cap(300, 3), 110);
        assert_eq!(padded_community(300, 3), 128);
        assert_eq!(padded_global(383), 384);
    }

    #[test]
    fn partition_always_fits_padded_community() {
        // Any valid partition (imbalance <= 1+EPS) fits the padded size.
        use crate::data::fixtures;
        use crate::partition::{partition, Method};
        let ds = fixtures::caveman(30, 2);
        for m in [2, 3, 4] {
            let p = partition(&ds.graph, m, Method::Metis, 5);
            let cap = community_cap(ds.n(), m);
            for s in p.sizes() {
                assert!(s <= cap, "community size {s} > cap {cap} (m={m})");
            }
        }
    }

    #[test]
    fn sig_format_matches_python_side() {
        // Mirrors aot.artifact_sig ordering: n, a, b, c, steps.
        let s = nab("w_grad_hidden", 384, 745, 64, false);
        assert_eq!(s.sig(), "w_grad_hidden__n384_a745_b64");
        let f = ArtifactSpec {
            entry: "zl_fista",
            n: 256,
            a: 0,
            b: 0,
            c: 8,
            steps: 10,
            pallas: true,
        };
        assert_eq!(f.sig(), "zl_fista__n256_c8_steps10");
    }

    #[test]
    fn two_layer_dataset_artifact_inventory() {
        let ds = PlanDataset {
            name: "t".into(),
            nodes: 100,
            features: 16,
            classes: 4,
            hidden: 8,
            layers: 2,
            fista_steps: 10,
            ms: vec![1, 3],
        };
        let arts = dataset_artifacts(&ds);
        assert_eq!(ds.row_counts(), vec![128]);
        let entries: std::collections::HashSet<_> = arts.iter().map(|a| a.entry).collect();
        for e in [
            "mm_nn",
            "mm_tn",
            "mm_bt",
            "fwd_relu",
            "hidden_residual",
            "hidden_phi",
            "out_residual",
            "out_phi",
            "z_combine",
            "z_prox_val",
            "zl_fista",
            "bp_out_grads",
            "bp_hidden_grads",
            "xent_loss",
        ] {
            assert!(entries.contains(e), "missing entry {e}");
        }
    }

    #[test]
    fn three_layer_dataset_has_hidden_width_entries_per_layer() {
        let ds = PlanDataset {
            name: "t3".into(),
            nodes: 100,
            features: 16,
            classes: 4,
            hidden: 8,
            layers: 3,
            fista_steps: 10,
            ms: vec![1],
        };
        let arts = dataset_artifacts(&ds);
        // mm primitives exist for every layer dim pair.
        for (a, b) in [(16, 8), (8, 8), (8, 4)] {
            assert!(
                arts.iter()
                    .any(|s| s.entry == "mm_nn" && s.a == a && s.b == b),
                "missing mm_nn {a}x{b}"
            );
        }
        assert!(arts.iter().any(|s| s.entry == "hidden_residual" && s.c == 8));
    }

    #[test]
    fn plan_json_is_parseable_and_deduped() {
        let plan = plan_to_json(&default_plan_datasets(64, 0.05, vec![1, 3]));
        let text = plan.to_pretty();
        let back = Json::parse(&text).unwrap();
        let arts = back.get("artifacts").as_arr().unwrap();
        assert!(arts.len() > 20);
        let mut sigs = std::collections::HashSet::new();
        for a in arts {
            let key = format!(
                "{}_{}_{}_{}_{}_{}",
                a.get("entry").as_str().unwrap(),
                a.get("n").as_f64().unwrap(),
                a.get("a").as_f64().unwrap_or(0.0),
                a.get("b").as_f64().unwrap_or(0.0),
                a.get("c").as_f64().unwrap_or(0.0),
                a.get("steps").as_f64().unwrap_or(0.0),
            );
            assert!(sigs.insert(key), "duplicate artifact in plan");
        }
    }
}
