//! Host-side dense f32 matrices.
//!
//! The training hot path runs dense math inside AOT-compiled XLA artifacts;
//! this module provides the host-side complement: optimizer state, weight
//! init, message buffers, accuracy evaluation, and a reference matmul used
//! to cross-check artifact outputs in tests. Row-major, f32 — matching the
//! layout the runtime hands to PJRT literals, so conversions are memcpys.

mod matrix;
mod ops;
pub mod simd;

pub use matrix::Matrix;
pub use ops::{argmax, argmax_rows, masked_cross_entropy, relu, relu_mask, softmax_rows};
