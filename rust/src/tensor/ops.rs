//! Activation / classification helpers over [`Matrix`].
//!
//! These are host-side reference implementations: the artifact-compiled
//! versions (L1 Pallas kernels) are the hot path, and tests assert the two
//! agree. ReLU'(0) is defined as 0 everywhere (matching `ref.py`), which is
//! what makes zero-padded community rows provably inert (DESIGN.md §4).

use super::Matrix;

/// Elementwise ReLU.
pub fn relu(m: &Matrix) -> Matrix {
    m.map(|x| x.max(0.0))
}

/// ReLU derivative mask: 1 where x > 0 else 0 (subgradient 0 at 0).
pub fn relu_mask(m: &Matrix) -> Matrix {
    m.map(|x| if x > 0.0 { 1.0 } else { 0.0 })
}

/// Numerically-stabilised row softmax.
pub fn softmax_rows(m: &Matrix) -> Matrix {
    let mut out = Matrix::zeros(m.rows(), m.cols());
    for r in 0..m.rows() {
        let row = m.row(r);
        let max = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut sum = 0.0f32;
        let orow = out.row_mut(r);
        for (o, &x) in orow.iter_mut().zip(row) {
            let e = (x - max).exp();
            *o = e;
            sum += e;
        }
        let inv = 1.0 / sum;
        for o in orow.iter_mut() {
            *o *= inv;
        }
    }
    out
}

/// Argmax of one row: first index of the maximum (NaN-safe — `>` never
/// holds for NaN, so NaN entries are skipped rather than panicking).
pub fn argmax(row: &[f32]) -> usize {
    let mut best = 0;
    for (c, &x) in row.iter().enumerate() {
        if x > row[best] {
            best = c;
        }
    }
    best
}

/// Row-wise argmax (predicted class per node).
pub fn argmax_rows(m: &Matrix) -> Vec<usize> {
    (0..m.rows()).map(|r| argmax(m.row(r))).collect()
}

/// Masked mean softmax cross-entropy: `mask` selects the labeled training
/// rows; `labels[r]` is the class index. Returns (loss, gradient wrt logits)
/// where the gradient is `(softmax - onehot) * mask / mask_count` — the same
/// normalisation the `softmax_xent` Pallas kernel uses.
pub fn masked_cross_entropy(logits: &Matrix, labels: &[usize], mask: &[f32]) -> (f64, Matrix) {
    assert_eq!(logits.rows(), labels.len());
    assert_eq!(logits.rows(), mask.len());
    let p = softmax_rows(logits);
    let count: f32 = mask.iter().sum();
    let denom = if count > 0.0 { count } else { 1.0 };
    let mut grad = Matrix::zeros(logits.rows(), logits.cols());
    let mut loss = 0.0f64;
    for r in 0..logits.rows() {
        if mask[r] == 0.0 {
            continue;
        }
        let y = labels[r];
        loss += -(p.at(r, y).max(1e-30) as f64).ln() * mask[r] as f64;
        let grow = grad.row_mut(r);
        for (c, g) in grow.iter_mut().enumerate() {
            let onehot = if c == y { 1.0 } else { 0.0 };
            *g = (p.at(r, c) - onehot) * mask[r] / denom;
        }
    }
    (loss / denom as f64, grad)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn relu_and_mask() {
        let m = Matrix::from_vec(1, 4, vec![-1.0, 0.0, 2.0, -0.5]);
        assert_eq!(relu(&m).data(), &[0.0, 0.0, 2.0, 0.0]);
        assert_eq!(relu_mask(&m).data(), &[0.0, 0.0, 1.0, 0.0]);
    }

    #[test]
    fn softmax_rows_sum_to_one_and_shift_invariant() {
        let mut rng = Rng::new(5);
        let m = Matrix::glorot(6, 9, &mut rng).scale(10.0);
        let s = softmax_rows(&m);
        for r in 0..6 {
            let sum: f32 = s.row(r).iter().sum();
            assert!((sum - 1.0).abs() < 1e-5, "row {r} sums to {sum}");
        }
        let shifted = m.map(|x| x + 123.0);
        assert!(softmax_rows(&shifted).max_abs_diff(&s) < 1e-5);
    }

    #[test]
    fn argmax_simple() {
        let m = Matrix::from_vec(2, 3, vec![0.1, 0.9, 0.0, 5.0, -1.0, 2.0]);
        assert_eq!(argmax_rows(&m), vec![1, 0]);
    }

    #[test]
    fn cross_entropy_perfect_prediction_loss_small() {
        // Strong correct logits => small loss, small gradient.
        let logits = Matrix::from_vec(2, 2, vec![10.0, -10.0, -10.0, 10.0]);
        let (loss, grad) = masked_cross_entropy(&logits, &[0, 1], &[1.0, 1.0]);
        assert!(loss < 1e-6, "loss={loss}");
        assert!(grad.abs_max() < 1e-6);
    }

    #[test]
    fn cross_entropy_uniform_is_log_c() {
        let logits = Matrix::zeros(3, 4);
        let (loss, _) = masked_cross_entropy(&logits, &[0, 1, 2], &[1.0, 1.0, 1.0]);
        assert!((loss - (4.0f64).ln()).abs() < 1e-6);
    }

    #[test]
    fn cross_entropy_respects_mask() {
        let mut rng = Rng::new(6);
        let logits = Matrix::glorot(4, 3, &mut rng);
        let labels = [0, 1, 2, 0];
        let (_, grad) = masked_cross_entropy(&logits, &labels, &[1.0, 0.0, 1.0, 0.0]);
        assert!(grad.row(1).iter().all(|&g| g == 0.0));
        assert!(grad.row(3).iter().all(|&g| g == 0.0));
        assert!(grad.row(0).iter().any(|&g| g != 0.0));
    }

    #[test]
    fn cross_entropy_gradient_matches_finite_difference() {
        let mut rng = Rng::new(7);
        let logits = Matrix::glorot(3, 4, &mut rng);
        let labels = [2, 0, 3];
        let mask = [1.0, 1.0, 0.0];
        let (_, grad) = masked_cross_entropy(&logits, &labels, &mask);
        let eps = 1e-3f32;
        for r in 0..3 {
            for c in 0..4 {
                let mut plus = logits.clone();
                plus.set(r, c, logits.at(r, c) + eps);
                let mut minus = logits.clone();
                minus.set(r, c, logits.at(r, c) - eps);
                let (lp, _) = masked_cross_entropy(&plus, &labels, &mask);
                let (lm, _) = masked_cross_entropy(&minus, &labels, &mask);
                let fd = ((lp - lm) / (2.0 * eps as f64)) as f32;
                assert!(
                    (fd - grad.at(r, c)).abs() < 1e-3,
                    "fd mismatch at ({r},{c}): fd={fd} grad={}",
                    grad.at(r, c)
                );
            }
        }
    }
}
