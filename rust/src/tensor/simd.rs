//! The 8-wide f32 SIMD microkernel behind the dense matmul family.
//!
//! One primitive covers every dense inner loop ([`Matrix::matmul`],
//! `mm_nn_rows`, `mm_tn_rows` and the transposed-strip `mm_bt` path in
//! `runtime/backend.rs`): [`axpy`], the row update `o[j] += a · b[j]`.
//! The vector form lifts 8 output columns (`j` lanes) per AVX register
//! with *separate* `vmulps`/`vaddps` — never `vfmadd`, because fusing
//! skips the intermediate rounding of the product and would change the
//! result bits. Each output element therefore accumulates through the
//! exact IEEE operation sequence the scalar loop performs, in the same
//! ascending-`k` order (lanes are independent elements; vectorising
//! across `j` reorders nothing), so SIMD-on, SIMD-off, serial and any
//! thread count are all bitwise identical. The `cols % 8` remainder
//! lanes run the scalar loop. See DESIGN.md §12.
//!
//! Gate: AVX is detected once per process (`is_x86_feature_detected!`);
//! `CGCN_SIMD=off` (or `0`/`false`) is the escape hatch, and
//! [`force`] flips the gate in-process for A/B tests and benches —
//! forcing *on* is clamped to hardware support, so the override can
//! change code paths but never results. Backends snapshot the gate at
//! construction ([`enabled`]); [`Matrix::matmul`] reads it per call.
//!
//! [`Matrix::matmul`]: crate::tensor::Matrix::matmul

use std::sync::atomic::{AtomicU8, Ordering};

const UNSET: u8 = 0;
const ON: u8 = 1;
const OFF: u8 = 2;

/// Process-wide gate: lazily initialised from detection + `CGCN_SIMD`,
/// overridable via [`force`].
static GATE: AtomicU8 = AtomicU8::new(UNSET);

/// True when the host CPU supports the AVX ops the microkernel uses.
/// Always false off x86-64.
pub fn detected() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        is_x86_feature_detected!("avx")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

fn env_off() -> bool {
    matches!(
        std::env::var("CGCN_SIMD").as_deref(),
        Ok("off") | Ok("0") | Ok("false")
    )
}

/// Whether the vector path is active: AVX detected and not disabled by
/// `CGCN_SIMD=off` (or a [`force`] override). Cached after first use.
pub fn enabled() -> bool {
    match GATE.load(Ordering::Relaxed) {
        ON => true,
        OFF => false,
        _ => {
            let on = detected() && !env_off();
            let v = if on { ON } else { OFF };
            // compare_exchange so a racing `force` is never overwritten by
            // a stale lazy init.
            match GATE.compare_exchange(UNSET, v, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => on,
                Err(cur) => cur == ON,
            }
        }
    }
}

/// Override the gate in-process (tests/benches A/B `CGCN_SIMD` without
/// re-exec). Forcing `true` is clamped to hardware support; since the
/// vector path is bitwise identical to scalar, flipping this mid-run is
/// observable only in speed.
pub fn force(on: bool) {
    GATE.store(if on && detected() { ON } else { OFF }, Ordering::Relaxed);
}

/// `orow[j] += a * brow[j]` over the zipped length. With `simd` the 8-lane
/// AVX body runs (caller must only pass `simd = true` under [`enabled`] /
/// [`detected`] — backends snapshot that at construction); otherwise the
/// scalar loop, which is the exact inner loop the pre-SIMD kernels ran.
#[inline]
pub fn axpy(simd: bool, orow: &mut [f32], a: f32, brow: &[f32]) {
    #[cfg(target_arch = "x86_64")]
    if simd {
        // SAFETY: `simd` is only true when AVX was detected (the gate and
        // `NativeBackend` both clamp on `detected()`).
        unsafe { axpy_avx(orow, a, brow) };
        return;
    }
    let _ = simd;
    for (o, &b) in orow.iter_mut().zip(brow) {
        *o += a * b;
    }
}

/// 8-lane AVX body of [`axpy`]: broadcast `a`, then per group of 8 columns
/// load-mul-add-store. Mul and add stay separate instructions so each lane
/// rounds the product before the sum exactly like the scalar `a * b` then
/// `+=` — do not "optimise" this into `_mm256_fmadd_ps`.
///
/// SAFETY: caller guarantees the CPU supports AVX.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx")]
unsafe fn axpy_avx(orow: &mut [f32], a: f32, brow: &[f32]) {
    use std::arch::x86_64::{
        _mm256_add_ps, _mm256_loadu_ps, _mm256_mul_ps, _mm256_set1_ps, _mm256_storeu_ps,
    };
    let n = orow.len().min(brow.len());
    let av = _mm256_set1_ps(a);
    let op = orow.as_mut_ptr();
    let bp = brow.as_ptr();
    let mut j = 0usize;
    while j + 8 <= n {
        let bv = _mm256_loadu_ps(bp.add(j));
        let ov = _mm256_loadu_ps(op.add(j));
        let prod = _mm256_mul_ps(av, bv);
        _mm256_storeu_ps(op.add(j), _mm256_add_ps(ov, prod));
        j += 8;
    }
    while j < n {
        *op.add(j) += a * *bp.add(j);
        j += 1;
    }
}

/// Debug-build guard for the finite-operand kernel contract
/// (`ComputeBackend` docs, DESIGN.md §12): the zero-skip matmuls drop
/// `0 · x` terms, which only equals real IEEE matmul when every operand is
/// finite (`0 · ±inf = NaN`). Release builds skip the scan; a NaN entering
/// training under `debug_assertions` panics here instead of being silently
/// masked by the skip.
#[inline]
pub fn debug_assert_finite(tag: &str, data: &[f32]) {
    if cfg!(debug_assertions) {
        if let Some((i, v)) = data.iter().enumerate().find(|(_, v)| !v.is_finite()) {
            panic!(
                "{tag}: non-finite operand {v} at flat index {i} violates the \
                 finite-operand kernel contract (DESIGN.md §12)"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scalar_axpy(orow: &mut [f32], a: f32, brow: &[f32]) {
        for (o, &b) in orow.iter_mut().zip(brow) {
            *o += a * b;
        }
    }

    #[test]
    fn axpy_simd_is_bitwise_identical_to_scalar_at_every_remainder() {
        // Lengths 0..=33 cover len < 8 and every len % 8; values include
        // denormals and awkward magnitudes so rounding actually differs if
        // anyone fuses the mul-add. Compared via to_bits: exact or bust.
        let mut rng = crate::util::rng::Rng::new(0x51AD);
        for len in 0..=33usize {
            let a = rng.gen_f32() * 3.0 - 1.5;
            let brow: Vec<f32> = (0..len).map(|_| rng.gen_f32() * 2e3 - 1e3).collect();
            let base: Vec<f32> = (0..len).map(|_| rng.gen_f32() * 1e-3).collect();
            let mut want = base.clone();
            scalar_axpy(&mut want, a, &brow);
            let mut got = base.clone();
            axpy(detected(), &mut got, a, &brow);
            for (j, (g, w)) in got.iter().zip(&want).enumerate() {
                assert_eq!(g.to_bits(), w.to_bits(), "len={len} lane {j}");
            }
        }
    }

    #[test]
    fn force_clamps_to_detection() {
        force(true);
        assert_eq!(enabled(), detected(), "forcing on must clamp to hardware");
        force(false);
        assert!(!enabled());
        force(true); // leave the gate in its default-on state for other tests
    }

    #[test]
    fn finite_guard_trips_on_nan_in_debug() {
        debug_assert_finite("ok", &[0.0, -1.5, 3.0e37]);
        if cfg!(debug_assertions) {
            let r = std::panic::catch_unwind(|| {
                debug_assert_finite("bad", &[1.0, f32::NAN]);
            });
            assert!(r.is_err(), "NaN must trip the debug finite guard");
        }
    }
}
