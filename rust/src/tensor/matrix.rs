//! The dense row-major f32 matrix type.

use crate::util::rng::Rng;
use std::fmt;

/// Dense row-major f32 matrix.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Matrix({}x{})", self.rows, self.cols)?;
        if self.rows * self.cols <= 36 {
            for r in 0..self.rows {
                write!(f, "\n  {:?}", &self.data[r * self.cols..(r + 1) * self.cols])?;
            }
        }
        Ok(())
    }
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Matrix {
        assert_eq!(
            data.len(),
            rows * cols,
            "from_vec: {}x{} needs {} elements, got {}",
            rows,
            cols,
            rows * cols,
            data.len()
        );
        Matrix { rows, cols, data }
    }

    /// Build from a closure over (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Matrix {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Matrix { rows, cols, data }
    }

    /// Glorot/Xavier-uniform init — the standard GCN weight init [Kipf'17].
    pub fn glorot(rows: usize, cols: usize, rng: &mut Rng) -> Matrix {
        let limit = (6.0 / (rows + cols) as f64).sqrt() as f32;
        Matrix::from_fn(rows, cols, |_, _| (rng.gen_f32() * 2.0 - 1.0) * limit)
    }

    pub fn rows(&self) -> usize {
        self.rows
    }
    pub fn cols(&self) -> usize {
        self.cols
    }
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }
    pub fn data(&self) -> &[f32] {
        &self.data
    }
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Copy `src`'s rows into `self` starting at `row_off` (shape-checked).
    pub fn copy_rows_from(&mut self, src: &Matrix, row_off: usize) {
        assert_eq!(self.cols, src.cols);
        assert!(row_off + src.rows <= self.rows);
        let start = row_off * self.cols;
        self.data[start..start + src.data.len()].copy_from_slice(&src.data);
    }

    /// Extract rows `[lo, hi)` as a new matrix.
    pub fn slice_rows(&self, lo: usize, hi: usize) -> Matrix {
        assert!(lo <= hi && hi <= self.rows);
        Matrix {
            rows: hi - lo,
            cols: self.cols,
            data: self.data[lo * self.cols..hi * self.cols].to_vec(),
        }
    }

    /// Gather the given rows into a new matrix (used to regroup nodes by
    /// community).
    pub fn gather_rows(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(idx.len(), self.cols);
        for (i, &r) in idx.iter().enumerate() {
            out.row_mut(i).copy_from_slice(self.row(r));
        }
        out
    }

    /// Zero-pad to `new_rows` rows (new_rows >= rows).
    pub fn pad_rows(&self, new_rows: usize) -> Matrix {
        assert!(new_rows >= self.rows);
        let mut out = Matrix::zeros(new_rows, self.cols);
        out.copy_rows_from(self, 0);
        out
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness.
        const B: usize = 32;
        for rb in (0..self.rows).step_by(B) {
            for cb in (0..self.cols).step_by(B) {
                for r in rb..(rb + B).min(self.rows) {
                    for c in cb..(cb + B).min(self.cols) {
                        out.data[c * self.rows + r] = self.data[r * self.cols + c];
                    }
                }
            }
        }
        out
    }

    /// Reference dense matmul (ikj loop order, row-major friendly). Used
    /// for verification and small host-side products; the training path
    /// uses XLA artifacts instead.
    ///
    /// The inner `j` loop runs the 8-wide [`crate::tensor::simd`] axpy
    /// when the gate is on; each output element accumulates in the same
    /// ascending-`k` IEEE sequence either way, so results are bitwise
    /// identical with SIMD on or off. Operands must be finite — the
    /// zero-skip drops `0 · x` terms (debug builds assert this).
    pub fn matmul(&self, rhs: &Matrix) -> Matrix {
        assert_eq!(
            self.cols, rhs.rows,
            "matmul shape mismatch: {}x{} @ {}x{}",
            self.rows, self.cols, rhs.rows, rhs.cols
        );
        crate::tensor::simd::debug_assert_finite("matmul lhs", &self.data);
        crate::tensor::simd::debug_assert_finite("matmul rhs", &rhs.data);
        let simd = crate::tensor::simd::enabled();
        let mut out = Matrix::zeros(self.rows, rhs.cols);
        let n = rhs.cols;
        for i in 0..self.rows {
            let out_row = &mut out.data[i * n..(i + 1) * n];
            for k in 0..self.cols {
                let a = self.data[i * self.cols + k];
                if a == 0.0 {
                    continue;
                }
                let rhs_row = &rhs.data[k * n..(k + 1) * n];
                crate::tensor::simd::axpy(simd, out_row, a, rhs_row);
            }
        }
        out
    }

    // ---- elementwise ------------------------------------------------------

    pub fn add(&self, rhs: &Matrix) -> Matrix {
        self.zip(rhs, |a, b| a + b)
    }
    pub fn sub(&self, rhs: &Matrix) -> Matrix {
        self.zip(rhs, |a, b| a - b)
    }
    pub fn hadamard(&self, rhs: &Matrix) -> Matrix {
        self.zip(rhs, |a, b| a * b)
    }
    pub fn scale(&self, s: f32) -> Matrix {
        self.map(|a| a * s)
    }

    pub fn add_assign(&mut self, rhs: &Matrix) {
        assert_eq!(self.shape(), rhs.shape());
        for (a, b) in self.data.iter_mut().zip(&rhs.data) {
            *a += b;
        }
    }

    /// self += s * rhs (axpy).
    pub fn axpy(&mut self, s: f32, rhs: &Matrix) {
        assert_eq!(self.shape(), rhs.shape());
        for (a, b) in self.data.iter_mut().zip(&rhs.data) {
            *a += s * b;
        }
    }

    /// self += s * (a − b), without materialising the difference (the
    /// ADMM dual update `U += ρ (Z − Q)` used to clone Z for this).
    /// Bitwise-equivalent to `clone a; axpy(-1.0, b); axpy(s, ..)`:
    /// IEEE negation is exact, so `x + (-1.0)·y == x − y`.
    pub fn axpy_sub(&mut self, s: f32, a: &Matrix, b: &Matrix) {
        assert_eq!(self.shape(), a.shape());
        assert_eq!(self.shape(), b.shape());
        for ((u, x), y) in self.data.iter_mut().zip(&a.data).zip(&b.data) {
            *u += s * (x - y);
        }
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    fn zip(&self, rhs: &Matrix, f: impl Fn(f32, f32) -> f32) -> Matrix {
        assert_eq!(
            self.shape(),
            rhs.shape(),
            "elementwise shape mismatch: {:?} vs {:?}",
            self.shape(),
            rhs.shape()
        );
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .zip(&rhs.data)
                .map(|(&a, &b)| f(a, b))
                .collect(),
        }
    }

    // ---- reductions ---------------------------------------------------------

    pub fn frob_norm_sq(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    pub fn frob_norm(&self) -> f64 {
        self.frob_norm_sq().sqrt()
    }

    /// Frobenius inner product <self, rhs>.
    pub fn dot(&self, rhs: &Matrix) -> f64 {
        assert_eq!(self.shape(), rhs.shape());
        self.data
            .iter()
            .zip(&rhs.data)
            .map(|(&a, &b)| a as f64 * b as f64)
            .sum()
    }

    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// Max |a-b| against another matrix (test helper).
    pub fn max_abs_diff(&self, rhs: &Matrix) -> f32 {
        assert_eq!(self.shape(), rhs.shape());
        self.data
            .iter()
            .zip(&rhs.data)
            .fold(0.0f32, |m, (&a, &b)| m.max((a - b).abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_sub_matches_clone_axpy_bitwise() {
        let mut rng = Rng::new(7);
        let a = Matrix::glorot(6, 5, &mut rng);
        let b = Matrix::glorot(6, 5, &mut rng);
        let u0 = Matrix::glorot(6, 5, &mut rng);
        let s = 0.31f32;
        let mut want = u0.clone();
        let mut d = a.clone();
        d.axpy(-1.0, &b);
        want.axpy(s, &d);
        let mut got = u0.clone();
        got.axpy_sub(s, &a, &b);
        assert_eq!(got.data(), want.data());
    }

    #[test]
    fn matmul_known() {
        let a = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Matrix::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(1);
        let a = Matrix::glorot(5, 5, &mut rng);
        let eye = Matrix::from_fn(5, 5, |r, c| if r == c { 1.0 } else { 0.0 });
        assert!(a.matmul(&eye).max_abs_diff(&a) < 1e-7);
        assert!(eye.matmul(&a).max_abs_diff(&a) < 1e-7);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(2);
        let a = Matrix::glorot(17, 33, &mut rng);
        assert!(a.transpose().transpose().max_abs_diff(&a) == 0.0);
        assert_eq!(a.transpose().shape(), (33, 17));
    }

    #[test]
    fn transpose_matmul_property() {
        // (AB)^T == B^T A^T
        let mut rng = Rng::new(3);
        let a = Matrix::glorot(7, 11, &mut rng);
        let b = Matrix::glorot(11, 5, &mut rng);
        let lhs = a.matmul(&b).transpose();
        let rhs = b.transpose().matmul(&a.transpose());
        assert!(lhs.max_abs_diff(&rhs) < 1e-5);
    }

    #[test]
    fn elementwise_and_norms() {
        let a = Matrix::from_vec(2, 2, vec![1., -2., 3., -4.]);
        let b = Matrix::from_vec(2, 2, vec![0.5, 0.5, 0.5, 0.5]);
        assert_eq!(a.add(&b).data(), &[1.5, -1.5, 3.5, -3.5]);
        assert_eq!(a.hadamard(&b).data(), &[0.5, -1.0, 1.5, -2.0]);
        assert_eq!(a.frob_norm_sq(), 30.0);
        assert_eq!(a.abs_max(), 4.0);
        assert!((a.dot(&b) - (0.5 - 1.0 + 1.5 - 2.0)).abs() < 1e-12);
    }

    #[test]
    fn rows_gather_pad() {
        let a = Matrix::from_fn(4, 3, |r, c| (r * 3 + c) as f32);
        let g = a.gather_rows(&[3, 1]);
        assert_eq!(g.row(0), &[9., 10., 11.]);
        assert_eq!(g.row(1), &[3., 4., 5.]);
        let p = g.pad_rows(4);
        assert_eq!(p.rows(), 4);
        assert_eq!(p.row(2), &[0., 0., 0.]);
        let s = a.slice_rows(1, 3);
        assert_eq!(s.row(0), &[3., 4., 5.]);
        assert_eq!(s.rows(), 2);
    }

    #[test]
    fn glorot_bounds() {
        let mut rng = Rng::new(4);
        let w = Matrix::glorot(100, 50, &mut rng);
        let limit = (6.0f64 / 150.0).sqrt() as f32 + 1e-6;
        assert!(w.data().iter().all(|&x| x.abs() <= limit));
        // Not degenerate:
        assert!(w.frob_norm() > 0.1);
    }

    #[test]
    #[should_panic(expected = "matmul shape mismatch")]
    fn matmul_shape_checked() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(2, 3);
        let _ = a.matmul(&b);
    }
}
