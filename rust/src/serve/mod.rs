//! The serving half of the system: train → **snapshot** → **serve**.
//!
//! The trainers ([`crate::coordinator::AdmmTrainer`],
//! [`crate::baselines::BaselineTrainer`],
//! [`crate::baselines::ClusterGcnTrainer`]) produce weights; everything
//! after that lives here:
//!
//! - [`snapshot`] — the versioned `.cgnm` model-snapshot codec
//!   (per-layer weights + layer dims + the run metadata that rebuilds
//!   the deterministic workspace), with `save_model` exported from both
//!   trainers and [`snapshot::load_model`] to read it back.
//! - [`session`] — [`session::InferenceSession`]: forward-only GCN
//!   inference over any [`crate::runtime::ComputeBackend`], full-graph
//!   or node-subset, with a per-community hidden-activation cache
//!   (explicit invalidation) so warm communities answer queries with a
//!   row gather + one output matmul.
//! - [`server`] — the multi-threaded TCP inference server: pool-threaded
//!   connection handlers feeding a micro-batching queue, plus the
//!   blocking [`server::ServeClient`].
//! - [`loadgen`] — the closed-loop load generator behind `cgcn loadgen`
//!   and `benches/serve_throughput.rs`.
//!
//! All paths — single query, coalesced batch, warm cache, cold cache,
//! full forward — are bitwise identical to
//! [`crate::coordinator::evaluate_forward`]; see DESIGN.md §6 for the
//! argument and the invalidation rule.

pub mod loadgen;
pub mod server;
pub mod session;
pub mod snapshot;

pub use loadgen::{LoadgenOpts, LoadgenReport};
pub use server::{serve, ServeClient, ServeOptions, ServerHandle};
pub use session::InferenceSession;
pub use snapshot::{load_model, ModelSnapshot, SnapshotMeta};
