//! The multi-threaded TCP inference server and its client.
//!
//! Topology: an accept thread hands each connection to a task on the
//! shared work-stealing [`Runtime`] when the session's backend exposes
//! one (`--runtime shared`, the default) — handlers and the batcher's
//! kernel forks then share one worker set under one thread budget — or
//! to a dedicated [`crate::util::pool::Pool`] in dual mode. Either way
//! the thread count bounds concurrently *served* connections, and the
//! acceptor sheds load with an error frame beyond a small backlog
//! multiple of it. In shared mode a [`TaskGroup`] restores the
//! drain-on-drop guarantee the dedicated pool used to provide: the
//! accept loop waits for every in-flight handler before returning, so
//! replies flush before the server reports stopped.
//! Handlers parse length-framed requests
//! ([`crate::util::wire`]) and push node queries into a shared
//! **micro-batching queue**; a single batcher thread owns the
//! [`InferenceSession`] and drains the queue once per batch window,
//! coalescing all pending queries into one deduplicated backend batch.
//! Responses fan back out over per-request `mpsc` channels.
//!
//! Batching trades a bounded latency floor (the window) for throughput:
//! N concurrent single-node queries cost one row gather + one matmul
//! instead of N. Because every backend kernel is row-independent, a
//! node's logits are bitwise identical whether it was served alone, in a
//! coalesced batch, or read out of a full-graph forward — so batching is
//! purely a scheduling decision, never a numerics one (DESIGN.md §6).
//!
//! Protocol frames (`[u32 len][u8 tag][payload]`, little-endian):
//!
//! | tag | dir             | payload                                     |
//! |-----|-----------------|---------------------------------------------|
//! | 1   | client→server   | Info {}                                     |
//! | 2   | server→client   | InfoR { label, n u64, classes u32, dims }   |
//! | 3   | client→server   | Query { node ids u32s }                     |
//! | 4   | server→client   | Logits { ids u32s, flat f32s (row-major) }  |
//! | 5   | server→client   | Err { message str }                         |
//! | 6   | client→server   | Stats {}                                    |
//! | 7   | server→client   | StatsR { requests, nodes, batches, warms }  |
//! | 8   | client→server   | Shutdown {}                                 |
//! | 9   | server→client   | ShutdownR {}                                |
//! | 10  | client→server   | Metrics {}                                  |
//! | 11  | server→client   | MetricsR { prometheus text str }            |
//!
//! The Metrics frame scrapes the server process's [`crate::obs`] registry
//! (Prometheus-style text exposition, including latency quantiles) — the
//! `cgcn stats` subcommand is a thin client for it (DESIGN.md §10).

use super::session::InferenceSession;
use crate::util::pool::{resolve_threads, Pool, Runtime};
use crate::util::wire::{read_frame, read_frame_capped, write_frame, Dec, Enc};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::io::{self, BufReader, BufWriter};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

pub const TAG_INFO: u8 = 1;
pub const TAG_INFO_R: u8 = 2;
pub const TAG_QUERY: u8 = 3;
pub const TAG_LOGITS: u8 = 4;
pub const TAG_ERR: u8 = 5;
pub const TAG_STATS: u8 = 6;
pub const TAG_STATS_R: u8 = 7;
pub const TAG_SHUTDOWN: u8 = 8;
pub const TAG_SHUTDOWN_R: u8 = 9;
pub const TAG_METRICS: u8 = 10;
pub const TAG_METRICS_R: u8 = 11;

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Bind address; port 0 picks a free port (the handle reports it).
    pub addr: String,
    /// Connection-handler threads (0 = all cores). Bounds the number of
    /// concurrently served connections. When the session's backend
    /// carries a shared [`Runtime`] this is ignored in favour of the
    /// runtime's budget — one knob governs handlers and kernels alike.
    pub threads: usize,
    /// Micro-batch window in microseconds: after the first query of a
    /// batch arrives, the batcher keeps collecting this long. 0 = drain
    /// whatever is already queued (minimal batching, minimal latency).
    pub batch_window_us: u64,
    /// Hard cap on queries coalesced into one batch.
    pub max_batch: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            addr: "127.0.0.1:0".to_string(),
            threads: 0,
            batch_window_us: 200,
            max_batch: 256,
        }
    }
}

/// Server-side counters (all monotonic).
#[derive(Default)]
pub struct ServerStats {
    /// Query frames answered.
    pub requests: AtomicU64,
    /// Node rows returned.
    pub nodes: AtomicU64,
    /// Backend batches executed.
    pub batches: AtomicU64,
}

struct Pending {
    nodes: Vec<usize>,
    resp: mpsc::Sender<Result<Vec<f32>, String>>,
}

struct QueueInner {
    pending: Vec<Pending>,
    closed: bool,
}

/// The micro-batching queue: handlers push, the batcher pops a coalesced
/// batch per window.
struct BatchQueue {
    inner: Mutex<QueueInner>,
    cv: Condvar,
}

impl BatchQueue {
    fn new() -> BatchQueue {
        BatchQueue {
            inner: Mutex::new(QueueInner {
                pending: Vec::new(),
                closed: false,
            }),
            cv: Condvar::new(),
        }
    }

    /// Enqueue; false if the server is shutting down.
    fn push(&self, p: Pending) -> bool {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return false;
        }
        g.pending.push(p);
        crate::obs_gauge!("serve.queue.depth").set(g.pending.len() as i64);
        self.cv.notify_all();
        true
    }

    /// Block for the first query, then collect until the window closes,
    /// `max` queries are pending, or the queue closes. `None` once closed
    /// *and* drained. Entries already pending on entry (leftovers from an
    /// overflowed batch) have had their window — they drain immediately
    /// rather than paying a second one.
    fn pop_batch(&self, window: Duration, max: usize) -> Option<Vec<Pending>> {
        let mut g = self.inner.lock().unwrap();
        let backlog = !g.pending.is_empty();
        while g.pending.is_empty() && !g.closed {
            g = self.cv.wait(g).unwrap();
        }
        if g.pending.is_empty() {
            return None; // closed and drained
        }
        if !backlog {
            let deadline = Instant::now() + window;
            while g.pending.len() < max && !g.closed {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (gg, _) = self.cv.wait_timeout(g, deadline - now).unwrap();
                g = gg;
            }
        }
        let take = g.pending.len().min(max);
        let batch: Vec<Pending> = g.pending.drain(..take).collect();
        crate::obs_gauge!("serve.queue.depth").set(g.pending.len() as i64);
        Some(batch)
    }

    fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }
}

/// Model facts handlers answer without touching the session.
struct ServeShared {
    label: String,
    n: usize,
    dims: Vec<usize>,
    addr: SocketAddr,
    queue: BatchQueue,
    shutdown: AtomicBool,
    stats: ServerStats,
    /// Cache entries computed by the session (sampled at batch bounds).
    warms: AtomicU64,
    /// Clones of every live connection, keyed by a per-connection token,
    /// so shutdown can force-close sockets whose handlers are blocked in
    /// a read — without this an idle client would pin its pool worker
    /// and hang the teardown joins forever. Handlers remove their own
    /// entry on exit (the clone holds a dup'd fd, so leaving it behind
    /// would leak one fd per historical connection).
    conns: Mutex<HashMap<u64, TcpStream>>,
    next_conn_token: AtomicU64,
}

impl ServeShared {
    /// Unblock every registered connection's reader (idempotent; errors
    /// on already-dead sockets are expected and ignored). Read-side only:
    /// blocked `read_frame` calls return EOF so handlers exit, while
    /// replies to queries already in the batch queue still flush — the
    /// drain-on-close contract answers them before the batcher stops.
    fn close_conns(&self) {
        for (_, s) in self.conns.lock().unwrap().drain() {
            let _ = s.shutdown(std::net::Shutdown::Read);
        }
    }

    /// An address a local connect can actually reach, to wake the
    /// blocking `accept()`: a wildcard bind (0.0.0.0 / ::) is not
    /// connectable on every platform, so substitute loopback.
    fn wake_addr(&self) -> SocketAddr {
        let mut a = self.addr;
        if a.ip().is_unspecified() {
            a.set_ip(match a.ip() {
                std::net::IpAddr::V4(_) => std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST),
                std::net::IpAddr::V6(_) => std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST),
            });
        }
        a
    }
}

/// A running server; stop with [`ServerHandle::stop`] or remotely via the
/// Shutdown frame (then [`ServerHandle::wait`] returns).
pub struct ServerHandle {
    addr: SocketAddr,
    shared: Arc<ServeShared>,
    accept: Option<JoinHandle<()>>,
    batcher: Option<JoinHandle<()>>,
}

/// Start serving `session` per `opts`. The session is warmed by the
/// caller (or lazily by the first queries); ownership moves to the
/// batcher thread.
pub fn serve(session: InferenceSession, opts: &ServeOptions) -> Result<ServerHandle> {
    let listener = TcpListener::bind(&opts.addr)
        .with_context(|| format!("binding inference server to {}", opts.addr))?;
    let addr = listener.local_addr()?;
    let ws = session.workspace();
    let shared = Arc::new(ServeShared {
        label: session.label().to_string(),
        n: ws.n,
        dims: ws.dims.clone(),
        addr,
        queue: BatchQueue::new(),
        shutdown: AtomicBool::new(false),
        stats: ServerStats::default(),
        warms: AtomicU64::new(session.stats().warms),
        conns: Mutex::new(HashMap::new()),
        next_conn_token: AtomicU64::new(0),
    });
    let window = Duration::from_micros(opts.batch_window_us);
    let max_batch = opts.max_batch.max(1);
    // Shared-runtime mode: handlers run on the same workers the
    // batcher's kernels fork onto, under the runtime's one budget.
    let rt = session.backend().runtime().cloned();
    let threads = match &rt {
        Some(rt) => rt.threads(),
        None => resolve_threads(opts.threads),
    };

    let batcher = {
        let shared = shared.clone();
        std::thread::Builder::new()
            .name("cgcn-serve-batcher".into())
            .spawn(move || batcher_loop(session, shared, window, max_batch))?
    };
    let accept = {
        let shared = shared.clone();
        std::thread::Builder::new()
            .name("cgcn-serve-accept".into())
            .spawn(move || accept_loop(listener, shared, threads, rt))?
    };
    log::info!("inference server on {addr} ({threads} handler threads, window {window:?})");
    Ok(ServerHandle {
        addr,
        shared,
        accept: Some(accept),
        batcher: Some(batcher),
    })
}

impl ServerHandle {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// (requests, nodes, batches) served so far.
    pub fn counters(&self) -> (u64, u64, u64) {
        let s = &self.shared.stats;
        (
            s.requests.load(Ordering::Relaxed),
            s.nodes.load(Ordering::Relaxed),
            s.batches.load(Ordering::Relaxed),
        )
    }

    /// Block until the server stops (remote Shutdown frame). The
    /// teardown backstop in `Drop` is a no-op once the joins finish.
    pub fn wait(mut self) {
        self.join_threads();
    }

    /// Stop from the owning process: close the queue, wake the acceptor,
    /// join both threads (handlers drain as clients disconnect).
    pub fn stop(self) {
        // Drop runs shutdown_and_join.
    }

    fn join_threads(&mut self) {
        if let Some(j) = self.accept.take() {
            let _ = j.join();
        }
        if let Some(j) = self.batcher.take() {
            let _ = j.join();
        }
    }

    fn shutdown_and_join(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.queue.close();
        self.shared.close_conns(); // unblock handlers mid-read
        let _ = TcpStream::connect(self.shared.wake_addr()); // wake accept()
        self.join_threads();
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.shutdown_and_join();
    }
}

/// In-flight handler tasks on the shared runtime. The dual-mode `Pool`
/// joins its workers on drop, which is what guaranteed every reply had
/// flushed before `accept_loop` returned; runtime tasks have no such
/// implicit join, so the group counts them and [`TaskGroup::wait_idle`]
/// restores the drain-before-return contract.
struct TaskGroup {
    live: Mutex<usize>,
    cv: Condvar,
}

impl TaskGroup {
    fn new() -> Arc<TaskGroup> {
        Arc::new(TaskGroup {
            live: Mutex::new(0),
            cv: Condvar::new(),
        })
    }

    /// Run `f` as a runtime task, counted until it finishes. The
    /// decrement rides a `Drop` guard *inside* the task, so a panicking
    /// handler (caught by the runtime worker) still counts down and
    /// `wait_idle` cannot hang on it.
    fn spawn_on(self: &Arc<Self>, rt: &Runtime, f: impl FnOnce() + Send + 'static) {
        *self.live.lock().unwrap() += 1;
        struct Dec(Arc<TaskGroup>);
        impl Drop for Dec {
            fn drop(&mut self) {
                let mut live = self.0.live.lock().unwrap();
                *live -= 1;
                if *live == 0 {
                    self.0.cv.notify_all();
                }
            }
        }
        let dec = Dec(self.clone());
        rt.execute(move || {
            let _dec = dec;
            f();
        });
    }

    /// Block until every spawned task has finished.
    fn wait_idle(&self) {
        let g = self.live.lock().unwrap();
        drop(self.cv.wait_while(g, |live| *live > 0).unwrap());
    }
}

fn accept_loop(
    listener: TcpListener,
    shared: Arc<ServeShared>,
    threads: usize,
    rt: Option<Arc<Runtime>>,
) {
    // Dual mode owns a dedicated handler pool; shared mode schedules
    // handlers as tasks on the runtime and tracks them in a TaskGroup.
    let pool = rt.is_none().then(|| Pool::new(threads));
    let group = TaskGroup::new();
    // Live connections (running + queued for a handler) are bounded at a
    // small multiple of the thread budget; beyond that the acceptor
    // sheds load with an error frame instead of queueing fds without
    // limit.
    let max_conns = threads * 8;
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                if shared.shutdown.load(Ordering::SeqCst) {
                    break;
                }
                if shared.conns.lock().unwrap().len() >= max_conns {
                    let _ = write_frame(
                        &mut &stream,
                        &err_frame("server saturated: too many connections"),
                    );
                    continue; // stream drops → connection closes
                }
                // Register the connection so shutdown can force-close it.
                let token = shared.next_conn_token.fetch_add(1, Ordering::Relaxed);
                if let Ok(clone) = stream.try_clone() {
                    shared.conns.lock().unwrap().insert(token, clone);
                }
                // Re-check after registering: if shutdown's close_conns
                // drained the registry before our insert, the flag
                // (stored before the drain) is now visible — close this
                // socket ourselves so it can't pin a worker.
                if shared.shutdown.load(Ordering::SeqCst) {
                    let _ = stream.shutdown(std::net::Shutdown::Both);
                    break;
                }
                let shared = shared.clone();
                let task = move || {
                    let result = handle_conn(stream, &shared);
                    // Deregister (drops the dup'd fd — the registry must
                    // not outlive the connection or fds leak per client).
                    shared.conns.lock().unwrap().remove(&token);
                    if let Err(e) = result {
                        log::debug!("serve connection ended: {e:#}");
                    }
                };
                match (&rt, &pool) {
                    (Some(rt), _) => group.spawn_on(rt, task),
                    (None, Some(pool)) => pool.execute(task),
                    (None, None) => unreachable!("accept loop without an executor"),
                }
            }
            Err(e) => {
                if shared.shutdown.load(Ordering::SeqCst) {
                    break;
                }
                log::warn!("accept error: {e}");
                // Don't hot-spin on persistent failures (e.g. EMFILE).
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
    // Dual: Pool drop joins the handlers. Shared: wait for the in-flight
    // handler tasks (the runtime outlives us — it belongs to the
    // backend). Either way close_conns has already unblocked (or will
    // unblock, via the shutdown paths) any blocked reads.
    group.wait_idle();
}

fn batcher_loop(
    mut session: InferenceSession,
    shared: Arc<ServeShared>,
    window: Duration,
    max_batch: usize,
) {
    while let Some(batch) = shared.queue.pop_batch(window, max_batch) {
        let _span = crate::span!("serve.batch", queries = batch.len());
        crate::obs_hist!("serve.batch.size", crate::obs::SIZE_BUCKETS).record(batch.len() as f64);
        // Coalesce: union of requested ids, one backend batch.
        let mut ids: Vec<usize> = batch.iter().flat_map(|p| p.nodes.iter().copied()).collect();
        ids.sort_unstable();
        ids.dedup();
        match session.logits_for(&ids) {
            Ok(logits) => {
                let cols = logits.cols();
                for p in &batch {
                    let mut flat = Vec::with_capacity(p.nodes.len() * cols);
                    for &id in &p.nodes {
                        let ri = ids.binary_search(&id).expect("coalesced id missing");
                        flat.extend_from_slice(logits.row(ri));
                    }
                    let _ = p.resp.send(Ok(flat));
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for p in &batch {
                    let _ = p.resp.send(Err(msg.clone()));
                }
            }
        }
        shared.stats.batches.fetch_add(1, Ordering::Relaxed);
        shared
            .warms
            .store(session.stats().warms, Ordering::Relaxed);
    }
}

fn err_frame(msg: &str) -> Vec<u8> {
    let mut e = Enc::new();
    e.u8(TAG_ERR).str(msg);
    e.into_bytes()
}

/// Largest request frame a handler will read. Queries are u32 node ids
/// (4 MiB of ids ≫ any real graph here); anything bigger is hostile.
const MAX_REQUEST_FRAME: usize = 16 << 20;

/// Drop a connection after this long without receiving a byte. The pool
/// bounds concurrent connections, so without a timeout `--threads` idle
/// sockets would pin every handler and starve later clients; with it,
/// workers recycle. (A legitimately quiet client just reconnects.)
const IDLE_TIMEOUT: Duration = Duration::from_secs(60);

fn handle_conn(stream: TcpStream, shared: &ServeShared) -> Result<()> {
    crate::obs_counter!("serve.connections").inc();
    stream.set_nodelay(true)?;
    stream.set_read_timeout(Some(IDLE_TIMEOUT))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    loop {
        let frame = match read_frame_capped(&mut reader, MAX_REQUEST_FRAME) {
            Ok(Some(f)) => f,
            Ok(None) => break, // clean disconnect
            Err(e) if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) => {
                log::debug!("closing idle serve connection");
                break;
            }
            Err(e) => return Err(e.into()),
        };
        let Some(&tag) = frame.first() else {
            write_frame(&mut writer, &err_frame("empty frame"))?;
            continue;
        };
        match tag {
            TAG_INFO => {
                let mut e = Enc::new();
                e.u8(TAG_INFO_R).str(&shared.label).u64(shared.n as u64);
                e.u32(*shared.dims.last().unwrap() as u32);
                e.u32s(&shared.dims.iter().map(|&d| d as u32).collect::<Vec<_>>());
                write_frame(&mut writer, e.bytes())?;
            }
            TAG_QUERY => {
                let mut d = Dec::new(&frame[1..]);
                // A corrupt payload gets a diagnostic reply like every
                // other bad-input path — not a dropped connection.
                let ids32 = match d.u32s() {
                    Ok(ids) => ids,
                    Err(e) => {
                        write_frame(&mut writer, &err_frame(&format!("malformed query: {e}")))?;
                        continue;
                    }
                };
                let nodes: Vec<usize> = ids32.iter().map(|&i| i as usize).collect();
                if let Some(&bad) = nodes.iter().find(|&&i| i >= shared.n) {
                    write_frame(
                        &mut writer,
                        &err_frame(&format!("node id {bad} out of range (n={})", shared.n)),
                    )?;
                    continue;
                }
                let n_nodes = nodes.len() as u64;
                let t0 = Instant::now();
                let (tx, rx) = mpsc::channel();
                let accepted = shared.queue.push(Pending { nodes, resp: tx });
                if !accepted {
                    write_frame(&mut writer, &err_frame("server is shutting down"))?;
                    continue;
                }
                match rx.recv() {
                    Ok(Ok(flat)) => {
                        // Count before the reply flushes: once a client
                        // observes the response, the counters include it.
                        shared.stats.requests.fetch_add(1, Ordering::Relaxed);
                        shared.stats.nodes.fetch_add(n_nodes, Ordering::Relaxed);
                        let mut e = Enc::new();
                        e.u8(TAG_LOGITS).u32s(&ids32);
                        e.f32s(&flat);
                        write_frame(&mut writer, e.bytes())?;
                    }
                    Ok(Err(msg)) => {
                        crate::obs_counter!("serve.request.errors").inc();
                        write_frame(&mut writer, &err_frame(&msg))?;
                    }
                    Err(_) => {
                        crate::obs_counter!("serve.request.errors").inc();
                        write_frame(&mut writer, &err_frame("batcher stopped"))?;
                    }
                }
                // Queue wait + batch compute + reply flush, per request.
                crate::obs_hist!("serve.request.secs", crate::obs::TIME_BUCKETS).record_secs(t0);
            }
            TAG_STATS => {
                let mut e = Enc::new();
                e.u8(TAG_STATS_R)
                    .u64(shared.stats.requests.load(Ordering::Relaxed))
                    .u64(shared.stats.nodes.load(Ordering::Relaxed))
                    .u64(shared.stats.batches.load(Ordering::Relaxed))
                    .u64(shared.warms.load(Ordering::Relaxed));
                write_frame(&mut writer, e.bytes())?;
            }
            TAG_METRICS => {
                let mut e = Enc::new();
                e.u8(TAG_METRICS_R).str(&crate::obs::prometheus_text());
                write_frame(&mut writer, e.bytes())?;
            }
            TAG_SHUTDOWN => {
                let mut e = Enc::new();
                e.u8(TAG_SHUTDOWN_R);
                write_frame(&mut writer, e.bytes())?;
                shared.shutdown.store(true, Ordering::SeqCst);
                shared.queue.close();
                // Unblock every other handler (idle clients would pin
                // their pool workers and hang the teardown joins), then
                // wake the acceptor. The ack above is already flushed,
                // so closing our own socket too is harmless.
                shared.close_conns();
                let _ = TcpStream::connect(shared.wake_addr()); // wake accept()
                break;
            }
            other => write_frame(&mut writer, &err_frame(&format!("unknown tag {other}")))?,
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Client
// ---------------------------------------------------------------------------

/// Model facts reported by the Info frame.
#[derive(Clone, Debug)]
pub struct ServerInfo {
    pub label: String,
    pub n: usize,
    pub classes: usize,
    pub dims: Vec<usize>,
}

/// Serving counters reported by the Stats frame.
#[derive(Clone, Copy, Debug)]
pub struct ServerCounters {
    pub requests: u64,
    pub nodes: u64,
    pub batches: u64,
    pub warms: u64,
}

/// Blocking client for the inference protocol (used by `cgcn query`, the
/// load generator, benches and tests).
pub struct ServeClient {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl ServeClient {
    pub fn connect(addr: &str) -> Result<ServeClient> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
        stream.set_nodelay(true)?;
        Ok(ServeClient {
            reader: BufReader::new(stream.try_clone()?),
            writer: BufWriter::new(stream),
        })
    }

    fn roundtrip(&mut self, req: &[u8], want: u8) -> Result<Vec<u8>> {
        write_frame(&mut self.writer, req)?;
        let frame = read_frame(&mut self.reader)?
            .ok_or_else(|| anyhow::anyhow!("server closed connection"))?;
        match frame.first() {
            Some(&t) if t == want => Ok(frame),
            Some(&TAG_ERR) => {
                let msg = Dec::new(&frame[1..]).str().unwrap_or_default();
                bail!("server error: {msg}");
            }
            other => bail!("unexpected frame tag {other:?}"),
        }
    }

    pub fn info(&mut self) -> Result<ServerInfo> {
        let mut e = Enc::new();
        e.u8(TAG_INFO);
        let frame = self.roundtrip(e.bytes(), TAG_INFO_R)?;
        let mut d = Dec::new(&frame[1..]);
        let label = d.str()?;
        let n = d.u64()? as usize;
        let classes = d.u32()? as usize;
        let dims = d.u32s()?.into_iter().map(|x| x as usize).collect();
        Ok(ServerInfo {
            label,
            n,
            classes,
            dims,
        })
    }

    /// Query logits for `nodes`; returns one row per node, request order.
    pub fn query(&mut self, nodes: &[usize]) -> Result<Vec<Vec<f32>>> {
        let ids: Vec<u32> = nodes.iter().map(|&i| i as u32).collect();
        let mut e = Enc::new();
        e.u8(TAG_QUERY).u32s(&ids);
        let frame = self.roundtrip(e.bytes(), TAG_LOGITS)?;
        let mut d = Dec::new(&frame[1..]);
        let echo = d.u32s()?;
        anyhow::ensure!(echo == ids, "response id echo mismatch");
        let flat = d.f32s()?;
        anyhow::ensure!(
            nodes.is_empty() || flat.len() % nodes.len() == 0,
            "ragged logits payload"
        );
        let cols = if nodes.is_empty() {
            0
        } else {
            flat.len() / nodes.len()
        };
        Ok(flat.chunks(cols.max(1)).map(|c| c.to_vec()).collect())
    }

    pub fn stats(&mut self) -> Result<ServerCounters> {
        let mut e = Enc::new();
        e.u8(TAG_STATS);
        let frame = self.roundtrip(e.bytes(), TAG_STATS_R)?;
        let mut d = Dec::new(&frame[1..]);
        Ok(ServerCounters {
            requests: d.u64()?,
            nodes: d.u64()?,
            batches: d.u64()?,
            warms: d.u64()?,
        })
    }

    /// Scrape the server process's metrics registry as Prometheus-style
    /// text (counters, gauges, histogram buckets + latency quantiles).
    pub fn metrics(&mut self) -> Result<String> {
        let mut e = Enc::new();
        e.u8(TAG_METRICS);
        let frame = self.roundtrip(e.bytes(), TAG_METRICS_R)?;
        Ok(Dec::new(&frame[1..]).str()?)
    }

    /// Ask the server to stop (acknowledged before it exits).
    pub fn shutdown(&mut self) -> Result<()> {
        let mut e = Enc::new();
        e.u8(TAG_SHUTDOWN);
        self.roundtrip(e.bytes(), TAG_SHUTDOWN_R)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_queue_coalesces_and_closes() {
        let q = Arc::new(BatchQueue::new());
        let (tx, _rx) = mpsc::channel();
        for i in 0..3 {
            assert!(q.push(Pending {
                nodes: vec![i],
                resp: tx.clone(),
            }));
        }
        let batch = q.pop_batch(Duration::from_micros(0), 2).unwrap();
        assert_eq!(batch.len(), 2);
        let batch = q.pop_batch(Duration::from_micros(0), 16).unwrap();
        assert_eq!(batch.len(), 1);
        q.close();
        assert!(!q.push(Pending {
            nodes: vec![9],
            resp: tx,
        }));
        assert!(q.pop_batch(Duration::from_millis(1), 16).is_none());
    }

    #[test]
    fn pop_batch_waits_out_the_window() {
        let q = Arc::new(BatchQueue::new());
        let (tx, _rx) = mpsc::channel();
        let q2 = q.clone();
        let tx2 = tx.clone();
        let t = std::thread::spawn(move || {
            q2.push(Pending {
                nodes: vec![1],
                resp: tx2.clone(),
            });
            std::thread::sleep(Duration::from_millis(5));
            q2.push(Pending {
                nodes: vec![2],
                resp: tx2,
            });
        });
        // A generous window should see both pushes in one batch.
        let batch = q.pop_batch(Duration::from_millis(500), 16).unwrap();
        t.join().unwrap();
        let total: usize = batch.len();
        assert!(total >= 1, "first push must be in the batch");
        if total == 2 {
            assert_eq!(batch[1].nodes, vec![2]);
        } else {
            // Slow host: second push lands in the next batch.
            let rest = q.pop_batch(Duration::from_millis(0), 16).unwrap();
            assert_eq!(rest[0].nodes, vec![2]);
        }
    }
}
