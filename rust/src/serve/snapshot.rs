//! `.cgnm` — the on-disk binary model-snapshot format.
//!
//! A snapshot is everything needed to stand an inference session back up
//! without the trainer: per-layer weights, the layer dims they were
//! trained at, and the run metadata (dataset spec, seed, partition) that
//! rebuilds the deterministic [`Workspace`] — synthesis, partitioning and
//! normalisation are all seeded, so only the weights have to persist.
//!
//! Layout (all little-endian, via [`crate::util::wire`], in the style of
//! the `.cgnp` dataset format in [`crate::data::format`]):
//!
//! ```text
//! magic "CGNM" | version u32 | label str
//! dataset str | scale f64 | seed u64 | partition str | communities u32
//! hidden u32 | layers u32 | dims u32s (len L+1)
//! L × ( rows u64 | cols u64 | f32 data )
//! ```

use crate::config::HyperParams;
use crate::coordinator::Workspace;
use crate::tensor::Matrix;
use crate::util::wire::{Dec, Enc};
use anyhow::{bail, ensure, Context, Result};
use std::path::Path;
use std::sync::Arc;

const MAGIC: &[u8; 4] = b"CGNM";
const VERSION: u32 = 1;

/// Run metadata persisted alongside the weights: everything needed to
/// rebuild the training-time workspace (dataset, partition) plus a
/// human-readable label for logs.
#[derive(Clone, Debug, PartialEq)]
pub struct SnapshotMeta {
    /// Run label (e.g. `admm-parallel-m3`, `adam`).
    pub label: String,
    /// Dataset name or `.cgnp` path, as passed to `--dataset`.
    pub dataset: String,
    /// Synthetic dataset scale (ignored for fixtures / `.cgnp` paths).
    pub scale: f64,
    /// Seed for dataset synthesis, partitioning and init.
    pub seed: u64,
    /// Partitioner name (`metis|random|bfs`).
    pub partition: String,
    /// Community count the model was trained with (the serving cache
    /// shards activations at the same granularity).
    pub communities: usize,
    /// Resolved hidden width (post fixture overrides).
    pub hidden: usize,
    /// Resolved layer count L.
    pub layers: usize,
}

impl SnapshotMeta {
    /// Append the meta fields to an encoder — the field set and order are
    /// shared by the `.cgnm` model snapshot and the `.cgck` training
    /// checkpoint, so both formats rebuild workspaces the same way.
    pub fn encode(&self, e: &mut Enc) {
        e.str(&self.label);
        e.str(&self.dataset);
        e.f64(self.scale);
        e.u64(self.seed);
        e.str(&self.partition);
        e.u32(self.communities as u32);
        e.u32(self.hidden as u32);
        e.u32(self.layers as u32);
    }

    /// Decode the meta fields written by [`SnapshotMeta::encode`].
    pub fn decode(d: &mut Dec) -> Result<SnapshotMeta> {
        Ok(SnapshotMeta {
            label: d.str()?,
            dataset: d.str()?,
            scale: d.f64()?,
            seed: d.u64()?,
            partition: d.str()?,
            communities: d.u32()? as usize,
            hidden: d.u32()? as usize,
            layers: d.u32()? as usize,
        })
    }

    /// Hyper-parameters that rebuild the training-time workspace: the
    /// dataset defaults with the *resolved* (post fixture override)
    /// hidden/layers/communities/seed recorded in the metadata. Callers
    /// that persisted ρ/ν separately (the checkpoint codec does) should
    /// overwrite those fields afterwards.
    pub fn base_hyperparams(&self) -> HyperParams {
        let mut hp = HyperParams::for_dataset(&self.dataset);
        hp.hidden = self.hidden;
        hp.layers = self.layers;
        hp.communities = self.communities;
        hp.seed = self.seed;
        hp
    }
}

/// A saved model: metadata + layer dims + the trained weights W_1..W_L.
#[derive(Clone, Debug)]
pub struct ModelSnapshot {
    pub meta: SnapshotMeta,
    /// Layer dims C_0..C_L (length L+1) at train time.
    pub dims: Vec<usize>,
    /// Weights, `w[l-1]` is `C_{l-1} × C_l`.
    pub w: Vec<Matrix>,
}

impl ModelSnapshot {
    /// Capture a snapshot from a workspace + trained weights. Validates
    /// that the weight shapes match the workspace dims.
    pub fn capture(meta: SnapshotMeta, ws: &Workspace, w: &[Matrix]) -> Result<ModelSnapshot> {
        ensure!(
            w.len() == ws.layers,
            "snapshot: {} weight matrices for {} layers",
            w.len(),
            ws.layers
        );
        for (li, wl) in w.iter().enumerate() {
            ensure!(
                wl.shape() == (ws.dims[li], ws.dims[li + 1]),
                "snapshot: W_{} is {}x{}, workspace dims want {}x{}",
                li + 1,
                wl.rows(),
                wl.cols(),
                ws.dims[li],
                ws.dims[li + 1]
            );
        }
        Ok(ModelSnapshot {
            meta,
            dims: ws.dims.clone(),
            w: w.to_vec(),
        })
    }

    /// Serialise to bytes.
    pub fn to_bytes(&self) -> Vec<u8> {
        let wbytes: usize = self.w.iter().map(|m| m.data().len() * 4 + 32).sum();
        let mut e = Enc::with_capacity(wbytes + 256);
        e.u8(MAGIC[0]).u8(MAGIC[1]).u8(MAGIC[2]).u8(MAGIC[3]);
        e.u32(VERSION);
        self.meta.encode(&mut e);
        e.u32s(&self.dims.iter().map(|&d| d as u32).collect::<Vec<_>>());
        for m in &self.w {
            e.u64(m.rows() as u64).u64(m.cols() as u64);
            e.f32s(m.data());
        }
        e.into_bytes()
    }

    /// Parse from bytes. Corruption (bad magic, version skew, truncation,
    /// shape mismatches, trailing garbage) is an error, never a panic.
    pub fn from_bytes(bytes: &[u8]) -> Result<ModelSnapshot> {
        let mut d = Dec::new(bytes);
        let magic = [d.u8()?, d.u8()?, d.u8()?, d.u8()?];
        if &magic != MAGIC {
            bail!("not a .cgnm model snapshot (bad magic)");
        }
        let version = d.u32()?;
        if version != VERSION {
            bail!("unsupported .cgnm version {version} (this build reads {VERSION})");
        }
        let meta = SnapshotMeta::decode(&mut d)?;
        let layers = meta.layers;
        let dims: Vec<usize> = d.u32s()?.into_iter().map(|x| x as usize).collect();
        ensure!(
            layers >= 1 && dims.len() == layers + 1,
            "dims length {} does not match layers {}",
            dims.len(),
            layers
        );
        let mut w = Vec::with_capacity(layers);
        for li in 0..layers {
            let rows = d.u64()? as usize;
            let cols = d.u64()? as usize;
            // Validate the shape against dims (u32-bounded) *before*
            // multiplying — corrupt u64 fields must error, not overflow.
            ensure!(
                (rows, cols) == (dims[li], dims[li + 1]),
                "W_{} is {rows}x{cols}, dims want {}x{}",
                li + 1,
                dims[li],
                dims[li + 1]
            );
            let data = d.f32s()?;
            ensure!(
                data.len() == rows * cols,
                "W_{} payload size mismatch",
                li + 1
            );
            w.push(Matrix::from_vec(rows, cols, data));
        }
        if !d.done() {
            bail!("trailing bytes in .cgnm snapshot");
        }
        Ok(ModelSnapshot { meta, dims, w })
    }

    /// Save to a file.
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_bytes())
            .with_context(|| format!("writing {}", path.display()))
    }

    /// Rebuild the training-time workspace from the snapshot metadata:
    /// same dataset, same seed, same partition — deterministic end to
    /// end. Fails if the rebuilt dims no longer match the saved ones
    /// (dataset drift would silently corrupt inference otherwise).
    pub fn rebuild_workspace(&self) -> Result<Arc<Workspace>> {
        let m = &self.meta;
        let ds = crate::data::load_by_name(&m.dataset, m.scale, m.seed)
            .with_context(|| format!("rebuilding dataset '{}'", m.dataset))?;
        let hp = m.base_hyperparams();
        let method = crate::partition::Method::parse(&m.partition)
            .ok_or_else(|| anyhow::anyhow!("unknown partition method '{}'", m.partition))?;
        let ws = Workspace::build(&ds, &hp, method)?;
        ensure!(
            ws.dims == self.dims,
            "rebuilt workspace dims {:?} != snapshot dims {:?} (dataset drift?)",
            ws.dims,
            self.dims
        );
        Ok(Arc::new(ws))
    }
}

/// Load a `.cgnm` snapshot from a file.
pub fn load_model(path: &Path) -> Result<ModelSnapshot> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    ModelSnapshot::from_bytes(&bytes).with_context(|| format!("parsing {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::Method;
    use crate::util::rng::Rng;

    fn fixture_snapshot() -> (ModelSnapshot, Arc<Workspace>) {
        let ds = crate::data::fixtures::caveman(24, 3);
        let mut hp = HyperParams::for_dataset("caveman");
        hp.communities = 3;
        hp.hidden = 8;
        hp.seed = 3;
        let ws = Workspace::build(&ds, &hp, Method::Metis).unwrap();
        let mut rng = Rng::new(9);
        let w: Vec<Matrix> = (1..=ws.layers)
            .map(|l| Matrix::glorot(ws.dims[l - 1], ws.dims[l], &mut rng))
            .collect();
        let meta = SnapshotMeta {
            label: "test".into(),
            dataset: "caveman".into(),
            scale: 1.0,
            seed: 3,
            partition: "metis".into(),
            communities: 3,
            hidden: 8,
            layers: ws.layers,
        };
        let snap = ModelSnapshot::capture(meta, &ws, &w).unwrap();
        (snap, Arc::new(ws))
    }

    #[test]
    fn byte_roundtrip_preserves_everything() {
        let (snap, _) = fixture_snapshot();
        let back = ModelSnapshot::from_bytes(&snap.to_bytes()).unwrap();
        assert_eq!(back.meta, snap.meta);
        assert_eq!(back.dims, snap.dims);
        assert_eq!(back.w.len(), snap.w.len());
        for (a, b) in back.w.iter().zip(&snap.w) {
            assert_eq!(a.data(), b.data());
        }
    }

    #[test]
    fn corrupt_inputs_error_not_panic() {
        let (snap, _) = fixture_snapshot();
        let bytes = snap.to_bytes();

        // Bad magic.
        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(ModelSnapshot::from_bytes(&bad).is_err());

        // Version mismatch.
        let mut bad = bytes.clone();
        bad[4..8].copy_from_slice(&99u32.to_le_bytes());
        let err = ModelSnapshot::from_bytes(&bad).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");

        // Truncation anywhere must be a clean error.
        for cut in [5, 20, bytes.len() / 2, bytes.len() - 1] {
            assert!(
                ModelSnapshot::from_bytes(&bytes[..cut]).is_err(),
                "truncation at {cut} did not error"
            );
        }

        // Trailing garbage.
        let mut bad = bytes.clone();
        bad.push(0);
        assert!(ModelSnapshot::from_bytes(&bad).is_err());
    }

    #[test]
    fn huge_weight_shape_errors_not_panics() {
        let (snap, _) = fixture_snapshot();
        // Hand-build a snapshot whose first weight block claims an absurd
        // shape: must be a clean error, not a multiply overflow.
        let mut e = Enc::new();
        e.u8(b'C').u8(b'G').u8(b'N').u8(b'M');
        e.u32(VERSION);
        e.str("x");
        e.str("caveman");
        e.f64(1.0);
        e.u64(3);
        e.str("metis");
        e.u32(3);
        e.u32(8);
        e.u32(snap.meta.layers as u32);
        e.u32s(&snap.dims.iter().map(|&d| d as u32).collect::<Vec<_>>());
        e.u64(u64::MAX).u64(2);
        e.f32s(&[0.0]);
        assert!(ModelSnapshot::from_bytes(&e.into_bytes()).is_err());
    }

    #[test]
    fn file_roundtrip_and_rebuild() {
        let (snap, ws) = fixture_snapshot();
        let dir = std::env::temp_dir().join("cgcn_test_snapshot");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.cgnm");
        snap.save(&path).unwrap();
        let back = load_model(&path).unwrap();
        std::fs::remove_file(&path).ok();
        let rebuilt = back.rebuild_workspace().unwrap();
        assert_eq!(rebuilt.dims, ws.dims);
        assert_eq!(rebuilt.n, ws.n);
        assert_eq!(rebuilt.m, ws.m);
    }

    #[test]
    fn capture_rejects_shape_mismatch() {
        let (snap, ws) = fixture_snapshot();
        let mut w = snap.w.clone();
        w[0] = Matrix::zeros(1, 1);
        assert!(ModelSnapshot::capture(snap.meta.clone(), &ws, &w).is_err());
        w.truncate(1);
        assert!(ModelSnapshot::capture(snap.meta, &ws, &w).is_err());
    }
}
