//! Closed-loop load generator for the inference server: N client threads
//! each issue a fixed count of node queries back-to-back, and the
//! per-request latencies are pooled into throughput + percentile stats.
//! Used by `cgcn loadgen` and `benches/serve_throughput.rs`.

use super::server::ServeClient;
use crate::util::rng::Rng;
use crate::util::stats::Summary;
use anyhow::{Context, Result};
use std::time::Instant;

/// Load shape.
#[derive(Clone, Copy, Debug)]
pub struct LoadgenOpts {
    /// Concurrent client connections. Keep ≤ the server's handler
    /// threads — the pool bounds concurrent connections, so extra
    /// clients would queue behind whole connections, not requests.
    pub clients: usize,
    /// Queries per client (closed loop: next query starts when the
    /// previous response lands).
    pub requests_per_client: usize,
    /// Node ids per query (drawn uniformly, seeded per client).
    pub nodes_per_query: usize,
    pub seed: u64,
}

impl Default for LoadgenOpts {
    fn default() -> Self {
        LoadgenOpts {
            clients: 4,
            requests_per_client: 200,
            nodes_per_query: 4,
            seed: 17,
        }
    }
}

/// Pooled results of one load-generation run.
#[derive(Clone, Debug)]
pub struct LoadgenReport {
    pub clients: usize,
    pub requests: usize,
    pub wall_secs: f64,
    /// Completed queries per second across all clients.
    pub qps: f64,
    /// Per-request latency stats in seconds (pooled over clients).
    pub latency: Summary,
}

/// Run a closed-loop load against `addr`, querying nodes in `0..n_nodes`.
pub fn run(addr: &str, n_nodes: usize, opts: &LoadgenOpts) -> Result<LoadgenReport> {
    anyhow::ensure!(n_nodes > 0, "loadgen needs a non-empty node range");
    anyhow::ensure!(
        opts.clients > 0 && opts.requests_per_client > 0 && opts.nodes_per_query > 0,
        "loadgen needs clients, requests and nodes-per-query all > 0"
    );
    let t0 = Instant::now();
    let results: Vec<Result<Vec<f64>>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..opts.clients)
            .map(|ci| {
                s.spawn(move || -> Result<Vec<f64>> {
                    let mut rng = Rng::new(opts.seed).fork(ci as u64 + 1);
                    let mut client = ServeClient::connect(addr)
                        .with_context(|| format!("loadgen client {ci}"))?;
                    let mut lats = Vec::with_capacity(opts.requests_per_client);
                    let mut nodes = vec![0usize; opts.nodes_per_query];
                    for _ in 0..opts.requests_per_client {
                        for nd in nodes.iter_mut() {
                            *nd = rng.gen_range(n_nodes);
                        }
                        let q0 = Instant::now();
                        let rows = client.query(&nodes)?;
                        lats.push(q0.elapsed().as_secs_f64());
                        anyhow::ensure!(rows.len() == nodes.len(), "short response");
                    }
                    Ok(lats)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("loadgen client panicked"))
            .collect()
    });
    let wall_secs = t0.elapsed().as_secs_f64();
    let mut lats = Vec::with_capacity(opts.clients * opts.requests_per_client);
    for r in results {
        lats.extend(r?);
    }
    let requests = lats.len();
    Ok(LoadgenReport {
        clients: opts.clients,
        requests,
        wall_secs,
        qps: requests as f64 / wall_secs.max(1e-9),
        latency: Summary::of(&lats),
    })
}
