//! Forward-only GCN inference with a per-community activation cache.
//!
//! The community-layerwise split makes inference naturally shardable: the
//! hidden state a query needs is `H_{L-1} = Ã Z_{L-1}`, and a row block of
//! any `H_l` depends only on `Z_l` rows of the owning community and its
//! partition neighbours (the nonzero columns of that community's `Ã`
//! blocks — the Cluster-GCN subgraph-batching observation). The session
//! exploits that with a cache of hidden activations at *per-community*
//! granularity:
//!
//! - a **cold** community is warmed by computing exactly the rows a query
//!   needs — its k-hop community neighbourhood, k = L−1 — via row-sliced
//!   kernels ([`Csr::slice_rows`] + the row-independent backend ops);
//! - a **warm** community answers node queries with a row gather plus one
//!   small `|query| × C_{L-1} × C_L` matmul — no layer-1 SpMM, no hidden
//!   matmuls at all;
//! - invalidation is **explicit** ([`InferenceSession::invalidate`]):
//!   dropping community `m` also drops every cache entry whose value
//!   depends on `m`'s rows, i.e. the communities within L−1 hops of `m`
//!   in the community adjacency. Weight swaps invalidate everything.
//!
//! Determinism: every kernel involved (dense matmul, SpMM, ReLU) computes
//! each output row from its input row(s) with the same scalar loop
//! regardless of which other rows are present (see
//! [`crate::runtime::backend`]), so warm-path, cold-path, batched and
//! single-node queries are all **bitwise identical** to the full-graph
//! forward pass [`evaluate_forward`] runs — asserted by the tests here,
//! by `rust/tests/serve_e2e.rs` and by the `query --verify` CI smoke
//! test.

use super::snapshot::ModelSnapshot;
use crate::coordinator::Workspace;
use crate::runtime::ComputeBackend;
use crate::tensor::{argmax_rows, Matrix};
use anyhow::{ensure, Result};
use std::sync::Arc;

/// Cache/query counters (cheap, read out over the serve stats endpoint).
#[derive(Clone, Copy, Debug, Default)]
pub struct SessionStats {
    /// Node-subset queries answered.
    pub queries: u64,
    /// Total nodes returned across queries.
    pub nodes: u64,
    /// Community cache entries computed (cold work).
    pub warms: u64,
    /// Queries answered entirely from warm communities.
    pub warm_hits: u64,
}

/// A loaded model bound to a workspace and a backend, ready to answer
/// forward-only queries.
pub struct InferenceSession {
    ws: Arc<Workspace>,
    backend: Arc<dyn ComputeBackend>,
    w: Vec<Matrix>,
    /// Human-readable model label (the snapshot's run label when loaded
    /// from one) — reported over the serve Info frame.
    label: String,
    /// Original dataset node id → permuted global row.
    old_to_new: Vec<usize>,
    /// Permuted global row → owning community (real rows only).
    community_of: Vec<usize>,
    /// `z_cache[l-1]` = Z_l rows (n_glob × C_l), valid per community.
    z_cache: Vec<Matrix>,
    /// `h_cache[l-1]` = (Ã Z_l) rows (n_glob × C_l), valid per community.
    h_cache: Vec<Matrix>,
    z_valid: Vec<Vec<bool>>,
    h_valid: Vec<Vec<bool>>,
    stats: SessionStats,
}

impl InferenceSession {
    /// Bind weights to a workspace. Weight shapes must match the
    /// workspace dims.
    pub fn new(
        ws: Arc<Workspace>,
        backend: Arc<dyn ComputeBackend>,
        w: Vec<Matrix>,
    ) -> Result<InferenceSession> {
        ensure!(
            w.len() == ws.layers && ws.layers >= 1,
            "session: {} weight matrices for {} layers",
            w.len(),
            ws.layers
        );
        for (li, wl) in w.iter().enumerate() {
            ensure!(
                wl.shape() == (ws.dims[li], ws.dims[li + 1]),
                "session: W_{} shape {:?} != dims ({}, {})",
                li + 1,
                wl.shape(),
                ws.dims[li],
                ws.dims[li + 1]
            );
        }

        let mut old_to_new = vec![0usize; ws.n];
        let mut community_of = vec![0usize; ws.n];
        for (ci, (c, members)) in ws
            .communities
            .iter()
            .zip(&ws.partition.members)
            .enumerate()
        {
            for (li, &old) in members.iter().enumerate() {
                old_to_new[old] = c.row_offset + li;
                community_of[c.row_offset + li] = ci;
            }
        }

        let hidden_layers = ws.layers - 1;
        let z_cache = (1..=hidden_layers)
            .map(|l| Matrix::zeros(ws.n_glob, ws.dims[l]))
            .collect();
        let h_cache = (1..=hidden_layers)
            .map(|l| Matrix::zeros(ws.n_glob, ws.dims[l]))
            .collect();
        let z_valid = vec![vec![false; ws.m]; hidden_layers];
        let h_valid = vec![vec![false; ws.m]; hidden_layers];
        let label = format!("n{}", ws.n);
        Ok(InferenceSession {
            ws,
            backend,
            w,
            label,
            old_to_new,
            community_of,
            z_cache,
            h_cache,
            z_valid,
            h_valid,
            stats: SessionStats::default(),
        })
    }

    /// Load a snapshot: rebuild its workspace and bind the weights.
    pub fn from_snapshot(
        snap: &ModelSnapshot,
        backend: Arc<dyn ComputeBackend>,
    ) -> Result<InferenceSession> {
        let ws = snap.rebuild_workspace()?;
        let mut session = InferenceSession::new(ws, backend, snap.w.clone())?;
        session.label = snap.meta.label.clone();
        Ok(session)
    }

    pub fn workspace(&self) -> &Arc<Workspace> {
        &self.ws
    }

    /// The compute backend answering this session's queries. The server
    /// probes it for a shared [`crate::util::pool::Runtime`] so
    /// connection handlers can run on the same workers as the kernels.
    pub fn backend(&self) -> &Arc<dyn ComputeBackend> {
        &self.backend
    }

    /// Model label shown to clients (snapshot run label when available).
    pub fn label(&self) -> &str {
        &self.label
    }

    pub fn weights(&self) -> &[Matrix] {
        &self.w
    }

    pub fn stats(&self) -> SessionStats {
        self.stats
    }

    /// Number of real (queryable) nodes.
    pub fn n(&self) -> usize {
        self.ws.n
    }

    pub fn num_classes(&self) -> usize {
        self.ws.dims[self.ws.layers]
    }

    // ---- cache maintenance -----------------------------------------------

    /// Drop every cache entry that depends on community `m`'s rows: `m`
    /// itself plus all communities within L−1 hops in the community
    /// adjacency (each SpMM hop widens the dependency cone by one
    /// neighbourhood). Conservative and cheap — validity bits only.
    pub fn invalidate(&mut self, m: usize) {
        assert!(m < self.ws.m, "invalidate: community {m} out of range");
        let hops = self.ws.layers.saturating_sub(1);
        let affected = self.community_hops(m, hops);
        for (zv, hv) in self.z_valid.iter_mut().zip(self.h_valid.iter_mut()) {
            for &c in &affected {
                zv[c] = false;
                hv[c] = false;
            }
        }
    }

    /// Drop the whole cache (weight swap, global feature refresh).
    pub fn invalidate_all(&mut self) {
        for v in self.z_valid.iter_mut().chain(self.h_valid.iter_mut()) {
            v.iter_mut().for_each(|b| *b = false);
        }
    }

    /// Communities within `hops` of `m` (inclusive), ascending.
    fn community_hops(&self, m: usize, hops: usize) -> Vec<usize> {
        let mut seen = vec![false; self.ws.m];
        seen[m] = true;
        let mut frontier = vec![m];
        for _ in 0..hops {
            let mut next = Vec::new();
            for &c in &frontier {
                for &r in &self.ws.communities[c].neighbors {
                    if !seen[r] {
                        seen[r] = true;
                        next.push(r);
                    }
                }
            }
            frontier = next;
        }
        (0..self.ws.m).filter(|&c| seen[c]).collect()
    }

    /// Warm Z_l rows for each listed community (`l` is 1-based).
    fn ensure_z(&mut self, l: usize, comms: &[usize]) -> Result<()> {
        for &m in comms {
            if self.z_valid[l - 1][m] {
                continue;
            }
            if l > 1 {
                self.ensure_h(l - 1, &[m])?;
            }
            let c = &self.ws.communities[m];
            let (lo, hi) = (c.row_offset, c.row_offset + c.size);
            let src = if l == 1 {
                self.ws.h0_glob.slice_rows(lo, hi)
            } else {
                self.h_cache[l - 2].slice_rows(lo, hi)
            };
            let rows = self.backend.fwd_relu(&src, &self.w[l - 1])?;
            self.z_cache[l - 1].copy_rows_from(&rows, lo);
            self.z_valid[l - 1][m] = true;
            self.stats.warms += 1;
            crate::obs_counter!("serve.cache.warms").inc();
        }
        Ok(())
    }

    /// Warm H_l = (Ã Z_l) rows for each listed community (`l` 1-based).
    /// A community's H rows read Z rows of itself and its partition
    /// neighbours — exactly the nonzero columns of its `Ã` row block.
    fn ensure_h(&mut self, l: usize, comms: &[usize]) -> Result<()> {
        for &m in comms {
            if self.h_valid[l - 1][m] {
                continue;
            }
            let mut needed: Vec<usize> = self.ws.communities[m]
                .neighbors
                .iter()
                .copied()
                .chain([m])
                .collect();
            needed.sort_unstable();
            self.ensure_z(l, &needed)?;
            let c = &self.ws.communities[m];
            let (lo, hi) = (c.row_offset, c.row_offset + c.size);
            let a_rows = self.ws.a_glob.slice_rows(lo, hi);
            let rows = self.backend.spmm(&a_rows, &self.z_cache[l - 1]);
            self.h_cache[l - 1].copy_rows_from(&rows, lo);
            self.h_valid[l - 1][m] = true;
            self.stats.warms += 1;
            crate::obs_counter!("serve.cache.warms").inc();
        }
        Ok(())
    }

    // ---- queries -----------------------------------------------------------

    /// Logits for a set of nodes (original dataset ids; duplicates fine),
    /// one row per requested node, in request order. Cold communities are
    /// warmed on the way; warm ones are a row gather + one matmul.
    pub fn logits_for(&mut self, nodes: &[usize]) -> Result<Matrix> {
        let _span = crate::span!("serve.logits", nodes = nodes.len());
        let l_total = self.ws.layers;
        let mut rows = Vec::with_capacity(nodes.len());
        for &id in nodes {
            ensure!(id < self.ws.n, "node id {id} out of range (n={})", self.ws.n);
            rows.push(self.old_to_new[id]);
        }

        if l_total >= 2 {
            let mut comms: Vec<usize> = rows.iter().map(|&r| self.community_of[r]).collect();
            comms.sort_unstable();
            comms.dedup();
            let all_warm = comms.iter().all(|&m| self.h_valid[l_total - 2][m]);
            self.ensure_h(l_total - 1, &comms)?;
            if all_warm {
                self.stats.warm_hits += 1;
            }
        }
        let h_last = if l_total >= 2 {
            &self.h_cache[l_total - 2]
        } else {
            &self.ws.h0_glob
        };
        let gathered = h_last.gather_rows(&rows);
        let logits = self.backend.mm_nn(&gathered, &self.w[l_total - 1])?;
        self.stats.queries += 1;
        self.stats.nodes += nodes.len() as u64;
        Ok(logits)
    }

    /// Predicted class per node (original ids, request order).
    pub fn predict(&mut self, nodes: &[usize]) -> Result<Vec<usize>> {
        Ok(argmax_rows(&self.logits_for(nodes)?))
    }

    /// Full-graph logits in **original** node order (n × C_L), via the
    /// exact kernel sequence of [`evaluate_forward`]; fills the whole
    /// cache as a side effect, so it doubles as the server's startup
    /// warm. Subset queries return bitwise-identical rows of this.
    ///
    /// [`evaluate_forward`]: crate::coordinator::evaluate_forward
    pub fn full_logits(&mut self) -> Result<Matrix> {
        let ws = &self.ws;
        let l_total = ws.layers;
        let backend = &*self.backend;
        let mut h = ws.h0_glob.clone();
        for l in 1..l_total {
            let zl = backend.fwd_relu(&h, &self.w[l - 1])?;
            h = backend.spmm(&ws.a_glob, &zl);
            self.z_cache[l - 1] = zl;
            self.h_cache[l - 1] = h.clone();
            self.z_valid[l - 1].iter_mut().for_each(|b| *b = true);
            self.h_valid[l - 1].iter_mut().for_each(|b| *b = true);
        }
        let logits_glob = backend.mm_nn(&h, &self.w[l_total - 1])?;
        self.stats.warms += 2 * (l_total - 1) as u64 * self.ws.m as u64;
        Ok(logits_glob.gather_rows(&self.old_to_new))
    }

    /// Warm every community at every layer (server startup).
    pub fn warm_all(&mut self) -> Result<()> {
        self.full_logits().map(|_| ())
    }

    /// (train_acc, test_acc, train loss) with the bound weights — same
    /// numbers the trainers report.
    pub fn evaluate(&self) -> Result<(f64, f64, f64)> {
        crate::coordinator::evaluate_forward(&self.ws, &*self.backend, &self.w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HyperParams;
    use crate::partition::Method;
    use crate::runtime::NativeBackend;
    use crate::util::rng::Rng;

    fn session(m: usize, layers: usize) -> InferenceSession {
        let ds = crate::data::fixtures::caveman(24, 3);
        let mut hp = HyperParams::for_dataset("caveman");
        hp.communities = m;
        hp.hidden = 8;
        hp.layers = layers;
        let ws = Arc::new(Workspace::build(&ds, &hp, Method::Metis).unwrap());
        let mut rng = Rng::new(41);
        let w: Vec<Matrix> = (1..=ws.layers)
            .map(|l| Matrix::glorot(ws.dims[l - 1], ws.dims[l], &mut rng))
            .collect();
        InferenceSession::new(ws, Arc::new(NativeBackend::new()), w).unwrap()
    }

    #[test]
    fn cold_subset_queries_match_full_logits_bitwise() {
        for layers in [2usize, 3] {
            let mut s = session(3, layers);
            let full = {
                let mut ref_s = session(3, layers);
                ref_s.full_logits().unwrap()
            };
            // Cold path: per-community warming, node by node and batched.
            let n = s.n();
            let ids: Vec<usize> = (0..n).step_by(5).collect();
            let batched = s.logits_for(&ids).unwrap();
            for (qi, &id) in ids.iter().enumerate() {
                assert_eq!(
                    batched.row(qi),
                    full.row(id),
                    "layers={layers} node {id} batched vs full"
                );
                let single = s.logits_for(&[id]).unwrap();
                assert_eq!(single.row(0), full.row(id), "single vs full");
            }
        }
    }

    #[test]
    fn warm_queries_skip_recompute_and_stay_identical() {
        let mut s = session(3, 2);
        let full = s.full_logits().unwrap(); // warms everything
        let warms_after_full = s.stats().warms;
        let got = s.logits_for(&[0, 7, 31]).unwrap();
        assert_eq!(s.stats().warms, warms_after_full, "warm query recomputed");
        assert_eq!(s.stats().warm_hits, 1);
        for (qi, &id) in [0usize, 7, 31].iter().enumerate() {
            assert_eq!(got.row(qi), full.row(id));
        }
    }

    #[test]
    fn invalidate_forces_recompute_to_same_values() {
        let mut s = session(3, 2);
        let full = s.full_logits().unwrap();
        s.invalidate(1);
        let warms_before = s.stats().warms;
        let ids: Vec<usize> = (0..s.n()).collect();
        let again = s.logits_for(&ids).unwrap();
        assert!(s.stats().warms > warms_before, "invalidate was a no-op");
        assert_eq!(again.data(), full.data());

        s.invalidate_all();
        let cold = s.logits_for(&ids).unwrap();
        assert_eq!(cold.data(), full.data());
    }

    #[test]
    fn duplicate_and_out_of_range_nodes() {
        let mut s = session(2, 2);
        let got = s.logits_for(&[5, 5, 2]).unwrap();
        assert_eq!(got.row(0), got.row(1));
        assert!(s.logits_for(&[s.n()]).is_err());
    }

    #[test]
    fn evaluate_matches_trainer_eval_path() {
        let s = session(3, 2);
        let (tr, te, loss) = s.evaluate().unwrap();
        let (tr2, te2, loss2) = crate::coordinator::evaluate_forward(
            s.workspace(),
            &NativeBackend::new(),
            s.weights(),
        )
        .unwrap();
        assert_eq!((tr, te, loss), (tr2, te2, loss2));
    }
}
