//! The community-based layerwise ADMM trainer (paper Algorithm 1).
//!
//! One epoch = one ADMM iteration:
//!
//! ```text
//! 1. gather  Z^k, U^k  → W-agent                       (star comm)
//! 2. W-agent: update every W_l in parallel (§3.1, eq. 2 with τ
//!    backtracking)                                     (layer parallelism)
//! 3. broadcast W^{k+1}                                 (star comm)
//! 4. communities: exchange first-order p and second-order s messages
//!    (Appendix A eq. 4)                                (p2p comm)
//! 5. communities: update Z_{l,m} (eq. 5/6 via eq. 8/10 with θ
//!    backtracking) and Z_{L,m} (eq. 7 via FISTA), all in parallel
//! 6. communities: dual update U_m (eq. 3)
//! ```
//!
//! Phases 4–6 are owned by [`CommunityAgent`]s and scheduled by an
//! executor chosen with [`ExecMode`]:
//!
//! - [`ExecMode::Serial`] runs the agents in a loop on the caller's
//!   thread, pricing "parallel" phases in virtual time at the critical
//!   path over agents (see [`super::clock`]) — the seed's 1-core model.
//! - [`ExecMode::Threads`] runs each agent as a real task with the p/s
//!   message phase exchanged through `mpsc` channels, so multi-core hosts
//!   observe the speedup in *wall clock* too. Tasks land on the shared
//!   work-stealing [`Runtime`] when the backend exposes one
//!   (`--runtime shared`, DESIGN.md §11 — agent phases and the kernels
//!   they fork trade the same threads), or on a dedicated agent [`Pool`]
//!   plus a W-partial [`FjPool`] in legacy `--runtime dual` mode. Message
//!   folds are order-canonicalised, so every mode produces
//!   bitwise-identical state; the virtual accounting is computed the same
//!   way (per-agent task seconds, max over agents per phase).
//!
//! Cross-community terms are strictly Jacobi (k-indexed) so the agents are
//! embarrassingly parallel within an epoch, while each agent's *own-block*
//! Z_L anchor uses its freshly updated Z_{L-1,m}
//! (`AdmmOptions::gauss_seidel`; the pure-Jacobi variant is an ablation).
//!
//! Deviation notes vs the paper's literal text:
//! - eq. 3 updates the dual with `p^k` messages; we use the residual
//!   against the exact `Q` the Z_L subproblem just solved
//!   (`U += ρ(Z_L^{k+1} − Q)`), the standard prox-linearised-ADMM ordering
//!   — it avoids an extra message round and is what dlADMM [7] implements.
//! - the W update defaults to a row-block-distributed reduction
//!   (`update_w_distributed`) rather than the centralised agent-(M+1)
//!   gather; `AdmmOptions::central_w` restores the paper-literal schedule.

use super::agent::{AgentCtx, CommunityAgent, PMsg, SMsg, BT_EPS, BT_MAX_DOUBLINGS, STEP_MIN};
use super::clock::{timed, EpochClock, LinkModel};
use super::workspace::Workspace;
use crate::metrics::{EpochRecord, RunReport};
use crate::runtime::ComputeBackend;
use crate::serve::{ModelSnapshot, SnapshotMeta};
use crate::tensor::{argmax_rows, Matrix};
use crate::util::pool::{fork_map, resolve_threads, FjPool, ForkExec, Pool, Runtime};
use crate::util::rng::Rng;
use anyhow::Result;
use std::sync::{mpsc, Arc};
use std::time::Instant;

/// How the community agents execute within one process.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// One thread, virtual-time accounting only (seed behaviour).
    Serial,
    /// Real shared-memory parallelism on the worker pool.
    Threads,
}

impl ExecMode {
    pub fn parse(s: &str) -> Option<ExecMode> {
        match s {
            "serial" => Some(ExecMode::Serial),
            "threads" => Some(ExecMode::Threads),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ExecMode::Serial => "serial",
            ExecMode::Threads => "threads",
        }
    }
}

/// Mutable ADMM state. `Clone` is the crash-recovery primitive: the
/// elastic coordinator snapshots the state at every epoch barrier and
/// restores it before retrying an epoch after a host loss, and the
/// `.cgck` checkpoint persists exactly these fields.
#[derive(Clone)]
pub struct AdmmState {
    /// Weights W_1..W_L (index l-1).
    pub w: Vec<Matrix>,
    /// z[l-1][m] = Z_{l,m} (n_pad × C_l), l = 1..=L.
    pub z: Vec<Vec<Matrix>>,
    /// Dual U_m (n_pad × C_L).
    pub u: Vec<Matrix>,
    /// τ_l per layer (quadratic-approximation steps, persisted).
    pub tau: Vec<f32>,
    /// θ_{l,m} per (hidden layer, community).
    pub theta: Vec<Vec<f32>>,
}

/// Trainer options beyond the workspace hyper-parameters.
#[derive(Clone, Debug)]
pub struct AdmmOptions {
    /// Account W updates at the per-layer critical path (Alg. 1 line 3).
    /// Only meaningful with `central_w` (the distributed W update is
    /// row-block-parallel instead).
    pub parallel_layers: bool,
    /// Gauss-Seidel within an epoch (serial mode): Z_L sees fresh Z_{L-1}.
    pub gauss_seidel: bool,
    /// Paper-literal centralised W update at agent M+1 (gather Z/U, update,
    /// broadcast). Default false: the W gradient reduces over community row
    /// blocks — same math, communication- and compute-parallel.
    pub central_w: bool,
    pub link: LinkModel,
    /// Agent executor (serial loop vs worker pool).
    pub exec: ExecMode,
    /// Worker threads for `exec == Threads` (0 = all available cores).
    pub threads: usize,
}

impl AdmmOptions {
    /// Paper-faithful defaults for a given community count.
    ///
    /// `gauss_seidel` defaults on for every mode: within a community agent
    /// the Z_L solve anchors against a `Q` whose *own-block* part uses the
    /// freshly updated Z_{L-1,m} (cross-community terms stay at k — no
    /// extra messages, so community parallelism is untouched). Pure-Jacobi
    /// anchoring is kept as an ablation (`benches/ablation_sweep`); it
    /// oscillates once the dual warms up, which is the within-epoch
    /// dependency the paper's own serial-vs-parallel gap reflects.
    pub fn for_mode(m: usize) -> AdmmOptions {
        AdmmOptions {
            parallel_layers: m > 1,
            gauss_seidel: true,
            central_w: false,
            link: LinkModel::new(10_000.0, 100.0),
            exec: ExecMode::Serial,
            threads: 0,
        }
    }
}

pub struct AdmmTrainer {
    pub ws: Arc<Workspace>,
    pub backend: Arc<dyn ComputeBackend>,
    pub opts: AdmmOptions,
    pub state: AdmmState,
    /// Shared work-stealing runtime, borrowed from the backend
    /// (`--runtime shared`): agent phase tasks go to its injector and the
    /// W-partial maps fork on it, alongside the backend's own kernel
    /// chunks — one thread budget for everything.
    rt: Option<Arc<Runtime>>,
    /// Dual-mode worker pool for `ExecMode::Threads` (one task per
    /// community agent). `None` on the shared runtime.
    pool: Option<Pool>,
    /// Dual-mode fork-join pool for the borrowed-data per-community
    /// W-partial maps in `ExecMode::Threads` (`pool` only takes `'static`
    /// jobs); the nested-fork inline guard in [`crate::util::pool`] keeps
    /// it safe next to the backend's kernel pool. `None` on the shared
    /// runtime.
    fj: Option<FjPool>,
    /// Resolved thread count (1 in serial mode).
    threads: usize,
}

impl AdmmTrainer {
    /// Initialise: Glorot weights, Z by a forward pass (dlADMM-style warm
    /// start), U = 0.
    pub fn new(
        ws: Arc<Workspace>,
        backend: Arc<dyn ComputeBackend>,
        opts: AdmmOptions,
    ) -> Result<AdmmTrainer> {
        // Pre-compile every artifact this run will touch up front — XLA
        // compilation is a startup cost in any real deployment and must not
        // pollute the per-epoch timings (no-op on the native backend).
        let sigs = training_sigs(&ws);
        backend.warmup(&sigs)?;

        let mut rng = Rng::new(ws.hp.seed);
        let l = ws.layers;
        let dims = ws.dims.clone();
        let mut w = Vec::with_capacity(l);
        for li in 1..=l {
            w.push(Matrix::glorot(dims[li - 1], dims[li], &mut rng));
        }

        // Forward warm start at the global view, then scatter.
        let mut z_glob: Vec<Matrix> = Vec::with_capacity(l);
        let mut h = ws.h0_glob.clone(); // Ã X
        for li in 1..=l {
            let zl = if li < l {
                // f(H W) — H already aggregated.
                backend.fwd_relu(&h, &w[li - 1])?
            } else {
                // Output layer is linear: Ã Z W — V then SpMM.
                let v = backend.mm_nn(&z_glob[li - 2], &w[li - 1])?;
                backend.spmm(&ws.a_glob, &v)
            };
            if li < l {
                h = backend.spmm(&ws.a_glob, &zl);
            }
            z_glob.push(zl);
        }
        let z: Vec<Vec<Matrix>> = z_glob.iter().map(|zg| ws.scatter(zg)).collect();
        let u = (0..ws.m)
            .map(|_| Matrix::zeros(ws.n_pad, dims[l]))
            .collect();

        // Agent executor resources: the backend's shared runtime when it
        // has one, else dual-mode pools (legacy `--runtime dual`, or a
        // backend like XLA that cannot share).
        let rt = backend.runtime().cloned();
        let threads = match (&opts.exec, &rt) {
            (ExecMode::Serial, _) => 1,
            (ExecMode::Threads, Some(rt)) => rt.threads(),
            (ExecMode::Threads, None) => resolve_threads(opts.threads),
        };
        let dual_pools = opts.exec == ExecMode::Threads && rt.is_none();
        let pool = dual_pools.then(|| Pool::new(threads.min(ws.m.max(1))));
        let fj = dual_pools.then(|| FjPool::new(threads.min(ws.m.max(1))));
        if opts.exec == ExecMode::Threads {
            match &rt {
                Some(rt) => log::info!(
                    "agent runtime: {} communities on the shared runtime ({} threads, backend={})",
                    ws.m,
                    rt.threads(),
                    backend.name()
                ),
                None => log::info!(
                    "agent runtime: {} communities on {} dual-mode pool threads (backend={})",
                    ws.m,
                    threads.min(ws.m.max(1)),
                    backend.name()
                ),
            }
        }

        // τ/θ start conservatively at 1.0 and adapt both ways: backtracking
        // doubles them when the quadratic majoriser is violated, and the
        // 0.5× post-acceptance decay lets them sink toward the subproblem's
        // true curvature scale (∝ ν, ρ) over the first ~15 epochs — the
        // ramp visible in the paper's own Figure-2 curves.
        Ok(AdmmTrainer {
            state: AdmmState {
                w,
                z,
                u,
                tau: vec![1.0; l],
                theta: vec![vec![1.0; ws.m]; l.saturating_sub(1)],
            },
            ws,
            backend,
            opts,
            rt,
            pool,
            fj,
            threads,
        })
    }

    /// Worker threads available to data-parallel phases (1 in serial mode).
    fn exec_threads(&self) -> usize {
        match self.opts.exec {
            ExecMode::Serial => 1,
            ExecMode::Threads => self.threads,
        }
    }

    /// The fork-join engine for the borrowed-data W-partial maps.
    fn fork_exec(&self) -> ForkExec<'_> {
        match (&self.rt, &self.fj) {
            (Some(rt), _) => ForkExec::Rt(rt),
            (None, Some(fj)) => ForkExec::Fj(fj),
            (None, None) => ForkExec::None,
        }
    }

    /// Submit one `'static` agent-phase task to the coarse executor: the
    /// shared runtime's injector, or the dual-mode agent pool. Panicking
    /// tasks are caught by either executor; the submitter notices through
    /// its result channel closing.
    fn submit(&self, task: impl FnOnce() + Send + 'static) {
        match (&self.rt, &self.pool) {
            (Some(rt), _) => rt.execute(task),
            (None, Some(pool)) => pool.execute(task),
            (None, None) => unreachable!("threads mode without an executor"),
        }
    }

    // ---- W subproblem (§3.1) ----------------------------------------------

    /// Update W_l (1-based l) given gathered global Z^k / U^k. Returns the
    /// subproblem value after the accepted step.
    fn update_w(&mut self, l: usize, z_glob: &[Matrix], u_glob: &Matrix) -> Result<f32> {
        let ws = self.ws.clone();
        let backend = &*self.backend;
        let last = l == ws.layers;
        let zprev = if l == 1 { &ws.x_glob } else { &z_glob[l - 2] };
        let zl = &z_glob[l - 1];
        let (nu, rho) = (ws.hp.nu, ws.hp.rho);

        let phi_at = |w: &Matrix| -> Result<f32> {
            // pre = Ã (Z_{l-1} W) — SpMM over the projected width.
            let v = backend.mm_nn(zprev, w)?;
            let pre = backend.spmm(&ws.a_glob, &v);
            if last {
                backend.out_phi(&pre, zl, u_glob, rho)
            } else {
                backend.hidden_phi(&pre, zl, nu)
            }
        };

        // Value + residual + gradient at W^k.
        let v = backend.mm_nn(zprev, &self.state.w[l - 1])?;
        let pre = backend.spmm(&ws.a_glob, &v);
        let (phi0, r) = if last {
            backend.out_residual(&pre, zl, u_glob, rho)?
        } else {
            backend.hidden_residual(&pre, zl, nu)?
        };
        let ar = backend.spmm(&ws.a_glob, &r);
        let gw = backend.mm_tn(zprev, &ar)?;
        let gsq = gw.frob_norm_sq() as f32;

        // Backtracking on τ: accept W⁺ = W − g/τ once
        // φ(W⁺) ≤ φ(W) − ‖g‖²/(2τ)  (⇔ P_l(W⁺; τ) ≥ φ(W⁺), eq. 2).
        let mut tau = self.state.tau[l - 1].max(STEP_MIN);
        let mut accepted = None;
        for _ in 0..BT_MAX_DOUBLINGS {
            let mut cand = self.state.w[l - 1].clone();
            cand.axpy(-1.0 / tau, &gw);
            let phi_c = phi_at(&cand)?;
            if phi_c <= phi0 - gsq / (2.0 * tau) + BT_EPS * phi0.abs().max(1.0) {
                accepted = Some((cand, phi_c));
                break;
            }
            tau *= 2.0;
        }
        let (cand, phi_c) =
            accepted.unwrap_or((self.state.w[l - 1].clone(), phi0)); // give up: keep W
        self.state.w[l - 1] = cand;
        // Gentle decay so τ can shrink again when the landscape flattens.
        self.state.tau[l - 1] = (tau * 0.5).max(STEP_MIN);
        Ok(phi_c)
    }

    /// Distributed W_l update: the gradient and objective decompose exactly
    /// over community row blocks,
    ///
    /// ```text
    /// φ_l(W)  = Σ_m φ_{l,m}(W)       with pre_m = S_m W,
    /// ∇φ_l(W) = Σ_m S_mᵀ R_m         where S_m = Σ_r Ã_{m,r} Z_{l-1,r},
    /// ```
    ///
    /// so each community computes its partial from local + boundary rows
    /// and the leader reduces. Per-community partials are independent, so
    /// in `--exec threads` mode they run on scoped workers; the reduction
    /// always folds in community order, keeping results bitwise identical
    /// to the serial schedule. τ backtracking only re-evaluates the cheap
    /// `pre_m = S_m W_c` products (S_m is fixed across trials).
    ///
    /// Returns the number of trials (for broadcast byte accounting) and
    /// accumulates per-community compute seconds.
    fn update_w_distributed(&mut self, l: usize, per_comm_secs: &mut [f64]) -> Result<usize> {
        let ws = self.ws.clone();
        let n = ws.n_pad;
        let (a, b) = (ws.dims[l - 1], ws.dims[l]);
        let last = l == ws.layers;
        let (nu, rho) = (ws.hp.nu, ws.hp.rho);
        let backend = self.backend.clone();
        let par = self.exec_threads();
        let fx = self.fork_exec();

        // S_m = Σ_r Ã_{m,r} Z_{l-1,r} — one sparse aggregate per community,
        // reused by every backtracking trial. For l = 1 it equals the
        // *static* per-community H0 rows (X never changes), so no SpMM at
        // all.
        let state_z = &self.state.z;
        let s_results: Vec<(Option<Matrix>, f64)> = fork_map(fx, par, ws.m, |mi| {
            if l == 1 {
                return (None, 0.0);
            }
            let t0 = Instant::now();
            let comm = &ws.communities[mi];
            let mut s = Matrix::zeros(n, a);
            for r in comm.neighbors.iter().copied().chain([mi]) {
                if let Some(blk) = comm.blocks.get(&r) {
                    s.add_assign(&backend.spmm(blk, &state_z[l - 2][r]));
                }
            }
            (Some(s), t0.elapsed().as_secs_f64())
        });
        let mut s_own: Vec<Option<Matrix>> = Vec::with_capacity(ws.m);
        for (mi, (s, secs)) in s_results.into_iter().enumerate() {
            per_comm_secs[mi] += secs;
            s_own.push(s);
        }
        let s_refs: Vec<&Matrix> = (0..ws.m)
            .map(|mi| s_own[mi].as_ref().unwrap_or(&ws.h0_comm[mi]))
            .collect();

        // Partial values/gradients at W^k; leader reduces in m order.
        let w_k = &self.state.w[l - 1];
        let zl = &self.state.z[l - 1];
        let u = &self.state.u;
        let partials: Vec<Result<(f32, Matrix, f64)>> = fork_map(fx, par, ws.m, |mi| {
            let _span = crate::span!("admm.w_partial", community = mi);
            let t0 = Instant::now();
            let pre = backend.mm_nn(s_refs[mi], w_k)?;
            let (phi_m, r_m) = if last {
                backend.out_residual(&pre, &zl[mi], &u[mi], rho)?
            } else {
                backend.hidden_residual(&pre, &zl[mi], nu)?
            };
            let g_m = backend.mm_tn(s_refs[mi], &r_m)?;
            backend.recycle(pre);
            backend.recycle(r_m);
            Ok((phi_m, g_m, t0.elapsed().as_secs_f64()))
        });
        let mut phi0 = 0.0f32;
        let mut gw = Matrix::zeros(a, b);
        for (mi, res) in partials.into_iter().enumerate() {
            let (phi_m, g_m, secs) = res?;
            phi0 += phi_m;
            gw.add_assign(&g_m);
            backend.recycle(g_m);
            per_comm_secs[mi] += secs;
        }
        let gsq = gw.frob_norm_sq() as f32;

        // Backtracking on τ: accept W⁺ = W − g/τ once
        // φ(W⁺) ≤ φ(W) − ‖g‖²/(2τ)  (⇔ P_l(W⁺; τ) ≥ φ(W⁺), eq. 2).
        let mut tau = self.state.tau[l - 1].max(STEP_MIN);
        let mut trials = 0usize;
        let mut accepted = None;
        for _ in 0..BT_MAX_DOUBLINGS {
            trials += 1;
            let mut cand = self.state.w[l - 1].clone();
            cand.axpy(-1.0 / tau, &gw);
            let cand_ref = &cand;
            let trial: Vec<Result<(f32, f64)>> = fork_map(fx, par, ws.m, |mi| {
                let t0 = Instant::now();
                let pre = backend.mm_nn(s_refs[mi], cand_ref)?;
                let phi = if last {
                    backend.out_phi(&pre, &zl[mi], &u[mi], rho)?
                } else {
                    backend.hidden_phi(&pre, &zl[mi], nu)?
                };
                backend.recycle(pre);
                Ok((phi, t0.elapsed().as_secs_f64()))
            });
            let mut phi_c = 0.0f32;
            for (mi, res) in trial.into_iter().enumerate() {
                let (phi, secs) = res?;
                phi_c += phi;
                per_comm_secs[mi] += secs;
            }
            if phi_c <= phi0 - gsq / (2.0 * tau) + BT_EPS * phi0.abs().max(1.0) {
                accepted = Some(cand);
                break;
            }
            tau *= 2.0;
        }
        if let Some(cand) = accepted {
            self.state.w[l - 1] = cand;
        }
        if trials > 4 {
            log::trace!("w backtracking: layer {l} took {trials} trials (tau={tau:.3e})");
        }
        // Adaptive step persistence: only probe a smaller τ after an epoch
        // that accepted on the first trial — keeps the steady-state trial
        // count near 1.5 instead of paying a guaranteed violation per epoch.
        self.state.tau[l - 1] = if trials == 1 {
            (tau * 0.5).max(STEP_MIN)
        } else {
            tau
        };
        // S_m aggregates are epoch-local temporaries; park them for reuse.
        drop(s_refs);
        for s in s_own.into_iter().flatten() {
            backend.recycle(s);
        }
        Ok(trials)
    }

    // ---- agent phases (4–6) -------------------------------------------------

    /// Move per-community state out into [`CommunityAgent`]s, run phases
    /// 4–6 on the configured executor, and write the state back. Returns
    /// per-agent (message, z-update) compute seconds plus per-sender byte
    /// lists for the p and s exchanges.
    #[allow(clippy::type_complexity)]
    fn run_agent_phases(&mut self) -> Result<(Vec<f64>, Vec<f64>, Vec<Vec<u64>>, Vec<Vec<u64>>)> {
        let m = self.ws.m;
        let mut agents: Vec<CommunityAgent> = (0..m).map(|mi| self.take_agent(mi)).collect();

        // State is always written back — even on error — so a failed epoch
        // leaves the trainer with its agents' last consistent state rather
        // than 0×0 placeholders. (A panicked pool task can still lose its
        // agent; the error is propagated either way.)
        match self.opts.exec {
            ExecMode::Serial => {
                let result = self.agents_serial(&mut agents);
                for ag in agents {
                    self.put_agent(ag);
                }
                result
            }
            ExecMode::Threads => {
                let (recovered, result) = self.agents_threaded(agents);
                for ag in recovered {
                    self.put_agent(ag);
                }
                result
            }
        }
    }

    /// Serial executor: the agents run in a loop on this thread; messages
    /// move through plain vectors, received p by reference (zero-copy, as
    /// the seed's fold did). Virtual time still prices each phase at the
    /// critical path over agents.
    #[allow(clippy::type_complexity)]
    fn agents_serial(
        &self,
        agents: &mut [CommunityAgent],
    ) -> Result<(Vec<f64>, Vec<f64>, Vec<Vec<u64>>, Vec<Vec<u64>>)> {
        let ws = &*self.ws;
        let ctx = AgentCtx {
            ws,
            backend: &*self.backend,
            w: &self.state.w,
            gauss_seidel: self.opts.gauss_seidel,
        };
        let m = ws.m;
        let mut msg_secs = vec![0.0f64; m];
        let mut z_secs = vec![0.0f64; m];

        // Phase A: first-order products.
        let mut p_owns: Vec<Vec<Matrix>> = Vec::with_capacity(m);
        let mut p_outs: Vec<Vec<PMsg>> = Vec::with_capacity(m);
        for ag in agents.iter() {
            let _span = crate::span!("admm.p_products", community = ag.mi);
            let t0 = Instant::now();
            let (own, out) = ag.p_products(&ctx)?;
            msg_secs[ag.mi] += t0.elapsed().as_secs_f64();
            p_owns.push(own);
            p_outs.push(out);
        }
        let p_bytes = p_byte_lists(ws, &p_outs);

        // Route p by reference — senders keep ownership for phase C.
        let mut p_ins: Vec<Vec<&PMsg>> = (0..m).map(|_| Vec::new()).collect();
        for out in &p_outs {
            for msg in out {
                p_ins[msg.dst].push(msg);
            }
        }

        // Phase B: fold + second-order messages.
        let mut fulls: Vec<Vec<Matrix>> = Vec::with_capacity(m);
        let mut crosses: Vec<Vec<Matrix>> = Vec::with_capacity(m);
        let mut s_outs: Vec<Vec<SMsg>> = Vec::with_capacity(m);
        for (i, ag) in agents.iter().enumerate() {
            let _span = crate::span!("admm.s_messages", community = ag.mi);
            let t0 = Instant::now();
            let (full, cross) = ag.fold_p(&ctx, &p_owns[i], &mut p_ins[i]);
            let s = ag.s_messages(&ctx, &full, &p_ins[i])?;
            msg_secs[ag.mi] += t0.elapsed().as_secs_f64();
            fulls.push(full);
            crosses.push(cross);
            s_outs.push(s);
        }
        let s_bytes = s_byte_lists(ws, &s_outs);

        // Route s (moves — senders are done with them).
        let mut s_ins: Vec<Vec<SMsg>> = (0..m).map(|_| Vec::new()).collect();
        for out in s_outs {
            for msg in out {
                s_ins[msg.dst].push(msg);
            }
        }

        // Phase C: Z/U updates.
        for (i, ag) in agents.iter_mut().enumerate() {
            let _span = crate::span!("admm.z_update", community = ag.mi);
            let t0 = Instant::now();
            ag.update_z_u(&ctx, &fulls[i], &crosses[i], &p_outs[i], &mut s_ins[i])?;
            z_secs[i] += t0.elapsed().as_secs_f64();
        }
        Ok((msg_secs, z_secs, p_bytes, s_bytes))
    }

    /// Threaded executor: one task per agent per phase (on the shared
    /// runtime's injector or the dual-mode agent pool — see [`Self::submit`]),
    /// with the p/s messages exchanged through per-community `mpsc`
    /// mailboxes. Stage
    /// barriers (collect-all between phases) give every receiver its full
    /// inbox; sorting inside the agent makes fold order — and therefore
    /// the result — identical to the serial executor, bit for bit.
    ///
    /// Always returns the agents it could recover (so the caller can
    /// restore trainer state even when the epoch errors); an agent inside
    /// a task that panicked is lost.
    #[allow(clippy::type_complexity)]
    fn agents_threaded(
        &self,
        agents: Vec<CommunityAgent>,
    ) -> (
        Vec<CommunityAgent>,
        Result<(Vec<f64>, Vec<f64>, Vec<Vec<u64>>, Vec<Vec<u64>>)>,
    ) {
        let ws = self.ws.clone();
        let backend = self.backend.clone();
        let w = Arc::new(self.state.w.clone());
        let gs = self.opts.gauss_seidel;
        let m = ws.m;
        let mut msg_secs = vec![0.0f64; m];
        let mut z_secs = vec![0.0f64; m];

        // Per-community p mailboxes.
        let mut p_txs = Vec::with_capacity(m);
        let mut p_rxs = Vec::with_capacity(m);
        for _ in 0..m {
            let (tx, rx) = mpsc::channel::<PMsg>();
            p_txs.push(tx);
            p_rxs.push(rx);
        }

        // ---- Phase A ------------------------------------------------------
        let (done_tx, done_rx) = mpsc::channel();
        for ag in agents {
            let ws = ws.clone();
            let backend = backend.clone();
            let w = w.clone();
            let p_txs = p_txs.clone();
            let done_tx = done_tx.clone();
            self.submit(move || {
                let _span = crate::span!("admm.p_products", community = ag.mi);
                let t0 = Instant::now();
                let ctx = AgentCtx {
                    ws: &ws,
                    backend: &*backend,
                    w: &w,
                    gauss_seidel: gs,
                };
                let res = ag.p_products(&ctx).map(|(own, out)| {
                    for msg in &out {
                        let _ = p_txs[msg.dst].send(msg.clone());
                    }
                    (own, out)
                });
                let secs = t0.elapsed().as_secs_f64();
                let _ = done_tx.send((ag, res, secs));
            });
        }
        drop(done_tx);
        drop(p_txs);
        let mut slots_a: Vec<Option<(CommunityAgent, Vec<Matrix>, Vec<PMsg>)>> =
            (0..m).map(|_| None).collect();
        let mut failed: Vec<CommunityAgent> = Vec::new();
        let mut first_err: Option<anyhow::Error> = None;
        let barrier_a = crate::span!("admm.barrier_wait", phase = 0);
        for _ in 0..m {
            let Ok((ag, res, secs)) = done_rx.recv() else {
                first_err = first_err.or(Some(anyhow::anyhow!("agent task panicked in phase A")));
                break;
            };
            let mi = ag.mi;
            msg_secs[mi] += secs;
            match res {
                Ok((own, out)) => slots_a[mi] = Some((ag, own, out)),
                Err(e) => {
                    first_err = first_err.or(Some(e));
                    failed.push(ag);
                }
            }
        }
        drop(barrier_a);
        if let Some(e) = first_err {
            failed.extend(slots_a.into_iter().flatten().map(|(ag, _, _)| ag));
            return (failed, Err(e));
        }
        let p_bytes: Vec<Vec<u64>> = slots_a
            .iter()
            .map(|s| p_bytes_for(&ws, &s.as_ref().expect("missing agent").2))
            .collect();

        // ---- Phase B ------------------------------------------------------
        let mut s_txs = Vec::with_capacity(m);
        let mut s_rxs = Vec::with_capacity(m);
        for _ in 0..m {
            let (tx, rx) = mpsc::channel::<SMsg>();
            s_txs.push(tx);
            s_rxs.push(rx);
        }
        let (done_tx, done_rx) = mpsc::channel();
        for (slot, p_rx) in slots_a.into_iter().zip(p_rxs) {
            let (ag, p_own, p_out) = slot.expect("missing agent result");
            let ws = ws.clone();
            let backend = backend.clone();
            let w = w.clone();
            let s_txs = s_txs.clone();
            let done_tx = done_tx.clone();
            self.submit(move || {
                let _span = crate::span!("admm.s_messages", community = ag.mi);
                let t0 = Instant::now();
                let ctx = AgentCtx {
                    ws: &ws,
                    backend: &*backend,
                    w: &w,
                    gauss_seidel: gs,
                };
                let mut p_in_owned: Vec<PMsg> = Vec::new();
                while let Ok(msg) = p_rx.try_recv() {
                    p_in_owned.push(msg);
                }
                let mut p_in: Vec<&PMsg> = p_in_owned.iter().collect();
                let (full, cross) = ag.fold_p(&ctx, &p_own, &mut p_in);
                let res = ag.s_messages(&ctx, &full, &p_in).map(|s_out| {
                    // Byte-account before the matrices move into mailboxes.
                    let bytes = s_bytes_for(&ws, &s_out);
                    for msg in s_out {
                        let _ = s_txs[msg.dst].send(msg);
                    }
                    (full, cross, p_out, bytes)
                });
                let secs = t0.elapsed().as_secs_f64();
                let _ = done_tx.send((ag, res, secs));
            });
        }
        drop(done_tx);
        drop(s_txs);
        #[allow(clippy::type_complexity)]
        let mut slots_b: Vec<Option<(CommunityAgent, Vec<Matrix>, Vec<Matrix>, Vec<PMsg>)>> =
            (0..m).map(|_| None).collect();
        let mut s_bytes: Vec<Vec<u64>> = (0..m).map(|_| Vec::new()).collect();
        let barrier_b = crate::span!("admm.barrier_wait", phase = 1);
        for _ in 0..m {
            let Ok((ag, res, secs)) = done_rx.recv() else {
                first_err = first_err.or(Some(anyhow::anyhow!("agent task panicked in phase B")));
                break;
            };
            let mi = ag.mi;
            msg_secs[mi] += secs;
            match res {
                Ok((full, cross, p_out, bytes)) => {
                    s_bytes[mi] = bytes;
                    slots_b[mi] = Some((ag, full, cross, p_out))
                }
                Err(e) => {
                    first_err = first_err.or(Some(e));
                    failed.push(ag);
                }
            }
        }
        drop(barrier_b);
        if let Some(e) = first_err {
            failed.extend(slots_b.into_iter().flatten().map(|(ag, _, _, _)| ag));
            return (failed, Err(e));
        }

        // ---- Phase C ------------------------------------------------------
        let (done_tx, done_rx) = mpsc::channel();
        for (slot, s_rx) in slots_b.into_iter().zip(s_rxs) {
            let (mut ag, full, cross, p_out) = slot.expect("missing agent result");
            let ws = ws.clone();
            let backend = backend.clone();
            let w = w.clone();
            let done_tx = done_tx.clone();
            self.submit(move || {
                let _span = crate::span!("admm.z_update", community = ag.mi);
                let t0 = Instant::now();
                let ctx = AgentCtx {
                    ws: &ws,
                    backend: &*backend,
                    w: &w,
                    gauss_seidel: gs,
                };
                let mut s_in: Vec<SMsg> = Vec::new();
                while let Ok(msg) = s_rx.try_recv() {
                    s_in.push(msg);
                }
                let res = ag.update_z_u(&ctx, &full, &cross, &p_out, &mut s_in);
                let secs = t0.elapsed().as_secs_f64();
                let _ = done_tx.send((ag, res, secs));
            });
        }
        drop(done_tx);
        let mut out_agents: Vec<Option<CommunityAgent>> = (0..m).map(|_| None).collect();
        let barrier_c = crate::span!("admm.barrier_wait", phase = 2);
        for _ in 0..m {
            let Ok((ag, res, secs)) = done_rx.recv() else {
                first_err = first_err.or(Some(anyhow::anyhow!("agent task panicked in phase C")));
                break;
            };
            let mi = ag.mi;
            z_secs[mi] += secs;
            match res {
                Ok(()) => out_agents[mi] = Some(ag),
                Err(e) => {
                    first_err = first_err.or(Some(e));
                    failed.push(ag);
                }
            }
        }
        drop(barrier_c);
        let recovered: Vec<CommunityAgent> = out_agents
            .into_iter()
            .flatten()
            .chain(failed)
            .collect();
        if let Some(e) = first_err {
            return (recovered, Err(e));
        }
        (recovered, Ok((msg_secs, z_secs, p_bytes, s_bytes)))
    }

    // ---- one ADMM epoch ------------------------------------------------------

    pub fn epoch(&mut self) -> Result<EpochClock> {
        let _span = crate::span!("admm.epoch");
        crate::obs_counter!("admm.epochs").inc();
        let ws = self.ws.clone();
        let mut clock = EpochClock::default();
        let l_total = ws.layers;

        // ---- 1–3. W update ------------------------------------------------
        if self.opts.central_w {
            // Paper-literal agent-(M+1) W update: gather Z^k/U^k, update
            // centrally (layer-parallel), broadcast W^{k+1}.
            if ws.m > 1 {
                let mut msgs = Vec::new();
                for c in ws.communities.iter() {
                    let mut bytes = 0u64;
                    for l in 1..=l_total {
                        bytes += ws.msg_bytes(c.size, ws.dims[l]);
                    }
                    bytes += ws.msg_bytes(c.size, ws.dims[l_total]); // U
                    msgs.push(bytes);
                }
                clock.star(&self.opts.link, &msgs);
            }
            let z_glob: Vec<Matrix> = (0..l_total)
                .map(|li| ws.gather(&self.state.z[li]))
                .collect();
            let u_glob = ws.gather(&self.state.u);
            let mut layer_secs = Vec::with_capacity(l_total);
            for l in 1..=l_total {
                let _span = crate::span!("admm.w_update", layer = l);
                let (res, secs) = timed(|| self.update_w(l, &z_glob, &u_glob));
                res?;
                layer_secs.push(secs);
            }
            if self.opts.parallel_layers {
                clock.parallel_phase(&layer_secs);
            } else {
                clock.serial_phase(layer_secs.iter().sum());
            }
            if ws.m > 1 {
                let w_bytes: u64 = (1..=l_total)
                    .map(|l| ws.msg_bytes(ws.dims[l - 1], ws.dims[l]))
                    .sum();
                clock.star(&self.opts.link, &vec![w_bytes; ws.m]);
            }
        } else {
            // Distributed W update (default — see update_w_distributed).
            // Comm: boundary Z-block exchange (l ≥ 2; X is static),
            // gradient-partial reduce up, W/trial broadcasts down.
            let mut w_secs = vec![0.0f64; ws.m];
            let mut total_trials = 0usize;
            for l in 1..=l_total {
                let _span = crate::span!("admm.w_update", layer = l);
                if ws.m > 1 && l >= 2 {
                    let per_sender: Vec<Vec<u64>> = ws
                        .communities
                        .iter()
                        .map(|c| {
                            c.boundary_to
                                .values()
                                .map(|&rows| ws.msg_bytes(rows, ws.dims[l - 1]))
                                .collect()
                        })
                        .collect();
                    clock.exchange(&self.opts.link, &per_sender);
                }
                total_trials += self.update_w_distributed(l, &mut w_secs)?;
            }
            clock.parallel_phase(&w_secs);
            // Trial count only moves 8-byte scalars on the wire; keep the
            // tally visible in the metrics scrape.
            crate::obs_counter!("admm.w_trials").add(total_trials as u64);
            if ws.m > 1 {
                // Per layer: M gradient partials up, one aggregated gradient
                // down per community (workers form W − g/τ locally; the τ
                // backtracking exchanges scalars, which round to nothing).
                let per_w: u64 = (1..=l_total)
                    .map(|l| ws.msg_bytes(ws.dims[l - 1], ws.dims[l]))
                    .sum();
                clock.star(&self.opts.link, &vec![per_w; ws.m]); // reduce up
                clock.star(&self.opts.link, &vec![per_w; ws.m]); // g down
            }
        }
        let t_after_w = clock.train;

        // ---- 4–6. agent phases (p/s messages, Z updates, dual) ------------
        let (msg_secs, z_secs, p_bytes, s_bytes) = self.run_agent_phases()?;
        clock.parallel_phase(&msg_secs);
        if ws.m > 1 {
            // p messages m→r: nonzero only at r's boundary rows toward m
            // (the nonzero rows of Ã_{r,m}), so only those ship. s messages
            // r→m: two dense (n_r × C_{l+1}) halves per edge, l ≥ 1 only.
            clock.exchange(&self.opts.link, &p_bytes);
            clock.exchange(&self.opts.link, &s_bytes);
        }
        clock.parallel_phase(&z_secs);
        log::trace!(
            "epoch phases: W {:.1}ms, msg+Z {:.1}ms, comm {:.1}ms",
            t_after_w * 1e3,
            (clock.train - t_after_w) * 1e3,
            clock.comm * 1e3
        );
        Ok(clock)
    }

    // ---- transport hooks (the TCP worker/leader drive phases directly) ------

    /// Distributed W update for one layer — leader side of the TCP runtime
    /// (identical math to the local default schedule).
    pub fn update_w_distributed_public(
        &mut self,
        l: usize,
        per_comm_secs: &mut [f64],
    ) -> Result<usize> {
        self.update_w_distributed(l, per_comm_secs)
    }

    /// Move one community's state out as an agent (TCP worker side).
    pub fn take_agent(&mut self, mi: usize) -> CommunityAgent {
        let l_total = self.ws.layers;
        CommunityAgent {
            mi,
            z: (0..l_total)
                .map(|li| std::mem::replace(&mut self.state.z[li][mi], Matrix::zeros(0, 0)))
                .collect(),
            u: std::mem::replace(&mut self.state.u[mi], Matrix::zeros(0, 0)),
            theta: (0..l_total - 1).map(|li| self.state.theta[li][mi]).collect(),
        }
    }

    /// Write an agent's state back into the trainer.
    pub fn put_agent(&mut self, agent: CommunityAgent) {
        let mi = agent.mi;
        for (li, z) in agent.z.into_iter().enumerate() {
            self.state.z[li][mi] = z;
        }
        self.state.u[mi] = agent.u;
        for (li, th) in agent.theta.into_iter().enumerate() {
            self.state.theta[li][mi] = th;
        }
    }

    /// Read-only per-epoch context for driving [`CommunityAgent`] phases
    /// externally (TCP worker side).
    pub fn agent_ctx(&self) -> AgentCtx<'_> {
        AgentCtx {
            ws: &self.ws,
            backend: &*self.backend,
            w: &self.state.w,
            gauss_seidel: self.opts.gauss_seidel,
        }
    }

    // ---- evaluation (untimed, leader-side forward pass) ---------------------

    /// Forward pass with current weights; returns (train_acc, test_acc,
    /// train loss).
    pub fn evaluate(&self) -> Result<(f64, f64, f64)> {
        evaluate_forward(&self.ws, &*self.backend, &self.state.w)
    }

    /// Snapshot the current weights to a `.cgnm` file (`train --save`);
    /// reload with [`crate::serve::load_model`] and serve with
    /// [`crate::serve::InferenceSession`].
    pub fn save_model(&self, path: &std::path::Path, meta: SnapshotMeta) -> Result<()> {
        ModelSnapshot::capture(meta, &self.ws, &self.state.w)?.save(path)
    }

    /// Run a full training: `epochs` ADMM iterations with per-epoch eval.
    pub fn train(&mut self, epochs: usize, label: &str) -> Result<RunReport> {
        self.train_range(0, epochs, label, None)
    }

    /// Run epochs `start..epochs`, optionally writing a `.cgck` training
    /// checkpoint at the sink's interval. Each epoch is a pure function of
    /// the state at its epoch barrier, so a run interrupted after any
    /// checkpoint and resumed from it reproduces the uninterrupted run's
    /// weights bit for bit (see `rust/tests/fault_tolerance.rs`).
    pub fn train_range(
        &mut self,
        start: usize,
        epochs: usize,
        label: &str,
        sink: Option<&super::checkpoint::CheckpointSink>,
    ) -> Result<RunReport> {
        let mut report = RunReport::new(label, &dataset_label(&self.ws), self.ws.m);
        for e in start..epochs {
            let wall0 = Instant::now();
            let clock = self.epoch()?;
            let wall = wall0.elapsed().as_secs_f64();
            crate::obs_hist!("admm.epoch.secs", crate::obs::TIME_BUCKETS).record(wall);
            let (train_acc, test_acc, loss) = self.evaluate()?;
            log::debug!(
                "[{label}] epoch {e}: loss={loss:.4} train={train_acc:.3} test={test_acc:.3} \
                 vt={:.3}s vc={:.3}s wall={wall:.3}s",
                clock.train,
                clock.comm
            );
            report.push(EpochRecord {
                epoch: e,
                train_acc,
                test_acc,
                loss,
                t_train: clock.train,
                t_comm: clock.comm,
                t_wall: wall,
                bytes: clock.bytes,
            });
            if let Some(sink) = sink {
                sink.maybe_write(e + 1, || super::checkpoint::CkptState::from_admm(&self.state))?;
            }
        }
        Ok(report)
    }
}

/// One sender's byte list for the p exchange: only the receiver's boundary
/// rows toward the sender are nonzero, so only those ship.
fn p_bytes_for(ws: &Workspace, msgs: &[PMsg]) -> Vec<u64> {
    msgs.iter()
        .map(|m| {
            let rows = ws.communities[m.src].boundary_from[&m.dst];
            ws.msg_bytes(rows, ws.dims[m.layer + 1])
        })
        .collect()
}

/// One sender's byte list for the s exchange: two dense halves per message.
fn s_bytes_for(ws: &Workspace, msgs: &[SMsg]) -> Vec<u64> {
    msgs.iter()
        .map(|m| 2 * ws.msg_bytes(ws.communities[m.src].size, ws.dims[m.layer + 1]))
        .collect()
}

/// Per-sender byte lists for the p exchange.
fn p_byte_lists(ws: &Workspace, p_outs: &[Vec<PMsg>]) -> Vec<Vec<u64>> {
    p_outs.iter().map(|msgs| p_bytes_for(ws, msgs)).collect()
}

/// Per-sender byte lists for the s exchange.
fn s_byte_lists(ws: &Workspace, s_outs: &[Vec<SMsg>]) -> Vec<Vec<u64>> {
    s_outs.iter().map(|msgs| s_bytes_for(ws, msgs)).collect()
}

/// Forward-pass evaluation shared with the baselines: accuracy on train and
/// test masks plus the training loss, computed at the (padded) global view.
pub fn evaluate_forward(
    ws: &Workspace,
    backend: &dyn ComputeBackend,
    w: &[Matrix],
) -> Result<(f64, f64, f64)> {
    let l_total = ws.layers;
    let mut h = ws.h0_glob.clone();
    let mut z = None;
    for l in 1..=l_total {
        if l < l_total {
            let zl = backend.fwd_relu(&h, &w[l - 1])?;
            h = backend.spmm(&ws.a_glob, &zl);
            z = Some(zl);
        } else {
            let src = z.as_ref().map(|_| &h).unwrap_or(&ws.h0_glob);
            // logits = Ã Z_{L-1} W_L — but h is already Ã Z_{L-1}, so the
            // product IS the logits; no extra SpMM.
            let logits = backend.mm_nn(src, &w[l - 1])?;
            let loss = backend.xent_loss(&logits, &ws.y_glob, &ws.train_mask_glob, ws.denom)?
                as f64;
            let preds = argmax_rows(&logits);
            let (mut tr_c, mut tr_t, mut te_c, mut te_t) = (0usize, 0usize, 0usize, 0usize);
            for i in 0..ws.n {
                if ws.train_mask_glob[i] > 0.0 {
                    tr_t += 1;
                    if preds[i] == ws.labels[i] {
                        tr_c += 1;
                    }
                }
                if ws.test_mask_glob[i] > 0.0 {
                    te_t += 1;
                    if preds[i] == ws.labels[i] {
                        te_c += 1;
                    }
                }
            }
            return Ok((
                tr_c as f64 / tr_t.max(1) as f64,
                te_c as f64 / te_t.max(1) as f64,
                loss,
            ));
        }
    }
    unreachable!("layers >= 1")
}

pub(super) fn dataset_label(ws: &Workspace) -> String {
    format!("n{}", ws.n)
}

/// Every artifact signature an ADMM run touches (warmup list for the XLA
/// backend; the native backend ignores it).
pub fn training_sigs(ws: &Workspace) -> Vec<String> {
    let l_total = ws.layers;
    let mut sigs = Vec::new();
    for &n in &[ws.n_pad, ws.n_glob] {
        for l in 1..=l_total {
            let (a, b) = (ws.dims[l - 1], ws.dims[l]);
            for entry in ["mm_nn", "mm_tn", "mm_bt"] {
                sigs.push(ws.sig_nab(entry, n, a, b));
            }
            if l < l_total {
                sigs.push(ws.sig_nab("fwd_relu", n, a, b));
            }
        }
        for l in 1..l_total {
            let c = ws.dims[l];
            for entry in ["hidden_residual", "hidden_phi", "z_combine", "z_prox_val"] {
                sigs.push(ws.sig_nc(entry, n, c));
            }
        }
        let classes = ws.dims[l_total];
        for entry in ["out_residual", "out_phi", "xent_loss"] {
            sigs.push(ws.sig_nc(entry, n, classes));
        }
        sigs.push(ws.sig_fista(n));
    }
    sigs.sort();
    sigs.dedup();
    sigs
}
