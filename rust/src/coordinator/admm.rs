//! The community-based layerwise ADMM trainer (paper Algorithm 1).
//!
//! One epoch = one ADMM iteration:
//!
//! ```text
//! 1. gather  Z^k, U^k  → W-agent                       (star comm)
//! 2. W-agent: update every W_l in parallel (§3.1, eq. 2 with τ
//!    backtracking)                                     (layer parallelism)
//! 3. broadcast W^{k+1}                                 (star comm)
//! 4. communities: exchange first-order p and second-order s messages
//!    (Appendix A eq. 4)                                (p2p comm)
//! 5. communities: update Z_{l,m} (eq. 5/6 via eq. 8/10 with θ
//!    backtracking) and Z_{L,m} (eq. 7 via FISTA), all in parallel
//! 6. communities: dual update U_m (eq. 3)
//! ```
//!
//! Serial mode (M = 1) runs the same code with an empty message graph; in
//! parallel mode, cross-community terms are strictly Jacobi (k-indexed) so
//! phases 4–6 run embarrassingly parallel across communities, while each
//! agent's *own-block* Z_L anchor uses its freshly updated Z_{L-1,m}
//! (`AdmmOptions::gauss_seidel`; the pure-Jacobi variant is an ablation).
//!
//! Deviation notes vs the paper's literal text (DESIGN.md §6):
//! - eq. 3 updates the dual with `p^k` messages; we use the residual
//!   against the exact `Q` the Z_L subproblem just solved
//!   (`U += ρ(Z_L^{k+1} − Q)`), the standard prox-linearised-ADMM ordering
//!   — it avoids an extra message round and is what dlADMM [7] implements.
//! - the W update defaults to a row-block-distributed reduction
//!   (`update_w_distributed`) rather than the centralised agent-(M+1)
//!   gather; `AdmmOptions::central_w` restores the paper-literal schedule.

use super::clock::{timed, EpochClock, LinkModel};
use super::workspace::Workspace;
use crate::metrics::{EpochRecord, RunReport};
use crate::runtime::{Engine, In};
use crate::tensor::{argmax_rows, Matrix};
use crate::util::rng::Rng;
use anyhow::Result;
use std::sync::Arc;
use std::time::Instant;

/// Backtracking safety margin and bounds.
const BT_EPS: f32 = 1e-6;
const BT_MAX_DOUBLINGS: usize = 40;
const STEP_MIN: f32 = 1e-8;

/// Mutable ADMM state.
pub struct AdmmState {
    /// Weights W_1..W_L (index l-1).
    pub w: Vec<Matrix>,
    /// z[l-1][m] = Z_{l,m} (n_pad × C_l), l = 1..=L.
    pub z: Vec<Vec<Matrix>>,
    /// Dual U_m (n_pad × C_L).
    pub u: Vec<Matrix>,
    /// τ_l per layer (quadratic-approximation steps, persisted).
    pub tau: Vec<f32>,
    /// θ_{l,m} per (hidden layer, community).
    pub theta: Vec<Vec<f32>>,
}

/// Trainer options beyond the workspace hyper-parameters.
#[derive(Clone, Debug)]
pub struct AdmmOptions {
    /// Account W updates at the per-layer critical path (Alg. 1 line 3).
    /// Only meaningful with `central_w` (the distributed W update is
    /// row-block-parallel instead).
    pub parallel_layers: bool,
    /// Gauss-Seidel within an epoch (serial mode): Z_L sees fresh Z_{L-1}.
    pub gauss_seidel: bool,
    /// Paper-literal centralised W update at agent M+1 (gather Z/U, update,
    /// broadcast). Default false: the W gradient reduces over community row
    /// blocks — same math, communication- and compute-parallel.
    pub central_w: bool,
    pub link: LinkModel,
}

impl AdmmOptions {
    /// Paper-faithful defaults for a given community count.
    ///
    /// `gauss_seidel` defaults on for every mode: within a community agent
    /// the Z_L solve anchors against a `Q` whose *own-block* part uses the
    /// freshly updated Z_{L-1,m} (cross-community terms stay at k — no
    /// extra messages, so community parallelism is untouched). Pure-Jacobi
    /// anchoring is kept as an ablation (`benches/ablation_sweep`); it
    /// oscillates once the dual warms up, which is the within-epoch
    /// dependency the paper's own serial-vs-parallel gap reflects.
    pub fn for_mode(m: usize) -> AdmmOptions {
        AdmmOptions {
            parallel_layers: m > 1,
            gauss_seidel: true,
            central_w: false,
            link: LinkModel::new(10_000.0, 100.0),
        }
    }
}

pub struct AdmmTrainer {
    pub ws: Arc<Workspace>,
    pub engine: Arc<Engine>,
    pub opts: AdmmOptions,
    pub state: AdmmState,
}

impl AdmmTrainer {
    /// Initialise: Glorot weights, Z by a forward pass (dlADMM-style warm
    /// start), U = 0.
    pub fn new(ws: Arc<Workspace>, engine: Arc<Engine>, opts: AdmmOptions) -> Result<AdmmTrainer> {
        // Compile every artifact this run will touch up front — XLA
        // compilation is a startup cost in any real deployment and must not
        // pollute the per-epoch timings.
        let sigs = training_sigs(&ws);
        engine.warmup(&sigs)?;

        let mut rng = Rng::new(ws.hp.seed);
        let l = ws.layers;
        let dims = ws.dims.clone();
        let mut w = Vec::with_capacity(l);
        for li in 1..=l {
            w.push(Matrix::glorot(dims[li - 1], dims[li], &mut rng));
        }

        // Forward warm start at the global view, then scatter.
        let mut z_glob: Vec<Matrix> = Vec::with_capacity(l);
        let mut h = ws.h0_glob.clone(); // Ã X
        for li in 1..=l {
            let (a, b) = (dims[li - 1], dims[li]);
            let n = ws.n_glob;
            let zl = if li < l {
                // f(H W) — H already aggregated.
                exec1(
                    &engine,
                    &ws.sig_nab("fwd_relu", n, a, b),
                    &[In::Mat(&h), In::Mat(&w[li - 1])],
                )?
            } else {
                // Output layer is linear: Ã Z W — V then SpMM.
                let v = exec1(
                    &engine,
                    &ws.sig_nab("mm_nn", n, a, b),
                    &[In::Mat(&z_glob[li - 2]), In::Mat(&w[li - 1])],
                )?;
                ws.a_glob.spmm(&v)
            };
            if li < l {
                h = ws.a_glob.spmm(&zl);
            }
            z_glob.push(zl);
        }
        let z: Vec<Vec<Matrix>> = z_glob.iter().map(|zg| ws.scatter(zg)).collect();
        let u = (0..ws.m)
            .map(|_| Matrix::zeros(ws.n_pad, dims[l]))
            .collect();

        // τ/θ start conservatively at 1.0 and adapt both ways: backtracking
        // doubles them when the quadratic majoriser is violated, and the
        // 0.5× post-acceptance decay lets them sink toward the subproblem's
        // true curvature scale (∝ ν, ρ) over the first ~15 epochs — the
        // ramp visible in the paper's own Figure-2 curves.
        Ok(AdmmTrainer {
            state: AdmmState {
                w,
                z,
                u,
                tau: vec![1.0; l],
                theta: vec![vec![1.0; ws.m]; l.saturating_sub(1)],
            },
            ws,
            engine,
            opts,
        })
    }

    // ---- artifact helpers -------------------------------------------------

    fn mm_nn(&self, n: usize, a: usize, b: usize, x: &Matrix, w: &Matrix) -> Result<Matrix> {
        exec1(
            &self.engine,
            &self.ws.sig_nab("mm_nn", n, a, b),
            &[In::Mat(x), In::Mat(w)],
        )
    }

    fn mm_tn(&self, n: usize, a: usize, b: usize, x: &Matrix, y: &Matrix) -> Result<Matrix> {
        exec1(
            &self.engine,
            &self.ws.sig_nab("mm_tn", n, a, b),
            &[In::Mat(x), In::Mat(y)],
        )
    }

    fn mm_bt(&self, n: usize, a: usize, b: usize, x: &Matrix, w: &Matrix) -> Result<Matrix> {
        exec1(
            &self.engine,
            &self.ws.sig_nab("mm_bt", n, a, b),
            &[In::Mat(x), In::Mat(w)],
        )
    }

    fn hidden_residual(&self, n: usize, c: usize, pre: &Matrix, zt: &Matrix) -> Result<(f32, Matrix)> {
        let outs = self.engine.exec(
            &self.ws.sig_nc("hidden_residual", n, c),
            &[In::Mat(pre), In::Mat(zt), In::Scalar(self.ws.hp.nu)],
        )?;
        let mut it = outs.into_iter();
        Ok((it.next().unwrap().scalar(), it.next().unwrap().into_mat()))
    }

    fn out_residual(
        &self,
        n: usize,
        c: usize,
        pre: &Matrix,
        zt: &Matrix,
        u: &Matrix,
    ) -> Result<(f32, Matrix)> {
        let outs = self.engine.exec(
            &self.ws.sig_nc("out_residual", n, c),
            &[
                In::Mat(pre),
                In::Mat(zt),
                In::Mat(u),
                In::Scalar(self.ws.hp.rho),
            ],
        )?;
        let mut it = outs.into_iter();
        Ok((it.next().unwrap().scalar(), it.next().unwrap().into_mat()))
    }

    fn hidden_phi(&self, n: usize, c: usize, pre: &Matrix, zt: &Matrix) -> Result<f32> {
        Ok(self
            .engine
            .exec(
                &self.ws.sig_nc("hidden_phi", n, c),
                &[In::Mat(pre), In::Mat(zt), In::Scalar(self.ws.hp.nu)],
            )?
            .remove(0)
            .scalar())
    }

    fn out_phi(&self, n: usize, c: usize, pre: &Matrix, zt: &Matrix, u: &Matrix) -> Result<f32> {
        Ok(self
            .engine
            .exec(
                &self.ws.sig_nc("out_phi", n, c),
                &[
                    In::Mat(pre),
                    In::Mat(zt),
                    In::Mat(u),
                    In::Scalar(self.ws.hp.rho),
                ],
            )?
            .remove(0)
            .scalar())
    }

    // ---- W subproblem (§3.1) ----------------------------------------------

    /// Update W_l (1-based l) given gathered global Z^k / U^k. Returns the
    /// subproblem value after the accepted step.
    fn update_w(&mut self, l: usize, z_glob: &[Matrix], u_glob: &Matrix) -> Result<f32> {
        let ws = &self.ws;
        let n = ws.n_glob;
        let (a, b) = (ws.dims[l - 1], ws.dims[l]);
        let last = l == ws.layers;
        let zprev = if l == 1 { &ws.x_glob } else { &z_glob[l - 2] };
        let zl = &z_glob[l - 1];

        let phi_at = |w: &Matrix| -> Result<(f32, Matrix)> {
            // pre = Ã (Z_{l-1} W) — SpMM over the projected width.
            let v = self.mm_nn(n, a, b, zprev, w)?;
            let pre = ws.a_glob.spmm(&v);
            Ok((
                if last {
                    self.out_phi(n, b, &pre, zl, u_glob)?
                } else {
                    self.hidden_phi(n, b, &pre, zl)?
                },
                pre,
            ))
        };

        // Value + residual + gradient at W^k.
        let v = self.mm_nn(n, a, b, zprev, &self.state.w[l - 1])?;
        let pre = ws.a_glob.spmm(&v);
        let (phi0, r) = if last {
            self.out_residual(n, b, &pre, zl, u_glob)?
        } else {
            self.hidden_residual(n, b, &pre, zl)?
        };
        let ar = ws.a_glob.spmm(&r);
        let gw = self.mm_tn(n, a, b, zprev, &ar)?;
        let gsq = gw.frob_norm_sq() as f32;

        // Backtracking on τ: accept W⁺ = W − g/τ once
        // φ(W⁺) ≤ φ(W) − ‖g‖²/(2τ)  (⇔ P_l(W⁺; τ) ≥ φ(W⁺), eq. 2).
        let mut tau = self.state.tau[l - 1].max(STEP_MIN);
        let mut accepted = None;
        for _ in 0..BT_MAX_DOUBLINGS {
            let mut cand = self.state.w[l - 1].clone();
            cand.axpy(-1.0 / tau, &gw);
            let (phi_c, _) = phi_at(&cand)?;
            if phi_c <= phi0 - gsq / (2.0 * tau) + BT_EPS * phi0.abs().max(1.0) {
                accepted = Some((cand, phi_c));
                break;
            }
            tau *= 2.0;
        }
        let (cand, phi_c) =
            accepted.unwrap_or((self.state.w[l - 1].clone(), phi0)); // give up: keep W
        self.state.w[l - 1] = cand;
        // Gentle decay so τ can shrink again when the landscape flattens.
        self.state.tau[l - 1] = (tau * 0.5).max(STEP_MIN);
        Ok(phi_c)
    }

    /// Distributed W_l update: the gradient and objective decompose exactly
    /// over community row blocks,
    ///
    /// ```text
    /// φ_l(W)  = Σ_m φ_{l,m}(W)       with pre_m = S_m W,
    /// ∇φ_l(W) = Σ_m S_mᵀ R_m         where S_m = Σ_r Ã_{m,r} Z_{l-1,r},
    /// ```
    ///
    /// so each community computes its partial from local + boundary rows,
    /// the leader reduces, and τ backtracking only re-evaluates the cheap
    /// `pre_m = S_m W_c` products (S_m is fixed across trials). This is the
    /// "update W_l for different l in parallel" of Algorithm 1 with the
    /// row-block reduction any multi-machine deployment would use; the
    /// paper-literal centralised variant (gather Z at agent M+1) is kept
    /// behind `AdmmOptions::central_w` as an ablation.
    ///
    /// Returns per-community compute seconds and the number of trials
    /// (for broadcast byte accounting).
    fn update_w_distributed(&mut self, l: usize, per_comm_secs: &mut [f64]) -> Result<usize> {
        let ws = self.ws.clone();
        let n = ws.n_pad;
        let (a, b) = (ws.dims[l - 1], ws.dims[l]);
        let last = l == ws.layers;

        // S_m = Σ_r Ã_{m,r} Z_{l-1,r} — one sparse aggregate per community,
        // reused by every backtracking trial. For l = 1 it equals the
        // *static* per-community H0 rows (X never changes), so no SpMM at
        // all. Marshalled once into a Prepared literal — the trial loop
        // re-sends only the small W candidate.
        let mut s_per: Vec<crate::runtime::Prepared> = Vec::with_capacity(ws.m);
        for (mi, comm) in ws.communities.iter().enumerate() {
            let t0 = Instant::now();
            let s = if l == 1 {
                self.engine.prepare(&ws.h0_comm[mi])?
            } else {
                let mut s = Matrix::zeros(n, a);
                for r in comm.neighbors.iter().copied().chain([mi]) {
                    if let Some(blk) = comm.blocks.get(&r) {
                        s.add_assign(&blk.spmm(&self.state.z[l - 2][r]));
                    }
                }
                self.engine.prepare(&s)?
            };
            per_comm_secs[mi] += t0.elapsed().as_secs_f64();
            s_per.push(s);
        }
        let mm_nn_sig = ws.sig_nab("mm_nn", n, a, b);
        let mm_tn_sig = ws.sig_nab("mm_tn", n, a, b);

        // Partial values/gradients at W^k; leader reduces.
        let mut phi0 = 0.0f32;
        let mut gw = Matrix::zeros(a, b);
        for mi in 0..ws.m {
            let t0 = Instant::now();
            let pre = exec1(
                &self.engine,
                &mm_nn_sig,
                &[In::Prep(&s_per[mi]), In::Mat(&self.state.w[l - 1])],
            )?;
            let (phi_m, r_m) = if last {
                self.out_residual(n, b, &pre, &self.state.z[l - 1][mi], &self.state.u[mi])?
            } else {
                self.hidden_residual(n, b, &pre, &self.state.z[l - 1][mi])?
            };
            let g_m = exec1(
                &self.engine,
                &mm_tn_sig,
                &[In::Prep(&s_per[mi]), In::Mat(&r_m)],
            )?;
            phi0 += phi_m;
            gw.add_assign(&g_m);
            per_comm_secs[mi] += t0.elapsed().as_secs_f64();
        }
        let gsq = gw.frob_norm_sq() as f32;

        // Backtracking on τ: accept W⁺ = W − g/τ once
        // φ(W⁺) ≤ φ(W) − ‖g‖²/(2τ)  (⇔ P_l(W⁺; τ) ≥ φ(W⁺), eq. 2).
        let mut tau = self.state.tau[l - 1].max(STEP_MIN);
        let mut trials = 0usize;
        let mut accepted = None;
        for _ in 0..BT_MAX_DOUBLINGS {
            trials += 1;
            let mut cand = self.state.w[l - 1].clone();
            cand.axpy(-1.0 / tau, &gw);
            let mut phi_c = 0.0f32;
            for mi in 0..ws.m {
                let t0 = Instant::now();
                let pre = exec1(
                    &self.engine,
                    &mm_nn_sig,
                    &[In::Prep(&s_per[mi]), In::Mat(&cand)],
                )?;
                phi_c += if last {
                    self.out_phi(n, b, &pre, &self.state.z[l - 1][mi], &self.state.u[mi])?
                } else {
                    self.hidden_phi(n, b, &pre, &self.state.z[l - 1][mi])?
                };
                per_comm_secs[mi] += t0.elapsed().as_secs_f64();
            }
            if phi_c <= phi0 - gsq / (2.0 * tau) + BT_EPS * phi0.abs().max(1.0) {
                accepted = Some(cand);
                break;
            }
            tau *= 2.0;
        }
        if let Some(cand) = accepted {
            self.state.w[l - 1] = cand;
        }
        if trials > 4 {
            log::trace!("w backtracking: layer {l} took {trials} trials (tau={tau:.3e})");
        }
        // Adaptive step persistence: only probe a smaller τ after an epoch
        // that accepted on the first trial — keeps the steady-state trial
        // count near 1.5 instead of paying a guaranteed violation per epoch.
        self.state.tau[l - 1] = if trials == 1 {
            (tau * 0.5).max(STEP_MIN)
        } else {
            tau
        };
        Ok(trials)
    }

    // ---- message phase (Appendix A eq. 4) -----------------------------------

    /// Per-community first/second-order message computation for epoch k.
    ///
    /// First order (eq. 4 top): `v = Z_{l,m} W_{l+1}`, diag `Ã_mm v`, and
    /// outgoing `p_{l,m→r} = Ã_{r,m} v`. Second order (eq. 4 bottom),
    /// computed at the *sender* r from its received-p sums — exactly how a
    /// distributed deployment forwards two-hop information through one-hop
    /// links. Returns `MessagePhase` plus per-community compute seconds.
    fn message_phase(&self) -> Result<(MessagePhase, Vec<f64>)> {
        let ws = &self.ws;
        let l_total = ws.layers;
        let n = ws.n_pad;
        let mut ph = MessagePhase {
            p_full: vec![Vec::new(); l_total],
            p_cross: vec![Vec::new(); l_total],
            p_out: vec![vec![Vec::new(); ws.m]; l_total],
            s_in: vec![vec![Vec::new(); ws.m]; l_total],
        };
        let mut secs = vec![0.0f64; ws.m];

        // Stage 1: every community computes its projections and products.
        let mut p_own: Vec<Vec<Matrix>> = vec![Vec::new(); l_total];
        for mi in 0..ws.m {
            let t0 = Instant::now();
            let comm = &ws.communities[mi];
            for l in 0..l_total {
                let (a, b) = (ws.dims[l], ws.dims[l + 1]);
                let zsrc = if l == 0 {
                    &comm.x
                } else {
                    &self.state.z[l - 1][mi]
                };
                let v = self.mm_nn(n, a, b, zsrc, &self.state.w[l])?;
                p_own[l].push(comm.blocks[&mi].spmm(&v));
                for &r in &comm.neighbors {
                    // Ã_{r,m} v — the rows live on r; this is message m→r.
                    ph.p_out[l][mi].push((r, comm.blocks_t[&r].spmm(&v)));
                }
            }
            secs[mi] += t0.elapsed().as_secs_f64();
        }

        // Stage 2: receivers fold incoming p messages (attributed to the
        // receiver's clock).
        for mi in 0..ws.m {
            let t0 = Instant::now();
            for l in 0..l_total {
                let mut cross = Matrix::zeros(n, ws.dims[l + 1]);
                for (src, msgs) in ph.p_out[l].iter().enumerate() {
                    if src == mi {
                        continue;
                    }
                    for (dst, mat) in msgs {
                        if *dst == mi {
                            cross.add_assign(mat);
                        }
                    }
                }
                let mut full = p_own[l][mi].clone();
                full.add_assign(&cross);
                ph.p_cross[l].push(cross);
                ph.p_full[l].push(full);
            }
            secs[mi] += t0.elapsed().as_secs_f64();
        }

        // Stage 3: senders assemble second-order messages s_{l,r→m} from
        // their p sums (eq. 4) — local to r, then shipped to m. Only layers
        // whose Z is a variable need them (l ≥ 1: Z_0 = X is fixed, so no
        // eq.-5/6 subproblem consumes s at l = 0).
        for r in 0..ws.m {
            let t0 = Instant::now();
            for &m in &ws.communities[r].neighbors {
                for l in 1..l_total {
                    // Σ_{r'∈N_r∪{r}\{m}} p_{l,r'→r} = P_full − p_{l,m→r}.
                    let p_m_to_r = ph.p_out[l][m]
                        .iter()
                        .find(|(dst, _)| *dst == r)
                        .map(|(_, mat)| mat)
                        .expect("neighbor without p message");
                    let mut sum = ph.p_full[l][r].clone();
                    sum.axpy(-1.0, p_m_to_r);
                    let (s1, s2) = if l + 1 < l_total {
                        (self.state.z[l][r].clone(), sum)
                    } else {
                        let mut s1 = self.state.z[l_total - 1][r].clone();
                        s1.axpy(-1.0, &sum);
                        (s1, self.state.u[r].clone())
                    };
                    ph.s_in[l][m].push((r, s1, s2));
                }
            }
            secs[r] += t0.elapsed().as_secs_f64();
        }
        Ok((ph, secs))
    }

    // ---- one ADMM epoch ------------------------------------------------------

    pub fn epoch(&mut self) -> Result<EpochClock> {
        let ws = self.ws.clone();
        let mut clock = EpochClock::default();
        let l_total = ws.layers;
        let n_pad = ws.n_pad;

        // ---- 1. gather Z^k, U^k (star) -----------------------------------
        if self.opts.central_w {
            // Paper-literal agent-(M+1) W update: gather Z^k/U^k, update
            // centrally (layer-parallel), broadcast W^{k+1}.
            if ws.m > 1 {
                let mut msgs = Vec::new();
                for c in ws.communities.iter() {
                    let mut bytes = 0u64;
                    for l in 1..=l_total {
                        bytes += ws.msg_bytes(c.size, ws.dims[l]);
                    }
                    bytes += ws.msg_bytes(c.size, ws.dims[l_total]); // U
                    msgs.push(bytes);
                }
                clock.star(&self.opts.link, &msgs);
            }
            let z_glob: Vec<Matrix> = (0..l_total)
                .map(|li| ws.gather(&self.state.z[li]))
                .collect();
            let u_glob = ws.gather(&self.state.u);
            let mut layer_secs = Vec::with_capacity(l_total);
            for l in 1..=l_total {
                let (res, secs) = timed(|| self.update_w(l, &z_glob, &u_glob));
                res?;
                layer_secs.push(secs);
            }
            if self.opts.parallel_layers {
                clock.parallel_phase(&layer_secs);
            } else {
                clock.serial_phase(layer_secs.iter().sum());
            }
            if ws.m > 1 {
                let w_bytes: u64 = (1..=l_total)
                    .map(|l| ws.msg_bytes(ws.dims[l - 1], ws.dims[l]))
                    .sum();
                clock.star(&self.opts.link, &vec![w_bytes; ws.m]);
            }
        } else {
            // Distributed W update (default — see update_w_distributed).
            // Comm: boundary Z-block exchange (l ≥ 2; X is static),
            // gradient-partial reduce up, W/trial broadcasts down.
            let mut w_secs = vec![0.0f64; ws.m];
            let mut total_trials = 0usize;
            for l in 1..=l_total {
                if ws.m > 1 && l >= 2 {
                    let per_sender: Vec<Vec<u64>> = ws
                        .communities
                        .iter()
                        .map(|c| {
                            c.boundary_to
                                .values()
                                .map(|&rows| ws.msg_bytes(rows, ws.dims[l - 1]))
                                .collect()
                        })
                        .collect();
                    clock.exchange(&self.opts.link, &per_sender);
                }
                total_trials += self.update_w_distributed(l, &mut w_secs)?;
            }
            clock.parallel_phase(&w_secs);
            let _ = total_trials; // trial count only moves 8-byte scalars
            if ws.m > 1 {
                // Per layer: M gradient partials up, one aggregated gradient
                // down per community (workers form W − g/τ locally; the τ
                // backtracking exchanges scalars, which round to nothing).
                let per_w: u64 = (1..=l_total)
                    .map(|l| ws.msg_bytes(ws.dims[l - 1], ws.dims[l]))
                    .sum();
                clock.star(&self.opts.link, &vec![per_w; ws.m]); // reduce up
                clock.star(&self.opts.link, &vec![per_w; ws.m]); // g down
            }
        }

        // ---- 4. p/s message phase ------------------------------------------
        let (ph, msg_secs) = self.message_phase()?;
        clock.parallel_phase(&msg_secs);
        if ws.m > 1 {
            // p messages m→r: nonzero only at r's boundary rows toward m
            // (the nonzero rows of Ã_{r,m}), so only those ship.
            let mut per_sender: Vec<Vec<u64>> = Vec::with_capacity(ws.m);
            for mi in 0..ws.m {
                let mut msgs = Vec::new();
                for l in 0..l_total {
                    for (r, _) in &ph.p_out[l][mi] {
                        let rows = ws.communities[mi].boundary_from[r];
                        msgs.push(ws.msg_bytes(rows, ws.dims[l + 1]));
                    }
                }
                per_sender.push(msgs);
            }
            clock.exchange(&self.opts.link, &per_sender);
            // s messages r→m: two dense (n_r × C_{l+1}) halves per edge,
            // layers l ≥ 1 only.
            let mut per_sender_s: Vec<Vec<u64>> = Vec::with_capacity(ws.m);
            for r in 0..ws.m {
                let mut msgs = Vec::new();
                for l in 1..l_total {
                    for _m in &ws.communities[r].neighbors {
                        msgs.push(2 * ws.msg_bytes(ws.communities[r].size, ws.dims[l + 1]));
                    }
                }
                per_sender_s.push(msgs);
            }
            clock.exchange(&self.opts.link, &per_sender_s);
        }

        // ---- 5+6. Z updates + dual, per community ---------------------------
        let t_before_z = clock.train;
        let mut comm_secs = vec![0.0f64; ws.m];
        // Snapshot Z^k for Jacobi targets.
        let z_prev: Vec<Vec<Matrix>> = self.state.z.clone();
        for mi in 0..ws.m {
            let t0 = Instant::now();
            self.update_community(mi, &z_prev, &ph)?;
            comm_secs[mi] = t0.elapsed().as_secs_f64();
        }
        clock.parallel_phase(&comm_secs);
        log::trace!(
            "epoch phases: W+msg {:.1}ms, Z {:.1}ms, comm {:.1}ms",
            t_before_z * 1e3,
            (clock.train - t_before_z) * 1e3,
            clock.comm * 1e3
        );
        let _ = n_pad;
        Ok(clock)
    }

    /// Z_{l,m} for l = 1..L−1, then Z_{L,m} (FISTA), then U_m. Consumes only
    /// community-local state plus *received* messages — the same inputs a
    /// remote worker gets over the wire.
    fn update_community(&mut self, mi: usize, z_prev: &[Vec<Matrix>], ph: &MessagePhase) -> Result<()> {
        let ws = self.ws.clone();
        let n = ws.n_pad;
        let l_total = ws.layers;
        let comm = &ws.communities[mi];
        let nu = ws.hp.nu;
        let rho = ws.hp.rho;

        // ---- hidden Z updates (eq. 5/6 via eq. 8/10) ------------------------
        for l in 1..l_total {
            let c_l = ws.dims[l];
            let c_next = ws.dims[l + 1];
            let out_layer = l + 1 == l_total; // coupling into the linear head?
            let pin = &ph.p_full[l - 1][mi];
            let zk = &z_prev[l - 1][mi];

            // Own coupling: pre = Ã_mm Z_l W_{l+1} + Σ_cross p = P_full[l][m].
            let pre_own = &ph.p_full[l][mi];
            let (mut psi0, r_own) = if out_layer {
                self.out_residual(n, c_next, pre_own, &z_prev[l][mi], &self.state.u[mi])?
            } else {
                self.hidden_residual(n, c_next, pre_own, &z_prev[l][mi])?
            };
            let mut g_acc = comm.blocks[&mi].spmm(&r_own);

            // Neighbor couplings (the second-order terms, from received s).
            let mut s_cache: Vec<(usize, &Matrix, &Matrix)> = Vec::new();
            for (r, s1, s2) in &ph.s_in[l][mi] {
                let p_sent = ph.p_out[l][mi]
                    .iter()
                    .find(|(dst, _)| dst == r)
                    .map(|(_, mat)| mat)
                    .unwrap();
                let (val, rr) = if out_layer {
                    // pre = Ã_rm Z W_L (no bias), dual s2 = U_r.
                    self.out_residual(n, c_next, p_sent, s1, s2)?
                } else {
                    let mut pre = p_sent.clone();
                    pre.add_assign(s2);
                    self.hidden_residual(n, c_next, &pre, s1)?
                };
                psi0 += val;
                // Ã_{r,m}ᵀ R = Ã_{m,r} R — the block m already holds.
                g_acc.add_assign(&comm.blocks[r].spmm(&rr));
                s_cache.push((*r, s1, s2));
            }
            let gsum = self.mm_bt(n, c_l, c_next, &g_acc, &self.state.w[l])?;

            // ψ at a candidate Z (for θ backtracking).
            let psi_at = |z: &Matrix| -> Result<f32> {
                let mut val = self
                    .engine
                    .exec(
                        &ws.sig_nc("z_prox_val", n, c_l),
                        &[In::Mat(z), In::Mat(pin), In::Scalar(nu)],
                    )?
                    .remove(0)
                    .scalar();
                let v = self.mm_nn(n, c_l, c_next, z, &self.state.w[l])?;
                let mut pre = comm.blocks[&mi].spmm(&v);
                pre.add_assign(&ph.p_cross[l][mi]);
                val += if out_layer {
                    self.out_phi(n, c_next, &pre, &z_prev[l][mi], &self.state.u[mi])?
                } else {
                    self.hidden_phi(n, c_next, &pre, &z_prev[l][mi])?
                };
                for (r, s1, s2) in &s_cache {
                    let mut pre_r = comm.blocks_t[r].spmm(&v);
                    val += if out_layer {
                        self.out_phi(n, c_next, &pre_r, s1, s2)?
                    } else {
                        pre_r.add_assign(s2);
                        self.hidden_phi(n, c_next, &pre_r, s1)?
                    };
                }
                Ok(val)
            };

            // θ backtracking on the combined step.
            let mut theta = self.state.theta[l - 1][mi].max(STEP_MIN);
            let mut accepted: Option<Matrix> = None;
            let mut trials = 0usize;
            for _ in 0..BT_MAX_DOUBLINGS {
                trials += 1;
                let outs = self.engine.exec(
                    &ws.sig_nc("z_combine", n, c_l),
                    &[
                        In::Mat(zk),
                        In::Mat(pin),
                        In::Mat(&gsum),
                        In::Scalar(nu),
                        In::Scalar(theta),
                    ],
                )?;
                let mut it = outs.into_iter();
                let znew = it.next().unwrap().into_mat();
                let prox0 = it.next().unwrap().scalar();
                let gsq = it.next().unwrap().scalar();
                let bound = psi0 + prox0 - gsq / (2.0 * theta)
                    + BT_EPS * (psi0 + prox0).abs().max(1.0);
                if psi_at(&znew)? <= bound {
                    accepted = Some(znew);
                    break;
                }
                theta *= 2.0;
            }
            if let Some(znew) = accepted {
                self.state.z[l - 1][mi] = znew;
            }
            if trials > 4 {
                log::trace!(
                    "z backtracking: comm {mi} layer {l} took {trials} trials (theta={theta:.3e})"
                );
            }
            // Same adaptive persistence as τ (see update_w_distributed).
            self.state.theta[l - 1][mi] = if trials == 1 {
                (theta * 0.5).max(STEP_MIN)
            } else {
                theta
            };
        }

        // ---- Z_L via FISTA (eq. 7) ------------------------------------------
        let classes = ws.dims[l_total];
        let q = if self.opts.gauss_seidel {
            // Serial mode: Q from the freshly updated Z_{L-1,m}.
            let v = self.mm_nn(
                n,
                ws.dims[l_total - 1],
                classes,
                &self.state.z[l_total - 2][mi],
                &self.state.w[l_total - 1],
            )?;
            let mut q = comm.blocks[&mi].spmm(&v);
            q.add_assign(&ph.p_cross[l_total - 1][mi]);
            q
        } else {
            ph.p_full[l_total - 1][mi].clone()
        };
        let outs = self.engine.exec(
            &ws.sig_fista(n),
            &[
                In::Mat(&q),
                In::Mat(&self.state.u[mi]),
                In::Mat(&comm.y),
                In::Vec(&comm.train_mask),
                In::Mat(&z_prev[l_total - 1][mi]),
                In::Scalar(rho),
                In::Scalar(ws.denom),
            ],
        )?;
        let mut it = outs.into_iter();
        let z_l_new = it.next().unwrap().into_mat();
        let _risk = it.next().unwrap().scalar();

        // ---- dual update (eq. 3, residual against the solved Q) -------------
        let mut resid = z_l_new.clone();
        resid.axpy(-1.0, &q);
        self.state.u[mi].axpy(rho, &resid);
        self.state.z[l_total - 1][mi] = z_l_new;
        Ok(())
    }

    // ---- transport hooks (the TCP worker/leader drive phases directly) ------

    /// W update for one layer — leader side of the TCP runtime.
    pub fn update_w_public(&mut self, l: usize, z_glob: &[Matrix], u_glob: &Matrix) -> Result<f32> {
        self.update_w(l, z_glob, u_glob)
    }

    /// Community Z/U update from received messages — worker side.
    pub fn update_community_public(
        &mut self,
        mi: usize,
        z_prev: &[Vec<Matrix>],
        ph: &MessagePhase,
    ) -> Result<()> {
        self.update_community(mi, z_prev, ph)
    }

    /// First-order products for one community only (worker side):
    /// returns (p_own[l], p_out[l] = (dst, matrix)).
    #[allow(clippy::type_complexity)]
    pub fn local_p_products(
        &self,
        mi: usize,
    ) -> Result<(Vec<Matrix>, Vec<Vec<(usize, Matrix)>>)> {
        let ws = &self.ws;
        let n = ws.n_pad;
        let comm = &ws.communities[mi];
        let mut p_own = Vec::with_capacity(ws.layers);
        let mut p_out = vec![Vec::new(); ws.layers];
        for l in 0..ws.layers {
            let (a, b) = (ws.dims[l], ws.dims[l + 1]);
            let zsrc = if l == 0 {
                &comm.x
            } else {
                &self.state.z[l - 1][mi]
            };
            let v = self.mm_nn(n, a, b, zsrc, &self.state.w[l])?;
            p_own.push(comm.blocks[&mi].spmm(&v));
            for &r in &comm.neighbors {
                p_out[l].push((r, comm.blocks_t[&r].spmm(&v)));
            }
        }
        Ok((p_own, p_out))
    }

    // ---- evaluation (untimed, leader-side forward pass) ---------------------

    /// Forward pass with current weights; returns (train_acc, test_acc,
    /// train loss).
    pub fn evaluate(&self) -> Result<(f64, f64, f64)> {
        evaluate_forward(&self.ws, &self.engine, &self.state.w)
    }

    /// Run a full training: `epochs` ADMM iterations with per-epoch eval.
    pub fn train(&mut self, epochs: usize, label: &str) -> Result<RunReport> {
        let mut report = RunReport::new(label, &dataset_label(&self.ws), self.ws.m);
        for e in 0..epochs {
            let wall0 = Instant::now();
            let clock = self.epoch()?;
            let wall = wall0.elapsed().as_secs_f64();
            let (train_acc, test_acc, loss) = self.evaluate()?;
            log::debug!(
                "[{label}] epoch {e}: loss={loss:.4} train={train_acc:.3} test={test_acc:.3} \
                 vt={:.3}s vc={:.3}s wall={wall:.3}s",
                clock.train,
                clock.comm
            );
            report.push(EpochRecord {
                epoch: e,
                train_acc,
                test_acc,
                loss,
                t_train: clock.train,
                t_comm: clock.comm,
                t_wall: wall,
                bytes: clock.bytes,
            });
        }
        Ok(report)
    }
}

/// Forward-pass evaluation shared with the baselines: accuracy on train and
/// test masks plus the training loss, computed at the (padded) global view.
pub fn evaluate_forward(
    ws: &Workspace,
    engine: &Engine,
    w: &[Matrix],
) -> Result<(f64, f64, f64)> {
    let n = ws.n_glob;
    let l_total = ws.layers;
    let mut h = ws.h0_glob.clone();
    let mut z = None;
    for l in 1..=l_total {
        let (a, b) = (ws.dims[l - 1], ws.dims[l]);
        if l < l_total {
            let zl = exec1(
                engine,
                &ws.sig_nab("fwd_relu", n, a, b),
                &[In::Mat(&h), In::Mat(&w[l - 1])],
            )?;
            h = ws.a_glob.spmm(&zl);
            z = Some(zl);
        } else {
            let src = z.as_ref().map(|_| &h).unwrap_or(&ws.h0_glob);
            let logits_pre = exec1(
                engine,
                &ws.sig_nab("mm_nn", n, a, b),
                &[In::Mat(src), In::Mat(&w[l - 1])],
            )?;
            // logits = Ã Z_{L-1} W_L — but h is already Ã Z_{L-1}, so the
            // product IS the logits; no extra SpMM.
            let logits = logits_pre;
            let loss = engine
                .exec(
                    &ws.sig_nc("xent_loss", n, ws.dims[l_total]),
                    &[
                        In::Mat(&logits),
                        In::Mat(&ws.y_glob),
                        In::Vec(&ws.train_mask_glob),
                        In::Scalar(ws.denom),
                    ],
                )?
                .remove(0)
                .scalar() as f64;
            let preds = argmax_rows(&logits);
            let (mut tr_c, mut tr_t, mut te_c, mut te_t) = (0usize, 0usize, 0usize, 0usize);
            for i in 0..ws.n {
                if ws.train_mask_glob[i] > 0.0 {
                    tr_t += 1;
                    if preds[i] == ws.labels[i] {
                        tr_c += 1;
                    }
                }
                if ws.test_mask_glob[i] > 0.0 {
                    te_t += 1;
                    if preds[i] == ws.labels[i] {
                        te_c += 1;
                    }
                }
            }
            return Ok((
                tr_c as f64 / tr_t.max(1) as f64,
                te_c as f64 / te_t.max(1) as f64,
                loss,
            ));
        }
    }
    unreachable!("layers >= 1")
}

pub(super) fn dataset_label(ws: &Workspace) -> String {
    format!("n{}", ws.n)
}

/// Every artifact signature an ADMM run touches (warmup list).
pub fn training_sigs(ws: &Workspace) -> Vec<String> {
    let l_total = ws.layers;
    let mut sigs = Vec::new();
    for &n in &[ws.n_pad, ws.n_glob] {
        for l in 1..=l_total {
            let (a, b) = (ws.dims[l - 1], ws.dims[l]);
            for entry in ["mm_nn", "mm_tn", "mm_bt"] {
                sigs.push(ws.sig_nab(entry, n, a, b));
            }
            if l < l_total {
                sigs.push(ws.sig_nab("fwd_relu", n, a, b));
            }
        }
        for l in 1..l_total {
            let c = ws.dims[l];
            for entry in ["hidden_residual", "hidden_phi", "z_combine", "z_prox_val"] {
                sigs.push(ws.sig_nc(entry, n, c));
            }
        }
        let classes = ws.dims[l_total];
        for entry in ["out_residual", "out_phi", "xent_loss"] {
            sigs.push(ws.sig_nc(entry, n, classes));
        }
        sigs.push(ws.sig_fista(n));
    }
    sigs.sort();
    sigs.dedup();
    sigs
}

fn exec1(engine: &Engine, sig: &str, inputs: &[In]) -> Result<Matrix> {
    Ok(engine.exec(sig, inputs)?.remove(0).into_mat())
}

/// The per-epoch message-phase outputs (what actually crosses agent
/// boundaries, plus receiver-side aggregates).
pub struct MessagePhase {
    /// [l][m] = Σ_{r∈N_m∪{m}} p_{l,r→m} (diag + received).
    pub p_full: Vec<Vec<Matrix>>,
    /// [l][m] = Σ_{r∈N_m} p_{l,r→m} (received only).
    pub p_cross: Vec<Vec<Matrix>>,
    /// [l][m] = outgoing (dst, p_{l,m→dst}).
    pub p_out: Vec<Vec<Vec<(usize, Matrix)>>>,
    /// [l][m] = incoming (src, s1, s2) second-order messages.
    pub s_in: Vec<Vec<Vec<(usize, Matrix, Matrix)>>>,
}
