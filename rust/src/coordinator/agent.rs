//! The community agent: one community's Z/U state plus the per-epoch
//! subproblems it runs against *received messages only*.
//!
//! This is the unit the parallel runtime schedules. Every function here
//! consumes community-local state (`z`, `u`, `θ`), static workspace blocks
//! and the messages that crossed the agent boundary — exactly the inputs a
//! remote worker gets over the wire, which is why the TCP transport and
//! the in-process serial/threaded executors all drive the same code. The
//! agent is scheduler-agnostic: its kernels go through [`ComputeBackend`],
//! so when a phase task runs on the shared work-stealing runtime
//! (`--runtime shared`) the kernels fork on the *same* workers the agent
//! task occupies — no second pool, no oversubscription (DESIGN.md §11):
//!
//! ```text
//! phase A  p_products   →  outgoing p_{l,m→r}            (eq. 4 top)
//! phase B  fold_p + s_messages → p_full/p_cross, s_{l,r→m} (eq. 4 bottom)
//! phase C  update_z_u   →  Z_{l,m} (eq. 5/6), Z_{L,m} (eq. 7), U_m (eq. 3)
//! ```
//!
//! Determinism: incoming message vectors are sorted by `(layer, src)`
//! before folding, so sums are accumulated in the same order regardless of
//! arrival order — threaded runs are bitwise identical to serial ones.

use super::workspace::Workspace;
use crate::runtime::ComputeBackend;
use crate::tensor::Matrix;
use anyhow::Result;

/// Backtracking safety margin and bounds (shared with the W subproblem).
pub(crate) const BT_EPS: f32 = 1e-6;
pub(crate) const BT_MAX_DOUBLINGS: usize = 40;
pub(crate) const STEP_MIN: f32 = 1e-8;

/// First-order message `p_{layer, src→dst}` (eq. 4 top).
#[derive(Clone)]
pub struct PMsg {
    /// 0-based layer index l (projection through W_{l+1}).
    pub layer: usize,
    pub src: usize,
    pub dst: usize,
    pub mat: Matrix,
}

/// Second-order message `s_{layer, src→dst}` (eq. 4 bottom): two dense
/// halves, (coupling target, pre-activation complement) at hidden layers
/// or (anchor, dual) at the output layer.
#[derive(Clone)]
pub struct SMsg {
    pub layer: usize,
    pub src: usize,
    pub dst: usize,
    pub s1: Matrix,
    pub s2: Matrix,
}

/// Read-only context shared by every agent in one epoch.
pub struct AgentCtx<'a> {
    pub ws: &'a Workspace,
    pub backend: &'a dyn ComputeBackend,
    /// Weights W_1..W_L for this epoch (already updated by the W phase).
    pub w: &'a [Matrix],
    /// Own-block Gauss-Seidel anchoring for the Z_L solve.
    pub gauss_seidel: bool,
}

/// One community's mutable ADMM state.
pub struct CommunityAgent {
    pub mi: usize,
    /// z[l-1] = Z_{l,mi} (n_pad × C_l), l = 1..=L.
    pub z: Vec<Matrix>,
    /// Dual U_mi (n_pad × C_L).
    pub u: Matrix,
    /// θ step per hidden layer (persisted across epochs).
    pub theta: Vec<f32>,
}

impl CommunityAgent {
    /// Rebuild an agent from shipped state — exactly the fields the
    /// elastic coordinator transfers when a community is adopted by a new
    /// host after its previous host crashed (and what the `.cgck`
    /// checkpoint persists per community).
    pub fn from_state(mi: usize, z: Vec<Matrix>, u: Matrix, theta: Vec<f32>) -> CommunityAgent {
        CommunityAgent { mi, z, u, theta }
    }

    /// Phase A — first-order products: for every layer l, project the own
    /// Z through W_{l+1} and split through the adjacency blocks into the
    /// diagonal part `p_own[l] = Ã_mm v` and one outgoing message
    /// `p_{l,m→r} = Ã_{r,m} v` per neighbor r.
    pub fn p_products(&self, ctx: &AgentCtx) -> Result<(Vec<Matrix>, Vec<PMsg>)> {
        let ws = ctx.ws;
        let comm = &ws.communities[self.mi];
        let l_total = ws.layers;
        let mut p_own = Vec::with_capacity(l_total);
        let mut out = Vec::new();
        for l in 0..l_total {
            let zsrc = if l == 0 { &comm.x } else { &self.z[l - 1] };
            let v = ctx.backend.mm_nn(zsrc, &ctx.w[l])?;
            p_own.push(ctx.backend.spmm(&comm.blocks[&self.mi], &v));
            for &r in &comm.neighbors {
                // Ã_{r,m} v — the rows live on r; this is message m→r.
                out.push(PMsg {
                    layer: l,
                    src: self.mi,
                    dst: r,
                    mat: ctx.backend.spmm(&comm.blocks_t[&r], &v),
                });
            }
        }
        Ok((p_own, out))
    }

    /// Phase B (fold) — sort incoming p by `(layer, src)` and fold into
    /// per-layer sums: `p_cross[l] = Σ_received`, `p_full[l] = p_own[l] +
    /// p_cross[l]`. Takes message *references* so the serial executor can
    /// route without copying dense matrices.
    pub fn fold_p(
        &self,
        ctx: &AgentCtx,
        p_own: &[Matrix],
        p_in: &mut Vec<&PMsg>,
    ) -> (Vec<Matrix>, Vec<Matrix>) {
        let ws = ctx.ws;
        p_in.sort_by_key(|m| (m.layer, m.src));
        let mut p_cross: Vec<Matrix> = (0..ws.layers)
            .map(|l| Matrix::zeros(ws.n_pad, ws.dims[l + 1]))
            .collect();
        for m in p_in.iter() {
            debug_assert_eq!(m.dst, self.mi);
            p_cross[m.layer].add_assign(&m.mat);
        }
        let p_full: Vec<Matrix> = p_own
            .iter()
            .zip(&p_cross)
            .map(|(own, cross)| {
                let mut f = own.clone();
                f.add_assign(cross);
                f
            })
            .collect();
        (p_full, p_cross)
    }

    /// Phase B (send) — assemble second-order messages `s_{l,m→dst}` from
    /// the folded p sums (eq. 4 bottom). Only layers whose Z is a variable
    /// need them (l ≥ 1: Z_0 = X is fixed).
    pub fn s_messages(
        &self,
        ctx: &AgentCtx,
        p_full: &[Matrix],
        p_in: &[&PMsg],
    ) -> Result<Vec<SMsg>> {
        let ws = ctx.ws;
        let l_total = ws.layers;
        let mut out = Vec::new();
        for &dst in &ws.communities[self.mi].neighbors {
            for l in 1..l_total {
                // Σ_{r'∈N_m∪{m}\{dst}} p_{l,r'→m} = p_full − p_{l,dst→m}.
                let p_from_dst = p_in
                    .iter()
                    .find(|m| m.layer == l && m.src == dst)
                    .map(|m| &m.mat)
                    .ok_or_else(|| {
                        anyhow::anyhow!("community {} missing p from neighbor {dst}", self.mi)
                    })?;
                let mut sum = p_full[l].clone();
                sum.axpy(-1.0, p_from_dst);
                let (s1, s2) = if l + 1 < l_total {
                    (self.z[l].clone(), sum)
                } else {
                    let mut s1 = self.z[l_total - 1].clone();
                    s1.axpy(-1.0, &sum);
                    (s1, self.u.clone())
                };
                out.push(SMsg {
                    layer: l,
                    src: self.mi,
                    dst,
                    s1,
                    s2,
                });
            }
        }
        Ok(out)
    }

    /// Phase C — Z_{l,m} for l = 1..L−1 (eq. 5/6 via the eq. 8/10 prox
    /// step with θ backtracking), then Z_{L,m} via FISTA (eq. 7), then the
    /// dual U_m (eq. 3, residual against the solved Q). `p_out` is this
    /// agent's own phase-A output (needed for the neighbor couplings);
    /// `s_in` is sorted in place by `(layer, src)`.
    pub fn update_z_u(
        &mut self,
        ctx: &AgentCtx,
        p_full: &[Matrix],
        p_cross: &[Matrix],
        p_out: &[PMsg],
        s_in: &mut [SMsg],
    ) -> Result<()> {
        let ws = ctx.ws;
        let backend = ctx.backend;
        let l_total = ws.layers;
        let comm = &ws.communities[self.mi];
        let nu = ws.hp.nu;
        let rho = ws.hp.rho;
        s_in.sort_by_key(|m| (m.layer, m.src));

        // Jacobi targets: the state this agent entered the epoch with (the
        // same Z the phase-A products were computed from).
        let z_prev: Vec<Matrix> = self.z.clone();

        // ---- hidden Z updates (eq. 5/6 via eq. 8/10) ----------------------
        for l in 1..l_total {
            let out_layer = l + 1 == l_total; // coupling into the linear head?
            let pin = &p_full[l - 1];
            let zk = &z_prev[l - 1];

            // Own coupling: pre = Ã_mm Z_l W_{l+1} + Σ_cross p = p_full[l].
            let pre_own = &p_full[l];
            let (mut psi0, r_own) = if out_layer {
                backend.out_residual(pre_own, &z_prev[l], &self.u, rho)?
            } else {
                backend.hidden_residual(pre_own, &z_prev[l], nu)?
            };
            let mut g_acc = backend.spmm(&comm.blocks[&self.mi], &r_own);
            backend.recycle(r_own);

            // Neighbor couplings (second-order terms, from received s).
            let mut s_cache: Vec<(usize, &Matrix, &Matrix)> = Vec::new();
            for sm in s_in.iter().filter(|m| m.layer == l) {
                let r = sm.src;
                let p_sent = p_out
                    .iter()
                    .find(|p| p.layer == l && p.dst == r)
                    .map(|p| &p.mat)
                    .expect("neighbor without own p message");
                let (val, rr) = if out_layer {
                    // pre = Ã_rm Z W_L (no complement), dual s2 = U_r.
                    backend.out_residual(p_sent, &sm.s1, &sm.s2, rho)?
                } else {
                    let mut pre = p_sent.clone();
                    pre.add_assign(&sm.s2);
                    let out = backend.hidden_residual(&pre, &sm.s1, nu)?;
                    backend.recycle(pre);
                    out
                };
                psi0 += val;
                // Ã_{r,m}ᵀ R = Ã_{m,r} R — the block m already holds.
                let gr = backend.spmm(&comm.blocks[&r], &rr);
                g_acc.add_assign(&gr);
                backend.recycle(gr);
                backend.recycle(rr);
                s_cache.push((r, &sm.s1, &sm.s2));
            }
            let gsum = backend.mm_bt(&g_acc, &ctx.w[l])?;
            backend.recycle(g_acc);

            // ψ at a candidate Z (for θ backtracking).
            let u_ref = &self.u;
            let psi_at = |z: &Matrix| -> Result<f32> {
                let mut val = backend.z_prox_val(z, pin, nu)?;
                let v = backend.mm_nn(z, &ctx.w[l])?;
                let mut pre = backend.spmm(&comm.blocks[&self.mi], &v);
                pre.add_assign(&p_cross[l]);
                val += if out_layer {
                    backend.out_phi(&pre, &z_prev[l], u_ref, rho)?
                } else {
                    backend.hidden_phi(&pre, &z_prev[l], nu)?
                };
                backend.recycle(pre);
                for (r, s1, s2) in &s_cache {
                    let mut pre_r = backend.spmm(&comm.blocks_t[r], &v);
                    val += if out_layer {
                        backend.out_phi(&pre_r, s1, s2, rho)?
                    } else {
                        pre_r.add_assign(s2);
                        backend.hidden_phi(&pre_r, s1, nu)?
                    };
                    backend.recycle(pre_r);
                }
                backend.recycle(v);
                Ok(val)
            };

            // θ backtracking on the combined step.
            let mut theta = self.theta[l - 1].max(STEP_MIN);
            let mut accepted: Option<Matrix> = None;
            let mut trials = 0usize;
            for _ in 0..BT_MAX_DOUBLINGS {
                trials += 1;
                let (znew, prox0, gsq) = backend.z_combine(zk, pin, &gsum, nu, theta)?;
                let bound = psi0 + prox0 - gsq / (2.0 * theta)
                    + BT_EPS * (psi0 + prox0).abs().max(1.0);
                if psi_at(&znew)? <= bound {
                    accepted = Some(znew);
                    break;
                }
                backend.recycle(znew);
                theta *= 2.0;
            }
            backend.recycle(gsum);
            if let Some(znew) = accepted {
                backend.recycle(std::mem::replace(&mut self.z[l - 1], znew));
            }
            if trials > 4 {
                log::trace!(
                    "z backtracking: comm {} layer {l} took {trials} trials (theta={theta:.3e})",
                    self.mi
                );
            }
            // Adaptive step persistence: only probe a smaller θ after an
            // epoch that accepted on the first trial (see the W subproblem).
            self.theta[l - 1] = if trials == 1 {
                (theta * 0.5).max(STEP_MIN)
            } else {
                theta
            };
        }

        // ---- Z_L via FISTA (eq. 7) ----------------------------------------
        let q = if ctx.gauss_seidel {
            // Own-block anchor from the freshly updated Z_{L-1,m};
            // cross-community terms stay at k (p_cross).
            let v = backend.mm_nn(&self.z[l_total - 2], &ctx.w[l_total - 1])?;
            let mut q = backend.spmm(&comm.blocks[&self.mi], &v);
            backend.recycle(v);
            q.add_assign(&p_cross[l_total - 1]);
            q
        } else {
            p_full[l_total - 1].clone()
        };
        let (z_l_new, _risk) = {
            let _span = crate::span!("admm.zl_fista", community = self.mi);
            backend.zl_fista(
                &q,
                &self.u,
                &comm.y,
                &comm.train_mask,
                &z_prev[l_total - 1],
                rho,
                ws.denom,
                ws.hp.fista_steps,
            )?
        };

        // ---- dual update (eq. 3, residual against the solved Q) -----------
        // axpy_sub is bitwise-equivalent to the former clone + axpy(-1) +
        // axpy(rho) sequence and skips the residual allocation entirely.
        let _u_span = crate::span!("admm.u_update", community = self.mi);
        self.u.axpy_sub(rho, &z_l_new, &q);
        backend.recycle(q);
        backend.recycle(std::mem::replace(&mut self.z[l_total - 1], z_l_new));
        // The Jacobi snapshot is epoch-local; park it for reuse.
        for m in z_prev {
            backend.recycle(m);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HyperParams;
    use crate::partition::Method;
    use crate::runtime::NativeBackend;
    use std::sync::Arc;

    fn ws(m: usize) -> Workspace {
        let ds = crate::data::fixtures::caveman(24, 3);
        let mut hp = HyperParams::for_dataset("caveman");
        hp.communities = m;
        hp.hidden = 8;
        Workspace::build(&ds, &hp, Method::Metis).unwrap()
    }

    fn agents_for(ws: &Workspace) -> Vec<CommunityAgent> {
        let mut rng = crate::util::rng::Rng::new(9);
        (0..ws.m)
            .map(|mi| CommunityAgent {
                mi,
                z: (1..=ws.layers)
                    .map(|l| Matrix::glorot(ws.n_pad, ws.dims[l], &mut rng))
                    .collect(),
                u: Matrix::zeros(ws.n_pad, ws.dims[ws.layers]),
                theta: vec![1.0; ws.layers - 1],
            })
            .collect()
    }

    #[test]
    fn p_products_cover_every_neighbor_and_layer() {
        let ws = ws(3);
        let backend = Arc::new(NativeBackend::new());
        let mut rng = crate::util::rng::Rng::new(4);
        let w: Vec<Matrix> = (1..=ws.layers)
            .map(|l| Matrix::glorot(ws.dims[l - 1], ws.dims[l], &mut rng))
            .collect();
        let ctx = AgentCtx {
            ws: &ws,
            backend: &*backend,
            w: &w,
            gauss_seidel: true,
        };
        for ag in agents_for(&ws) {
            let (p_own, out) = ag.p_products(&ctx).unwrap();
            assert_eq!(p_own.len(), ws.layers);
            let expect = ws.communities[ag.mi].neighbors.len() * ws.layers;
            assert_eq!(out.len(), expect);
            for m in &out {
                assert_eq!(m.src, ag.mi);
                assert!(ws.communities[ag.mi].neighbors.contains(&m.dst));
                assert_eq!(m.mat.shape(), (ws.n_pad, ws.dims[m.layer + 1]));
            }
        }
    }

    #[test]
    fn fold_is_order_independent() {
        let ws = ws(3);
        let backend = Arc::new(NativeBackend::new());
        let mut rng = crate::util::rng::Rng::new(4);
        let w: Vec<Matrix> = (1..=ws.layers)
            .map(|l| Matrix::glorot(ws.dims[l - 1], ws.dims[l], &mut rng))
            .collect();
        let ctx = AgentCtx {
            ws: &ws,
            backend: &*backend,
            w: &w,
            gauss_seidel: true,
        };
        let agents = agents_for(&ws);
        // Collect everything destined to community 0.
        let mut inbox: Vec<PMsg> = Vec::new();
        for ag in &agents[1..] {
            let (_, out) = ag.p_products(&ctx).unwrap();
            inbox.extend(out.into_iter().filter(|m| m.dst == 0));
        }
        let (p_own, _) = agents[0].p_products(&ctx).unwrap();
        let mut fwd: Vec<&PMsg> = inbox.iter().collect();
        let (full_a, cross_a) = agents[0].fold_p(&ctx, &p_own, &mut fwd);
        let mut rev: Vec<&PMsg> = inbox.iter().rev().collect();
        let (full_b, cross_b) = agents[0].fold_p(&ctx, &p_own, &mut rev);
        for (a, b) in full_a.iter().zip(&full_b) {
            assert_eq!(a.data(), b.data());
        }
        for (a, b) in cross_a.iter().zip(&cross_b) {
            assert_eq!(a.data(), b.data());
        }
    }
}
