//! The paper's system contribution: community-based layerwise distributed
//! ADMM training of GCNs.
//!
//! - [`workspace`] — partition, padded `Ã` blocks, per-community tensors.
//! - [`agent`] — one community's Z/U state + its per-epoch subproblems,
//!   driven entirely by received messages (the schedulable unit).
//! - [`admm`] — Algorithm 1 (W subproblem, epoch loop) plus the serial and
//!   pool-threaded agent executors.
//! - [`clock`] — virtual-time accounting + link model (1-core testbed).
//! - [`transport`] — the multi-process TCP runtime (leader + workers).

pub mod admm;
pub mod agent;
pub mod clock;
pub mod transport;
pub mod workspace;

pub use admm::{evaluate_forward, AdmmOptions, AdmmTrainer, ExecMode};
pub use agent::{AgentCtx, CommunityAgent, PMsg, SMsg};
pub use clock::{EpochClock, LinkModel};
pub use workspace::{Community, Workspace};

use crate::baselines;
use crate::config::HyperParams;
use crate::metrics::RunReport;
use crate::runtime::{select_backend, BackendChoice, ComputeBackend};
use crate::serve::SnapshotMeta;
use crate::util::cli::Args;
use anyhow::{bail, Context, Result};
use std::sync::Arc;

/// Everything `cgcn train` needs, resolved from CLI arguments.
pub struct TrainSetup {
    pub ws: Arc<Workspace>,
    /// Original-order dataset (the mini-batch engine extracts induced
    /// subgraphs from it; the workspace holds the permuted view).
    pub ds: Arc<crate::data::Dataset>,
    pub backend: Arc<dyn ComputeBackend>,
    pub hp: HyperParams,
    pub method: String,
    pub link: LinkModel,
    pub epochs: usize,
    pub exec: ExecMode,
    pub threads: usize,
}

/// Resolve CLI args into a workspace + backend (shared by train and bench).
pub fn setup_from_args(args: &Args) -> Result<TrainSetup> {
    let dataset = args.get_str("dataset");
    let scale = args.get_f64("scale");
    let seed = args.get_u64("seed");
    let method = args.get_str("method");

    let mut hp = HyperParams::for_dataset(&dataset);
    hp.hidden = args.get_usize("hidden");
    hp.layers = args.get_usize("layers");
    hp.communities = args.get_usize("communities");
    hp.epochs = args.get_usize("epochs");
    hp.seed = seed;
    if let Some(r) = args.get("rho").filter(|s| *s != "auto") {
        hp.rho = r.parse().context("--rho")?;
    }
    if let Some(n) = args.get("nu").filter(|s| *s != "auto") {
        hp.nu = n.parse().context("--nu")?;
    }
    // Fixture dims are fixed by the artifact plan.
    if dataset.starts_with("fig1") || dataset.starts_with("caveman") {
        hp.hidden = 8;
        if dataset == "caveman-l3" {
            hp.layers = 3;
        }
    }

    let exec = ExecMode::parse(&args.get_str("exec"))
        .ok_or_else(|| anyhow::anyhow!("unknown --exec value (serial|threads)"))?;
    let threads = args.get_usize("threads");
    let choice = BackendChoice::parse(&args.get_str("backend"))
        .ok_or_else(|| anyhow::anyhow!("unknown --backend value (auto|native|xla)"))?;
    // With a threaded agent executor the parallelism budget goes to the
    // agents; keep native backend ops serial to avoid oversubscription.
    let op_threads = if exec == ExecMode::Threads { 1 } else { threads.max(1) };
    let backend = select_backend(choice, op_threads)?;

    let ds = crate::cmd::load_dataset(&dataset, scale, seed)?;
    let pmethod = crate::cmd::parse_method(&args.get_str("partition"))?;
    let ws = Arc::new(Workspace::build(&ds, &hp, pmethod)?);
    let link = LinkModel::new(args.get_f64("link-mbps"), args.get_f64("link-lat-us"));
    Ok(TrainSetup {
        ws,
        ds: Arc::new(ds),
        backend,
        hp: hp.clone(),
        method,
        link,
        epochs: hp.epochs,
        exec,
        threads,
    })
}

/// `train --save <path>`: snapshot `w` to the requested path (no-op
/// without the flag). The metadata records the *resolved* run config
/// (post fixture overrides), so `rebuild_workspace` replays it verbatim.
pub(crate) fn maybe_save_model(
    args: &Args,
    ws: &Workspace,
    label: &str,
    w: &[crate::tensor::Matrix],
) -> Result<()> {
    let Some(path) = args.get("save").filter(|s| !s.is_empty()) else {
        return Ok(());
    };
    let meta = SnapshotMeta {
        label: label.to_string(),
        dataset: args.get_str("dataset"),
        scale: args.get_f64("scale"),
        seed: ws.hp.seed,
        partition: args.get_str("partition"),
        communities: ws.hp.communities,
        hidden: ws.hp.hidden,
        layers: ws.layers,
    };
    crate::serve::ModelSnapshot::capture(meta, ws, w)?.save(std::path::Path::new(path))?;
    log::info!("saved model snapshot to {path}");
    Ok(())
}

/// Run one training configuration (ADMM or a baseline optimizer).
pub fn run_training(setup: &TrainSetup, args: &Args) -> Result<RunReport> {
    let label = match setup.method.as_str() {
        "admm" => {
            if setup.ws.m == 1 {
                "admm-serial".to_string()
            } else {
                format!("admm-parallel-m{}", setup.ws.m)
            }
        }
        other => other.to_string(),
    };
    match setup.method.as_str() {
        "admm" => {
            if args.get_str("transport") == "tcp" {
                return transport::run_tcp_training(setup, args);
            }
            let mut opts = AdmmOptions::for_mode(setup.ws.m);
            opts.link = setup.link;
            opts.exec = setup.exec;
            opts.threads = setup.threads;
            if args.get_flag("parallel-layers") {
                opts.parallel_layers = true;
            }
            let mut trainer = AdmmTrainer::new(setup.ws.clone(), setup.backend.clone(), opts)?;
            let mut report = trainer.train(setup.epochs, &label)?;
            report.dataset = args.get_str("dataset");
            maybe_save_model(args, &setup.ws, &label, &trainer.state.w)?;
            Ok(report)
        }
        "gd" | "adam" | "adagrad" | "adadelta" => {
            let opt = baselines::Optimizer::parse(&setup.method, args.get("lr"))?;
            let mut trainer =
                baselines::BaselineTrainer::new(setup.ws.clone(), setup.backend.clone(), opt)?;
            let mut report = trainer.train(setup.epochs)?;
            report.dataset = args.get_str("dataset");
            maybe_save_model(args, &setup.ws, &label, trainer.weights())?;
            Ok(report)
        }
        "cluster-gcn" => {
            // Stochastic community mini-batch engine: Adam over induced
            // cluster-group subgraphs (paper lr unless --lr overrides).
            let opt = baselines::Optimizer::parse("adam", args.get("lr"))?;
            let opts = baselines::ClusterGcnOptions::from_args(args);
            let mut trainer = baselines::ClusterGcnTrainer::new(
                setup.ds.clone(),
                setup.ws.clone(),
                setup.backend.clone(),
                opt,
                opts,
            )?;
            let mut report = trainer.train(setup.epochs)?;
            report.dataset = args.get_str("dataset");
            log::info!(
                "cluster-gcn: {} clusters, peak batch {} nodes (full graph: {})",
                trainer.num_clusters(),
                trainer.peak_batch_nodes(),
                setup.ws.n
            );
            maybe_save_model(args, &setup.ws, &label, trainer.weights())?;
            Ok(report)
        }
        other => bail!("unknown method '{other}' (admm|gd|adam|adagrad|adadelta|cluster-gcn)"),
    }
}

/// `cgcn train` entry point.
pub fn run_from_args(args: &Args) -> Result<()> {
    let setup = setup_from_args(args)?;
    log::info!(
        "train: dataset={} n={} m={} method={} backend={} exec={} hidden={} layers={} epochs={}",
        args.get_str("dataset"),
        setup.ws.n,
        setup.ws.m,
        setup.method,
        setup.backend.name(),
        setup.exec.name(),
        setup.hp.hidden,
        setup.hp.layers,
        setup.epochs
    );
    let report = run_training(&setup, args)?;
    if args.get_flag("csv") {
        print!("{}", report.to_csv());
    } else {
        println!("{}", report.summary_json().to_pretty());
    }
    if let Some(out) = args.get("out").filter(|s| !s.is_empty()) {
        std::fs::write(out, report.to_csv())?;
        log::info!("wrote per-epoch CSV to {out}");
    }
    Ok(())
}
