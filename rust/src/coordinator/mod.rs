//! The paper's system contribution: community-based layerwise distributed
//! ADMM training of GCNs.
//!
//! - [`workspace`] — partition, padded `Ã` blocks, per-community tensors.
//! - [`admm`] — Algorithm 1 (W/Z/U subproblems, p/s message protocol).
//! - [`clock`] — virtual-time accounting + link model (1-core testbed).
//! - [`transport`] — the multi-process TCP runtime (leader + workers).

pub mod admm;
pub mod clock;
pub mod transport;
pub mod workspace;

pub use admm::{evaluate_forward, AdmmOptions, AdmmTrainer};
pub use clock::{EpochClock, LinkModel};
pub use workspace::{Community, Workspace};

use crate::baselines;
use crate::config::HyperParams;
use crate::metrics::RunReport;
use crate::runtime::Engine;
use crate::util::cli::Args;
use anyhow::{bail, Context, Result};
use std::sync::Arc;

/// Everything `cgcn train` needs, resolved from CLI arguments.
pub struct TrainSetup {
    pub ws: Arc<Workspace>,
    pub engine: Arc<Engine>,
    pub hp: HyperParams,
    pub method: String,
    pub link: LinkModel,
    pub epochs: usize,
}

/// Resolve CLI args into a workspace + engine (shared by train and bench).
pub fn setup_from_args(args: &Args) -> Result<TrainSetup> {
    let dataset = args.get_str("dataset");
    let scale = args.get_f64("scale");
    let seed = args.get_u64("seed");
    let method = args.get_str("method");

    let mut hp = HyperParams::for_dataset(&dataset);
    hp.hidden = args.get_usize("hidden");
    hp.layers = args.get_usize("layers");
    hp.communities = args.get_usize("communities");
    hp.epochs = args.get_usize("epochs");
    hp.seed = seed;
    if let Some(r) = args.get("rho").filter(|s| *s != "auto") {
        hp.rho = r.parse().context("--rho")?;
    }
    if let Some(n) = args.get("nu").filter(|s| *s != "auto") {
        hp.nu = n.parse().context("--nu")?;
    }
    // Fixture dims are fixed by the artifact plan.
    if dataset.starts_with("fig1") || dataset.starts_with("caveman") {
        hp.hidden = 8;
        if dataset == "caveman-l3" {
            hp.layers = 3;
        }
    }

    let ds = crate::cmd::load_dataset(&dataset, scale, seed)?;
    let pmethod = crate::cmd::parse_method(&args.get_str("partition"))?;
    let ws = Arc::new(Workspace::build(&ds, &hp, pmethod)?);
    let engine = Arc::new(Engine::load(&Engine::default_dir())?);
    let link = LinkModel::new(args.get_f64("link-mbps"), args.get_f64("link-lat-us"));
    Ok(TrainSetup {
        ws,
        engine,
        hp: hp.clone(),
        method,
        link,
        epochs: hp.epochs,
    })
}

/// Run one training configuration (ADMM or a baseline optimizer).
pub fn run_training(setup: &TrainSetup, args: &Args) -> Result<RunReport> {
    let label = match setup.method.as_str() {
        "admm" => {
            if setup.ws.m == 1 {
                "admm-serial".to_string()
            } else {
                format!("admm-parallel-m{}", setup.ws.m)
            }
        }
        other => other.to_string(),
    };
    match setup.method.as_str() {
        "admm" => {
            if args.get_str("transport") == "tcp" {
                return transport::run_tcp_training(setup, args);
            }
            let mut opts = AdmmOptions::for_mode(setup.ws.m);
            opts.link = setup.link;
            if args.get_flag("parallel-layers") {
                opts.parallel_layers = true;
            }
            let mut trainer = AdmmTrainer::new(setup.ws.clone(), setup.engine.clone(), opts)?;
            let mut report = trainer.train(setup.epochs, &label)?;
            report.dataset = args.get_str("dataset");
            Ok(report)
        }
        "gd" | "adam" | "adagrad" | "adadelta" => {
            let opt = baselines::Optimizer::parse(&setup.method, args.get("lr"))?;
            let mut trainer =
                baselines::BaselineTrainer::new(setup.ws.clone(), setup.engine.clone(), opt)?;
            let mut report = trainer.train(setup.epochs)?;
            report.dataset = args.get_str("dataset");
            Ok(report)
        }
        other => bail!("unknown method '{other}' (admm|gd|adam|adagrad|adadelta)"),
    }
}

/// `cgcn train` entry point.
pub fn run_from_args(args: &Args) -> Result<()> {
    let setup = setup_from_args(args)?;
    log::info!(
        "train: dataset={} n={} m={} method={} hidden={} layers={} epochs={}",
        args.get_str("dataset"),
        setup.ws.n,
        setup.ws.m,
        setup.method,
        setup.hp.hidden,
        setup.hp.layers,
        setup.epochs
    );
    let report = run_training(&setup, args)?;
    if std::env::var("CGCN_PROFILE").is_ok() {
        eprintln!("--- engine stats (top 15 by exec time) ---");
        for (sig, s) in setup.engine.stats().into_iter().take(15) {
            eprintln!(
                "{sig:<44} calls {:>6}  exec {:>8.3}s  marshal {:>8.3}s  compile {:>6.3}s",
                s.calls, s.exec_secs, s.marshal_secs, s.compile_secs
            );
        }
    }
    if args.get_flag("csv") {
        print!("{}", report.to_csv());
    } else {
        println!("{}", report.summary_json().to_pretty());
    }
    if let Some(out) = args.get("out").filter(|s| !s.is_empty()) {
        std::fs::write(out, report.to_csv())?;
        log::info!("wrote per-epoch CSV to {out}");
    }
    Ok(())
}
