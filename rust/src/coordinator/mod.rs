//! The paper's system contribution: community-based layerwise distributed
//! ADMM training of GCNs.
//!
//! - [`workspace`] — partition, padded `Ã` blocks, per-community tensors.
//! - [`agent`] — one community's Z/U state + its per-epoch subproblems,
//!   driven entirely by received messages (the schedulable unit).
//! - [`admm`] — Algorithm 1 (W subproblem, epoch loop) plus the serial and
//!   pool-threaded agent executors.
//! - [`clock`] — virtual-time accounting + link model (1-core testbed).
//! - [`transport`] — the elastic distributed runtime: the [`Transport`]
//!   trait, the [`WorkerCore`] host state machine, the fault-tolerant
//!   leader loop, and the TCP (multi-process) + channel (in-process
//!   threads) transports.
//! - [`sim`] — deterministic fault-injecting transport for chaos tests.
//! - [`checkpoint`] — the `.cgck` training-checkpoint codec
//!   (`--checkpoint-every` / `--resume`).

pub mod admm;
pub mod agent;
pub mod checkpoint;
pub mod clock;
pub mod sim;
pub mod transport;
pub mod workspace;

pub use admm::{evaluate_forward, AdmmOptions, AdmmTrainer, ExecMode};
pub use agent::{AgentCtx, CommunityAgent, PMsg, SMsg};
pub use checkpoint::{CheckpointSink, CkptMeta, CkptState, TrainCheckpoint};
pub use clock::{EpochClock, LinkModel};
pub use sim::{FaultPlan, SimStats, SimTransport};
pub use transport::{
    run_elastic_training, ChannelTransport, ElasticCfg, TcpTransport, Transport, TransportError,
    WorkerCore,
};
pub use workspace::{Community, Workspace};

use crate::baselines;
use crate::config::HyperParams;
use crate::metrics::RunReport;
use crate::runtime::{select_backend, select_backend_shared, BackendChoice, ComputeBackend};
use crate::serve::SnapshotMeta;
use crate::util::cli::Args;
use crate::util::pool::{resolve_threads, shared_thread_budget, Runtime};
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Resolved run identity (post fixture overrides) — what checkpoints and
/// snapshots record, and what TCP worker processes are spawned with.
/// `--resume` rebuilds this from the checkpoint instead of the CLI, so a
/// resumed run cannot drift from the run it continues.
#[derive(Clone, Debug)]
pub struct RunCfg {
    pub dataset: String,
    pub scale: f64,
    pub partition: String,
}

/// Everything `cgcn train` needs, resolved from CLI arguments.
pub struct TrainSetup {
    pub ws: Arc<Workspace>,
    /// Original-order dataset (the mini-batch engine extracts induced
    /// subgraphs from it; the workspace holds the permuted view).
    pub ds: Arc<crate::data::Dataset>,
    pub backend: Arc<dyn ComputeBackend>,
    pub hp: HyperParams,
    pub method: String,
    pub link: LinkModel,
    pub epochs: usize,
    pub exec: ExecMode,
    pub threads: usize,
    pub run: RunCfg,
}

/// Resolve `--exec`/`--threads`/`--runtime`/`--backend` into an executor
/// + backend (shared by the fresh-run and resume setup paths).
///
/// `--runtime shared` (the default) builds one work-stealing [`Runtime`]
/// whose budget is [`shared_thread_budget`]; the backend borrows it for
/// kernel forks and trainers submit agent/batch tasks to it through
/// [`ComputeBackend::runtime`]. `--runtime dual` keeps the legacy
/// two-pool setup for A/B: a dedicated agent pool plus a backend-owned
/// kernel pool.
fn resolve_exec(args: &Args) -> Result<(ExecMode, usize, Arc<dyn ComputeBackend>)> {
    let exec = ExecMode::parse(&args.get_str("exec"))
        .ok_or_else(|| anyhow::anyhow!("unknown --exec value (serial|threads)"))?;
    let threads = args.get_usize("threads");
    let choice = BackendChoice::parse(&args.get_str("backend"))
        .ok_or_else(|| anyhow::anyhow!("unknown --backend value (auto|native|xla)"))?;
    let spawn_ops = args.get_flag("op-spawn");
    let op_threads_arg = args.get_usize("op-threads");
    let shared = match args.get("runtime").unwrap_or("shared") {
        "shared" => true,
        "dual" => false,
        other => bail!("unknown --runtime '{other}' (shared|dual)"),
    };
    if shared {
        let budget = shared_thread_budget(threads, op_threads_arg);
        if threads != 0 && op_threads_arg != 0 && threads != op_threads_arg {
            log::info!(
                "--threads {threads} and --op-threads {op_threads_arg} differ; \
                 shared runtime budget = max = {budget}"
            );
        }
        // A backend that cannot share a runtime (XLA) reports
        // `runtime() == None` and the trainers fall back to dual-mode
        // pools on their own.
        let backend = select_backend_shared(choice, Arc::new(Runtime::new(budget)), spawn_ops)?;
        return Ok((exec, threads, backend));
    }
    // Legacy dual-pool accounting: `--op-threads 0` auto-sizes — all
    // cores under the serial agent executor, 1 under `--exec threads` so
    // kernel threads don't multiply against the agent pool. Either way
    // results are bitwise identical; only speed differs.
    let op_threads = match op_threads_arg {
        0 if exec == ExecMode::Threads => 1,
        0 => resolve_threads(0),
        n => n,
    };
    if exec == ExecMode::Threads {
        let cores = resolve_threads(0);
        let agents = resolve_threads(threads);
        if agents.saturating_mul(op_threads) > cores {
            log::warn!(
                "dual-pool mode may oversubscribe: up to {agents} agent threads × \
                 {op_threads} op threads on {cores} cores (--runtime shared uses one budget)"
            );
        }
    }
    let backend = select_backend(choice, op_threads, spawn_ops)?;
    Ok((exec, threads, backend))
}

/// Resolve CLI args into a workspace + backend (shared by train and bench).
pub fn setup_from_args(args: &Args) -> Result<TrainSetup> {
    let dataset = args.get_str("dataset");
    let scale = args.get_f64("scale");
    let seed = args.get_u64("seed");
    let method = args.get_str("method");

    let mut hp = HyperParams::for_dataset(&dataset);
    hp.hidden = args.get_usize("hidden");
    hp.layers = args.get_usize("layers");
    hp.communities = args.get_usize("communities");
    hp.epochs = args.get_usize("epochs");
    hp.seed = seed;
    if let Some(r) = args.get("rho").filter(|s| *s != "auto") {
        hp.rho = r.parse().context("--rho")?;
    }
    if let Some(n) = args.get("nu").filter(|s| *s != "auto") {
        hp.nu = n.parse().context("--nu")?;
    }
    // Fixture dims are fixed by the artifact plan.
    if dataset.starts_with("fig1") || dataset.starts_with("caveman") {
        hp.hidden = 8;
        if dataset == "caveman-l3" {
            hp.layers = 3;
        }
    }

    let (exec, threads, backend) = resolve_exec(args)?;
    let ds = crate::cmd::load_dataset(&dataset, scale, seed)?;
    let pfile = args.get("partition-file").unwrap_or("").to_string();
    let (ws, partition_name) = if pfile.is_empty() {
        let pmethod = crate::cmd::parse_method(&args.get_str("partition"))?;
        (
            Arc::new(Workspace::build(&ds, &hp, pmethod)?),
            args.get_str("partition"),
        )
    } else {
        // Import a precomputed assignment. The file's community count
        // overrides --communities, and its method name is recorded as
        // the run's partition so checkpoints/snapshots stay parseable
        // (a --resume re-detects with that method + hp.seed rather than
        // re-reading the file).
        let pf = crate::community::load_partition_file(&pfile)
            .with_context(|| format!("--partition-file {pfile}"))?;
        hp.communities = pf.partition.m();
        let name = if pf.method.is_empty() {
            args.get_str("partition")
        } else {
            pf.method.clone()
        };
        anyhow::ensure!(
            crate::cmd::parse_method(&name).is_ok(),
            "--partition-file {pfile}: unknown method {name:?}"
        );
        (Arc::new(Workspace::from_partition(&ds, &hp, pf.partition)?), name)
    };
    let link = LinkModel::new(args.get_f64("link-mbps"), args.get_f64("link-lat-us"));
    Ok(TrainSetup {
        ws,
        ds: Arc::new(ds),
        backend,
        hp: hp.clone(),
        method,
        link,
        epochs: hp.epochs,
        exec,
        threads,
        run: RunCfg {
            dataset,
            scale,
            partition: partition_name,
        },
    })
}

/// Rebuild a run from a `.cgck` checkpoint: dataset, seed, partition,
/// dims and penalties all come from the checkpoint (the CLI only chooses
/// the epoch target, executor, transport, backend and link model — knobs
/// that cannot change the math).
pub fn setup_from_checkpoint(ck: &TrainCheckpoint, args: &Args) -> Result<TrainSetup> {
    let m = &ck.meta.snap;
    let mut hp = m.base_hyperparams();
    hp.rho = ck.meta.rho;
    hp.nu = ck.meta.nu;
    hp.epochs = args.get_usize("epochs");
    anyhow::ensure!(
        (ck.epoch as usize) < hp.epochs,
        "checkpoint already covers epoch {} ≥ --epochs {}; raise --epochs to continue training",
        ck.epoch,
        hp.epochs
    );
    let (exec, threads, backend) = resolve_exec(args)?;
    let ds = crate::cmd::load_dataset(&m.dataset, m.scale, m.seed)
        .with_context(|| format!("rebuilding dataset '{}' from checkpoint", m.dataset))?;
    let pmethod = crate::cmd::parse_method(&m.partition)?;
    let ws = Arc::new(Workspace::build(&ds, &hp, pmethod)?);
    let link = LinkModel::new(args.get_f64("link-mbps"), args.get_f64("link-lat-us"));
    Ok(TrainSetup {
        ws,
        ds: Arc::new(ds),
        backend,
        hp: hp.clone(),
        method: ck.meta.method.clone(),
        link,
        epochs: hp.epochs,
        exec,
        threads,
        run: RunCfg {
            dataset: m.dataset.clone(),
            scale: m.scale,
            partition: m.partition.clone(),
        },
    })
}

/// The run's `.cgnm`/`.cgck` metadata block from resolved config.
fn snapshot_meta(run: &RunCfg, ws: &Workspace, label: &str) -> SnapshotMeta {
    SnapshotMeta {
        label: label.to_string(),
        dataset: run.dataset.clone(),
        scale: run.scale,
        seed: ws.hp.seed,
        partition: run.partition.clone(),
        communities: ws.hp.communities,
        hidden: ws.hp.hidden,
        layers: ws.layers,
    }
}

/// `train --save <path>`: snapshot `w` to the requested path (no-op
/// without the flag). The metadata records the *resolved* run config
/// (post fixture overrides), so `rebuild_workspace` replays it verbatim.
pub(crate) fn maybe_save_model(
    args: &Args,
    run: &RunCfg,
    ws: &Workspace,
    label: &str,
    w: &[crate::tensor::Matrix],
) -> Result<()> {
    let Some(path) = args.get("save").filter(|s| !s.is_empty()) else {
        return Ok(());
    };
    let meta = snapshot_meta(run, ws, label);
    crate::serve::ModelSnapshot::capture(meta, ws, w)?.save(Path::new(path))?;
    log::info!("saved model snapshot to {path}");
    Ok(())
}

/// Build the periodic checkpoint writer from `--checkpoint-every` /
/// `--checkpoint-dir` (None when disabled). Tolerates arg specs that
/// don't declare the flags (library callers).
fn checkpoint_sink(args: &Args, setup: &TrainSetup, label: &str) -> Result<Option<CheckpointSink>> {
    let every = args
        .get("checkpoint-every")
        .and_then(|s| s.parse::<usize>().ok())
        .unwrap_or(0);
    if every == 0 {
        return Ok(None);
    }
    let dir = PathBuf::from(args.get("checkpoint-dir").unwrap_or("checkpoints"));
    let meta = CkptMeta {
        snap: snapshot_meta(&setup.run, &setup.ws, label),
        method: setup.method.clone(),
        rho: setup.ws.hp.rho,
        nu: setup.ws.hp.nu,
    };
    Ok(Some(CheckpointSink::new(every, dir, meta)?))
}

/// Reconstruct the exact optimizer a baseline/cluster-gcn checkpoint was
/// written with.
fn optimizer_from_ckpt(ck: &TrainCheckpoint) -> Result<baselines::Optimizer> {
    match &ck.state {
        CkptState::Baseline { opt, lr, .. } | CkptState::ClusterGcn { opt, lr, .. } => {
            let mut o = baselines::Optimizer::parse(opt, None)?;
            o.set_lr(*lr);
            Ok(o)
        }
        CkptState::Admm { .. } => bail!("admm checkpoint has no baseline optimizer"),
    }
}

/// Reassemble per-layer optimizer slots from a checkpoint's parallel
/// `m`/`v`/`t` field vectors (shared by both backprop resume paths).
fn opt_states_from_ckpt(m: &[crate::tensor::Matrix], v: &[crate::tensor::Matrix], t: &[u64]) -> Vec<baselines::OptState> {
    (0..m.len())
        .map(|i| baselines::OptState {
            m: m[i].clone(),
            v: v[i].clone(),
            t: t[i],
        })
        .collect()
}

fn restore_baseline(trainer: &mut baselines::BaselineTrainer, ck: &TrainCheckpoint) -> Result<()> {
    let CkptState::Baseline { w, m, v, t, .. } = &ck.state else {
        bail!("checkpoint does not hold full-batch baseline state");
    };
    trainer.restore_state(w.clone(), opt_states_from_ckpt(m, v, t))
}

fn restore_cluster_gcn(
    trainer: &mut baselines::ClusterGcnTrainer,
    ck: &TrainCheckpoint,
) -> Result<()> {
    let CkptState::ClusterGcn {
        w, m, v, t, rng, peak, ..
    } = &ck.state
    else {
        bail!("checkpoint does not hold cluster-gcn state");
    };
    trainer.restore_state(w.clone(), opt_states_from_ckpt(m, v, t), *rng, *peak as usize)
}

/// Run one training configuration (ADMM or a baseline optimizer).
pub fn run_training(setup: &TrainSetup, args: &Args) -> Result<RunReport> {
    run_training_resumed(setup, args, None)
}

/// Run one training configuration, optionally continuing from a `.cgck`
/// checkpoint (`resume`). The checkpoint's epoch counter becomes the
/// first epoch; determinism of every trainer makes the resumed run
/// bitwise-identical to an uninterrupted one.
pub fn run_training_resumed(
    setup: &TrainSetup,
    args: &Args,
    resume: Option<&TrainCheckpoint>,
) -> Result<RunReport> {
    let start = resume.map(|c| c.epoch as usize).unwrap_or(0);
    let label = match setup.method.as_str() {
        "admm" => {
            if setup.ws.m == 1 {
                "admm-serial".to_string()
            } else {
                format!("admm-parallel-m{}", setup.ws.m)
            }
        }
        other => other.to_string(),
    };
    match setup.method.as_str() {
        "admm" => {
            let sink = checkpoint_sink(args, setup, &label)?;
            match args.get("transport").unwrap_or("local") {
                "tcp" => {
                    return transport::run_tcp_training(setup, args, resume, sink.as_ref())
                }
                "channel" => {
                    return transport::run_channel_training(setup, args, resume, sink.as_ref())
                }
                "local" => {}
                other => bail!("unknown --transport '{other}' (local|channel|tcp)"),
            }
            let mut opts = AdmmOptions::for_mode(setup.ws.m);
            opts.link = setup.link;
            opts.exec = setup.exec;
            opts.threads = setup.threads;
            if args.get_flag("parallel-layers") {
                opts.parallel_layers = true;
            }
            let mut trainer = AdmmTrainer::new(setup.ws.clone(), setup.backend.clone(), opts)?;
            if let Some(ck) = resume {
                checkpoint::restore_admm(&mut trainer, ck)?;
            }
            let mut report = trainer.train_range(start, setup.epochs, &label, sink.as_ref())?;
            report.dataset = setup.run.dataset.clone();
            maybe_save_model(args, &setup.run, &setup.ws, &label, &trainer.state.w)?;
            Ok(report)
        }
        "gd" | "adam" | "adagrad" | "adadelta" => {
            let opt = match resume {
                Some(ck) => optimizer_from_ckpt(ck)?,
                None => baselines::Optimizer::parse(&setup.method, args.get("lr"))?,
            };
            let mut trainer =
                baselines::BaselineTrainer::new(setup.ws.clone(), setup.backend.clone(), opt)?;
            if let Some(ck) = resume {
                restore_baseline(&mut trainer, ck)?;
            }
            let sink = checkpoint_sink(args, setup, &label)?;
            let mut report = trainer.train_range(start, setup.epochs, sink.as_ref())?;
            report.dataset = setup.run.dataset.clone();
            maybe_save_model(args, &setup.run, &setup.ws, &label, trainer.weights())?;
            Ok(report)
        }
        "cluster-gcn" => {
            // Stochastic community mini-batch engine: Adam over induced
            // cluster-group subgraphs (paper lr unless --lr overrides).
            let (opt, opts) = match resume {
                Some(ck) => {
                    let CkptState::ClusterGcn {
                        clusters,
                        batch_clusters,
                        ..
                    } = &ck.state
                    else {
                        bail!("checkpoint does not hold cluster-gcn state");
                    };
                    (
                        optimizer_from_ckpt(ck)?,
                        baselines::ClusterGcnOptions {
                            clusters: *clusters as usize,
                            batch_clusters: *batch_clusters as usize,
                            method: crate::cmd::parse_method(&setup.run.partition)?,
                        },
                    )
                }
                None => (
                    baselines::Optimizer::parse("adam", args.get("lr"))?,
                    baselines::ClusterGcnOptions::from_args(args),
                ),
            };
            let mut trainer = baselines::ClusterGcnTrainer::new(
                setup.ds.clone(),
                setup.ws.clone(),
                setup.backend.clone(),
                opt,
                opts,
            )?;
            if let Some(ck) = resume {
                restore_cluster_gcn(&mut trainer, ck)?;
            }
            let sink = checkpoint_sink(args, setup, &label)?;
            let mut report = trainer.train_range(start, setup.epochs, sink.as_ref())?;
            report.dataset = setup.run.dataset.clone();
            log::info!(
                "cluster-gcn: {} clusters, peak batch {} nodes (full graph: {})",
                trainer.num_clusters(),
                trainer.peak_batch_nodes(),
                setup.ws.n
            );
            maybe_save_model(args, &setup.run, &setup.ws, &label, trainer.weights())?;
            Ok(report)
        }
        other => bail!("unknown method '{other}' (admm|gd|adam|adagrad|adadelta|cluster-gcn)"),
    }
}

/// `cgcn train` entry point. `--resume <path.cgck>` continues a
/// checkpointed run; everything else starts fresh from the CLI config.
pub fn run_from_args(args: &Args) -> Result<()> {
    let (setup, resume) = match args.get("resume").filter(|s| !s.is_empty()) {
        Some(path) => {
            let ck = TrainCheckpoint::load(Path::new(path))
                .with_context(|| format!("--resume {path}"))?;
            let setup = setup_from_checkpoint(&ck, args)?;
            log::info!(
                "resuming {} from {} at epoch {} (of {})",
                ck.meta.method,
                path,
                ck.epoch,
                setup.epochs
            );
            (setup, Some(ck))
        }
        None => (setup_from_args(args)?, None),
    };
    log::info!(
        "train: dataset={} n={} m={} method={} backend={} exec={} hidden={} layers={} epochs={}",
        setup.run.dataset,
        setup.ws.n,
        setup.ws.m,
        setup.method,
        setup.backend.name(),
        setup.exec.name(),
        setup.hp.hidden,
        setup.hp.layers,
        setup.epochs
    );
    let report = run_training_resumed(&setup, args, resume.as_ref())?;
    if args.get_flag("csv") {
        print!("{}", report.to_csv());
    } else {
        println!("{}", report.summary_json().to_pretty());
    }
    if let Some(out) = args.get("out").filter(|s| !s.is_empty()) {
        std::fs::write(out, report.to_csv())?;
        log::info!("wrote per-epoch CSV to {out}");
    }
    if let Some(out) = args.get("trace-out").filter(|s| !s.is_empty()) {
        crate::obs::write_chrome_trace(Path::new(out))?;
    }
    if let Some(out) = args.get("metrics-out").filter(|s| !s.is_empty()) {
        crate::obs::write_metrics_json(Path::new(out))?;
    }
    Ok(())
}
