//! `.cgck` — the on-disk training-checkpoint codec (crash recovery).
//!
//! A checkpoint is everything needed to *continue training* after a crash
//! with bitwise-identical results to an uninterrupted run — strictly more
//! than the `.cgnm` model snapshot, which only rebuilds inference:
//!
//! - ADMM: weights `W`, the per-layer `τ` steps, every community's
//!   `Z`/`U`/`θ` state. One ADMM epoch is a pure function of this state
//!   and the (deterministically rebuilt) workspace, so resuming from the
//!   epoch barrier replays the exact float sequence.
//! - Full-batch baselines: weights plus the optimizer moment slots
//!   (`m`/`v`/`t` — Adam bias correction depends on `t`, so it persists).
//! - Cluster-GCN: baseline state plus the batch-shuffle RNG stream
//!   (xoshiro256** state words) and the measured peak batch size.
//!
//! Layout (all little-endian via [`crate::util::wire`], in the style of
//! `.cgnp`/`.cgnm`):
//!
//! ```text
//! magic "CGCK" | version u32
//! method str | rho f32 | nu f32 | SnapshotMeta (shared .cgnm field block)
//! epoch u64                      (completed epochs == resume point)
//! state tag u8:
//!   1 ADMM:       L | L×W | L×tau | M | M×( L×Z, U, (L-1)×theta )
//!   2 BASELINE:   opt str | lr | L | L×( W, m, v, t u64 )
//!   3 CLUSTER-GCN: opt str | lr | clusters | batch-clusters |
//!                 rng 4×u64 | peak u64 | L | L×( W, m, v, t u64 )
//! ```
//!
//! Corruption (bad magic, version skew, truncation at any byte, trailing
//! garbage, bogus state tags) is an error, never a panic — `--resume`
//! refuses cleanly. Writes are atomic (temp file + rename) so a crash
//! *during* checkpointing never leaves a half-written `.cgck` behind.

use super::admm::{AdmmState, AdmmTrainer};
use super::transport::{dec_matrix, enc_matrix};
use crate::serve::SnapshotMeta;
use crate::tensor::Matrix;
use crate::util::wire::{Dec, Enc};
use anyhow::{bail, ensure, Context, Result};
use std::path::{Path, PathBuf};

const MAGIC: &[u8; 4] = b"CGCK";
const VERSION: u32 = 1;
const TAG_ADMM: u8 = 1;
const TAG_BASELINE: u8 = 2;
const TAG_CLUSTER_GCN: u8 = 3;

/// Run identity persisted with every checkpoint: the `.cgnm`-style
/// metadata that rebuilds the workspace, the training method, and the
/// resolved ADMM penalties (ρ/ν feed the epoch math directly, so CLI
/// defaults must not be re-derived at resume time).
#[derive(Clone, Debug, PartialEq)]
pub struct CkptMeta {
    pub snap: SnapshotMeta,
    /// Training method (`admm`, `gd`, `adam`, ..., `cluster-gcn`).
    pub method: String,
    pub rho: f32,
    pub nu: f32,
}

/// The resumable mutable state of one trainer.
#[derive(Clone, Debug, PartialEq)]
pub enum CkptState {
    Admm {
        /// Weights W_1..W_L.
        w: Vec<Matrix>,
        /// τ_l per layer.
        tau: Vec<f32>,
        /// z[l-1][m] = Z_{l,m}.
        z: Vec<Vec<Matrix>>,
        /// Dual U_m per community.
        u: Vec<Matrix>,
        /// theta[l-1][m] per (hidden layer, community).
        theta: Vec<Vec<f32>>,
    },
    Baseline {
        opt: String,
        lr: f32,
        w: Vec<Matrix>,
        /// First-moment slots per layer.
        m: Vec<Matrix>,
        /// Second-moment slots per layer.
        v: Vec<Matrix>,
        /// Step counters per layer.
        t: Vec<u64>,
    },
    ClusterGcn {
        opt: String,
        lr: f32,
        clusters: u32,
        batch_clusters: u32,
        /// Batch-shuffle RNG state (continues the exact stream).
        rng: [u64; 4],
        /// Measured peak batch node count so far.
        peak: u64,
        w: Vec<Matrix>,
        m: Vec<Matrix>,
        v: Vec<Matrix>,
        t: Vec<u64>,
    },
}

impl CkptState {
    /// Capture the ADMM trainer's full mutable state.
    pub fn from_admm(st: &AdmmState) -> CkptState {
        CkptState::Admm {
            w: st.w.clone(),
            tau: st.tau.clone(),
            z: st.z.clone(),
            u: st.u.clone(),
            theta: st.theta.clone(),
        }
    }

    fn label(&self) -> &'static str {
        match self {
            CkptState::Admm { .. } => "admm",
            CkptState::Baseline { .. } => "baseline",
            CkptState::ClusterGcn { .. } => "cluster-gcn",
        }
    }
}

/// A saved training checkpoint: run identity + completed-epoch counter +
/// the trainer state at that epoch barrier.
#[derive(Clone, Debug, PartialEq)]
pub struct TrainCheckpoint {
    pub meta: CkptMeta,
    /// Completed epochs — the epoch index training resumes at.
    pub epoch: u64,
    pub state: CkptState,
}

fn enc_opt_layers(e: &mut Enc, w: &[Matrix], m: &[Matrix], v: &[Matrix], t: &[u64]) {
    e.u32(w.len() as u32);
    for li in 0..w.len() {
        enc_matrix(e, &w[li]);
        enc_matrix(e, &m[li]);
        enc_matrix(e, &v[li]);
        e.u64(t[li]);
    }
}

#[allow(clippy::type_complexity)]
fn dec_opt_layers(d: &mut Dec) -> Result<(Vec<Matrix>, Vec<Matrix>, Vec<Matrix>, Vec<u64>)> {
    let l = d.u32()? as usize;
    ensure!(l >= 1, "checkpoint has zero layers");
    let (mut w, mut m, mut v, mut t) = (Vec::new(), Vec::new(), Vec::new(), Vec::new());
    for li in 0..l {
        let wl = dec_matrix(d).with_context(|| format!("W_{}", li + 1))?;
        let ml = dec_matrix(d).with_context(|| format!("m_{}", li + 1))?;
        let vl = dec_matrix(d).with_context(|| format!("v_{}", li + 1))?;
        ensure!(
            ml.shape() == wl.shape() && vl.shape() == wl.shape(),
            "optimizer slot shapes disagree with W_{}",
            li + 1
        );
        w.push(wl);
        m.push(ml);
        v.push(vl);
        t.push(d.u64()?);
    }
    Ok((w, m, v, t))
}

impl TrainCheckpoint {
    /// Serialise to bytes.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut e = Enc::with_capacity(4096);
        e.u8(MAGIC[0]).u8(MAGIC[1]).u8(MAGIC[2]).u8(MAGIC[3]);
        e.u32(VERSION);
        e.str(&self.meta.method);
        e.f32(self.meta.rho).f32(self.meta.nu);
        self.meta.snap.encode(&mut e);
        e.u64(self.epoch);
        match &self.state {
            CkptState::Admm { w, tau, z, u, theta } => {
                e.u8(TAG_ADMM);
                e.u32(w.len() as u32);
                for wl in w {
                    enc_matrix(&mut e, wl);
                }
                for &tl in tau {
                    e.f32(tl);
                }
                let m = u.len();
                e.u32(m as u32);
                for mi in 0..m {
                    for zl in z {
                        enc_matrix(&mut e, &zl[mi]);
                    }
                    enc_matrix(&mut e, &u[mi]);
                    e.u32(theta.len() as u32);
                    for th in theta {
                        e.f32(th[mi]);
                    }
                }
            }
            CkptState::Baseline { opt, lr, w, m, v, t } => {
                e.u8(TAG_BASELINE);
                e.str(opt);
                e.f32(*lr);
                enc_opt_layers(&mut e, w, m, v, t);
            }
            CkptState::ClusterGcn {
                opt,
                lr,
                clusters,
                batch_clusters,
                rng,
                peak,
                w,
                m,
                v,
                t,
            } => {
                e.u8(TAG_CLUSTER_GCN);
                e.str(opt);
                e.f32(*lr);
                e.u32(*clusters).u32(*batch_clusters);
                for &s in rng {
                    e.u64(s);
                }
                e.u64(*peak);
                enc_opt_layers(&mut e, w, m, v, t);
            }
        }
        e.into_bytes()
    }

    /// Parse from bytes. Any corruption is an error, never a panic.
    pub fn from_bytes(bytes: &[u8]) -> Result<TrainCheckpoint> {
        let mut d = Dec::new(bytes);
        let magic = [d.u8()?, d.u8()?, d.u8()?, d.u8()?];
        if &magic != MAGIC {
            bail!("not a .cgck training checkpoint (bad magic)");
        }
        let version = d.u32()?;
        if version != VERSION {
            bail!("unsupported .cgck version {version} (this build reads {VERSION})");
        }
        let method = d.str()?;
        let rho = d.f32()?;
        let nu = d.f32()?;
        let snap = SnapshotMeta::decode(&mut d)?;
        let epoch = d.u64()?;
        let state = match d.u8()? {
            TAG_ADMM => {
                let l = d.u32()? as usize;
                ensure!(l >= 1, "admm checkpoint has zero layers");
                let mut w = Vec::with_capacity(l);
                for li in 0..l {
                    w.push(dec_matrix(&mut d).with_context(|| format!("W_{}", li + 1))?);
                }
                let mut tau = Vec::with_capacity(l);
                for _ in 0..l {
                    tau.push(d.f32()?);
                }
                let m = d.u32()? as usize;
                ensure!(m >= 1, "admm checkpoint has zero communities");
                let mut z: Vec<Vec<Matrix>> = (0..l).map(|_| Vec::with_capacity(m)).collect();
                let mut u = Vec::with_capacity(m);
                let mut theta: Vec<Vec<f32>> = (0..l - 1).map(|_| Vec::with_capacity(m)).collect();
                for mi in 0..m {
                    for zl in z.iter_mut() {
                        zl.push(dec_matrix(&mut d).with_context(|| format!("Z community {mi}"))?);
                    }
                    u.push(dec_matrix(&mut d).with_context(|| format!("U community {mi}"))?);
                    let nt = d.u32()? as usize;
                    ensure!(nt == l - 1, "theta count {nt} != layers-1 ({})", l - 1);
                    for th in theta.iter_mut() {
                        th.push(d.f32()?);
                    }
                }
                CkptState::Admm { w, tau, z, u, theta }
            }
            TAG_BASELINE => {
                let opt = d.str()?;
                let lr = d.f32()?;
                let (w, m, v, t) = dec_opt_layers(&mut d)?;
                CkptState::Baseline { opt, lr, w, m, v, t }
            }
            TAG_CLUSTER_GCN => {
                let opt = d.str()?;
                let lr = d.f32()?;
                let clusters = d.u32()?;
                let batch_clusters = d.u32()?;
                ensure!(
                    clusters >= 1 && batch_clusters >= 1,
                    "cluster-gcn checkpoint with zero clusters"
                );
                let rng = [d.u64()?, d.u64()?, d.u64()?, d.u64()?];
                let peak = d.u64()?;
                let (w, m, v, t) = dec_opt_layers(&mut d)?;
                CkptState::ClusterGcn {
                    opt,
                    lr,
                    clusters,
                    batch_clusters,
                    rng,
                    peak,
                    w,
                    m,
                    v,
                    t,
                }
            }
            other => bail!("unknown .cgck state tag {other}"),
        };
        if !d.done() {
            bail!("trailing bytes in .cgck checkpoint");
        }
        Ok(TrainCheckpoint {
            meta: CkptMeta {
                snap,
                method,
                rho,
                nu,
            },
            epoch,
            state,
        })
    }

    /// Save atomically: write `<path>.tmp`, then rename over `path` — a
    /// crash mid-write can never leave a truncated checkpoint that a
    /// later `--resume` would trip over.
    pub fn save(&self, path: &Path) -> Result<()> {
        let tmp = path.with_extension("cgck.tmp");
        std::fs::write(&tmp, self.to_bytes())
            .with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, path)
            .with_context(|| format!("renaming {} into place", tmp.display()))?;
        Ok(())
    }

    /// Load a `.cgck` checkpoint from a file.
    pub fn load(path: &Path) -> Result<TrainCheckpoint> {
        let bytes =
            std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
        TrainCheckpoint::from_bytes(&bytes)
            .with_context(|| format!("parsing {}", path.display()))
    }
}

/// Canonical checkpoint filename for an epoch (zero-padded so
/// lexicographic order == epoch order; `ls | sort | tail -1` finds the
/// latest, as does [`latest_in_dir`]).
pub fn checkpoint_path(dir: &Path, epoch: u64) -> PathBuf {
    dir.join(format!("ckpt_{epoch:06}.cgck"))
}

/// The newest checkpoint in a directory (by epoch-ordered filename), or
/// `None` when the directory holds none.
pub fn latest_in_dir(dir: &Path) -> Result<Option<PathBuf>> {
    let mut best: Option<PathBuf> = None;
    let entries = std::fs::read_dir(dir)
        .with_context(|| format!("listing checkpoint dir {}", dir.display()))?;
    for entry in entries {
        let path = entry?.path();
        let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
        if name.starts_with("ckpt_") && name.ends_with(".cgck") {
            let newer = match &best {
                None => true,
                Some(b) => b.file_name().and_then(|n| n.to_str()).unwrap_or("") < name,
            };
            if newer {
                best = Some(path);
            }
        }
    }
    Ok(best)
}

/// Periodic checkpoint writer handed to the training loops
/// (`--checkpoint-every N --checkpoint-dir D`).
pub struct CheckpointSink {
    every: usize,
    dir: PathBuf,
    meta: CkptMeta,
}

impl CheckpointSink {
    pub fn new(every: usize, dir: PathBuf, meta: CkptMeta) -> Result<CheckpointSink> {
        ensure!(every > 0, "checkpoint interval must be positive");
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
        Ok(CheckpointSink { every, dir, meta })
    }

    /// True when a checkpoint is due after `completed` epochs.
    pub fn due(&self, completed: usize) -> bool {
        completed > 0 && completed % self.every == 0
    }

    /// Write a checkpoint if one is due; `capture` is only invoked (and
    /// the state only cloned) when it is.
    pub fn maybe_write(
        &self,
        completed: usize,
        capture: impl FnOnce() -> CkptState,
    ) -> Result<()> {
        if !self.due(completed) {
            return Ok(());
        }
        let _span = crate::span!("ckpt.write", epoch = completed);
        let t0 = std::time::Instant::now();
        let ck = TrainCheckpoint {
            meta: self.meta.clone(),
            epoch: completed as u64,
            state: capture(),
        };
        let path = checkpoint_path(&self.dir, completed as u64);
        ck.save(&path)?;
        crate::obs_counter!("ckpt.writes").inc();
        crate::obs_hist!("ckpt.write.secs", crate::obs::TIME_BUCKETS).record_secs(t0);
        log::info!("wrote training checkpoint {}", path.display());
        leader_crash_hook(completed);
        Ok(())
    }
}

/// Test-only failure hook: `CGCN_TEST_LEADER_CRASH_AT=<completed-epochs>`
/// hard-aborts the process immediately after the matching checkpoint is
/// written — `ci.sh` uses it to exercise a leader crash + `--resume`
/// deterministically (a timed `kill -9` on the leader would race the
/// checkpoint write).
fn leader_crash_hook(completed: usize) {
    if let Ok(v) = std::env::var("CGCN_TEST_LEADER_CRASH_AT") {
        if v.parse::<usize>() == Ok(completed) {
            eprintln!("CGCN_TEST_LEADER_CRASH_AT={completed}: aborting after checkpoint write");
            std::process::abort();
        }
    }
}

/// Restore an ADMM trainer's mutable state from a checkpoint, shape-
/// checking every tensor against the (freshly rebuilt) workspace so a
/// stale or mismatched checkpoint errs instead of corrupting training.
pub fn restore_admm(trainer: &mut AdmmTrainer, ck: &TrainCheckpoint) -> Result<()> {
    let CkptState::Admm { w, tau, z, u, theta } = &ck.state else {
        bail!(
            "checkpoint holds {} state; this run trains with admm",
            ck.state.label()
        );
    };
    let ws = trainer.ws.clone();
    let l = ws.layers;
    let m = ws.m;
    ensure!(w.len() == l && tau.len() == l, "checkpoint layer count mismatch");
    ensure!(
        z.len() == l && u.len() == m && theta.len() == l - 1,
        "checkpoint community/layer state mismatch"
    );
    for (li, wl) in w.iter().enumerate() {
        ensure!(
            wl.shape() == (ws.dims[li], ws.dims[li + 1]),
            "checkpoint W_{} is {:?}, workspace wants {:?}",
            li + 1,
            wl.shape(),
            (ws.dims[li], ws.dims[li + 1])
        );
    }
    for (li, zl) in z.iter().enumerate() {
        ensure!(zl.len() == m, "checkpoint Z layer {} community count", li + 1);
        for zm in zl {
            ensure!(
                zm.shape() == (ws.n_pad, ws.dims[li + 1]),
                "checkpoint Z_{} shape {:?} != {:?}",
                li + 1,
                zm.shape(),
                (ws.n_pad, ws.dims[li + 1])
            );
        }
    }
    for um in u {
        ensure!(
            um.shape() == (ws.n_pad, ws.dims[l]),
            "checkpoint U shape {:?} != {:?}",
            um.shape(),
            (ws.n_pad, ws.dims[l])
        );
    }
    for th in theta {
        ensure!(th.len() == m, "checkpoint theta community count mismatch");
    }
    trainer.state.w = w.clone();
    trainer.state.tau = tau.clone();
    trainer.state.z = z.clone();
    trainer.state.u = u.clone();
    trainer.state.theta = theta.clone();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta() -> CkptMeta {
        CkptMeta {
            snap: SnapshotMeta {
                label: "t".into(),
                dataset: "caveman".into(),
                scale: 1.0,
                seed: 3,
                partition: "metis".into(),
                communities: 2,
                hidden: 4,
                layers: 2,
            },
            method: "admm".into(),
            rho: 1e-3,
            nu: 1e-3,
        }
    }

    fn mat(r: usize, c: usize, base: f32) -> Matrix {
        Matrix::from_fn(r, c, |i, j| base + (i * c + j) as f32 * 0.5)
    }

    fn admm_ckpt() -> TrainCheckpoint {
        TrainCheckpoint {
            meta: meta(),
            epoch: 6,
            state: CkptState::Admm {
                w: vec![mat(3, 4, 0.1), mat(4, 2, -1.0)],
                tau: vec![0.5, 2.0],
                z: vec![
                    vec![mat(5, 4, 1.0), mat(5, 4, 2.0)],
                    vec![mat(5, 2, 3.0), mat(5, 2, 4.0)],
                ],
                u: vec![mat(5, 2, -0.5), mat(5, 2, 0.25)],
                theta: vec![vec![1.0, 0.125]],
            },
        }
    }

    fn baseline_ckpt() -> TrainCheckpoint {
        let mut m = meta();
        m.method = "adam".into();
        TrainCheckpoint {
            meta: m,
            epoch: 9,
            state: CkptState::Baseline {
                opt: "adam".into(),
                lr: 1e-3,
                w: vec![mat(3, 4, 0.0), mat(4, 2, 1.0)],
                m: vec![mat(3, 4, 0.1), mat(4, 2, 0.2)],
                v: vec![mat(3, 4, 0.3), mat(4, 2, 0.4)],
                t: vec![9, 9],
            },
        }
    }

    fn cluster_ckpt() -> TrainCheckpoint {
        let mut m = meta();
        m.method = "cluster-gcn".into();
        TrainCheckpoint {
            meta: m,
            epoch: 2,
            state: CkptState::ClusterGcn {
                opt: "adam".into(),
                lr: 5e-2,
                clusters: 8,
                batch_clusters: 2,
                rng: [1, 2, 3, u64::MAX],
                peak: 31,
                w: vec![mat(3, 4, 0.0), mat(4, 2, 1.0)],
                m: vec![mat(3, 4, 0.1), mat(4, 2, 0.2)],
                v: vec![mat(3, 4, 0.3), mat(4, 2, 0.4)],
                t: vec![4, 4],
            },
        }
    }

    #[test]
    fn all_variants_roundtrip_bitwise() {
        for ck in [admm_ckpt(), baseline_ckpt(), cluster_ckpt()] {
            let back = TrainCheckpoint::from_bytes(&ck.to_bytes()).unwrap();
            assert_eq!(back, ck);
        }
    }

    #[test]
    fn truncation_at_every_boundary_errors_never_panics() {
        for ck in [admm_ckpt(), baseline_ckpt(), cluster_ckpt()] {
            let bytes = ck.to_bytes();
            for cut in 0..bytes.len() {
                assert!(
                    TrainCheckpoint::from_bytes(&bytes[..cut]).is_err(),
                    "truncation at {cut}/{} did not error",
                    bytes.len()
                );
            }
        }
    }

    #[test]
    fn bad_magic_version_skew_and_trailing_bytes_error() {
        let bytes = admm_ckpt().to_bytes();

        let mut bad = bytes.clone();
        bad[0] = b'X';
        let err = TrainCheckpoint::from_bytes(&bad).unwrap_err();
        assert!(err.to_string().contains("magic"), "{err}");

        let mut bad = bytes.clone();
        bad[4..8].copy_from_slice(&99u32.to_le_bytes());
        let err = TrainCheckpoint::from_bytes(&bad).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");

        let mut bad = bytes.clone();
        bad.push(0);
        let err = TrainCheckpoint::from_bytes(&bad).unwrap_err();
        assert!(err.to_string().contains("trailing"), "{err}");
    }

    #[test]
    fn bogus_state_tag_errors() {
        // Re-encode the header with a nonsense state tag.
        let ck = admm_ckpt();
        let mut e = Enc::new();
        e.u8(b'C').u8(b'G').u8(b'C').u8(b'K');
        e.u32(VERSION);
        e.str(&ck.meta.method);
        e.f32(ck.meta.rho).f32(ck.meta.nu);
        ck.meta.snap.encode(&mut e);
        e.u64(ck.epoch);
        e.u8(77);
        let err = TrainCheckpoint::from_bytes(&e.into_bytes()).unwrap_err();
        assert!(err.to_string().contains("state tag"), "{err}");
    }

    #[test]
    fn corrupt_length_fields_error_not_panic() {
        // Flip every byte of a valid checkpoint one at a time; parsing
        // must never panic (errors and silent value changes are both
        // fine — shape checks happen at restore time).
        let bytes = admm_ckpt().to_bytes();
        for i in 0..bytes.len() {
            let mut bad = bytes.clone();
            bad[i] ^= 0xFF;
            let _ = TrainCheckpoint::from_bytes(&bad);
        }
    }

    #[test]
    fn atomic_save_load_and_latest_selection() {
        let dir = std::env::temp_dir().join(format!("cgcn_ckpt_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let ck = admm_ckpt();
        for epoch in [2u64, 4, 10] {
            let mut c = ck.clone();
            c.epoch = epoch;
            c.save(&checkpoint_path(&dir, epoch)).unwrap();
        }
        let latest = latest_in_dir(&dir).unwrap().expect("checkpoints exist");
        assert!(latest.ends_with("ckpt_000010.cgck"), "{latest:?}");
        let back = TrainCheckpoint::load(&latest).unwrap();
        assert_eq!(back.epoch, 10);
        // No temp files left behind.
        for entry in std::fs::read_dir(&dir).unwrap() {
            let name = entry.unwrap().file_name();
            assert!(
                name.to_string_lossy().ends_with(".cgck"),
                "stray file {name:?}"
            );
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sink_interval_and_capture_laziness() {
        let dir = std::env::temp_dir().join(format!("cgcn_sink_test_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let sink = CheckpointSink::new(2, dir.clone(), meta()).unwrap();
        assert!(!sink.due(1) && sink.due(2) && !sink.due(3) && sink.due(4));
        // Not due: capture must not run.
        sink.maybe_write(3, || panic!("capture ran while not due")).unwrap();
        sink.maybe_write(4, || admm_ckpt().state).unwrap();
        assert!(checkpoint_path(&dir, 4).exists());
        assert!(!checkpoint_path(&dir, 3).exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
