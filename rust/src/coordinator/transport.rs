//! The elastic distributed runtime: a transport-agnostic leader loop
//! driving per-host worker state machines, with crash detection and
//! community reassignment.
//!
//! The deployment shape is the paper's (1 agent = 1 machine) star
//! topology, hardened for partial failure:
//!
//! - [`Transport`] — the leader's view of the network: an ordered,
//!   reliable frame channel per *host*, with failure surfaced as
//!   [`TransportError::Dead`]. Three implementations share it bit for
//!   bit: [`TcpTransport`] (worker processes + heartbeats),
//!   [`ChannelTransport`] (in-process worker threads over `mpsc`) and
//!   [`super::sim::SimTransport`] (single-threaded, deterministic,
//!   fault-injectable — the chaos-test harness).
//! - [`WorkerCore`] — the transport-agnostic worker: one host owning one
//!   *or more* [`CommunityAgent`]s, driven purely by received frames. The
//!   TCP worker process, the channel worker thread and the simulated host
//!   all run this same state machine, so recovery behaviour tested under
//!   `SimTransport` is the behaviour the real deployment executes.
//! - [`run_elastic_training`] — the leader loop. It snapshots the full
//!   mirrored ADMM state at every *epoch barrier* (all Z-reports applied
//!   atomically), and on any host loss it restores the barrier state,
//!   reassigns the lost host's communities to survivors (shipping their
//!   authoritative state via `Adopt` frames) and retries the epoch.
//!   Because an epoch is a pure function of its barrier state, a
//!   recovered run produces **bitwise-identical** weights to a fault-free
//!   one — asserted in `rust/tests/fault_tolerance.rs`.
//!
//! Protocol frames (all little-endian via [`crate::util::wire`]; data
//! frames carry an `(epoch, attempt)` tag so stale or duplicated frames
//! from an aborted epoch are recognised and skipped, and workers answer
//! duplicated requests from a reply cache instead of recomputing):
//!
//! | tag | dir            | payload                                         |
//! |-----|----------------|-------------------------------------------------|
//! | 1   | worker→leader  | Hello { host index }                            |
//! | 2   | worker→leader  | Ping (transport heartbeat)                      |
//! | 3   | leader→worker  | SetW { epoch, attempt, L weight matrices }      |
//! | 4   | worker→leader  | PMsgs { epoch, attempt, (layer, src, dst, M)* } |
//! | 5   | leader→worker  | PDeliver { same layout as 4 }                   |
//! | 6   | worker→leader  | SMsgs { epoch, attempt, (layer, src, dst, M, M)* } |
//! | 7   | leader→worker  | SDeliver { same layout as 6 }                   |
//! | 8   | worker→leader  | ZReport { epoch, attempt, per-community Z/U/θ, secs } |
//! | 9   | leader→worker  | Shutdown                                        |
//! | 10  | leader→worker  | Adopt { community, Z_1..Z_L, U, θ }             |
//!
//! Dead-host detection is transport-layer: TCP workers heartbeat with
//! Ping frames from a side thread, and the leader's reads carry a
//! deadline (`--hb-timeout-ms`) — silence beyond it, EOF, or any socket
//! error declares the host dead. A `kill -9`'d worker is detected by EOF
//! within milliseconds; a stalled link by the heartbeat deadline.

use super::admm::{AdmmOptions, AdmmTrainer};
use super::agent::{AgentCtx, CommunityAgent, PMsg, SMsg};
use super::checkpoint::{CheckpointSink, CkptState, TrainCheckpoint};
use super::clock::LinkModel;
use super::workspace::Workspace;
use super::TrainSetup;
use crate::metrics::{EpochRecord, RunReport};
use crate::runtime::ComputeBackend;
use crate::tensor::Matrix;
use crate::util::cli::Args;
use crate::util::wire::{read_frame, write_frame, Dec, Enc};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::io::{BufReader, BufWriter};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

pub(crate) const TAG_HELLO: u8 = 1;
pub(crate) const TAG_PING: u8 = 2;
pub(crate) const TAG_SET_W: u8 = 3;
pub(crate) const TAG_P_MSGS: u8 = 4;
pub(crate) const TAG_P_DELIVER: u8 = 5;
pub(crate) const TAG_S_MSGS: u8 = 6;
pub(crate) const TAG_S_DELIVER: u8 = 7;
pub(crate) const TAG_Z_REPORT: u8 = 8;
pub(crate) const TAG_SHUTDOWN: u8 = 9;
pub(crate) const TAG_ADOPT: u8 = 10;

pub(crate) fn enc_matrix(e: &mut Enc, m: &Matrix) {
    e.u32(m.rows() as u32).u32(m.cols() as u32).f32s(m.data());
}

pub(crate) fn dec_matrix(d: &mut Dec) -> Result<Matrix> {
    let rows = d.u32()? as usize;
    let cols = d.u32()? as usize;
    let data = d.f32s()?;
    anyhow::ensure!(data.len() == rows * cols, "matrix payload size mismatch");
    Ok(Matrix::from_vec(rows, cols, data))
}

// ---------------------------------------------------------------------------
// The transport trait
// ---------------------------------------------------------------------------

/// Why a leader-side transport operation failed.
#[derive(Debug)]
pub enum TransportError {
    /// The host is unreachable / crashed / timed out — recoverable by
    /// fencing it and reassigning its communities to survivors.
    Dead { host: usize, why: String },
    /// Unrecoverable (protocol invariant broken, local failure).
    Fatal(anyhow::Error),
}

pub type TResult<T> = std::result::Result<T, TransportError>;

pub(crate) fn dead<T>(host: usize, why: impl std::fmt::Display) -> TResult<T> {
    Err(TransportError::Dead {
        host,
        why: why.to_string(),
    })
}

/// The leader's view of the agent network: an ordered, reliable frame
/// channel per host. `recv` blocks up to the transport's liveness
/// deadline; both directions surface failure as [`TransportError::Dead`]
/// so the elastic loop can recover.
pub trait Transport {
    fn hosts(&self) -> usize;
    fn label(&self) -> &'static str;
    fn send(&mut self, host: usize, frame: &[u8]) -> TResult<()>;
    fn recv(&mut self, host: usize) -> TResult<Vec<u8>>;
    /// Fence a dead host: release its resources; every later op on it
    /// returns `Dead` immediately.
    fn fence(&mut self, host: usize);
    /// Total bytes moved so far (both directions, all hosts).
    fn bytes(&self) -> u64;
}

// ---------------------------------------------------------------------------
// WorkerCore — the transport-agnostic host state machine
// ---------------------------------------------------------------------------

/// What the caller should do after a frame is handled.
pub enum CoreAction {
    /// Nothing to send back (Adopt, Ping).
    None,
    /// Send this reply frame to the leader. `Arc` so the idempotency
    /// cache shares the buffer instead of copying multi-MB replies on
    /// the fault-free hot path.
    Reply(Arc<Vec<u8>>),
    /// Graceful shutdown requested.
    Shutdown,
}

/// One host's state machine: the set of [`CommunityAgent`]s it currently
/// owns (one initially; more after adopting a crashed host's communities)
/// plus the in-flight epoch's phase state. Entirely frame-driven — the
/// TCP worker process, the channel worker thread and the simulated host
/// feed it the same bytes and get the same bytes back.
pub struct WorkerCore {
    ws: Arc<Workspace>,
    backend: Arc<dyn ComputeBackend>,
    gauss_seidel: bool,
    agents: BTreeMap<usize, CommunityAgent>,
    w: Vec<Matrix>,
    epoch: u64,
    attempt: u32,
    p_own: BTreeMap<usize, Vec<Matrix>>,
    p_out: BTreeMap<usize, Vec<PMsg>>,
    fulls: BTreeMap<usize, Vec<Matrix>>,
    crosses: BTreeMap<usize, Vec<Matrix>>,
    /// Compute seconds since this epoch's SetW (reported in ZReport).
    secs: f64,
    /// Reply cache per request tag: a duplicated request (at-least-once
    /// delivery under faults) is answered from cache, not recomputed.
    replay: BTreeMap<u8, (u64, u32, Arc<Vec<u8>>)>,
}

impl WorkerCore {
    pub fn new(
        ws: Arc<Workspace>,
        backend: Arc<dyn ComputeBackend>,
        gauss_seidel: bool,
    ) -> WorkerCore {
        WorkerCore {
            ws,
            backend,
            gauss_seidel,
            agents: BTreeMap::new(),
            w: Vec::new(),
            epoch: 0,
            attempt: 0,
            p_own: BTreeMap::new(),
            p_out: BTreeMap::new(),
            fulls: BTreeMap::new(),
            crosses: BTreeMap::new(),
            secs: 0.0,
            replay: BTreeMap::new(),
        }
    }

    /// Communities currently hosted here (sorted).
    pub fn communities(&self) -> Vec<usize> {
        self.agents.keys().copied().collect()
    }

    fn ctx(&self) -> AgentCtx<'_> {
        AgentCtx {
            ws: &self.ws,
            backend: &*self.backend,
            w: &self.w,
            gauss_seidel: self.gauss_seidel,
        }
    }

    /// Handle one frame from the leader.
    pub fn handle(&mut self, frame: &[u8]) -> Result<CoreAction> {
        match frame.first() {
            None => bail!("empty frame"),
            Some(&TAG_SHUTDOWN) => Ok(CoreAction::Shutdown),
            Some(&TAG_PING) => Ok(CoreAction::None),
            Some(&TAG_ADOPT) => {
                self.handle_adopt(&frame[1..])?;
                Ok(CoreAction::None)
            }
            Some(&(tag @ (TAG_SET_W | TAG_P_DELIVER | TAG_S_DELIVER))) => {
                self.request(tag, &frame[1..])
            }
            Some(&other) => bail!("worker got unexpected frame tag {other}"),
        }
    }

    fn request(&mut self, tag: u8, payload: &[u8]) -> Result<CoreAction> {
        let mut d = Dec::new(payload);
        let epoch = d.u64()?;
        let attempt = d.u32()?;
        if let Some((e, a, reply)) = self.replay.get(&tag) {
            if (*e, *a) == (epoch, attempt) {
                return Ok(CoreAction::Reply(reply.clone()));
            }
        }
        let reply = Arc::new(match tag {
            TAG_SET_W => self.phase_a(epoch, attempt, &mut d)?,
            TAG_P_DELIVER => self.phase_b(epoch, attempt, &mut d)?,
            TAG_S_DELIVER => self.phase_c(epoch, attempt, &mut d)?,
            _ => unreachable!("request() called with non-request tag"),
        });
        self.replay.insert(tag, (epoch, attempt, reply.clone()));
        Ok(CoreAction::Reply(reply))
    }

    /// Adopt a community: install the shipped Z/U/θ state as a fresh
    /// agent (initial assignment, reassignment after a crash, and epoch
    /// retry all use this — the leader's barrier state is authoritative).
    fn handle_adopt(&mut self, payload: &[u8]) -> Result<()> {
        let ws = self.ws.clone();
        let l_total = ws.layers;
        let mut d = Dec::new(payload);
        let mi = d.u32()? as usize;
        anyhow::ensure!(mi < ws.m, "adopt: community {mi} out of range");
        let l = d.u32()? as usize;
        anyhow::ensure!(l == l_total, "adopt: layer count mismatch");
        let mut z = Vec::with_capacity(l);
        for li in 0..l {
            let zl = dec_matrix(&mut d)?;
            anyhow::ensure!(
                zl.shape() == (ws.n_pad, ws.dims[li + 1]),
                "adopt: Z_{} shape mismatch",
                li + 1
            );
            z.push(zl);
        }
        let u = dec_matrix(&mut d)?;
        anyhow::ensure!(
            u.shape() == (ws.n_pad, ws.dims[l_total]),
            "adopt: U shape mismatch"
        );
        let nt = d.u32()? as usize;
        anyhow::ensure!(nt == l_total - 1, "adopt: theta count mismatch");
        let mut theta = Vec::with_capacity(nt);
        for _ in 0..nt {
            theta.push(d.f32()?);
        }
        anyhow::ensure!(d.done(), "adopt: trailing bytes");
        self.agents
            .insert(mi, CommunityAgent::from_state(mi, z, u, theta));
        // Any in-flight phase state for this community is now stale.
        self.p_own.remove(&mi);
        self.p_out.remove(&mi);
        self.fulls.remove(&mi);
        self.crosses.remove(&mi);
        Ok(())
    }

    /// SetW: store the epoch's weights, run phase A (first-order products)
    /// for every hosted agent in community order, reply with all outgoing
    /// p messages.
    fn phase_a(&mut self, epoch: u64, attempt: u32, d: &mut Dec) -> Result<Vec<u8>> {
        let _span = crate::span!("worker.phase_a", epoch = epoch);
        let t0 = Instant::now();
        let l_total = self.ws.layers;
        let count = d.u32()? as usize;
        anyhow::ensure!(count == l_total, "setw: layer count mismatch");
        let mut w = Vec::with_capacity(count);
        for li in 0..count {
            let wl = dec_matrix(d)?;
            anyhow::ensure!(
                wl.shape() == (self.ws.dims[li], self.ws.dims[li + 1]),
                "setw: W_{} shape mismatch",
                li + 1
            );
            w.push(wl);
        }
        anyhow::ensure!(d.done(), "setw: trailing bytes");
        anyhow::ensure!(!self.agents.is_empty(), "setw: host has no communities");
        self.w = w;
        self.epoch = epoch;
        self.attempt = attempt;
        self.secs = 0.0;
        self.p_own.clear();
        self.p_out.clear();
        self.fulls.clear();
        self.crosses.clear();

        let mut own_map = BTreeMap::new();
        let mut out_map = BTreeMap::new();
        {
            let ctx = self.ctx();
            for (&mi, ag) in &self.agents {
                let (own, out) = ag.p_products(&ctx)?;
                own_map.insert(mi, own);
                out_map.insert(mi, out);
            }
        }
        let total: usize = out_map.values().map(|o: &Vec<PMsg>| o.len()).sum();
        let mut e = Enc::new();
        e.u8(TAG_P_MSGS).u64(epoch).u32(attempt).u32(total as u32);
        for out in out_map.values() {
            for msg in out {
                e.u32(msg.layer as u32).u32(msg.src as u32).u32(msg.dst as u32);
                enc_matrix(&mut e, &msg.mat);
            }
        }
        self.p_own = own_map;
        self.p_out = out_map;
        self.secs += t0.elapsed().as_secs_f64();
        Ok(e.into_bytes())
    }

    /// PDeliver: fold incoming p per agent, build second-order messages,
    /// reply with all outgoing s messages.
    fn phase_b(&mut self, epoch: u64, attempt: u32, d: &mut Dec) -> Result<Vec<u8>> {
        let _span = crate::span!("worker.phase_b", epoch = epoch);
        let t0 = Instant::now();
        anyhow::ensure!(
            (epoch, attempt) == (self.epoch, self.attempt),
            "p-deliver for epoch {epoch}.{attempt}, host is at {}.{}",
            self.epoch,
            self.attempt
        );
        let count = d.u32()? as usize;
        let mut inbox: BTreeMap<usize, Vec<PMsg>> =
            self.agents.keys().map(|&mi| (mi, Vec::new())).collect();
        for _ in 0..count {
            let layer = d.u32()? as usize;
            let src = d.u32()? as usize;
            let dst = d.u32()? as usize;
            let mat = dec_matrix(d)?;
            let slot = inbox
                .get_mut(&dst)
                .ok_or_else(|| anyhow!("p-deliver for community {dst} not hosted here"))?;
            slot.push(PMsg {
                layer,
                src,
                dst,
                mat,
            });
        }
        anyhow::ensure!(d.done(), "p-deliver: trailing bytes");

        let mut fulls = BTreeMap::new();
        let mut crosses = BTreeMap::new();
        let mut s_total = 0usize;
        let mut s_frames: Vec<SMsg> = Vec::new();
        {
            let ctx = self.ctx();
            for (&mi, ag) in &self.agents {
                let own = self
                    .p_own
                    .get(&mi)
                    .ok_or_else(|| anyhow!("p-deliver before setw for community {mi}"))?;
                let msgs = &inbox[&mi];
                let mut refs: Vec<&PMsg> = msgs.iter().collect();
                let (full, cross) = ag.fold_p(&ctx, own, &mut refs);
                let s_out = ag.s_messages(&ctx, &full, &refs)?;
                s_total += s_out.len();
                s_frames.extend(s_out);
                fulls.insert(mi, full);
                crosses.insert(mi, cross);
            }
        }
        let mut e = Enc::new();
        e.u8(TAG_S_MSGS).u64(epoch).u32(attempt).u32(s_total as u32);
        for msg in &s_frames {
            e.u32(msg.layer as u32).u32(msg.src as u32).u32(msg.dst as u32);
            enc_matrix(&mut e, &msg.s1);
            enc_matrix(&mut e, &msg.s2);
        }
        self.fulls = fulls;
        self.crosses = crosses;
        self.secs += t0.elapsed().as_secs_f64();
        Ok(e.into_bytes())
    }

    /// SDeliver: run the Z/U updates for every hosted agent, reply with
    /// the fresh per-community state (the leader's mirror + the epoch
    /// barrier are built from these reports).
    fn phase_c(&mut self, epoch: u64, attempt: u32, d: &mut Dec) -> Result<Vec<u8>> {
        let _span = crate::span!("worker.phase_c", epoch = epoch);
        let t0 = Instant::now();
        anyhow::ensure!(
            (epoch, attempt) == (self.epoch, self.attempt),
            "s-deliver for epoch {epoch}.{attempt}, host is at {}.{}",
            self.epoch,
            self.attempt
        );
        let count = d.u32()? as usize;
        let mut inbox: BTreeMap<usize, Vec<SMsg>> =
            self.agents.keys().map(|&mi| (mi, Vec::new())).collect();
        for _ in 0..count {
            let layer = d.u32()? as usize;
            let src = d.u32()? as usize;
            let dst = d.u32()? as usize;
            let s1 = dec_matrix(d)?;
            let s2 = dec_matrix(d)?;
            let slot = inbox
                .get_mut(&dst)
                .ok_or_else(|| anyhow!("s-deliver for community {dst} not hosted here"))?;
            slot.push(SMsg {
                layer,
                src,
                dst,
                s1,
                s2,
            });
        }
        anyhow::ensure!(d.done(), "s-deliver: trailing bytes");

        {
            let WorkerCore {
                ws,
                backend,
                w,
                gauss_seidel,
                agents,
                p_out,
                fulls,
                crosses,
                ..
            } = self;
            let ctx = AgentCtx {
                ws: &**ws,
                backend: &**backend,
                w: &**w,
                gauss_seidel: *gauss_seidel,
            };
            for (&mi, ag) in agents.iter_mut() {
                let full = fulls
                    .get(&mi)
                    .ok_or_else(|| anyhow!("s-deliver before p-deliver for community {mi}"))?;
                let cross = crosses
                    .get(&mi)
                    .ok_or_else(|| anyhow!("missing cross state for community {mi}"))?;
                let out = p_out
                    .get(&mi)
                    .ok_or_else(|| anyhow!("missing p_out for community {mi}"))?;
                let s_in = inbox.get_mut(&mi).expect("inbox slot exists");
                ag.update_z_u(&ctx, full, cross, out, s_in)?;
            }
        }
        self.secs += t0.elapsed().as_secs_f64();

        let l_total = self.ws.layers;
        let mut e = Enc::new();
        e.u8(TAG_Z_REPORT)
            .u64(epoch)
            .u32(attempt)
            .u32(self.agents.len() as u32);
        for (&mi, ag) in &self.agents {
            e.u32(mi as u32).u32(l_total as u32);
            for zl in &ag.z {
                enc_matrix(&mut e, zl);
            }
            enc_matrix(&mut e, &ag.u);
            e.u32(ag.theta.len() as u32);
            for &th in &ag.theta {
                e.f32(th);
            }
        }
        e.f64(self.secs);
        Ok(e.into_bytes())
    }
}

// ---------------------------------------------------------------------------
// The elastic leader loop (transport-generic)
// ---------------------------------------------------------------------------

/// Elastic training configuration.
pub struct ElasticCfg<'a> {
    pub label: String,
    pub dataset: String,
    /// First epoch to run (non-zero when resuming from a checkpoint).
    pub start_epoch: usize,
    pub epochs: usize,
    pub link: LinkModel,
    pub sink: Option<&'a CheckpointSink>,
}

/// Parse the `(tag, epoch, attempt)` header of a worker data frame.
fn frame_ea(frame: &[u8]) -> Option<(u8, u64, u32)> {
    let tag = *frame.first()?;
    if !(TAG_P_MSGS..=TAG_Z_REPORT).contains(&tag) {
        return None;
    }
    let mut d = Dec::new(&frame[1..]);
    let e = d.u64().ok()?;
    let a = d.u32().ok()?;
    Some((tag, e, a))
}

/// Receive the next frame matching `(want, epoch, attempt)` from `host`,
/// skipping heartbeats, stale frames from aborted attempts, and
/// duplicates of earlier phases (worker→leader tags ascend with the
/// phases, so `tag < want` at the current `(epoch, attempt)` is a dup).
fn expect_frame(
    t: &mut dyn Transport,
    host: usize,
    want: u8,
    epoch: u64,
    attempt: u32,
) -> TResult<Vec<u8>> {
    loop {
        let f = t.recv(host)?;
        if matches!(f.first(), Some(&TAG_PING) | Some(&TAG_HELLO)) {
            continue;
        }
        let Some((tag, e, a)) = frame_ea(&f) else {
            return dead(host, "malformed frame");
        };
        if (e, a) == (epoch, attempt) && tag == want {
            return Ok(f);
        }
        if (e, a) < (epoch, attempt) || ((e, a) == (epoch, attempt) && tag < want) {
            continue; // stale or duplicated — harmless under at-least-once delivery
        }
        return Err(TransportError::Fatal(anyhow!(
            "host {host}: unexpected frame tag {tag} at ({e},{a}) while expecting {want} at ({epoch},{attempt})"
        )));
    }
}

/// Ship every community's authoritative state to its assigned host.
/// Returns the first host that failed, if any.
fn ship_state(
    trainer: &AdmmTrainer,
    t: &mut dyn Transport,
    assign: &[usize],
) -> Option<(usize, String)> {
    let l_total = trainer.ws.layers;
    for (mi, &h) in assign.iter().enumerate() {
        let mut e = Enc::new();
        e.u8(TAG_ADOPT).u32(mi as u32).u32(l_total as u32);
        for li in 0..l_total {
            enc_matrix(&mut e, &trainer.state.z[li][mi]);
        }
        enc_matrix(&mut e, &trainer.state.u[mi]);
        e.u32((l_total - 1) as u32);
        for li in 0..l_total - 1 {
            e.f32(trainer.state.theta[li][mi]);
        }
        match t.send(h, e.bytes()) {
            Ok(()) => {}
            Err(TransportError::Dead { host, why }) => return Some((host, why)),
            Err(TransportError::Fatal(err)) => return Some((h, format!("{err:#}"))),
        }
    }
    None
}

/// Fence a lost host and deterministically reassign its communities to
/// the surviving hosts (ascending round-robin). Errors once no host
/// survives.
fn lose_host(
    t: &mut dyn Transport,
    host: usize,
    why: &str,
    live: &mut [bool],
    assign: &mut [usize],
) -> Result<()> {
    if live[host] {
        log::warn!("host {host} lost ({why}); reassigning its communities to survivors");
        crate::obs_counter!("transport.hosts_lost").inc();
        t.fence(host);
        live[host] = false;
    }
    let survivors: Vec<usize> = live
        .iter()
        .enumerate()
        .filter(|&(_, &l)| l)
        .map(|(i, _)| i)
        .collect();
    anyhow::ensure!(
        !survivors.is_empty(),
        "all agent hosts lost — cannot recover (last failure: host {host}: {why})"
    );
    let mut next = 0usize;
    for slot in assign.iter_mut() {
        if !live[*slot] {
            *slot = survivors[next % survivors.len()];
            next += 1;
        }
    }
    Ok(())
}

/// One distributed epoch over the transport. On success the leader's
/// mirror holds the new epoch-barrier state; on `Dead` the epoch must be
/// considered void (the caller restores the barrier snapshot).
fn elastic_epoch(
    trainer: &mut AdmmTrainer,
    t: &mut dyn Transport,
    assign: &[usize],
    epoch: u64,
    attempt: u32,
) -> TResult<(f64, f64)> {
    let _span = crate::span!("transport.epoch", epoch = epoch);
    let ws = trainer.ws.clone();
    let m = ws.m;
    let l_total = ws.layers;

    // 1. W update at the leader over the mirrored barrier state —
    // identical math to the local executors' distributed reduction.
    let mut w_secs = vec![0.0f64; m];
    for l in 1..=l_total {
        trainer
            .update_w_distributed_public(l, &mut w_secs)
            .map_err(TransportError::Fatal)?;
    }

    let hosts: Vec<usize> = {
        let set: BTreeSet<usize> = assign.iter().copied().collect();
        set.into_iter().collect()
    };

    // 2. Broadcast W.
    let mut e = Enc::new();
    e.u8(TAG_SET_W)
        .u64(epoch)
        .u32(attempt)
        .u32(l_total as u32);
    for w in &trainer.state.w {
        enc_matrix(&mut e, w);
    }
    let w_frame = e.into_bytes();
    for &h in &hosts {
        t.send(h, &w_frame)?;
    }

    // 3. Collect p messages, route by destination community.
    let mut inbox_p: Vec<VecDeque<(usize, usize, Matrix)>> =
        (0..m).map(|_| VecDeque::new()).collect();
    for &h in &hosts {
        let f = expect_frame(t, h, TAG_P_MSGS, epoch, attempt)?;
        let decode = (|| -> Result<()> {
            let mut d = Dec::new(&f[1..]);
            let (_, _) = (d.u64()?, d.u32()?);
            let count = d.u32()? as usize;
            for _ in 0..count {
                let layer = d.u32()? as usize;
                let src = d.u32()? as usize;
                let dst = d.u32()? as usize;
                let mat = dec_matrix(&mut d)?;
                anyhow::ensure!(layer < l_total && src < m && dst < m, "p message out of range");
                inbox_p[dst].push_back((layer, src, mat));
            }
            anyhow::ensure!(d.done(), "trailing bytes in PMsgs");
            Ok(())
        })();
        if let Err(err) = decode {
            return dead(h, format!("bad PMsgs frame: {err:#}"));
        }
    }

    // 4. Deliver p to each host (its communities' inboxes).
    for &h in &hosts {
        let mut e = Enc::new();
        e.u8(TAG_P_DELIVER).u64(epoch).u32(attempt);
        let total: usize = (0..m)
            .filter(|&mi| assign[mi] == h)
            .map(|mi| inbox_p[mi].len())
            .sum();
        e.u32(total as u32);
        for mi in 0..m {
            if assign[mi] != h {
                continue;
            }
            for (layer, src, mat) in &inbox_p[mi] {
                e.u32(*layer as u32).u32(*src as u32).u32(mi as u32);
                enc_matrix(&mut e, mat);
            }
        }
        t.send(h, e.bytes())?;
    }

    // 5. Collect + 6. deliver s messages the same way.
    let mut inbox_s: Vec<VecDeque<(usize, usize, Matrix, Matrix)>> =
        (0..m).map(|_| VecDeque::new()).collect();
    for &h in &hosts {
        let f = expect_frame(t, h, TAG_S_MSGS, epoch, attempt)?;
        let decode = (|| -> Result<()> {
            let mut d = Dec::new(&f[1..]);
            let (_, _) = (d.u64()?, d.u32()?);
            let count = d.u32()? as usize;
            for _ in 0..count {
                let layer = d.u32()? as usize;
                let src = d.u32()? as usize;
                let dst = d.u32()? as usize;
                let s1 = dec_matrix(&mut d)?;
                let s2 = dec_matrix(&mut d)?;
                anyhow::ensure!(layer < l_total && src < m && dst < m, "s message out of range");
                inbox_s[dst].push_back((layer, src, s1, s2));
            }
            anyhow::ensure!(d.done(), "trailing bytes in SMsgs");
            Ok(())
        })();
        if let Err(err) = decode {
            return dead(h, format!("bad SMsgs frame: {err:#}"));
        }
    }
    for &h in &hosts {
        let mut e = Enc::new();
        e.u8(TAG_S_DELIVER).u64(epoch).u32(attempt);
        let total: usize = (0..m)
            .filter(|&mi| assign[mi] == h)
            .map(|mi| inbox_s[mi].len())
            .sum();
        e.u32(total as u32);
        for mi in 0..m {
            if assign[mi] != h {
                continue;
            }
            for (layer, src, s1, s2) in &inbox_s[mi] {
                e.u32(*layer as u32).u32(*src as u32).u32(mi as u32);
                enc_matrix(&mut e, s1);
                enc_matrix(&mut e, s2);
            }
        }
        t.send(h, e.bytes())?;
    }

    // 7. Z reports — buffer everything, then apply atomically. This is
    // the epoch barrier: a host death anywhere above leaves the mirror
    // untouched relative to the caller's snapshot.
    let mut pending: Vec<(usize, Vec<Matrix>, Matrix, Vec<f32>)> = Vec::new();
    let mut host_secs = vec![0.0f64; t.hosts()];
    for &h in &hosts {
        let f = expect_frame(t, h, TAG_Z_REPORT, epoch, attempt)?;
        let decode = (|| -> Result<()> {
            let mut d = Dec::new(&f[1..]);
            let (_, _) = (d.u64()?, d.u32()?);
            let ncomm = d.u32()? as usize;
            let expect: BTreeSet<usize> =
                (0..m).filter(|&mi| assign[mi] == h).collect();
            anyhow::ensure!(
                ncomm == expect.len(),
                "host reported {ncomm} communities, owns {}",
                expect.len()
            );
            let mut seen = BTreeSet::new();
            for _ in 0..ncomm {
                let mi = d.u32()? as usize;
                anyhow::ensure!(expect.contains(&mi), "unexpected community {mi} in report");
                anyhow::ensure!(seen.insert(mi), "duplicate community {mi} in report");
                let l = d.u32()? as usize;
                anyhow::ensure!(l == l_total, "report layer count mismatch");
                let mut z = Vec::with_capacity(l);
                for li in 0..l {
                    let zl = dec_matrix(&mut d)?;
                    anyhow::ensure!(
                        zl.shape() == (ws.n_pad, ws.dims[li + 1]),
                        "report Z shape mismatch"
                    );
                    z.push(zl);
                }
                let u = dec_matrix(&mut d)?;
                anyhow::ensure!(
                    u.shape() == (ws.n_pad, ws.dims[l_total]),
                    "report U shape mismatch"
                );
                let nt = d.u32()? as usize;
                anyhow::ensure!(nt == l_total - 1, "report theta count mismatch");
                let mut theta = Vec::with_capacity(nt);
                for _ in 0..nt {
                    theta.push(d.f32()?);
                }
                pending.push((mi, z, u, theta));
            }
            host_secs[h] = d.f64()?;
            anyhow::ensure!(d.done(), "trailing bytes in ZReport");
            Ok(())
        })();
        if let Err(err) = decode {
            return dead(h, format!("bad ZReport frame: {err:#}"));
        }
    }
    for (mi, z, u, theta) in pending {
        for (li, zl) in z.into_iter().enumerate() {
            trainer.state.z[li][mi] = zl;
        }
        trainer.state.u[mi] = u;
        for (li, th) in theta.into_iter().enumerate() {
            trainer.state.theta[li][mi] = th;
        }
    }
    let w_par = w_secs.iter().copied().fold(0.0, f64::max);
    let z_par = host_secs.iter().copied().fold(0.0, f64::max);
    Ok((w_par, z_par))
}

/// Run elastic distributed ADMM training over any [`Transport`]: the
/// leader mirrors all community state, snapshots it at every epoch
/// barrier, detects dead hosts, reassigns their communities to survivors
/// from the last barrier, and (optionally) writes `.cgck` checkpoints.
pub fn run_elastic_training(
    trainer: &mut AdmmTrainer,
    t: &mut dyn Transport,
    cfg: &ElasticCfg,
) -> Result<RunReport> {
    let ws = trainer.ws.clone();
    let m = ws.m;
    anyhow::ensure!(
        t.hosts() == m,
        "transport has {} hosts for {} communities",
        t.hosts(),
        m
    );
    let mut live = vec![true; m];
    let mut assign: Vec<usize> = (0..m).collect();
    let mut need_ship = true;
    let mut report = RunReport::new(&cfg.label, &cfg.dataset, m);

    for e in cfg.start_epoch..cfg.epochs {
        let wall0 = Instant::now();
        // The epoch barrier: every retry of this epoch restarts from here.
        let barrier = trainer.state.clone();
        let mut attempt = 0u32;
        let (w_par, z_par, bytes) = loop {
            if need_ship {
                if let Some((host, why)) = ship_state(trainer, t, &assign) {
                    lose_host(t, host, &why, &mut live, &mut assign)?;
                    continue;
                }
                need_ship = false;
            }
            let bytes0 = t.bytes();
            match elastic_epoch(trainer, t, &assign, e as u64, attempt) {
                Ok((w_par, z_par)) => break (w_par, z_par, t.bytes() - bytes0),
                Err(TransportError::Dead { host, why }) => {
                    crate::obs_counter!("transport.epoch_retries").inc();
                    trainer.state = barrier.clone();
                    lose_host(t, host, &why, &mut live, &mut assign)?;
                    attempt += 1;
                    need_ship = true;
                    log::info!(
                        "epoch {e}: retrying (attempt {attempt}) with {} live hosts",
                        live.iter().filter(|&&l| l).count()
                    );
                }
                Err(TransportError::Fatal(err)) => return Err(err),
            }
        };
        let wall = wall0.elapsed().as_secs_f64();
        let live_n = live.iter().filter(|&&l| l).count().max(1);
        let (train_acc, test_acc, loss) = trainer.evaluate()?;
        let t_comm = cfg.link.msg_secs(bytes / live_n as u64) * live_n as f64;
        log::info!(
            "[{}] epoch {e}: loss={loss:.4} train={train_acc:.3} test={test_acc:.3} \
             wall={wall:.2}s bytes={bytes} hosts={live_n}",
            t.label()
        );
        report.push(EpochRecord {
            epoch: e,
            train_acc,
            test_acc,
            loss,
            t_train: w_par + z_par,
            t_comm,
            t_wall: wall,
            bytes,
        });
        if let Some(sink) = cfg.sink {
            sink.maybe_write(e + 1, || CkptState::from_admm(&trainer.state))?;
        }
    }

    let mut sd = Enc::new();
    sd.u8(TAG_SHUTDOWN);
    for h in 0..m {
        if live[h] {
            let _ = t.send(h, sd.bytes());
        }
    }
    Ok(report)
}

// ---------------------------------------------------------------------------
// TCP transport (leader side)
// ---------------------------------------------------------------------------

struct Conn {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

/// Multi-process transport: one worker process per host, length-framed
/// binary protocol over TCP, liveness via Ping heartbeats + read
/// deadlines.
pub struct TcpTransport {
    conns: Vec<Option<Conn>>,
    bytes: u64,
    /// Last heartbeat (or any frame) arrival per host, for the
    /// heartbeat-gap histogram — a gap creeping toward `--hb-timeout-ms`
    /// is the early warning before a host is declared dead.
    last_seen: Vec<Option<Instant>>,
}

impl TcpTransport {
    /// Accept `hosts` workers on `listener`, indexed by their Hello
    /// frames. `hb_timeout` becomes the per-read liveness deadline, and
    /// the whole accept phase is bounded by a startup deadline — a worker
    /// that dies *before* connecting (spawn failure, instant OOM-kill)
    /// must surface as an error, not hang the leader forever. Workers
    /// connect and Hello before their (possibly long) workspace build, so
    /// the deadline only needs to cover process startup.
    pub fn accept(
        listener: &TcpListener,
        hosts: usize,
        hb_timeout: Duration,
    ) -> Result<TcpTransport> {
        let startup_grace = hb_timeout.max(Duration::from_secs(5)) * 6;
        let accept_deadline = Instant::now() + startup_grace;
        listener.set_nonblocking(true)?;
        let mut conns: Vec<Option<Conn>> = (0..hosts).map(|_| None).collect();
        let mut bytes = 0u64;
        for _ in 0..hosts {
            let stream = loop {
                match listener.accept() {
                    Ok((stream, _)) => break stream,
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        anyhow::ensure!(
                            Instant::now() < accept_deadline,
                            "only {} of {hosts} workers connected before the startup deadline",
                            conns.iter().filter(|c| c.is_some()).count()
                        );
                        std::thread::sleep(Duration::from_millis(10));
                    }
                    Err(e) => return Err(e.into()),
                }
            };
            stream.set_nonblocking(false)?;
            stream.set_nodelay(true)?;
            stream.set_read_timeout(Some(hb_timeout))?;
            // Writes need a deadline too: a stalled-but-open connection
            // (SIGSTOP, zero-window partition) would otherwise block the
            // leader inside a broadcast forever, and recv's heartbeat
            // deadline never gets the chance to declare the host dead.
            // It is deliberately looser than the read deadline: the
            // initial Adopt ship can outpace a worker that is still
            // rebuilding its workspace, and socket buffers are finite —
            // a slow-but-alive host must not be killed at startup.
            stream.set_write_timeout(Some(startup_grace))?;
            let mut reader = BufReader::new(stream.try_clone()?);
            let hello = read_frame(&mut reader)?
                .ok_or_else(|| anyhow!("worker closed before Hello"))?;
            bytes += hello.len() as u64 + 4;
            anyhow::ensure!(hello.first() == Some(&TAG_HELLO), "expected Hello frame");
            let mut d = Dec::new(&hello[1..]);
            let idx = d.u32()? as usize;
            anyhow::ensure!(
                idx < hosts && conns[idx].is_none(),
                "bad worker index {idx}"
            );
            conns[idx] = Some(Conn {
                reader,
                writer: BufWriter::new(stream),
            });
        }
        let last_seen = vec![None; hosts];
        Ok(TcpTransport {
            conns,
            bytes,
            last_seen,
        })
    }
}

impl Transport for TcpTransport {
    fn hosts(&self) -> usize {
        self.conns.len()
    }

    fn label(&self) -> &'static str {
        "tcp"
    }

    fn send(&mut self, host: usize, frame: &[u8]) -> TResult<()> {
        let Some(conn) = self.conns[host].as_mut() else {
            return dead(host, "fenced");
        };
        match write_frame(&mut conn.writer, frame) {
            Ok(()) => {
                self.bytes += frame.len() as u64 + 4;
                crate::obs_counter!("transport.frames_sent").inc();
                crate::obs_counter!("transport.bytes_sent").add(frame.len() as u64 + 4);
                Ok(())
            }
            Err(e) => dead(host, format!("write failed: {e}")),
        }
    }

    fn recv(&mut self, host: usize) -> TResult<Vec<u8>> {
        let Some(conn) = self.conns[host].as_mut() else {
            return dead(host, "fenced");
        };
        loop {
            match read_frame(&mut conn.reader) {
                Ok(Some(f)) => {
                    self.bytes += f.len() as u64 + 4;
                    crate::obs_counter!("transport.frames_recv").inc();
                    crate::obs_counter!("transport.bytes_recv").add(f.len() as u64 + 4);
                    let now = Instant::now();
                    if let Some(prev) = self.last_seen[host].replace(now) {
                        crate::obs_hist!("transport.heartbeat.gap.secs", crate::obs::TIME_BUCKETS)
                            .record((now - prev).as_secs_f64());
                    }
                    if f.first() == Some(&TAG_PING) {
                        crate::obs_counter!("transport.heartbeats").inc();
                        continue; // heartbeat — liveness proven, keep waiting
                    }
                    return Ok(f);
                }
                Ok(None) => return dead(host, "connection closed"),
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    return dead(host, "heartbeat deadline exceeded")
                }
                Err(e) => return dead(host, format!("read failed: {e}")),
            }
        }
    }

    fn fence(&mut self, host: usize) {
        if let Some(conn) = self.conns[host].take() {
            let _ = conn.writer.get_ref().shutdown(std::net::Shutdown::Both);
        }
    }

    fn bytes(&self) -> u64 {
        self.bytes
    }
}

// ---------------------------------------------------------------------------
// Channel transport (in-process worker threads over mpsc)
// ---------------------------------------------------------------------------

/// In-process transport: one real thread per host running [`WorkerCore`],
/// frames exchanged over `mpsc` channels — the same leader loop and
/// worker state machine as TCP without process management. There are no
/// heartbeats here and `recv` blocks without a deadline on purpose: for
/// in-process threads, channel disconnection (thread exit or panic)
/// already detects real death precisely, and a timeout could only
/// produce false positives on long compute phases.
pub struct ChannelTransport {
    txs: Vec<Option<mpsc::Sender<Vec<u8>>>>,
    rxs: Vec<Option<mpsc::Receiver<Arc<Vec<u8>>>>>,
    handles: Vec<Option<std::thread::JoinHandle<()>>>,
    bytes: u64,
}

impl ChannelTransport {
    pub fn spawn(
        ws: &Arc<Workspace>,
        backend: &Arc<dyn ComputeBackend>,
        gauss_seidel: bool,
    ) -> ChannelTransport {
        let hosts = ws.m;
        let mut txs = Vec::with_capacity(hosts);
        let mut rxs = Vec::with_capacity(hosts);
        let mut handles = Vec::with_capacity(hosts);
        for h in 0..hosts {
            let (ltx, wrx) = mpsc::channel::<Vec<u8>>();
            let (wtx, lrx) = mpsc::channel::<Arc<Vec<u8>>>();
            let mut core = WorkerCore::new(ws.clone(), backend.clone(), gauss_seidel);
            let handle = std::thread::Builder::new()
                .name(format!("cgcn-host-{h}"))
                .spawn(move || {
                    while let Ok(frame) = wrx.recv() {
                        match core.handle(&frame) {
                            Ok(CoreAction::None) => {}
                            Ok(CoreAction::Reply(reply)) => {
                                if wtx.send(reply).is_err() {
                                    break;
                                }
                            }
                            Ok(CoreAction::Shutdown) => break,
                            Err(e) => {
                                log::warn!("channel host {h} failed: {e:#}");
                                break;
                            }
                        }
                    }
                })
                .expect("spawning host thread");
            txs.push(Some(ltx));
            rxs.push(Some(lrx));
            handles.push(Some(handle));
        }
        ChannelTransport {
            txs,
            rxs,
            handles,
            bytes: 0,
        }
    }
}

impl Transport for ChannelTransport {
    fn hosts(&self) -> usize {
        self.txs.len()
    }

    fn label(&self) -> &'static str {
        "channel"
    }

    fn send(&mut self, host: usize, frame: &[u8]) -> TResult<()> {
        let Some(tx) = self.txs[host].as_ref() else {
            return dead(host, "fenced");
        };
        match tx.send(frame.to_vec()) {
            Ok(()) => {
                self.bytes += frame.len() as u64 + 4;
                crate::obs_counter!("transport.frames_sent").inc();
                crate::obs_counter!("transport.bytes_sent").add(frame.len() as u64 + 4);
                Ok(())
            }
            Err(_) => dead(host, "host thread exited"),
        }
    }

    fn recv(&mut self, host: usize) -> TResult<Vec<u8>> {
        let Some(rx) = self.rxs[host].as_ref() else {
            return dead(host, "fenced");
        };
        match rx.recv() {
            Ok(f) => {
                self.bytes += f.len() as u64 + 4;
                crate::obs_counter!("transport.frames_recv").inc();
                crate::obs_counter!("transport.bytes_recv").add(f.len() as u64 + 4);
                Ok(Arc::try_unwrap(f).unwrap_or_else(|a| (*a).clone()))
            }
            Err(_) => dead(host, "host thread exited"),
        }
    }

    fn fence(&mut self, host: usize) {
        self.txs[host] = None;
        self.rxs[host] = None;
    }

    fn bytes(&self) -> u64 {
        self.bytes
    }
}

impl Drop for ChannelTransport {
    fn drop(&mut self) {
        for tx in self.txs.iter_mut() {
            *tx = None; // closing the channel stops the thread
        }
        for handle in self.handles.iter_mut().filter_map(|h| h.take()) {
            let _ = handle.join();
        }
    }
}

// ---------------------------------------------------------------------------
// CLI entry points (leader side)
// ---------------------------------------------------------------------------

fn hb_timeout_from_args(args: &Args) -> Duration {
    let ms = args
        .get("hb-timeout-ms")
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(5000)
        .max(100);
    Duration::from_millis(ms)
}

fn start_and_restore(
    setup: &TrainSetup,
    resume: Option<&TrainCheckpoint>,
) -> Result<(AdmmTrainer, usize)> {
    let mut opts = AdmmOptions::for_mode(setup.ws.m);
    opts.link = setup.link;
    let mut trainer = AdmmTrainer::new(setup.ws.clone(), setup.backend.clone(), opts)?;
    let start = match resume {
        Some(ck) => {
            super::checkpoint::restore_admm(&mut trainer, ck)?;
            ck.epoch as usize
        }
        None => 0,
    };
    Ok((trainer, start))
}

/// `--transport tcp`: spawn one worker process per community, run the
/// elastic leader loop, and wait for workers to exit.
pub fn run_tcp_training(
    setup: &TrainSetup,
    args: &Args,
    resume: Option<&TrainCheckpoint>,
    sink: Option<&CheckpointSink>,
) -> Result<RunReport> {
    let ws = setup.ws.clone();
    anyhow::ensure!(ws.m > 1, "tcp transport needs --communities > 1");
    let hb_timeout = hb_timeout_from_args(args);

    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    log::info!("leader listening on {addr}, spawning {} workers", ws.m);

    // Spawn workers with the *resolved* run config (post fixture
    // overrides, post checkpoint restore) — never raw CLI args, so
    // `--resume` runs spawn workers that rebuild the checkpoint's exact
    // workspace. CGCN_WORKER_EXE lets integration tests point at the real
    // binary (current_exe would be the test harness there).
    let exe = match std::env::var("CGCN_WORKER_EXE") {
        Ok(path) => std::path::PathBuf::from(path),
        Err(_) => std::env::current_exe()?,
    };
    let hb_interval = (hb_timeout.as_millis() as u64 / 4).max(50);
    let mut children = Vec::new();
    for mi in 0..ws.m {
        let child = std::process::Command::new(&exe)
            .args([
                "worker",
                "--listen",
                &addr.to_string(),
                "--worker-idx",
                &mi.to_string(),
                "--dataset",
                &setup.run.dataset,
                "--scale",
                &setup.run.scale.to_string(),
                "--seed",
                &ws.hp.seed.to_string(),
                "--hidden",
                &ws.hp.hidden.to_string(),
                "--layers",
                &ws.hp.layers.to_string(),
                "--communities",
                &ws.hp.communities.to_string(),
                "--rho",
                &ws.hp.rho.to_string(),
                "--nu",
                &ws.hp.nu.to_string(),
                "--partition",
                &setup.run.partition,
                "--epochs",
                &setup.epochs.to_string(),
                "--backend",
                &args.get_str("backend"),
                "--hb-interval-ms",
                &hb_interval.to_string(),
            ])
            .spawn()
            .context("spawning worker process")?;
        children.push(child);
    }

    let mut transport = TcpTransport::accept(&listener, ws.m, hb_timeout)?;
    let (mut trainer, start) = start_and_restore(setup, resume)?;
    let cfg = ElasticCfg {
        label: format!("admm-tcp-m{}", ws.m),
        dataset: setup.run.dataset.clone(),
        start_epoch: start,
        epochs: setup.epochs,
        link: setup.link,
        sink,
    };
    let result = run_elastic_training(&mut trainer, &mut transport, &cfg);
    // Fenced workers see their socket close and exit on their own; a
    // graceful run got a Shutdown frame. Either way, reap every child.
    drop(transport);
    for mut child in children {
        child.wait().ok();
    }
    let report = result?;
    // Save only after the workers are down — a failed --save must not
    // leave orphaned worker processes behind.
    super::maybe_save_model(args, &setup.run, &ws, &report.method, &trainer.state.w)?;
    Ok(report)
}

/// `--transport channel`: the same elastic leader loop over in-process
/// worker threads (mpsc frames, no processes).
pub fn run_channel_training(
    setup: &TrainSetup,
    args: &Args,
    resume: Option<&TrainCheckpoint>,
    sink: Option<&CheckpointSink>,
) -> Result<RunReport> {
    let ws = setup.ws.clone();
    anyhow::ensure!(ws.m > 1, "channel transport needs --communities > 1");
    let gs = AdmmOptions::for_mode(ws.m).gauss_seidel;
    let mut transport = ChannelTransport::spawn(&ws, &setup.backend, gs);
    let (mut trainer, start) = start_and_restore(setup, resume)?;
    let cfg = ElasticCfg {
        label: format!("admm-channel-m{}", ws.m),
        dataset: setup.run.dataset.clone(),
        start_epoch: start,
        epochs: setup.epochs,
        link: setup.link,
        sink,
    };
    let report = run_elastic_training(&mut trainer, &mut transport, &cfg)?;
    drop(transport);
    super::maybe_save_model(args, &setup.run, &ws, &report.method, &trainer.state.w)?;
    Ok(report)
}

// ---------------------------------------------------------------------------
// Worker side (TCP)
// ---------------------------------------------------------------------------

/// Worker process entry (`cgcn worker --listen <leader> --worker-idx i
/// <run config>`): rebuilds the deterministic workspace, then runs
/// [`WorkerCore`] against the leader's frames while a side thread
/// heartbeats Ping frames so the leader can tell "busy computing" from
/// "dead".
pub fn worker_main(args: &Args) -> Result<()> {
    let addr = args.get_str("listen");
    if addr.is_empty() {
        bail!("worker needs --listen <leader address>");
    }
    let mi = args.get_usize("worker-idx");
    let hb_ms = args
        .get("hb-interval-ms")
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(1000)
        .max(10);

    // Connect + Hello + heartbeats *before* the (possibly long) workspace
    // rebuild, so the leader's liveness clock is fed from the first
    // moment this process exists.
    let stream = TcpStream::connect(&addr)?;
    stream.set_nodelay(true)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let writer = Arc::new(Mutex::new(BufWriter::new(stream)));
    {
        let mut e = Enc::new();
        e.u8(TAG_HELLO).u32(mi as u32);
        let mut w = writer.lock().unwrap();
        write_frame(&mut *w, e.bytes())?;
    }
    log::info!("worker {mi} connected to {addr}");

    let stop = Arc::new(AtomicBool::new(false));
    let hb = {
        let writer = writer.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            let mut e = Enc::new();
            e.u8(TAG_PING);
            while !stop.load(Ordering::Relaxed) {
                std::thread::sleep(Duration::from_millis(hb_ms));
                let mut w = writer.lock().unwrap();
                if write_frame(&mut *w, e.bytes()).is_err() {
                    break;
                }
            }
        })
    };

    let result = (|| -> Result<()> {
        let setup = super::setup_from_args(args)?;
        let ws = setup.ws.clone();
        anyhow::ensure!(mi < ws.m, "worker index {mi} out of range");
        let gs = AdmmOptions::for_mode(ws.m).gauss_seidel;
        let mut core = WorkerCore::new(ws, setup.backend.clone(), gs);
        loop {
            let frame = read_frame(&mut reader)?
                .ok_or_else(|| anyhow!("leader closed connection"))?;
            match core.handle(&frame)? {
                CoreAction::None => {}
                CoreAction::Reply(reply) => {
                    let mut w = writer.lock().unwrap();
                    write_frame(&mut *w, &reply)?;
                }
                CoreAction::Shutdown => break,
            }
        }
        Ok(())
    })();
    stop.store(true, Ordering::Relaxed);
    let _ = hb.join();
    log::info!("worker {mi} shutting down");
    result
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_ea_parses_data_frames_only() {
        let mut e = Enc::new();
        e.u8(TAG_P_MSGS).u64(7).u32(2).u32(0);
        assert_eq!(frame_ea(e.bytes()), Some((TAG_P_MSGS, 7, 2)));
        let mut ping = Enc::new();
        ping.u8(TAG_PING);
        assert_eq!(frame_ea(ping.bytes()), None);
        let mut short = Enc::new();
        short.u8(TAG_Z_REPORT).u32(1); // truncated header
        assert_eq!(frame_ea(short.bytes()), None);
    }

    #[test]
    fn lose_host_reassigns_round_robin_deterministically() {
        struct NullTransport;
        impl Transport for NullTransport {
            fn hosts(&self) -> usize {
                4
            }
            fn label(&self) -> &'static str {
                "null"
            }
            fn send(&mut self, _: usize, _: &[u8]) -> TResult<()> {
                Ok(())
            }
            fn recv(&mut self, host: usize) -> TResult<Vec<u8>> {
                dead(host, "null")
            }
            fn fence(&mut self, _: usize) {}
            fn bytes(&self) -> u64 {
                0
            }
        }
        let mut t = NullTransport;
        let mut live = vec![true; 4];
        let mut assign = vec![0, 1, 2, 3];
        lose_host(&mut t, 1, "test", &mut live, &mut assign).unwrap();
        assert_eq!(assign, vec![0, 0, 2, 3]);
        lose_host(&mut t, 0, "test", &mut live, &mut assign).unwrap();
        // Communities 0 and 1 (both on host 0) round-robin over {2, 3}.
        assert_eq!(assign, vec![2, 3, 2, 3]);
        lose_host(&mut t, 2, "test", &mut live, &mut assign).unwrap();
        assert_eq!(assign, vec![3, 3, 3, 3]);
        let err = lose_host(&mut t, 3, "test", &mut live, &mut assign).unwrap_err();
        assert!(err.to_string().contains("cannot recover"), "{err}");
    }
}
