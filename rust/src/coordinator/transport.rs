//! Multi-process distributed runtime: leader + one worker process per
//! community, speaking a length-framed binary protocol over TCP.
//!
//! This is the deployment shape the paper describes (1 agent = 1 machine):
//! the leader owns the W reduction and message routing (star topology);
//! each worker owns one community's Z/U state and drives the same
//! [`CommunityAgent`] phases the in-process executors run, against
//! messages received over the wire. Workers rebuild the deterministic
//! workspace from the run config on their command line (dataset synthesis,
//! partitioning and init are all seeded), so only *state deltas* cross the
//! wire: W broadcasts, p/s messages and Z/U reports — exactly the traffic
//! the virtual link model prices in local mode. The leader mirrors worker
//! state from reports and runs the identical distributed W update, so a
//! TCP run reproduces a local serial run bit for bit.
//!
//! Protocol frames (all little-endian, via [`crate::util::wire`]):
//!
//! | tag | dir            | payload                                    |
//! |-----|----------------|---------------------------------------------|
//! | 1   | worker→leader  | Hello { worker index }                      |
//! | 3   | leader→worker  | SetW { L weight matrices }                  |
//! | 4   | worker→leader  | PMsgs { (layer, dst, matrix)* }             |
//! | 5   | leader→worker  | PDeliver { (layer, src, matrix)* }          |
//! | 6   | worker→leader  | SMsgs { (layer, dst, s1, s2)* }             |
//! | 7   | leader→worker  | SDeliver { (layer, src, s1, s2)* }          |
//! | 8   | worker→leader  | ZReport { Z_1..Z_L, U, compute seconds }    |
//! | 9   | leader→worker  | Shutdown                                    |

use super::agent::{PMsg, SMsg};
use super::admm::{AdmmOptions, AdmmTrainer};
use super::TrainSetup;
use crate::metrics::{EpochRecord, RunReport};
use crate::tensor::Matrix;
use crate::util::cli::Args;
use crate::util::wire::{read_frame, write_frame, Dec, Enc};
use anyhow::{bail, Context, Result};
use std::io::{BufReader, BufWriter};
use std::net::{TcpListener, TcpStream};
use std::time::Instant;

const TAG_HELLO: u8 = 1;
const TAG_SET_W: u8 = 3;
const TAG_P_MSGS: u8 = 4;
const TAG_P_DELIVER: u8 = 5;
const TAG_S_MSGS: u8 = 6;
const TAG_S_DELIVER: u8 = 7;
const TAG_Z_REPORT: u8 = 8;
const TAG_SHUTDOWN: u8 = 9;

fn enc_matrix(e: &mut Enc, m: &Matrix) {
    e.u32(m.rows() as u32).u32(m.cols() as u32).f32s(m.data());
}

fn dec_matrix(d: &mut Dec) -> Result<Matrix> {
    let rows = d.u32()? as usize;
    let cols = d.u32()? as usize;
    let data = d.f32s()?;
    anyhow::ensure!(data.len() == rows * cols, "matrix payload size mismatch");
    Ok(Matrix::from_vec(rows, cols, data))
}

struct Conn {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    /// Bytes sent + received on this connection (comm accounting).
    bytes: u64,
}

impl Conn {
    fn new(stream: TcpStream) -> Result<Conn> {
        stream.set_nodelay(true)?;
        Ok(Conn {
            reader: BufReader::new(stream.try_clone()?),
            writer: BufWriter::new(stream),
            bytes: 0,
        })
    }

    fn send(&mut self, payload: &[u8]) -> Result<()> {
        self.bytes += payload.len() as u64 + 4;
        write_frame(&mut self.writer, payload)?;
        Ok(())
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        let frame = read_frame(&mut self.reader)?
            .ok_or_else(|| anyhow::anyhow!("peer closed connection"))?;
        self.bytes += frame.len() as u64 + 4;
        Ok(frame)
    }

    fn expect(&mut self, tag: u8) -> Result<Vec<u8>> {
        let frame = self.recv()?;
        anyhow::ensure!(
            frame.first() == Some(&tag),
            "expected frame tag {tag}, got {:?}",
            frame.first()
        );
        Ok(frame)
    }
}

// ---------------------------------------------------------------------------
// Leader side
// ---------------------------------------------------------------------------

/// Run parallel ADMM with real worker processes. The leader keeps the full
/// trainer (for W updates + evaluation) and mirrors worker Z/U state from
/// their reports.
pub fn run_tcp_training(setup: &TrainSetup, args: &Args) -> Result<RunReport> {
    let ws = setup.ws.clone();
    anyhow::ensure!(ws.m > 1, "tcp transport needs --communities > 1");
    let l_total = ws.layers;

    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    log::info!("leader listening on {addr}, spawning {} workers", ws.m);

    // Spawn workers with the same run config; everything deterministic.
    // CGCN_WORKER_EXE lets integration tests point at the real binary
    // (current_exe would be the test harness there).
    let exe = match std::env::var("CGCN_WORKER_EXE") {
        Ok(path) => std::path::PathBuf::from(path),
        Err(_) => std::env::current_exe()?,
    };
    let mut children = Vec::new();
    for mi in 0..ws.m {
        let child = std::process::Command::new(&exe)
            .args([
                "worker",
                "--listen",
                &addr.to_string(),
                "--worker-idx",
                &mi.to_string(),
                "--dataset",
                &args.get_str("dataset"),
                "--scale",
                &args.get_str("scale"),
                "--seed",
                &args.get_str("seed"),
                "--hidden",
                &args.get_str("hidden"),
                "--layers",
                &args.get_str("layers"),
                "--communities",
                &args.get_str("communities"),
                "--rho",
                &args.get_str("rho"),
                "--nu",
                &args.get_str("nu"),
                "--partition",
                &args.get_str("partition"),
                "--epochs",
                &args.get_str("epochs"),
                "--backend",
                &args.get_str("backend"),
            ])
            .spawn()
            .context("spawning worker process")?;
        children.push(child);
    }

    // Accept + index connections by Hello.
    let mut conns: Vec<Option<Conn>> = (0..ws.m).map(|_| None).collect();
    for _ in 0..ws.m {
        let (stream, _) = listener.accept()?;
        let mut conn = Conn::new(stream)?;
        let hello = conn.expect(TAG_HELLO)?;
        let mut d = Dec::new(&hello[1..]);
        let idx = d.u32()? as usize;
        anyhow::ensure!(idx < ws.m && conns[idx].is_none(), "bad worker index {idx}");
        conns[idx] = Some(conn);
    }
    let mut conns: Vec<Conn> = conns.into_iter().map(|c| c.unwrap()).collect();

    // Leader-side trainer: W updates + evaluation + state mirror. Runs the
    // same distributed W reduction as local mode, over the mirrored state.
    let mut opts = AdmmOptions::for_mode(ws.m);
    opts.link = setup.link;
    let mut trainer = AdmmTrainer::new(ws.clone(), setup.backend.clone(), opts)?;

    let mut report = RunReport::new(
        &format!("admm-tcp-m{}", ws.m),
        &args.get_str("dataset"),
        ws.m,
    );
    let epochs = setup.epochs;
    for e in 0..epochs {
        let wall0 = Instant::now();
        let bytes0: u64 = conns.iter().map(|c| c.bytes).sum();

        // 1. W update at the leader over the mirrored state (identical math
        // to local mode's distributed reduction).
        let mut w_secs = vec![0.0f64; ws.m];
        for l in 1..=l_total {
            trainer.update_w_distributed_public(l, &mut w_secs)?;
        }

        // 2. Broadcast W.
        let mut enc = Enc::new();
        enc.u8(TAG_SET_W).u32(l_total as u32);
        for w in &trainer.state.w {
            enc_matrix(&mut enc, w);
        }
        let w_frame = enc.into_bytes();
        for conn in conns.iter_mut() {
            conn.send(&w_frame)?;
        }

        // 3. Collect p messages, route to destinations.
        let mut inbox_p: Vec<Vec<(u32, u32, Matrix)>> = vec![Vec::new(); ws.m];
        for (src, conn) in conns.iter_mut().enumerate() {
            let frame = conn.expect(TAG_P_MSGS)?;
            let mut d = Dec::new(&frame[1..]);
            let count = d.u32()?;
            for _ in 0..count {
                let l = d.u32()?;
                let dst = d.u32()? as usize;
                let mat = dec_matrix(&mut d)?;
                inbox_p[dst].push((l, src as u32, mat));
            }
        }
        for (dst, conn) in conns.iter_mut().enumerate() {
            let mut enc = Enc::new();
            enc.u8(TAG_P_DELIVER).u32(inbox_p[dst].len() as u32);
            for (l, src, mat) in &inbox_p[dst] {
                enc.u32(*l).u32(*src);
                enc_matrix(&mut enc, mat);
            }
            conn.send(&enc.into_bytes())?;
        }

        // 4. Collect + route s messages.
        let mut inbox_s: Vec<Vec<(u32, u32, Matrix, Matrix)>> = vec![Vec::new(); ws.m];
        for (src, conn) in conns.iter_mut().enumerate() {
            let frame = conn.expect(TAG_S_MSGS)?;
            let mut d = Dec::new(&frame[1..]);
            let count = d.u32()?;
            for _ in 0..count {
                let l = d.u32()?;
                let dst = d.u32()? as usize;
                let s1 = dec_matrix(&mut d)?;
                let s2 = dec_matrix(&mut d)?;
                inbox_s[dst].push((l, src as u32, s1, s2));
            }
        }
        for (dst, conn) in conns.iter_mut().enumerate() {
            let mut enc = Enc::new();
            enc.u8(TAG_S_DELIVER).u32(inbox_s[dst].len() as u32);
            for (l, src, s1, s2) in &inbox_s[dst] {
                enc.u32(*l).u32(*src);
                enc_matrix(&mut enc, s1);
                enc_matrix(&mut enc, s2);
            }
            conn.send(&enc.into_bytes())?;
        }

        // 5. Z reports: mirror worker state.
        let mut z_secs = vec![0.0f64; ws.m];
        for (mi, conn) in conns.iter_mut().enumerate() {
            let frame = conn.expect(TAG_Z_REPORT)?;
            let mut d = Dec::new(&frame[1..]);
            let layers = d.u32()? as usize;
            anyhow::ensure!(layers == l_total, "layer count mismatch in ZReport");
            for li in 0..l_total {
                trainer.state.z[li][mi] = dec_matrix(&mut d)?;
            }
            trainer.state.u[mi] = dec_matrix(&mut d)?;
            z_secs[mi] = d.f64()?;
        }

        let wall = wall0.elapsed().as_secs_f64();
        let bytes: u64 = conns.iter().map(|c| c.bytes).sum::<u64>() - bytes0;
        let (train_acc, test_acc, loss) = trainer.evaluate()?;
        // Virtual accounting mirrors local mode: W partials at critical
        // path, worker compute at critical path, comm from *measured* bytes.
        let t_train = w_secs.iter().copied().fold(0.0, f64::max)
            + z_secs.iter().copied().fold(0.0, f64::max);
        let t_comm = setup.link.msg_secs(bytes / ws.m as u64) * ws.m as f64;
        log::info!(
            "[tcp] epoch {e}: loss={loss:.4} train={train_acc:.3} test={test_acc:.3} \
             wall={wall:.2}s bytes={bytes}"
        );
        report.push(EpochRecord {
            epoch: e,
            train_acc,
            test_acc,
            loss,
            t_train,
            t_comm,
            t_wall: wall,
            bytes,
        });
    }

    for conn in conns.iter_mut() {
        let mut enc = Enc::new();
        enc.u8(TAG_SHUTDOWN);
        conn.send(&enc.into_bytes()).ok();
    }
    for mut child in children {
        child.wait().ok();
    }
    // Save only after the workers are shut down gracefully — a failed
    // --save must not leave orphaned worker processes behind.
    super::maybe_save_model(args, &ws, &report.method, &trainer.state.w)?;
    Ok(report)
}

// ---------------------------------------------------------------------------
// Worker side
// ---------------------------------------------------------------------------

/// Worker process entry (`cgcn worker --listen <leader addr> --worker-idx i
/// <run config>`): owns one community's Z/U state and drives the
/// [`super::agent::CommunityAgent`] phases against wire messages.
pub fn worker_main(args: &Args) -> Result<()> {
    let addr = args.get_str("listen");
    if addr.is_empty() {
        bail!("worker needs --listen <leader address>");
    }
    let mi = args.get_usize("worker-idx");

    // Rebuild the deterministic workspace + initial state.
    let setup = super::setup_from_args(args)?;
    let ws = setup.ws.clone();
    let l_total = ws.layers;
    anyhow::ensure!(mi < ws.m, "worker index {mi} out of range");
    let mut trainer = AdmmTrainer::new(
        ws.clone(),
        setup.backend.clone(),
        AdmmOptions::for_mode(ws.m),
    )?;
    let mut agent = trainer.take_agent(mi);

    let mut conn = Conn::new(TcpStream::connect(&addr)?)?;
    let mut enc = Enc::new();
    enc.u8(TAG_HELLO).u32(mi as u32);
    conn.send(&enc.into_bytes())?;
    log::info!("worker {mi} connected to {addr}");

    loop {
        // SetW or Shutdown.
        let frame = conn.recv()?;
        match frame.first() {
            Some(&TAG_SHUTDOWN) => break,
            Some(&TAG_SET_W) => {}
            other => bail!("unexpected frame {other:?}"),
        }
        let t0 = Instant::now();
        let mut d = Dec::new(&frame[1..]);
        let count = d.u32()? as usize;
        anyhow::ensure!(count == l_total);
        for li in 0..count {
            trainer.state.w[li] = dec_matrix(&mut d)?;
        }
        let ctx = trainer.agent_ctx();

        // Phase A: local p products; ship outgoing p.
        let (p_own, p_out) = agent.p_products(&ctx)?;
        let mut enc = Enc::new();
        enc.u8(TAG_P_MSGS).u32(p_out.len() as u32);
        for msg in &p_out {
            enc.u32(msg.layer as u32).u32(msg.dst as u32);
            enc_matrix(&mut enc, &msg.mat);
        }
        conn.send(&enc.into_bytes())?;

        // Receive incoming p.
        let frame = conn.expect(TAG_P_DELIVER)?;
        let mut d = Dec::new(&frame[1..]);
        let count = d.u32()?;
        let mut p_in_owned: Vec<PMsg> = Vec::with_capacity(count as usize);
        for _ in 0..count {
            let layer = d.u32()? as usize;
            let src = d.u32()? as usize;
            let mat = dec_matrix(&mut d)?;
            p_in_owned.push(PMsg {
                layer,
                src,
                dst: mi,
                mat,
            });
        }

        // Phase B: fold + second-order messages; ship outgoing s.
        let mut p_in: Vec<&PMsg> = p_in_owned.iter().collect();
        let (p_full, p_cross) = agent.fold_p(&ctx, &p_own, &mut p_in);
        let s_out = agent.s_messages(&ctx, &p_full, &p_in)?;
        let mut enc = Enc::new();
        enc.u8(TAG_S_MSGS).u32(s_out.len() as u32);
        for msg in &s_out {
            enc.u32(msg.layer as u32).u32(msg.dst as u32);
            enc_matrix(&mut enc, &msg.s1);
            enc_matrix(&mut enc, &msg.s2);
        }
        conn.send(&enc.into_bytes())?;

        // Receive incoming s.
        let frame = conn.expect(TAG_S_DELIVER)?;
        let mut d = Dec::new(&frame[1..]);
        let count = d.u32()?;
        let mut s_in: Vec<SMsg> = Vec::with_capacity(count as usize);
        for _ in 0..count {
            let layer = d.u32()? as usize;
            let src = d.u32()? as usize;
            let s1 = dec_matrix(&mut d)?;
            let s2 = dec_matrix(&mut d)?;
            s_in.push(SMsg {
                layer,
                src,
                dst: mi,
                s1,
                s2,
            });
        }

        // Phase C: Z + U updates for this community only.
        agent.update_z_u(&ctx, &p_full, &p_cross, &p_out, &mut s_in)?;
        let secs = t0.elapsed().as_secs_f64();

        // Report fresh state.
        let mut enc = Enc::new();
        enc.u8(TAG_Z_REPORT).u32(l_total as u32);
        for li in 0..l_total {
            enc_matrix(&mut enc, &agent.z[li]);
        }
        enc_matrix(&mut enc, &agent.u);
        enc.f64(secs);
        conn.send(&enc.into_bytes())?;
    }
    trainer.put_agent(agent);
    log::info!("worker {mi} shutting down");
    Ok(())
}
