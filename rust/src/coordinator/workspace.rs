//! Workspace: everything static for one training run — the partition, the
//! padded `Ã` blocks, per-community tensors and the permuted global view.
//!
//! Node order is *community-major*: the global permutation concatenates the
//! partition's member lists, so community `m` owns the contiguous global
//! row range `offsets[m] .. offsets[m] + size[m]` and gather/scatter between
//! community-padded matrices and global matrices are plain row copies.

use crate::config::{self, HyperParams};
use crate::data::Dataset;
use crate::graph::{split_blocks, Csr};
use crate::partition::{self, Method, Partition};
use crate::tensor::Matrix;
use anyhow::Result;
use std::collections::HashMap;

/// Static per-community data.
pub struct Community {
    /// Real (unpadded) node count n_m.
    pub size: usize,
    /// Neighbor communities N_m (paper §2).
    pub neighbors: Vec<usize>,
    /// r → Ã_{m,r}, padded to (n_pad × n_pad). Includes r = m.
    pub blocks: HashMap<usize, Csr>,
    /// r → Ã_{r,m} = Ã_{m,r}ᵀ, padded to (n_pad × n_pad) (what this
    /// community applies when *sending* rows that live on r).
    pub blocks_t: HashMap<usize, Csr>,
    /// r → number of this community's *boundary* nodes toward r (distinct
    /// nonzero columns of Ã_{r,m}) — the rows of Z_m that must actually be
    /// shipped to r for cross-block products; sizes the W-phase exchange.
    pub boundary_to: HashMap<usize, usize>,
    /// r → number of r's nodes adjacent to this community (distinct nonzero
    /// columns of Ã_{m,r}) — the only nonzero rows of an outgoing
    /// first-order message p_{l,m→r}, so they size the p exchange.
    pub boundary_from: HashMap<usize, usize>,
    /// Features X_m (n_pad × C0), zero-padded.
    pub x: Matrix,
    /// One-hot labels Y_m (n_pad × C_L), zero-padded.
    pub y: Matrix,
    /// Train mask (n_pad), zero on padding.
    pub train_mask: Vec<f32>,
    /// Global (permuted) row offset of this community.
    pub row_offset: usize,
}

/// The full static workspace shared by all agents.
pub struct Workspace {
    pub hp: HyperParams,
    pub m: usize,
    /// Per-community padded row count (equals n_glob when m == 1).
    pub n_pad: usize,
    /// Padded global row count.
    pub n_glob: usize,
    /// Real node count N.
    pub n: usize,
    /// Layer dims C_0..C_L.
    pub dims: Vec<usize>,
    /// Number of layers L.
    pub layers: usize,
    /// Global normalised adjacency in permuted order, padded (n_glob²).
    pub a_glob: Csr,
    /// Permuted global features (n_glob × C0).
    pub x_glob: Matrix,
    /// Cached H0 = Ã X (n_glob × C0) — used by eval, init and baselines.
    pub h0_glob: Matrix,
    /// Per-community rows of H0, padded (n_pad × C0): the W_1 subproblem's
    /// sparse aggregate S_m = Σ_r Ã_{m,r} X_r, which is static because X
    /// never changes — so the layer-1 W update needs no per-epoch SpMM or
    /// boundary exchange at all.
    pub h0_comm: Vec<Matrix>,
    /// Permuted global one-hot labels (n_glob × C_L).
    pub y_glob: Matrix,
    /// Permuted global masks (n_glob).
    pub train_mask_glob: Vec<f32>,
    pub test_mask_glob: Vec<f32>,
    /// Permuted labels (length n, unpadded).
    pub labels: Vec<usize>,
    /// Global labeled-node count (the softmax denom — shared by every
    /// community so per-community losses sum to the serial loss).
    pub denom: f32,
    pub communities: Vec<Community>,
    pub partition: Partition,
    /// Edge cut of the partition (reported in ablations).
    pub edgecut: usize,
}

impl Workspace {
    /// Build a workspace: partition, permute, extract and pad blocks.
    pub fn build(ds: &Dataset, hp: &HyperParams, method: Method) -> Result<Workspace> {
        let part = partition::partition(&ds.graph, hp.communities, method, hp.seed);
        Workspace::from_partition(ds, hp, part)
    }

    /// Build a workspace from an already-computed partition (e.g. one
    /// imported with `--partition-file`). Validates that the partition
    /// matches the dataset and hyper-parameters: node coverage, exactly
    /// `hp.communities` non-empty parts, and the balance cap every padded
    /// artifact shape assumes.
    pub fn from_partition(ds: &Dataset, hp: &HyperParams, part: Partition) -> Result<Workspace> {
        let n = ds.n();
        let m = hp.communities;
        let dims = hp.dims(ds.num_features(), ds.num_classes);
        let layers = dims.len() - 1;

        anyhow::ensure!(
            part.assignment.len() == n,
            "partition covers {} nodes, dataset has {n}",
            part.assignment.len()
        );
        anyhow::ensure!(
            part.m() == m,
            "partition has {} communities, run wants --communities {m}",
            part.m()
        );
        anyhow::ensure!(
            part.members.iter().all(|mem| !mem.is_empty()),
            "partition has an empty community"
        );
        let cap = config::community_cap(n, m);
        for (ci, s) in part.sizes().iter().enumerate() {
            anyhow::ensure!(
                *s <= cap,
                "community {ci} has {s} nodes > cap {cap}; partition/balance mismatch"
            );
        }
        let n_pad = if m == 1 {
            config::padded_global(n)
        } else {
            config::padded_community(n, m)
        };
        let n_glob = config::padded_global(n);
        let edgecut = part.edgecut(&ds.graph);

        // ---- permute to community-major order -----------------------------
        let mut perm = Vec::with_capacity(n); // perm[new] = old
        let mut offsets = Vec::with_capacity(m);
        for mem in &part.members {
            offsets.push(perm.len());
            perm.extend_from_slice(mem);
        }

        let a = ds.graph.normalized_adjacency();
        debug_assert!(a.is_symmetric(1e-6));
        let blocks = split_blocks(&a, &part.members);

        // Global permuted Ã (rows/cols reordered), padded.
        let mut old_to_new = vec![0usize; n];
        for (new, &old) in perm.iter().enumerate() {
            old_to_new[old] = new;
        }
        let mut trips = Vec::with_capacity(a.nnz());
        for old_r in 0..n {
            let (cols, vals) = a.row(old_r);
            for (&c, &v) in cols.iter().zip(vals) {
                trips.push((old_to_new[old_r], old_to_new[c as usize], v));
            }
        }
        let a_glob = Csr::from_triplets(n_glob, n_glob, &trips);

        // Permuted global tensors, padded.
        let x_glob = ds.features.gather_rows(&perm).pad_rows(n_glob);
        let classes = ds.num_classes;
        let mut y_glob = Matrix::zeros(n_glob, classes);
        let mut train_mask_glob = vec![0.0f32; n_glob];
        let mut test_mask_glob = vec![0.0f32; n_glob];
        let mut labels = Vec::with_capacity(n);
        for (new, &old) in perm.iter().enumerate() {
            y_glob.set(new, ds.labels[old], 1.0);
            train_mask_glob[new] = ds.train_mask[old];
            test_mask_glob[new] = ds.test_mask[old];
            labels.push(ds.labels[old]);
        }
        let denom = train_mask_glob.iter().sum::<f32>().max(1.0);
        let h0_glob = a_glob.spmm(&x_glob);

        // ---- per-community data -------------------------------------------
        let mut communities = Vec::with_capacity(m);
        for ci in 0..m {
            let mem = &part.members[ci];
            let size = mem.len();
            let mut cblocks = HashMap::new();
            let mut cblocks_t = HashMap::new();
            let mut boundary_to = HashMap::new();
            let mut boundary_from = HashMap::new();
            for r in blocks.neighbors[ci].iter().copied().chain([ci]) {
                if let Some(b) = blocks.block(ci, r) {
                    let bt = b.transpose();
                    if r != ci {
                        boundary_to.insert(r, bt.distinct_cols());
                        boundary_from.insert(r, b.distinct_cols());
                    }
                    cblocks.insert(r, b.pad_to(n_pad, n_pad));
                    cblocks_t.insert(r, bt.pad_to(n_pad, n_pad));
                }
            }
            let x = ds.features.gather_rows(mem).pad_rows(n_pad);
            let mut y = Matrix::zeros(n_pad, classes);
            let mut train_mask = vec![0.0f32; n_pad];
            for (li, &g) in mem.iter().enumerate() {
                y.set(li, ds.labels[g], 1.0);
                train_mask[li] = ds.train_mask[g];
            }
            communities.push(Community {
                size,
                neighbors: blocks.neighbors[ci].clone(),
                blocks: cblocks,
                blocks_t: cblocks_t,
                boundary_to,
                boundary_from,
                x,
                y,
                train_mask,
                row_offset: offsets[ci],
            });
        }

        // Static W_1 aggregates: community rows of H0, padded.
        let h0_comm: Vec<Matrix> = communities
            .iter()
            .map(|c| {
                h0_glob
                    .slice_rows(c.row_offset, c.row_offset + c.size)
                    .pad_rows(n_pad)
            })
            .collect();

        Ok(Workspace {
            hp: hp.clone(),
            m,
            n_pad,
            n_glob,
            n,
            dims,
            layers,
            a_glob,
            x_glob,
            h0_glob,
            h0_comm,
            y_glob,
            train_mask_glob,
            test_mask_glob,
            labels,
            denom,
            communities,
            partition: part,
            edgecut,
        })
    }

    /// Gather per-community padded matrices into a global padded matrix
    /// (strips community padding; global padding rows stay zero).
    pub fn gather(&self, per_comm: &[Matrix]) -> Matrix {
        assert_eq!(per_comm.len(), self.m);
        let cols = per_comm[0].cols();
        let mut out = Matrix::zeros(self.n_glob, cols);
        for (c, mat) in self.communities.iter().zip(per_comm) {
            assert_eq!(mat.cols(), cols);
            let src = mat.slice_rows(0, c.size);
            out.copy_rows_from(&src, c.row_offset);
        }
        out
    }

    /// Scatter a global padded matrix into per-community padded matrices.
    pub fn scatter(&self, global: &Matrix) -> Vec<Matrix> {
        self.communities
            .iter()
            .map(|c| {
                global
                    .slice_rows(c.row_offset, c.row_offset + c.size)
                    .pad_rows(self.n_pad)
            })
            .collect()
    }

    /// Bytes on the wire for a community-padded matrix message (only real
    /// rows are shipped — padding is reconstructed at the receiver).
    pub fn msg_bytes(&self, real_rows: usize, cols: usize) -> u64 {
        // wire: u32 tag + u32 from + u32 to + u32 layer + u64 len + payload
        24 + (real_rows * cols * 4) as u64
    }

    /// Artifact signature helpers bound to this workspace's shapes.
    pub fn sig_nab(&self, entry: &str, n: usize, a: usize, b: usize) -> String {
        format!("{entry}__n{n}_a{a}_b{b}")
    }
    pub fn sig_nc(&self, entry: &str, n: usize, c: usize) -> String {
        format!("{entry}__n{n}_c{c}")
    }
    pub fn sig_fista(&self, n: usize) -> String {
        format!(
            "zl_fista__n{n}_c{}_steps{}",
            self.dims[self.layers], self.hp.fista_steps
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::fixtures;

    fn ws(m: usize) -> Workspace {
        let ds = fixtures::caveman(24, 3);
        let mut hp = HyperParams::for_dataset("caveman");
        hp.communities = m;
        hp.hidden = 8;
        Workspace::build(&ds, &hp, Method::Metis).unwrap()
    }

    #[test]
    fn builds_serial_and_parallel() {
        for m in [1, 2, 3] {
            let w = ws(m);
            assert_eq!(w.m, m);
            assert_eq!(w.n, 48);
            assert_eq!(w.n_glob, 128);
            assert_eq!(w.communities.len(), m);
            let total: usize = w.communities.iter().map(|c| c.size).sum();
            assert_eq!(total, 48);
        }
    }

    #[test]
    fn from_partition_accepts_valid_rejects_mismatched() {
        let ds = fixtures::caveman(24, 3);
        let mut hp = HyperParams::for_dataset("caveman");
        hp.communities = 3;
        hp.hidden = 8;
        let part = crate::partition::partition(&ds.graph, 3, Method::Louvain, hp.seed);
        let w = Workspace::from_partition(&ds, &hp, part.clone()).unwrap();
        assert_eq!(w.m, 3);
        assert_eq!(w.partition.assignment, part.assignment);
        // Community-count mismatch must be rejected, not mis-shaped.
        hp.communities = 4;
        assert!(Workspace::from_partition(&ds, &hp, part).is_err());
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let w = ws(3);
        let mut per = Vec::new();
        for (ci, c) in w.communities.iter().enumerate() {
            let mut m = Matrix::zeros(w.n_pad, 4);
            for r in 0..c.size {
                for col in 0..4 {
                    m.set(r, col, (ci * 1000 + r * 4 + col) as f32);
                }
            }
            per.push(m);
        }
        let global = w.gather(&per);
        let back = w.scatter(&global);
        for (a, b) in per.iter().zip(&back) {
            assert_eq!(a.data(), b.data());
        }
        // Global padding rows are zero.
        for r in w.n..w.n_glob {
            assert!(global.row(r).iter().all(|&v| v == 0.0));
        }
    }

    #[test]
    fn blockwise_product_matches_global_product() {
        // Σ_r Ã_{m,r} Z_r == rows_m(Ã Z) — invariant 4, with padding.
        let w = ws(3);
        let mut rng = crate::util::rng::Rng::new(5);
        let zg = Matrix::glorot(w.n_glob, 5, &mut rng);
        // Zero the padding rows as the coordinator maintains.
        let mut zg_clean = Matrix::zeros(w.n_glob, 5);
        zg_clean.copy_rows_from(&zg.slice_rows(0, w.n), 0);
        let z_comm = w.scatter(&zg_clean);
        let full = w.a_glob.spmm(&zg_clean);
        for (ci, c) in w.communities.iter().enumerate() {
            let mut acc = Matrix::zeros(w.n_pad, 5);
            for (&r, blk) in &c.blocks {
                acc.add_assign(&blk.spmm(&z_comm[r]));
            }
            let expect = full
                .slice_rows(c.row_offset, c.row_offset + c.size)
                .pad_rows(w.n_pad);
            assert!(
                acc.max_abs_diff(&expect) < 1e-5,
                "community {ci} block product mismatch"
            );
        }
    }

    #[test]
    fn transposed_blocks_are_transposes() {
        let w = ws(3);
        for c in &w.communities {
            for (r, b) in &c.blocks {
                let bt = &c.blocks_t[r];
                assert!(bt.to_dense().max_abs_diff(&b.to_dense().transpose()) < 1e-7);
            }
        }
    }

    #[test]
    fn denom_is_global_train_count() {
        let w = ws(3);
        let per_comm: f32 = w
            .communities
            .iter()
            .map(|c| c.train_mask.iter().sum::<f32>())
            .sum();
        assert_eq!(w.denom, per_comm);
        assert!(w.denom > 0.0);
    }

    #[test]
    fn neighbor_blocks_present_and_symmetric() {
        let w = ws(3);
        for (ci, c) in w.communities.iter().enumerate() {
            assert!(c.blocks.contains_key(&ci), "diagonal block missing");
            for &r in &c.neighbors {
                assert!(c.blocks.contains_key(&r));
                assert!(
                    w.communities[r].neighbors.contains(&ci),
                    "neighbor sets not symmetric"
                );
            }
        }
    }
}
