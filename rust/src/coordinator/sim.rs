//! `SimTransport` — a single-threaded, fully deterministic simulation of
//! the distributed transport with seeded, injectable faults.
//!
//! The simulated "network" runs every [`WorkerCore`] in-process and
//! synchronously: a leader `send` delivers the frame (faults permitting)
//! and immediately runs the worker state machine; replies queue in a
//! per-host outbox that the leader's `recv` drains. Because there is
//! exactly one thread, the sequence of transport events — and therefore
//! every RNG draw in the fault sampler — is a pure function of the
//! [`FaultPlan`], so chaos tests replay bit for bit from a seed instead
//! of racing `kill -9` against wall clocks.
//!
//! Fault semantics mirror what the TCP transport can actually observe.
//! TCP never *loses* an in-order frame — a link either delivers or dies —
//! so a `Drop` (and a `Delay` past the heartbeat deadline) marks the
//! host's link as lost: nothing flows either way any more, and the
//! leader's next `recv` reports the host dead, exactly as a heartbeat
//! timeout would. `Dup` models at-least-once delivery after retries: the
//! frame arrives twice, which [`WorkerCore`]'s reply cache and the
//! leader's stale-frame skipping must absorb without changing results.
//! `crash_at` kills a host the instant it receives `SetW` for the given
//! epoch — the deterministic equivalent of `kill -9` at an epoch
//! boundary.

use super::admm::AdmmTrainer;
use super::transport::{
    dead, CoreAction, ElasticCfg, TResult, Transport, WorkerCore, TAG_SET_W,
};
use super::workspace::Workspace;
use crate::metrics::RunReport;
use crate::runtime::ComputeBackend;
use crate::util::rng::Rng;
use crate::util::wire::Dec;
use std::collections::VecDeque;
use std::sync::Arc;

/// What happens to one frame crossing the simulated network.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Fault {
    None,
    Drop,
    Dup,
    Delay,
}

/// Seeded fault schedule for one simulated run.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    /// Seed for the probabilistic sampler (and the delay-severity draw).
    pub seed: u64,
    /// `(host, epoch)`: crash the host the moment it receives `SetW` for
    /// that epoch — deterministic agent death at an epoch boundary.
    pub crash_at: Vec<(usize, u64)>,
    /// Per-frame fault probabilities (all 0.0 = no sampling, no RNG use).
    pub p_drop: f64,
    pub p_dup: f64,
    pub p_delay: f64,
    /// Scheduled faults by global frame index (deterministic scenarios
    /// that need an exact fault site rather than a probability).
    pub drop_frames: Vec<u64>,
    pub dup_frames: Vec<u64>,
    pub delay_frames: Vec<u64>,
}

impl FaultPlan {
    /// A plan that injects nothing — the no-fault baseline.
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    /// Crash `host` when it receives `SetW` for `epoch`.
    pub fn crash(host: usize, epoch: u64) -> FaultPlan {
        FaultPlan {
            crash_at: vec![(host, epoch)],
            ..FaultPlan::default()
        }
    }
}

/// Observability counters for assertions in chaos tests.
#[derive(Clone, Debug, Default)]
pub struct SimStats {
    /// Frames that entered the fault sampler (both directions).
    pub frames: u64,
    pub dropped: u64,
    pub duplicated: u64,
    pub delayed: u64,
    /// Links declared lost (drops + fatal delays).
    pub links_lost: u64,
    /// Hosts that crashed (scheduled or via internal error).
    pub crashes: u64,
}

struct SimHost {
    core: Option<WorkerCore>,
    outbox: VecDeque<Arc<Vec<u8>>>,
    /// A frame on this link was lost: the connection is stalled and the
    /// leader's next recv on it reports the host dead.
    lost: bool,
    fenced: bool,
}

/// The deterministic fault-injecting transport. Same [`Transport`] trait,
/// same [`WorkerCore`] state machine as TCP/channel — only the network is
/// simulated.
pub struct SimTransport {
    hosts: Vec<SimHost>,
    plan: FaultPlan,
    rng: Rng,
    frame_idx: u64,
    bytes: u64,
    pub stats: SimStats,
}

impl SimTransport {
    pub fn new(
        ws: Arc<Workspace>,
        backend: Arc<dyn ComputeBackend>,
        plan: FaultPlan,
    ) -> SimTransport {
        let gs = super::admm::AdmmOptions::for_mode(ws.m).gauss_seidel;
        let hosts = (0..ws.m)
            .map(|_| SimHost {
                core: Some(WorkerCore::new(ws.clone(), backend.clone(), gs)),
                outbox: VecDeque::new(),
                lost: false,
                fenced: false,
            })
            .collect();
        let rng = Rng::new(plan.seed);
        SimTransport {
            hosts,
            plan,
            rng,
            frame_idx: 0,
            bytes: 0,
            stats: SimStats::default(),
        }
    }

    /// Sample the fate of the next frame. Scheduled frame indices win;
    /// otherwise the probabilistic sampler runs (consuming RNG only when
    /// any probability is non-zero, so a fault-free plan burns no state).
    fn sample(&mut self) -> Fault {
        let idx = self.frame_idx;
        self.frame_idx += 1;
        self.stats.frames += 1;
        if self.plan.drop_frames.contains(&idx) {
            return Fault::Drop;
        }
        if self.plan.dup_frames.contains(&idx) {
            return Fault::Dup;
        }
        if self.plan.delay_frames.contains(&idx) {
            return Fault::Delay;
        }
        let (pd, pu, pl) = (self.plan.p_drop, self.plan.p_dup, self.plan.p_delay);
        if pd <= 0.0 && pu <= 0.0 && pl <= 0.0 {
            return Fault::None;
        }
        let x = self.rng.gen_f64();
        if x < pd {
            Fault::Drop
        } else if x < pd + pu {
            Fault::Dup
        } else if x < pd + pu + pl {
            Fault::Delay
        } else {
            Fault::None
        }
    }

    /// A delayed frame either lands inside the heartbeat deadline
    /// (harmless jitter) or beyond it (the link is declared dead) —
    /// drawn deterministically from the plan's RNG stream.
    fn delay_is_fatal(&mut self) -> bool {
        self.stats.delayed += 1;
        self.rng.gen_bool(0.5)
    }

    fn lose_link(&mut self, host: usize) {
        self.stats.links_lost += 1;
        self.hosts[host].lost = true;
        self.hosts[host].outbox.clear();
    }

    /// Deliver a leader→worker frame to the host's state machine,
    /// honouring crash-at-epoch and fault-sampling any replies.
    fn process(&mut self, host: usize, frame: &[u8]) {
        if frame.first() == Some(&TAG_SET_W) {
            let mut d = Dec::new(&frame[1..]);
            if let Ok(epoch) = d.u64() {
                if self
                    .plan
                    .crash_at
                    .iter()
                    .any(|&(ch, ce)| ch == host && ce == epoch)
                    && self.hosts[host].core.take().is_some()
                {
                    self.stats.crashes += 1;
                    log::debug!("sim: host {host} crashed receiving SetW for epoch {epoch}");
                    return;
                }
            }
        }
        let outcome = {
            let Some(core) = self.hosts[host].core.as_mut() else {
                return;
            };
            core.handle(frame)
        };
        match outcome {
            Ok(CoreAction::None) => {}
            Ok(CoreAction::Reply(reply)) => match self.sample() {
                Fault::None => self.hosts[host].outbox.push_back(reply),
                Fault::Drop => {
                    self.stats.dropped += 1;
                    self.lose_link(host);
                }
                Fault::Dup => {
                    self.stats.duplicated += 1;
                    self.hosts[host].outbox.push_back(reply.clone());
                    self.hosts[host].outbox.push_back(reply);
                }
                Fault::Delay => {
                    if self.delay_is_fatal() {
                        self.lose_link(host);
                    } else {
                        self.hosts[host].outbox.push_back(reply);
                    }
                }
            },
            Ok(CoreAction::Shutdown) => {
                self.hosts[host].core = None;
            }
            Err(e) => {
                log::warn!("sim: host {host} state machine failed: {e:#}");
                self.hosts[host].core = None;
                self.stats.crashes += 1;
            }
        }
    }
}

impl Transport for SimTransport {
    fn hosts(&self) -> usize {
        self.hosts.len()
    }

    fn label(&self) -> &'static str {
        "sim"
    }

    fn send(&mut self, host: usize, frame: &[u8]) -> TResult<()> {
        if self.hosts[host].fenced {
            return dead(host, "fenced");
        }
        if self.hosts[host].lost {
            return dead(host, "link lost");
        }
        self.bytes += frame.len() as u64 + 4;
        match self.sample() {
            Fault::None => self.process(host, frame),
            Fault::Drop => {
                // The write "succeeds" (like a TCP send into a stalled
                // peer's buffer); the loss surfaces at the next recv.
                self.stats.dropped += 1;
                self.lose_link(host);
            }
            Fault::Dup => {
                self.stats.duplicated += 1;
                self.process(host, frame);
                self.process(host, frame);
            }
            Fault::Delay => {
                if self.delay_is_fatal() {
                    self.lose_link(host);
                } else {
                    self.process(host, frame);
                }
            }
        }
        Ok(())
    }

    fn recv(&mut self, host: usize) -> TResult<Vec<u8>> {
        if self.hosts[host].fenced {
            return dead(host, "fenced");
        }
        if self.hosts[host].lost {
            return dead(host, "link lost (heartbeat deadline exceeded)");
        }
        match self.hosts[host].outbox.pop_front() {
            Some(f) => {
                self.bytes += f.len() as u64 + 4;
                Ok(Arc::try_unwrap(f).unwrap_or_else(|a| (*a).clone()))
            }
            None => {
                if self.hosts[host].core.is_none() {
                    dead(host, "host crashed")
                } else {
                    dead(host, "timed out waiting for frame")
                }
            }
        }
    }

    fn fence(&mut self, host: usize) {
        self.hosts[host].fenced = true;
        self.hosts[host].outbox.clear();
    }

    fn bytes(&self) -> u64 {
        self.bytes
    }
}

/// Run elastic ADMM training over a fresh [`SimTransport`] built from the
/// trainer's own workspace/backend; returns the run report plus the
/// simulation's fault counters.
pub fn run_sim_training(
    trainer: &mut AdmmTrainer,
    plan: FaultPlan,
    cfg: &ElasticCfg,
) -> anyhow::Result<(RunReport, SimStats)> {
    let mut t = SimTransport::new(trainer.ws.clone(), trainer.backend.clone(), plan);
    let report = super::transport::run_elastic_training(trainer, &mut t, cfg)?;
    Ok((report, t.stats.clone()))
}
