//! Virtual-time accounting for the distributed schedule.
//!
//! This container has a single CPU core, so agents cannot physically run
//! concurrently; the paper's testbed gave each agent its own execution
//! resources. We therefore measure each agent's compute individually and
//! account parallel phases at their critical path (`max` over agents),
//! serial phases as the sum — exactly what an M-machine deployment of the
//! same binaries would observe, minus OS jitter. Communication is priced
//! by a configurable link model over *measured* message bytes (the wire
//! encoding the TCP transport actually ships). DESIGN.md §2 documents the
//! substitution; the real wall-clock is always reported alongside.

use std::time::Instant;

/// Bandwidth/latency model of the inter-agent links.
#[derive(Clone, Copy, Debug)]
pub struct LinkModel {
    /// Bandwidth in bytes/second.
    pub bytes_per_sec: f64,
    /// Per-message latency in seconds.
    pub latency: f64,
}

impl LinkModel {
    /// `mbps` megabit/s, `lat_us` microseconds (defaults mimic the paper's
    /// LAN: 1 Gbit/s, 100 µs).
    pub fn new(mbps: f64, lat_us: f64) -> LinkModel {
        LinkModel {
            bytes_per_sec: mbps * 1e6 / 8.0,
            latency: lat_us * 1e-6,
        }
    }

    /// Transfer time of one message.
    pub fn msg_secs(&self, bytes: u64) -> f64 {
        self.latency + bytes as f64 / self.bytes_per_sec
    }
}

/// Accumulates one epoch's virtual time, split the way Table 3 reports it.
#[derive(Clone, Debug, Default)]
pub struct EpochClock {
    /// Virtual training (compute) seconds.
    pub train: f64,
    /// Virtual communication seconds.
    pub comm: f64,
    /// Bytes shipped this epoch.
    pub bytes: u64,
    /// Messages shipped this epoch.
    pub messages: u64,
}

impl EpochClock {
    /// Add a parallel compute phase: agents ran "concurrently", wall time
    /// is the slowest agent (critical path).
    pub fn parallel_phase(&mut self, per_agent_secs: &[f64]) {
        self.train += per_agent_secs.iter().copied().fold(0.0, f64::max);
    }

    /// Add a serial compute phase (sum of parts).
    pub fn serial_phase(&mut self, secs: f64) {
        self.train += secs;
    }

    /// Peer-to-peer exchange: every agent transmits its own messages
    /// sequentially, agents in parallel ⇒ max over senders.
    pub fn exchange(&mut self, link: &LinkModel, per_sender_bytes: &[Vec<u64>]) {
        let mut worst = 0.0f64;
        for msgs in per_sender_bytes {
            let mut t = 0.0;
            for &b in msgs {
                t += link.msg_secs(b);
                self.bytes += b;
                self.messages += 1;
            }
            worst = worst.max(t);
        }
        self.comm += worst;
    }

    /// Star gather/broadcast through the leader: the leader's NIC is the
    /// bottleneck, messages serialise there.
    pub fn star(&mut self, link: &LinkModel, msgs: &[u64]) {
        for &b in msgs {
            self.comm += link.msg_secs(b);
            self.bytes += b;
            self.messages += 1;
        }
    }

    pub fn total(&self) -> f64 {
        self.train + self.comm
    }
}

/// Measure a closure's wall time, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn link_model_math() {
        let link = LinkModel::new(1000.0, 100.0); // 1 Gbit/s, 100 µs
        // 1 MB at 125 MB/s = 8 ms, + 0.1 ms latency.
        let t = link.msg_secs(1_000_000);
        assert!((t - 0.0081).abs() < 1e-4, "{t}");
    }

    #[test]
    fn parallel_phase_takes_max_serial_takes_sum() {
        let mut c = EpochClock::default();
        c.parallel_phase(&[0.1, 0.5, 0.2]);
        assert!((c.train - 0.5).abs() < 1e-12);
        c.serial_phase(0.3);
        assert!((c.train - 0.8).abs() < 1e-12);
    }

    #[test]
    fn exchange_is_max_over_senders_star_is_sum() {
        let link = LinkModel {
            bytes_per_sec: 1000.0,
            latency: 0.0,
        };
        let mut c = EpochClock::default();
        c.exchange(&link, &[vec![1000, 1000], vec![500]]);
        assert!((c.comm - 2.0).abs() < 1e-9); // max(2.0, 0.5)
        assert_eq!(c.bytes, 2500);
        assert_eq!(c.messages, 3);
        let mut s = EpochClock::default();
        s.star(&link, &[1000, 1000, 500]);
        assert!((s.comm - 2.5).abs() < 1e-9);
    }
}
