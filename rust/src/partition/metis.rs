//! Multilevel k-way partitioner (METIS-style, [Karypis & Kumar '98]).
//!
//! Three phases, as in the paper the paper cites:
//!
//! 1. **Coarsening** — repeated heavy-edge matching (HEM): match each
//!    vertex to its heaviest-edge unmatched neighbor, contract matched
//!    pairs, summing vertex and edge weights. Stops when the graph is small
//!    or stops shrinking.
//! 2. **Initial partitioning** — greedy graph growing on the coarsest
//!    graph: BFS-grow each part from a random seed until it reaches its
//!    weight share.
//! 3. **Uncoarsening + refinement** — project the assignment back level by
//!    level, running Fiduccia–Mattheyses-style boundary refinement passes
//!    (positive-gain moves under a balance cap) at every level.

use super::Partition;
use crate::graph::Graph;
use crate::util::rng::Rng;

/// Allowed imbalance: max part weight <= (1 + EPS) * ideal.
const EPS: f64 = 0.10;
/// Stop coarsening below this many vertices (scaled by m).
const COARSEST: usize = 64;
/// FM passes per level.
const FM_PASSES: usize = 4;

/// Weighted graph used across coarsening levels.
#[derive(Clone, Debug)]
struct WGraph {
    /// Vertex weights (number of original vertices inside).
    vwgt: Vec<u64>,
    /// adj[u] = (neighbor, edge weight), neighbor-sorted, no self loops.
    adj: Vec<Vec<(u32, u64)>>,
}

impl WGraph {
    fn n(&self) -> usize {
        self.vwgt.len()
    }
    fn total_weight(&self) -> u64 {
        self.vwgt.iter().sum()
    }

    fn from_graph(g: &Graph) -> WGraph {
        WGraph {
            vwgt: vec![1; g.n()],
            adj: (0..g.n())
                .map(|u| g.neighbors(u).iter().map(|&v| (v, 1u64)).collect())
                .collect(),
        }
    }
}

/// Entry point: multilevel k-way partition.
///
/// The effective part count is clamped to `g.n()`: asking for more parts
/// than nodes yields one singleton community per node (so the returned
/// [`Partition`] has `min(m, n)` parts, none of them empty).
pub fn partition(g: &Graph, m: usize, rng: &mut Rng) -> Partition {
    if m == 1 {
        return Partition::from_assignment(1, vec![0; g.n()]);
    }
    if m >= g.n() {
        // Degenerate: one node per community. `v % m` here would leave
        // parts n..m empty; clamping the part count keeps the invariant
        // that every returned community is non-empty.
        let assignment: Vec<usize> = (0..g.n()).collect();
        return Partition::from_assignment(g.n(), assignment);
    }

    // ---- phase 1: coarsen -------------------------------------------------
    let mut levels: Vec<WGraph> = vec![WGraph::from_graph(g)];
    let mut maps: Vec<Vec<u32>> = Vec::new(); // maps[l][v_fine] = v_coarse
    let stop = COARSEST.max(8 * m);
    loop {
        let cur = levels.last().unwrap();
        if cur.n() <= stop {
            break;
        }
        let (coarse, map) = contract(cur, rng);
        // Stalled (e.g. star graphs): stop coarsening.
        if coarse.n() as f64 > cur.n() as f64 * 0.95 {
            break;
        }
        levels.push(coarse);
        maps.push(map);
    }

    // ---- phase 2: initial partition on coarsest ---------------------------
    let coarsest = levels.last().unwrap();
    let mut assignment = greedy_growing(coarsest, m, rng);
    balance_fix(coarsest, m, &mut assignment);
    fm_refine(coarsest, m, &mut assignment, rng);

    // ---- phase 3: uncoarsen + refine ---------------------------------------
    for l in (0..maps.len()).rev() {
        let fine = &levels[l];
        let map = &maps[l];
        let mut fine_assignment = vec![0usize; fine.n()];
        for v in 0..fine.n() {
            fine_assignment[v] = assignment[map[v] as usize];
        }
        assignment = fine_assignment;
        fm_refine(fine, m, &mut assignment, rng);
    }

    ensure_nonempty(&levels[0], m, &mut assignment);
    Partition::from_assignment(m, assignment)
}

/// Heavy-edge matching contraction. Returns the coarse graph and the
/// fine→coarse vertex map.
fn contract(g: &WGraph, rng: &mut Rng) -> (WGraph, Vec<u32>) {
    let n = g.n();
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let mut mate = vec![u32::MAX; n];
    for &u in &order {
        if mate[u] != u32::MAX {
            continue;
        }
        // Heaviest unmatched neighbor.
        let mut best: Option<(u32, u64)> = None;
        for &(v, w) in &g.adj[u] {
            if mate[v as usize] == u32::MAX
                && best.map(|(_, bw)| w > bw).unwrap_or(true)
            {
                best = Some((v, w));
            }
        }
        match best {
            Some((v, _)) => {
                mate[u] = v;
                mate[v as usize] = u as u32;
            }
            None => mate[u] = u as u32, // matched with itself
        }
    }

    // Assign coarse ids (pair gets one id).
    let mut map = vec![u32::MAX; n];
    let mut next = 0u32;
    for u in 0..n {
        if map[u] != u32::MAX {
            continue;
        }
        let v = mate[u] as usize;
        map[u] = next;
        map[v] = next; // v == u for self-matched
        next += 1;
    }
    let cn = next as usize;

    // Build coarse adjacency by accumulating weights.
    let mut vwgt = vec![0u64; cn];
    for u in 0..n {
        vwgt[map[u] as usize] += g.vwgt[u];
    }
    let mut adj: Vec<Vec<(u32, u64)>> = vec![Vec::new(); cn];
    // Single pass over fine edges.
    let mut acc: Vec<std::collections::HashMap<u32, u64>> =
        vec![std::collections::HashMap::new(); cn];
    for u in 0..n {
        let cu = map[u];
        for &(v, w) in &g.adj[u] {
            let cv = map[v as usize];
            if cu != cv {
                *acc[cu as usize].entry(cv).or_insert(0) += w;
            }
        }
    }
    for (cu, h) in acc.into_iter().enumerate() {
        // Each fine edge (u,v) with map[u]=cu, map[v]=cv contributes its
        // weight to acc[cu][cv] exactly once (from the u side), and to
        // acc[cv][cu] once (from the v side) — so `acc` is already the
        // symmetric inter-cluster weight, no halving needed.
        let mut row: Vec<(u32, u64)> = h.into_iter().collect();
        row.sort_unstable_by_key(|&(v, _)| v);
        adj[cu] = row;
    }

    (WGraph { vwgt, adj }, map)
}

/// Greedy graph growing initial partition over vertex weights.
fn greedy_growing(g: &WGraph, m: usize, rng: &mut Rng) -> Vec<usize> {
    let n = g.n();
    let total = g.total_weight();
    let unassigned = usize::MAX;
    let mut assignment = vec![unassigned; n];
    let mut remaining_weight = total;
    let mut remaining_nodes = n;
    // Monotone cursor over unassigned vertices for disconnected-component
    // jumps: vertices below it are all assigned, so each jump resumes the
    // scan where the last one stopped instead of rescanning from 0
    // (O(n²) on fragmented graphs otherwise).
    let mut cursor = 0usize;

    for part in 0..m {
        if remaining_nodes == 0 {
            break;
        }
        let target = remaining_weight / (m - part) as u64;
        // Random unassigned seed.
        let seed = {
            let mut s = rng.gen_range(n);
            while assignment[s] != unassigned {
                s = (s + 1) % n;
            }
            s
        };
        let mut grown = 0u64;
        let mut queue = std::collections::VecDeque::new();
        queue.push_back(seed);
        while grown < target.max(1) && remaining_nodes > 0 {
            let u = match queue.pop_front() {
                Some(u) => u,
                None => {
                    // Disconnected: jump to the next unassigned vertex.
                    while cursor < n && assignment[cursor] != unassigned {
                        cursor += 1;
                    }
                    if cursor == n {
                        break;
                    }
                    cursor
                }
            };
            if assignment[u] != unassigned {
                continue;
            }
            assignment[u] = part;
            grown += g.vwgt[u];
            remaining_nodes -= 1;
            for &(v, _) in &g.adj[u] {
                if assignment[v as usize] == unassigned {
                    queue.push_back(v as usize);
                }
            }
        }
        remaining_weight -= grown.min(remaining_weight);
    }
    // Leftovers -> lightest part.
    let mut weights = vec![0u64; m];
    for v in 0..n {
        if assignment[v] != unassigned {
            weights[assignment[v]] += g.vwgt[v];
        }
    }
    for v in 0..n {
        if assignment[v] == unassigned {
            let lightest = (0..m).min_by_key(|&p| weights[p]).unwrap();
            assignment[v] = lightest;
            weights[lightest] += g.vwgt[v];
        }
    }
    assignment
}

/// Upper bound on balance passes — a safety net only. Each executed move
/// strictly decreases Σ w_p², so the loop reaches a fixed point on its
/// own; in practice two or three passes suffice.
const BALANCE_PASSES: usize = 64;

/// Move vertices from overweight parts to lighter ones until the balance
/// cap holds (used right after initial partitioning).
///
/// Iterates to a fixed point: a single pass (trying only the lightest
/// part per vertex, never revisiting) can exit with parts still above the
/// `(1 + EPS)` cap. A move is taken whenever *any* part both stays under
/// cap and is strictly lighter than the donor after the move (so Σ w_p²
/// strictly decreases and the loop terminates). A part never gives up its
/// last vertex.
fn balance_fix(g: &WGraph, m: usize, assignment: &mut [usize]) {
    let total = g.total_weight();
    let cap = (((1.0 + EPS) * total as f64) / m as f64).ceil() as u64;
    let mut weights = vec![0u64; m];
    let mut counts = vec![0u64; m];
    for v in 0..g.n() {
        weights[assignment[v]] += g.vwgt[v];
        counts[assignment[v]] += 1;
    }
    for _pass in 0..BALANCE_PASSES {
        let mut moved = false;
        for v in 0..g.n() {
            let p = assignment[v];
            if weights[p] <= cap || counts[p] <= 1 {
                continue;
            }
            let w = g.vwgt[v];
            // Lightest part the vertex fits into that the move improves.
            let dest = (0..m)
                .filter(|&q| q != p && weights[q] + w <= cap && weights[q] + w < weights[p])
                .min_by_key(|&q| weights[q]);
            if let Some(q) = dest {
                weights[p] -= w;
                counts[p] -= 1;
                weights[q] += w;
                counts[q] += 1;
                assignment[v] = q;
                moved = true;
            }
        }
        if !moved || (0..m).all(|p| weights[p] <= cap) {
            break;
        }
    }
    // Post-condition (debug builds): every part is under cap, or the loop
    // is at a genuine fixed point — no vertex of an overweight part fits
    // into any other part with room left under the cap.
    #[cfg(debug_assertions)]
    for p in 0..m {
        if weights[p] > cap {
            let movable = (0..g.n()).any(|v| {
                assignment[v] == p
                    && counts[p] > 1
                    && (0..m).any(|q| {
                        q != p
                            && weights[q] + g.vwgt[v] <= cap
                            && weights[q] + g.vwgt[v] < weights[p]
                    })
            });
            debug_assert!(
                !movable,
                "balance_fix exited over cap with a legal move still available (part {p}: {} > {cap})",
                weights[p]
            );
        }
    }
}

/// FM-style boundary refinement: greedy positive-gain moves with a balance
/// cap, several passes.
fn fm_refine(g: &WGraph, m: usize, assignment: &mut [usize], rng: &mut Rng) {
    let n = g.n();
    let total = g.total_weight();
    let cap = (((1.0 + EPS) * total as f64) / m as f64).ceil() as u64;
    let mut weights = vec![0u64; m];
    let mut counts = vec![0u64; m];
    for v in 0..n {
        weights[assignment[v]] += g.vwgt[v];
        counts[assignment[v]] += 1;
    }

    let mut order: Vec<usize> = (0..n).collect();
    for _pass in 0..FM_PASSES {
        rng.shuffle(&mut order);
        let mut moved = 0usize;
        // Per-vertex connectivity to each part (computed lazily).
        let mut conn = vec![0u64; m];
        for &u in &order {
            let from = assignment[u];
            if counts[from] <= 1 {
                continue; // never empty a part
            }
            // Connectivity of u to each part.
            for c in conn.iter_mut() {
                *c = 0;
            }
            for &(v, w) in &g.adj[u] {
                conn[assignment[v as usize]] += w;
            }
            let internal = conn[from];
            // Best external part by gain, then by resulting balance.
            let mut best: Option<(usize, i64)> = None;
            for p in 0..m {
                if p == from {
                    continue;
                }
                if weights[p] + g.vwgt[u] > cap {
                    continue;
                }
                let gain = conn[p] as i64 - internal as i64;
                let better = match best {
                    None => gain > 0 || (gain == 0 && weights[p] + g.vwgt[u] < weights[from]),
                    Some((bp, bg)) => gain > bg || (gain == bg && weights[p] < weights[bp]),
                };
                if better && (gain > 0 || (gain == 0 && weights[p] + g.vwgt[u] < weights[from])) {
                    best = Some((p, gain));
                }
            }
            if let Some((p, _)) = best {
                weights[from] -= g.vwgt[u];
                counts[from] -= 1;
                weights[p] += g.vwgt[u];
                counts[p] += 1;
                assignment[u] = p;
                moved += 1;
            }
        }
        if moved == 0 {
            break;
        }
    }
}

/// Final guard: no empty communities on the finest level.
fn ensure_nonempty(g: &WGraph, m: usize, assignment: &mut [usize]) {
    let n = g.n();
    let mut counts = vec![0usize; m];
    for v in 0..n {
        counts[assignment[v]] += 1;
    }
    for p in 0..m {
        while counts[p] == 0 {
            // Take a vertex from the largest part (lowest degree first to
            // minimise cut damage).
            let donor = (0..m).max_by_key(|&q| counts[q]).unwrap();
            let v = (0..n)
                .filter(|&v| assignment[v] == donor)
                .min_by_key(|&v| g.adj[v].len())
                .expect("donor part is non-empty");
            assignment[v] = p;
            counts[donor] -= 1;
            counts[p] += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::fixtures;

    #[test]
    fn coarsening_preserves_total_weight() {
        let ds = fixtures::caveman(40, 2);
        let wg = WGraph::from_graph(&ds.graph);
        let mut rng = Rng::new(8);
        let (coarse, map) = contract(&wg, &mut rng);
        assert_eq!(coarse.total_weight(), wg.total_weight());
        assert!(coarse.n() < wg.n());
        assert!(map.iter().all(|&c| (c as usize) < coarse.n()));
        // Coarse adjacency is symmetric.
        for u in 0..coarse.n() {
            for &(v, w) in &coarse.adj[u] {
                let back = coarse.adj[v as usize]
                    .iter()
                    .find(|&&(x, _)| x as usize == u)
                    .map(|&(_, bw)| bw);
                assert_eq!(back, Some(w), "asymmetric coarse edge {u}-{v}");
            }
        }
    }

    #[test]
    fn contraction_preserves_cut_weights() {
        // The cut between two caves survives contraction as total weight.
        let ds = fixtures::caveman(20, 6);
        let wg = WGraph::from_graph(&ds.graph);
        let mut rng = Rng::new(9);
        let (coarse, map) = contract(&wg, &mut rng);
        // Sum of all edge weights is preserved (each fine edge either
        // contracts away into a vertex or contributes its weight to a
        // coarse edge).
        let fine_total: u64 = wg.adj.iter().flatten().map(|&(_, w)| w).sum::<u64>() / 2;
        let coarse_total: u64 =
            coarse.adj.iter().flatten().map(|&(_, w)| w).sum::<u64>() / 2;
        let contracted: u64 = {
            // Edges whose endpoints share a coarse vertex.
            let mut t = 0;
            for u in 0..wg.n() {
                for &(v, w) in &wg.adj[u] {
                    if map[u] == map[v as usize] && u < v as usize {
                        t += w;
                    }
                }
            }
            t
        };
        assert_eq!(fine_total, coarse_total + contracted);
    }

    #[test]
    fn degenerate_m_clamps_to_n_with_no_empty_parts() {
        // Regression for the `v % m` path: m > n used to leave parts
        // n..m empty (zero-node communities downstream).
        let ds = fixtures::caveman(5, 1);
        let n = ds.n();
        for m in [n, n + 1, 2 * n, 10 * n] {
            let mut rng = Rng::new(4);
            let p = partition(&ds.graph, m, &mut rng);
            assert_eq!(p.m(), n, "m={m} should clamp to n={n}");
            assert!(p.members.iter().all(|mem| mem.len() == 1));
            p.validate(n);
        }
    }

    #[test]
    fn balance_fix_reaches_cap_fixed_point() {
        // Start from a maximally unbalanced assignment (everything in part
        // 0). The old single-pass version could exit with parts over cap;
        // the fixed-point version must balance any uniformly-weighted
        // graph to the cap exactly.
        for (n, m) in [(40usize, 4usize), (33, 5), (64, 3), (7, 7)] {
            let edges: Vec<(usize, usize)> = (0..n - 1).map(|i| (i, i + 1)).collect();
            let g = crate::graph::Graph::from_edges(n, &edges);
            let wg = WGraph::from_graph(&g);
            let mut assignment = vec![0usize; n];
            balance_fix(&wg, m, &mut assignment);
            let cap = (((1.0 + EPS) * n as f64) / m as f64).ceil() as u64;
            let mut weights = vec![0u64; m];
            for v in 0..n {
                weights[assignment[v]] += wg.vwgt[v];
            }
            for (p, &w) in weights.iter().enumerate() {
                assert!(
                    w <= cap,
                    "n={n} m={m}: part {p} weight {w} > cap {cap} ({weights:?})"
                );
            }
        }
    }

    #[test]
    fn balance_fix_respects_heavy_vertices() {
        // A coarse vertex heavier than the cap cannot be balanced away;
        // the fixed point must still hold for all other parts and never
        // lose vertices.
        let g = crate::graph::Graph::from_edges(4, &[(0, 1), (1, 2), (2, 3)]);
        let mut wg = WGraph::from_graph(&g);
        wg.vwgt = vec![100, 1, 1, 1]; // total 103, m=2 → cap 57
        let mut assignment = vec![0usize, 0, 0, 0];
        balance_fix(&wg, 2, &mut assignment);
        assert_eq!(assignment.len(), 4);
        assert_eq!(assignment[0], 0, "heavy vertex should stay put");
        // The three light vertices all fit under the cap in part 1.
        assert!(assignment[1..].iter().all(|&p| p == 1));
    }

    #[test]
    fn greedy_growing_handles_fragmented_graphs() {
        // Edgeless graph: every vertex is its own component, so growth
        // jumps through the disconnected path for nearly every vertex.
        let g = crate::graph::Graph::from_edges(200, &[]);
        let wg = WGraph::from_graph(&g);
        let mut rng = Rng::new(12);
        let a = greedy_growing(&wg, 4, &mut rng);
        assert_eq!(a.len(), 200);
        assert!(a.iter().all(|&p| p < 4));
        let mut counts = vec![0usize; 4];
        for &p in &a {
            counts[p] += 1;
        }
        assert!(counts.iter().all(|&c| c > 0), "{counts:?}");
    }

    #[test]
    fn greedy_growing_assigns_everything() {
        let ds = fixtures::caveman(30, 3);
        let wg = WGraph::from_graph(&ds.graph);
        let mut rng = Rng::new(10);
        let a = greedy_growing(&wg, 3, &mut rng);
        assert!(a.iter().all(|&p| p < 3));
        assert_eq!(a.len(), 60);
    }

    #[test]
    fn refinement_never_violates_validity() {
        let ds = fixtures::caveman(25, 4);
        let wg = WGraph::from_graph(&ds.graph);
        let mut rng = Rng::new(11);
        let mut a = greedy_growing(&wg, 4, &mut rng);
        let before: Vec<usize> = a.clone();
        fm_refine(&wg, 4, &mut a, &mut rng);
        assert_eq!(a.len(), before.len());
        assert!(a.iter().all(|&p| p < 4));
        // Refinement does not increase the cut.
        let cut = |asg: &[usize]| -> u64 {
            let mut t = 0;
            for u in 0..wg.n() {
                for &(v, w) in &wg.adj[u] {
                    if asg[u] != asg[v as usize] && u < v as usize {
                        t += w;
                    }
                }
            }
            t
        };
        assert!(cut(&a) <= cut(&before));
    }
}
