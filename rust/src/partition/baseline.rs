//! Baseline partitioners: random and BFS-order chunking.
//!
//! Used as ablation comparators for the METIS-style partitioner: both are
//! valid (disjoint, balanced, non-empty) but make no attempt to minimise
//! edge-cut, so they bound the communication cost from above (random) and
//! give a cheap locality heuristic (BFS).

use super::Partition;
use crate::graph::Graph;
use crate::util::rng::Rng;

/// Exactly-balanced random partition: shuffle nodes, deal round-robin.
/// Like the metis path, `m > n` clamps to `n` singleton parts so no
/// community is ever empty.
pub fn random(g: &Graph, m: usize, rng: &mut Rng) -> Partition {
    let parts = m.min(g.n()).max(1);
    let mut order: Vec<usize> = (0..g.n()).collect();
    rng.shuffle(&mut order);
    let mut assignment = vec![0usize; g.n()];
    for (i, &v) in order.iter().enumerate() {
        assignment[v] = i % parts;
    }
    Partition::from_assignment(parts, assignment)
}

/// BFS partition: traverse from a random root (restarting on disconnected
/// components) and cut the traversal order into `m` near-equal chunks.
/// Contiguous BFS regions tend to share edges, so this captures *some*
/// locality without any optimisation.
pub fn bfs(g: &Graph, m: usize, rng: &mut Rng) -> Partition {
    let n = g.n();
    let mut order = Vec::with_capacity(n);
    let mut visited = vec![false; n];
    let mut queue = std::collections::VecDeque::new();
    let root = rng.gen_range(n);
    queue.push_back(root);
    visited[root] = true;
    let mut next_unvisited = 0usize;
    while order.len() < n {
        while let Some(u) = queue.pop_front() {
            order.push(u);
            for &v in g.neighbors(u) {
                if !visited[v as usize] {
                    visited[v as usize] = true;
                    queue.push_back(v as usize);
                }
            }
        }
        // Restart on another component if needed.
        while next_unvisited < n && visited[next_unvisited] {
            next_unvisited += 1;
        }
        if next_unvisited < n {
            visited[next_unvisited] = true;
            queue.push_back(next_unvisited);
        }
    }
    chunk_order(&order, m)
}

/// Cut a node order into `m` near-equal contiguous chunks. `m > n`
/// clamps to `n` singleton chunks (an `n/m == 0` base would otherwise
/// produce empty communities).
pub(super) fn chunk_order(order: &[usize], m: usize) -> Partition {
    let n = order.len();
    let m = m.min(n).max(1);
    let mut assignment = vec![0usize; n];
    // Sizes differ by at most 1: first (n % m) chunks get one extra.
    let base = n / m;
    let extra = n % m;
    let mut pos = 0;
    for c in 0..m {
        let len = base + usize::from(c < extra);
        for &v in &order[pos..pos + len] {
            assignment[v] = c;
        }
        pos += len;
    }
    Partition::from_assignment(m, assignment)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::fixtures;

    #[test]
    fn random_is_exactly_balanced() {
        let ds = fixtures::caveman(25, 1); // n = 50
        let mut rng = Rng::new(2);
        let p = random(&ds.graph, 4, &mut rng);
        let sizes = p.sizes();
        assert_eq!(sizes.iter().sum::<usize>(), 50);
        assert!(sizes.iter().all(|&s| s == 12 || s == 13), "{sizes:?}");
    }

    #[test]
    fn bfs_covers_disconnected_graphs() {
        // Two components, no edges between.
        let g = Graph::from_edges(6, &[(0, 1), (1, 2), (3, 4), (4, 5)]);
        let mut rng = Rng::new(3);
        let p = bfs(&g, 2, &mut rng);
        p.validate(6);
        assert_eq!(p.sizes(), vec![3, 3]);
    }

    #[test]
    fn bfs_beats_random_on_caveman() {
        let ds = fixtures::caveman(30, 4);
        let mut rng = Rng::new(4);
        let pb = bfs(&ds.graph, 2, &mut rng);
        let pr = random(&ds.graph, 2, &mut rng);
        assert!(pb.edgecut(&ds.graph) < pr.edgecut(&ds.graph));
    }

    #[test]
    fn baselines_clamp_m_to_n_with_no_empty_community() {
        // Regression: `bfs` (via chunk_order's n/m == 0 base) and
        // `random` (i % m) used to emit empty communities when m > n.
        // Both now clamp to n singleton parts, matching metis.
        let g = Graph::from_edges(5, &[(0, 1), (1, 2), (2, 3), (3, 4)]);
        let n = 5;
        for m in [n - 1, n, n + 1, 2 * n, 10 * n] {
            for (name, p) in [
                ("random", random(&g, m, &mut Rng::new(7))),
                ("bfs", bfs(&g, m, &mut Rng::new(7))),
            ] {
                p.validate(n);
                assert_eq!(p.m(), m.min(n), "{name} m={m}: wrong part count");
                assert!(
                    p.members.iter().all(|mem| !mem.is_empty()),
                    "{name} m={m}: empty community, sizes={:?}",
                    p.sizes()
                );
            }
        }
    }

    #[test]
    fn chunk_sizes_differ_by_at_most_one() {
        let order: Vec<usize> = (0..17).collect();
        let p = chunk_order(&order, 5);
        let sizes = p.sizes();
        assert_eq!(sizes, vec![4, 4, 3, 3, 3]);
    }
}
