//! Graph partitioning: the paper partitions `G` into `M` dense communities
//! with METIS [Karypis & Kumar '98]. We implement the same multilevel
//! scheme from scratch ([`metis`]) plus [`baseline`] partitioners (random,
//! BFS) used as ablations — the paper's speedup depends on low edge-cut
//! (small `p`/`s` messages), which the ablation bench quantifies.

pub mod baseline;
pub mod metis;

use crate::graph::Graph;
use crate::util::pool::Runtime;
use crate::util::rng::Rng;

/// Which partitioner to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Multilevel heavy-edge-matching + FM refinement (METIS-style).
    Metis,
    /// Uniform random assignment (worst-case communication).
    Random,
    /// BFS traversal chunks (cheap locality).
    Bfs,
    /// Louvain modularity maximization mapped onto `m` balanced agents
    /// ([`crate::community`]). Deterministic; ignores the seed.
    Louvain,
    /// Label propagation mapped onto `m` balanced agents. Deterministic;
    /// ignores the seed.
    Lpa,
}

impl Method {
    pub fn parse(s: &str) -> Option<Method> {
        match s {
            "metis" => Some(Method::Metis),
            "random" => Some(Method::Random),
            "bfs" => Some(Method::Bfs),
            "louvain" => Some(Method::Louvain),
            "lpa" => Some(Method::Lpa),
            _ => None,
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            Method::Metis => "metis",
            Method::Random => "random",
            Method::Bfs => "bfs",
            Method::Louvain => "louvain",
            Method::Lpa => "lpa",
        }
    }
    /// Every method, for sweeps and property tests.
    pub const ALL: [Method; 5] = [
        Method::Metis,
        Method::Random,
        Method::Bfs,
        Method::Louvain,
        Method::Lpa,
    ];
}

/// A disjoint cover of the graph's nodes into `m` communities.
#[derive(Clone, Debug)]
pub struct Partition {
    /// members[c] = sorted global node ids of community c.
    pub members: Vec<Vec<usize>>,
    /// assignment[v] = community of node v.
    pub assignment: Vec<usize>,
}

impl Partition {
    pub fn from_assignment(m: usize, assignment: Vec<usize>) -> Partition {
        let mut members = vec![Vec::new(); m];
        for (v, &c) in assignment.iter().enumerate() {
            assert!(c < m, "assignment out of range");
            members[c].push(v);
        }
        Partition {
            members,
            assignment,
        }
    }

    pub fn m(&self) -> usize {
        self.members.len()
    }

    pub fn sizes(&self) -> Vec<usize> {
        self.members.iter().map(|v| v.len()).collect()
    }

    /// Number of edges crossing communities.
    pub fn edgecut(&self, g: &Graph) -> usize {
        g.edges()
            .iter()
            .filter(|&&(u, v)| self.assignment[u as usize] != self.assignment[v as usize])
            .count()
    }

    /// max community size / ideal size — 1.0 is perfectly balanced.
    pub fn imbalance(&self, n: usize) -> f64 {
        let ideal = n as f64 / self.m() as f64;
        self.sizes()
            .iter()
            .map(|&s| s as f64 / ideal)
            .fold(0.0, f64::max)
    }

    /// Validate the partition is a disjoint cover (panics otherwise).
    pub fn validate(&self, n: usize) {
        assert_eq!(self.assignment.len(), n);
        let total: usize = self.sizes().iter().sum();
        assert_eq!(total, n, "partition does not cover all nodes");
        for (c, mem) in self.members.iter().enumerate() {
            for &v in mem {
                assert_eq!(self.assignment[v], c);
            }
        }
    }
}

/// Partition `g` into `m` communities with the chosen method.
///
/// All methods guarantee: disjoint cover, every community non-empty
/// (for m <= n), and a max community size within
/// [`crate::config::community_cap`].
pub fn partition(g: &Graph, m: usize, method: Method, seed: u64) -> Partition {
    partition_with_runtime(g, m, method, seed, None)
}

/// [`partition`] with an optional shared [`Runtime`] for the detectors
/// that parallelise (louvain, lpa). Results are bitwise identical with
/// and without a runtime, at any thread count.
pub fn partition_with_runtime(
    g: &Graph,
    m: usize,
    method: Method,
    seed: u64,
    rt: Option<&Runtime>,
) -> Partition {
    assert!(m >= 1, "need at least one community");
    assert!(m <= g.n(), "more communities than nodes");
    let mut rng = Rng::new(seed);
    let p = match method {
        Method::Metis => metis::partition(g, m, &mut rng),
        Method::Random => baseline::random(g, m, &mut rng),
        Method::Bfs => baseline::bfs(g, m, &mut rng),
        Method::Louvain => crate::community::louvain_partition(g, m, rt),
        Method::Lpa => crate::community::lpa_partition(g, m, rt),
    };
    p.validate(g.n());
    debug_assert!(p.members.iter().all(|mem| !mem.is_empty()));
    p
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::fixtures;
    use crate::data::synth;
    use crate::prop_assert;
    use crate::util::proplite;

    #[test]
    fn all_methods_produce_valid_partitions() {
        let ds = fixtures::caveman(20, 3);
        for method in Method::ALL {
            for m in [1, 2, 3, 5] {
                let p = partition(&ds.graph, m, method, 7);
                p.validate(ds.n());
                assert_eq!(p.m(), m);
                assert!(
                    p.members.iter().all(|mem| !mem.is_empty()),
                    "{:?} m={m} produced an empty community",
                    method
                );
            }
        }
    }

    #[test]
    fn metis_beats_random_on_planted_communities() {
        let ds = synth::generate(&synth::AMAZON_PHOTO, 0.08, 5);
        let pm = partition(&ds.graph, 3, Method::Metis, 1);
        let pr = partition(&ds.graph, 3, Method::Random, 1);
        let cm = pm.edgecut(&ds.graph);
        let cr = pr.edgecut(&ds.graph);
        assert!(
            (cm as f64) < 0.7 * cr as f64,
            "metis edgecut {cm} not clearly better than random {cr}"
        );
    }

    #[test]
    fn metis_recovers_caveman_split() {
        let ds = fixtures::caveman(16, 9);
        let p = partition(&ds.graph, 2, Method::Metis, 3);
        // Each community should be (almost) one cave: edgecut ~= bridges (2).
        let cut = p.edgecut(&ds.graph);
        assert!(cut <= 4, "caveman edgecut {cut} too high");
        assert!(p.imbalance(ds.n()) <= 1.15);
    }

    #[test]
    fn partition_property_disjoint_cover_balanced() {
        proplite::check("partition-valid", 25, 0xBEEF, |g| {
            let n = g.usize_in(6, 80).max(6);
            let edges = g.edges(n, 0.15);
            let graph = crate::graph::Graph::from_edges(n, &edges);
            let m = g.usize_in(1, 4).clamp(1, n);
            for method in Method::ALL {
                let p = partition(&graph, m, method, g.rng.next_u64());
                let total: usize = p.sizes().iter().sum();
                prop_assert!(total == n, "{method:?}: cover {total} != {n}");
                prop_assert!(
                    p.members.iter().all(|mem| !mem.is_empty()),
                    "{method:?}: empty community (n={n}, m={m})"
                );
                prop_assert!(
                    p.imbalance(n) <= 1.5 + 1e-9,
                    "{method:?}: imbalance {} too high (n={n}, m={m})",
                    p.imbalance(n)
                );
            }
            Ok(())
        });
    }

    #[test]
    fn metis_property_no_empty_community_for_any_m() {
        // Regression for the `v % m` degenerate path: for every m —
        // including m == n and m > n — the returned partition must have
        // no empty community (empty communities become zero-node
        // Workspace blocks downstream). The part count clamps to n.
        proplite::check("metis-no-empty", 20, 0xD06, |g| {
            let n = g.usize_in(4, 60).max(4);
            let edges = g.edges(n, 0.12);
            let graph = crate::graph::Graph::from_edges(n, &edges);
            for m in [1, (n / 2).max(1), n, 2 * n] {
                let mut rng = crate::util::rng::Rng::new(g.rng.next_u64());
                let p = metis::partition(&graph, m, &mut rng);
                prop_assert!(
                    p.m() == m.min(n),
                    "m={m}: got {} parts, want {}",
                    p.m(),
                    m.min(n)
                );
                prop_assert!(
                    p.members.iter().all(|mem| !mem.is_empty()),
                    "m={m}: empty community (n={n}, sizes={:?})",
                    p.sizes()
                );
                let total: usize = p.sizes().iter().sum();
                prop_assert!(total == n, "m={m}: cover {total} != {n}");
            }
            Ok(())
        });
    }

    #[test]
    fn every_method_is_deterministic_across_thread_counts() {
        // n = 765 > the detectors' parallel threshold, so louvain/lpa
        // really dispatch on the runtime at t > 1. The contract is
        // bitwise-identical assignments for a fixed seed at any thread
        // count (metis/random/bfs ignore the runtime entirely).
        let ds = synth::generate(&synth::AMAZON_PHOTO, 0.1, 11);
        for method in Method::ALL {
            let serial = partition(&ds.graph, 4, method, 42);
            for t in [1usize, 2, 8] {
                let rt = crate::util::pool::Runtime::new(t);
                let p = partition_with_runtime(&ds.graph, 4, method, 42, Some(&rt));
                assert_eq!(
                    serial.assignment, p.assignment,
                    "{method:?} diverged at {t} threads"
                );
            }
        }
    }

    #[test]
    fn m_equals_one_is_trivial() {
        let ds = fixtures::fig1();
        let p = partition(&ds.graph, 1, Method::Metis, 0);
        assert_eq!(p.sizes(), vec![9]);
        assert_eq!(p.edgecut(&ds.graph), 0);
    }
}
