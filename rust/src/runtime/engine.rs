//! The artifact execution engine.

use crate::tensor::Matrix;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::Instant;

/// A pre-marshalled input buffer: build once with [`Engine::prepare`],
/// reuse across calls (e.g. the fixed aggregate `S_m` across τ-backtracking
/// trials — saves a multi-MB host copy per trial).
pub struct Prepared {
    buf: xla::PjRtBuffer,
    rows: usize,
    cols: usize,
}

// SAFETY: a PjRtBuffer is immutable once created; see the Engine
// thread-safety note.
unsafe impl Send for Prepared {}
unsafe impl Sync for Prepared {}

/// An input operand for an artifact call.
pub enum In<'a> {
    /// Dense matrix (n × m) — row-major f32, marshalled per call.
    Mat(&'a Matrix),
    /// Pre-marshalled matrix (see [`Prepared`]).
    Prep(&'a Prepared),
    /// Rank-1 vector (masks).
    Vec(&'a [f32]),
    /// Rank-0 scalar (ν, ρ, θ, denom, ...).
    Scalar(f32),
}

/// Owned-or-borrowed device buffer so `execute_b` sees one slice type.
///
/// Inputs are marshalled straight to PJRT buffers (`execute_b`), NOT
/// through `Literal` + `execute`: the C wrapper of `execute` leaks the
/// per-argument device copies (~input size per call — measured in
/// examples/leak_probe.rs), while buffers we create ourselves are freed by
/// `PjRtBuffer`'s Drop.
enum BufRef<'a> {
    Own(xla::PjRtBuffer),
    Ref(&'a xla::PjRtBuffer),
}

impl<'a> std::borrow::Borrow<xla::PjRtBuffer> for BufRef<'a> {
    fn borrow(&self) -> &xla::PjRtBuffer {
        match self {
            BufRef::Own(b) => b,
            BufRef::Ref(b) => b,
        }
    }
}

impl<'a> In<'a> {
    fn shape(&self) -> Vec<usize> {
        match self {
            In::Mat(m) => vec![m.rows(), m.cols()],
            In::Prep(p) => vec![p.rows, p.cols],
            In::Vec(v) => vec![v.len()],
            In::Scalar(_) => vec![],
        }
    }

    fn to_buffer(&self, client: &xla::PjRtClient) -> Result<BufRef<'a>> {
        Ok(match self {
            In::Mat(m) => BufRef::Own(client.buffer_from_host_buffer(
                m.data(),
                &[m.rows(), m.cols()],
                None,
            )?),
            In::Prep(p) => BufRef::Ref(&p.buf),
            In::Vec(v) => BufRef::Own(client.buffer_from_host_buffer(v, &[v.len()], None)?),
            In::Scalar(s) => {
                BufRef::Own(client.buffer_from_host_buffer(&[*s], &[], None)?)
            }
        })
    }
}

/// An output operand from an artifact call.
#[derive(Debug)]
pub enum Out {
    Mat(Matrix),
    Scalar(f32),
}

impl Out {
    pub fn into_mat(self) -> Matrix {
        match self {
            Out::Mat(m) => m,
            Out::Scalar(s) => panic!("expected matrix output, got scalar {s}"),
        }
    }
    pub fn scalar(&self) -> f32 {
        match self {
            Out::Scalar(s) => *s,
            Out::Mat(m) => panic!("expected scalar output, got {:?}", m.shape()),
        }
    }
}

/// Manifest entry for one artifact.
#[derive(Clone, Debug)]
struct ArtifactMeta {
    file: PathBuf,
    input_shapes: Vec<Vec<usize>>,
    num_outputs: usize,
}

/// Per-artifact execution statistics.
#[derive(Clone, Debug, Default)]
pub struct ExecStats {
    pub calls: u64,
    /// Seconds spent inside PJRT execute (compute).
    pub exec_secs: f64,
    /// Seconds spent converting literals (host marshalling).
    pub marshal_secs: f64,
    /// Seconds spent compiling (once per signature).
    pub compile_secs: f64,
}

struct Compiled {
    exe: xla::PjRtLoadedExecutable,
}

/// The engine. Create once, share via `Arc` across agent threads.
///
/// # Thread safety
/// The `xla` crate does not mark its wrappers `Send`/`Sync` (raw pointers),
/// but the underlying PJRT CPU client and loaded executables are
/// thread-safe by the PJRT C API contract (XLA's `PjRtClient`/
/// `PjRtLoadedExecutable` are documented thread-safe; the CPU plugin
/// serialises internally where needed). Executions from multiple agent
/// threads are therefore sound; compilation is guarded by our own mutex.
pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: HashMap<String, ArtifactMeta>,
    cache: Mutex<HashMap<String, &'static Compiled>>,
    stats: Mutex<HashMap<String, ExecStats>>,
}

// SAFETY: see the struct-level docs — PJRT CPU client & executables are
// thread-safe; all interior mutability on the Rust side is mutex-guarded.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    /// Load the manifest from an artifacts directory (`make artifacts`).
    pub fn load(dir: &Path) -> Result<Engine> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                manifest_path.display()
            )
        })?;
        let json = Json::parse(&text).context("parsing manifest.json")?;
        let mut manifest = HashMap::new();
        for a in json
            .get("artifacts")
            .as_arr()
            .ok_or_else(|| anyhow!("manifest missing 'artifacts'"))?
        {
            let sig = a
                .get("sig")
                .as_str()
                .ok_or_else(|| anyhow!("artifact missing 'sig'"))?
                .to_string();
            let file = dir.join(
                a.get("file")
                    .as_str()
                    .ok_or_else(|| anyhow!("artifact missing 'file'"))?,
            );
            let input_shapes = a
                .get("input_shapes")
                .as_arr()
                .ok_or_else(|| anyhow!("artifact missing 'input_shapes'"))?
                .iter()
                .map(|s| {
                    s.as_arr()
                        .map(|dims| {
                            dims.iter().filter_map(|d| d.as_usize()).collect::<Vec<_>>()
                        })
                        .ok_or_else(|| anyhow!("bad input shape"))
                })
                .collect::<Result<Vec<_>>>()?;
            let num_outputs = a
                .get("num_outputs")
                .as_usize()
                .ok_or_else(|| anyhow!("artifact missing 'num_outputs'"))?;
            manifest.insert(
                sig,
                ArtifactMeta {
                    file,
                    input_shapes,
                    num_outputs,
                },
            );
        }
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        log::info!(
            "runtime: {} artifacts indexed from {} (platform={})",
            manifest.len(),
            dir.display(),
            client.platform_name()
        );
        Ok(Engine {
            client,
            dir: dir.to_path_buf(),
            manifest,
            cache: Mutex::new(HashMap::new()),
            stats: Mutex::new(HashMap::new()),
        })
    }

    /// The default artifacts directory, honouring `CGCN_ARTIFACTS`.
    pub fn default_dir() -> PathBuf {
        std::env::var("CGCN_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// True if an artifacts directory with a manifest exists (used by
    /// integration tests to skip gracefully before `make artifacts`).
    pub fn available() -> bool {
        Self::default_dir().join("manifest.json").exists()
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }

    pub fn has(&self, sig: &str) -> bool {
        self.manifest.contains_key(sig)
    }

    /// Number of indexed artifacts.
    pub fn len(&self) -> usize {
        self.manifest.len()
    }

    pub fn is_empty(&self) -> bool {
        self.manifest.is_empty()
    }

    fn compiled(&self, sig: &str) -> Result<&'static Compiled> {
        {
            let cache = self.cache.lock().unwrap();
            if let Some(c) = cache.get(sig) {
                return Ok(c);
            }
        }
        let meta = self
            .manifest
            .get(sig)
            .ok_or_else(|| {
                anyhow!(
                    "artifact '{sig}' not in manifest ({} entries) — regenerate with \
                     `cgcn plan` + `make artifacts`",
                    self.manifest.len()
                )
            })?
            .clone();
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            meta.file
                .to_str()
                .ok_or_else(|| anyhow!("non-utf8 artifact path"))?,
        )
        .with_context(|| format!("parsing HLO text {}", meta.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {sig}"))?;
        let compile_secs = t0.elapsed().as_secs_f64();
        // Executables live for the program lifetime; leaking gives us a
        // &'static we can hand out without self-referential lifetimes.
        let compiled: &'static Compiled = Box::leak(Box::new(Compiled { exe }));
        self.stats
            .lock()
            .unwrap()
            .entry(sig.to_string())
            .or_default()
            .compile_secs += compile_secs;
        let mut cache = self.cache.lock().unwrap();
        Ok(*cache.entry(sig.to_string()).or_insert(compiled))
    }

    /// Pre-marshal a matrix into a reusable input buffer.
    pub fn prepare(&self, m: &Matrix) -> Result<Prepared> {
        Ok(Prepared {
            buf: self
                .client
                .buffer_from_host_buffer(m.data(), &[m.rows(), m.cols()], None)?,
            rows: m.rows(),
            cols: m.cols(),
        })
    }

    /// Pre-compile a set of signatures (startup, off the timed path).
    pub fn warmup(&self, sigs: &[String]) -> Result<()> {
        for sig in sigs {
            self.compiled(sig)?;
        }
        Ok(())
    }

    /// Execute an artifact. Input shapes are validated against the
    /// manifest; outputs are decomposed from the result tuple into
    /// matrices / scalars by rank.
    pub fn exec(&self, sig: &str, inputs: &[In]) -> Result<Vec<Out>> {
        let meta = self
            .manifest
            .get(sig)
            .ok_or_else(|| anyhow!("artifact '{sig}' not in manifest"))?;
        if inputs.len() != meta.input_shapes.len() {
            bail!(
                "{sig}: expected {} inputs, got {}",
                meta.input_shapes.len(),
                inputs.len()
            );
        }
        for (i, (input, expect)) in inputs.iter().zip(&meta.input_shapes).enumerate() {
            let got = input.shape();
            if &got != expect {
                bail!("{sig}: input {i} shape {got:?} != expected {expect:?}");
            }
        }
        let exe = self.compiled(sig)?;

        let t0 = Instant::now();
        let buffers = inputs
            .iter()
            .map(|i| i.to_buffer(&self.client))
            .collect::<Result<Vec<_>>>()?;
        let t1 = Instant::now();
        let result = exe
            .exe
            .execute_b(&buffers)
            .with_context(|| format!("executing {sig}"))?[0][0]
            .to_literal_sync()?;
        let t2 = Instant::now();

        // aot.py lowers with return_tuple=True: always a tuple.
        let parts = result.to_tuple()?;
        if parts.len() != meta.num_outputs {
            bail!(
                "{sig}: expected {} outputs, got {}",
                meta.num_outputs,
                parts.len()
            );
        }
        let mut outs = Vec::with_capacity(parts.len());
        for part in parts {
            let shape = part.array_shape()?;
            let dims = shape.dims();
            match dims.len() {
                0 => outs.push(Out::Scalar(part.to_vec::<f32>()?[0])),
                2 => {
                    let (r, c) = (dims[0] as usize, dims[1] as usize);
                    outs.push(Out::Mat(Matrix::from_vec(r, c, part.to_vec::<f32>()?)));
                }
                other => bail!("{sig}: unsupported output rank {other}"),
            }
        }
        let t3 = Instant::now();

        let mut stats = self.stats.lock().unwrap();
        let s = stats.entry(sig.to_string()).or_default();
        s.calls += 1;
        s.exec_secs += (t2 - t1).as_secs_f64();
        s.marshal_secs += (t1 - t0).as_secs_f64() + (t3 - t2).as_secs_f64();
        Ok(outs)
    }

    /// Snapshot of accumulated per-artifact stats.
    pub fn stats(&self) -> Vec<(String, ExecStats)> {
        let mut v: Vec<_> = self
            .stats
            .lock()
            .unwrap()
            .iter()
            .map(|(k, s)| (k.clone(), s.clone()))
            .collect();
        v.sort_by(|a, b| b.1.exec_secs.total_cmp(&a.1.exec_secs));
        v
    }

    /// Total seconds spent in PJRT execute across all artifacts.
    pub fn total_exec_secs(&self) -> f64 {
        self.stats
            .lock()
            .unwrap()
            .values()
            .map(|s| s.exec_secs)
            .sum()
    }

    /// Reset accumulated stats (between benchmark phases).
    pub fn reset_stats(&self) {
        self.stats.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Full end-to-end engine tests live in rust/tests/ (they need
    // `make artifacts`); here we test manifest parsing and input checks
    // against a tiny fake manifest.

    fn fake_dir() -> PathBuf {
        let dir = std::env::temp_dir().join(format!("cgcn_engine_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn missing_manifest_is_a_clear_error() {
        let dir = fake_dir().join("nope");
        let err = match Engine::load(&dir) {
            Err(e) => e,
            Ok(_) => panic!("expected load to fail"),
        };
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn manifest_parses_and_validates_inputs() {
        let dir = fake_dir();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"artifacts": [{"sig": "t__n8_a4_b2", "file": "t.hlo.txt",
                "input_shapes": [[8, 4], [4, 2], []], "num_outputs": 1}]}"#,
        )
        .unwrap();
        let engine = Engine::load(&dir).unwrap();
        assert!(engine.has("t__n8_a4_b2"));
        assert!(!engine.has("other"));
        // Wrong arity.
        let m = Matrix::zeros(8, 4);
        let err = engine.exec("t__n8_a4_b2", &[In::Mat(&m)]).unwrap_err();
        assert!(format!("{err}").contains("expected 3 inputs"));
        // Wrong shape.
        let w = Matrix::zeros(3, 2);
        let err = engine
            .exec(
                "t__n8_a4_b2",
                &[In::Mat(&m), In::Mat(&w), In::Scalar(1.0)],
            )
            .unwrap_err();
        assert!(format!("{err}").contains("shape"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
