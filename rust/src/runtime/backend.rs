//! The `ComputeBackend` trait — every dense kernel the trainers need,
//! decoupled from how it executes.
//!
//! Two implementations:
//!
//! - [`NativeBackend`] — pure Rust, always available. Hot paths (dense
//!   matmul variants, [`Csr::spmm`]) are row-block parallelised through
//!   [`crate::util::pool`] when constructed with > 1 thread; every output
//!   row is produced by the same scalar loop the serial path runs, so
//!   results are bitwise identical at any thread count.
//! - `XlaBackend` (behind `--features xla`) — wraps the PJRT [`Engine`] and
//!   dispatches each call to the AOT-compiled artifact with the matching
//!   shape signature, exactly as the seed trainers did directly.
//!
//! The kernel *semantics* are specified by `python/compile/kernels/ref.py`
//! and `python/compile/model.py`; the native implementations transcribe
//! those definitions (f = ReLU with f'(0) := 0, masked-mean softmax
//! cross-entropy with an explicit global denominator, FISTA with the
//! static 1/(ρ + ½) step). `rust/tests/integration_engine.rs` asserts both
//! backends agree with the host reference ops in [`crate::tensor`].

use crate::graph::Csr;
use crate::tensor::Matrix;
use crate::util::pool::{parallel_row_chunks, resolve_threads};
use anyhow::Result;
use std::sync::Arc;

/// Dense-kernel execution interface shared by the ADMM trainer, the
/// backprop baselines, the Cluster-GCN mini-batch engine, evaluation,
/// the TCP transport workers and the benches. Kernels are shape-agnostic:
/// the mini-batch path drives the same `spmm`/`fwd_relu`/`bp_*` calls
/// with batch-sized operands (|B| rows instead of the padded global row
/// count), which is what makes its memory bound real rather than modeled.
pub trait ComputeBackend: Send + Sync {
    /// Short human-readable backend name for logs.
    fn name(&self) -> &'static str;

    /// `X @ W` — projections `V = Z W`, logits, Q assembly.
    fn mm_nn(&self, x: &Matrix, w: &Matrix) -> Result<Matrix>;

    /// `Xᵀ @ Y` — weight gradients `gW = Z_{l-1}ᵀ (Ã R)`.
    fn mm_tn(&self, x: &Matrix, y: &Matrix) -> Result<Matrix>;

    /// `Y @ Wᵀ` — Z-gradient back-projection `(Ã R) Wᵀ`.
    fn mm_bt(&self, y: &Matrix, w: &Matrix) -> Result<Matrix>;

    /// `ReLU(H @ W)` — forward hidden layer (eval, init, baselines).
    fn fwd_relu(&self, h: &Matrix, w: &Matrix) -> Result<Matrix>;

    /// ν-coupling at a ReLU layer: returns
    /// `(ν/2 ‖f(pre) − Zt‖², ν (f(pre) − Zt) ⊙ f'(pre))`.
    fn hidden_residual(&self, pre: &Matrix, zt: &Matrix, nu: f32) -> Result<(f32, Matrix)>;

    /// Value-only hidden coupling (τ/θ backtracking).
    fn hidden_phi(&self, pre: &Matrix, zt: &Matrix, nu: f32) -> Result<f32>;

    /// Augmented-Lagrangian coupling at the linear output layer: returns
    /// `(⟨U, Zt − pre⟩ + ρ/2 ‖Zt − pre‖², −(U + ρ(Zt − pre)))`.
    fn out_residual(&self, pre: &Matrix, zt: &Matrix, u: &Matrix, rho: f32)
        -> Result<(f32, Matrix)>;

    /// Value-only output coupling (τ/θ backtracking).
    fn out_phi(&self, pre: &Matrix, zt: &Matrix, u: &Matrix, rho: f32) -> Result<f32>;

    /// Value-only proximal term `ν/2 ‖Z − f(Pin)‖²` (θ backtracking).
    fn z_prox_val(&self, z: &Matrix, pin: &Matrix, nu: f32) -> Result<f32>;

    /// Proximal-gradient combine step (eq. 8/10):
    /// `g = ν(Z − f(Pin)) + Gsum; Z⁺ = Z − g/θ`. Returns
    /// `(Z⁺, ν/2 ‖Z − f(Pin)‖², ‖g‖²)`.
    fn z_combine(
        &self,
        z: &Matrix,
        pin: &Matrix,
        gsum: &Matrix,
        nu: f32,
        theta: f32,
    ) -> Result<(Matrix, f32, f32)>;

    /// Z_L subproblem (eq. 7): `steps` FISTA iterations on
    /// `R(Z, Y) + ⟨U, Z − Q⟩ + ρ/2 ‖Z − Q‖²` from warm start `z0`, with
    /// the static step `1/(ρ + ½)`. Returns `(Z⁺, risk at Z⁺)`.
    #[allow(clippy::too_many_arguments)]
    fn zl_fista(
        &self,
        q: &Matrix,
        u: &Matrix,
        y: &Matrix,
        mask: &[f32],
        z0: &Matrix,
        rho: f32,
        denom: f32,
        steps: usize,
    ) -> Result<(Matrix, f32)>;

    /// Masked mean softmax cross-entropy loss (global `denom`).
    fn xent_loss(&self, logits: &Matrix, y: &Matrix, mask: &[f32], denom: f32) -> Result<f32>;

    /// Baseline loss head: `logits = H1 W2`; returns
    /// `(loss, dW2 = H1ᵀ dL, dH1 = dL W2ᵀ)`.
    fn bp_out_grads(
        &self,
        h1: &Matrix,
        w2: &Matrix,
        y: &Matrix,
        mask: &[f32],
        denom: f32,
    ) -> Result<(f32, Matrix, Matrix)>;

    /// Baseline hidden tail: `dW1 = H0ᵀ (dZ1 ⊙ f'(H0 W1))`.
    fn bp_hidden_grads(&self, h0: &Matrix, w1: &Matrix, dz1: &Matrix) -> Result<Matrix>;

    /// Sparse × dense (the Ã-product hot path). Backends may parallelise;
    /// the default is the serial CSR kernel.
    fn spmm(&self, a: &Csr, x: &Matrix) -> Matrix {
        a.spmm(x)
    }

    /// Pre-compile the given artifact signatures (startup, off the timed
    /// path). No-op for backends that compile nothing.
    fn warmup(&self, _sigs: &[String]) -> Result<()> {
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// NativeBackend
// ---------------------------------------------------------------------------

/// Pure-Rust backend. `threads > 1` row-block parallelises matmul/SpMM via
/// scoped workers once an op's flop count crosses `min_par_flops`
/// (bitwise-identical results either way — see [`crate::util::pool`]).
pub struct NativeBackend {
    threads: usize,
    min_par_flops: usize,
}

/// Below this many flops a dense op runs serially even on a multi-thread
/// backend — thread fork/join (~tens of µs) would dominate.
const MIN_PAR_FLOPS: usize = 1 << 21;

impl NativeBackend {
    /// Single-threaded backend (the deterministic baseline).
    pub fn new() -> NativeBackend {
        NativeBackend {
            threads: 1,
            min_par_flops: MIN_PAR_FLOPS,
        }
    }

    /// Backend with op-level row parallelism on up to `threads` workers
    /// (0 = all available cores).
    pub fn with_threads(threads: usize) -> NativeBackend {
        NativeBackend {
            threads: resolve_threads(threads),
            min_par_flops: MIN_PAR_FLOPS,
        }
    }

    /// Like [`NativeBackend::with_threads`] but with an explicit
    /// parallelism grain (tests/benches use 0 to force the parallel path
    /// on tiny shapes).
    pub fn with_grain(threads: usize, min_par_flops: usize) -> NativeBackend {
        NativeBackend {
            threads: resolve_threads(threads),
            min_par_flops,
        }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Threads to use for an op costing `flops`.
    fn par(&self, flops: usize) -> usize {
        if self.threads > 1 && flops >= self.min_par_flops {
            self.threads
        } else {
            1
        }
    }

    fn matmul(&self, x: &Matrix, w: &Matrix, relu: bool) -> Matrix {
        assert_eq!(
            x.cols(),
            w.rows(),
            "matmul shape mismatch: {}x{} @ {}x{}",
            x.rows(),
            x.cols(),
            w.rows(),
            w.cols()
        );
        let (rows, inner, cols) = (x.rows(), x.cols(), w.cols());
        let mut out = Matrix::zeros(rows, cols);
        let t = self.par(2 * rows * inner * cols);
        parallel_row_chunks(t, rows, cols, out.data_mut(), |lo, hi, chunk| {
            mm_nn_rows(x, w, relu, lo, hi, chunk)
        });
        out
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        NativeBackend::new()
    }
}

/// Rows `lo..hi` of `X @ W` (optionally ReLU'd) into `chunk` — the same
/// ikj loop as [`Matrix::matmul`], so results match it bitwise.
fn mm_nn_rows(x: &Matrix, w: &Matrix, relu: bool, lo: usize, hi: usize, chunk: &mut [f32]) {
    let inner = x.cols();
    let n = w.cols();
    let xd = x.data();
    let wd = w.data();
    for (ri, i) in (lo..hi).enumerate() {
        let orow = &mut chunk[ri * n..(ri + 1) * n];
        for k in 0..inner {
            let a = xd[i * inner + k];
            if a == 0.0 {
                continue;
            }
            let wrow = &wd[k * n..(k + 1) * n];
            for (o, &b) in orow.iter_mut().zip(wrow) {
                *o += a * b;
            }
        }
        if relu {
            for o in orow.iter_mut() {
                if *o < 0.0 {
                    *o = 0.0;
                }
            }
        }
    }
}

/// Rows `lo..hi` of `Xᵀ @ Y` into `chunk` (output is `x.cols() × y.cols()`;
/// bitwise-matches `x.transpose().matmul(&y)`).
fn mm_tn_rows(x: &Matrix, y: &Matrix, lo: usize, hi: usize, chunk: &mut [f32]) {
    let a = x.cols();
    let n = y.cols();
    let xd = x.data();
    let yd = y.data();
    for (ri, i) in (lo..hi).enumerate() {
        let orow = &mut chunk[ri * n..(ri + 1) * n];
        for k in 0..x.rows() {
            let v = xd[k * a + i];
            if v == 0.0 {
                continue;
            }
            let yrow = &yd[k * n..(k + 1) * n];
            for (o, &b) in orow.iter_mut().zip(yrow) {
                *o += v * b;
            }
        }
    }
}

/// Rows `lo..hi` of `Y @ Wᵀ` into `chunk` (output is `y.rows() × w.rows()`).
fn mm_bt_rows(y: &Matrix, w: &Matrix, lo: usize, hi: usize, chunk: &mut [f32]) {
    let k = y.cols();
    let a = w.rows();
    for (ri, i) in (lo..hi).enumerate() {
        let yrow = y.row(i);
        let orow = &mut chunk[ri * a..(ri + 1) * a];
        for (j, o) in orow.iter_mut().enumerate() {
            let wrow = w.row(j);
            let mut acc = 0.0f32;
            for idx in 0..k {
                acc += yrow[idx] * wrow[idx];
            }
            *o = acc;
        }
    }
}

/// Rows `lo..hi` of `A @ X` (CSR × dense) into `chunk` — same inner loop
/// as [`Csr::spmm`].
fn spmm_rows(a: &Csr, x: &Matrix, lo: usize, hi: usize, chunk: &mut [f32]) {
    let k = x.cols();
    let xd = x.data();
    for (ri, r) in (lo..hi).enumerate() {
        let (cols, vals) = a.row(r);
        let orow = &mut chunk[ri * k..(ri + 1) * k];
        for (&c, &v) in cols.iter().zip(vals) {
            let xrow = &xd[c as usize * k..(c as usize + 1) * k];
            for (o, &xv) in orow.iter_mut().zip(xrow) {
                *o += v * xv;
            }
        }
    }
}

/// Masked mean softmax cross-entropy per `kernels/ref.py::softmax_xent_ref`:
/// `loss = Σ_r mask_r (lse_r − ⟨y_r, logits_r⟩) / denom`,
/// `grad = (softmax(logits) − Y) ⊙ mask / denom` (computed only when
/// `grad_out` is given). Loss accumulates in f64 for stability.
fn softmax_xent(
    logits: &Matrix,
    y: &Matrix,
    mask: &[f32],
    denom: f32,
    mut grad_out: Option<&mut Matrix>,
) -> f32 {
    assert_eq!(logits.shape(), y.shape());
    assert_eq!(logits.rows(), mask.len());
    let c = logits.cols();
    let mut loss = 0.0f64;
    let mut p_row = vec![0.0f32; c];
    for r in 0..logits.rows() {
        let row = logits.row(r);
        let mut max = f32::NEG_INFINITY;
        for &x in row {
            if x > max {
                max = x;
            }
        }
        let mut sum = 0.0f32;
        for (pc, &x) in p_row.iter_mut().zip(row) {
            let e = (x - max).exp();
            *pc = e;
            sum += e;
        }
        let inv = 1.0 / sum;
        let lse = sum.ln() + max;
        let m = mask[r];
        if m != 0.0 {
            let mut picked = 0.0f32;
            for (ci, &x) in row.iter().enumerate() {
                picked += y.at(r, ci) * x;
            }
            loss += ((lse - picked) * m) as f64;
        }
        if let Some(g) = grad_out.as_mut() {
            let grow = g.row_mut(r);
            for (ci, gc) in grow.iter_mut().enumerate() {
                *gc = (p_row[ci] * inv - y.at(r, ci)) * m / denom;
            }
        }
    }
    (loss / denom as f64) as f32
}

impl ComputeBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn mm_nn(&self, x: &Matrix, w: &Matrix) -> Result<Matrix> {
        Ok(self.matmul(x, w, false))
    }

    fn mm_tn(&self, x: &Matrix, y: &Matrix) -> Result<Matrix> {
        assert_eq!(x.rows(), y.rows(), "mm_tn row mismatch");
        let (rows, cols) = (x.cols(), y.cols());
        let mut out = Matrix::zeros(rows, cols);
        let t = self.par(2 * rows * cols * x.rows());
        parallel_row_chunks(t, rows, cols, out.data_mut(), |lo, hi, chunk| {
            mm_tn_rows(x, y, lo, hi, chunk)
        });
        Ok(out)
    }

    fn mm_bt(&self, y: &Matrix, w: &Matrix) -> Result<Matrix> {
        assert_eq!(y.cols(), w.cols(), "mm_bt col mismatch");
        let (rows, cols) = (y.rows(), w.rows());
        let mut out = Matrix::zeros(rows, cols);
        let t = self.par(2 * rows * cols * y.cols());
        parallel_row_chunks(t, rows, cols, out.data_mut(), |lo, hi, chunk| {
            mm_bt_rows(y, w, lo, hi, chunk)
        });
        Ok(out)
    }

    fn fwd_relu(&self, h: &Matrix, w: &Matrix) -> Result<Matrix> {
        Ok(self.matmul(h, w, true))
    }

    fn hidden_residual(&self, pre: &Matrix, zt: &Matrix, nu: f32) -> Result<(f32, Matrix)> {
        assert_eq!(pre.shape(), zt.shape());
        let mut r = Matrix::zeros(pre.rows(), pre.cols());
        let mut val = 0.0f64;
        let rd = r.data_mut();
        for (i, (&p, &z)) in pre.data().iter().zip(zt.data()).enumerate() {
            let act = p.max(0.0);
            let d = act - z;
            val += (d as f64) * (d as f64);
            rd[i] = if p > 0.0 { nu * d } else { 0.0 };
        }
        Ok(((0.5 * nu as f64 * val) as f32, r))
    }

    fn hidden_phi(&self, pre: &Matrix, zt: &Matrix, nu: f32) -> Result<f32> {
        assert_eq!(pre.shape(), zt.shape());
        let mut val = 0.0f64;
        for (&p, &z) in pre.data().iter().zip(zt.data()) {
            let d = p.max(0.0) - z;
            val += (d as f64) * (d as f64);
        }
        Ok((0.5 * nu as f64 * val) as f32)
    }

    fn out_residual(
        &self,
        pre: &Matrix,
        zt: &Matrix,
        u: &Matrix,
        rho: f32,
    ) -> Result<(f32, Matrix)> {
        assert_eq!(pre.shape(), zt.shape());
        assert_eq!(pre.shape(), u.shape());
        let mut r = Matrix::zeros(pre.rows(), pre.cols());
        let rd = r.data_mut();
        let mut lin = 0.0f64;
        let mut quad = 0.0f64;
        for (i, ((&p, &z), &uu)) in pre
            .data()
            .iter()
            .zip(zt.data())
            .zip(u.data())
            .enumerate()
        {
            let d = z - p;
            lin += (uu as f64) * (d as f64);
            quad += (d as f64) * (d as f64);
            rd[i] = -(uu + rho * d);
        }
        Ok(((lin + 0.5 * rho as f64 * quad) as f32, r))
    }

    fn out_phi(&self, pre: &Matrix, zt: &Matrix, u: &Matrix, rho: f32) -> Result<f32> {
        assert_eq!(pre.shape(), zt.shape());
        assert_eq!(pre.shape(), u.shape());
        let mut lin = 0.0f64;
        let mut quad = 0.0f64;
        for ((&p, &z), &uu) in pre.data().iter().zip(zt.data()).zip(u.data()) {
            let d = z - p;
            lin += (uu as f64) * (d as f64);
            quad += (d as f64) * (d as f64);
        }
        Ok((lin + 0.5 * rho as f64 * quad) as f32)
    }

    fn z_prox_val(&self, z: &Matrix, pin: &Matrix, nu: f32) -> Result<f32> {
        assert_eq!(z.shape(), pin.shape());
        let mut val = 0.0f64;
        for (&zz, &p) in z.data().iter().zip(pin.data()) {
            let d = zz - p.max(0.0);
            val += (d as f64) * (d as f64);
        }
        Ok((0.5 * nu as f64 * val) as f32)
    }

    fn z_combine(
        &self,
        z: &Matrix,
        pin: &Matrix,
        gsum: &Matrix,
        nu: f32,
        theta: f32,
    ) -> Result<(Matrix, f32, f32)> {
        assert_eq!(z.shape(), pin.shape());
        assert_eq!(z.shape(), gsum.shape());
        let mut znew = Matrix::zeros(z.rows(), z.cols());
        let zd = znew.data_mut();
        let mut prox = 0.0f64;
        let mut gsq = 0.0f64;
        let inv_theta = 1.0 / theta;
        for (i, ((&zz, &p), &gs)) in z
            .data()
            .iter()
            .zip(pin.data())
            .zip(gsum.data())
            .enumerate()
        {
            let d = zz - p.max(0.0);
            prox += (d as f64) * (d as f64);
            let g = nu * d + gs;
            gsq += (g as f64) * (g as f64);
            zd[i] = zz - g * inv_theta;
        }
        Ok((znew, (0.5 * nu as f64 * prox) as f32, gsq as f32))
    }

    fn zl_fista(
        &self,
        q: &Matrix,
        u: &Matrix,
        y: &Matrix,
        mask: &[f32],
        z0: &Matrix,
        rho: f32,
        denom: f32,
        steps: usize,
    ) -> Result<(Matrix, f32)> {
        assert_eq!(q.shape(), u.shape());
        assert_eq!(q.shape(), y.shape());
        assert_eq!(q.shape(), z0.shape());
        let step = 1.0f32 / (rho + 0.5);
        let mut z = z0.clone();
        let mut v = z0.clone();
        let mut t = 1.0f32;
        let mut g = Matrix::zeros(q.rows(), q.cols());
        for _ in 0..steps {
            softmax_xent(&v, y, mask, denom, Some(&mut g));
            // g += U + ρ(v − Q); z_next = v − step * g.
            let mut z_next = v.clone();
            {
                let gd = g.data_mut();
                let zn = z_next.data_mut();
                for (i, ((&uu, &qq), &vv)) in
                    u.data().iter().zip(q.data()).zip(v.data()).enumerate()
                {
                    let gi = gd[i] + uu + rho * (vv - qq);
                    zn[i] = vv - step * gi;
                }
            }
            let t_next = 0.5 * (1.0 + (1.0 + 4.0 * t * t).sqrt());
            let momentum = (t - 1.0) / t_next;
            // v = z_next + momentum * (z_next − z)
            let mut v_new = z_next.clone();
            {
                let vd = v_new.data_mut();
                for (i, &zold) in z.data().iter().enumerate() {
                    vd[i] += momentum * (vd[i] - zold);
                }
            }
            z = z_next;
            v = v_new;
            t = t_next;
        }
        let loss = softmax_xent(&z, y, mask, denom, None);
        Ok((z, loss))
    }

    fn xent_loss(&self, logits: &Matrix, y: &Matrix, mask: &[f32], denom: f32) -> Result<f32> {
        Ok(softmax_xent(logits, y, mask, denom, None))
    }

    fn bp_out_grads(
        &self,
        h1: &Matrix,
        w2: &Matrix,
        y: &Matrix,
        mask: &[f32],
        denom: f32,
    ) -> Result<(f32, Matrix, Matrix)> {
        let logits = self.matmul(h1, w2, false);
        let mut dl = Matrix::zeros(logits.rows(), logits.cols());
        let loss = softmax_xent(&logits, y, mask, denom, Some(&mut dl));
        let dw2 = self.mm_tn(h1, &dl)?;
        let dh1 = self.mm_bt(&dl, w2)?;
        Ok((loss, dw2, dh1))
    }

    fn bp_hidden_grads(&self, h0: &Matrix, w1: &Matrix, dz1: &Matrix) -> Result<Matrix> {
        let pre = self.matmul(h0, w1, false);
        assert_eq!(pre.shape(), dz1.shape());
        let mut r = Matrix::zeros(pre.rows(), pre.cols());
        let rd = r.data_mut();
        for (i, (&p, &d)) in pre.data().iter().zip(dz1.data()).enumerate() {
            rd[i] = if p > 0.0 { d } else { 0.0 };
        }
        self.mm_tn(h0, &r)
    }

    fn spmm(&self, a: &Csr, x: &Matrix) -> Matrix {
        assert_eq!(
            a.ncols(),
            x.rows(),
            "spmm shape mismatch: {}x{} @ {}x{}",
            a.nrows(),
            a.ncols(),
            x.rows(),
            x.cols()
        );
        let k = x.cols();
        let mut out = Matrix::zeros(a.nrows(), k);
        let t = self.par(2 * a.nnz() * k);
        parallel_row_chunks(t, a.nrows(), k, out.data_mut(), |lo, hi, chunk| {
            spmm_rows(a, x, lo, hi, chunk)
        });
        out
    }
}

// ---------------------------------------------------------------------------
// XlaBackend (feature-gated)
// ---------------------------------------------------------------------------

#[cfg(feature = "xla")]
pub use xla_backend::XlaBackend;

#[cfg(feature = "xla")]
mod xla_backend {
    use super::ComputeBackend;
    use crate::graph::Csr;
    use crate::runtime::{Engine, In};
    use crate::tensor::Matrix;
    use anyhow::Result;
    use std::path::Path;

    /// PJRT artifact backend: maps each typed kernel call to the artifact
    /// signature for its shapes and executes it on the [`Engine`].
    pub struct XlaBackend {
        engine: Engine,
    }

    impl XlaBackend {
        pub fn load(dir: &Path) -> Result<XlaBackend> {
            Ok(XlaBackend {
                engine: Engine::load(dir)?,
            })
        }

        pub fn from_engine(engine: Engine) -> XlaBackend {
            XlaBackend { engine }
        }

        pub fn engine(&self) -> &Engine {
            &self.engine
        }

        fn exec1(&self, sig: &str, inputs: &[In]) -> Result<Matrix> {
            Ok(self.engine.exec(sig, inputs)?.remove(0).into_mat())
        }

        fn nab(entry: &str, n: usize, a: usize, b: usize) -> String {
            format!("{entry}__n{n}_a{a}_b{b}")
        }

        fn nc(entry: &str, n: usize, c: usize) -> String {
            format!("{entry}__n{n}_c{c}")
        }
    }

    impl ComputeBackend for XlaBackend {
        fn name(&self) -> &'static str {
            "xla"
        }

        fn mm_nn(&self, x: &Matrix, w: &Matrix) -> Result<Matrix> {
            let sig = Self::nab("mm_nn", x.rows(), x.cols(), w.cols());
            self.exec1(&sig, &[In::Mat(x), In::Mat(w)])
        }

        fn mm_tn(&self, x: &Matrix, y: &Matrix) -> Result<Matrix> {
            let sig = Self::nab("mm_tn", x.rows(), x.cols(), y.cols());
            self.exec1(&sig, &[In::Mat(x), In::Mat(y)])
        }

        fn mm_bt(&self, y: &Matrix, w: &Matrix) -> Result<Matrix> {
            let sig = Self::nab("mm_bt", y.rows(), w.rows(), w.cols());
            self.exec1(&sig, &[In::Mat(y), In::Mat(w)])
        }

        fn fwd_relu(&self, h: &Matrix, w: &Matrix) -> Result<Matrix> {
            let sig = Self::nab("fwd_relu", h.rows(), h.cols(), w.cols());
            self.exec1(&sig, &[In::Mat(h), In::Mat(w)])
        }

        fn hidden_residual(&self, pre: &Matrix, zt: &Matrix, nu: f32) -> Result<(f32, Matrix)> {
            let sig = Self::nc("hidden_residual", pre.rows(), pre.cols());
            let outs = self
                .engine
                .exec(&sig, &[In::Mat(pre), In::Mat(zt), In::Scalar(nu)])?;
            let mut it = outs.into_iter();
            Ok((it.next().unwrap().scalar(), it.next().unwrap().into_mat()))
        }

        fn hidden_phi(&self, pre: &Matrix, zt: &Matrix, nu: f32) -> Result<f32> {
            let sig = Self::nc("hidden_phi", pre.rows(), pre.cols());
            Ok(self
                .engine
                .exec(&sig, &[In::Mat(pre), In::Mat(zt), In::Scalar(nu)])?
                .remove(0)
                .scalar())
        }

        fn out_residual(
            &self,
            pre: &Matrix,
            zt: &Matrix,
            u: &Matrix,
            rho: f32,
        ) -> Result<(f32, Matrix)> {
            let sig = Self::nc("out_residual", pre.rows(), pre.cols());
            let outs = self.engine.exec(
                &sig,
                &[In::Mat(pre), In::Mat(zt), In::Mat(u), In::Scalar(rho)],
            )?;
            let mut it = outs.into_iter();
            Ok((it.next().unwrap().scalar(), it.next().unwrap().into_mat()))
        }

        fn out_phi(&self, pre: &Matrix, zt: &Matrix, u: &Matrix, rho: f32) -> Result<f32> {
            let sig = Self::nc("out_phi", pre.rows(), pre.cols());
            Ok(self
                .engine
                .exec(
                    &sig,
                    &[In::Mat(pre), In::Mat(zt), In::Mat(u), In::Scalar(rho)],
                )?
                .remove(0)
                .scalar())
        }

        fn z_prox_val(&self, z: &Matrix, pin: &Matrix, nu: f32) -> Result<f32> {
            let sig = Self::nc("z_prox_val", z.rows(), z.cols());
            Ok(self
                .engine
                .exec(&sig, &[In::Mat(z), In::Mat(pin), In::Scalar(nu)])?
                .remove(0)
                .scalar())
        }

        fn z_combine(
            &self,
            z: &Matrix,
            pin: &Matrix,
            gsum: &Matrix,
            nu: f32,
            theta: f32,
        ) -> Result<(Matrix, f32, f32)> {
            let sig = Self::nc("z_combine", z.rows(), z.cols());
            let outs = self.engine.exec(
                &sig,
                &[
                    In::Mat(z),
                    In::Mat(pin),
                    In::Mat(gsum),
                    In::Scalar(nu),
                    In::Scalar(theta),
                ],
            )?;
            let mut it = outs.into_iter();
            Ok((
                it.next().unwrap().into_mat(),
                it.next().unwrap().scalar(),
                it.next().unwrap().scalar(),
            ))
        }

        fn zl_fista(
            &self,
            q: &Matrix,
            u: &Matrix,
            y: &Matrix,
            mask: &[f32],
            z0: &Matrix,
            rho: f32,
            denom: f32,
            steps: usize,
        ) -> Result<(Matrix, f32)> {
            let sig = format!("zl_fista__n{}_c{}_steps{}", q.rows(), q.cols(), steps);
            let outs = self.engine.exec(
                &sig,
                &[
                    In::Mat(q),
                    In::Mat(u),
                    In::Mat(y),
                    In::Vec(mask),
                    In::Mat(z0),
                    In::Scalar(rho),
                    In::Scalar(denom),
                ],
            )?;
            let mut it = outs.into_iter();
            Ok((it.next().unwrap().into_mat(), it.next().unwrap().scalar()))
        }

        fn xent_loss(&self, logits: &Matrix, y: &Matrix, mask: &[f32], denom: f32) -> Result<f32> {
            let sig = Self::nc("xent_loss", logits.rows(), logits.cols());
            Ok(self
                .engine
                .exec(
                    &sig,
                    &[
                        In::Mat(logits),
                        In::Mat(y),
                        In::Vec(mask),
                        In::Scalar(denom),
                    ],
                )?
                .remove(0)
                .scalar())
        }

        fn bp_out_grads(
            &self,
            h1: &Matrix,
            w2: &Matrix,
            y: &Matrix,
            mask: &[f32],
            denom: f32,
        ) -> Result<(f32, Matrix, Matrix)> {
            let sig = Self::nab("bp_out_grads", h1.rows(), h1.cols(), w2.cols());
            let outs = self.engine.exec(
                &sig,
                &[
                    In::Mat(h1),
                    In::Mat(w2),
                    In::Mat(y),
                    In::Vec(mask),
                    In::Scalar(denom),
                ],
            )?;
            let mut it = outs.into_iter();
            Ok((
                it.next().unwrap().scalar(),
                it.next().unwrap().into_mat(),
                it.next().unwrap().into_mat(),
            ))
        }

        fn bp_hidden_grads(&self, h0: &Matrix, w1: &Matrix, dz1: &Matrix) -> Result<Matrix> {
            let sig = Self::nab("bp_hidden_grads", h0.rows(), h0.cols(), w1.cols());
            self.exec1(&sig, &[In::Mat(h0), In::Mat(w1), In::Mat(dz1)])
        }

        fn spmm(&self, a: &Csr, x: &Matrix) -> Matrix {
            a.spmm(x)
        }

        fn warmup(&self, sigs: &[String]) -> Result<()> {
            self.engine.warmup(sigs)
        }
    }
}

// ---------------------------------------------------------------------------
// Backend selection
// ---------------------------------------------------------------------------

/// Requested backend kind (CLI `--backend`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendChoice {
    /// XLA artifacts when compiled in *and* present, otherwise native.
    Auto,
    Native,
    Xla,
}

impl BackendChoice {
    pub fn parse(s: &str) -> Option<BackendChoice> {
        match s {
            "auto" => Some(BackendChoice::Auto),
            "native" => Some(BackendChoice::Native),
            "xla" => Some(BackendChoice::Xla),
            _ => None,
        }
    }
}

/// True if the XLA artifact directory is usable (always false without the
/// `xla` feature).
#[cfg(feature = "xla")]
pub fn xla_available() -> bool {
    crate::runtime::Engine::available()
}

/// True if the XLA artifact directory is usable (always false without the
/// `xla` feature).
#[cfg(not(feature = "xla"))]
pub fn xla_available() -> bool {
    false
}

#[cfg(feature = "xla")]
fn load_xla_backend() -> Result<Arc<dyn ComputeBackend>> {
    let dir = crate::runtime::Engine::default_dir();
    Ok(Arc::new(XlaBackend::load(&dir)?))
}

#[cfg(not(feature = "xla"))]
fn load_xla_backend() -> Result<Arc<dyn ComputeBackend>> {
    anyhow::bail!("built without the `xla` feature — rebuild with --features xla or use --backend native")
}

/// Resolve a backend. `op_threads` sets the native backend's op-level row
/// parallelism (1 = fully serial ops; ignored by the XLA backend).
pub fn select_backend(choice: BackendChoice, op_threads: usize) -> Result<Arc<dyn ComputeBackend>> {
    match choice {
        BackendChoice::Native => Ok(Arc::new(NativeBackend::with_threads(op_threads.max(1)))),
        BackendChoice::Xla => load_xla_backend(),
        BackendChoice::Auto => {
            if xla_available() {
                load_xla_backend()
            } else {
                select_backend(BackendChoice::Native, op_threads)
            }
        }
    }
}

/// The default backend: XLA when available, else single-threaded native.
/// Never fails (falls back to native on any XLA load error).
pub fn default_backend() -> Arc<dyn ComputeBackend> {
    select_backend(BackendChoice::Auto, 1)
        .unwrap_or_else(|_| Arc::new(NativeBackend::new()) as Arc<dyn ComputeBackend>)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn matmul_variants_match_host_reference() {
        let mut rng = Rng::new(21);
        let be = NativeBackend::new();
        let x = Matrix::glorot(13, 7, &mut rng);
        let w = Matrix::glorot(7, 5, &mut rng);
        let y = Matrix::glorot(13, 5, &mut rng);
        assert_eq!(be.mm_nn(&x, &w).unwrap().data(), x.matmul(&w).data());
        assert_eq!(
            be.mm_tn(&x, &y).unwrap().data(),
            x.transpose().matmul(&y).data()
        );
        let bt = be.mm_bt(&y, &w).unwrap();
        let want = y.matmul(&w.transpose());
        assert!(bt.max_abs_diff(&want) < 1e-5);
        let fr = be.fwd_relu(&x, &w).unwrap();
        assert_eq!(fr.data(), crate::tensor::relu(&x.matmul(&w)).data());
    }

    #[test]
    fn parallel_ops_are_bitwise_identical_to_serial() {
        let mut rng = Rng::new(22);
        let serial = NativeBackend::new();
        let x = Matrix::glorot(64, 33, &mut rng);
        let w = Matrix::glorot(33, 17, &mut rng);
        let mut trips = Vec::new();
        for r in 0..64 {
            for c in 0..64 {
                if rng.gen_bool(0.1) {
                    trips.push((r, c, rng.gen_f32()));
                }
            }
        }
        let a = Csr::from_triplets(64, 64, &trips);
        let xs = Matrix::glorot(64, 17, &mut rng);
        for t in [2usize, 4, 8] {
            let par = NativeBackend::with_grain(t, 0); // force parallel path
            assert_eq!(
                par.mm_nn(&x, &w).unwrap().data(),
                serial.mm_nn(&x, &w).unwrap().data(),
                "mm_nn t={t}"
            );
            assert_eq!(
                par.mm_tn(&x, &x).unwrap().data(),
                serial.mm_tn(&x, &x).unwrap().data(),
                "mm_tn t={t}"
            );
            assert_eq!(
                par.mm_bt(&x, &Matrix::glorot(9, 33, &mut Rng::new(5)))
                    .unwrap()
                    .data(),
                serial
                    .mm_bt(&x, &Matrix::glorot(9, 33, &mut Rng::new(5)))
                    .unwrap()
                    .data(),
                "mm_bt t={t}"
            );
            assert_eq!(
                par.spmm(&a, &xs).data(),
                serial.spmm(&a, &xs).data(),
                "spmm t={t}"
            );
        }
    }

    #[test]
    fn residual_formulas() {
        let mut rng = Rng::new(23);
        let be = NativeBackend::new();
        let pre = Matrix::glorot(6, 4, &mut rng);
        let zt = Matrix::glorot(6, 4, &mut rng);
        let nu = 0.37f32;
        let (val, r) = be.hidden_residual(&pre, &zt, nu).unwrap();
        let act = crate::tensor::relu(&pre);
        let d = act.sub(&zt);
        let want_val = 0.5 * nu * d.frob_norm_sq() as f32;
        assert!((val - want_val).abs() < 1e-5 * want_val.abs().max(1.0));
        let want_r = d
            .hadamard(&crate::tensor::relu_mask(&pre))
            .scale(nu);
        assert!(r.max_abs_diff(&want_r) < 1e-6);
        assert_eq!(be.hidden_phi(&pre, &zt, nu).unwrap(), val);

        let u = Matrix::glorot(6, 4, &mut rng);
        let rho = 0.05f32;
        let (oval, orr) = be.out_residual(&pre, &zt, &u, rho).unwrap();
        let dz = zt.sub(&pre);
        let want = u.dot(&dz) as f32 + 0.5 * rho * dz.frob_norm_sq() as f32;
        assert!((oval - want).abs() < 1e-5 * want.abs().max(1.0));
        let mut want_r = u.clone();
        want_r.axpy(rho, &dz);
        assert!(orr.max_abs_diff(&want_r.scale(-1.0)) < 1e-6);
        assert_eq!(be.out_phi(&pre, &zt, &u, rho).unwrap(), oval);
    }

    #[test]
    fn z_combine_matches_manual() {
        let mut rng = Rng::new(24);
        let be = NativeBackend::new();
        let z = Matrix::glorot(5, 3, &mut rng);
        let pin = Matrix::glorot(5, 3, &mut rng);
        let gsum = Matrix::glorot(5, 3, &mut rng);
        let (nu, theta) = (0.2f32, 1.5f32);
        let (znew, prox, gsq) = be.z_combine(&z, &pin, &gsum, nu, theta).unwrap();
        let fpin = crate::tensor::relu(&pin);
        let d = z.sub(&fpin);
        let g = d.scale(nu).add(&gsum);
        let want_z = z.sub(&g.scale(1.0 / theta));
        assert!(znew.max_abs_diff(&want_z) < 1e-6);
        assert!((prox - 0.5 * nu * d.frob_norm_sq() as f32).abs() < 1e-5);
        assert!((gsq - g.frob_norm_sq() as f32).abs() < 1e-4 * gsq.abs().max(1.0));
        assert_eq!(be.z_prox_val(&z, &pin, nu).unwrap(), prox);
    }

    #[test]
    fn xent_matches_host_cross_entropy() {
        let mut rng = Rng::new(25);
        let be = NativeBackend::new();
        let n = 12;
        let c = 4;
        let logits = Matrix::glorot(n, c, &mut rng).scale(3.0);
        let labels: Vec<usize> = (0..n).map(|_| rng.gen_range(c)).collect();
        let mut y = Matrix::zeros(n, c);
        let mut mask = vec![0.0f32; n];
        for i in 0..n {
            y.set(i, labels[i], 1.0);
            if rng.gen_bool(0.6) {
                mask[i] = 1.0;
            }
        }
        let denom: f32 = mask.iter().sum::<f32>().max(1.0);
        let got = be.xent_loss(&logits, &y, &mask, denom).unwrap();
        let (want, _) = crate::tensor::masked_cross_entropy(&logits, &labels, &mask);
        assert!(
            (got as f64 - want).abs() < 1e-5 * want.abs().max(1.0),
            "native {got} vs host {want}"
        );
    }

    #[test]
    fn fista_decreases_objective() {
        let mut rng = Rng::new(26);
        let be = NativeBackend::new();
        let n = 16;
        let c = 3;
        let q = Matrix::glorot(n, c, &mut rng);
        let u = Matrix::glorot(n, c, &mut rng).scale(0.05);
        let labels: Vec<usize> = (0..n).map(|_| rng.gen_range(c)).collect();
        let mut y = Matrix::zeros(n, c);
        let mask = vec![1.0f32; n];
        for i in 0..n {
            y.set(i, labels[i], 1.0);
        }
        let denom = n as f32;
        let rho = 0.1f32;
        let objective = |z: &Matrix| -> f64 {
            let (ce, _) = crate::tensor::masked_cross_entropy(z, &labels, &mask);
            let d = z.sub(&q);
            ce + u.dot(&d) + 0.5 * rho as f64 * d.frob_norm_sq()
        };
        let (z_new, _risk) = be
            .zl_fista(&q, &u, &y, &mask, &q, rho, denom, 10)
            .unwrap();
        assert!(
            objective(&z_new) < objective(&q) - 1e-6,
            "FISTA failed to decrease the eq.-7 objective"
        );
    }
}
