//! The `ComputeBackend` trait — every dense kernel the trainers need,
//! decoupled from how it executes.
//!
//! Two implementations:
//!
//! - [`NativeBackend`] — pure Rust, always available. All hot paths (dense
//!   matmul variants, [`Csr::spmm`], the elementwise ADMM kernels and the
//!   softmax grad path) are row-block parallelised when constructed with
//!   > 1 thread — through the shared work-stealing [`Runtime`]
//!   (`--runtime shared`, the default: the backend *borrows* the runtime
//!   that also executes agent phases and serve handlers, DESIGN.md §11)
//!   or through an owned [`FjPool`] (`--runtime dual`). Every output row
//!   is produced by the same scalar loop the serial path runs and every
//!   reduction is folded on the caller in row order, so results are
//!   bitwise identical at any thread count on either engine. The dense
//!   matmul inner loops additionally run the 8-wide AVX microkernel in
//!   [`crate::tensor::simd`] when the hardware supports it
//!   (`CGCN_SIMD=off` disables; DESIGN.md §12) — the lane layout keeps
//!   per-element accumulation order, so SIMD on/off is bitwise identical
//!   too. Temporaries
//!   come from a per-backend scratch [`Arena`]; callers hand them back
//!   through [`ComputeBackend::recycle`] to keep the inner ADMM loops
//!   allocation-free.
//! - `XlaBackend` (behind `--features xla`) — wraps the PJRT [`Engine`] and
//!   dispatches each call to the AOT-compiled artifact with the matching
//!   shape signature, exactly as the seed trainers did directly.
//!
//! The kernel *semantics* are specified by `python/compile/kernels/ref.py`
//! and `python/compile/model.py`; the native implementations transcribe
//! those definitions (f = ReLU with f'(0) := 0, masked-mean softmax
//! cross-entropy with an explicit global denominator, FISTA with the
//! static 1/(ρ + ½) step). `rust/tests/integration_engine.rs` asserts both
//! backends agree with the host reference ops in [`crate::tensor`].
//!
//! See DESIGN.md §9 for the kernel-runtime architecture (FjPool lifecycle,
//! nnz-balanced SpMM partitioning, arena ownership, and the
//! bitwise-determinism argument).

use crate::graph::Csr;
use crate::tensor::{simd, Matrix};
use crate::util::pool::{
    dispatch_ranges, resolve_threads, uniform_chunks, FjPool, OpExec, Runtime, SendPtr,
};
use anyhow::Result;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Dense-kernel execution interface shared by the ADMM trainer, the
/// backprop baselines, the Cluster-GCN mini-batch engine, evaluation,
/// the TCP transport workers and the benches. Kernels are shape-agnostic:
/// the mini-batch path drives the same `spmm`/`fwd_relu`/`bp_*` calls
/// with batch-sized operands (|B| rows instead of the padded global row
/// count), which is what makes its memory bound real rather than modeled.
///
/// **Finite-operand contract:** every matrix/vector operand must contain
/// only finite values (no NaN, no ±inf). The dense matmuls skip
/// zero-valued left-operand entries (post-ReLU activations are 50–75 %
/// zeros), which drops the `0 · x` term — equal to real IEEE matmul only
/// when `x` is finite (`0 · ±inf = NaN`). The native kernels assert the
/// contract at entry in debug builds ([`simd::debug_assert_finite`]), so
/// a NaN entering training surfaces loudly at the first matmul instead
/// of being silently masked by the skip; release builds do not scan.
/// The SIMD path implements the identical skip semantics (the skip is
/// decided on the scalar operand before the vector row update), so the
/// contract and the results are the same with SIMD on or off.
pub trait ComputeBackend: Send + Sync {
    /// Short human-readable backend name for logs.
    fn name(&self) -> &'static str;

    /// `X @ W` — projections `V = Z W`, logits, Q assembly.
    fn mm_nn(&self, x: &Matrix, w: &Matrix) -> Result<Matrix>;

    /// `Xᵀ @ Y` — weight gradients `gW = Z_{l-1}ᵀ (Ã R)`.
    fn mm_tn(&self, x: &Matrix, y: &Matrix) -> Result<Matrix>;

    /// `Y @ Wᵀ` — Z-gradient back-projection `(Ã R) Wᵀ`.
    fn mm_bt(&self, y: &Matrix, w: &Matrix) -> Result<Matrix>;

    /// `ReLU(H @ W)` — forward hidden layer (eval, init, baselines).
    fn fwd_relu(&self, h: &Matrix, w: &Matrix) -> Result<Matrix>;

    /// ν-coupling at a ReLU layer: returns
    /// `(ν/2 ‖f(pre) − Zt‖², ν (f(pre) − Zt) ⊙ f'(pre))`.
    fn hidden_residual(&self, pre: &Matrix, zt: &Matrix, nu: f32) -> Result<(f32, Matrix)>;

    /// Value-only hidden coupling (τ/θ backtracking).
    fn hidden_phi(&self, pre: &Matrix, zt: &Matrix, nu: f32) -> Result<f32>;

    /// Augmented-Lagrangian coupling at the linear output layer: returns
    /// `(⟨U, Zt − pre⟩ + ρ/2 ‖Zt − pre‖², −(U + ρ(Zt − pre)))`.
    fn out_residual(&self, pre: &Matrix, zt: &Matrix, u: &Matrix, rho: f32)
        -> Result<(f32, Matrix)>;

    /// Value-only output coupling (τ/θ backtracking).
    fn out_phi(&self, pre: &Matrix, zt: &Matrix, u: &Matrix, rho: f32) -> Result<f32>;

    /// Value-only proximal term `ν/2 ‖Z − f(Pin)‖²` (θ backtracking).
    fn z_prox_val(&self, z: &Matrix, pin: &Matrix, nu: f32) -> Result<f32>;

    /// Proximal-gradient combine step (eq. 8/10):
    /// `g = ν(Z − f(Pin)) + Gsum; Z⁺ = Z − g/θ`. Returns
    /// `(Z⁺, ν/2 ‖Z − f(Pin)‖², ‖g‖²)`.
    fn z_combine(
        &self,
        z: &Matrix,
        pin: &Matrix,
        gsum: &Matrix,
        nu: f32,
        theta: f32,
    ) -> Result<(Matrix, f32, f32)>;

    /// Z_L subproblem (eq. 7): `steps` FISTA iterations on
    /// `R(Z, Y) + ⟨U, Z − Q⟩ + ρ/2 ‖Z − Q‖²` from warm start `z0`, with
    /// the static step `1/(ρ + ½)`. Returns `(Z⁺, risk at Z⁺)`.
    #[allow(clippy::too_many_arguments)]
    fn zl_fista(
        &self,
        q: &Matrix,
        u: &Matrix,
        y: &Matrix,
        mask: &[f32],
        z0: &Matrix,
        rho: f32,
        denom: f32,
        steps: usize,
    ) -> Result<(Matrix, f32)>;

    /// Masked mean softmax cross-entropy loss (global `denom`).
    fn xent_loss(&self, logits: &Matrix, y: &Matrix, mask: &[f32], denom: f32) -> Result<f32>;

    /// Baseline loss head: `logits = H1 W2`; returns
    /// `(loss, dW2 = H1ᵀ dL, dH1 = dL W2ᵀ)`.
    fn bp_out_grads(
        &self,
        h1: &Matrix,
        w2: &Matrix,
        y: &Matrix,
        mask: &[f32],
        denom: f32,
    ) -> Result<(f32, Matrix, Matrix)>;

    /// Baseline hidden tail: `dW1 = H0ᵀ (dZ1 ⊙ f'(H0 W1))`.
    fn bp_hidden_grads(&self, h0: &Matrix, w1: &Matrix, dz1: &Matrix) -> Result<Matrix>;

    /// Sparse × dense (the Ã-product hot path). Backends may parallelise;
    /// the default is the serial CSR kernel.
    fn spmm(&self, a: &Csr, x: &Matrix) -> Matrix {
        a.spmm(x)
    }

    /// Hand a temporary matrix back to the backend so its allocation can
    /// be reused by a later kernel of the same size. Purely an
    /// optimisation hook: dropping the matrix instead is always correct.
    /// No-op by default; [`NativeBackend`] parks the buffer in its
    /// scratch arena.
    fn recycle(&self, _m: Matrix) {}

    /// The shared work-stealing [`Runtime`] this backend forks its kernels
    /// on, when built in `--runtime shared` mode. Trainers and the serving
    /// layer submit their own coarse tasks (agent phases, batch prep,
    /// connection handlers) to the same runtime so the whole process runs
    /// on one thread budget. `None` means legacy dual-pool mode: callers
    /// create their own dedicated pools.
    fn runtime(&self) -> Option<&Arc<Runtime>> {
        None
    }

    /// Pre-compile the given artifact signatures (startup, off the timed
    /// path). No-op for backends that compile nothing.
    fn warmup(&self, _sigs: &[String]) -> Result<()> {
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Scratch arena
// ---------------------------------------------------------------------------

/// Size-bucketed free lists of `f32`/`f64` buffers, so the per-epoch hot
/// loops (`zl_fista`, the residual/combine kernels, backprop temporaries)
/// stop allocating once warm. Buffers are keyed by exact length; each
/// bucket keeps at most [`ARENA_BUCKET_CAP`] entries and anything beyond
/// that is simply dropped, bounding retained memory at a small multiple of
/// the live working set.
///
/// Ownership rule: a buffer taken from the arena is owned by exactly one
/// kernel call (or returned to the caller inside a [`Matrix`]); it re-enters
/// the arena only through an explicit `put` / [`ComputeBackend::recycle`].
/// Plain `take` returns *stale* contents — callers must overwrite every
/// element (all elementwise kernels do); accumulating kernels use
/// `take_zeroed`.
#[derive(Default)]
struct Arena {
    f32s: Mutex<HashMap<usize, Vec<Vec<f32>>>>,
    f64s: Mutex<HashMap<usize, Vec<Vec<f64>>>>,
}

/// Max recycled buffers retained per exact size.
const ARENA_BUCKET_CAP: usize = 16;

impl Arena {
    /// A `len`-sized f32 buffer with unspecified (stale) contents.
    fn take_f32(&self, len: usize) -> Vec<f32> {
        if let Some(v) = self.f32s.lock().unwrap().get_mut(&len).and_then(Vec::pop) {
            return v;
        }
        vec![0.0; len]
    }

    /// A `len`-sized f32 buffer guaranteed all-zero.
    fn take_f32_zeroed(&self, len: usize) -> Vec<f32> {
        if let Some(mut v) = self.f32s.lock().unwrap().get_mut(&len).and_then(Vec::pop) {
            v.fill(0.0);
            return v;
        }
        vec![0.0; len]
    }

    fn put_f32(&self, v: Vec<f32>) {
        let mut map = self.f32s.lock().unwrap();
        let bucket = map.entry(v.len()).or_default();
        if bucket.len() < ARENA_BUCKET_CAP {
            bucket.push(v);
        }
    }

    /// A `len`-sized f64 buffer with unspecified (stale) contents
    /// (reduction partials: every slot is written before being read).
    fn take_f64(&self, len: usize) -> Vec<f64> {
        if let Some(v) = self.f64s.lock().unwrap().get_mut(&len).and_then(Vec::pop) {
            return v;
        }
        vec![0.0; len]
    }

    fn put_f64(&self, v: Vec<f64>) {
        let mut map = self.f64s.lock().unwrap();
        let bucket = map.entry(v.len()).or_default();
        if bucket.len() < ARENA_BUCKET_CAP {
            bucket.push(v);
        }
    }
}

// ---------------------------------------------------------------------------
// Per-op parallelism grains
// ---------------------------------------------------------------------------

/// Per-op minimum estimated-flop thresholds below which an op runs
/// serially even on a multi-thread backend.
///
/// Why per-op rather than the old single `MIN_PAR_FLOPS = 1<<21`:
///
/// - The persistent [`FjPool`] dispatch costs ~1–2 µs (a mutex round-trip
///   plus a condvar wake) versus ~30–60 µs for the spawn-per-op
///   `thread::scope` path the old constant was calibrated against, so the
///   profitable crossover moves down by roughly an order of magnitude for
///   every dense op.
/// - `mm_tn` skips zero inputs, and its left operand in the trainers is a
///   post-ReLU activation (typically ~50–75 % zeros), so its nominal
///   `2·a·b·n` estimate overstates real work by ~2–4×. Its threshold is
///   therefore kept a factor ~8 *higher* than `mm_nn`'s rather than
///   lowered with the rest.
/// - `spmm`'s `2·nnz·k` estimate is exact, and the kernel is memory-bound
///   (one streamed `x` row per nonzero), so it parallelises profitably
///   earliest of all.
///
/// These values are the measured crossover region on the
/// `benches/kernel_bench.rs` reference shapes (op × shape × threads sweep);
/// re-run `cargo bench --bench kernel_bench` and inspect
/// `BENCH_kernels.json` to recalibrate on new hardware.
#[derive(Clone, Copy, Debug)]
pub struct OpGrains {
    /// `mm_nn`/`fwd_relu`, estimate `2·n·a·b`.
    pub mm_nn: usize,
    /// `mm_tn`, nominal estimate `2·a·b·n` (pessimistic on sparse inputs).
    pub mm_tn: usize,
    /// `mm_bt`, estimate `2·n·a·k`.
    pub mm_bt: usize,
    /// `spmm`, exact estimate `2·nnz·k`.
    pub spmm: usize,
    /// Elementwise residual/combine/FISTA-update kernels, estimate
    /// ~`6–10·len`.
    pub eltwise: usize,
    /// Softmax cross-entropy rows, estimate `8·n·c`.
    pub xent: usize,
}

impl OpGrains {
    /// The calibrated defaults described on the struct (scalar kernels).
    pub fn calibrated() -> OpGrains {
        OpGrains {
            mm_nn: 1 << 19,
            mm_tn: 1 << 22,
            mm_bt: 1 << 19,
            spmm: 1 << 17,
            eltwise: 1 << 19,
            xent: 1 << 19,
        }
    }

    /// Calibration matched to the active matmul inner loop. The 8-wide
    /// SIMD axpy roughly quadruples serial dense-matmul throughput, so the
    /// flop count at which forking amortises the ~1–2 µs pool dispatch
    /// moves up by about the same factor for `mm_nn`/`mm_bt`; `mm_tn`
    /// stays put (its threshold is dominated by the zero-skip discount,
    /// not raw loop speed), as do the non-vectorised op families. Bench
    /// `simd_ab` in `BENCH_kernels.json` is the recalibration reference.
    pub fn calibrated_for(simd: bool) -> OpGrains {
        let mut g = OpGrains::calibrated();
        if simd {
            g.mm_nn = 1 << 21;
            g.mm_bt = 1 << 21;
        }
        g
    }

    /// The same threshold for every op (tests/benches use 0 to force the
    /// parallel path on tiny shapes).
    pub fn uniform(grain: usize) -> OpGrains {
        OpGrains {
            mm_nn: grain,
            mm_tn: grain,
            mm_bt: grain,
            spmm: grain,
            eltwise: grain,
            xent: grain,
        }
    }
}

impl Default for OpGrains {
    fn default() -> Self {
        OpGrains::calibrated()
    }
}

// ---------------------------------------------------------------------------
// NativeBackend
// ---------------------------------------------------------------------------

/// Pure-Rust backend. With `threads > 1` every kernel is row-block
/// parallelised once its estimated flop count crosses the per-op
/// [`OpGrains`] threshold — over the borrowed shared [`Runtime`]
/// (`with_runtime`, `--runtime shared`) or an owned [`FjPool`]
/// (`with_threads`, `--runtime dual`); results are bitwise identical to
/// serial either way (see [`crate::util::pool`] and DESIGN.md §9/§11).
/// `with_spawn_threads` keeps the legacy spawn-per-op executor as an A/B
/// reference (`--op-spawn`).
pub struct NativeBackend {
    threads: usize,
    grains: OpGrains,
    /// Owned dual-mode fork-join pool; `None` when serial, in spawn mode,
    /// or on the shared runtime.
    pool: Option<FjPool>,
    /// Borrowed shared work-stealing runtime (`--runtime shared`). Kept
    /// even in spawn mode so [`ComputeBackend::runtime`] still exposes it
    /// to trainers/serving while kernels A/B against spawn-per-op.
    runtime: Option<Arc<Runtime>>,
    /// Use the legacy `thread::scope` spawn-per-op executor.
    spawn_ops: bool,
    /// Run the dense matmul inner loops through the 8-wide AVX microkernel
    /// ([`simd`], DESIGN.md §12). Snapshotted from detection + `CGCN_SIMD`
    /// at construction; results are bitwise identical either way.
    simd: bool,
    arena: Arena,
}

impl NativeBackend {
    fn build(threads: usize, grains: OpGrains, spawn_ops: bool) -> NativeBackend {
        let pool = if threads > 1 && !spawn_ops {
            Some(FjPool::new(threads))
        } else {
            None
        };
        NativeBackend {
            threads,
            grains,
            pool,
            runtime: None,
            spawn_ops,
            simd: simd::enabled(),
            arena: Arena::default(),
        }
    }

    fn build_on_runtime(rt: Arc<Runtime>, grains: OpGrains, spawn_ops: bool) -> NativeBackend {
        NativeBackend {
            threads: rt.threads(),
            grains,
            pool: None,
            runtime: Some(rt),
            spawn_ops,
            simd: simd::enabled(),
            arena: Arena::default(),
        }
    }

    /// Override the microkernel choice (tests/benches A/B the SIMD and
    /// scalar paths in one process). Forcing `true` is clamped to hardware
    /// support, so the override selects a code path but never a result.
    pub fn with_simd(mut self, on: bool) -> NativeBackend {
        self.simd = on && simd::detected();
        self
    }

    /// Single-threaded backend (the deterministic baseline — though since
    /// parallel results are bitwise identical, "baseline" here only means
    /// "no worker threads").
    pub fn new() -> NativeBackend {
        NativeBackend::build(1, OpGrains::calibrated_for(simd::enabled()), false)
    }

    /// Backend with op-level row parallelism on a persistent pool of up to
    /// `threads` workers (0 = all available cores).
    pub fn with_threads(threads: usize) -> NativeBackend {
        NativeBackend::build(
            resolve_threads(threads),
            OpGrains::calibrated_for(simd::enabled()),
            false,
        )
    }

    /// Like [`NativeBackend::with_threads`] but with a uniform explicit
    /// parallelism grain (tests/benches use 0 to force the parallel path
    /// on tiny shapes).
    pub fn with_grain(threads: usize, min_par_flops: usize) -> NativeBackend {
        NativeBackend::build(resolve_threads(threads), OpGrains::uniform(min_par_flops), false)
    }

    /// Legacy spawn-per-op backend: same kernels, but parallel ops fork
    /// fresh scoped threads instead of using the persistent pool. Kept as
    /// the `--op-spawn` A/B reference for `benches/kernel_bench.rs`.
    pub fn with_spawn_threads(threads: usize) -> NativeBackend {
        NativeBackend::build(
            resolve_threads(threads),
            OpGrains::calibrated_for(simd::enabled()),
            true,
        )
    }

    /// [`NativeBackend::with_spawn_threads`] with a uniform explicit grain.
    pub fn with_spawn_grain(threads: usize, min_par_flops: usize) -> NativeBackend {
        NativeBackend::build(resolve_threads(threads), OpGrains::uniform(min_par_flops), true)
    }

    /// Backend whose parallel kernels fork on the shared work-stealing
    /// [`Runtime`] instead of an owned pool (`--runtime shared`). The
    /// effective thread count is the runtime's budget. With `spawn_ops`
    /// kernels use the spawn-per-op executor (`--op-spawn` A/B) but the
    /// runtime handle is still exposed for agent/serving tasks.
    pub fn with_runtime(rt: Arc<Runtime>, spawn_ops: bool) -> NativeBackend {
        NativeBackend::build_on_runtime(rt, OpGrains::calibrated_for(simd::enabled()), spawn_ops)
    }

    /// [`NativeBackend::with_runtime`] with a uniform explicit grain
    /// (tests use 0 to force the parallel path on tiny shapes).
    pub fn with_runtime_grain(rt: Arc<Runtime>, min_par_flops: usize) -> NativeBackend {
        NativeBackend::build_on_runtime(rt, OpGrains::uniform(min_par_flops), false)
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Threads to use for an op with estimated cost `flops` gated by
    /// per-op threshold `grain`.
    fn par(&self, flops: usize, grain: usize) -> usize {
        if self.threads > 1 && flops >= grain {
            self.threads
        } else {
            1
        }
    }

    /// The executor for an op that resolved to `t` threads. Also the one
    /// telemetry choke point for backend ops: a single per-thread counter
    /// bump per dispatched op (kernel inner loops stay untouched).
    fn exec(&self, t: usize) -> OpExec<'_> {
        if t <= 1 {
            crate::obs_counter!("backend.ops.serial").inc();
            OpExec::Serial
        } else if self.spawn_ops {
            crate::obs_counter!("backend.ops.spawn").inc();
            OpExec::Spawn
        } else if let Some(rt) = &self.runtime {
            crate::obs_counter!("backend.ops.pooled").inc();
            OpExec::Rt(rt)
        } else if let Some(p) = &self.pool {
            crate::obs_counter!("backend.ops.pooled").inc();
            OpExec::Pool(p)
        } else {
            crate::obs_counter!("backend.ops.serial").inc();
            OpExec::Serial
        }
    }

    /// A `rows × cols` matrix whose buffer is all-zero (for accumulating
    /// kernels), drawn from the arena when possible.
    fn take_mat_zeroed(&self, rows: usize, cols: usize) -> Matrix {
        Matrix::from_vec(rows, cols, self.arena.take_f32_zeroed(rows * cols))
    }

    /// A `rows × cols` matrix with stale contents — every element must be
    /// written before the matrix escapes the kernel.
    fn take_mat_stale(&self, rows: usize, cols: usize) -> Matrix {
        Matrix::from_vec(rows, cols, self.arena.take_f32(rows * cols))
    }

    /// An arena-backed copy of `src`.
    fn take_mat_copy(&self, src: &Matrix) -> Matrix {
        let mut v = self.arena.take_f32(src.rows() * src.cols());
        v.copy_from_slice(src.data());
        Matrix::from_vec(src.rows(), src.cols(), v)
    }

    /// Fold row partials in ascending row order on the calling thread —
    /// the one reduction order used by serial and parallel paths alike,
    /// which is what keeps reduction outputs bitwise identical across
    /// thread counts.
    fn fold_partials(&self, partials: Vec<f64>) -> f64 {
        let mut acc = 0.0f64;
        for &p in &partials {
            acc += p;
        }
        self.arena.put_f64(partials);
        acc
    }

    fn matmul(&self, x: &Matrix, w: &Matrix, relu: bool) -> Matrix {
        assert_eq!(
            x.cols(),
            w.rows(),
            "matmul shape mismatch: {}x{} @ {}x{}",
            x.rows(),
            x.cols(),
            w.rows(),
            w.cols()
        );
        simd::debug_assert_finite("mm_nn lhs", x.data());
        simd::debug_assert_finite("mm_nn rhs", w.data());
        let (rows, inner, cols) = (x.rows(), x.cols(), w.cols());
        let mut out = self.take_mat_zeroed(rows, cols);
        let t = self.par(2 * rows * inner * cols, self.grains.mm_nn);
        let bounds = uniform_chunks(t, rows);
        let op = SendPtr::new(out.data_mut().as_mut_ptr());
        dispatch_ranges(&self.exec(t), &bounds, &|lo, hi| {
            // SAFETY: row ranges are disjoint; `out` outlives the dispatch.
            let chunk = unsafe { span_mut(op.get(), lo, hi, cols) };
            mm_nn_rows(x, w, relu, self.simd, lo, hi, chunk)
        });
        out
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        NativeBackend::new()
    }
}

/// Mutable view of rows `lo..hi` (stride `stride`) of the row-major buffer
/// at `base`.
///
/// SAFETY: caller guarantees (a) concurrent calls use disjoint `lo..hi`
/// ranges, (b) the buffer covers `hi * stride` elements, and (c) it
/// outlives the dispatch call, which blocks until every range is done.
unsafe fn span_mut<'a, T>(base: *mut T, lo: usize, hi: usize, stride: usize) -> &'a mut [T] {
    std::slice::from_raw_parts_mut(base.add(lo * stride), (hi - lo) * stride)
}

/// Rows `lo..hi` of `X @ W` (optionally ReLU'd) into `chunk` — the same
/// ikj loop as [`Matrix::matmul`], so results match it bitwise. `simd`
/// selects the 8-lane row update ([`simd::axpy`]); the zero-skip is
/// decided on the scalar `a` before the row update either way, so skip
/// semantics and bits are identical across paths.
fn mm_nn_rows(
    x: &Matrix,
    w: &Matrix,
    relu: bool,
    simd: bool,
    lo: usize,
    hi: usize,
    chunk: &mut [f32],
) {
    let inner = x.cols();
    let n = w.cols();
    let xd = x.data();
    let wd = w.data();
    for (ri, i) in (lo..hi).enumerate() {
        let orow = &mut chunk[ri * n..(ri + 1) * n];
        for k in 0..inner {
            let a = xd[i * inner + k];
            if a == 0.0 {
                continue;
            }
            let wrow = &wd[k * n..(k + 1) * n];
            simd::axpy(simd, orow, a, wrow);
        }
        if relu {
            for o in orow.iter_mut() {
                if *o < 0.0 {
                    *o = 0.0;
                }
            }
        }
    }
}

/// Rows `lo..hi` of `Xᵀ @ Y` into `chunk` (output is `x.cols() × y.cols()`;
/// bitwise-matches `x.transpose().matmul(&y)`).
///
/// Cache-blocked over the shared dimension: `KB` rows of `x`/`y` are
/// processed at a time so the strided column reads of `x` and the rows of
/// `y` stay L1/L2-resident across the chunk. `k` still advances in
/// ascending order both inside and across blocks, so each output element
/// accumulates in exactly the serial order — blocking changes locality,
/// not results. The inner row update is the same [`simd::axpy`] as
/// `mm_nn_rows` (zero-skip decided on the scalar `v` first).
fn mm_tn_rows(x: &Matrix, y: &Matrix, simd: bool, lo: usize, hi: usize, chunk: &mut [f32]) {
    const KB: usize = 64;
    let a = x.cols();
    let n = y.cols();
    let m = x.rows();
    let xd = x.data();
    let yd = y.data();
    let mut k0 = 0usize;
    while k0 < m {
        let k1 = (k0 + KB).min(m);
        for (ri, i) in (lo..hi).enumerate() {
            let orow = &mut chunk[ri * n..(ri + 1) * n];
            for k in k0..k1 {
                let v = xd[k * a + i];
                if v == 0.0 {
                    continue;
                }
                let yrow = &yd[k * n..(k + 1) * n];
                simd::axpy(simd, orow, v, yrow);
            }
        }
        k0 = k1;
    }
}

/// Rows `lo..hi` of `Y @ Wᵀ` into `chunk` (output is `y.rows() × w.rows()`).
///
/// Blocked over the output columns: a strip of `JB` rows of `w` is reused
/// across every `y` row in the chunk before moving on, keeping the strip
/// cache-resident. Each output element is still one complete dot product
/// in ascending index order, so results are bitwise unchanged.
fn mm_bt_rows(y: &Matrix, w: &Matrix, lo: usize, hi: usize, chunk: &mut [f32]) {
    const JB: usize = 64;
    let k = y.cols();
    let a = w.rows();
    let mut j0 = 0usize;
    while j0 < a {
        let j1 = (j0 + JB).min(a);
        for (ri, i) in (lo..hi).enumerate() {
            let yrow = y.row(i);
            let orow = &mut chunk[ri * a..(ri + 1) * a];
            for (j, o) in orow[j0..j1].iter_mut().enumerate() {
                let wrow = w.row(j0 + j);
                let mut acc = 0.0f32;
                for idx in 0..k {
                    acc += yrow[idx] * wrow[idx];
                }
                *o = acc;
            }
        }
        j0 = j1;
    }
}

/// SIMD rows `lo..hi` of `Y @ Wᵀ` given the pre-transposed strip
/// `wt = Wᵀ` (`y.cols() × w.rows()`): `out[i][j] = Σ_idx y[i][idx] ·
/// wt[idx][j]`, lifted 8 `j` lanes at a time by [`simd::axpy`].
///
/// Bitwise identity with the scalar `mm_bt_rows` dot product: the chunk
/// arrives zeroed, so each output element accumulates `0 + y₀·w₀ + y₁·w₁
/// + …` in ascending `idx` — the exact f32 sequence the scalar `acc`
/// register walks (transposing copies values, it doesn't change them,
/// and the scalar dot has no zero-skip so neither does this path). The
/// same `JB` output-column blocking keeps the `wt` strip cache-resident;
/// a full ascending-`idx` sweep runs per block, so blocking reorders
/// nothing per element.
fn mm_bt_rows_simd(y: &Matrix, wt: &Matrix, lo: usize, hi: usize, chunk: &mut [f32]) {
    const JB: usize = 64;
    debug_assert_eq!(wt.rows(), y.cols());
    let a = wt.cols();
    let wd = wt.data();
    let mut j0 = 0usize;
    while j0 < a {
        let j1 = (j0 + JB).min(a);
        for (ri, i) in (lo..hi).enumerate() {
            let yrow = y.row(i);
            let orow = &mut chunk[ri * a + j0..ri * a + j1];
            for (idx, &v) in yrow.iter().enumerate() {
                simd::axpy(true, orow, v, &wd[idx * a + j0..idx * a + j1]);
            }
        }
        j0 = j1;
    }
}

/// Rows `lo..hi` of `A @ X` (CSR × dense) into `chunk` — same inner loop
/// as [`Csr::spmm`].
fn spmm_rows(a: &Csr, x: &Matrix, lo: usize, hi: usize, chunk: &mut [f32]) {
    let k = x.cols();
    let xd = x.data();
    for (ri, r) in (lo..hi).enumerate() {
        let (cols, vals) = a.row(r);
        let orow = &mut chunk[ri * k..(ri + 1) * k];
        for (&c, &v) in cols.iter().zip(vals) {
            let xrow = &xd[c as usize * k..(c as usize + 1) * k];
            for (o, &xv) in orow.iter_mut().zip(xrow) {
                *o += v * xv;
            }
        }
    }
}

/// Rows `lo..hi` of masked mean softmax cross-entropy per
/// `kernels/ref.py::softmax_xent_ref`. Writes each row's (already
/// mask-weighted) loss term into `partials` and, when `grad` is given, the
/// gradient rows `(softmax(logits) − Y) ⊙ mask / denom` in place. The grad
/// row doubles as the exp scratch buffer, so the kernel allocates nothing.
/// Per-element arithmetic is identical with and without `grad`.
#[allow(clippy::too_many_arguments)]
fn softmax_xent_rows(
    logits: &Matrix,
    y: &Matrix,
    mask: &[f32],
    denom: f32,
    lo: usize,
    hi: usize,
    mut grad: Option<&mut [f32]>,
    partials: &mut [f64],
) {
    let c = logits.cols();
    for (ri, r) in (lo..hi).enumerate() {
        let row = logits.row(r);
        let mut max = f32::NEG_INFINITY;
        for &x in row {
            if x > max {
                max = x;
            }
        }
        let m = mask[r];
        let mut sum = 0.0f32;
        if let Some(g) = grad.as_mut() {
            let grow = &mut g[ri * c..(ri + 1) * c];
            for (gc, &x) in grow.iter_mut().zip(row) {
                let e = (x - max).exp();
                *gc = e;
                sum += e;
            }
        } else {
            for &x in row {
                sum += (x - max).exp();
            }
        }
        let inv = 1.0 / sum;
        let lse = sum.ln() + max;
        let mut term = 0.0f64;
        if m != 0.0 {
            let mut picked = 0.0f32;
            for (ci, &x) in row.iter().enumerate() {
                picked += y.at(r, ci) * x;
            }
            term = ((lse - picked) * m) as f64;
        }
        partials[ri] = term;
        if let Some(g) = grad.as_mut() {
            let grow = &mut g[ri * c..(ri + 1) * c];
            for (ci, gc) in grow.iter_mut().enumerate() {
                *gc = (*gc * inv - y.at(r, ci)) * m / denom;
            }
        }
    }
}

impl NativeBackend {
    /// Masked mean softmax cross-entropy; optionally writes the gradient
    /// into `grad_out` (shape-checked by the caller). Row-parallel: each
    /// row's loss term lands in a partials slot and is folded in row order
    /// on the caller, matching the serial fold bitwise.
    fn softmax_xent(
        &self,
        logits: &Matrix,
        y: &Matrix,
        mask: &[f32],
        denom: f32,
        grad_out: Option<&mut Matrix>,
    ) -> f32 {
        assert_eq!(logits.shape(), y.shape());
        assert_eq!(logits.rows(), mask.len());
        let (rows, cols) = (logits.rows(), logits.cols());
        let t = self.par(8 * rows * cols, self.grains.xent);
        let mut partials = self.arena.take_f64(rows);
        {
            let pp = SendPtr::new(partials.as_mut_ptr());
            let gp = grad_out.map(|g| SendPtr::new(g.data_mut().as_mut_ptr()));
            let bounds = uniform_chunks(t, rows);
            dispatch_ranges(&self.exec(t), &bounds, &|lo, hi| {
                // SAFETY: row ranges are disjoint; buffers outlive the
                // dispatch.
                let pc = unsafe { span_mut(pp.get(), lo, hi, 1) };
                let gc = gp
                    .as_ref()
                    .map(|g| unsafe { span_mut(g.get(), lo, hi, cols) });
                softmax_xent_rows(logits, y, mask, denom, lo, hi, gc, pc);
            });
        }
        let loss = self.fold_partials(partials);
        (loss / denom as f64) as f32
    }
}

impl ComputeBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn runtime(&self) -> Option<&Arc<Runtime>> {
        self.runtime.as_ref()
    }

    fn mm_nn(&self, x: &Matrix, w: &Matrix) -> Result<Matrix> {
        Ok(self.matmul(x, w, false))
    }

    fn mm_tn(&self, x: &Matrix, y: &Matrix) -> Result<Matrix> {
        assert_eq!(x.rows(), y.rows(), "mm_tn row mismatch");
        simd::debug_assert_finite("mm_tn lhs", x.data());
        simd::debug_assert_finite("mm_tn rhs", y.data());
        let (rows, cols) = (x.cols(), y.cols());
        let mut out = self.take_mat_zeroed(rows, cols);
        let t = self.par(2 * rows * cols * x.rows(), self.grains.mm_tn);
        let bounds = uniform_chunks(t, rows);
        let op = SendPtr::new(out.data_mut().as_mut_ptr());
        dispatch_ranges(&self.exec(t), &bounds, &|lo, hi| {
            // SAFETY: disjoint row ranges; `out` outlives the dispatch.
            let chunk = unsafe { span_mut(op.get(), lo, hi, cols) };
            mm_tn_rows(x, y, self.simd, lo, hi, chunk)
        });
        Ok(out)
    }

    fn mm_bt(&self, y: &Matrix, w: &Matrix) -> Result<Matrix> {
        assert_eq!(y.cols(), w.cols(), "mm_bt col mismatch");
        simd::debug_assert_finite("mm_bt lhs", y.data());
        simd::debug_assert_finite("mm_bt rhs", w.data());
        let (rows, cols) = (y.rows(), w.rows());
        let mut out = self.take_mat_zeroed(rows, cols);
        let t = self.par(2 * rows * cols * y.cols(), self.grains.mm_bt);
        let bounds = uniform_chunks(t, rows);
        let op = SendPtr::new(out.data_mut().as_mut_ptr());
        if self.simd {
            // The vector path wants unit-stride `j` lanes, so transpose `w`
            // once into an arena strip and accumulate outer products —
            // same per-element ascending-`idx` chain as the scalar dot
            // (see `mm_bt_rows_simd`). Transpose cost is `a·k` copies vs
            // `2·rows·a·k` flops, and the strip is recycled afterwards.
            let mut wt = self.take_mat_stale(w.cols(), w.rows());
            {
                let wd = w.data();
                let (wr, wc) = (w.rows(), w.cols());
                let td = wt.data_mut();
                for r in 0..wr {
                    for c in 0..wc {
                        td[c * wr + r] = wd[r * wc + c];
                    }
                }
            }
            dispatch_ranges(&self.exec(t), &bounds, &|lo, hi| {
                // SAFETY: disjoint row ranges; `out` outlives the dispatch.
                let chunk = unsafe { span_mut(op.get(), lo, hi, cols) };
                mm_bt_rows_simd(y, &wt, lo, hi, chunk)
            });
            self.recycle(wt);
        } else {
            dispatch_ranges(&self.exec(t), &bounds, &|lo, hi| {
                // SAFETY: disjoint row ranges; `out` outlives the dispatch.
                let chunk = unsafe { span_mut(op.get(), lo, hi, cols) };
                mm_bt_rows(y, w, lo, hi, chunk)
            });
        }
        Ok(out)
    }

    fn fwd_relu(&self, h: &Matrix, w: &Matrix) -> Result<Matrix> {
        Ok(self.matmul(h, w, true))
    }

    fn hidden_residual(&self, pre: &Matrix, zt: &Matrix, nu: f32) -> Result<(f32, Matrix)> {
        assert_eq!(pre.shape(), zt.shape());
        let (rows, cols) = pre.shape();
        let mut r = self.take_mat_stale(rows, cols);
        let t = self.par(6 * rows * cols, self.grains.eltwise);
        let mut partials = self.arena.take_f64(rows);
        {
            let pd = pre.data();
            let zd = zt.data();
            let rp = SendPtr::new(r.data_mut().as_mut_ptr());
            let pp = SendPtr::new(partials.as_mut_ptr());
            let bounds = uniform_chunks(t, rows);
            dispatch_ranges(&self.exec(t), &bounds, &|lo, hi| {
                // SAFETY: disjoint row ranges; buffers outlive the dispatch.
                let rc = unsafe { span_mut(rp.get(), lo, hi, cols) };
                let pc = unsafe { span_mut(pp.get(), lo, hi, 1) };
                for (ri, row) in (lo..hi).enumerate() {
                    let base = row * cols;
                    let mut acc = 0.0f64;
                    for ci in 0..cols {
                        let p = pd[base + ci];
                        let d = p.max(0.0) - zd[base + ci];
                        acc += (d as f64) * (d as f64);
                        rc[ri * cols + ci] = if p > 0.0 { nu * d } else { 0.0 };
                    }
                    pc[ri] = acc;
                }
            });
        }
        let val = self.fold_partials(partials);
        Ok(((0.5 * nu as f64 * val) as f32, r))
    }

    fn hidden_phi(&self, pre: &Matrix, zt: &Matrix, nu: f32) -> Result<f32> {
        assert_eq!(pre.shape(), zt.shape());
        let (rows, cols) = pre.shape();
        let t = self.par(4 * rows * cols, self.grains.eltwise);
        let mut partials = self.arena.take_f64(rows);
        {
            let pd = pre.data();
            let zd = zt.data();
            let pp = SendPtr::new(partials.as_mut_ptr());
            let bounds = uniform_chunks(t, rows);
            dispatch_ranges(&self.exec(t), &bounds, &|lo, hi| {
                // SAFETY: disjoint row ranges; buffer outlives the dispatch.
                let pc = unsafe { span_mut(pp.get(), lo, hi, 1) };
                for (ri, row) in (lo..hi).enumerate() {
                    let base = row * cols;
                    let mut acc = 0.0f64;
                    for ci in 0..cols {
                        let d = pd[base + ci].max(0.0) - zd[base + ci];
                        acc += (d as f64) * (d as f64);
                    }
                    pc[ri] = acc;
                }
            });
        }
        let val = self.fold_partials(partials);
        Ok((0.5 * nu as f64 * val) as f32)
    }

    fn out_residual(
        &self,
        pre: &Matrix,
        zt: &Matrix,
        u: &Matrix,
        rho: f32,
    ) -> Result<(f32, Matrix)> {
        assert_eq!(pre.shape(), zt.shape());
        assert_eq!(pre.shape(), u.shape());
        let (rows, cols) = pre.shape();
        let mut r = self.take_mat_stale(rows, cols);
        let t = self.par(8 * rows * cols, self.grains.eltwise);
        // Two partials per row: Σ u·d (lin) and Σ d² (quad), folded
        // separately so the final combine matches the serial formula.
        let mut lin_p = self.arena.take_f64(rows);
        let mut quad_p = self.arena.take_f64(rows);
        {
            let pd = pre.data();
            let zd = zt.data();
            let ud = u.data();
            let rp = SendPtr::new(r.data_mut().as_mut_ptr());
            let lp = SendPtr::new(lin_p.as_mut_ptr());
            let qp = SendPtr::new(quad_p.as_mut_ptr());
            let bounds = uniform_chunks(t, rows);
            dispatch_ranges(&self.exec(t), &bounds, &|lo, hi| {
                // SAFETY: disjoint row ranges; buffers outlive the dispatch.
                let rc = unsafe { span_mut(rp.get(), lo, hi, cols) };
                let lc = unsafe { span_mut(lp.get(), lo, hi, 1) };
                let qc = unsafe { span_mut(qp.get(), lo, hi, 1) };
                for (ri, row) in (lo..hi).enumerate() {
                    let base = row * cols;
                    let mut lin = 0.0f64;
                    let mut quad = 0.0f64;
                    for ci in 0..cols {
                        let d = zd[base + ci] - pd[base + ci];
                        let uu = ud[base + ci];
                        lin += (uu as f64) * (d as f64);
                        quad += (d as f64) * (d as f64);
                        rc[ri * cols + ci] = -(uu + rho * d);
                    }
                    lc[ri] = lin;
                    qc[ri] = quad;
                }
            });
        }
        let lin = self.fold_partials(lin_p);
        let quad = self.fold_partials(quad_p);
        Ok(((lin + 0.5 * rho as f64 * quad) as f32, r))
    }

    fn out_phi(&self, pre: &Matrix, zt: &Matrix, u: &Matrix, rho: f32) -> Result<f32> {
        assert_eq!(pre.shape(), zt.shape());
        assert_eq!(pre.shape(), u.shape());
        let (rows, cols) = pre.shape();
        let t = self.par(6 * rows * cols, self.grains.eltwise);
        let mut lin_p = self.arena.take_f64(rows);
        let mut quad_p = self.arena.take_f64(rows);
        {
            let pd = pre.data();
            let zd = zt.data();
            let ud = u.data();
            let lp = SendPtr::new(lin_p.as_mut_ptr());
            let qp = SendPtr::new(quad_p.as_mut_ptr());
            let bounds = uniform_chunks(t, rows);
            dispatch_ranges(&self.exec(t), &bounds, &|lo, hi| {
                // SAFETY: disjoint row ranges; buffers outlive the dispatch.
                let lc = unsafe { span_mut(lp.get(), lo, hi, 1) };
                let qc = unsafe { span_mut(qp.get(), lo, hi, 1) };
                for (ri, row) in (lo..hi).enumerate() {
                    let base = row * cols;
                    let mut lin = 0.0f64;
                    let mut quad = 0.0f64;
                    for ci in 0..cols {
                        let d = zd[base + ci] - pd[base + ci];
                        lin += (ud[base + ci] as f64) * (d as f64);
                        quad += (d as f64) * (d as f64);
                    }
                    lc[ri] = lin;
                    qc[ri] = quad;
                }
            });
        }
        let lin = self.fold_partials(lin_p);
        let quad = self.fold_partials(quad_p);
        Ok((lin + 0.5 * rho as f64 * quad) as f32)
    }

    fn z_prox_val(&self, z: &Matrix, pin: &Matrix, nu: f32) -> Result<f32> {
        assert_eq!(z.shape(), pin.shape());
        let (rows, cols) = z.shape();
        let t = self.par(4 * rows * cols, self.grains.eltwise);
        let mut partials = self.arena.take_f64(rows);
        {
            let zd = z.data();
            let pd = pin.data();
            let pp = SendPtr::new(partials.as_mut_ptr());
            let bounds = uniform_chunks(t, rows);
            dispatch_ranges(&self.exec(t), &bounds, &|lo, hi| {
                // SAFETY: disjoint row ranges; buffer outlives the dispatch.
                let pc = unsafe { span_mut(pp.get(), lo, hi, 1) };
                for (ri, row) in (lo..hi).enumerate() {
                    let base = row * cols;
                    let mut acc = 0.0f64;
                    for ci in 0..cols {
                        let d = zd[base + ci] - pd[base + ci].max(0.0);
                        acc += (d as f64) * (d as f64);
                    }
                    pc[ri] = acc;
                }
            });
        }
        let val = self.fold_partials(partials);
        Ok((0.5 * nu as f64 * val) as f32)
    }

    fn z_combine(
        &self,
        z: &Matrix,
        pin: &Matrix,
        gsum: &Matrix,
        nu: f32,
        theta: f32,
    ) -> Result<(Matrix, f32, f32)> {
        assert_eq!(z.shape(), pin.shape());
        assert_eq!(z.shape(), gsum.shape());
        let (rows, cols) = z.shape();
        let mut znew = self.take_mat_stale(rows, cols);
        let t = self.par(10 * rows * cols, self.grains.eltwise);
        let mut prox_p = self.arena.take_f64(rows);
        let mut gsq_p = self.arena.take_f64(rows);
        let inv_theta = 1.0 / theta;
        {
            let zd = z.data();
            let pd = pin.data();
            let gd = gsum.data();
            let zp = SendPtr::new(znew.data_mut().as_mut_ptr());
            let pp = SendPtr::new(prox_p.as_mut_ptr());
            let gp = SendPtr::new(gsq_p.as_mut_ptr());
            let bounds = uniform_chunks(t, rows);
            dispatch_ranges(&self.exec(t), &bounds, &|lo, hi| {
                // SAFETY: disjoint row ranges; buffers outlive the dispatch.
                let zc = unsafe { span_mut(zp.get(), lo, hi, cols) };
                let pc = unsafe { span_mut(pp.get(), lo, hi, 1) };
                let gc = unsafe { span_mut(gp.get(), lo, hi, 1) };
                for (ri, row) in (lo..hi).enumerate() {
                    let base = row * cols;
                    let mut prox = 0.0f64;
                    let mut gsq = 0.0f64;
                    for ci in 0..cols {
                        let zz = zd[base + ci];
                        let d = zz - pd[base + ci].max(0.0);
                        prox += (d as f64) * (d as f64);
                        let g = nu * d + gd[base + ci];
                        gsq += (g as f64) * (g as f64);
                        zc[ri * cols + ci] = zz - g * inv_theta;
                    }
                    pc[ri] = prox;
                    gc[ri] = gsq;
                }
            });
        }
        let prox = self.fold_partials(prox_p);
        let gsq = self.fold_partials(gsq_p);
        Ok((znew, (0.5 * nu as f64 * prox) as f32, gsq as f32))
    }

    fn zl_fista(
        &self,
        q: &Matrix,
        u: &Matrix,
        y: &Matrix,
        mask: &[f32],
        z0: &Matrix,
        rho: f32,
        denom: f32,
        steps: usize,
    ) -> Result<(Matrix, f32)> {
        assert_eq!(q.shape(), u.shape());
        assert_eq!(q.shape(), y.shape());
        assert_eq!(q.shape(), z0.shape());
        let (rows, cols) = q.shape();
        let step = 1.0f32 / (rho + 0.5);
        // All iteration state lives in arena buffers: z/znext ping-pong via
        // swap, v is updated in place, g is the reusable gradient buffer.
        // The seed implementation cloned three matrices and zeroed one per
        // step; the arithmetic here is element-for-element identical.
        let mut z = self.take_mat_copy(z0);
        let mut v = self.take_mat_copy(z0);
        let mut g = self.take_mat_stale(rows, cols);
        let mut znext = self.take_mat_stale(rows, cols);
        let mut t = 1.0f32;
        let thr = self.par(8 * rows * cols, self.grains.eltwise);
        let bounds = uniform_chunks(thr, rows);
        for _ in 0..steps {
            self.softmax_xent(&v, y, mask, denom, Some(&mut g));
            let t_next = 0.5 * (1.0 + (1.0 + 4.0 * t * t).sqrt());
            let momentum = (t - 1.0) / t_next;
            {
                let qd = q.data();
                let ud = u.data();
                let zd = z.data();
                let gd = g.data();
                let vp = SendPtr::new(v.data_mut().as_mut_ptr());
                let np = SendPtr::new(znext.data_mut().as_mut_ptr());
                // Fused per-element update:
                //   zn = v − step·(g + U + ρ(v − Q));  v ← zn + momentum·(zn − z)
                // Reads of v/z happen before the writes within each element,
                // so updating v in place is safe and order-independent.
                dispatch_ranges(&self.exec(thr), &bounds, &|lo, hi| {
                    for i in lo * cols..hi * cols {
                        // SAFETY: disjoint element ranges (rows lo..hi);
                        // buffers outlive the dispatch.
                        unsafe {
                            let vv = *vp.get().add(i);
                            let gi = gd[i] + ud[i] + rho * (vv - qd[i]);
                            let zn = vv - step * gi;
                            *np.get().add(i) = zn;
                            *vp.get().add(i) = zn + momentum * (zn - zd[i]);
                        }
                    }
                });
            }
            std::mem::swap(&mut z, &mut znext);
            t = t_next;
        }
        let loss = self.softmax_xent(&z, y, mask, denom, None);
        self.recycle(v);
        self.recycle(g);
        self.recycle(znext);
        Ok((z, loss))
    }

    fn xent_loss(&self, logits: &Matrix, y: &Matrix, mask: &[f32], denom: f32) -> Result<f32> {
        Ok(self.softmax_xent(logits, y, mask, denom, None))
    }

    fn bp_out_grads(
        &self,
        h1: &Matrix,
        w2: &Matrix,
        y: &Matrix,
        mask: &[f32],
        denom: f32,
    ) -> Result<(f32, Matrix, Matrix)> {
        let logits = self.matmul(h1, w2, false);
        let mut dl = self.take_mat_stale(logits.rows(), logits.cols());
        let loss = self.softmax_xent(&logits, y, mask, denom, Some(&mut dl));
        let dw2 = self.mm_tn(h1, &dl)?;
        let dh1 = self.mm_bt(&dl, w2)?;
        self.recycle(logits);
        self.recycle(dl);
        Ok((loss, dw2, dh1))
    }

    fn bp_hidden_grads(&self, h0: &Matrix, w1: &Matrix, dz1: &Matrix) -> Result<Matrix> {
        let pre = self.matmul(h0, w1, false);
        assert_eq!(pre.shape(), dz1.shape());
        let (rows, cols) = pre.shape();
        let mut r = self.take_mat_stale(rows, cols);
        let t = self.par(2 * rows * cols, self.grains.eltwise);
        {
            let pd = pre.data();
            let dd = dz1.data();
            let rp = SendPtr::new(r.data_mut().as_mut_ptr());
            let bounds = uniform_chunks(t, rows);
            dispatch_ranges(&self.exec(t), &bounds, &|lo, hi| {
                // SAFETY: disjoint row ranges; buffer outlives the dispatch.
                let rc = unsafe { span_mut(rp.get(), lo, hi, cols) };
                for (ci, i) in (lo * cols..hi * cols).enumerate() {
                    rc[ci] = if pd[i] > 0.0 { dd[i] } else { 0.0 };
                }
            });
        }
        let out = self.mm_tn(h0, &r)?;
        self.recycle(pre);
        self.recycle(r);
        Ok(out)
    }

    fn spmm(&self, a: &Csr, x: &Matrix) -> Matrix {
        assert_eq!(
            a.ncols(),
            x.rows(),
            "spmm shape mismatch: {}x{} @ {}x{}",
            a.nrows(),
            a.ncols(),
            x.rows(),
            x.cols()
        );
        let k = x.cols();
        let mut out = self.take_mat_zeroed(a.nrows(), k);
        let t = self.par(2 * a.nnz() * k, self.grains.spmm);
        // Balance chunks by nonzero count, not row count: community
        // partitions concentrate power-law degree mass, so equal-row
        // chunks can leave one worker with most of the nnz. Any chunking
        // of rows yields bitwise-identical output (each row is written by
        // exactly one worker running the serial row kernel).
        let bounds = if t > 1 {
            a.balanced_row_chunks(t)
        } else {
            uniform_chunks(1, a.nrows())
        };
        let op = SendPtr::new(out.data_mut().as_mut_ptr());
        dispatch_ranges(&self.exec(t), &bounds, &|lo, hi| {
            // SAFETY: disjoint row ranges; `out` outlives the dispatch.
            let chunk = unsafe { span_mut(op.get(), lo, hi, k) };
            spmm_rows(a, x, lo, hi, chunk)
        });
        out
    }

    fn recycle(&self, m: Matrix) {
        self.arena.put_f32(m.into_vec());
    }
}

// ---------------------------------------------------------------------------
// XlaBackend (feature-gated)
// ---------------------------------------------------------------------------

#[cfg(feature = "xla")]
pub use xla_backend::XlaBackend;

#[cfg(feature = "xla")]
mod xla_backend {
    use super::ComputeBackend;
    use crate::graph::Csr;
    use crate::runtime::{Engine, In};
    use crate::tensor::Matrix;
    use anyhow::Result;
    use std::path::Path;

    /// PJRT artifact backend: maps each typed kernel call to the artifact
    /// signature for its shapes and executes it on the [`Engine`].
    pub struct XlaBackend {
        engine: Engine,
    }

    impl XlaBackend {
        pub fn load(dir: &Path) -> Result<XlaBackend> {
            Ok(XlaBackend {
                engine: Engine::load(dir)?,
            })
        }

        pub fn from_engine(engine: Engine) -> XlaBackend {
            XlaBackend { engine }
        }

        pub fn engine(&self) -> &Engine {
            &self.engine
        }

        fn exec1(&self, sig: &str, inputs: &[In]) -> Result<Matrix> {
            Ok(self.engine.exec(sig, inputs)?.remove(0).into_mat())
        }

        fn nab(entry: &str, n: usize, a: usize, b: usize) -> String {
            format!("{entry}__n{n}_a{a}_b{b}")
        }

        fn nc(entry: &str, n: usize, c: usize) -> String {
            format!("{entry}__n{n}_c{c}")
        }
    }

    impl ComputeBackend for XlaBackend {
        fn name(&self) -> &'static str {
            "xla"
        }

        fn mm_nn(&self, x: &Matrix, w: &Matrix) -> Result<Matrix> {
            let sig = Self::nab("mm_nn", x.rows(), x.cols(), w.cols());
            self.exec1(&sig, &[In::Mat(x), In::Mat(w)])
        }

        fn mm_tn(&self, x: &Matrix, y: &Matrix) -> Result<Matrix> {
            let sig = Self::nab("mm_tn", x.rows(), x.cols(), y.cols());
            self.exec1(&sig, &[In::Mat(x), In::Mat(y)])
        }

        fn mm_bt(&self, y: &Matrix, w: &Matrix) -> Result<Matrix> {
            let sig = Self::nab("mm_bt", y.rows(), w.rows(), w.cols());
            self.exec1(&sig, &[In::Mat(y), In::Mat(w)])
        }

        fn fwd_relu(&self, h: &Matrix, w: &Matrix) -> Result<Matrix> {
            let sig = Self::nab("fwd_relu", h.rows(), h.cols(), w.cols());
            self.exec1(&sig, &[In::Mat(h), In::Mat(w)])
        }

        fn hidden_residual(&self, pre: &Matrix, zt: &Matrix, nu: f32) -> Result<(f32, Matrix)> {
            let sig = Self::nc("hidden_residual", pre.rows(), pre.cols());
            let outs = self
                .engine
                .exec(&sig, &[In::Mat(pre), In::Mat(zt), In::Scalar(nu)])?;
            let mut it = outs.into_iter();
            Ok((it.next().unwrap().scalar(), it.next().unwrap().into_mat()))
        }

        fn hidden_phi(&self, pre: &Matrix, zt: &Matrix, nu: f32) -> Result<f32> {
            let sig = Self::nc("hidden_phi", pre.rows(), pre.cols());
            Ok(self
                .engine
                .exec(&sig, &[In::Mat(pre), In::Mat(zt), In::Scalar(nu)])?
                .remove(0)
                .scalar())
        }

        fn out_residual(
            &self,
            pre: &Matrix,
            zt: &Matrix,
            u: &Matrix,
            rho: f32,
        ) -> Result<(f32, Matrix)> {
            let sig = Self::nc("out_residual", pre.rows(), pre.cols());
            let outs = self.engine.exec(
                &sig,
                &[In::Mat(pre), In::Mat(zt), In::Mat(u), In::Scalar(rho)],
            )?;
            let mut it = outs.into_iter();
            Ok((it.next().unwrap().scalar(), it.next().unwrap().into_mat()))
        }

        fn out_phi(&self, pre: &Matrix, zt: &Matrix, u: &Matrix, rho: f32) -> Result<f32> {
            let sig = Self::nc("out_phi", pre.rows(), pre.cols());
            Ok(self
                .engine
                .exec(
                    &sig,
                    &[In::Mat(pre), In::Mat(zt), In::Mat(u), In::Scalar(rho)],
                )?
                .remove(0)
                .scalar())
        }

        fn z_prox_val(&self, z: &Matrix, pin: &Matrix, nu: f32) -> Result<f32> {
            let sig = Self::nc("z_prox_val", z.rows(), z.cols());
            Ok(self
                .engine
                .exec(&sig, &[In::Mat(z), In::Mat(pin), In::Scalar(nu)])?
                .remove(0)
                .scalar())
        }

        fn z_combine(
            &self,
            z: &Matrix,
            pin: &Matrix,
            gsum: &Matrix,
            nu: f32,
            theta: f32,
        ) -> Result<(Matrix, f32, f32)> {
            let sig = Self::nc("z_combine", z.rows(), z.cols());
            let outs = self.engine.exec(
                &sig,
                &[
                    In::Mat(z),
                    In::Mat(pin),
                    In::Mat(gsum),
                    In::Scalar(nu),
                    In::Scalar(theta),
                ],
            )?;
            let mut it = outs.into_iter();
            Ok((
                it.next().unwrap().into_mat(),
                it.next().unwrap().scalar(),
                it.next().unwrap().scalar(),
            ))
        }

        fn zl_fista(
            &self,
            q: &Matrix,
            u: &Matrix,
            y: &Matrix,
            mask: &[f32],
            z0: &Matrix,
            rho: f32,
            denom: f32,
            steps: usize,
        ) -> Result<(Matrix, f32)> {
            let sig = format!("zl_fista__n{}_c{}_steps{}", q.rows(), q.cols(), steps);
            let outs = self.engine.exec(
                &sig,
                &[
                    In::Mat(q),
                    In::Mat(u),
                    In::Mat(y),
                    In::Vec(mask),
                    In::Mat(z0),
                    In::Scalar(rho),
                    In::Scalar(denom),
                ],
            )?;
            let mut it = outs.into_iter();
            Ok((it.next().unwrap().into_mat(), it.next().unwrap().scalar()))
        }

        fn xent_loss(&self, logits: &Matrix, y: &Matrix, mask: &[f32], denom: f32) -> Result<f32> {
            let sig = Self::nc("xent_loss", logits.rows(), logits.cols());
            Ok(self
                .engine
                .exec(
                    &sig,
                    &[
                        In::Mat(logits),
                        In::Mat(y),
                        In::Vec(mask),
                        In::Scalar(denom),
                    ],
                )?
                .remove(0)
                .scalar())
        }

        fn bp_out_grads(
            &self,
            h1: &Matrix,
            w2: &Matrix,
            y: &Matrix,
            mask: &[f32],
            denom: f32,
        ) -> Result<(f32, Matrix, Matrix)> {
            let sig = Self::nab("bp_out_grads", h1.rows(), h1.cols(), w2.cols());
            let outs = self.engine.exec(
                &sig,
                &[
                    In::Mat(h1),
                    In::Mat(w2),
                    In::Mat(y),
                    In::Vec(mask),
                    In::Scalar(denom),
                ],
            )?;
            let mut it = outs.into_iter();
            Ok((
                it.next().unwrap().scalar(),
                it.next().unwrap().into_mat(),
                it.next().unwrap().into_mat(),
            ))
        }

        fn bp_hidden_grads(&self, h0: &Matrix, w1: &Matrix, dz1: &Matrix) -> Result<Matrix> {
            let sig = Self::nab("bp_hidden_grads", h0.rows(), h0.cols(), w1.cols());
            self.exec1(&sig, &[In::Mat(h0), In::Mat(w1), In::Mat(dz1)])
        }

        fn spmm(&self, a: &Csr, x: &Matrix) -> Matrix {
            a.spmm(x)
        }

        fn warmup(&self, sigs: &[String]) -> Result<()> {
            self.engine.warmup(sigs)
        }
    }
}

// ---------------------------------------------------------------------------
// Backend selection
// ---------------------------------------------------------------------------

/// Requested backend kind (CLI `--backend`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendChoice {
    /// XLA artifacts when compiled in *and* present, otherwise native.
    Auto,
    Native,
    Xla,
}

impl BackendChoice {
    pub fn parse(s: &str) -> Option<BackendChoice> {
        match s {
            "auto" => Some(BackendChoice::Auto),
            "native" => Some(BackendChoice::Native),
            "xla" => Some(BackendChoice::Xla),
            _ => None,
        }
    }
}

/// True if the XLA artifact directory is usable (always false without the
/// `xla` feature).
#[cfg(feature = "xla")]
pub fn xla_available() -> bool {
    crate::runtime::Engine::available()
}

/// True if the XLA artifact directory is usable (always false without the
/// `xla` feature).
#[cfg(not(feature = "xla"))]
pub fn xla_available() -> bool {
    false
}

#[cfg(feature = "xla")]
fn load_xla_backend() -> Result<Arc<dyn ComputeBackend>> {
    let dir = crate::runtime::Engine::default_dir();
    Ok(Arc::new(XlaBackend::load(&dir)?))
}

#[cfg(not(feature = "xla"))]
fn load_xla_backend() -> Result<Arc<dyn ComputeBackend>> {
    anyhow::bail!("built without the `xla` feature — rebuild with --features xla or use --backend native")
}

/// Resolve a backend. `op_threads` sets the native backend's op-level row
/// parallelism (1 = fully serial ops; ignored by the XLA backend);
/// `spawn_ops` selects the legacy spawn-per-op executor instead of the
/// persistent pool (`--op-spawn`, A/B benchmarking only).
pub fn select_backend(
    choice: BackendChoice,
    op_threads: usize,
    spawn_ops: bool,
) -> Result<Arc<dyn ComputeBackend>> {
    match choice {
        BackendChoice::Native => {
            let t = op_threads.max(1);
            Ok(if spawn_ops {
                Arc::new(NativeBackend::with_spawn_threads(t))
            } else {
                Arc::new(NativeBackend::with_threads(t))
            })
        }
        BackendChoice::Xla => load_xla_backend(),
        BackendChoice::Auto => {
            if xla_available() {
                load_xla_backend()
            } else {
                select_backend(BackendChoice::Native, op_threads, spawn_ops)
            }
        }
    }
}

/// [`select_backend`] for `--runtime shared`: the native backend borrows
/// the shared work-stealing runtime (whose budget sets the effective op
/// thread count) instead of owning a pool. The XLA backend has no op
/// threads to share — it falls back to [`select_backend`] semantics and
/// the caller's trainers run dual-mode.
pub fn select_backend_shared(
    choice: BackendChoice,
    rt: Arc<Runtime>,
    spawn_ops: bool,
) -> Result<Arc<dyn ComputeBackend>> {
    match choice {
        BackendChoice::Native => Ok(Arc::new(NativeBackend::with_runtime(rt, spawn_ops))),
        BackendChoice::Xla => {
            log::info!("xla backend does not share the thread runtime; using dual-mode pools");
            load_xla_backend()
        }
        BackendChoice::Auto => {
            if xla_available() {
                select_backend_shared(BackendChoice::Xla, rt, spawn_ops)
            } else {
                select_backend_shared(BackendChoice::Native, rt, spawn_ops)
            }
        }
    }
}

/// The default backend: XLA when available, else single-threaded native.
/// Never fails (falls back to native on any XLA load error).
pub fn default_backend() -> Arc<dyn ComputeBackend> {
    select_backend(BackendChoice::Auto, 1, false)
        .unwrap_or_else(|_| Arc::new(NativeBackend::new()) as Arc<dyn ComputeBackend>)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn matmul_variants_match_host_reference() {
        let mut rng = Rng::new(21);
        let be = NativeBackend::new();
        let x = Matrix::glorot(13, 7, &mut rng);
        let w = Matrix::glorot(7, 5, &mut rng);
        let y = Matrix::glorot(13, 5, &mut rng);
        assert_eq!(be.mm_nn(&x, &w).unwrap().data(), x.matmul(&w).data());
        assert_eq!(
            be.mm_tn(&x, &y).unwrap().data(),
            x.transpose().matmul(&y).data()
        );
        let bt = be.mm_bt(&y, &w).unwrap();
        let want = y.matmul(&w.transpose());
        assert!(bt.max_abs_diff(&want) < 1e-5);
        let fr = be.fwd_relu(&x, &w).unwrap();
        assert_eq!(fr.data(), crate::tensor::relu(&x.matmul(&w)).data());
    }

    #[test]
    fn matmul_blocking_matches_reference_past_block_size() {
        // Shapes larger than the KB/JB cache tiles, so the blocked loops
        // actually wrap: results must still match the host reference
        // bitwise (mm_tn) / to rounding (mm_bt's dot order is unchanged,
        // so it is bitwise equal to the unblocked backend path too).
        let mut rng = Rng::new(27);
        let be = NativeBackend::new();
        let x = Matrix::glorot(150, 90, &mut rng);
        let y = Matrix::glorot(150, 70, &mut rng);
        assert_eq!(
            be.mm_tn(&x, &y).unwrap().data(),
            x.transpose().matmul(&y).data()
        );
        let w = Matrix::glorot(150, 33, &mut rng);
        let yy = Matrix::glorot(40, 33, &mut rng);
        let bt = be.mm_bt(&yy, &w).unwrap();
        let want = yy.matmul(&w.transpose());
        assert!(bt.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn simd_matmuls_are_bitwise_identical_to_scalar() {
        // Shapes straddle the 8-lane width (cols < 8, == 8, and every
        // remainder) so both the vector body and the scalar tail run.
        // With AVX undetected `with_simd(true)` clamps to scalar and the
        // assertions hold trivially.
        let mut rng = Rng::new(41);
        for cols in [1usize, 5, 7, 8, 9, 13, 16, 21] {
            let x = Matrix::glorot(11, 10, &mut rng);
            let w = Matrix::glorot(10, cols, &mut rng); // mm_nn lanes = cols
            let y = Matrix::glorot(11, cols, &mut rng); // mm_tn lanes = cols
            let wb = Matrix::glorot(cols, 10, &mut rng); // mm_bt lanes = cols
            let scalar = NativeBackend::new().with_simd(false);
            let vector = NativeBackend::new().with_simd(true);
            assert_eq!(
                scalar.mm_nn(&x, &w).unwrap().data(),
                vector.mm_nn(&x, &w).unwrap().data(),
                "mm_nn cols={cols}"
            );
            assert_eq!(
                scalar.mm_tn(&x, &y).unwrap().data(),
                vector.mm_tn(&x, &y).unwrap().data(),
                "mm_tn cols={cols}"
            );
            assert_eq!(
                scalar.mm_bt(&x, &wb).unwrap().data(),
                vector.mm_bt(&x, &wb).unwrap().data(),
                "mm_bt cols={cols}"
            );
            assert_eq!(
                scalar.fwd_relu(&x, &w).unwrap().data(),
                vector.fwd_relu(&x, &w).unwrap().data(),
                "fwd_relu cols={cols}"
            );
        }
    }

    #[test]
    fn parallel_ops_are_bitwise_identical_to_serial() {
        let mut rng = Rng::new(22);
        let serial = NativeBackend::new();
        let x = Matrix::glorot(64, 33, &mut rng);
        let w = Matrix::glorot(33, 17, &mut rng);
        let mut trips = Vec::new();
        for r in 0..64 {
            for c in 0..64 {
                if rng.gen_bool(0.1) {
                    trips.push((r, c, rng.gen_f32()));
                }
            }
        }
        let a = Csr::from_triplets(64, 64, &trips);
        let xs = Matrix::glorot(64, 17, &mut rng);
        for t in [2usize, 4, 8] {
            let par = NativeBackend::with_grain(t, 0); // force parallel path
            assert_eq!(
                par.mm_nn(&x, &w).unwrap().data(),
                serial.mm_nn(&x, &w).unwrap().data(),
                "mm_nn t={t}"
            );
            assert_eq!(
                par.mm_tn(&x, &x).unwrap().data(),
                serial.mm_tn(&x, &x).unwrap().data(),
                "mm_tn t={t}"
            );
            assert_eq!(
                par.mm_bt(&x, &Matrix::glorot(9, 33, &mut Rng::new(5)))
                    .unwrap()
                    .data(),
                serial
                    .mm_bt(&x, &Matrix::glorot(9, 33, &mut Rng::new(5)))
                    .unwrap()
                    .data(),
                "mm_bt t={t}"
            );
            assert_eq!(
                par.spmm(&a, &xs).data(),
                serial.spmm(&a, &xs).data(),
                "spmm t={t}"
            );
        }
    }

    #[test]
    fn parallel_elementwise_is_bitwise_identical_to_serial() {
        // Every elementwise/reduction kernel at forced-parallel grain on
        // odd shapes: scalars and matrices must match serial exactly,
        // because partials are per-row and folded in row order on the
        // caller regardless of thread count.
        let mut rng = Rng::new(31);
        let serial = NativeBackend::new();
        let (n, c) = (37, 5);
        let pre = Matrix::glorot(n, c, &mut rng);
        let zt = Matrix::glorot(n, c, &mut rng);
        let u = Matrix::glorot(n, c, &mut rng);
        let gsum = Matrix::glorot(n, c, &mut rng);
        let labels: Vec<usize> = (0..n).map(|_| rng.gen_range(c)).collect();
        let mut y = Matrix::zeros(n, c);
        let mut mask = vec![0.0f32; n];
        for i in 0..n {
            y.set(i, labels[i], 1.0);
            if rng.gen_bool(0.7) {
                mask[i] = 1.0;
            }
        }
        mask[0] = 1.0;
        let denom: f32 = mask.iter().sum();
        let (nu, rho, theta) = (0.37f32, 0.05f32, 1.4f32);

        let wsq = Matrix::glorot(c, c, &mut rng); // square head: logits keep n×c

        let (hv_s, hr_s) = serial.hidden_residual(&pre, &zt, nu).unwrap();
        let (ov_s, or_s) = serial.out_residual(&pre, &zt, &u, rho).unwrap();
        let (zc_s, zp_s, zg_s) = serial.z_combine(&zt, &pre, &gsum, nu, theta).unwrap();
        let xl_s = serial.xent_loss(&pre, &y, &mask, denom).unwrap();
        let (zf_s, fl_s) = serial
            .zl_fista(&pre, &u, &y, &mask, &zt, rho, denom, 7)
            .unwrap();
        let (bl_s, bw_s, bh_s) = serial.bp_out_grads(&pre, &wsq, &y, &mask, denom).unwrap();
        let bg_s = serial.bp_hidden_grads(&pre, &wsq, &gsum).unwrap();

        for t in [2usize, 3, 8] {
            let par = NativeBackend::with_grain(t, 0);
            let (hv, hr) = par.hidden_residual(&pre, &zt, nu).unwrap();
            assert_eq!(hv, hv_s, "hidden_residual val t={t}");
            assert_eq!(hr.data(), hr_s.data(), "hidden_residual mat t={t}");
            assert_eq!(
                par.hidden_phi(&pre, &zt, nu).unwrap(),
                hv_s,
                "hidden_phi t={t}"
            );
            let (ov, or_) = par.out_residual(&pre, &zt, &u, rho).unwrap();
            assert_eq!(ov, ov_s, "out_residual val t={t}");
            assert_eq!(or_.data(), or_s.data(), "out_residual mat t={t}");
            assert_eq!(
                par.out_phi(&pre, &zt, &u, rho).unwrap(),
                ov_s,
                "out_phi t={t}"
            );
            let (zc, zp, zg) = par.z_combine(&zt, &pre, &gsum, nu, theta).unwrap();
            assert_eq!(zc.data(), zc_s.data(), "z_combine mat t={t}");
            assert_eq!(zp, zp_s, "z_combine prox t={t}");
            assert_eq!(zg, zg_s, "z_combine gsq t={t}");
            assert_eq!(
                par.z_prox_val(&zt, &pre, nu).unwrap(),
                zp_s,
                "z_prox_val t={t}"
            );
            assert_eq!(
                par.xent_loss(&pre, &y, &mask, denom).unwrap(),
                xl_s,
                "xent_loss t={t}"
            );
            let (zf, fl) = par
                .zl_fista(&pre, &u, &y, &mask, &zt, rho, denom, 7)
                .unwrap();
            assert_eq!(zf.data(), zf_s.data(), "zl_fista z t={t}");
            assert_eq!(fl, fl_s, "zl_fista loss t={t}");
            let (bl, bw, bh) = par.bp_out_grads(&pre, &wsq, &y, &mask, denom).unwrap();
            assert_eq!(bl, bl_s, "bp_out loss t={t}");
            assert_eq!(bw.data(), bw_s.data(), "bp_out dW t={t}");
            assert_eq!(bh.data(), bh_s.data(), "bp_out dH t={t}");
            assert_eq!(
                par.bp_hidden_grads(&pre, &wsq, &gsum).unwrap().data(),
                bg_s.data(),
                "bp_hidden t={t}"
            );
        }
    }

    #[test]
    fn spawn_executor_matches_pooled() {
        // The --op-spawn A/B path runs the identical kernels on scoped
        // threads: results must be bitwise equal to the pooled path.
        let mut rng = Rng::new(33);
        let pooled = NativeBackend::with_grain(4, 0);
        let spawn = NativeBackend::with_spawn_grain(4, 0);
        let x = Matrix::glorot(41, 19, &mut rng);
        let w = Matrix::glorot(19, 11, &mut rng);
        let zt = Matrix::glorot(41, 11, &mut rng);
        assert_eq!(
            pooled.mm_nn(&x, &w).unwrap().data(),
            spawn.mm_nn(&x, &w).unwrap().data()
        );
        let pre = pooled.mm_nn(&x, &w).unwrap();
        let (pv, pr) = pooled.hidden_residual(&pre, &zt, 0.3).unwrap();
        let (sv, sr) = spawn.hidden_residual(&pre, &zt, 0.3).unwrap();
        assert_eq!(pv, sv);
        assert_eq!(pr.data(), sr.data());
    }

    #[test]
    fn recycle_reuses_buffers_without_corruption() {
        // A recycled (dirty) buffer must not leak stale values into the
        // next op of the same shape: accumulating kernels re-zero, element-
        // wise kernels overwrite fully.
        let mut rng = Rng::new(34);
        let be = NativeBackend::with_threads(2);
        let x = Matrix::glorot(23, 9, &mut rng);
        let w = Matrix::glorot(9, 6, &mut rng);
        let want = x.matmul(&w);
        for _ in 0..4 {
            let got = be.mm_nn(&x, &w).unwrap();
            assert_eq!(got.data(), want.data());
            be.recycle(got);
        }
        let zt = Matrix::glorot(23, 6, &mut rng);
        let serial = NativeBackend::new();
        let want_r = serial.hidden_residual(&want, &zt, 0.2).unwrap();
        for _ in 0..4 {
            let (v, r) = be.hidden_residual(&want, &zt, 0.2).unwrap();
            assert_eq!(v, want_r.0);
            assert_eq!(r.data(), want_r.1.data());
            be.recycle(r);
        }
    }

    #[test]
    fn residual_formulas() {
        let mut rng = Rng::new(23);
        let be = NativeBackend::new();
        let pre = Matrix::glorot(6, 4, &mut rng);
        let zt = Matrix::glorot(6, 4, &mut rng);
        let nu = 0.37f32;
        let (val, r) = be.hidden_residual(&pre, &zt, nu).unwrap();
        let act = crate::tensor::relu(&pre);
        let d = act.sub(&zt);
        let want_val = 0.5 * nu * d.frob_norm_sq() as f32;
        assert!((val - want_val).abs() < 1e-5 * want_val.abs().max(1.0));
        let want_r = d
            .hadamard(&crate::tensor::relu_mask(&pre))
            .scale(nu);
        assert!(r.max_abs_diff(&want_r) < 1e-6);
        assert_eq!(be.hidden_phi(&pre, &zt, nu).unwrap(), val);

        let u = Matrix::glorot(6, 4, &mut rng);
        let rho = 0.05f32;
        let (oval, orr) = be.out_residual(&pre, &zt, &u, rho).unwrap();
        let dz = zt.sub(&pre);
        let want = u.dot(&dz) as f32 + 0.5 * rho * dz.frob_norm_sq() as f32;
        assert!((oval - want).abs() < 1e-5 * want.abs().max(1.0));
        let mut want_r = u.clone();
        want_r.axpy(rho, &dz);
        assert!(orr.max_abs_diff(&want_r.scale(-1.0)) < 1e-6);
        assert_eq!(be.out_phi(&pre, &zt, &u, rho).unwrap(), oval);
    }

    #[test]
    fn z_combine_matches_manual() {
        let mut rng = Rng::new(24);
        let be = NativeBackend::new();
        let z = Matrix::glorot(5, 3, &mut rng);
        let pin = Matrix::glorot(5, 3, &mut rng);
        let gsum = Matrix::glorot(5, 3, &mut rng);
        let (nu, theta) = (0.2f32, 1.5f32);
        let (znew, prox, gsq) = be.z_combine(&z, &pin, &gsum, nu, theta).unwrap();
        let fpin = crate::tensor::relu(&pin);
        let d = z.sub(&fpin);
        let g = d.scale(nu).add(&gsum);
        let want_z = z.sub(&g.scale(1.0 / theta));
        assert!(znew.max_abs_diff(&want_z) < 1e-6);
        assert!((prox - 0.5 * nu * d.frob_norm_sq() as f32).abs() < 1e-5);
        assert!((gsq - g.frob_norm_sq() as f32).abs() < 1e-4 * gsq.abs().max(1.0));
        assert_eq!(be.z_prox_val(&z, &pin, nu).unwrap(), prox);
    }

    #[test]
    fn xent_matches_host_cross_entropy() {
        let mut rng = Rng::new(25);
        let be = NativeBackend::new();
        let n = 12;
        let c = 4;
        let logits = Matrix::glorot(n, c, &mut rng).scale(3.0);
        let labels: Vec<usize> = (0..n).map(|_| rng.gen_range(c)).collect();
        let mut y = Matrix::zeros(n, c);
        let mut mask = vec![0.0f32; n];
        for i in 0..n {
            y.set(i, labels[i], 1.0);
            if rng.gen_bool(0.6) {
                mask[i] = 1.0;
            }
        }
        let denom: f32 = mask.iter().sum::<f32>().max(1.0);
        let got = be.xent_loss(&logits, &y, &mask, denom).unwrap();
        let (want, _) = crate::tensor::masked_cross_entropy(&logits, &labels, &mask);
        assert!(
            (got as f64 - want).abs() < 1e-5 * want.abs().max(1.0),
            "native {got} vs host {want}"
        );
    }

    #[test]
    fn fista_decreases_objective() {
        let mut rng = Rng::new(26);
        let be = NativeBackend::new();
        let n = 16;
        let c = 3;
        let q = Matrix::glorot(n, c, &mut rng);
        let u = Matrix::glorot(n, c, &mut rng).scale(0.05);
        let labels: Vec<usize> = (0..n).map(|_| rng.gen_range(c)).collect();
        let mut y = Matrix::zeros(n, c);
        let mask = vec![1.0f32; n];
        for i in 0..n {
            y.set(i, labels[i], 1.0);
        }
        let denom = n as f32;
        let rho = 0.1f32;
        let objective = |z: &Matrix| -> f64 {
            let (ce, _) = crate::tensor::masked_cross_entropy(z, &labels, &mask);
            let d = z.sub(&q);
            ce + u.dot(&d) + 0.5 * rho as f64 * d.frob_norm_sq()
        };
        let (z_new, _risk) = be
            .zl_fista(&q, &u, &y, &mask, &q, rho, denom, 10)
            .unwrap();
        assert!(
            objective(&z_new) < objective(&q) - 1e-6,
            "FISTA failed to decrease the eq.-7 objective"
        );
    }
}
