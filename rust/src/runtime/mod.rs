//! PJRT runtime bridge — loads the AOT-compiled HLO-text artifacts and
//! executes them from the training hot path.
//!
//! Python runs only at build time (`make artifacts`); this module is how
//! the Rust coordinator reaches the L2/L1 compute graphs afterwards:
//!
//! ```text
//! manifest.json ─► ArtifactMeta ─► (lazy) PjRtClient::compile ─► execute
//! ```
//!
//! Executables are compiled once per artifact signature and cached;
//! per-call timing is accumulated so the benchmark harness can separate
//! "XLA compute" from coordinator overhead.

mod engine;

pub use engine::{Engine, ExecStats, In, Out, Prepared};
