//! Execution backends for the dense training kernels.
//!
//! The trainers talk to a [`ComputeBackend`] trait object; the concrete
//! implementation is chosen at startup:
//!
//! ```text
//!                ┌──────────────────────────────┐
//!  AdmmTrainer   │ ComputeBackend               │   NativeBackend (always)
//!  baselines  ──►│  mm_nn/tn/bt · fwd_relu      │──► pure Rust, pool-
//!  transport     │  *_residual/phi · z_combine  │    parallel matmul/SpMM
//!  bench/eval    │  zl_fista · xent · bp_* ·    │
//!                │  spmm · warmup               │   XlaBackend (--features
//!                └──────────────────────────────┘──► xla): PJRT artifacts
//! ```
//!
//! With `--features xla`, [`Engine`] loads AOT-compiled HLO-text artifacts
//! (`make artifacts`; Python runs only at build time) and `XlaBackend`
//! maps each typed kernel call onto the artifact with the matching shape
//! signature. Without the feature the crate builds and trains with the
//! native backend alone — no artifacts, no registry, no Python.

mod backend;
#[cfg(feature = "xla")]
mod engine;

pub use backend::{
    default_backend, select_backend, select_backend_shared, xla_available, BackendChoice,
    ComputeBackend, NativeBackend, OpGrains,
};
#[cfg(feature = "xla")]
pub use backend::XlaBackend;
#[cfg(feature = "xla")]
pub use engine::{Engine, ExecStats, In, Out, Prepared};
