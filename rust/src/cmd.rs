//! CLI subcommand implementations (wired from `main.rs`).

use crate::config;
use crate::data::{self, Dataset};
use crate::partition::Method;
use crate::util::cli::Args;

/// Resolve a dataset by name — thin alias for [`data::load_by_name`].
pub fn load_dataset(name: &str, scale: f64, seed: u64) -> anyhow::Result<Dataset> {
    data::load_by_name(name, scale, seed)
}

/// `cgcn plan` — write configs/artifacts.json from the canonical shape plan.
pub fn cmd_plan(args: &Args) -> i32 {
    let hidden = args.get_usize("hidden");
    let scale: f64 = args.get_f64("scale");
    let out = match args.get("out") {
        Some("") | None => "configs/artifacts.json".to_string(),
        Some(p) => p.to_string(),
    };
    let datasets = config::default_plan_datasets(hidden, scale, vec![1, 3]);
    let json = config::plan_to_json(&datasets);
    if let Some(parent) = std::path::Path::new(&out).parent() {
        std::fs::create_dir_all(parent).ok();
    }
    match std::fs::write(&out, json.to_pretty() + "\n") {
        Ok(()) => {
            let n = json.get("artifacts").as_arr().map(|a| a.len()).unwrap_or(0);
            println!("wrote {n} artifact specs to {out}");
            0
        }
        Err(e) => {
            eprintln!("error writing {out}: {e}");
            1
        }
    }
}

/// `cgcn data` — dataset stats / generation / export.
pub fn cmd_data(args: &Args) -> i32 {
    let name = args.get_str("dataset");
    let scale = args.get_f64("scale");
    let seed = args.get_u64("seed");
    let ds = match load_dataset(&name, scale, seed) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("error: {e:#}");
            return 1;
        }
    };
    println!(
        "{:<18} {:>7} {:>8} {:>7} {:>7} {:>9} {:>9} {:>8}",
        "dataset", "nodes", "train", "test", "classes", "features", "edges", "avgdeg"
    );
    println!("{}", ds.stats_row());
    if let Some(out) = args.get("out").filter(|s| !s.is_empty()) {
        if let Err(e) = data::format::save(&ds, std::path::Path::new(out)) {
            eprintln!("error saving: {e:#}");
            return 1;
        }
        println!("saved to {out}");
    }
    0
}

/// `cgcn partition` — partition a dataset with any method, print a
/// partition-quality report (modularity, edge-cut, boundary volume,
/// conductance, balance), and optionally export the assignment
/// (`--partition-file`) for `train --partition-file` to reuse, or the
/// quality report as JSON (`--out`).
pub fn cmd_partition(args: &Args) -> i32 {
    let run = || -> anyhow::Result<()> {
        let name = args.get_str("dataset");
        let scale = args.get_f64("scale");
        let seed = args.get_u64("seed");
        let ds = load_dataset(&name, scale, seed)?;
        let m = args.get_usize("communities");
        anyhow::ensure!(
            (1..=ds.n()).contains(&m),
            "--communities {m} out of range for {} nodes",
            ds.n()
        );
        let method = parse_method(&args.get_str("partition"))?;
        // Louvain/LPA sweeps dispatch on a shared runtime; results are
        // bitwise identical at any thread budget.
        let budget = crate::util::pool::shared_thread_budget(
            args.get("threads").and_then(|s| s.parse().ok()).unwrap_or(0),
            args.get("op-threads").and_then(|s| s.parse().ok()).unwrap_or(0),
        );
        let rt = crate::util::pool::Runtime::new(budget);
        let t0 = std::time::Instant::now();
        let p = crate::partition::partition_with_runtime(&ds.graph, m, method, seed, Some(&rt));
        let detect_secs = t0.elapsed().as_secs_f64();
        let q = crate::community::evaluate(&ds.graph, &p, method.name());
        q.record_obs();
        println!(
            "partition {}: {} ({} nodes, {} edges) into {} communities in {:.3}s",
            method.name(),
            name,
            q.n,
            q.num_edges,
            q.m,
            detect_secs
        );
        println!("  modularity      {:.4}", q.modularity);
        println!(
            "  edge-cut        {} ({:.1}% of edges)",
            q.edge_cut,
            q.cut_fraction * 100.0
        );
        println!(
            "  boundary nodes  {} ({:.1}% of nodes)",
            q.boundary_nodes,
            q.boundary_nodes as f64 / (q.n.max(1)) as f64 * 100.0
        );
        println!(
            "  imbalance       {:.3} (sizes {}..{}, cap {})",
            q.imbalance,
            q.min_size,
            q.max_size,
            config::community_cap(q.n, q.m)
        );
        println!(
            "  conductance     max {:.3}  mean {:.3}",
            q.max_conductance, q.mean_conductance
        );
        if let Some(path) = args.get("partition-file").filter(|s| !s.is_empty()) {
            let pf = crate::community::PartitionFile {
                dataset: name.clone(),
                method: method.name().to_string(),
                seed,
                partition: p.clone(),
            };
            crate::community::save_partition_file(path, &pf)?;
            println!("wrote assignment to {path} (feed to train via --partition-file)");
        }
        if let Some(out) = args.get("out").filter(|s| !s.is_empty()) {
            let json = crate::util::json::Json::obj(vec![
                ("dataset", crate::util::json::Json::str(&name)),
                ("seed", crate::util::json::Json::num(seed as f64)),
                ("detect_secs", crate::util::json::Json::num(detect_secs)),
                ("quality", q.to_json()),
            ]);
            std::fs::write(out, json.to_pretty() + "\n")?;
            println!("wrote quality report to {out}");
        }
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("partition error: {e:#}");
            1
        }
    }
}

/// `cgcn artifacts` — list and compile-check artifacts (XLA backend only).
#[cfg(feature = "xla")]
pub fn cmd_artifacts(_args: &Args) -> i32 {
    let dir = crate::runtime::Engine::default_dir();
    let engine = match crate::runtime::Engine::load(&dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("error: {e:#}");
            return 1;
        }
    };
    println!("{} artifacts indexed in {}", engine.len(), dir.display());
    0
}

/// `cgcn artifacts` without the `xla` feature: nothing to index — the
/// native backend needs no artifacts.
#[cfg(not(feature = "xla"))]
pub fn cmd_artifacts(_args: &Args) -> i32 {
    println!("built without the `xla` feature — the native backend uses no artifacts");
    0
}

/// `cgcn train` — run one training configuration and print per-epoch
/// rows. `--method` selects full-batch ADMM/backprop or the stochastic
/// community mini-batch engine (`cluster-gcn`, with `--clusters` /
/// `--batch-clusters` controlling batch construction).
/// `--checkpoint-every N --checkpoint-dir D` writes resumable `.cgck`
/// training checkpoints; `--resume <path.cgck>` continues an interrupted
/// run with bitwise-identical results to an uninterrupted one.
pub fn cmd_train(args: &Args) -> i32 {
    match crate::coordinator::run_from_args(args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

/// `cgcn worker` — community worker process (TCP transport). Hosts one
/// community initially and adopts more when the elastic leader reassigns
/// a crashed peer's communities; heartbeats `Ping` frames so the leader
/// can tell "busy computing" from "dead".
pub fn cmd_worker(args: &Args) -> i32 {
    match crate::coordinator::transport::worker_main(args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("worker error: {e:#}");
            1
        }
    }
}

/// Parse the partition method CLI value.
pub fn parse_method(s: &str) -> anyhow::Result<Method> {
    Method::parse(s).ok_or_else(|| anyhow::anyhow!("unknown partition method '{s}'"))
}

// ---------------------------------------------------------------------------
// Serving subcommands
// ---------------------------------------------------------------------------

/// Load `--model`, rebuild its workspace, and bind an inference session
/// on the requested backend (`--backend`, `--runtime`, `--op-threads`).
fn open_session(args: &Args) -> anyhow::Result<crate::serve::InferenceSession> {
    let model = args.get_str("model");
    anyhow::ensure!(!model.is_empty(), "need --model <path.cgnm>");
    let snap = crate::serve::load_model(std::path::Path::new(&model))?;
    let choice = crate::runtime::BackendChoice::parse(&args.get_str("backend"))
        .ok_or_else(|| anyhow::anyhow!("unknown --backend value (auto|native|xla)"))?;
    let spawn_ops = args.get_flag("op-spawn");
    let op_threads_arg = args.get_usize("op-threads");
    // `cgcn serve` declares `--threads` (connection handlers); the other
    // session consumers (`query --verify`) do not — treat absent as 0.
    let conn_threads = args
        .get("threads")
        .and_then(|s| s.parse::<usize>().ok())
        .unwrap_or(0);
    let shared = match args.get("runtime").unwrap_or("shared") {
        "shared" => true,
        "dual" => false,
        other => anyhow::bail!("unknown --runtime '{other}' (shared|dual)"),
    };
    let backend = if shared {
        // One work-stealing runtime under one budget: connection
        // handlers and kernel forks share the same workers.
        let budget = crate::util::pool::shared_thread_budget(conn_threads, op_threads_arg);
        let rt = std::sync::Arc::new(crate::util::pool::Runtime::new(budget));
        crate::runtime::select_backend_shared(choice, rt, spawn_ops)?
    } else {
        // Dual mode: `--op-threads 0` auto-sizes to all cores;
        // request-level parallelism comes from the connection pool, so
        // heavy per-query batches still benefit from pooled kernels
        // past the flop grain.
        let op_threads = match op_threads_arg {
            0 => crate::util::pool::resolve_threads(0),
            n => n,
        };
        crate::runtime::select_backend(choice, op_threads, spawn_ops)?
    };
    log::info!(
        "model '{}' ({}, dims {:?}) on backend {} ({} runtime)",
        model,
        snap.meta.label,
        snap.dims,
        backend.name(),
        if shared { "shared" } else { "dual" }
    );
    crate::serve::InferenceSession::from_snapshot(&snap, backend)
}

/// The `--addr` a client subcommand should connect to; rejects the serve
/// bind default (an ephemeral port can't be guessed).
fn client_addr(args: &Args) -> anyhow::Result<String> {
    let addr = args.get_str("addr");
    anyhow::ensure!(
        !addr.is_empty() && !addr.ends_with(":0"),
        "need --addr <host:port> (the address the server printed)"
    );
    Ok(addr)
}

/// `cgcn serve` — load a model snapshot and run the batched inference
/// server until a client sends Shutdown.
pub fn cmd_serve(args: &Args) -> i32 {
    let run = || -> anyhow::Result<()> {
        let mut session = open_session(args)?;
        // Warm the whole activation cache up front so first-query latency
        // matches steady state.
        session.warm_all()?;
        let opts = crate::serve::ServeOptions {
            addr: args.get_str("addr"),
            threads: args.get_usize("threads"),
            batch_window_us: args.get_u64("batch-window-us"),
            max_batch: args.get_usize("max-batch"),
        };
        let n = session.n();
        let handle = crate::serve::serve(session, &opts)?;
        println!(
            "serving {} ({} nodes) on {} (window {}us, max batch {})",
            args.get_str("model"),
            n,
            handle.addr(),
            opts.batch_window_us,
            opts.max_batch
        );
        if let Some(path) = args.get("addr-file").filter(|s| !s.is_empty()) {
            std::fs::write(path, handle.addr().to_string())?;
        }
        handle.wait();
        println!("server stopped");
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("serve error: {e:#}");
            1
        }
    }
}

/// `cgcn query` — query a running server (`--nodes`), bitwise-verify it
/// against an in-process forward pass (`--verify`), or stop it
/// (`--shutdown-server`).
pub fn cmd_query(args: &Args) -> i32 {
    let run = || -> anyhow::Result<()> {
        let addr = client_addr(args)?;
        if args.get_flag("shutdown-server") {
            let mut client = crate::serve::ServeClient::connect(&addr)?;
            client.shutdown()?;
            println!("server at {addr} acknowledged shutdown");
            return Ok(());
        }
        if args.get_flag("verify") {
            // Do the slow local work (workspace rebuild + full forward)
            // *before* connecting — an open-but-silent socket would trip
            // the server's idle timeout on large models.
            let mut session = open_session(args)?;
            let full = session.full_logits()?;
            let mut client = crate::serve::ServeClient::connect(&addr)?;
            let info = client.info()?;
            anyhow::ensure!(
                info.n == session.n(),
                "server has {} nodes, local model has {}",
                info.n,
                session.n()
            );
            let ids: Vec<usize> = (0..info.n).collect();
            for chunk in ids.chunks(256) {
                let rows = client.query(chunk)?;
                anyhow::ensure!(
                    rows.len() == chunk.len(),
                    "short response: {} rows for {} nodes",
                    rows.len(),
                    chunk.len()
                );
                for (row, &id) in rows.iter().zip(chunk) {
                    // Compare representations, not values: the guarantee
                    // is bitwise identity, and f32 `==` would reject
                    // byte-identical NaNs (and accept 0.0 vs -0.0).
                    let local = full.row(id);
                    let bits_eq = row.len() == local.len()
                        && row.iter().zip(local).all(|(a, b)| a.to_bits() == b.to_bits());
                    anyhow::ensure!(
                        bits_eq,
                        "logits mismatch at node {id}: served {:?} != local {:?}",
                        row,
                        local
                    );
                }
            }
            println!(
                "verify OK: {} nodes, served logits bitwise-identical to the in-process forward pass",
                info.n
            );
            return Ok(());
        }
        let nodes = args.get_list_usize("nodes");
        anyhow::ensure!(
            !nodes.is_empty(),
            "query needs --nodes <id,id,...> (or --verify / --shutdown-server)"
        );
        let mut client = crate::serve::ServeClient::connect(&addr)?;
        let rows = client.query(&nodes)?;
        anyhow::ensure!(
            rows.len() == nodes.len(),
            "short response: {} rows for {} nodes",
            rows.len(),
            nodes.len()
        );
        println!("{:>8} {:>6}  logits", "node", "class");
        for (row, &id) in rows.iter().zip(&nodes) {
            let class = crate::tensor::argmax(row);
            let logits: Vec<String> = row.iter().map(|v| format!("{v:.4}")).collect();
            println!("{id:>8} {class:>6}  [{}]", logits.join(", "));
        }
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("query error: {e:#}");
            1
        }
    }
}

/// `cgcn stats` — scrape a running inference server: the serve counter
/// block (Stats frame) plus the server process's whole metrics registry
/// as Prometheus-style text (Metrics frame), which includes request
/// latency quantiles. `--out` also writes the text to a file.
pub fn cmd_stats(args: &Args) -> i32 {
    let run = || -> anyhow::Result<()> {
        let addr = client_addr(args)?;
        let mut client = crate::serve::ServeClient::connect(&addr)?;
        let c = client.stats()?;
        println!(
            "server {addr}: requests {}  nodes {}  batches {}  cache warms {}",
            c.requests, c.nodes, c.batches, c.warms
        );
        let text = client.metrics()?;
        print!("{text}");
        if let Some(out) = args.get("out").filter(|s| !s.is_empty()) {
            std::fs::write(out, &text)?;
            eprintln!("wrote metrics text to {out}");
        }
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("stats error: {e:#}");
            1
        }
    }
}

/// `cgcn loadgen` — closed-loop load against a running server; prints
/// qps + latency percentiles, optional JSON to `--out`.
pub fn cmd_loadgen(args: &Args) -> i32 {
    let run = || -> anyhow::Result<()> {
        let addr = client_addr(args)?;
        let info = crate::serve::ServeClient::connect(&addr)?.info()?;
        let opts = crate::serve::LoadgenOpts {
            clients: args.get_usize("clients"),
            requests_per_client: args.get_usize("requests"),
            nodes_per_query: args.get_usize("nodes-per-query"),
            seed: args.get_u64("seed"),
        };
        let r = crate::serve::loadgen::run(&addr, info.n, &opts)?;
        println!(
            "{} clients x {} reqs ({} nodes/query) against {} ({} nodes)",
            r.clients, opts.requests_per_client, opts.nodes_per_query, addr, info.n
        );
        println!(
            "qps {:.0}  latency p50 {:.3}ms  p99 {:.3}ms  mean {:.3}ms  wall {:.2}s",
            r.qps,
            r.latency.p50 * 1e3,
            r.latency.p99 * 1e3,
            r.latency.mean * 1e3,
            r.wall_secs
        );
        if let Some(out) = args.get("out").filter(|s| !s.is_empty()) {
            let json = crate::util::json::Json::obj(vec![
                ("clients", crate::util::json::Json::num(r.clients as f64)),
                ("requests", crate::util::json::Json::num(r.requests as f64)),
                ("qps", crate::util::json::Json::num(r.qps)),
                ("p50_ms", crate::util::json::Json::num(r.latency.p50 * 1e3)),
                ("p99_ms", crate::util::json::Json::num(r.latency.p99 * 1e3)),
            ]);
            std::fs::write(out, json.to_pretty() + "\n")?;
        }
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("loadgen error: {e:#}");
            1
        }
    }
}
