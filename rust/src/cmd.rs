//! CLI subcommand implementations (wired from `main.rs`).

use crate::config;
use crate::data::{self, synth, Dataset};
use crate::partition::Method;
use crate::util::cli::Args;

/// Resolve a dataset by name: synthetic spec, fixture, or `.cgnp` path.
pub fn load_dataset(name: &str, scale: f64, seed: u64) -> anyhow::Result<Dataset> {
    if let Some(spec) = synth::spec_by_name(name) {
        return Ok(synth::generate(&spec, scale, seed));
    }
    match name {
        "fig1" => Ok(data::fixtures::fig1()),
        "caveman" | "caveman-l3" => Ok(data::fixtures::caveman(24, seed)),
        path if path.ends_with(".cgnp") => data::format::load(std::path::Path::new(path)),
        other => anyhow::bail!(
            "unknown dataset '{other}' (try synth-computers, synth-photo, fig1, caveman, or a .cgnp path)"
        ),
    }
}

/// `cgcn plan` — write configs/artifacts.json from the canonical shape plan.
pub fn cmd_plan(args: &Args) -> i32 {
    let hidden = args.get_usize("hidden");
    let scale: f64 = args.get_f64("scale");
    let out = match args.get("out") {
        Some("") | None => "configs/artifacts.json".to_string(),
        Some(p) => p.to_string(),
    };
    let datasets = config::default_plan_datasets(hidden, scale, vec![1, 3]);
    let json = config::plan_to_json(&datasets);
    if let Some(parent) = std::path::Path::new(&out).parent() {
        std::fs::create_dir_all(parent).ok();
    }
    match std::fs::write(&out, json.to_pretty() + "\n") {
        Ok(()) => {
            let n = json.get("artifacts").as_arr().map(|a| a.len()).unwrap_or(0);
            println!("wrote {n} artifact specs to {out}");
            0
        }
        Err(e) => {
            eprintln!("error writing {out}: {e}");
            1
        }
    }
}

/// `cgcn data` — dataset stats / generation / export.
pub fn cmd_data(args: &Args) -> i32 {
    let name = args.get_str("dataset");
    let scale = args.get_f64("scale");
    let seed = args.get_u64("seed");
    let ds = match load_dataset(&name, scale, seed) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("error: {e:#}");
            return 1;
        }
    };
    println!(
        "{:<18} {:>7} {:>8} {:>7} {:>7} {:>9} {:>9} {:>8}",
        "dataset", "nodes", "train", "test", "classes", "features", "edges", "avgdeg"
    );
    println!("{}", ds.stats_row());
    if let Some(out) = args.get("out").filter(|s| !s.is_empty()) {
        if let Err(e) = data::format::save(&ds, std::path::Path::new(out)) {
            eprintln!("error saving: {e:#}");
            return 1;
        }
        println!("saved to {out}");
    }
    0
}

/// `cgcn artifacts` — list and compile-check artifacts (XLA backend only).
#[cfg(feature = "xla")]
pub fn cmd_artifacts(_args: &Args) -> i32 {
    let dir = crate::runtime::Engine::default_dir();
    let engine = match crate::runtime::Engine::load(&dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("error: {e:#}");
            return 1;
        }
    };
    println!("{} artifacts indexed in {}", engine.len(), dir.display());
    0
}

/// `cgcn artifacts` without the `xla` feature: nothing to index — the
/// native backend needs no artifacts.
#[cfg(not(feature = "xla"))]
pub fn cmd_artifacts(_args: &Args) -> i32 {
    println!("built without the `xla` feature — the native backend uses no artifacts");
    0
}

/// `cgcn train` — run one training configuration and print per-epoch rows.
pub fn cmd_train(args: &Args) -> i32 {
    match crate::coordinator::run_from_args(args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

/// `cgcn worker` — community worker process (TCP transport).
pub fn cmd_worker(args: &Args) -> i32 {
    match crate::coordinator::transport::worker_main(args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("worker error: {e:#}");
            1
        }
    }
}

/// Parse the partition method CLI value.
pub fn parse_method(s: &str) -> anyhow::Result<Method> {
    Method::parse(s).ok_or_else(|| anyhow::anyhow!("unknown partition method '{s}'"))
}
