//! Benchmark harness — criterion is not resolvable offline, so `cargo
//! bench` targets (`benches/*.rs`, `harness = false`) use this module:
//! warmup + timed iterations + robust summary statistics, plus table
//! printing helpers shared by the paper-reproduction benches.

use crate::util::stats::Summary;
use std::time::Instant;

/// Configuration for a micro-benchmark run.
#[derive(Clone, Copy, Debug)]
pub struct BenchOpts {
    pub warmup_iters: usize,
    pub iters: usize,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts {
            warmup_iters: 3,
            iters: 10,
        }
    }
}

/// Time `f` (seconds per iteration) with warmup; returns a summary.
pub fn bench<T>(opts: BenchOpts, mut f: impl FnMut() -> T) -> Summary {
    for _ in 0..opts.warmup_iters {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(opts.iters);
    for _ in 0..opts.iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    Summary::of(&samples)
}

/// Print one bench row: name, mean ± std, p50, min.
pub fn report_row(name: &str, s: &Summary) {
    println!(
        "{name:<44} {:>10} ±{:>9}  p50 {:>10}  min {:>10}",
        fmt_secs(s.mean),
        fmt_secs(s.std),
        fmt_secs(s.p50),
        fmt_secs(s.min)
    );
}

/// Human duration: ns/µs/ms/s with 3 significant digits.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.2}s", s)
    }
}

/// Section header for bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// GFLOP/s helper for matmul-ish kernels.
pub fn gflops(flops: f64, secs: f64) -> f64 {
    flops / secs / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let s = bench(
            BenchOpts {
                warmup_iters: 1,
                iters: 5,
            },
            || {
                let mut x = 0u64;
                for i in 0..10_000 {
                    x = x.wrapping_add(i * i);
                }
                x
            },
        );
        assert_eq!(s.n, 5);
        assert!(s.mean > 0.0);
        assert!(s.min <= s.mean && s.mean <= s.max);
    }

    #[test]
    fn fmt_secs_units() {
        assert!(fmt_secs(5e-9).ends_with("ns"));
        assert!(fmt_secs(5e-6).ends_with("µs"));
        assert!(fmt_secs(5e-3).ends_with("ms"));
        assert!(fmt_secs(5.0).ends_with('s'));
    }
}
