//! Partition-quality analytics: modularity, edge-cut, boundary volume,
//! per-community conductance, and balance for **any** [`Partition`] —
//! the numbers the paper's argument rests on (dense communities ⇒ small
//! `p`/`s` boundary messages ⇒ cheap distributed ADMM).
//!
//! One entry point: [`evaluate`] walks the edge list once and the nodes
//! once, so it is O(E + V) and safe to run on every training startup.
//! Reports export as JSON ([`QualityReport::to_json`]) and, behind the
//! `CGCN_OBS` gate, as `cgcn_partition_*` gauges ([`QualityReport::record_obs`]).

use crate::graph::Graph;
use crate::partition::Partition;
use crate::util::json::Json;

/// Quality metrics for one (graph, partition) pair.
#[derive(Clone, Debug)]
pub struct QualityReport {
    /// Partitioner name ("louvain", "metis", …) — label only.
    pub method: String,
    pub n: usize,
    pub m: usize,
    /// Undirected edge count of the graph.
    pub num_edges: usize,
    /// Newman modularity Q = Σ_c [l_c/E − (d_c/2E)²] ∈ [−0.5, 1).
    pub modularity: f64,
    /// Edges with endpoints in different communities.
    pub edge_cut: usize,
    /// edge_cut / num_edges (0 when the graph has no edges).
    pub cut_fraction: f64,
    /// Nodes with at least one neighbor in another community — the `p`/`s`
    /// exchange set of the paper's ADMM formulation.
    pub boundary_nodes: usize,
    /// max community size / ideal size (1.0 = perfectly balanced).
    pub imbalance: f64,
    pub min_size: usize,
    pub max_size: usize,
    /// Per-community conductance cut(c)/min(vol(c), vol(V∖c)) ∈ [0, 1].
    pub conductance: Vec<f64>,
    pub max_conductance: f64,
    pub mean_conductance: f64,
}

/// Compute every quality metric for `p` over `g` in one O(E + V) pass.
pub fn evaluate(g: &Graph, p: &Partition, method: &str) -> QualityReport {
    let n = g.n();
    let m = p.m();
    let e = g.num_edges();
    // Per-community tallies: intra edges, cut edges, degree volume.
    let mut intra = vec![0u64; m];
    let mut cut = vec![0u64; m];
    let mut vol = vec![0u64; m];
    let mut edge_cut = 0usize;
    for &(u, v) in g.edges() {
        let (cu, cv) = (p.assignment[u as usize], p.assignment[v as usize]);
        if cu == cv {
            intra[cu] += 1;
        } else {
            edge_cut += 1;
            cut[cu] += 1;
            cut[cv] += 1;
        }
    }
    for v in 0..n {
        vol[p.assignment[v]] += g.degree(v) as u64;
    }
    let total_vol: u64 = vol.iter().sum(); // = 2E
    let modularity = if e == 0 {
        0.0
    } else {
        let ef = e as f64;
        (0..m)
            .map(|c| intra[c] as f64 / ef - (vol[c] as f64 / (2.0 * ef)).powi(2))
            .sum()
    };
    let conductance: Vec<f64> = (0..m)
        .map(|c| {
            let denom = vol[c].min(total_vol - vol[c]);
            if denom == 0 {
                0.0
            } else {
                cut[c] as f64 / denom as f64
            }
        })
        .collect();
    let boundary_nodes = (0..n)
        .filter(|&v| {
            let c = p.assignment[v];
            g.neighbors(v)
                .iter()
                .any(|&u| p.assignment[u as usize] != c)
        })
        .count();
    let sizes = p.sizes();
    QualityReport {
        method: method.to_string(),
        n,
        m,
        num_edges: e,
        modularity,
        edge_cut,
        cut_fraction: if e == 0 { 0.0 } else { edge_cut as f64 / e as f64 },
        boundary_nodes,
        imbalance: p.imbalance(n),
        min_size: sizes.iter().copied().min().unwrap_or(0),
        max_size: sizes.iter().copied().max().unwrap_or(0),
        max_conductance: conductance.iter().copied().fold(0.0, f64::max),
        mean_conductance: if m == 0 {
            0.0
        } else {
            conductance.iter().sum::<f64>() / m as f64
        },
        conductance,
    }
}

impl QualityReport {
    /// Serialise the full report (per-community conductances included).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("method", Json::str(&self.method)),
            ("n", Json::num(self.n as f64)),
            ("m", Json::num(self.m as f64)),
            ("num_edges", Json::num(self.num_edges as f64)),
            ("modularity", Json::num(self.modularity)),
            ("edge_cut", Json::num(self.edge_cut as f64)),
            ("cut_fraction", Json::num(self.cut_fraction)),
            ("boundary_nodes", Json::num(self.boundary_nodes as f64)),
            ("imbalance", Json::num(self.imbalance)),
            ("min_size", Json::num(self.min_size as f64)),
            ("max_size", Json::num(self.max_size as f64)),
            ("max_conductance", Json::num(self.max_conductance)),
            ("mean_conductance", Json::num(self.mean_conductance)),
            (
                "conductance",
                Json::arr(self.conductance.iter().map(|&c| Json::num(c)).collect()),
            ),
        ])
    }

    /// Export the scalar metrics as `cgcn_partition_*` gauges. Gauges are
    /// integral, so float metrics are milli-scaled (modularity 0.413 →
    /// 413). No-op (one load + branch inside `Gauge::set`) unless
    /// `CGCN_OBS` is on.
    pub fn record_obs(&self) {
        let milli = |x: f64| (x * 1000.0).round() as i64;
        crate::obs_gauge!("cgcn_partition_communities").set(self.m as i64);
        crate::obs_gauge!("cgcn_partition_modularity_milli").set(milli(self.modularity));
        crate::obs_gauge!("cgcn_partition_edge_cut").set(self.edge_cut as i64);
        crate::obs_gauge!("cgcn_partition_boundary_nodes").set(self.boundary_nodes as i64);
        crate::obs_gauge!("cgcn_partition_imbalance_milli").set(milli(self.imbalance));
        crate::obs_gauge!("cgcn_partition_max_conductance_milli").set(milli(self.max_conductance));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::fixtures;
    use crate::partition::{partition, Method};

    #[test]
    fn single_community_has_zero_modularity_and_cut() {
        let ds = fixtures::fig1();
        let p = partition(&ds.graph, 1, Method::Metis, 0);
        let q = evaluate(&ds.graph, &p, "metis");
        assert_eq!(q.edge_cut, 0);
        assert_eq!(q.boundary_nodes, 0);
        assert!(q.modularity.abs() < 1e-12, "Q = {}", q.modularity);
        assert_eq!(q.conductance, vec![0.0]);
    }

    #[test]
    fn planted_split_beats_random_on_every_metric() {
        let ds = fixtures::caveman(20, 8);
        let good = partition(&ds.graph, 2, Method::Metis, 1);
        let bad = partition(&ds.graph, 2, Method::Random, 1);
        let qg = evaluate(&ds.graph, &good, "metis");
        let qb = evaluate(&ds.graph, &bad, "random");
        assert!(qg.modularity > qb.modularity, "{} <= {}", qg.modularity, qb.modularity);
        assert!(qg.edge_cut < qb.edge_cut);
        assert!(qg.boundary_nodes <= qb.boundary_nodes);
        assert!(qg.max_conductance < qb.max_conductance);
    }

    #[test]
    fn conductance_bounded_and_cut_consistent() {
        let ds = fixtures::caveman(15, 2);
        for m in [2, 3, 4] {
            for method in [Method::Metis, Method::Random, Method::Bfs] {
                let p = partition(&ds.graph, m, method, 9);
                let q = evaluate(&ds.graph, &p, method.name());
                assert!(q.conductance.iter().all(|&c| (0.0..=1.0).contains(&c)));
                assert_eq!(q.edge_cut, p.edgecut(&ds.graph));
                assert!(q.cut_fraction <= 1.0);
                assert!(q.boundary_nodes <= ds.n());
            }
        }
    }

    #[test]
    fn report_json_roundtrips() {
        let ds = fixtures::fig1();
        let p = partition(&ds.graph, 3, Method::Metis, 0);
        let q = evaluate(&ds.graph, &p, "metis");
        let back = Json::parse(&q.to_json().to_pretty()).unwrap();
        assert_eq!(back.get("method").as_str().unwrap(), "metis");
        assert_eq!(back.get("m").as_usize().unwrap(), 3);
        let qj = back.get("modularity").as_f64().unwrap();
        assert!((qj - q.modularity).abs() < 1e-9);
        assert_eq!(back.get("conductance").as_arr().unwrap().len(), 3);
    }

    #[test]
    fn edgeless_graph_reports_zeros() {
        let g = Graph::from_edges(4, &[]);
        let p = Partition::from_assignment(2, vec![0, 0, 1, 1]);
        let q = evaluate(&g, &p, "test");
        assert_eq!(q.modularity, 0.0);
        assert_eq!(q.cut_fraction, 0.0);
        assert_eq!(q.max_conductance, 0.0);
    }
}
