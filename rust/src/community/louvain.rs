//! Louvain modularity maximization [Blondel et al. '08], deterministic at
//! any thread count.
//!
//! Each level runs local-moving sweeps in two phases (DESIGN.md §13):
//!
//! 1. **Proposal** — for every node, the best-gain community among its
//!    neighbors is computed against the state *frozen at sweep start*
//!    (assignment + per-community strength totals). Each proposal is a
//!    pure function of that frozen state with one writer per output
//!    element, so the pass dispatches on the shared [`Runtime`] and is
//!    bitwise identical to the serial loop at any thread count.
//! 2. **Application** — proposals are applied serially in ascending node
//!    order, revalidating each move's gain against the *live* state and
//!    skipping moves whose gain is no longer positive. Every applied move
//!    strictly increases modularity, so sweeps cannot oscillate and each
//!    level terminates at a genuine local optimum (a sweep that applies
//!    no moves saw live == frozen state, i.e. no positive-gain move
//!    exists).
//!
//! Ties between equal-gain target communities always break to the lowest
//! community id; the node-visit order is fixed (ascending id); no RNG is
//! consulted anywhere — the detector is a pure function of the graph.
//!
//! After local moving converges the communities are contracted into a
//! weighted coarse graph (self-loops carry intra-community weight, both
//! directions) and the next level repeats, exactly as in the original
//! multilevel scheme.

use crate::graph::Graph;
use crate::util::pool::{uniform_chunks, Runtime, SendPtr};
use std::collections::HashMap;

/// Safety cap on local-moving sweeps per level. Convergence normally
/// stops the loop long before this (each sweep strictly increases Q).
const MAX_SWEEPS: usize = 64;
/// Safety cap on aggregation levels (each level shrinks the graph).
const MAX_LEVELS: usize = 16;
/// A move must beat this modularity-gain threshold (in the unnormalised
/// `ΔQ · 2m` scale) to be taken — filters float dust near local optima.
const GAIN_EPS: f64 = 1e-9;
/// Below this many nodes a proposal pass runs serially even when a
/// runtime is available (dispatch overhead beats the scan).
const PAR_MIN_NODES: usize = 512;

/// Weighted multigraph for aggregation levels. Level 0 is the input graph
/// (unit edge weights, no self-loops); coarser levels accumulate weights.
struct WGraph {
    /// adj[u] = (neighbor, weight), neighbor-sorted, no self entries.
    adj: Vec<Vec<(u32, u64)>>,
    /// Self-loop weight (counts both directions: 2 × intra weight).
    self_w: Vec<u64>,
    /// Strength k_u = self_w[u] + Σ adjacent weights.
    node_w: Vec<u64>,
    /// Σ node_w — the `2m` normaliser, invariant across levels.
    total_w: u64,
}

impl WGraph {
    fn n(&self) -> usize {
        self.node_w.len()
    }

    fn from_graph(g: &Graph) -> WGraph {
        let adj: Vec<Vec<(u32, u64)>> = (0..g.n())
            .map(|u| g.neighbors(u).iter().map(|&v| (v, 1u64)).collect())
            .collect();
        let node_w: Vec<u64> = (0..g.n()).map(|u| g.degree(u) as u64).collect();
        let total_w = node_w.iter().sum();
        WGraph {
            adj,
            self_w: vec![0; g.n()],
            node_w,
            total_w,
        }
    }

    /// Contract each community into one coarse vertex. `comm` must be
    /// compact (values 0..ncomm). Edge weights between communities sum;
    /// intra-community weight (both directions) plus member self-loops
    /// become the coarse self-loop, so `total_w` is preserved.
    fn aggregate(&self, comm: &[usize], ncomm: usize) -> WGraph {
        let mut self_w = vec![0u64; ncomm];
        let mut acc: Vec<HashMap<u32, u64>> = vec![HashMap::new(); ncomm];
        for u in 0..self.n() {
            let cu = comm[u];
            self_w[cu] += self.self_w[u];
            for &(v, w) in &self.adj[u] {
                let cv = comm[v as usize];
                if cu == cv {
                    // Each intra edge appears from both endpoints, so this
                    // accumulates 2× the intra weight — the self-loop
                    // convention node_w expects.
                    self_w[cu] += w;
                } else {
                    *acc[cu].entry(cv as u32).or_insert(0) += w;
                }
            }
        }
        let mut adj: Vec<Vec<(u32, u64)>> = Vec::with_capacity(ncomm);
        for h in acc {
            let mut row: Vec<(u32, u64)> = h.into_iter().collect();
            row.sort_unstable_by_key(|&(v, _)| v);
            adj.push(row);
        }
        let node_w: Vec<u64> = (0..ncomm)
            .map(|c| self_w[c] + adj[c].iter().map(|&(_, w)| w).sum::<u64>())
            .collect();
        let total_w = node_w.iter().sum();
        debug_assert_eq!(total_w, self.total_w, "aggregation lost weight");
        WGraph {
            adj,
            self_w,
            node_w,
            total_w,
        }
    }
}

/// Best-move proposal for node `v` against the frozen (comm, tot) state:
/// the neighboring community with the highest modularity gain (strictly
/// positive, ties to the lowest community id), or `comm[v]` to stay.
fn propose_one(wg: &WGraph, comm: &[usize], tot: &[u64], m2: f64, v: usize) -> usize {
    let a = comm[v];
    if wg.adj[v].is_empty() {
        return a;
    }
    // Accumulate v's edge weight into each adjacent community. Candidate
    // order is first-seen (CSR neighbor order) but the winner is selected
    // by (gain, lowest id), so iteration order cannot change the result.
    let mut cand: Vec<usize> = Vec::new();
    let mut wto: HashMap<usize, u64> = HashMap::new();
    for &(u, w) in &wg.adj[v] {
        let c = comm[u as usize];
        match wto.entry(c) {
            std::collections::hash_map::Entry::Occupied(mut e) => *e.get_mut() += w,
            std::collections::hash_map::Entry::Vacant(e) => {
                e.insert(w);
                cand.push(c);
            }
        }
    }
    let kv = wg.node_w[v] as f64;
    let ka = wto.get(&a).copied().unwrap_or(0) as f64;
    let tot_a_less_v = (tot[a] - wg.node_w[v]) as f64;
    let mut best: Option<(usize, f64)> = None;
    for &c in &cand {
        if c == a {
            continue;
        }
        let kc = wto[&c] as f64;
        // ΔQ · 2m for moving v from a to c (v's own self-loop travels
        // with it and cancels out of the difference).
        let gain = (kc - ka) - kv * (tot[c] as f64 - tot_a_less_v) / m2;
        let better = match best {
            None => true,
            Some((bc, bg)) => gain > bg || (gain == bg && c < bc),
        };
        if better {
            best = Some((c, gain));
        }
    }
    match best {
        Some((c, g)) if g > GAIN_EPS => c,
        _ => a,
    }
}

/// The frozen-state proposal pass over all nodes — serial, or dispatched
/// on the runtime in disjoint index chunks (one writer per element, same
/// scalar loop, so results are bitwise identical either way).
fn propose_all(
    wg: &WGraph,
    comm: &[usize],
    tot: &[u64],
    m2: f64,
    rt: Option<&Runtime>,
) -> Vec<usize> {
    let n = wg.n();
    let mut props = vec![0usize; n];
    match rt {
        Some(rt) if rt.threads() > 1 && n >= PAR_MIN_NODES => {
            let chunks = uniform_chunks(rt.threads() * 4, n);
            let ptr = SendPtr::new(props.as_mut_ptr());
            rt.run(chunks.len(), &|ci| {
                let (lo, hi) = chunks[ci];
                for v in lo..hi {
                    // SAFETY: chunks are disjoint and `props` outlives the
                    // blocking dispatch; element v has exactly one writer.
                    unsafe {
                        *ptr.get().add(v) = propose_one(wg, comm, tot, m2, v);
                    }
                }
            });
        }
        _ => {
            for (v, p) in props.iter_mut().enumerate() {
                *p = propose_one(wg, comm, tot, m2, v);
            }
        }
    }
    props
}

/// Exact live-state edge weight from `v` to communities `a` and `b`.
fn weight_to(wg: &WGraph, comm: &[usize], v: usize, a: usize, b: usize) -> (u64, u64) {
    let (mut wa, mut wb) = (0u64, 0u64);
    for &(u, w) in &wg.adj[v] {
        let c = comm[u as usize];
        if c == a {
            wa += w;
        } else if c == b {
            wb += w;
        }
    }
    (wa, wb)
}

/// One level of local moving. Returns the compacted community assignment
/// (ids renumbered by first occurrence in node order).
fn local_moving(wg: &WGraph, rt: Option<&Runtime>) -> Vec<usize> {
    let n = wg.n();
    let m2 = wg.total_w as f64;
    let mut comm: Vec<usize> = (0..n).collect();
    let mut tot: Vec<u64> = wg.node_w.clone();
    for sweep in 0..MAX_SWEEPS {
        let _span = crate::span!("community.louvain.local_move", sweep = sweep);
        let props = propose_all(wg, &comm, &tot, m2, rt);
        let mut moves = 0usize;
        for v in 0..n {
            let b = props[v];
            let a = comm[v];
            if b == a {
                continue;
            }
            // Revalidate against the live state: earlier moves this sweep
            // may have changed both communities since the proposal froze.
            let (wa, wb) = weight_to(wg, &comm, v, a, b);
            let kv = wg.node_w[v] as f64;
            let gain = (wb as f64 - wa as f64)
                - kv * (tot[b] as f64 - (tot[a] - wg.node_w[v]) as f64) / m2;
            if gain > GAIN_EPS {
                tot[a] -= wg.node_w[v];
                tot[b] += wg.node_w[v];
                comm[v] = b;
                moves += 1;
            }
        }
        crate::obs_counter!("community.louvain.moves").add(moves as u64);
        if moves == 0 {
            break;
        }
    }
    compact(&comm)
}

/// Renumber arbitrary labels to 0..k by first occurrence in index order.
pub(crate) fn compact(labels: &[usize]) -> Vec<usize> {
    let mut map: HashMap<usize, usize> = HashMap::new();
    let mut out = Vec::with_capacity(labels.len());
    for &l in labels {
        let next = map.len();
        out.push(*map.entry(l).or_insert(next));
    }
    out
}

/// Multilevel Louvain community detection. Returns one compact community
/// label per node (0..k in first-occurrence order). Deterministic: fixed
/// visit order, lowest-id tie-breaking, no RNG — and bitwise identical
/// with `rt` at any thread count (the parallel pass is pure per element).
pub fn louvain(g: &Graph, rt: Option<&Runtime>) -> Vec<usize> {
    let n = g.n();
    if n == 0 || g.num_edges() == 0 {
        // No edges: modularity is undefined (2m = 0); every node is its
        // own community and the merge step packs them.
        return (0..n).collect();
    }
    let mut wg = WGraph::from_graph(g);
    let mut labels: Vec<usize> = (0..n).collect();
    for level in 0..MAX_LEVELS {
        let _span = crate::span!("community.louvain.level", level = level);
        let comm = local_moving(&wg, rt);
        let ncomm = comm.iter().copied().max().map_or(0, |c| c + 1);
        if ncomm == wg.n() {
            break; // no node moved — a local optimum at this level
        }
        for l in labels.iter_mut() {
            *l = comm[*l];
        }
        if ncomm <= 1 {
            break;
        }
        wg = wg.aggregate(&comm, ncomm);
    }
    compact(&labels)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::fixtures;

    #[test]
    fn two_cliques_become_two_communities() {
        // Two K4s joined by one bridge edge.
        let mut edges = Vec::new();
        for a in 0..4usize {
            for b in (a + 1)..4 {
                edges.push((a, b));
                edges.push((a + 4, b + 4));
            }
        }
        edges.push((0, 4));
        let g = Graph::from_edges(8, &edges);
        let labels = louvain(&g, None);
        assert_eq!(labels.len(), 8);
        let k = labels.iter().copied().max().unwrap() + 1;
        assert_eq!(k, 2, "labels {labels:?}");
        assert!(labels[0..4].iter().all(|&l| l == labels[0]));
        assert!(labels[4..8].iter().all(|&l| l == labels[4]));
        assert_ne!(labels[0], labels[4]);
    }

    #[test]
    fn caveman_communities_respect_cave_boundary() {
        // Two dense caves of 12 joined by 2 bridges: no detected community
        // may straddle the bridge (each community lives inside one cave).
        let ds = fixtures::caveman(12, 4);
        let labels = louvain(&ds.graph, None);
        let k = labels.iter().copied().max().unwrap() + 1;
        assert!((2..=6).contains(&k), "unexpected community count {k}");
        for c in 0..k {
            let members: Vec<usize> = (0..24).filter(|&v| labels[v] == c).collect();
            assert!(!members.is_empty());
            let in_first = members[0] < 12;
            assert!(
                members.iter().all(|&v| (v < 12) == in_first),
                "community {c} straddles the bridge: {labels:?}"
            );
        }
    }

    #[test]
    fn edgeless_graph_is_all_singletons() {
        let g = Graph::from_edges(5, &[]);
        assert_eq!(louvain(&g, None), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn parallel_dispatch_matches_serial_exactly() {
        let ds = crate::data::synth::generate(&crate::data::synth::AMAZON_PHOTO, 0.1, 9);
        let serial = louvain(&ds.graph, None);
        for t in [1usize, 2, 8] {
            let rt = Runtime::new(t);
            let par = louvain(&ds.graph, Some(&rt));
            assert_eq!(serial, par, "louvain diverged at {t} threads");
        }
    }

    #[test]
    fn aggregate_preserves_total_weight() {
        let ds = fixtures::caveman(10, 3);
        let wg = WGraph::from_graph(&ds.graph);
        let comm = local_moving(&wg, None);
        let ncomm = comm.iter().copied().max().unwrap() + 1;
        let coarse = wg.aggregate(&comm, ncomm);
        assert_eq!(coarse.total_w, wg.total_w);
        assert_eq!(coarse.n(), ncomm);
    }

    #[test]
    fn compact_renumbers_by_first_occurrence() {
        assert_eq!(compact(&[7, 7, 3, 7, 9]), vec![0, 0, 1, 0, 2]);
        assert_eq!(compact(&[]), Vec::<usize>::new());
    }
}
