//! Label propagation (LPA) [Raghavan et al. '07] — the cheap second
//! detector: no objective function, just neighbor-majority voting.
//!
//! The update is *synchronous*: every node's next label is a pure
//! function of the frozen sweep-start labels (most frequent label among
//! its neighbors plus one self-vote, ties to the lowest label), so the
//! sweep dispatches on the shared [`Runtime`] with one writer per element
//! and is bitwise identical to the serial loop at any thread count. The
//! self-vote damps the classic two-node swap oscillation (a pair of
//! adjacent singletons converges to the lower label instead of trading
//! labels forever); a sweep cap bounds the pathological cases that
//! remain, and the result is always a valid labelling regardless of where
//! the cap lands.

use crate::graph::Graph;
use crate::util::pool::{uniform_chunks, Runtime, SendPtr};
use std::collections::HashMap;

/// Sweep cap — LPA usually settles in a handful of sweeps.
const MAX_SWEEPS: usize = 64;
/// Below this many nodes a sweep runs serially even with a runtime.
const PAR_MIN_NODES: usize = 512;

/// Next label for `v` against the frozen labels: most frequent neighbor
/// label with one vote added for v's own label; ties break low.
fn vote_one(g: &Graph, labels: &[usize], v: usize) -> usize {
    let own = labels[v];
    if g.degree(v) == 0 {
        return own;
    }
    let mut counts: HashMap<usize, u64> = HashMap::new();
    counts.insert(own, 1); // self-vote: damps synchronous swaps
    for &u in g.neighbors(v) {
        *counts.entry(labels[u as usize]).or_insert(0) += 1;
    }
    // Winner by (count, lowest label) — selection is order-independent.
    let mut best = (own, counts[&own]);
    for (&l, &c) in &counts {
        if c > best.1 || (c == best.1 && l < best.0) {
            best = (l, c);
        }
    }
    best.0
}

/// Synchronous label-propagation community detection. Returns one compact
/// label per node (0..k, first-occurrence order). Deterministic and
/// bitwise identical at any thread count.
pub fn lpa(g: &Graph, rt: Option<&Runtime>) -> Vec<usize> {
    let n = g.n();
    let mut labels: Vec<usize> = (0..n).collect();
    let mut next = vec![0usize; n];
    for sweep in 0..MAX_SWEEPS {
        let _span = crate::span!("community.lpa.sweep", sweep = sweep);
        match rt {
            Some(rt) if rt.threads() > 1 && n >= PAR_MIN_NODES => {
                let chunks = uniform_chunks(rt.threads() * 4, n);
                let ptr = SendPtr::new(next.as_mut_ptr());
                let frozen = &labels;
                rt.run(chunks.len(), &|ci| {
                    let (lo, hi) = chunks[ci];
                    for v in lo..hi {
                        // SAFETY: disjoint chunks, one writer per element,
                        // `next` outlives the blocking dispatch.
                        unsafe {
                            *ptr.get().add(v) = vote_one(g, frozen, v);
                        }
                    }
                });
            }
            _ => {
                for (v, slot) in next.iter_mut().enumerate() {
                    *slot = vote_one(g, &labels, v);
                }
            }
        }
        let changed = labels
            .iter()
            .zip(&next)
            .filter(|(a, b)| a != b)
            .count();
        std::mem::swap(&mut labels, &mut next);
        crate::obs_counter!("community.lpa.changes").add(changed as u64);
        if changed == 0 {
            break;
        }
    }
    super::louvain::compact(&labels)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::fixtures;

    #[test]
    fn pair_converges_to_lower_label() {
        let g = Graph::from_edges(2, &[(0, 1)]);
        assert_eq!(lpa(&g, None), vec![0, 0]);
    }

    #[test]
    fn caveman_caves_get_distinct_labels() {
        let ds = fixtures::caveman(15, 6); // two caves of 15, 2 bridges
        let labels = lpa(&ds.graph, None);
        let k = labels.iter().copied().max().unwrap() + 1;
        assert!((2..=6).contains(&k), "unexpected label count {k}");
        // The dominant label of each cave must differ.
        let dom = |lo: usize, hi: usize| -> usize {
            let mut c = std::collections::HashMap::new();
            for v in lo..hi {
                *c.entry(labels[v]).or_insert(0usize) += 1;
            }
            c.into_iter().max_by_key(|&(l, n)| (n, usize::MAX - l)).unwrap().0
        };
        assert_ne!(dom(0, 15), dom(15, 30), "caves merged: {labels:?}");
    }

    #[test]
    fn isolated_nodes_keep_singleton_labels() {
        let g = Graph::from_edges(4, &[(0, 1)]);
        let labels = lpa(&g, None);
        assert_eq!(labels[0], labels[1]);
        assert_ne!(labels[2], labels[3]);
        assert_ne!(labels[2], labels[0]);
    }

    #[test]
    fn parallel_dispatch_matches_serial_exactly() {
        let ds = crate::data::synth::generate(&crate::data::synth::AMAZON_PHOTO, 0.1, 3);
        let serial = lpa(&ds.graph, None);
        for t in [2usize, 8] {
            let rt = Runtime::new(t);
            assert_eq!(serial, lpa(&ds.graph, Some(&rt)), "lpa diverged at {t} threads");
        }
    }
}
