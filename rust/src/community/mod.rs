//! Community detection + partition analytics (DESIGN.md §13).
//!
//! The paper's premise is that training cost is governed by *community
//! structure* — dense intra-community subgraphs with thin boundaries —
//! yet the seed repo only had an edge-cut minimizer ([`crate::partition::metis`]).
//! This module adds true community detection and the analytics to judge
//! any partition:
//!
//! - [`louvain`] — multilevel modularity maximization (local moving +
//!   graph aggregation), deterministic at any thread count;
//! - [`lpa`] — synchronous label propagation, the cheap second detector;
//! - [`merge_to_m`] — deterministic size-aware merge/split that maps a
//!   variable number of detected communities onto exactly `m` balanced
//!   agents (respecting [`config::community_cap`]), so the resulting
//!   [`Partition`] plugs into ADMM, cluster-gcn, and the elastic
//!   transport unchanged;
//! - [`quality`] — modularity / edge-cut / boundary / conductance /
//!   balance analytics for any partition;
//! - [`save_partition_file`] / [`load_partition_file`] — a JSON
//!   assignment format (`cgcn-partition-v1`) so `cgcn partition` can
//!   export an assignment and `cgcn train --partition-file` can reuse it.

pub mod louvain;
pub mod lpa;
pub mod quality;

pub use louvain::louvain;
pub use lpa::lpa;
pub use quality::{evaluate, QualityReport};

use crate::config;
use crate::graph::Graph;
use crate::partition::Partition;
use crate::util::json::Json;
use crate::util::pool::Runtime;
use anyhow::{anyhow, bail, ensure, Context, Result};

/// Louvain detection mapped onto exactly `m` communities.
pub fn louvain_partition(g: &Graph, m: usize, rt: Option<&Runtime>) -> Partition {
    merge_to_m(g.n(), &louvain(g, rt), m)
}

/// LPA detection mapped onto exactly `m` communities.
pub fn lpa_partition(g: &Graph, m: usize, rt: Option<&Runtime>) -> Partition {
    merge_to_m(g.n(), &lpa(g, rt), m)
}

/// Map a detected labelling (any number of communities) onto exactly `m`
/// non-empty parts, each within [`config::community_cap`]. Deterministic:
/// no RNG, no iteration-order dependence.
///
/// Steps (DESIGN.md §13.2):
/// 1. compact labels by first occurrence → pieces (node ids ascending);
/// 2. split any piece over the cap into near-equal chunks under it;
/// 3. while fewer than `m` pieces, halve the largest (a size-≥2 piece
///    always exists while pieces < m ≤ n, by pigeonhole);
/// 4. sort pieces by (size desc, first node asc) and pack each into the
///    least-loaded bin (ties → lowest bin index). If a piece overflows
///    the cap, the bin is filled to the cap and the remainder spills to
///    the next-least-loaded bin — `m · cap ≥ n` guarantees room.
///
/// Because pieces arrive largest-first, the first `m` pieces land in `m`
/// distinct empty bins, so every part is non-empty.
pub fn merge_to_m(n: usize, labels: &[usize], m: usize) -> Partition {
    assert_eq!(labels.len(), n);
    assert!((1..=n).contains(&m), "need 1 <= m <= n");
    let cap = config::community_cap(n, m);
    // 1. Gather pieces; `compact` guarantees labels are 0..k dense.
    let labels = louvain::compact(labels);
    let k = labels.iter().copied().max().map_or(0, |x| x + 1);
    let mut pieces: Vec<Vec<usize>> = vec![Vec::new(); k];
    for (v, &c) in labels.iter().enumerate() {
        pieces[c].push(v);
    }
    // 2. Split oversized pieces into near-equal chunks under the cap.
    let mut sized: Vec<Vec<usize>> = Vec::with_capacity(pieces.len());
    for piece in pieces {
        if piece.len() <= cap {
            sized.push(piece);
            continue;
        }
        let chunks = piece.len().div_ceil(cap);
        let base = piece.len() / chunks;
        let extra = piece.len() % chunks;
        let mut pos = 0;
        for c in 0..chunks {
            let len = base + usize::from(c < extra);
            sized.push(piece[pos..pos + len].to_vec());
            pos += len;
        }
    }
    // 3. Guarantee at least m pieces by halving the largest.
    while sized.len() < m {
        let (big, _) = sized
            .iter()
            .enumerate()
            .max_by_key(|(i, p)| (p.len(), usize::MAX - i))
            .expect("pieces is non-empty since m >= 1 and n >= 1");
        let piece = std::mem::take(&mut sized[big]);
        debug_assert!(piece.len() >= 2, "pigeonhole: m <= n");
        let half = piece.len() / 2;
        sized[big] = piece[..half].to_vec();
        sized.push(piece[half..].to_vec());
    }
    // 4. Largest-first greedy packing with cap-spill.
    sized.sort_by(|a, b| b.len().cmp(&a.len()).then(a[0].cmp(&b[0])));
    let mut load = vec![0usize; m];
    let mut assignment = vec![0usize; n];
    for piece in &sized {
        let mut rest: &[usize] = piece;
        while !rest.is_empty() {
            let (bin, _) = load
                .iter()
                .enumerate()
                .min_by_key(|&(i, &l)| (l, i))
                .expect("m >= 1");
            let space = cap - load[bin];
            assert!(space > 0, "all bins at cap with nodes left (m*cap >= n)");
            let take = rest.len().min(space);
            for &v in &rest[..take] {
                assignment[v] = bin;
            }
            load[bin] += take;
            rest = &rest[take..];
        }
    }
    Partition::from_assignment(m, assignment)
}

/// File-format tag for exported assignments.
pub const PARTITION_FORMAT: &str = "cgcn-partition-v1";

/// A partition loaded from (or about to be written to) an assignment file.
#[derive(Clone, Debug)]
pub struct PartitionFile {
    /// Dataset name/path the assignment was computed on (advisory —
    /// import only checks the node count).
    pub dataset: String,
    /// Partitioner that produced it ("louvain", "metis", …).
    pub method: String,
    /// Seed it was produced with.
    pub seed: u64,
    pub partition: Partition,
}

/// Write an assignment file (`cgcn-partition-v1` JSON).
pub fn save_partition_file(path: &str, pf: &PartitionFile) -> Result<()> {
    let json = Json::obj(vec![
        ("format", Json::str(PARTITION_FORMAT)),
        ("dataset", Json::str(&pf.dataset)),
        ("n", Json::num(pf.partition.assignment.len() as f64)),
        ("m", Json::num(pf.partition.m() as f64)),
        ("method", Json::str(&pf.method)),
        ("seed", Json::num(pf.seed as f64)),
        (
            "assignment",
            Json::arr(
                pf.partition
                    .assignment
                    .iter()
                    .map(|&c| Json::num(c as f64))
                    .collect(),
            ),
        ),
    ]);
    std::fs::write(path, json.to_pretty() + "\n")
        .with_context(|| format!("writing partition file {path}"))
}

/// Load and validate an assignment file: format tag, coverage, community
/// count, and no empty community.
pub fn load_partition_file(path: &str) -> Result<PartitionFile> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading partition file {path}"))?;
    let json = Json::parse(&text).map_err(|e| anyhow!("{path}: invalid JSON: {e:?}"))?;
    let format = json.get("format").as_str().unwrap_or("");
    ensure!(
        format == PARTITION_FORMAT,
        "{path}: format {format:?}, want {PARTITION_FORMAT:?}"
    );
    let n = json.get("n").as_usize().context("missing n")?;
    let m = json.get("m").as_usize().context("missing m")?;
    ensure!((1..=n).contains(&m), "{path}: invalid m={m} for n={n}");
    let raw = json.get("assignment").as_arr().context("missing assignment")?;
    ensure!(
        raw.len() == n,
        "{path}: assignment has {} entries, header says n={n}",
        raw.len()
    );
    let mut assignment = Vec::with_capacity(n);
    for (v, j) in raw.iter().enumerate() {
        let c = j
            .as_usize()
            .with_context(|| format!("assignment[{v}] not an index"))?;
        if c >= m {
            bail!("{path}: assignment[{v}] = {c} out of range (m={m})");
        }
        assignment.push(c);
    }
    let partition = Partition::from_assignment(m, assignment);
    if let Some(empty) = partition.members.iter().position(|mem| mem.is_empty()) {
        bail!("{path}: community {empty} is empty");
    }
    Ok(PartitionFile {
        dataset: json.get("dataset").as_str().unwrap_or("").to_string(),
        method: json.get("method").as_str().unwrap_or("").to_string(),
        seed: json.get("seed").as_f64().unwrap_or(0.0) as u64,
        partition,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::fixtures;
    use crate::prop_assert;
    use crate::util::proplite;

    #[test]
    fn merge_keeps_exact_community_count() {
        // 5 detected communities of varying size onto m = 1..=8 agents.
        let labels = [0, 0, 0, 0, 1, 1, 2, 2, 2, 3, 4, 4];
        let n = labels.len();
        for m in 1..=8 {
            let p = merge_to_m(n, &labels, m);
            p.validate(n);
            assert_eq!(p.m(), m);
            assert!(p.members.iter().all(|mem| !mem.is_empty()), "m={m}");
            let cap = crate::config::community_cap(n, m);
            assert!(p.sizes().iter().all(|&s| s <= cap), "m={m}: {:?}", p.sizes());
        }
    }

    #[test]
    fn merge_preserves_small_communities_when_counts_match() {
        // k == m and everything under cap: pieces must not be split.
        let labels = [0, 0, 0, 1, 1, 1, 2, 2, 2];
        let p = merge_to_m(9, &labels, 3);
        let mut sizes = p.sizes();
        sizes.sort_unstable();
        assert_eq!(sizes, vec![3, 3, 3]);
        // Nodes 0-2 stayed together (in some bin).
        assert_eq!(p.assignment[0], p.assignment[1]);
        assert_eq!(p.assignment[1], p.assignment[2]);
    }

    #[test]
    fn merge_property_cover_nonempty_capped() {
        proplite::check("merge-to-m", 40, 0xC0DE, |g| {
            let n = g.usize_in(4, 120).max(4);
            let k = g.usize_in(1, n);
            let labels: Vec<usize> = (0..n).map(|_| g.usize_in(0, k - 1).min(k - 1)).collect();
            let m = g.usize_in(1, n.min(9)).clamp(1, n);
            let p = merge_to_m(n, &labels, m);
            let total: usize = p.sizes().iter().sum();
            prop_assert!(total == n, "cover {total} != {n} (m={m})");
            prop_assert!(p.m() == m, "got {} parts, want {m}", p.m());
            prop_assert!(
                p.members.iter().all(|mem| !mem.is_empty()),
                "empty part (n={n}, m={m}, sizes={:?})",
                p.sizes()
            );
            let cap = crate::config::community_cap(n, m);
            prop_assert!(
                p.sizes().iter().all(|&s| s <= cap),
                "cap {cap} exceeded (n={n}, m={m}, sizes={:?})",
                p.sizes()
            );
            // Determinism: same labels, same result.
            let p2 = merge_to_m(n, &labels, m);
            prop_assert!(p.assignment == p2.assignment, "merge_to_m not deterministic");
            Ok(())
        });
    }

    #[test]
    fn louvain_partition_is_valid_and_low_cut_on_caveman() {
        let ds = fixtures::caveman(20, 5);
        let p = louvain_partition(&ds.graph, 2, None);
        p.validate(ds.n());
        assert_eq!(p.m(), 2);
        // Two caves, two bridges: a community-aware split keeps the cut
        // near the bridge count (random would cut ~half the edges).
        let cut = p.edgecut(&ds.graph);
        assert!(cut <= 6, "louvain caveman edgecut {cut} too high");
    }

    #[test]
    fn partition_file_roundtrip() {
        let dir = std::env::temp_dir().join(format!("cgcn_part_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("p.json");
        let path = path.to_str().unwrap();
        let ds = fixtures::caveman(10, 1);
        let p = louvain_partition(&ds.graph, 3, None);
        let pf = PartitionFile {
            dataset: "caveman".into(),
            method: "louvain".into(),
            seed: 17,
            partition: p.clone(),
        };
        save_partition_file(path, &pf).unwrap();
        let back = load_partition_file(path).unwrap();
        assert_eq!(back.dataset, "caveman");
        assert_eq!(back.method, "louvain");
        assert_eq!(back.seed, 17);
        assert_eq!(back.partition.assignment, p.assignment);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn partition_file_rejects_bad_input() {
        let dir = std::env::temp_dir().join(format!("cgcn_part_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let write = |name: &str, text: &str| -> String {
            let p = dir.join(name);
            std::fs::write(&p, text).unwrap();
            p.to_str().unwrap().to_string()
        };
        // Wrong format tag.
        let p = write("fmt.json", r#"{"format":"nope","n":1,"m":1,"assignment":[0]}"#);
        assert!(load_partition_file(&p).is_err());
        // Out-of-range community id.
        let p = write(
            "range.json",
            r#"{"format":"cgcn-partition-v1","n":2,"m":2,"assignment":[0,2]}"#,
        );
        assert!(load_partition_file(&p).is_err());
        // Empty community.
        let p = write(
            "empty.json",
            r#"{"format":"cgcn-partition-v1","n":2,"m":2,"assignment":[0,0]}"#,
        );
        assert!(load_partition_file(&p).is_err());
        // Length mismatch.
        let p = write(
            "len.json",
            r#"{"format":"cgcn-partition-v1","n":3,"m":1,"assignment":[0,0]}"#,
        );
        assert!(load_partition_file(&p).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
