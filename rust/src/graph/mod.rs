//! Graph substrate: CSR sparse matrices, GCN normalisation, community
//! block extraction, induced-subgraph renormalisation (the mini-batch
//! primitive), and the SpMM hot path.
//!
//! The ADMM coordinator never materialises a dense adjacency matrix: all
//! `Ã`-products (the sparse half of every subproblem — see DESIGN.md §1)
//! run through [`Csr::spmm`] on per-community blocks extracted by
//! [`blocks::split_blocks`].

mod csr;
pub mod blocks;
pub mod subgraph;

pub use csr::{Csr, Graph};
pub use blocks::{split_blocks, BlockMatrix};
pub use subgraph::{induced_subgraph, induced_subgraph_with, InducedSubgraph};
