//! Node-induced subgraphs with per-batch renormalisation — the graph-side
//! primitive of Cluster-GCN style mini-batch training [Chiang et al. '19].
//!
//! Given a batch `B ⊆ V` (the union of a few partitioner clusters), the
//! mini-batch step runs the exact GCN propagation rule on the *induced*
//! subgraph `G[B]`: edges with both endpoints in `B`, degrees recomputed
//! within the batch, and the self-looped symmetric normalisation applied
//! over those induced degrees:
//!
//! ```text
//! Ã_B = (D_B + I)^{-1/2} (A_B + I) (D_B + I)^{-1/2}
//! ```
//!
//! This is *not* a row slice of the global `Ã` — cross-batch edges are
//! dropped and the normalisation denominators shrink accordingly, which is
//! what bounds every dense *training activation* to `|B|` rows (a bound
//! the full-batch path can never offer). The partitioner keeps clusters
//! dense, so few edges are lost in expectation (Cluster-GCN's argument).

use super::{Csr, Graph};

/// A node-induced subgraph in batch-local indexing, ready for mini-batch
/// forward/backward: local row `i` corresponds to global node `nodes[i]`.
#[derive(Clone, Debug)]
pub struct InducedSubgraph {
    /// Sorted global node ids of the batch (defines the local order).
    pub nodes: Vec<usize>,
    /// Renormalised adjacency `Ã_B` over the induced edges (|B| × |B|,
    /// symmetric, unit Perron structure like the global `Ã`).
    pub a_norm: Csr,
    /// Number of induced undirected edges (excluding self-loops).
    pub num_edges: usize,
}

impl InducedSubgraph {
    /// Batch size |B| — the row count of every dense activation in a
    /// mini-batch step.
    pub fn n(&self) -> usize {
        self.nodes.len()
    }
}

/// Extract the induced subgraph over `nodes` (must be sorted, unique and
/// in range) and build its renormalised adjacency.
///
/// Allocates a fresh O(n) local-index map; callers extracting many
/// batches from one graph should hold a scratch map and use
/// [`induced_subgraph_with`] instead, which is O(Σ_{v∈B} deg(v) + |B|)
/// per call.
pub fn induced_subgraph(g: &Graph, nodes: &[usize]) -> InducedSubgraph {
    let mut scratch = vec![u32::MAX; g.n()];
    induced_subgraph_with(g, nodes, &mut scratch)
}

/// [`induced_subgraph`] with a caller-owned global→local scratch map:
/// `scratch.len() == g.n()`, every entry `u32::MAX` on entry, restored to
/// that state on return — so repeated batch extraction does O(|B|)
/// map work per call instead of an O(n) allocation.
///
/// Rows come out sorted because `nodes` and every global neighbor list
/// are sorted, so the result feeds [`Csr::from_rows`] directly (same
/// construction as [`Graph::normalized_adjacency`], which is the `B = V`
/// special case).
pub fn induced_subgraph_with(
    g: &Graph,
    nodes: &[usize],
    scratch: &mut [u32],
) -> InducedSubgraph {
    let nb = nodes.len();
    assert_eq!(scratch.len(), g.n(), "scratch map needs one entry per node");
    debug_assert!(scratch.iter().all(|&x| x == u32::MAX), "dirty scratch map");
    let local = scratch;
    for (i, &v) in nodes.iter().enumerate() {
        assert!(v < g.n(), "batch node {v} out of range n={}", g.n());
        assert!(
            i == 0 || nodes[i - 1] < v,
            "batch nodes must be sorted and unique"
        );
        local[v] = i as u32;
    }

    // Induced degrees (within-batch neighbors only).
    let deg: Vec<usize> = nodes
        .iter()
        .map(|&v| {
            g.neighbors(v)
                .iter()
                .filter(|&&u| local[u as usize] != u32::MAX)
                .count()
        })
        .collect();
    let inv_sqrt: Vec<f32> = deg.iter().map(|&d| 1.0 / ((d + 1) as f32).sqrt()).collect();

    let mut num_edges = 0usize;
    let mut rows = Vec::with_capacity(nb);
    for (i, &v) in nodes.iter().enumerate() {
        let mut cols = Vec::with_capacity(deg[i] + 1);
        let mut vals = Vec::with_capacity(deg[i] + 1);
        let mut placed_diag = false;
        for &u in g.neighbors(v) {
            let j = local[u as usize];
            if j == u32::MAX {
                continue;
            }
            let j_us = j as usize;
            if j_us > i {
                num_edges += 1;
                if !placed_diag {
                    cols.push(i as u32);
                    vals.push(inv_sqrt[i] * inv_sqrt[i]);
                    placed_diag = true;
                }
            }
            cols.push(j);
            vals.push(inv_sqrt[i] * inv_sqrt[j_us]);
        }
        if !placed_diag {
            cols.push(i as u32);
            vals.push(inv_sqrt[i] * inv_sqrt[i]);
        }
        rows.push((cols, vals));
    }

    // Restore the scratch invariant (only touched entries — O(|B|)).
    for &v in nodes {
        local[v] = u32::MAX;
    }

    InducedSubgraph {
        nodes: nodes.to_vec(),
        a_norm: Csr::from_rows(nb, rows),
        num_edges,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Matrix;

    fn path_graph(n: usize) -> Graph {
        Graph::from_edges(n, &(0..n - 1).map(|i| (i, i + 1)).collect::<Vec<_>>())
    }

    #[test]
    fn full_node_set_matches_global_normalisation() {
        let g = path_graph(12);
        let all: Vec<usize> = (0..12).collect();
        let sub = induced_subgraph(&g, &all);
        assert_eq!(sub.num_edges, g.num_edges());
        let a = g.normalized_adjacency();
        assert!(sub.a_norm.to_dense().max_abs_diff(&a.to_dense()) < 1e-7);
    }

    #[test]
    fn induced_degrees_are_renormalised() {
        // Path 0-1-2-3; batch {0,1}: node 1 loses its edge to 2, so its
        // induced degree is 1 (not 2) and Ã_B[1,1] = 1/2, not 1/3.
        let g = path_graph(4);
        let sub = induced_subgraph(&g, &[0, 1]);
        assert_eq!(sub.n(), 2);
        assert_eq!(sub.num_edges, 1);
        assert!((sub.a_norm.get(0, 0) - 0.5).abs() < 1e-6);
        assert!((sub.a_norm.get(1, 1) - 0.5).abs() < 1e-6);
        assert!((sub.a_norm.get(0, 1) - 0.5).abs() < 1e-6);
        assert!(sub.a_norm.is_symmetric(1e-7));
    }

    #[test]
    fn batch_with_no_internal_edges_is_identity() {
        // Batch {0, 3} of a path: no induced edges → Ã_B = I.
        let g = path_graph(4);
        let sub = induced_subgraph(&g, &[0, 3]);
        assert_eq!(sub.num_edges, 0);
        assert!((sub.a_norm.get(0, 0) - 1.0).abs() < 1e-7);
        assert!((sub.a_norm.get(1, 1) - 1.0).abs() < 1e-7);
        assert_eq!(sub.a_norm.nnz(), 2);
    }

    #[test]
    fn perron_structure_survives_renormalisation() {
        // v_i = sqrt(d_i + 1) over *induced* degrees is an eigenvector of
        // Ã_B with eigenvalue 1 — same spectral sanity property the global
        // normalisation has.
        let ds = crate::data::fixtures::caveman(10, 4);
        let nodes: Vec<usize> = (3..17).collect();
        let sub = induced_subgraph(&ds.graph, &nodes);
        let deg: Vec<usize> = (0..sub.n())
            .map(|i| sub.a_norm.row(i).0.len() - 1)
            .collect();
        let v = Matrix::from_fn(sub.n(), 1, |r, _| ((deg[r] + 1) as f32).sqrt());
        let av = sub.a_norm.spmm(&v);
        assert!(av.max_abs_diff(&v) < 1e-5);
        assert!(sub.a_norm.is_symmetric(1e-7));
        for s in sub.a_norm.row_sums() {
            assert!(s > 0.0);
        }
    }

    #[test]
    #[should_panic(expected = "sorted and unique")]
    fn rejects_unsorted_batch() {
        let g = path_graph(4);
        induced_subgraph(&g, &[2, 1]);
    }

    #[test]
    fn scratch_variant_matches_and_restores() {
        // The reusable-scratch path must equal the allocating path and
        // leave the scratch all-MAX for the next batch.
        let ds = crate::data::fixtures::caveman(8, 2);
        let g = &ds.graph;
        let mut scratch = vec![u32::MAX; g.n()];
        for nodes in [vec![0, 1, 2, 3], vec![2, 5, 9, 10, 15], (0..g.n()).collect()] {
            let a = induced_subgraph(g, &nodes);
            let b = induced_subgraph_with(g, &nodes, &mut scratch);
            assert_eq!(a.a_norm, b.a_norm);
            assert_eq!(a.num_edges, b.num_edges);
            assert!(scratch.iter().all(|&x| x == u32::MAX), "scratch not restored");
        }
    }
}
